//! End-to-end driver across ALL THREE LAYERS (the e2e validation run
//! recorded in EXPERIMENTS.md):
//!
//!   L1/L2 — the Pallas/JAX strategy-latency model, AOT-compiled to HLO
//!           text by `make artifacts`;
//!   runtime — loaded and executed through PJRT from rust;
//!   L3 — the SM-AD adaptive strategy queries the model per transaction
//!        class and routes each transaction to SM-OB or SM-DD, beating
//!        both fixed strategies on a mixed workload.
//!
//! Run: `make artifacts && cargo run --release --example adaptive`

use pmsm::config::{Platform, StrategyKind};
use pmsm::coordinator::sched::{run_threads, TxnSource};
use pmsm::coordinator::{Mirror, ThreadCtx};
use pmsm::replication::TxnShape;
use pmsm::runtime::{fallback_predictor, LatencyModel};
use pmsm::workloads::transact::TransactConfig;
use pmsm::Ns;

/// Mixed workload: alternating small (4-1) and large (256-1) transactions
/// — exactly the regime where neither fixed strategy wins everywhere.
fn mixed_source(txns: u64) -> Box<dyn TxnSource> {
    let mut i = 0u64;
    Box::new(move |m: &mut Mirror, t: &mut ThreadCtx| {
        if i >= txns {
            return false;
        }
        let (epochs, writes) = if i % 2 == 0 { (4u32, 1u32) } else { (256, 1) };
        m.txn_begin(
            t,
            Some(TxnShape {
                epochs: epochs as f32,
                writes: writes as f32,
            }),
        );
        for e in 0..epochs {
            let addr = 0x6000_0000 + ((i * 301 + e as u64) % 4096) * 64;
            m.store(t, addr, i);
            m.clwb(t, addr);
            m.sfence(t);
        }
        m.txn_commit(t);
        i += 1;
        true
    })
}

fn run(kind: StrategyKind, plat: &Platform, txns: u64) -> Ns {
    let mut m = Mirror::new(plat.clone(), kind, false);
    let mut srcs: Vec<Box<dyn TxnSource>> = vec![mixed_source(txns)];
    run_threads(&mut m, &mut srcs).makespan
}

fn main() {
    let plat = Platform::default();
    let txns = 300u64;

    // L1/L2 model through PJRT (closed-form fallback if artifacts absent).
    let (predictor, source) = match LatencyModel::load(&plat) {
        Ok(model) => {
            println!("loaded AOT latency model (JAX/Pallas -> HLO text -> PJRT)");
            // Show the model's own Figure-4-style predictions.
            let e = [4.0f32, 256.0];
            let w = [1.0f32, 1.0];
            let (lat, _) = model.predict(&e, &w).expect("predict");
            for (i, l) in lat.iter().enumerate() {
                println!(
                    "  model {}-1: NO-SM {:.0}ns RC {:.0}ns OB {:.0}ns DD {:.0}ns -> {}",
                    e[i] as u32,
                    l[0],
                    l[1],
                    l[2],
                    l[3],
                    if l[2] < l[3] { "SM-OB" } else { "SM-DD" }
                );
            }
            (model.predictor().expect("predictor"), "pjrt")
        }
        Err(e) => {
            println!("artifacts unavailable ({e}); using closed-form fallback");
            (fallback_predictor(&plat), "fallback")
        }
    };

    // Fixed strategies on the mixed workload.
    let ob = run(StrategyKind::SmOb, &plat, txns);
    let dd = run(StrategyKind::SmDd, &plat, txns);

    // Adaptive: model-driven per-transaction routing.
    let mut m = Mirror::with_predictor(plat.clone(), StrategyKind::SmAd, predictor, false);
    let mut srcs: Vec<Box<dyn TxnSource>> = vec![mixed_source(txns)];
    let ad = run_threads(&mut m, &mut srcs).makespan;

    println!("\nmixed workload ({txns} txns, alternating 4-1 / 256-1):");
    println!("  SM-OB fixed    : {:.3} ms", ob as f64 / 1e6);
    println!("  SM-DD fixed    : {:.3} ms", dd as f64 / 1e6);
    println!("  SM-AD ({source:8}): {:.3} ms", ad as f64 / 1e6);
    let best = ob.min(dd);
    println!(
        "  adaptive vs best fixed: {:+.1}%",
        100.0 * (ad as f64 - best as f64) / best as f64
    );
    assert!(
        (ad as f64) <= best as f64 * 1.05,
        "adaptive should track or beat the best fixed strategy"
    );
    println!("adaptive OK");
}
