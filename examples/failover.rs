//! Failover drill: crash the primary at adversarial instants and recover
//! from the backup replica, verifying the paper's two guarantees
//! (failure atomicity + durability) at every crash point — first against
//! the paper's single backup, then against a 3-way replica group where a
//! backup is lost together with the primary.
//!
//! Run: `cargo run --release --example failover`

use pmsm::config::{AckPolicy, Platform, ReplicationConfig, StrategyKind};
use pmsm::coordinator::{Mirror, ThreadCtx};
use pmsm::pstore::log_base_for;
use pmsm::recovery::{best_prefix, check_crash, check_group_crashes, recover_image, TxnHistory};
use pmsm::txn::Txn;
use std::collections::HashMap;

fn main() {
    for kind in [StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd] {
        println!("=== strategy {kind} ===");
        let mut m = Mirror::new(Platform::default(), kind, true);
        let mut t = ThreadCtx::new(0);
        let log = log_base_for(0);
        let accounts: Vec<u64> = (0..4).map(|i| 0x4000_0000 + i * 64).collect();

        // A banking workload: each txn moves funds between two accounts.
        let mut hist = TxnHistory::new(HashMap::new());
        let mut img = HashMap::new();
        // Initial funding is itself a replicated transaction — the backup
        // must learn the opening balances.
        {
            let mut tx = Txn::begin(&mut m, &mut t, log, None);
            for &a in &accounts {
                tx.write(&mut m, &mut t, a, 1000);
                img.insert(a, 1000u64);
            }
            tx.commit(&mut m, &mut t);
            hist.commit(img.clone(), t.last_dfence);
        }
        for i in 0..12u64 {
            let from = accounts[(i % 4) as usize];
            let to = accounts[((i + 1) % 4) as usize];
            let mut tx = Txn::begin(&mut m, &mut t, log, None);
            let f = m.peek(from);
            let g = m.peek(to);
            tx.write(&mut m, &mut t, from, f - 50);
            tx.write(&mut m, &mut t, to, g + 50);
            tx.commit(&mut m, &mut t);
            img.insert(from, f - 50);
            img.insert(to, g + 50);
            hist.commit(img.clone(), t.last_dfence);
        }

        // Crash at every ledger event boundary and mid-flight instants.
        let ledger = &m.backup(0).ledger;
        let times: Vec<u64> = {
            let mut v: Vec<u64> = ledger.events().iter().map(|e| e.at).collect();
            v.sort_unstable();
            v.dedup();
            v
        };
        let mut worst_rollback = 0usize;
        let mut checked = 0;
        for &crash in &times {
            let k = check_crash(ledger, &hist, &[log], &accounts, crash)
                .unwrap_or_else(|e| panic!("{kind}: {e}"));
            worst_rollback = worst_rollback.max(hist.committed() - k.min(hist.committed()));
            checked += 1;
        }
        // Conservation law: money is conserved in every recovered state.
        for &crash in times.iter().step_by(7) {
            let rec = recover_image(ledger, crash, &[log]);
            let total: u64 = accounts
                .iter()
                .map(|a| rec.get(a).copied().unwrap_or(0))
                .sum();
            // Before the funding txn is durable the accounts read 0;
            // afterwards every consistent state conserves the 4000 total.
            assert!(
                total == 4000 || total < 4000 && crash <= hist.dfences[0],
                "{kind}: non-atomic balance {total} at crash {crash}"
            );
        }
        println!(
            "  {checked} crash points verified; deepest rollback: {worst_rollback} txn(s)"
        );
        println!("  final backup == primary: {}", {
            let rec = recover_image(ledger, ledger.horizon(), &[log]);
            accounts.iter().all(|a| rec.get(a) == Some(&m.peek(*a)))
        });
    }

    // ---- Replica-group drill: 3 backups, lose one together with the
    // primary; a quorum-2 policy must still recover every acked txn.
    for policy in [AckPolicy::All, AckPolicy::Quorum(2)] {
        println!("=== replica group: 3 backups, ack {policy} ===");
        let repl = ReplicationConfig::new(3, policy);
        let mut m =
            Mirror::with_replication(Platform::default(), StrategyKind::SmOb, repl, true)
                .expect("valid replica group");
        let mut t = ThreadCtx::new(0);
        let log = log_base_for(0);
        let accounts: Vec<u64> = (0..4).map(|i| 0x5000_0000 + i * 64).collect();
        let mut hist = TxnHistory::new(HashMap::new());
        let mut img = HashMap::new();
        for i in 0..10u64 {
            let a = accounts[(i % 4) as usize];
            let mut tx = Txn::begin(&mut m, &mut t, log, None);
            tx.write(&mut m, &mut t, a, 1000 + i);
            tx.commit(&mut m, &mut t);
            img.insert(a, 1000 + i);
            hist.commit(img.clone(), t.last_dfence);
        }
        let ledgers = m.fabric().ledgers();
        let checked =
            check_group_crashes(&ledgers, &hist, &[log], &accounts, repl.required())
                .expect("group durability");
        // Injected failure: drop each backup in turn; the best survivor
        // must keep every acked txn. Only unacked txns may be lost
        // relative to a no-failure recovery — track that depth.
        let horizon = m.fabric().group_horizon();
        let mut worst_unacked_loss = 0usize;
        for crash in (0..=horizon).step_by((horizon as usize / 16).max(1)) {
            let durable = hist.durable_by(crash);
            let prefixes: Vec<usize> = (0..3)
                .map(|b| {
                    best_prefix(ledgers[b], &hist, &[log], &accounts, crash)
                        .expect("atomicity per backup")
                })
                .collect();
            let no_failure_best = *prefixes.iter().max().unwrap();
            for failed in 0..3usize {
                let best = (0..3)
                    .filter(|&b| b != failed)
                    .map(|b| prefixes[b])
                    .max()
                    .unwrap();
                assert!(
                    best >= durable,
                    "ack {policy}: crash {crash}, backup {failed} lost: \
                     best survivor prefix {best} < durable {durable}"
                );
                worst_unacked_loss = worst_unacked_loss.max(no_failure_best - best);
            }
        }
        println!(
            "  {checked} crash points verified; any single backup loss \
             recovers all acked txns (deepest unacked-txn loss vs \
             no-failure recovery: {worst_unacked_loss})"
        );
    }
    println!("failover OK");
}
