//! Failover drill: crash the primary at adversarial instants and recover
//! from the backup replica, verifying the paper's two guarantees
//! (failure atomicity + durability) at every crash point.
//!
//! Run: `cargo run --release --example failover`

use pmsm::config::{Platform, StrategyKind};
use pmsm::coordinator::{Mirror, ThreadCtx};
use pmsm::pstore::log_base_for;
use pmsm::recovery::{check_crash, recover_image, TxnHistory};
use pmsm::txn::Txn;
use std::collections::HashMap;

fn main() {
    for kind in [StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd] {
        println!("=== strategy {kind} ===");
        let mut m = Mirror::new(Platform::default(), kind, true);
        let mut t = ThreadCtx::new(0);
        let log = log_base_for(0);
        let accounts: Vec<u64> = (0..4).map(|i| 0x4000_0000 + i * 64).collect();

        // A banking workload: each txn moves funds between two accounts.
        let mut hist = TxnHistory::new(HashMap::new());
        let mut img = HashMap::new();
        // Initial funding is itself a replicated transaction — the backup
        // must learn the opening balances.
        {
            let mut tx = Txn::begin(&mut m, &mut t, log, None);
            for &a in &accounts {
                tx.write(&mut m, &mut t, a, 1000);
                img.insert(a, 1000u64);
            }
            tx.commit(&mut m, &mut t);
            hist.commit(img.clone(), t.last_dfence);
        }
        for i in 0..12u64 {
            let from = accounts[(i % 4) as usize];
            let to = accounts[((i + 1) % 4) as usize];
            let mut tx = Txn::begin(&mut m, &mut t, log, None);
            let f = m.peek(from);
            let g = m.peek(to);
            tx.write(&mut m, &mut t, from, f - 50);
            tx.write(&mut m, &mut t, to, g + 50);
            tx.commit(&mut m, &mut t);
            img.insert(from, f - 50);
            img.insert(to, g + 50);
            hist.commit(img.clone(), t.last_dfence);
        }

        // Crash at every ledger event boundary and mid-flight instants.
        let ledger = &m.rdma.remote.ledger;
        let times: Vec<u64> = {
            let mut v: Vec<u64> = ledger.events().iter().map(|e| e.at).collect();
            v.sort_unstable();
            v.dedup();
            v
        };
        let mut worst_rollback = 0usize;
        let mut checked = 0;
        for &crash in &times {
            let k = check_crash(ledger, &hist, &[log], &accounts, crash)
                .unwrap_or_else(|e| panic!("{kind}: {e}"));
            worst_rollback = worst_rollback.max(hist.committed() - k.min(hist.committed()));
            checked += 1;
        }
        // Conservation law: money is conserved in every recovered state.
        for &crash in times.iter().step_by(7) {
            let rec = recover_image(ledger, crash, &[log]);
            let total: u64 = accounts
                .iter()
                .map(|a| rec.get(a).copied().unwrap_or(0))
                .sum();
            // Before the funding txn is durable the accounts read 0;
            // afterwards every consistent state conserves the 4000 total.
            assert!(
                total == 4000 || total < 4000 && crash <= hist.dfences[0],
                "{kind}: non-atomic balance {total} at crash {crash}"
            );
        }
        println!(
            "  {checked} crash points verified; deepest rollback: {worst_rollback} txn(s)"
        );
        println!("  final backup == primary: {}", {
            let rec = recover_image(ledger, ledger.horizon(), &[log]);
            accounts.iter().all(|a| rec.get(a) == Some(&m.peek(*a)))
        });
    }
    println!("failover OK");
}
