//! Quickstart: mirror a handful of undo-log transactions with SM-OB and
//! inspect what reached the backup.
//!
//! Run: `cargo run --release --example quickstart`

use pmsm::config::{Platform, StrategyKind};
use pmsm::coordinator::{Mirror, ThreadCtx};
use pmsm::pstore::log_base_for;
use pmsm::txn::Txn;

fn main() {
    // A primary/backup pair with the paper's platform model (Table 2).
    let platform = Platform::default();
    println!("{}\n", platform.table2());

    // Mirror with ordered buffering (SM-OB) and the durability ledger on.
    let mut mirror = Mirror::new(platform, StrategyKind::SmOb, true);
    let mut thread = ThreadCtx::new(0);
    let log = log_base_for(0);

    // Three failure-atomic transactions over two accounts.
    let alice = 0x1000_0000u64;
    let bob = 0x1000_0040u64;
    mirror.store(&mut thread, alice, 100);
    mirror.store(&mut thread, bob, 100);
    for i in 0..3u64 {
        let mut tx = Txn::begin(&mut mirror, &mut thread, log, None);
        let a = mirror.peek(alice);
        let b = mirror.peek(bob);
        tx.write(&mut mirror, &mut thread, alice, a - 10);
        tx.write(&mut mirror, &mut thread, bob, b + 10);
        tx.commit(&mut mirror, &mut thread);
        println!(
            "txn {i}: alice={} bob={} (t = {} ns, dfence complete)",
            mirror.peek(alice),
            mirror.peek(bob),
            thread.now()
        );
    }

    // Everything the primary persisted is durable on the backup.
    let ledger = &mirror.backup(0).ledger;
    println!(
        "\nbackup ledger: {} durable line writes, horizon {} ns",
        ledger.len(),
        ledger.horizon()
    );
    let img = ledger.image_at(ledger.horizon());
    println!(
        "backup image: alice={} bob={} (exactly mirrors the primary)",
        img[&alice], img[&bob]
    );
    assert_eq!(img[&alice], mirror.peek(alice));
    assert_eq!(img[&bob], mirror.peek(bob));
    println!("quickstart OK");
}
