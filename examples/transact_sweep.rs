//! Figure-4 sweep (paper §7.1): the Transact microbenchmark across all
//! replication strategies, printed as the paper's slowdown table, plus
//! the A1 crossover scan.
//!
//! Run: `cargo run --release --example transact_sweep [txns-per-cell]`

use pmsm::cli::fig4_sweep;
use pmsm::config::Platform;
use pmsm::metrics::report::fig4_table;

fn main() {
    let txns: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(10_000);
    let plat = Platform::default();

    let rows = fig4_sweep(&plat, txns, 1);
    println!("{}", fig4_table(&rows, None));

    println!("A1 — OB/DD crossover at w=1 (paper: DD wins small txns, OB large):");
    for r in rows.iter().filter(|r| r.writes == 1) {
        let winner = if r.ob < r.dd { "SM-OB" } else { "SM-DD" };
        println!(
            "  e={:<4} OB {:5.1}x  DD {:5.1}x  -> {winner}",
            r.epochs, r.ob, r.dd
        );
    }
}
