//! Figure-5 suite (paper §7.2): the five SM-extended WHISPER applications
//! under every replication strategy — execution time, throughput and the
//! H1 headline comparison.
//!
//! Run: `cargo run --release --example whisper_suite [ops-per-thread]`

use pmsm::cli::fig5_suite;
use pmsm::config::{Platform, StrategyKind};
use pmsm::metrics::report::fig5_tables;
use pmsm::workloads::{run_whisper, WhisperApp, WhisperConfig};

fn main() {
    let ops: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000);
    let plat = Platform::default();

    let rows = fig5_suite(&plat, ops, 4, None);
    println!("{}", fig5_tables(&rows));

    // Workload characterization (paper §7.2 discussion).
    println!("workload characterization (NO-SM):");
    println!("{:>8} {:>10} {:>12} {:>12}", "app", "txns", "epochs/txn", "writes/epoch");
    for app in WhisperApp::ALL {
        let cfg = WhisperConfig {
            app,
            ops: if app == WhisperApp::Echo { ops / 16 } else { ops }.max(10),
            threads: 4,
            seed: 42,
        };
        let out = run_whisper(&plat, StrategyKind::NoSm, cfg);
        println!(
            "{:>8} {:>10} {:>12.1} {:>12.2}",
            app.name(),
            out.txns,
            out.epochs_per_txn(),
            out.writes_per_epoch()
        );
    }
}
