#!/usr/bin/env python3
"""Schema / sanity checker for the BENCH_*.json artifacts the fig*
benches emit (see rust/src/bench.rs and rust/src/util/json.rs).

CI's bench-smoke job runs every bench with PMSM_BENCH_JSON_DIR pointed
at a scratch directory and then fails the build if any artifact is
missing, malformed, or carries non-finite / negative numbers — so perf
regressions in the fan-out hot path surface per-PR instead of rotting
in stdout.

Usage:
    python3 python/check_bench_json.py DIR_OR_FILE [...]
        [--expect name1,name2,...] [--compare BASELINE_DIR]

Exit code 0 when every document passes; 1 otherwise, with one line per
problem. --expect asserts that BENCH_<name>.json exists for each listed
bench (catching a bench that silently failed to emit). --compare checks
the run's counters against checked-in baseline artifacts (same file
names, results matched by name) and fails on a >10% regression in
busy_ns or wire_wqes; benches or results with no baseline counterpart
are skipped, so freshly added benches don't block until their baseline
lands.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys
from pathlib import Path

# Must match rust/src/util/json.rs::SCHEMA_VERSION.
SCHEMA_VERSION = 1

REQUIRED_RESULT_KEYS = ("name", "iters", "mean_ns", "stddev_ns", "min_ns")
OPTIONAL_NUMBER_KEYS = ("elems_per_iter", "elems_per_sec")
# Staged-pipeline and membership counters (rust/src/net/wqe.rs and
# rust/src/net/membership.rs): optional everywhere, but whenever present
# they must be non-negative ints, the amortization lattice must hold
# (doorbells <= wire_wqes <= posted_wqes, i.e. mean batch and mean span
# are both >= 1 whenever anything rang), and each bench listed below
# must emit its counter set on every result.
COUNTER_KEYS = (
    "doorbells",
    "posted_wqes",
    "wire_wqes",
    "combined_writes",
    "busy_ns",
    "fences_issued",
    "fence_piggybacks",
    "txns_committed",
    "membership_epochs",
    "failover_downtime_ns",
    "rereplicated_lines",
    "revoked_wqes",
    "flush_verbs",
    "compaction_lines",
    "volatile_window_ns",
    "chose_ob",
    "chose_dd",
    "adaptive_switches",
    "feedback_samples",
    "retransmits",
    "timeouts",
    "rnr_naks",
    "qp_resets",
    "dup_drops",
    "dups_injected",
)
BENCHES_REQUIRING_COUNTERS = {
    "fig9_batching": ("doorbells", "posted_wqes", "busy_ns"),
    "fig10_coalescing": (
        "doorbells",
        "posted_wqes",
        "wire_wqes",
        "combined_writes",
        "busy_ns",
    ),
    "fig11_concurrency": (
        "fences_issued",
        "fence_piggybacks",
        "txns_committed",
        "busy_ns",
    ),
    "fig12_failover_primary": (
        "membership_epochs",
        "failover_downtime_ns",
        "rereplicated_lines",
        "revoked_wqes",
        "txns_committed",
        "busy_ns",
    ),
    "fig13_persist_domains": (
        "flush_verbs",
        "compaction_lines",
        "volatile_window_ns",
        "doorbells",
        "txns_committed",
    ),
    "fig14_adaptive": (
        "chose_ob",
        "chose_dd",
        "adaptive_switches",
        "txns_committed",
        "busy_ns",
    ),
    "fig15_lossy_links": (
        "retransmits",
        "timeouts",
        "rnr_naks",
        "qp_resets",
        "dup_drops",
        "txns_committed",
    ),
}

# Counters compared against checked-in baselines under --compare; a
# current value more than REGRESSION_TOLERANCE above the baseline fails.
REGRESSION_KEYS = ("busy_ns", "wire_wqes")
REGRESSION_TOLERANCE = 0.10


def _is_finite_number(x) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool) and math.isfinite(x)


def check_result(
    doc_name: str, i: int, result, require_counters: tuple = ()
) -> list[str]:
    errors = []
    where = f"{doc_name}: results[{i}]"
    if not isinstance(result, dict):
        return [f"{where}: not an object"]
    for key in REQUIRED_RESULT_KEYS:
        if key not in result:
            errors.append(f"{where}: missing key {key!r}")
    for key in require_counters:
        if key not in result:
            errors.append(f"{where}: missing batching counter {key!r}")
    name = result.get("name")
    if "name" in result and (not isinstance(name, str) or not name):
        errors.append(f"{where}: name must be a nonempty string, got {name!r}")
    iters = result.get("iters")
    if "iters" in result and (not isinstance(iters, int) or isinstance(iters, bool) or iters <= 0):
        errors.append(f"{where}: iters must be a positive integer, got {iters!r}")
    for key in ("mean_ns", "stddev_ns", "min_ns"):
        if key not in result:
            continue
        v = result[key]
        if not _is_finite_number(v) or v < 0:
            errors.append(f"{where}: {key} must be a finite number >= 0, got {v!r}")
    for key in OPTIONAL_NUMBER_KEYS:
        v = result.get(key)
        if v is not None and (not _is_finite_number(v) or v < 0):
            errors.append(f"{where}: {key} must be null or a finite number >= 0, got {v!r}")
    for key in COUNTER_KEYS:
        v = result.get(key)
        if v is not None and (not isinstance(v, int) or isinstance(v, bool) or v < 0):
            errors.append(f"{where}: {key} must be a non-negative integer, got {v!r}")
    doorbells = result.get("doorbells")
    posted = result.get("posted_wqes")
    wire = result.get("wire_wqes")
    if isinstance(doorbells, int) and isinstance(posted, int) and doorbells > posted:
        errors.append(
            f"{where}: doorbells ({doorbells}) exceed posted_wqes ({posted}) — "
            "a doorbell launches at least one WQE, so mean batch must be >= 1"
        )
    if isinstance(wire, int) and isinstance(posted, int) and wire > posted:
        errors.append(
            f"{where}: wire_wqes ({wire}) exceed posted_wqes ({posted}) — "
            "a wire WQE carries at least one line, so mean span must be >= 1"
        )
    if isinstance(doorbells, int) and isinstance(wire, int) and doorbells > wire:
        errors.append(
            f"{where}: doorbells ({doorbells}) exceed wire_wqes ({wire}) — "
            "every doorbell launches at least one wire WQE"
        )
    fences = result.get("fences_issued")
    txns = result.get("txns_committed")
    if isinstance(fences, int) and isinstance(txns, int) and fences > txns:
        errors.append(
            f"{where}: fences_issued ({fences}) exceed txns_committed ({txns}) — "
            "a commit blocks on at most one issued fence, so group fencing "
            "can only push fences/txn below 1"
        )
    flush_verbs = result.get("flush_verbs")
    if isinstance(flush_verbs, int) and isinstance(doorbells, int) and flush_verbs > doorbells:
        errors.append(
            f"{where}: flush_verbs ({flush_verbs}) exceed doorbells ({doorbells}) — "
            "a flush verb only counts when it drains staged volatile lines, "
            "so every flush rides a rung doorbell"
        )
    switches = result.get("adaptive_switches")
    if isinstance(switches, int) and isinstance(txns, int) and switches > txns:
        errors.append(
            f"{where}: adaptive_switches ({switches}) exceed txns_committed "
            f"({txns}) — the controller applies at most one knob-vector "
            "change per transaction begin"
        )
    retransmits = result.get("retransmits")
    timeouts = result.get("timeouts")
    if isinstance(retransmits, int) and isinstance(timeouts, int) and timeouts > retransmits:
        errors.append(
            f"{where}: timeouts ({timeouts}) exceed retransmits ({retransmits}) — "
            "every ACK-timeout expiry re-sends, while RNR NAK retries re-send "
            "without a timeout, so retransmits >= timeouts always"
        )
    dup_drops = result.get("dup_drops")
    dups_injected = result.get("dups_injected")
    if (
        isinstance(dup_drops, int)
        and isinstance(retransmits, int)
        and isinstance(dups_injected, int)
        and dup_drops > retransmits + dups_injected
    ):
        errors.append(
            f"{where}: dup_drops ({dup_drops}) exceed retransmits "
            f"({retransmits}) + dups_injected ({dups_injected}) — the PSN "
            "dedup can only drop deliveries some re-send or dup event created"
        )
    return errors


def check_document(path: Path) -> list[str]:
    try:
        doc = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable or invalid JSON: {e}"]
    errors = []
    if not isinstance(doc, dict):
        return [f"{path}: top level must be an object"]
    version = doc.get("schema_version")
    if version != SCHEMA_VERSION:
        errors.append(
            f"{path}: schema_version must be {SCHEMA_VERSION}, got {version!r}"
        )
    bench = doc.get("bench")
    if not isinstance(bench, str) or not bench:
        errors.append(f"{path}: bench must be a nonempty string, got {bench!r}")
    elif path.name != f"BENCH_{bench}.json":
        errors.append(f"{path}: bench {bench!r} does not match the file name")
    results = doc.get("results")
    require_counters = BENCHES_REQUIRING_COUNTERS.get(bench, ())
    if not isinstance(results, list):
        errors.append(f"{path}: results must be a list, got {type(results).__name__}")
    elif not results:
        errors.append(f"{path}: results is empty — the bench measured nothing")
    else:
        for i, result in enumerate(results):
            errors.extend(check_result(str(path), i, result, require_counters))
    return errors


def compare_against_baseline(files: list[Path], baseline_dir: str) -> list[str]:
    """Flag >REGRESSION_TOLERANCE regressions in REGRESSION_KEYS against
    the checked-in baseline artifacts. Results are matched by (file
    name, result name); anything without a baseline counterpart is
    skipped so a new bench doesn't fail until its baseline is committed.
    """
    base = Path(baseline_dir)
    if not base.is_dir():
        return [f"--compare: baseline directory {baseline_dir!r} does not exist"]
    errors: list[str] = []
    compared = 0
    for f in files:
        bpath = base / f.name
        if not bpath.exists():
            continue
        try:
            cur = json.loads(f.read_text())
            old = json.loads(bpath.read_text())
        except (OSError, json.JSONDecodeError) as e:
            errors.append(f"--compare: unreadable baseline pair for {f.name}: {e}")
            continue
        old_results = {
            r.get("name"): r
            for r in old.get("results", [])
            if isinstance(r, dict) and isinstance(r.get("name"), str)
        }
        for r in cur.get("results", []):
            if not isinstance(r, dict):
                continue
            o = old_results.get(r.get("name"))
            if not isinstance(o, dict):
                continue
            for key in REGRESSION_KEYS:
                cv, ov = r.get(key), o.get(key)
                if not (isinstance(cv, int) and not isinstance(cv, bool)):
                    continue
                if not (isinstance(ov, int) and not isinstance(ov, bool)) or ov <= 0:
                    continue
                compared += 1
                if cv > ov * (1.0 + REGRESSION_TOLERANCE):
                    errors.append(
                        f"{f}: {r['name']}: {key} regressed {ov} -> {cv} "
                        f"(+{(cv / ov - 1.0) * 100.0:.1f}%, limit "
                        f"{REGRESSION_TOLERANCE * 100.0:.0f}%) vs {bpath}"
                    )
    if compared == 0 and not errors:
        # Not a failure — until the first real bench run commits its
        # baselines there is nothing to regress against — but it MUST be
        # loud: a silently skipped gate reads as a passing gate. Shout on
        # stderr and, when running under GitHub Actions, surface a
        # workflow warning annotation so the skip is visible on the run
        # summary instead of buried in the job log.
        msg = (
            f"--compare found no overlapping counters under "
            f"{baseline_dir!r}; the regression gate DID NOT RUN "
            f"(commit baseline BENCH_*.json artifacts to arm it)"
        )
        print(f"check_bench_json: WARNING: {msg}", file=sys.stderr)
        if os.environ.get("GITHUB_ACTIONS") == "true":
            print(f"::warning title=bench regression gate skipped::{msg}")
    return errors


def collect(paths: list[str]) -> list[Path]:
    files: list[Path] = []
    for raw in paths:
        p = Path(raw)
        if p.is_dir():
            files.extend(sorted(p.glob("BENCH_*.json")))
        else:
            files.append(p)
    return files


def main(argv: list[str]) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("paths", nargs="+", help="BENCH_*.json files or directories")
    parser.add_argument(
        "--expect",
        default="",
        help="comma-separated bench names that must be present (e.g. "
        "fig4_transact,fig8_shards)",
    )
    parser.add_argument(
        "--compare",
        default="",
        metavar="BASELINE_DIR",
        help="directory of checked-in baseline BENCH_*.json artifacts; "
        f"fail on a >{REGRESSION_TOLERANCE:.0%} regression in "
        f"{'/'.join(REGRESSION_KEYS)} (results matched by name)",
    )
    args = parser.parse_args(argv)

    files = collect(args.paths)
    errors: list[str] = []
    if not files:
        errors.append(f"no BENCH_*.json artifacts found under {args.paths}")

    present = {f.name for f in files}
    for name in filter(None, (s.strip() for s in args.expect.split(","))):
        want = f"BENCH_{name}.json"
        if want not in present:
            errors.append(f"expected artifact {want} was not emitted")

    for f in files:
        errors.extend(check_document(f))

    if args.compare:
        errors.extend(compare_against_baseline(files, args.compare))

    if errors:
        for e in errors:
            print(f"check_bench_json: FAIL: {e}", file=sys.stderr)
        return 1
    total = len(files)
    print(f"check_bench_json: OK — {total} artifact(s) pass schema v{SCHEMA_VERSION}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
