"""AOT: lower the L2 model to HLO *text* artifacts for the rust PJRT runtime.

HLO text — NOT `lowered.compile().serialize()` and NOT the serialized
HloModuleProto — is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids which xla_extension 0.5.1 (the version the published
`xla` 0.1.6 crate links) rejects (`proto.id() <= INT_MAX`). The HLO text
parser on the rust side reassigns ids and round-trips cleanly.

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_latency_model() -> str:
    n = model.MODEL_N
    spec_v = jax.ShapeDtypeStruct((n,), jnp.float32)
    spec_p = jax.ShapeDtypeStruct((16,), jnp.float32)
    lowered = jax.jit(model.strategy_model).lower(spec_v, spec_v, spec_p)
    return to_hlo_text(lowered)


def lower_cache_index() -> str:
    n = model.INDEX_N
    spec_a = jax.ShapeDtypeStruct((n,), jnp.uint64)
    spec_m = jax.ShapeDtypeStruct((8,), jnp.uint64)
    spec_meta = jax.ShapeDtypeStruct((2,), jnp.uint64)
    lowered = jax.jit(model.cache_index_model).lower(spec_a, spec_m, spec_meta)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    for name, fn in (
        ("latency_model", lower_latency_model),
        ("cache_index", lower_cache_index),
    ):
        text = fn()
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text)} chars to {path}")


if __name__ == "__main__":
    main()
