"""L1 Pallas kernel: batch LLC set-index computation.

Maps batches of physical line addresses to global LLC set indices using the
Intel complex-addressing slice hash (Maurice et al. [41]): slice bit i is the
XOR-fold (popcount parity) of the address masked with `masks[i]`; the local
set index is taken from address bits [6, 6+log2(sets_per_slice)).

The rust coordinator uses the AOT artifact of this kernel to annotate
workload traces with cache-set pressure in bulk (one PJRT call per trace
chunk), mirroring rust/src/mem/addr.rs which implements the identical hash
for the simulator hot path.

TPU mapping: pure integer VPU work; addresses stream HBM->VMEM in BLOCK-sized
tiles; masks (a handful of u64s) are replicated per step. interpret=True for
CPU execution (see latency.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 256
N_MASKS = 8  # supports up to 256 slices; unused masks are zero


def _cache_index_kernel(masks_ref, meta_ref, addr_ref, out_ref):
    """addr_ref: u64[BLOCK]; masks_ref: u64[N_MASKS]; meta_ref: u64[2] =
    [sets_per_slice, n_mask_bits]; out_ref: i32[BLOCK]."""
    addr = addr_ref[...]
    masks = masks_ref[...]
    sets_per_slice = meta_ref[0]

    bits = jax.lax.population_count(addr[:, None] & masks[None, :]) & jnp.uint64(1)
    weights = (jnp.uint64(1) << jnp.arange(N_MASKS, dtype=jnp.uint64))[None, :]
    # Zero masks produce popcount 0 -> bit 0, so unused mask slots are inert.
    slice_idx = jnp.sum(bits * weights, axis=1)
    local_set = (addr >> jnp.uint64(6)) & (sets_per_slice - jnp.uint64(1))
    out_ref[...] = (slice_idx * sets_per_slice + local_set).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=())
def cache_index(addr, masks, sets_per_slice):
    """Global LLC set index for each address.

    Args:
      addr: u64[n] physical line addresses.
      masks: u64[k<=N_MASKS] slice-hash XOR masks.
      sets_per_slice: int (power of two).
    Returns:
      i32[n].
    """
    addr = jnp.asarray(addr, jnp.uint64)
    masks = jnp.asarray(masks, jnp.uint64)
    k = masks.shape[0]
    if k < N_MASKS:
        masks = jnp.concatenate([masks, jnp.zeros((N_MASKS - k,), jnp.uint64)])
    meta = jnp.array([sets_per_slice, k], jnp.uint64)

    n = addr.shape[0]
    n_pad = -n % BLOCK
    if n_pad:
        addr = jnp.concatenate([addr, jnp.zeros((n_pad,), jnp.uint64)])
    grid = (addr.shape[0] // BLOCK,)
    out = pl.pallas_call(
        _cache_index_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((N_MASKS,), lambda i: (0,)),
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((addr.shape[0],), jnp.int32),
        interpret=True,
    )(masks, meta, addr)
    return out[:n]
