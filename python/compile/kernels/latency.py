"""L1 Pallas kernel: replication-strategy latency model.

Evaluates the closed-form per-transaction latency of the paper's four
replication strategies (NO-SM, SM-RC, SM-OB, SM-DD) for a batch of
(epochs/txn, writes/epoch) configurations.

TPU mapping (DESIGN.md §Hardware-Adaptation): the configuration batch is
tiled into VMEM-resident blocks via BlockSpec; the per-config arithmetic is
pure element-wise VPU work vectorized over the lane dimension; the 16-entry
platform parameter vector rides along as a whole-array block (scalar
prefetch-like). `interpret=True` is mandatory on this CPU test bed — a real
TPU lowering would emit a Mosaic custom-call the CPU PJRT plugin cannot run.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import params as P

# Block of configurations processed per grid step. 256 configs x (2 inputs +
# 4 outputs) x 4 B = 6 KiB per step — far inside a TPU core's VMEM budget,
# leaving headroom for double-buffering the HBM->VMEM stream.
BLOCK = 256


def _latency_kernel(p_ref, e_ref, w_ref, lat_ref):
    """Pallas kernel body. e_ref/w_ref: f32[BLOCK]; p_ref: f32[16];
    lat_ref: f32[BLOCK, 4]."""
    e = e_ref[...]
    w = w_ref[...]
    p = p_ref[...]

    rtt = p[P.P_RTT]
    gap = p[P.P_GAP]
    nqp = p[P.P_NQP]
    llc_mc = p[P.P_LLC_MC]
    mc_pm = p[P.P_MC_PM]
    store = p[P.P_STORE]
    flush = p[P.P_FLUSH]
    sfence = p[P.P_SFENCE]
    banks = p[P.P_MC_BANKS]
    ob_barrier = p[P.P_OB_BARRIER]
    qp_depth = p[P.P_QP_DEPTH]
    nt_serial = p[P.P_NT_SERIAL]
    ddio_lines = p[P.P_LLC_DDIO_LINES]

    n = e * w

    local_epoch = w * (store + flush) + sfence + w * llc_mc
    lat_nosm = e * local_epoch

    rc_remote_epoch = w * gap + rtt + w * llc_mc + mc_pm
    lat_rc = e * jnp.maximum(local_epoch, rc_remote_epoch)

    ob_issue = n * (gap / nqp) + e * (gap / nqp + ob_barrier)
    ob_drain = n * (mc_pm / banks)
    ob_overflow = jnp.maximum(0.0, n - ddio_lines) * (mc_pm / banks)
    lat_ob = (
        jnp.maximum(jnp.maximum(ob_issue, e * local_epoch), ob_drain)
        + ob_overflow
        + rtt
        + mc_pm  # rdfence: last-line PM landing (rcommit-like drain tail)
    )

    dd_issue = n * gap
    dd_serial = jnp.maximum(0.0, n - qp_depth) * jnp.maximum(0.0, nt_serial - gap)
    lat_dd = jnp.maximum(e * local_epoch, dd_issue + dd_serial) + rtt

    lat_ref[...] = jnp.stack([lat_nosm, lat_rc, lat_ob, lat_dd], axis=-1)


@functools.partial(jax.jit, static_argnames=())
def latency(e, w, p):
    """Per-transaction latency (ns) for [NO-SM, SM-RC, SM-OB, SM-DD].

    Args:
      e: f32[n] epochs/txn; w: f32[n] writes/epoch (n multiple of BLOCK, or
         it is padded); p: f32[16] platform vector.
    Returns:
      f32[n, 4].
    """
    e = jnp.asarray(e, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    p = jnp.asarray(p, jnp.float32)
    n = e.shape[0]
    n_pad = -n % BLOCK
    if n_pad:
        # Pad with a benign config (1 epoch, 1 write) — sliced off below.
        e = jnp.concatenate([e, jnp.ones((n_pad,), jnp.float32)])
        w = jnp.concatenate([w, jnp.ones((n_pad,), jnp.float32)])
    grid = (e.shape[0] // BLOCK,)
    out = pl.pallas_call(
        _latency_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((P.N_PARAMS,), lambda i: (0,)),  # params: replicated
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK, P.N_STRATEGIES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((e.shape[0], P.N_STRATEGIES), jnp.float32),
        interpret=True,
    )(p, e, w)
    return out[:n]


def slowdowns(e, w, p):
    """Slowdown over NO-SM for [SM-RC, SM-OB, SM-DD] — Figure 4 series."""
    lat = latency(e, w, p)
    return lat[:, 1:] / lat[:, :1]
