"""Platform parameter vector shared by the L1 kernels, the jnp oracle and the
rust simulator (rust/src/config/platform.rs mirrors the same indices).

All latencies are in nanoseconds. The defaults correspond to the paper's §6.1
model parameters (Xeon E5-2630 v3 + ConnectX-3) — see DESIGN.md §6.

The parameter vector is passed to the AOT-compiled model as a plain f32[16]
operand so the rust coordinator can re-evaluate the model for any platform
configuration without re-running Python.
"""

# Parameter vector indices (f32[16]).
P_RTT = 0  # RDMA small-message round-trip (ns)
P_GAP = 1  # per-WQE issue gap on one QP (ns)
P_NQP = 2  # number of QPs used by parallel strategies (SM-OB)
P_PCIE_RT = 3  # PCIe write round-trip to LLC (ns) — paper: 200
P_LLC_MC = 4  # LLC -> memory-controller queue transfer (ns) — paper: 10
P_MC_PM = 5  # MC queue -> PM write latency (ns) — paper: 150
P_MCQ = 6  # MC write queue depth (entries) — paper: 64
P_STORE = 7  # local store issue (ns)
P_FLUSH = 8  # local clflush/clwb issue (ns)
P_SFENCE = 9  # local sfence base cost (ns)
P_MC_BANKS = 10  # MC drain parallelism (banks); sustained drain = MC_PM/banks
P_OB_BARRIER = 11  # remote cross-QP ordering barrier bubble for rofence (ns)
P_QP_DEPTH = 12  # NIC pipeline depth hiding NT serialization (entries)
P_NT_SERIAL = 13  # serialized per-line cost of an NT write beyond QP_DEPTH (ns)
P_LLC_DDIO_LINES = 14  # lines the DDIO ways can buffer (2 MB / 64 B)
P_WIRE_LINE = 15  # serialization of each extra line in a scatter-gather span (ns);
#                   legacy default = GAP (full per-line issue cost, no SG benefit)

N_PARAMS = 16

# Extended parameter vector (f32[18]) for the knob-aware adaptive model
# `predict(epochs, writes, backups, quorum, batch_cap)` — the legacy 16
# slots plus the staged-pipeline CPU cost split the batch-cap knob
# amortizes (rust/src/config/platform.rs::to_param_vec_ext mirrors the
# same indices; see latency_knob_ref in ref.py).
P_DOORBELL = 16  # MMIO doorbell CPU cost per flushed chain (ns)
P_WQE_STAGE = 17  # CPU cost to build/stage one WQE in host memory (ns)

N_PARAMS_EXT = 18

# Strategy indices in the kernel output lat[n, 4].
S_NOSM = 0
S_RC = 1
S_OB = 2
S_DD = 3

N_STRATEGIES = 4


def default_params():
    """Paper §6.1 / Table 2 platform defaults (see DESIGN.md §6)."""
    p = [0.0] * N_PARAMS
    p[P_RTT] = 2600.0
    p[P_GAP] = 150.0
    p[P_NQP] = 4.0
    p[P_PCIE_RT] = 200.0
    p[P_LLC_MC] = 10.0
    p[P_MC_PM] = 150.0
    p[P_MCQ] = 64.0
    p[P_STORE] = 10.0
    p[P_FLUSH] = 25.0
    p[P_SFENCE] = 20.0
    p[P_MC_BANKS] = 4.0
    p[P_OB_BARRIER] = 75.0
    p[P_QP_DEPTH] = 64.0
    p[P_NT_SERIAL] = 210.0  # PCIe_RT + LLC_MC: non-posted ordered NT write
    p[P_LLC_DDIO_LINES] = 32768.0  # 2 MB / 64 B
    p[P_WIRE_LINE] = 150.0  # = GAP: legacy full per-line wire cost
    return p


def default_params_ext():
    """Extended f32[18] defaults: the legacy vector plus the doorbell /
    WQE-stage CPU split (lock-step with Platform::to_param_vec_ext)."""
    p = default_params() + [0.0] * (N_PARAMS_EXT - N_PARAMS)
    p[P_DOORBELL] = 20.0
    p[P_WQE_STAGE] = 10.0
    return p
