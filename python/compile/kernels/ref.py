"""Pure-jnp oracles for the L1 Pallas kernels.

These are the correctness references: `latency.py` and `cache_index.py` must
produce fp32-exact results against these under pytest + hypothesis sweeps.

The latency model is the closed-form counterpart of the rust discrete-event
simulator: per (epochs/txn, writes/epoch) configuration it predicts the
per-transaction latency of the four replication strategies of the paper
(NO-SM, SM-RC, SM-OB, SM-DD). See DESIGN.md §5-§6 for the derivation and the
parameter meanings.
"""

import jax
import jax.numpy as jnp

from . import params as P


def latency_ref(e, w, p):
    """Closed-form per-transaction latency (ns) for each strategy.

    Args:
      e: f32[n] — epochs per transaction.
      w: f32[n] — writes per epoch.
      p: f32[16] — platform parameter vector (see params.py).

    Returns:
      f32[n, 4] — latency for [NO-SM, SM-RC, SM-OB, SM-DD].
    """
    e = jnp.asarray(e, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    p = jnp.asarray(p, jnp.float32)

    rtt = p[P.P_RTT]
    gap = p[P.P_GAP]
    nqp = p[P.P_NQP]
    llc_mc = p[P.P_LLC_MC]
    mc_pm = p[P.P_MC_PM]
    store = p[P.P_STORE]
    flush = p[P.P_FLUSH]
    sfence = p[P.P_SFENCE]
    banks = p[P.P_MC_BANKS]
    ob_barrier = p[P.P_OB_BARRIER]
    qp_depth = p[P.P_QP_DEPTH]
    nt_serial = p[P.P_NT_SERIAL]
    ddio_lines = p[P.P_LLC_DDIO_LINES]

    n = e * w  # total persistent writes per transaction

    # --- NO-SM: local persistence only. Per epoch the thread issues w
    # store+clwb pairs, then the sfence waits for the tagged lines to reach
    # the MC write queue (persistence domain boundary under ADR).
    local_epoch = w * (store + flush) + sfence + w * llc_mc
    lat_nosm = e * local_epoch

    # --- SM-RC: per epoch, w async RDMA writes then a *blocking* rcommit
    # (RTT + drain of the touched lines from the remote LLC into the MC
    # queue + the last line's PM landing). Local work overlaps the remote
    # write burst but not the blocking fence.
    rc_remote_epoch = w * gap + rtt + w * llc_mc + mc_pm
    lat_rc = e * jnp.maximum(local_epoch, rc_remote_epoch)

    # --- SM-OB: rwtw writes round-robined over nqp QPs (issue gap/nqp),
    # one posted rofence WQE per epoch plus a remote cross-QP ordering
    # barrier bubble; the LLC DDIO ways buffer up to `ddio_lines` in flight;
    # the MC drains write-through traffic at mc_pm/banks sustained. The
    # single blocking point is the rdfence at the end (RTT + residual drain).
    ob_issue = n * (gap / nqp) + e * (gap / nqp + ob_barrier)
    ob_drain = n * (mc_pm / banks)
    # Beyond the DDIO buffering capacity the NIC itself is gated by drain.
    ob_overflow = jnp.maximum(0.0, n - ddio_lines) * (mc_pm / banks)
    lat_ob = (
        jnp.maximum(jnp.maximum(ob_issue, e * local_epoch), ob_drain)
        + ob_overflow
        + rtt
        + mc_pm  # rdfence: last-line PM landing (rcommit-like drain tail)
    )

    # --- SM-DD: every write is an rntw on a *single* QP (no QP parallelism:
    # full per-WQE gap). Ordering without DDIO forces serialized (non-posted)
    # PCIe transactions at the remote NIC; the NIC pipeline hides that
    # serialization for the first qp_depth writes, after which the effective
    # per-line cost is nt_serial. Durability is a single RDMA read (RTT).
    dd_issue = n * gap
    dd_serial = jnp.maximum(0.0, n - qp_depth) * jnp.maximum(0.0, nt_serial - gap)
    lat_dd = jnp.maximum(e * local_epoch, dd_issue + dd_serial) + rtt

    return jnp.stack([lat_nosm, lat_rc, lat_ob, lat_dd], axis=-1)


def latency_knob_ref(e, w, backups, quorum, batch_cap, p):
    """Knob-aware extension of `latency_ref` for the adaptive control
    plane: per (epochs, writes, backups, quorum, batch_cap) it predicts
    the OB/DD per-transaction latency (ns). At `backups = quorum =
    batch_cap = 1` it reduces *exactly* to the SM-OB/SM-DD columns of
    `latency_ref` — the legacy model is the calibration baseline and the
    extension adds only the marginal knob terms (mirrors
    rust/src/runtime/mod.rs::fallback_knob_predictor):

    * fan-out CPU: each line charges `b*(stage + doorbell/c)` of primary
      CPU against the 1-backup eager baseline `stage + doorbell` the
      legacy model folds into its calibration;
    * staging deferral: lines still staged when the blocking fence
      flushes serialize their wire issue into the fence wait (one `gap`
      each); SM-OB's per-epoch ordering fences flush, so only the last
      epoch's residual defers, while SM-DD stages across the whole txn;
    * quorum tail: the fence verb fans out serially, so waiting for the
      k-th completion adds ~(k-1) issue gaps.

    Args:
      e, w: f32[n] — epochs per transaction, writes per epoch.
      backups, quorum, batch_cap: f32[n] or scalar — the knob vector.
      p: f32[18] — extended parameter vector (see params.py).

    Returns:
      f32[n, 2] — latency for [SM-OB, SM-DD] at the given knobs.
    """
    e = jnp.asarray(e, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    p = jnp.asarray(p, jnp.float32)
    b = jnp.maximum(jnp.asarray(backups, jnp.float32), 1.0)
    n_back = jnp.broadcast_to(b, e.shape)
    k = jnp.clip(jnp.asarray(quorum, jnp.float32), 1.0, n_back)
    c = jnp.maximum(jnp.asarray(batch_cap, jnp.float32), 1.0)

    gap = p[P.P_GAP]
    doorbell = p[P.P_DOORBELL]
    stage = p[P.P_WQE_STAGE]

    base = latency_ref(e, w, p[: P.N_PARAMS])
    n = e * w
    fan_cpu = n * (n_back * (stage + doorbell / c) - (stage + doorbell))
    q_tail = (k - 1.0) * gap
    resid_ob = (w - c * jnp.floor(w / c)) * gap
    resid_dd = (n - c * jnp.floor(n / c)) * gap
    lat_ob = base[..., P.S_OB] + fan_cpu + resid_ob + q_tail
    lat_dd = base[..., P.S_DD] + fan_cpu + resid_dd + q_tail
    return jnp.stack([lat_ob, lat_dd], axis=-1)


def slowdowns_ref(e, w, p):
    """Slowdown of each SM strategy over NO-SM. Returns f32[n, 3] ordered
    [SM-RC, SM-OB, SM-DD] (paper Figure 4 series)."""
    lat = latency_ref(e, w, p)
    base = lat[..., P.S_NOSM : P.S_NOSM + 1]
    return lat[..., 1:] / base


def cache_index_ref(addr, masks, sets_per_slice):
    """Intel complex-addressing LLC set mapping (Maurice et al. [41]).

    Args:
      addr: uint64[n] — physical line addresses.
      masks: uint64[k] — per-slice-bit XOR masks; slice bit i =
        parity(popcount(addr & masks[i])).
      sets_per_slice: int — power of two.

    Returns:
      int32[n] — global set index = slice * sets_per_slice + local set.
    """
    addr = jnp.asarray(addr, jnp.uint64)
    masks = jnp.asarray(masks, jnp.uint64)
    bits = jax.lax.population_count(addr[:, None] & masks[None, :]) & jnp.uint64(1)
    k = masks.shape[0]
    weights = (jnp.uint64(1) << jnp.arange(k, dtype=jnp.uint64))[None, :]
    slice_idx = jnp.sum(bits * weights, axis=1)
    local_set = (addr >> jnp.uint64(6)) & jnp.uint64(sets_per_slice - 1)
    return (slice_idx * jnp.uint64(sets_per_slice) + local_set).astype(jnp.int32)
