"""L2: JAX compute graph combining the L1 kernels.

Two exported entry points (AOT-lowered by aot.py to HLO text for the rust
PJRT runtime):

  * `strategy_model(e, w, p)` — per-config latencies AND slowdowns for the
    four strategies; the rust SM-AD adaptive strategy and the `analytic` CLI
    evaluate this to pick SM-OB vs SM-DD per transaction class and to
    regenerate the Figure-4 prediction.
  * `cache_index_model(addr, masks, meta)` — bulk trace annotation.

Shapes are static for AOT (rust pads batches to MODEL_N / INDEX_N).
"""

import jax.numpy as jnp

from .kernels import cache_index as ci
from .kernels import latency as lat
from .kernels import params as P

# Static AOT batch sizes (rust pads to these; see rust/src/runtime/).
MODEL_N = 256
INDEX_N = 1024


def strategy_model(e, w, p):
    """f32[N],f32[N],f32[16] -> (f32[N,4] latencies, f32[N,3] slowdowns)."""
    l = lat.latency(e, w, p)
    slow = l[:, 1:] / jnp.maximum(l[:, :1], 1.0)
    return l, slow


def cache_index_model(addr, masks, meta):
    """u64[N], u64[8], u64[2] -> i32[N]. meta = [sets_per_slice, k]."""
    # Meta is a traced operand, but sets_per_slice is needed inside the
    # kernel as data — cache_index takes it as a python int for mask
    # padding only; here masks are already padded to 8 by the caller.
    import jax
    from jax.experimental import pallas as pl

    grid = (addr.shape[0] // ci.BLOCK,)
    return pl.pallas_call(
        ci._cache_index_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ci.N_MASKS,), lambda i: (0,)),
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((ci.BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((ci.BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((addr.shape[0],), jnp.int32),
        interpret=True,
    )(masks, meta, addr)


def fig4_grid():
    """The paper's Figure-4 sweep grid: e in {1,4,16,64,256} x w in
    {1,2,4,8}. Returns (e, w) f32 arrays of length 20."""
    es, ws = [], []
    for e in (1, 4, 16, 64, 256):
        for w in (1, 2, 4, 8):
            es.append(float(e))
            ws.append(float(w))
    return jnp.array(es, jnp.float32), jnp.array(ws, jnp.float32)


def default_params():
    return jnp.array(P.default_params(), jnp.float32)
