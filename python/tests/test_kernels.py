"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and parameter values; golden tests pin the paper's
qualitative Figure-4 shape (who wins, where the crossover falls).
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import cache_index as ci
from compile.kernels import latency as lk
from compile.kernels import params as P
from compile.kernels import ref

DEFAULT_P = jnp.array(P.default_params(), jnp.float32)


# ---------------------------------------------------------------- latency


@settings(max_examples=50, deadline=None)
@given(
    n=st.integers(1, 700),
    seed=st.integers(0, 2**31 - 1),
)
def test_latency_matches_ref_random_configs(n, seed):
    rng = np.random.default_rng(seed)
    e = jnp.asarray(rng.integers(1, 512, n), jnp.float32)
    w = jnp.asarray(rng.integers(1, 16, n), jnp.float32)
    got = lk.latency(e, w, DEFAULT_P)
    want = ref.latency_ref(e, w, DEFAULT_P)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    rtt=st.floats(500, 10000),
    gap=st.floats(10, 500),
    nqp=st.integers(1, 16),
    mc_pm=st.floats(50, 500),
    seed=st.integers(0, 2**31 - 1),
)
def test_latency_matches_ref_random_platforms(rtt, gap, nqp, mc_pm, seed):
    p = np.array(P.default_params(), np.float32)
    p[P.P_RTT] = rtt
    p[P.P_GAP] = gap
    p[P.P_NQP] = nqp
    p[P.P_MC_PM] = mc_pm
    p = jnp.asarray(p)
    rng = np.random.default_rng(seed)
    e = jnp.asarray(rng.integers(1, 300, 64), jnp.float32)
    w = jnp.asarray(rng.integers(1, 9, 64), jnp.float32)
    got = lk.latency(e, w, p)
    want = ref.latency_ref(e, w, p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_latency_handles_non_block_multiple():
    # Padding path: n not a multiple of BLOCK.
    e = jnp.array([1.0, 4.0, 16.0])
    w = jnp.array([1.0, 1.0, 2.0])
    got = lk.latency(e, w, DEFAULT_P)
    want = ref.latency_ref(e, w, DEFAULT_P)
    assert got.shape == (3, 4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_all_latencies_positive_and_ordered():
    e, w = np.meshgrid(np.arange(1, 65), np.arange(1, 9))
    e = jnp.asarray(e.ravel(), jnp.float32)
    w = jnp.asarray(w.ravel(), jnp.float32)
    lat = np.asarray(lk.latency(e, w, DEFAULT_P))
    assert (lat > 0).all()
    # Every SM strategy is at least as slow as NO-SM.
    assert (lat[:, 1:] >= lat[:, :1] - 1e-3).all()
    # SM-RC is never the fastest SM strategy (paper: RC worst everywhere).
    assert (lat[:, P.S_RC] >= lat[:, P.S_OB] - 1e-3).all()
    assert (lat[:, P.S_RC] >= lat[:, P.S_DD] - 1e-3).all()


def test_fig4_shape_rc_band():
    """Paper: SM-RC slowdowns range ~20x-55x, worst at w=1, easing with w."""
    e = jnp.array([1, 4, 16, 64, 256] * 4, jnp.float32)
    w = jnp.array([1] * 5 + [2] * 5 + [4] * 5 + [8] * 5, jnp.float32)
    s = np.asarray(lk.slowdowns(e, w, DEFAULT_P))
    rc = s[:, 0]
    assert rc.max() > 20, "RC worst case should exceed 20x"
    assert rc.max() < 80
    # Monotone easing with writes/epoch at fixed e.
    assert rc[0] > rc[5] > rc[10] > rc[15]


def test_fig4_shape_ob_dd_crossover():
    """Paper: DD better for few epochs/txn, OB better for many (fixed w)."""
    e = jnp.array([1.0, 4.0, 256.0])
    w = jnp.ones(3, jnp.float32)
    lat = np.asarray(lk.latency(e, w, DEFAULT_P))
    assert lat[0, P.S_DD] < lat[0, P.S_OB], "DD should win at e=1,w=1"
    assert lat[1, P.S_DD] < lat[1, P.S_OB], "DD should win at e=4,w=1"
    assert lat[2, P.S_OB] < lat[2, P.S_DD], "OB should win at e=256,w=1"


def test_fig4_ob_dd_beat_rc_by_up_to_3_5x():
    """Paper: OB/DD outperform RC by as much as ~3.5x (Transact 4-1)."""
    e = jnp.array([4.0])
    w = jnp.array([1.0])
    lat = np.asarray(lk.latency(e, w, DEFAULT_P))[0]
    assert lat[P.S_RC] / lat[P.S_DD] > 2.5
    assert lat[P.S_RC] / lat[P.S_OB] > 2.5


def test_slowdowns_match_ref():
    e = jnp.array([1, 4, 16, 64, 256] * 4, jnp.float32)
    w = jnp.array([1] * 5 + [2] * 5 + [4] * 5 + [8] * 5, jnp.float32)
    got = np.asarray(lk.slowdowns(e, w, DEFAULT_P))
    want = np.asarray(ref.slowdowns_ref(e, w, DEFAULT_P))
    np.testing.assert_allclose(got, want, rtol=1e-6)


# ------------------------------------------------------------ cache_index

INTEL_MASKS = [0x1B5F575440, 0x2EB5FAA880, 0x3CCCC93100]  # Maurice et al. [41]


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 2000),
    nbits=st.integers(28, 46),
    k=st.integers(1, 8),
    sets_log2=st.integers(6, 13),
    seed=st.integers(0, 2**31 - 1),
)
def test_cache_index_matches_ref(n, nbits, k, sets_log2, seed):
    rng = np.random.default_rng(seed)
    addr = jnp.asarray(rng.integers(0, 1 << nbits, n, dtype=np.uint64))
    masks = jnp.asarray(rng.integers(0, 1 << nbits, k, dtype=np.uint64))
    sets = 1 << sets_log2
    got = ci.cache_index(addr, masks, sets)
    want = ref.cache_index_ref(addr, masks, sets)
    assert bool(jnp.all(got == want))


def test_cache_index_intel_masks_in_range():
    rng = np.random.default_rng(7)
    addr = jnp.asarray(rng.integers(0, 1 << 38, 4096, dtype=np.uint64))
    masks = jnp.asarray(np.array(INTEL_MASKS, np.uint64))
    out = np.asarray(ci.cache_index(addr, masks, 2048))
    assert out.min() >= 0
    assert out.max() < 8 * 2048  # 8 slices x 2048 sets


def test_cache_index_uniformity():
    """The complex hash should spread sequential lines across slices."""
    addr = jnp.asarray(np.arange(0, 8192 * 64, 64, dtype=np.uint64))
    masks = jnp.asarray(np.array(INTEL_MASKS, np.uint64))
    out = np.asarray(ci.cache_index(addr, masks, 2048))
    slices = out // 2048
    counts = np.bincount(slices, minlength=8)
    assert counts.min() > 0.5 * counts.mean()


def test_cache_index_deterministic():
    addr = jnp.asarray(np.array([0, 64, 128, 1 << 33], np.uint64))
    masks = jnp.asarray(np.array(INTEL_MASKS, np.uint64))
    a = np.asarray(ci.cache_index(addr, masks, 2048))
    b = np.asarray(ci.cache_index(addr, masks, 2048))
    assert (a == b).all()
