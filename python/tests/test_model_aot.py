"""L2 model + AOT path: shapes, HLO text emission, and executability of the
lowered artifacts on the CPU PJRT backend (the same path the rust runtime
uses — modulo the text parser, exercised by rust integration tests)."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import params as P
from compile.kernels import ref


def test_strategy_model_shapes():
    e = jnp.ones((model.MODEL_N,), jnp.float32)
    w = jnp.ones((model.MODEL_N,), jnp.float32)
    p = model.default_params()
    lat, slow = model.strategy_model(e, w, p)
    assert lat.shape == (model.MODEL_N, 4)
    assert slow.shape == (model.MODEL_N, 3)


def test_strategy_model_matches_ref():
    rng = np.random.default_rng(3)
    e = jnp.asarray(rng.integers(1, 300, model.MODEL_N), jnp.float32)
    w = jnp.asarray(rng.integers(1, 9, model.MODEL_N), jnp.float32)
    p = model.default_params()
    lat, slow = model.strategy_model(e, w, p)
    want = ref.latency_ref(e, w, p)
    np.testing.assert_allclose(np.asarray(lat), np.asarray(want), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(slow),
        np.asarray(want[:, 1:] / np.maximum(want[:, :1], 1.0)),
        rtol=1e-6,
    )


def test_cache_index_model_matches_ref():
    rng = np.random.default_rng(4)
    addr = jnp.asarray(rng.integers(0, 1 << 40, model.INDEX_N, dtype=np.uint64))
    masks3 = rng.integers(0, 1 << 40, 3, dtype=np.uint64)
    masks = jnp.asarray(np.concatenate([masks3, np.zeros(5, np.uint64)]))
    meta = jnp.array([2048, 3], jnp.uint64)
    got = model.cache_index_model(addr, masks, meta)
    want = ref.cache_index_ref(addr, jnp.asarray(masks3), 2048)
    assert bool(jnp.all(got == want))


def test_fig4_grid():
    e, w = model.fig4_grid()
    assert e.shape == (20,)
    assert float(e.max()) == 256.0
    assert float(w.max()) == 8.0


@pytest.fixture(scope="module")
def hlo_texts():
    return {
        "latency_model": aot.lower_latency_model(),
        "cache_index": aot.lower_cache_index(),
    }


def test_hlo_text_is_emitted(hlo_texts):
    for name, text in hlo_texts.items():
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_hlo_text_no_custom_calls(hlo_texts):
    """interpret=True must lower Pallas to plain HLO — a Mosaic custom-call
    would be unloadable by the rust CPU PJRT client."""
    for name, text in hlo_texts.items():
        assert "custom-call" not in text, f"{name} contains a custom-call"


def test_hlo_text_round_trips_through_parser(hlo_texts):
    """The HLO text must re-parse (the rust side uses the same parser family
    in xla_extension; execution numerics are covered by rust integration
    tests against golden values produced by the jnp oracle)."""
    from jax._src.lib import xla_client as xc

    for name, text in hlo_texts.items():
        mod = xc._xla.hlo_module_from_text(text)
        assert mod.as_serialized_hlo_module_proto(), name


def test_latency_artifact_entry_signature(hlo_texts):
    """Entry computation carries the static AOT shapes the rust runtime
    assumes: f32[256] e, f32[256] w, f32[16] params -> tuple outputs."""
    text = hlo_texts["latency_model"]
    header = text.splitlines()[0]
    assert "f32[256]" in header
    assert "f32[16]" in header
    assert "f32[256,4]" in header and "f32[256,3]" in header


def test_cache_index_artifact_entry_signature(hlo_texts):
    text = hlo_texts["cache_index"]
    header = text.splitlines()[0]
    assert "u64[1024]" in header
    assert "u64[8]" in header and "u64[2]" in header
    assert "s32[1024]" in header
