//! Figure-10 bench (ours): flush-time coalescing — write combining +
//! scatter-gather WQE merging — on the staged fan-out path, swept over
//! workload locality (hot-header rewrites × contiguous log appends) ×
//! backups × shards × SM strategy × coalesce mode, under the `fence`
//! flush policy (the maximal chains the coalescer operates on).
//!
//! The bench *asserts* the tentpole's acceptance shape: on the
//! locality-heavy append workload at `backups >= 2`, `wire_wqes` is
//! strictly decreasing from `none` to `sg` (and `full <= sg`), write
//! combining elides a positive number of superseded line writes, and
//! the counter lattice `doorbells <= wire_wqes <= posted_wqes` holds in
//! every cell — so a regression in the coalescer fails the CI gate
//! instead of rotting in a table. It also shows the sharding
//! interaction: a modulo map destroys address contiguity within each
//! shard (spans stay at 1 line) while range striping preserves it.
//!
//! Emits `BENCH_fig10_coalescing.json` with `doorbells` / `posted_wqes`
//! / `wire_wqes` / `combined_writes` / `busy_ns` counters per cell,
//! validated by `python/check_bench_json.py` in CI's bench-smoke job
//! (`wire_wqes <= posted_wqes`, `combined_writes >= 0`, mean batch
//! `>= 1` whenever doorbells rang).
//!
//! Run: `cargo bench --bench fig10_coalescing`
//! Scale with PMSM_BENCH_TXNS (default 1000 transactions per cell) and
//! PMSM_BENCH_ITERS (wall-clock repetitions per timing).

use pmsm::bench::Bencher;
use pmsm::config::{AckPolicy, Platform, ReplicationConfig, StrategyKind};
use pmsm::coordinator::sched::RunOutcome;
use pmsm::coordinator::{Mirror, ShardMapSpec, ShardingConfig};
use pmsm::metrics::report::Table;
use pmsm::net::{CoalesceMode, FaultsConfig, FlushPolicy};
use pmsm::workloads::transact::run_append_on;
use pmsm::workloads::AppendConfig;

const MODES: [CoalesceMode; 4] = [
    CoalesceMode::None,
    CoalesceMode::Combine,
    CoalesceMode::Sg,
    CoalesceMode::Full,
];

const BACKUPS: [usize; 3] = [1, 2, 4];

fn cell(
    plat: &Platform,
    kind: StrategyKind,
    backups: usize,
    sharding: ShardingConfig,
    mode: CoalesceMode,
    cfg: AppendConfig,
) -> RunOutcome {
    let mut m = Mirror::try_build_sharded(
        plat.clone(),
        kind,
        None,
        ReplicationConfig::new(backups, AckPolicy::All),
        FaultsConfig::default(),
        sharding,
        false,
    )
    .expect("valid mirror shape");
    m.set_batching(FlushPolicy::Fence);
    m.set_coalescing(mode);
    run_append_on(&mut m, cfg)
}

fn main() {
    let txns: u64 = std::env::var("PMSM_BENCH_TXNS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000);
    // A realistic SG wire model: ~16 ns per extra 64 B line (the legacy
    // default of wire_line_ns = gap would make spans save NIC slots but
    // no issue bandwidth — the counters gate either way).
    let plat = Platform {
        wire_line_ns: 16,
        ..Platform::default()
    };
    // Locality-heavy: 2 hot-header rewrites + 8 contiguous appends per
    // epoch — the shape combining and scatter-gather both bite on.
    let cfg = AppendConfig {
        epochs: 2,
        writes: 8,
        rewrites: 2,
        txns,
        threads: 1,
    };
    let unsharded = ShardingConfig::default();

    // ---- Wire-footprint table per strategy: wire WQEs relative to the
    // uncoalesced pipeline, combined writes, mean span, makespan ratio.
    for kind in [StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd] {
        let mut t = Table::new(&[
            "backups",
            "none",
            "combine",
            "sg",
            "full",
            "combined(f)",
            "span(f)",
            "time(f)",
        ]);
        for &b in &BACKUPS {
            let outs: Vec<RunOutcome> = MODES
                .iter()
                .map(|&m| cell(&plat, kind, b, unsharded, m, cfg))
                .collect();
            let base_wire = outs[0].wire_wqes as f64;
            let mut cells = vec![format!("{b}")];
            for out in &outs {
                assert_eq!(out.txns, cfg.txns, "{kind}: every txn must commit");
                assert!(
                    out.doorbells <= out.wire_wqes && out.wire_wqes <= out.posted_wqes,
                    "{kind}: counter lattice violated: {} doorbells, {} wire, {} lines",
                    out.doorbells,
                    out.wire_wqes,
                    out.posted_wqes
                );
                cells.push(format!("{:.3}x", out.wire_wqes as f64 / base_wire));
            }
            cells.push(format!("{}", outs[3].combined_writes));
            cells.push(format!("{:.1}", outs[3].mean_span()));
            cells.push(format!(
                "{:.3}x",
                outs[3].makespan as f64 / outs[0].makespan as f64
            ));
            t.row(cells);
            // The acceptance gate: with fan-out (backups >= 2), the
            // wire footprint strictly shrinks under scatter-gather and
            // never grows under any mode; combining elides real writes.
            let (none, combine, sg, full) = (&outs[0], &outs[1], &outs[2], &outs[3]);
            assert_eq!(none.wire_wqes, none.posted_wqes, "{kind}: none is 1 line/WQE");
            assert_eq!(none.combined_writes, 0, "{kind}");
            if b >= 2 {
                assert!(
                    sg.wire_wqes < none.wire_wqes,
                    "{kind} backups={b}: sg must cut wire WQEs \
                     ({} vs {})",
                    sg.wire_wqes,
                    none.wire_wqes
                );
                assert!(
                    full.wire_wqes <= sg.wire_wqes,
                    "{kind} backups={b}: full must not exceed sg"
                );
                assert!(
                    combine.wire_wqes < none.wire_wqes,
                    "{kind} backups={b}: combining must drop wire WQEs"
                );
                assert!(
                    combine.combined_writes > 0 && full.combined_writes > 0,
                    "{kind} backups={b}: hot-header rewrites must combine"
                );
                assert!(
                    combine.posted_wqes < none.posted_wqes,
                    "{kind} backups={b}: combined lines must leave the wire"
                );
                assert_eq!(
                    sg.posted_wqes, none.posted_wqes,
                    "{kind} backups={b}: sg must drop nothing"
                );
                assert!(full.mean_span() > 1.0, "{kind} backups={b}");
            }
        }
        println!(
            "Figure 10 — append 2-8(+2 hot) coalescing, {kind} \
             (wire WQEs vs none; combined/span/time under full)\n{}",
            t.render()
        );
    }

    // ---- Sharding interaction: modulo interleaving destroys in-shard
    // contiguity (spans stay single-line), range striping preserves it.
    {
        let mut t = Table::new(&["map", "shards", "wire none", "wire full", "span(f)"]);
        for (map, shards) in [
            (ShardMapSpec::Modulo, 2usize),
            (ShardMapSpec::Range { stripe_lines: 1 << 16 }, 2),
        ] {
            let sharding = ShardingConfig::new(shards, map);
            let none = cell(&plat, StrategyKind::SmOb, 2, sharding, CoalesceMode::None, cfg);
            let full = cell(&plat, StrategyKind::SmOb, 2, sharding, CoalesceMode::Full, cfg);
            assert_eq!(full.txns, cfg.txns);
            assert!(full.wire_wqes <= none.wire_wqes);
            if matches!(map, ShardMapSpec::Range { .. }) {
                // Contiguity survives range striping: spans must form.
                assert!(
                    full.wire_wqes < none.wire_wqes && full.mean_span() > 1.0,
                    "range striping must preserve span formation"
                );
            }
            t.row(vec![
                format!("{map}"),
                format!("{shards}"),
                format!("{}", none.wire_wqes),
                format!("{}", full.wire_wqes),
                format!("{:.2}", full.mean_span()),
            ]);
        }
        println!(
            "sharding x coalescing at backups=2, SM-OB (full vs none)\n{}",
            t.render()
        );
    }

    // ---- Simulator throughput while coalescing (perf tracking): each
    // timing cell carries its simulated run's wire counters so the
    // JSON records the amortization directly.
    let mut b = Bencher::new();
    for &backups in &[2usize, 4] {
        for &mode in &MODES {
            let kind = StrategyKind::SmOb;
            let lines = cfg.txns * cfg.epochs as u64 * (cfg.writes + cfg.rewrites) as u64;
            let mut counters = (0u64, 0u64, 0u64, 0u64, 0u64);
            b.bench_elems(
                &format!("append/2-8+2/{kind}/backups-{backups}/{mode}"),
                (lines * backups as u64) as f64,
                || {
                    let out = cell(&plat, kind, backups, unsharded, mode, cfg);
                    counters = (
                        out.doorbells,
                        out.posted_wqes,
                        out.wire_wqes,
                        out.combined_writes,
                        out.busy_ns,
                    );
                    out
                },
            );
            b.annotate_last(&[
                ("doorbells", counters.0),
                ("posted_wqes", counters.1),
                ("wire_wqes", counters.2),
                ("combined_writes", counters.3),
                ("busy_ns", counters.4),
            ]);
        }
    }
    pmsm::bench::emit_json(&b, "fig10_coalescing");
}
