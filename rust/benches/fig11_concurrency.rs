//! Figure-11 bench (ours): the concurrent primary — Transact swept over
//! threads × commit pipelines × group-fence window under SM-OB at
//! backups = 2, reporting the primary CPU busy time and fences-per-txn
//! that cross-thread group fencing recovers, and the pipeline queueing
//! that widening the commit fan-out recovers. Emits
//! `BENCH_fig11_concurrency.json` with `fences_issued` /
//! `fence_piggybacks` / `txns_committed` / `busy_ns` counters per cell;
//! CI's bench-smoke job validates the artifact (including
//! `fences_issued <= txns_committed` on every group-fenced cell) with
//! `python/check_bench_json.py`.
//!
//! The bench *asserts* the tentpole's acceptance shape: at threads >= 2
//! a group-fence window strictly decreases both primary busy_ns and
//! fences-per-txn vs the serial (window = 0) baseline, and pipeline
//! wait time strictly decreases as the commit-pipeline count grows —
//! so a regression in the concurrency model fails the CI gate instead
//! of rotting in a table. (SM-OB only: its ordering fences are posted,
//! so blocking fences == commit fences and the fences/txn ratio is
//! exact. The `--commit-pipelines 1` serial anchor is pinned
//! event-for-event by `rust/tests/concurrency.rs`.)
//!
//! Run: `cargo bench --bench fig11_concurrency`
//! Scale with PMSM_BENCH_TXNS (default 2000 transactions per cell) and
//! PMSM_BENCH_ITERS (wall-clock repetitions per timing).

use pmsm::bench::Bencher;
use pmsm::config::{AckPolicy, Platform, ReplicationConfig, StrategyKind};
use pmsm::coordinator::sched::RunOutcome;
use pmsm::coordinator::ConcurrencyConfig;
use pmsm::metrics::report::Table;
use pmsm::workloads::transact::run_transact_concurrent;
use pmsm::workloads::TransactConfig;

/// Group-fence windows (ns): 0 is the issue-every-fence anchor; 2600 ~
/// one RTT; 10400 ~ four RTTs (threads drifting a whole commit apart
/// still share).
const WINDOWS: [u64; 3] = [0, 2_600, 10_400];
const THREADS: [usize; 3] = [1, 2, 4];
const PIPELINES: [usize; 3] = [1, 2, 4];

fn cell(
    plat: &Platform,
    threads: usize,
    conc: ConcurrencyConfig,
    txns: u64,
) -> RunOutcome {
    let cfg = TransactConfig {
        epochs: 4,
        writes: 1,
        txns,
        threads,
        ..Default::default()
    };
    run_transact_concurrent(
        plat,
        StrategyKind::SmOb,
        ReplicationConfig::new(2, AckPolicy::All),
        conc,
        cfg,
    )
    .expect("valid concurrency config")
}

fn main() {
    let txns: u64 = std::env::var("PMSM_BENCH_TXNS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2_000);
    let plat = Platform::default();

    // ---- Group-fencing table: threads x window at P = threads. The
    // serial (window = 0) column is the baseline; busy and fences/txn
    // must strictly decrease under a window once threads contend.
    let mut t = Table::new(&[
        "threads",
        "busy w=0",
        "busy w=2600",
        "busy w=10400",
        "fences/txn (0->10400)",
        "piggyback",
    ]);
    for &th in &THREADS {
        let outs: Vec<RunOutcome> = WINDOWS
            .iter()
            .map(|&w| cell(&plat, th, ConcurrencyConfig::new(th, w), txns))
            .collect();
        for out in &outs {
            assert_eq!(out.txns, txns * th as u64, "every txn must commit");
            assert!(
                out.fences_issued + out.fence_piggybacks == out.txns,
                "SM-OB blocks exactly one fence per commit: {} + {} != {}",
                out.fences_issued,
                out.fence_piggybacks,
                out.txns
            );
            assert!(
                out.fences_issued <= out.txns,
                "fences_issued {} > txns {}",
                out.fences_issued,
                out.txns
            );
        }
        t.row(vec![
            format!("{th}"),
            format!("{:.3} ms", outs[0].busy_ns as f64 / 1e6),
            format!("{:.3} ms", outs[1].busy_ns as f64 / 1e6),
            format!("{:.3} ms", outs[2].busy_ns as f64 / 1e6),
            format!(
                "{:.2} -> {:.2}",
                outs[0].fences_per_txn(),
                outs[2].fences_per_txn()
            ),
            format!("{}", outs[2].fence_piggybacks),
        ]);
        // Acceptance gate: contending threads must share fences.
        if th >= 2 {
            for (w, out) in WINDOWS.iter().zip(&outs).skip(1) {
                assert!(
                    out.fence_piggybacks > 0,
                    "threads={th} w={w}: no fence piggybacked"
                );
                assert!(
                    out.busy_ns < outs[0].busy_ns,
                    "threads={th} w={w}: busy {} not below serial {}",
                    out.busy_ns,
                    outs[0].busy_ns
                );
                assert!(
                    out.fences_per_txn() < outs[0].fences_per_txn(),
                    "threads={th} w={w}: fences/txn {} not below serial {}",
                    out.fences_per_txn(),
                    outs[0].fences_per_txn()
                );
            }
            assert!(
                outs[2].fences_issued <= outs[1].fences_issued,
                "threads={th}: widening the window must not issue more fences"
            );
        } else {
            // One thread never contends with itself across commits
            // faster than the window here, but the invariant still
            // holds: no cell may fence more than it commits.
            assert_eq!(outs[0].fence_piggybacks, 0);
        }
    }
    println!(
        "Figure 11 — Transact 4-1 group fencing, SM-OB backups=2, \
         P=threads (primary busy and fences/txn vs window)\n{}",
        t.render()
    );

    // ---- Pipeline table: threads=4, window=2600, P swept. The gated
    // path is active in every cell (window > 0), so P=1 models the
    // serial primary and pipeline wait time must strictly fall as the
    // commit fan-out widens.
    {
        let mut t = Table::new(&["pipelines", "pipe waits", "queued", "occupancy"]);
        let outs: Vec<RunOutcome> = PIPELINES
            .iter()
            .map(|&p| cell(&plat, 4, ConcurrencyConfig::new(p, 2_600), txns))
            .collect();
        for (p, out) in PIPELINES.iter().zip(&outs) {
            t.row(vec![
                format!("{p}"),
                format!("{}", out.pipeline_waits),
                format!("{:.3} ms", out.pipeline_wait_ns as f64 / 1e6),
                format!("{:.3}", out.pipeline_occupancy()),
            ]);
        }
        assert!(
            outs[0].pipeline_wait_ns > outs[1].pipeline_wait_ns
                && outs[1].pipeline_wait_ns > outs[2].pipeline_wait_ns,
            "pipeline queueing not strictly decreasing in P: {} / {} / {}",
            outs[0].pipeline_wait_ns,
            outs[1].pipeline_wait_ns,
            outs[2].pipeline_wait_ns
        );
        println!(
            "commit pipelines at threads=4, window=2600 (queueing vs P)\n{}",
            t.render()
        );
    }

    // ---- Simulator throughput under the concurrent-primary model
    // (perf tracking): each timing cell carries the fence and txn
    // counters of its simulated run so the JSON records the group-fence
    // invariant (`fences_issued <= txns_committed`) directly.
    let mut b = Bencher::new();
    for &th in &[2usize, 4] {
        for &w in &WINDOWS {
            let mut counters = (0u64, 0u64, 0u64, 0u64);
            b.bench_elems(
                &format!("transact/4-1/sm-ob/threads-{th}/pipes-{th}/window-{w}"),
                (txns * th as u64) as f64,
                || {
                    let out = cell(&plat, th, ConcurrencyConfig::new(th, w), txns);
                    counters = (
                        out.fences_issued,
                        out.fence_piggybacks,
                        out.txns,
                        out.busy_ns,
                    );
                    out
                },
            );
            b.annotate_last(&[
                ("fences_issued", counters.0),
                ("fence_piggybacks", counters.1),
                ("txns_committed", counters.2),
                ("busy_ns", counters.3),
            ]);
        }
    }
    pmsm::bench::emit_json(&b, "fig11_concurrency");
}
