//! Figure-12 bench (ours): primary failover — the Transact
//! microbenchmark swept over kill-time × ack-policy × shard count at
//! `backups = 3`, with the *primary* killed mid-run so the membership
//! layer must elect a successor (longest certified ledger prefix, ties
//! to the lowest replica id), fence the old primary's staged WQE
//! chains, re-replicate the winner's suffix, and re-admit writes.
//! Reports completion (or the stall point when no successor can be
//! seated), election downtime, revoked WQEs and re-replicated lines,
//! plus simulator throughput while failing over. Emits
//! `BENCH_fig12_failover_primary.json` for run-over-run perf tracking.
//!
//! Run: `cargo bench --bench fig12_failover_primary`
//! Scale with PMSM_BENCH_TXNS (default 2000 transactions per cell) and
//! PMSM_BENCH_ITERS (wall-clock repetitions per timing).

use pmsm::bench::Bencher;
use pmsm::config::{AckPolicy, Platform, ReplicationConfig, StrategyKind};
use pmsm::coordinator::sched::RunOutcome;
use pmsm::coordinator::{Mirror, ShardMapSpec, ShardingConfig};
use pmsm::metrics::report::Table;
use pmsm::net::{FaultsConfig, OnLoss};
use pmsm::workloads::transact::{run_transact_faulted, run_transact_on};
use pmsm::workloads::TransactConfig;

/// Kill instants as fractions of the fault-free makespan.
const KILL_FRACS: [(u64, u64); 3] = [(1, 4), (1, 2), (3, 4)];

fn faults(plan: &str, on_loss: OnLoss) -> FaultsConfig {
    FaultsConfig::with_plan(plan, on_loss).expect("valid plan")
}

/// `run_transact_sharded` pins a fault-free plan, so the faulted
/// sharded cells build the mirror directly: `shards` lanes that must
/// fail over as one node when the primary dies.
fn run_cell(
    plat: &Platform,
    repl: ReplicationConfig,
    faults: FaultsConfig,
    shards: usize,
    cfg: TransactConfig,
) -> RunOutcome {
    let mut mirror = Mirror::try_build_sharded(
        plat.clone(),
        StrategyKind::SmOb,
        None,
        repl,
        faults,
        ShardingConfig::new(shards, ShardMapSpec::Modulo),
        false,
    )
    .expect("valid fault config");
    run_transact_on(&mut mirror, cfg)
}

fn main() {
    let txns: u64 = std::env::var("PMSM_BENCH_TXNS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2_000);
    let plat = Platform::default();
    let cfg = TransactConfig {
        epochs: 4,
        writes: 1,
        txns,
        ..Default::default()
    };
    let repl = |policy| ReplicationConfig::new(3, policy);

    // Fault-free baseline places the kill instants.
    let base = run_transact_faulted(
        &plat,
        StrategyKind::SmOb,
        repl(AckPolicy::All),
        FaultsConfig::default(),
        cfg,
    )
    .expect("baseline")
    .makespan;

    // ---- Kill-time x ack-policy x shards matrix: kill the primary;
    // the surviving backup with the longest certified prefix takes
    // over (all shards as one node) and the run continues — or stalls
    // under all-halt, which needs every one of the 3 original backups
    // acking after failover leaves only 2.
    let cells: [(AckPolicy, OnLoss); 4] = [
        (AckPolicy::All, OnLoss::Halt),
        (AckPolicy::All, OnLoss::Degrade),
        (AckPolicy::Majority, OnLoss::Halt),
        (AckPolicy::Quorum(2), OnLoss::Halt),
    ];
    let mut t = Table::new(&[
        "kill@",
        "policy",
        "on_loss",
        "shards",
        "outcome",
        "time",
        "txns",
        "epochs",
        "downtime(ns)",
        "rerepl",
        "revoked",
    ]);
    for &(num, den) in &KILL_FRACS {
        let kill_at = base * num / den;
        let plan = format!("kill:p@{kill_at}");
        for &(policy, on_loss) in &cells {
            for shards in [1usize, 4] {
                let out = run_cell(&plat, repl(policy), faults(&plan, on_loss), shards, cfg);
                let outcome = match &out.stalled {
                    Some(s) => format!("STALL@{}", s.at),
                    None => "completed".to_string(),
                };
                t.row(vec![
                    format!("{num}/{den}"),
                    policy.to_string(),
                    on_loss.to_string(),
                    format!("{shards}"),
                    outcome,
                    format!("{:.2}x", out.makespan as f64 / base as f64),
                    format!("{}", out.txns),
                    format!("{}", out.membership_epochs),
                    format!("{}", out.failover_downtime_ns),
                    format!("{}", out.rereplicated_lines),
                    format!("{}", out.revoked_wqes),
                ]);
            }
        }
    }
    println!(
        "Figure 12 — Transact 4-1 primary failover at backups=3 \
         (kill the primary; longest certified prefix wins, all shards \
         fail over as one node; time vs fault-free)\n{}",
        t.render()
    );

    // ---- Simulator throughput while failing over (perf tracking).
    // Each timed cell re-runs its failover end to end; the counters of
    // the last run are annotated onto the result so the JSON artifact
    // carries the membership-epoch dimension per cell.
    let mut b = Bencher::new();
    let kill_at = base / 2;
    let plan = format!("kill:p@{kill_at}");
    for (name, policy, on_loss, shards) in [
        ("all-degrade/1", AckPolicy::All, OnLoss::Degrade, 1usize),
        ("majority-halt/1", AckPolicy::Majority, OnLoss::Halt, 1),
        ("quorum2-halt/1", AckPolicy::Quorum(2), OnLoss::Halt, 1),
        ("quorum2-halt/4", AckPolicy::Quorum(2), OnLoss::Halt, 4),
    ] {
        let writes = cfg.txns * 4;
        let mut last = None;
        b.bench_elems(
            &format!("transact/4-1/sm-ob/failover-primary/{name}"),
            (writes * 3) as f64,
            || {
                let out = run_cell(&plat, repl(policy), faults(&plan, on_loss), shards, cfg);
                let makespan = out.makespan;
                last = Some(out);
                makespan
            },
        );
        let out = last.expect("bench ran at least once");
        b.annotate_last(&[
            ("membership_epochs", out.membership_epochs),
            ("failover_downtime_ns", out.failover_downtime_ns),
            ("rereplicated_lines", out.rereplicated_lines),
            ("revoked_wqes", out.revoked_wqes),
            ("txns_committed", out.txns),
            ("busy_ns", out.busy_ns),
        ]);
    }
    pmsm::bench::emit_json(&b, "fig12_failover_primary");
}
