//! Figure-13 bench (ours): remote persistence domains — Transact swept
//! over persist domain × SM strategy × backups, reporting makespan
//! slowdown vs NO-SM plus the per-domain artifacts (flush verbs,
//! compacted lines, volatile-window exposure). Emits
//! `BENCH_fig13_persist_domains.json` with `flush_verbs` /
//! `compaction_lines` / `volatile_window_ns` / `doorbells` /
//! `txns_committed` counters per cell; CI's bench-smoke job validates
//! the artifact (including `flush_verbs <= doorbells` on every cell)
//! with `python/check_bench_json.py`.
//!
//! The bench *asserts* the tentpole's acceptance shape:
//!   * the adr anchor emits none of the new-domain artifacts
//!     (`flush_verbs == compaction_lines == 0`) — the guard-clause
//!     pass-through never pays for the redesign;
//!   * eADR is never slower than adr for the same cell (completion
//!     implies persistence; rcommit drains collapse), and strictly
//!     faster for SM-RC, the drain-heavy strategy;
//!   * rpmem-flush issues flush verbs (bounded by doorbells) and
//!     accrues a volatile window; eADR accrues none;
//!   * at least one strategy pair RE-RANKS between two domains — the
//!     domain is a first-class axis of the strategy choice, not a
//!     constant offset (the paper's Figure-4 ranking is adr-specific).
//!
//! Run: `cargo bench --bench fig13_persist_domains`
//! Scale with PMSM_BENCH_TXNS (default 400 transactions per cell) and
//! PMSM_BENCH_ITERS (wall-clock repetitions per timing).

use pmsm::bench::Bencher;
use pmsm::config::{AckPolicy, Platform, ReplicationConfig, StrategyKind};
use pmsm::coordinator::sched::RunOutcome;
use pmsm::coordinator::MirrorBuilder;
use pmsm::metrics::report::Table;
use pmsm::net::PersistDomain;
use pmsm::workloads::transact::run_transact_on;
use pmsm::workloads::TransactConfig;

const STRATEGIES: [StrategyKind; 3] =
    [StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd];
const BACKUPS: [usize; 2] = [1, 2];

fn cell(
    plat: &Platform,
    kind: StrategyKind,
    domain: PersistDomain,
    backups: usize,
    txns: u64,
) -> RunOutcome {
    let mut m = MirrorBuilder::new(plat.clone(), kind)
        .replication(ReplicationConfig::new(backups, AckPolicy::All))
        .persist_domain(domain)
        .build()
        .expect("valid domain cell");
    let cfg = TransactConfig {
        epochs: 4,
        writes: 1,
        txns,
        threads: 1,
        ..Default::default()
    };
    run_transact_on(&mut m, cfg)
}

fn main() {
    let txns: u64 = std::env::var("PMSM_BENCH_TXNS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);
    let plat = Platform::default();

    // ---- Makespan table: strategy x domain at each backup count, plus
    // the per-cell artifact assertions and the cross-domain re-ranking
    // check.
    let mut inversions: Vec<String> = Vec::new();
    for &backups in &BACKUPS {
        let mut t = Table::new(&[
            "strategy",
            "adr",
            "eadr",
            "rpmem-flush",
            "log-structured",
            "flush verbs (rpmem)",
            "compacted (log)",
        ]);
        // makespans[s][d] for the re-ranking scan.
        let mut makespans: Vec<Vec<u64>> = Vec::new();
        for &kind in &STRATEGIES {
            let outs: Vec<RunOutcome> = PersistDomain::ALL
                .iter()
                .map(|&d| cell(&plat, kind, d, backups, txns))
                .collect();
            for (d, out) in PersistDomain::ALL.iter().zip(&outs) {
                assert_eq!(out.txns, txns, "{kind:?}/{d}: every txn must commit");
                assert_eq!(out.persist_domain, d.name(), "{kind:?}: domain label");
                assert!(
                    out.flush_verbs <= out.doorbells,
                    "{kind:?}/{d}: flush_verbs {} > doorbells {}",
                    out.flush_verbs,
                    out.doorbells
                );
                match d {
                    PersistDomain::Adr => {
                        assert_eq!(out.flush_verbs, 0, "{kind:?}: adr flushed");
                        assert_eq!(out.compaction_lines, 0, "{kind:?}: adr compacted");
                    }
                    PersistDomain::Eadr => {
                        assert_eq!(out.flush_verbs, 0, "{kind:?}: eadr flushed");
                        assert_eq!(
                            out.volatile_window_ns, 0,
                            "{kind:?}: eadr left acked writes volatile"
                        );
                    }
                    PersistDomain::RpmemFlush => {
                        assert!(out.flush_verbs > 0, "{kind:?}: rpmem never flushed");
                        assert!(
                            out.volatile_window_ns > 0,
                            "{kind:?}: rpmem shows no volatile window"
                        );
                    }
                    PersistDomain::LogStructured => {
                        assert!(
                            out.compaction_lines > 0,
                            "{kind:?}: log-structured never compacted a rewrite"
                        );
                    }
                }
            }
            let adr = outs[0].makespan;
            let eadr = outs[1].makespan;
            assert!(
                eadr <= adr,
                "{kind:?} backups={backups}: eadr slower than adr ({eadr} > {adr})"
            );
            if kind == StrategyKind::SmRc {
                assert!(
                    eadr < adr,
                    "{kind:?} backups={backups}: eadr must collapse the rcommit drain"
                );
            }
            t.row(vec![
                format!("{kind}"),
                format!("{:.3} ms", outs[0].makespan as f64 / 1e6),
                format!("{:.3} ms", outs[1].makespan as f64 / 1e6),
                format!("{:.3} ms", outs[2].makespan as f64 / 1e6),
                format!("{:.3} ms", outs[3].makespan as f64 / 1e6),
                format!("{}", outs[2].flush_verbs),
                format!("{}", outs[3].compaction_lines),
            ]);
            makespans.push(outs.iter().map(|o| o.makespan).collect());
        }
        // Re-ranking scan: a strategy pair whose order flips between two
        // domains (the acceptance gate aggregates across backup counts).
        for a in 0..STRATEGIES.len() {
            for b in (a + 1)..STRATEGIES.len() {
                for d1 in 0..PersistDomain::ALL.len() {
                    for d2 in (d1 + 1)..PersistDomain::ALL.len() {
                        let under_d1 = makespans[a][d1] < makespans[b][d1];
                        let under_d2 = makespans[a][d2] < makespans[b][d2];
                        if under_d1 != under_d2 {
                            inversions.push(format!(
                                "backups={backups}: {} vs {} re-rank between {} and {}",
                                STRATEGIES[a],
                                STRATEGIES[b],
                                PersistDomain::ALL[d1],
                                PersistDomain::ALL[d2]
                            ));
                        }
                    }
                }
            }
        }
        println!(
            "Figure 13 — Transact 4-1 persist domains, backups={backups} \
             (makespan by strategy x domain)\n{}",
            t.render()
        );
    }
    assert!(
        !inversions.is_empty(),
        "no strategy pair re-ranked across domains — the domain axis is inert"
    );
    println!("strategy re-rankings across domains:");
    for inv in &inversions {
        println!("  {inv}");
    }

    // ---- Simulator throughput per domain cell (perf tracking): each
    // timing cell carries its run's persistence counters so the JSON
    // records `flush_verbs <= doorbells` directly.
    let mut b = Bencher::new();
    for &kind in &STRATEGIES {
        for &d in &PersistDomain::ALL {
            let mut counters = (0u64, 0u64, 0u64, 0u64, 0u64);
            b.bench_elems(
                &format!("transact/4-1/{kind}/{}/backups-2", d.name()),
                txns as f64,
                || {
                    let out = cell(&plat, kind, d, 2, txns);
                    counters = (
                        out.flush_verbs,
                        out.compaction_lines,
                        out.volatile_window_ns,
                        out.doorbells,
                        out.txns,
                    );
                    out
                },
            );
            b.annotate_last(&[
                ("flush_verbs", counters.0),
                ("compaction_lines", counters.1),
                ("volatile_window_ns", counters.2),
                ("doorbells", counters.3),
                ("txns_committed", counters.4),
            ]);
        }
    }
    pmsm::bench::emit_json(&b, "fig13_persist_domains");
}
