//! Figure-14 bench (ours): the online adaptive mirroring control plane
//! over a phase-mixed workload — bulk appends (1 epoch x 64 writes),
//! small update transactions (4 x 1) and hot-line transactions (64 x 2)
//! back to back. The adaptive cell (`sm-ad` + `[adaptive]` enabled,
//! backups=2, ack floor quorum:1) re-tunes mode / ack quorum / batch
//! cap per transaction class; the static grid sweeps every fixed
//! {SM-OB, SM-DD} x cap {1, 8, 32} x quorum {1, 2} combination over the
//! same phase mix. Emits `BENCH_fig14_adaptive.json` with `chose_ob` /
//! `chose_dd` / `adaptive_switches` / `txns_committed` / `busy_ns`
//! counters per cell; CI's bench-smoke job validates the artifact
//! (including `adaptive_switches <= txns_committed` on every cell) with
//! `python/check_bench_json.py`.
//!
//! The bench *asserts* the tentpole's acceptance shape:
//!   * the adaptive cell's makespan tracks EVERY static knob vector
//!     (within a 5% transient allowance) and strictly beats the worst
//!     one — no single static config matches per-class tuning over a
//!     phase-mixed workload;
//!   * the controller actually mixes modes across the phases (both
//!     `chose_ob` and `chose_dd` are nonzero) and re-tunes at the phase
//!     boundaries: the mix's knob vectors are OB/c32 -> DD/c1 -> OB/c32,
//!     so `2 <= adaptive_switches <= txns_committed`;
//!   * the quorum axis never undercuts the configured floor, and with
//!     headroom (floor 1 of 2 backups) the controller settles on the
//!     floor — the model's quorum tail penalty is monotone in k;
//!   * phase-pure runs converge per class: (4,1) -> SM-DD at cap 1,
//!     (1,64) and (64,2) -> SM-OB at cap 32. Convergence asserts are
//!     dominance-based (>= 90% of decisions) — the first decisions of a
//!     class ride the uncorrected model, and the class-correction EWMA
//!     allows a short exploration transient before feedback pins the
//!     steady-state cell.
//!
//! Run: `cargo bench --bench fig14_adaptive`
//! Scale with PMSM_BENCH_TXNS (default 400 phase-1 transactions per
//! cell) and PMSM_BENCH_ITERS (wall-clock repetitions per timing).

use pmsm::bench::Bencher;
use pmsm::config::{AckPolicy, AdaptiveConfig, Platform, ReplicationConfig, StrategyKind};
use pmsm::coordinator::sched::RunOutcome;
use pmsm::coordinator::MirrorBuilder;
use pmsm::metrics::report::Table;
use pmsm::net::FlushPolicy;
use pmsm::runtime::{fallback_knob_predictor, fallback_predictor};
use pmsm::workloads::transact::{run_phased_on, Phase};

const BACKUPS: usize = 2;
const FLOOR: usize = 1;
const MODES: [StrategyKind; 2] = [StrategyKind::SmOb, StrategyKind::SmDd];
const CAPS: [usize; 3] = [1, 8, 32];
const QUORUMS: [usize; 2] = [1, 2];
const SEED: u64 = 42;

/// The phase mix: writes/txn differ by 30x across phases, so the
/// per-phase txn counts are scaled to keep each phase's wall share
/// comparable. Ordered so consecutive phases want distinct knob
/// vectors (OB/c32 -> DD/c1 -> OB/c32): each boundary is a real
/// applied-knob switch.
fn phases(txns: u64) -> [Phase; 3] {
    [
        Phase { epochs: 1, writes: 64, txns: (txns / 8).max(20) },
        Phase { epochs: 4, writes: 1, txns },
        Phase { epochs: 64, writes: 2, txns: (txns / 16).max(10) },
    ]
}

/// One fixed knob vector over the full phase mix.
fn static_cell(
    plat: &Platform,
    kind: StrategyKind,
    quorum: usize,
    cap: usize,
    mix: &[Phase],
) -> RunOutcome {
    let mut m = MirrorBuilder::new(plat.clone(), kind)
        .replication(ReplicationConfig::new(BACKUPS, AckPolicy::Quorum(quorum)))
        .batching(FlushPolicy::Cap(cap))
        .build()
        .expect("valid static cell");
    run_phased_on(&mut m, mix, 1, SEED)
}

/// The adaptive control plane over the same phases (quorum floor 1).
fn adaptive_cell(plat: &Platform, mix: &[Phase]) -> RunOutcome {
    let mut m = MirrorBuilder::new(plat.clone(), StrategyKind::SmAd)
        .replication(ReplicationConfig::new(BACKUPS, AckPolicy::Quorum(FLOOR)))
        .predictor(fallback_predictor(plat))
        .knob_predictor(fallback_knob_predictor(plat))
        .adaptive(AdaptiveConfig::enabled())
        .build()
        .expect("valid adaptive cell");
    run_phased_on(&mut m, mix, 1, SEED)
}

fn main() {
    let txns: u64 = std::env::var("PMSM_BENCH_TXNS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);
    let plat = Platform::default();
    let mix = phases(txns);
    let total_txns: u64 = mix.iter().map(|p| p.txns).sum();

    // ---- The static grid vs the adaptive cell over the phase mix.
    let mut t = Table::new(&["config", "makespan", "vs adaptive"]);
    let adapt = adaptive_cell(&plat, &mix);
    assert_eq!(adapt.txns, total_txns, "every phase's txns must commit");
    let d = &adapt.decisions;
    assert!(d.chose_ob > 0, "the mix must route some txns to OB");
    assert!(d.chose_dd > 0, "the mix must route some txns to DD");
    assert!(
        d.adaptive_switches >= 2,
        "phase boundaries with distinct knob vectors need >= 2 switches, got {}",
        d.adaptive_switches
    );
    assert!(
        d.adaptive_switches <= adapt.txns,
        "switches {} exceed committed txns {}",
        d.adaptive_switches,
        adapt.txns
    );
    assert!(
        d.feedback_samples > 0,
        "feedback is enabled: measured commit latencies must land"
    );
    // Quorum floor: never undercut (hard invariant), and k=1 has
    // strictly less model tail than k=2, so the controller settles on
    // the floor (dominance — early feedback may explore briefly).
    assert!(
        d.quorum_hist.iter().take(FLOOR).all(|&n| n == 0),
        "decisions below the quorum floor: {:?}",
        d.quorum_hist
    );
    let decisions_total = d.chose_ob + d.chose_dd;
    assert!(
        d.quorum_hist.get(FLOOR).copied().unwrap_or(0) * 10 >= decisions_total * 9,
        "quorum headroom never beats the floor's tail: {:?}",
        d.quorum_hist
    );
    t.row(vec![
        "sm-ad adaptive".to_string(),
        format!("{:.3} ms", adapt.makespan as f64 / 1e6),
        "1.00x".to_string(),
    ]);

    let mut worst: Option<u64> = None;
    for &kind in &MODES {
        for &quorum in &QUORUMS {
            for &cap in &CAPS {
                let out = static_cell(&plat, kind, quorum, cap, &mix);
                assert_eq!(out.txns, total_txns, "{kind}/k{quorum}/c{cap}");
                assert_eq!(
                    out.decisions.adaptive_switches, 0,
                    "{kind}: static cells never switch"
                );
                // The acceptance gate: adaptive tracks every static
                // config (5% transient allowance for the first txn of
                // each class, decided before any feedback).
                assert!(
                    adapt.makespan as f64 <= out.makespan as f64 * 1.05,
                    "adaptive {} > static {kind}/k{quorum}/c{cap} {} + 5%",
                    adapt.makespan,
                    out.makespan
                );
                worst = Some(worst.map_or(out.makespan, |w| w.max(out.makespan)));
                t.row(vec![
                    format!("{kind} k={quorum} cap={cap}"),
                    format!("{:.3} ms", out.makespan as f64 / 1e6),
                    format!("{:.2}x", out.makespan as f64 / adapt.makespan as f64),
                ]);
            }
        }
    }
    let worst = worst.expect("static grid is nonempty");
    assert!(
        adapt.makespan < worst,
        "adaptive {} must strictly beat the worst static {}",
        adapt.makespan,
        worst
    );
    println!(
        "Figure 14 — adaptive control plane over a phase-mixed workload \
         ({} txns: 4x1 / 1x64 / 64x2, backups={BACKUPS}, floor quorum:{FLOOR})\n{}",
        total_txns,
        t.render()
    );
    println!(
        "adaptive decisions: {} ob / {} dd, {} switches, quorum hist {:?}, \
         cap hist {:?}, {} feedback samples, mean model err {:.1}%",
        d.chose_ob,
        d.chose_dd,
        d.adaptive_switches,
        d.quorum_hist,
        d.cap_hist,
        d.feedback_samples,
        d.mean_err_pct()
    );

    // ---- Per-phase convergence: a phase-pure run settles on that
    // class's knob vector. Dominance (>= 90%) rather than exactness:
    // the class-correction EWMA lags for the first samples, which can
    // admit a short exploration transient before feedback pins the
    // steady-state cell.
    for (phase, want_dd, want_cap) in [
        (Phase { epochs: 4, writes: 1, txns: 60 }, true, 1usize),
        (Phase { epochs: 1, writes: 64, txns: 30 }, false, 32),
        (Phase { epochs: 64, writes: 2, txns: 20 }, false, 32),
    ] {
        let out = adaptive_cell(&plat, &[phase]);
        let d = &out.decisions;
        let (chosen, other) = if want_dd {
            (d.chose_dd, d.chose_ob)
        } else {
            (d.chose_ob, d.chose_dd)
        };
        assert_eq!(
            chosen + other,
            phase.txns,
            "{}x{}: one decision per txn",
            phase.epochs, phase.writes
        );
        assert!(
            chosen * 10 >= phase.txns * 9,
            "{}x{}: class optimum must dominate (ob {} dd {})",
            phase.epochs, phase.writes, d.chose_ob, d.chose_dd
        );
        assert!(
            d.adaptive_switches <= 4,
            "{}x{}: a pure class re-tunes at most transiently, got {} switches",
            phase.epochs, phase.writes, d.adaptive_switches
        );
        let on_cap = d
            .cap_hist
            .iter()
            .find(|(c, _)| *c == want_cap)
            .map(|&(_, n)| n)
            .unwrap_or(0);
        assert!(
            on_cap * 10 >= phase.txns * 9,
            "{}x{}: batch cap converges to {} (hist {:?})",
            phase.epochs, phase.writes, want_cap, d.cap_hist
        );
    }
    println!("per-phase convergence: 4x1 -> dd/c1, 1x64 -> ob/c32, 64x2 -> ob/c32");

    // ---- Simulator throughput (perf tracking): the adaptive cell plus
    // two static anchors, each annotated with its decision counters.
    let mut b = Bencher::new();
    let mut counters = (0u64, 0u64, 0u64, 0u64, 0u64);
    b.bench_elems(&format!("phased/{total_txns}/sm-ad/adaptive"), total_txns as f64, || {
        let out = adaptive_cell(&plat, &mix);
        counters = (
            out.decisions.chose_ob,
            out.decisions.chose_dd,
            out.decisions.adaptive_switches,
            out.txns,
            out.busy_ns,
        );
        out
    });
    b.annotate_last(&[
        ("chose_ob", counters.0),
        ("chose_dd", counters.1),
        ("adaptive_switches", counters.2),
        ("txns_committed", counters.3),
        ("busy_ns", counters.4),
        ("feedback_samples", adapt.decisions.feedback_samples),
    ]);
    for &(kind, cap) in &[(StrategyKind::SmOb, 32usize), (StrategyKind::SmDd, 1)] {
        let mut counters = (0u64, 0u64);
        b.bench_elems(
            &format!("phased/{total_txns}/{kind}/k1-cap{cap}"),
            total_txns as f64,
            || {
                let out = static_cell(&plat, kind, 1, cap, &mix);
                counters = (out.txns, out.busy_ns);
                out
            },
        );
        b.annotate_last(&[
            ("chose_ob", 0),
            ("chose_dd", 0),
            ("adaptive_switches", 0),
            ("txns_committed", counters.0),
            ("busy_ns", counters.1),
        ]);
    }
    pmsm::bench::emit_json(&b, "fig14_adaptive");
}
