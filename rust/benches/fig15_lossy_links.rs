//! Figure-15 bench (ours): lossy links — Transact swept over loss rate
//! × ack policy × SM strategy with the RC retry machinery masking the
//! wire, reporting makespan plus the transport counters (retransmits,
//! timeouts, RNR NAKs, QP resets, dedup drops). Emits
//! `BENCH_fig15_lossy_links.json` with `retransmits` / `timeouts` /
//! `rnr_naks` / `qp_resets` / `dup_drops` / `txns_committed` counters
//! per cell; CI's bench-smoke job validates the artifact (including
//! `timeouts <= retransmits` on every cell) with
//! `python/check_bench_json.py`.
//!
//! The bench *asserts* the tentpole's acceptance shape:
//!   * the 0%-loss cell is event-for-event the reliable-wire anchor
//!     (identical makespan, zero transport counters) — the link layer
//!     never taxes a clean wire;
//!   * makespan is monotone non-decreasing in the loss rate for every
//!     strategy × policy cell — the common-random-numbers hash makes
//!     the drop set at `p1` a subset of the drop set at `p2 > p1`;
//!   * `retransmits >= timeouts` and
//!     `dup_drops <= retransmits + dups_injected` everywhere;
//!   * a sustained 100% loss window on one of two links exhausts the
//!     retry budget into a QP reset, which *stalls* `all` under halt
//!     but is fully masked by `quorum:1` (every txn commits) — the
//!     quorum machinery tolerates link failure exactly as it tolerates
//!     node failure;
//!   * a bounded receiver (`rnr_depth 1`) answers RNR NAKs, which count
//!     as retransmits but never as ACK timeouts.
//!
//! Run: `cargo bench --bench fig15_lossy_links`
//! Scale with PMSM_BENCH_TXNS (default 400 transactions per cell) and
//! PMSM_BENCH_ITERS (wall-clock repetitions per timing).

use pmsm::bench::Bencher;
use pmsm::config::{AckPolicy, Platform, ReplicationConfig, StrategyKind};
use pmsm::coordinator::sched::RunOutcome;
use pmsm::coordinator::MirrorBuilder;
use pmsm::metrics::report::Table;
use pmsm::net::{FaultsConfig, LinkConfig, OnLoss};
use pmsm::workloads::transact::run_transact_on;
use pmsm::workloads::TransactConfig;

const STRATEGIES: [StrategyKind; 3] =
    [StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd];
/// Run-long loss rates on backup 1's link (percent strings — parsed
/// exactly, displayed verbatim in the table header).
const RATES: [&str; 4] = ["0%", "0.5%", "2%", "5%"];
const POLICIES: [(AckPolicy, &str); 2] =
    [(AckPolicy::All, "all"), (AckPolicy::Quorum(1), "quorum:1")];

fn cell(
    plat: &Platform,
    kind: StrategyKind,
    policy: AckPolicy,
    on_loss: OnLoss,
    link: Option<LinkConfig>,
    txns: u64,
) -> RunOutcome {
    let mut b = MirrorBuilder::new(plat.clone(), kind)
        .replication(ReplicationConfig::new(2, policy))
        .faults(FaultsConfig::with_plan("", on_loss).expect("empty plan"));
    if let Some(link) = link {
        b = b.link(link);
    }
    let mut m = b.build().expect("valid lossy cell");
    let cfg = TransactConfig {
        epochs: 4,
        writes: 1,
        txns,
        threads: 1,
        ..Default::default()
    };
    run_transact_on(&mut m, cfg)
}

/// A run-long loss config on backup 1's link with a fixed seed.
fn loss_link(rate: &str) -> LinkConfig {
    let mut l = LinkConfig::with_plan(&format!("loss:1:{rate}")).expect("valid rate");
    l.seed = 42;
    l
}

fn check_invariants(label: &str, out: &RunOutcome) {
    assert!(
        out.retransmits >= out.transport_timeouts,
        "{label}: retransmits {} < timeouts {}",
        out.retransmits,
        out.transport_timeouts
    );
    assert!(
        out.dup_drops <= out.retransmits + out.dups_injected,
        "{label}: dup_drops {} > retransmits {} + dups_injected {}",
        out.dup_drops,
        out.retransmits,
        out.dups_injected
    );
}

fn main() {
    let txns: u64 = std::env::var("PMSM_BENCH_TXNS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);
    let plat = Platform::default();

    // ---- Loss-rate sweep: strategy x rate at each ack policy, with the
    // anchor, monotonicity and counter invariants checked per cell.
    for &(policy, pname) in &POLICIES {
        let mut t = Table::new(&["strategy", "0%", "0.5%", "2%", "5%", "retransmits @5%"]);
        for &kind in &STRATEGIES {
            let baseline = cell(&plat, kind, policy, OnLoss::Degrade, None, txns);
            assert_eq!(baseline.retransmits, 0, "{kind:?}/{pname}: reliable wire resent");
            let outs: Vec<RunOutcome> = RATES
                .iter()
                .map(|r| {
                    cell(&plat, kind, policy, OnLoss::Degrade, Some(loss_link(r)), txns)
                })
                .collect();
            for (rate, out) in RATES.iter().zip(&outs) {
                let label = format!("{kind:?}/{pname}/loss-{rate}");
                assert_eq!(out.txns, txns, "{label}: every txn must commit");
                check_invariants(&label, out);
            }
            // 0% loss through an *enabled* link is the anchor bit for bit.
            assert_eq!(
                outs[0].makespan, baseline.makespan,
                "{kind:?}/{pname}: a 0%-loss link must cost nothing"
            );
            assert_eq!(outs[0].retransmits, 0, "{kind:?}/{pname}: 0% loss resent");
            assert_eq!(outs[0].dup_drops, 0, "{kind:?}/{pname}: 0% loss deduped");
            // Common random numbers: makespan monotone in the loss rate.
            for w in outs.windows(2) {
                assert!(
                    w[0].makespan <= w[1].makespan,
                    "{kind:?}/{pname}: makespan not monotone in loss rate \
                     ({} > {})",
                    w[0].makespan,
                    w[1].makespan
                );
                assert!(
                    w[0].retransmits <= w[1].retransmits,
                    "{kind:?}/{pname}: retransmits not monotone in loss rate"
                );
            }
            assert!(
                outs.last().unwrap().retransmits > 0,
                "{kind:?}/{pname}: 5% loss never retransmitted"
            );
            t.row(vec![
                format!("{kind}"),
                format!("{:.3} ms", outs[0].makespan as f64 / 1e6),
                format!("{:.3} ms", outs[1].makespan as f64 / 1e6),
                format!("{:.3} ms", outs[2].makespan as f64 / 1e6),
                format!("{:.3} ms", outs[3].makespan as f64 / 1e6),
                format!("{}", outs[3].retransmits),
            ]);
        }
        println!(
            "Figure 15 — Transact 4-1 lossy links, backups=2, ack {pname} \
             (makespan by strategy x loss rate on backup 1's link)\n{}",
            t.render()
        );
    }

    // ---- Retry exhaustion: a sustained 100% loss window outlasts the
    // retry budget (3 retries x 8 us timeout with exponential backoff
    // spans 56 us << the 360 us window), forcing a QP reset. Under
    // `all` + halt the lost link stalls the run; `quorum:1` masks it
    // completely — link failure degrades into the node-failure path.
    let exhaust = || {
        let mut l =
            LinkConfig::with_plan("drop:1@40000..400000:100%").expect("valid window");
        l.retry_count = 3;
        l
    };
    let stalled = cell(
        &plat,
        StrategyKind::SmOb,
        AckPolicy::All,
        OnLoss::Halt,
        Some(exhaust()),
        txns,
    );
    assert!(stalled.qp_resets >= 1, "the loss window never exhausted the QP");
    assert!(
        stalled.stalled.is_some(),
        "ack all + halt must stall when one link dies"
    );
    let masked = cell(
        &plat,
        StrategyKind::SmOb,
        AckPolicy::Quorum(1),
        OnLoss::Halt,
        Some(exhaust()),
        txns,
    );
    assert!(masked.qp_resets >= 1, "the loss window never exhausted the QP");
    assert!(masked.stalled.is_none(), "quorum:1 must mask a single lost link");
    assert_eq!(masked.txns, txns, "quorum:1 must commit every txn");
    check_invariants("exhaustion/quorum:1", &masked);
    println!(
        "exhaustion: ack all stalls ({} qp reset(s)); quorum:1 masks the \
         dead link ({} qp reset(s), {} retransmits, all {} txns committed)",
        stalled.qp_resets, masked.qp_resets, masked.retransmits, masked.txns
    );

    // ---- RNR: a depth-1 receiver buffer NAKs bursts; NAK retries are
    // retransmits without ACK timeouts.
    let rnr = {
        let mut l = LinkConfig::default();
        l.rnr_depth = 1;
        cell(
            &plat,
            StrategyKind::SmOb,
            AckPolicy::All,
            OnLoss::Degrade,
            Some(l),
            txns,
        )
    };
    assert!(rnr.rnr_naks > 0, "a depth-1 receiver never NAKed");
    assert_eq!(rnr.transport_timeouts, 0, "an RNR NAK is not an ACK timeout");
    assert_eq!(rnr.txns, txns, "RNR backpressure must not lose txns");
    check_invariants("rnr", &rnr);
    println!(
        "rnr: depth-1 receiver — {} NAK(s), {} retransmit(s), 0 timeouts",
        rnr.rnr_naks, rnr.retransmits
    );

    // ---- Simulator throughput per cell (perf tracking): each timing
    // cell carries its run's transport counters so the JSON records the
    // `timeouts <= retransmits` invariant directly.
    let mut b = Bencher::new();
    for &kind in &STRATEGIES {
        for &(policy, pname) in &POLICIES {
            for rate in &RATES {
                let mut counters = (0u64, 0u64, 0u64, 0u64, 0u64, 0u64);
                b.bench_elems(
                    &format!("transact/4-1/{kind}/{pname}/loss-{rate}"),
                    txns as f64,
                    || {
                        let out = cell(
                            &plat,
                            kind,
                            policy,
                            OnLoss::Degrade,
                            Some(loss_link(rate)),
                            txns,
                        );
                        counters = (
                            out.retransmits,
                            out.transport_timeouts,
                            out.rnr_naks,
                            out.qp_resets,
                            out.dup_drops,
                            out.txns,
                        );
                        out
                    },
                );
                b.annotate_last(&[
                    ("retransmits", counters.0),
                    ("timeouts", counters.1),
                    ("rnr_naks", counters.2),
                    ("qp_resets", counters.3),
                    ("dup_drops", counters.4),
                    ("txns_committed", counters.5),
                ]);
            }
        }
    }
    pmsm::bench::emit_json(&b, "fig15_lossy_links");
}
