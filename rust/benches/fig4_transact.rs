//! Figure-4 bench: regenerates the Transact slowdown table (paper §7.1)
//! and times the simulator itself (elements/s = simulated line writes/s).
//!
//! Run: `cargo bench --bench fig4_transact`
//! Scale with PMSM_BENCH_TXNS (default 20000 committed writes per cell)
//! and PMSM_BENCH_ITERS (wall-clock repetitions per timing).

use pmsm::bench::Bencher;
use pmsm::cli::fig4_sweep;
use pmsm::config::{Platform, StrategyKind};
use pmsm::metrics::report::fig4_table;
use pmsm::runtime::fallback_predictor;
use pmsm::workloads::transact::run_transact_adaptive;
use pmsm::workloads::{run_transact, TransactConfig};

fn main() {
    let txns: u64 = std::env::var("PMSM_BENCH_TXNS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(20_000);
    let plat = Platform::default();

    // ---- The paper's figure: full e x w grid.
    let rows = fig4_sweep(&plat, txns, 1);
    println!("{}", fig4_table(&rows, None));

    // Shape summary (who wins, by roughly what factor).
    let rc_max = rows.iter().map(|r| r.rc).fold(0.0, f64::max);
    let rc_min = rows.iter().map(|r| r.rc).fold(f64::MAX, f64::min);
    println!("SM-RC slowdown band: {rc_min:.1}x ..= {rc_max:.1}x (paper: ~20x-55x)");
    // The paper quotes the 4-1 cell ("as much as 3.5x"); also report the
    // grid-wide maximum for context.
    let cell41 = rows
        .iter()
        .find(|r| r.epochs == 4 && r.writes == 1)
        .expect("4-1 cell");
    let grid_max = rows
        .iter()
        .map(|r| r.rc / r.ob.min(r.dd))
        .fold(0.0, f64::max);
    println!(
        "OB/DD gain over RC at 4-1: {:.1}x (paper: ~3.5x); grid max: {grid_max:.1}x\n",
        cell41.rc / cell41.ob.min(cell41.dd)
    );

    // ---- Simulator throughput (perf tracking, EXPERIMENTS.md §Perf).
    // Every strategy in StrategyKind::ALL gets a timing cell: the fixed
    // TABLE four run as-is, and SM-AD — which the old 4-entry ALL
    // silently skipped — runs with the closed-form fallback predictor.
    let mut b = Bencher::new();
    for (e, w) in [(4u32, 1u32), (64, 1), (16, 8)] {
        for kind in StrategyKind::ALL {
            let cfg = TransactConfig {
                epochs: e,
                writes: w,
                txns: (txns / (e as u64 * w as u64)).max(50),
                ..Default::default()
            };
            let writes = cfg.txns * e as u64 * w as u64;
            b.bench_elems(&format!("transact/{e}-{w}/{kind}"), writes as f64, || {
                if kind == StrategyKind::SmAd {
                    run_transact_adaptive(&plat, fallback_predictor(&plat), cfg).makespan
                } else {
                    run_transact(&plat, kind, cfg).makespan
                }
            });
        }
    }
    pmsm::bench::emit_json(&b, "fig4_transact");
}
