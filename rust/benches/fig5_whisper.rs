//! Figure-5 bench: regenerates the WHISPER execution-time (5a) and
//! throughput (5b) tables plus the headline H1 summary, and times the
//! simulator on each app.
//!
//! Run: `cargo bench --bench fig5_whisper`
//! Scale with PMSM_BENCH_OPS (transactions per thread, default 1000).

use pmsm::bench::Bencher;
use pmsm::cli::fig5_suite;
use pmsm::config::{Platform, StrategyKind};
use pmsm::metrics::report::fig5_tables;
use pmsm::workloads::{run_whisper, WhisperApp, WhisperConfig};

fn main() {
    let ops: u64 = std::env::var("PMSM_BENCH_OPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000);
    let plat = Platform::default();

    // ---- The paper's figure: all five apps x strategies, 4 threads.
    let rows = fig5_suite(&plat, ops, 4, None);
    println!("{}", fig5_tables(&rows));

    // ---- Simulator throughput per app (EXPERIMENTS.md §Perf).
    let mut b = Bencher::new();
    for app in WhisperApp::ALL {
        let cfg = WhisperConfig {
            app,
            ops: (ops / 4).max(50),
            threads: 4,
            seed: 42,
        };
        for kind in [StrategyKind::NoSm, StrategyKind::SmDd] {
            b.bench(&format!("whisper/{app}/{kind}"), || {
                run_whisper(&plat, kind, cfg).makespan
            });
        }
    }
    pmsm::bench::emit_json(&b, "fig5_whisper");
}
