//! Figure-6 bench (ours): replica-group scaling — the Transact
//! microbenchmark swept over `backups ∈ {1, 2, 3, 5}` × strategy, with
//! the standard metrics report (slowdown over the single-backup run plus
//! per-group fence-lag breakdowns). Emits `BENCH_fig6_replicas.json` so
//! run-over-run tracking captures the cost of N-way mirroring and of
//! relaxing `all` to quorum policies.
//!
//! Run: `cargo bench --bench fig6_replicas`
//! Scale with PMSM_BENCH_TXNS (default 2000 transactions per cell) and
//! PMSM_BENCH_ITERS (wall-clock repetitions per timing).

use pmsm::bench::Bencher;
use pmsm::config::{AckPolicy, Platform, ReplicationConfig, StrategyKind};
use pmsm::coordinator::Mirror;
use pmsm::metrics::report::Table;
use pmsm::metrics::GroupReport;
use pmsm::runtime::fallback_predictor;
use pmsm::workloads::transact::run_transact_on;
use pmsm::workloads::{run_transact_with, TransactConfig};

const BACKUPS: [usize; 4] = [1, 2, 3, 5];

fn cell(
    plat: &Platform,
    kind: StrategyKind,
    repl: ReplicationConfig,
    cfg: TransactConfig,
) -> u64 {
    let predictor = (kind == StrategyKind::SmAd).then(|| fallback_predictor(plat));
    run_transact_with(plat, kind, predictor, repl, cfg)
        .expect("valid replication config")
        .makespan
}

fn main() {
    let txns: u64 = std::env::var("PMSM_BENCH_TXNS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2_000);
    let plat = Platform::default();
    let cfg = TransactConfig {
        epochs: 4,
        writes: 1,
        txns,
        ..Default::default()
    };

    // ---- Replica-scaling table: slowdown over the same strategy at
    // backups = 1 (ack = all), the regression anchor column.
    let strategies = [
        StrategyKind::SmRc,
        StrategyKind::SmOb,
        StrategyKind::SmDd,
        StrategyKind::SmAd,
    ];
    let mut t = Table::new(&["backups", "policy", "SM-RC", "SM-OB", "SM-DD", "SM-AD"]);
    let base: Vec<f64> = strategies
        .iter()
        .map(|&k| cell(&plat, k, ReplicationConfig::default(), cfg) as f64)
        .collect();
    for &b in &BACKUPS {
        let mut policies = vec![AckPolicy::All];
        if b >= 3 {
            policies.push(AckPolicy::Majority);
        }
        for policy in policies {
            let mut cells = vec![format!("{b}"), policy.to_string()];
            for (i, &k) in strategies.iter().enumerate() {
                let ms = cell(&plat, k, ReplicationConfig::new(b, policy), cfg) as f64;
                cells.push(format!("{:.2}x", ms / base[i]));
            }
            t.row(cells);
        }
    }
    println!(
        "Figure 6 — Transact 4-1 replica-group scaling \
         (slowdown over backups=1, ack=all)\n{}",
        t.render()
    );

    // ---- Group fence-lag breakdown at 3 backups (per-backup report).
    for policy in [AckPolicy::All, AckPolicy::Quorum(2)] {
        let repl = ReplicationConfig::new(3, policy);
        let mut m = Mirror::with_replication(plat.clone(), StrategyKind::SmOb, repl, false)
            .expect("valid replication config");
        run_transact_on(&mut m, cfg);
        print!("{}", GroupReport::from_fabric(m.fabric()).render());
    }

    // ---- Simulator throughput while fanning out (perf tracking).
    let mut b = Bencher::new();
    for &n in &BACKUPS {
        for kind in [StrategyKind::SmOb, StrategyKind::SmDd] {
            let repl = ReplicationConfig::new(n, AckPolicy::All);
            let writes = cfg.txns * 4;
            b.bench_elems(
                &format!("transact/4-1/{kind}/backups-{n}"),
                (writes * n as u64) as f64,
                || cell(&plat, kind, repl, cfg),
            );
        }
    }
    pmsm::bench::emit_json(&b, "fig6_replicas");
}
