//! Figure-7 bench (ours): failover & rejoin dynamics — the Transact
//! microbenchmark swept over kill-time × ack-policy at `backups = 3`,
//! reporting completion (or the halt-mode stall point), per-backup dead
//! time and catch-up resync volume, plus simulator throughput while
//! fault-injecting. Emits `BENCH_fig7_failover.json` for run-over-run
//! perf tracking.
//!
//! Run: `cargo bench --bench fig7_failover`
//! Scale with PMSM_BENCH_TXNS (default 2000 transactions per cell) and
//! PMSM_BENCH_ITERS (wall-clock repetitions per timing).

use pmsm::bench::Bencher;
use pmsm::config::{AckPolicy, Platform, ReplicationConfig, StrategyKind};
use pmsm::metrics::report::Table;
use pmsm::net::{FaultsConfig, OnLoss};
use pmsm::workloads::transact::run_transact_faulted;
use pmsm::workloads::TransactConfig;

/// Kill instants as fractions of the fault-free makespan.
const KILL_FRACS: [(u64, u64); 3] = [(1, 4), (1, 2), (3, 4)];

fn faults(plan: &str, on_loss: OnLoss) -> FaultsConfig {
    FaultsConfig::with_plan(plan, on_loss).expect("valid plan")
}

fn main() {
    let txns: u64 = std::env::var("PMSM_BENCH_TXNS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2_000);
    let plat = Platform::default();
    let cfg = TransactConfig {
        epochs: 4,
        writes: 1,
        txns,
        ..Default::default()
    };
    let repl = |policy| ReplicationConfig::new(3, policy);

    // Fault-free baseline places the kill instants.
    let base = run_transact_faulted(
        &plat,
        StrategyKind::SmOb,
        repl(AckPolicy::All),
        FaultsConfig::default(),
        cfg,
    )
    .expect("baseline")
    .makespan;

    // ---- Kill-time x ack-policy matrix: kill backup 2, rejoin 20% of
    // the run later; report outcome relative to the fault-free run.
    let cells: [(AckPolicy, OnLoss); 4] = [
        (AckPolicy::All, OnLoss::Halt),
        (AckPolicy::All, OnLoss::Degrade),
        (AckPolicy::Majority, OnLoss::Halt),
        (AckPolicy::Quorum(2), OnLoss::Halt),
    ];
    let mut t = Table::new(&[
        "kill@",
        "policy",
        "on_loss",
        "outcome",
        "time",
        "txns",
        "dead(ns)",
        "resync(B)",
    ]);
    for &(num, den) in &KILL_FRACS {
        let kill_at = base * num / den;
        let rejoin_at = kill_at + base / 5;
        let plan = format!("kill:2@{kill_at},rejoin:2@{rejoin_at}");
        for &(policy, on_loss) in &cells {
            let out = run_transact_faulted(
                &plat,
                StrategyKind::SmOb,
                repl(policy),
                faults(&plan, on_loss),
                cfg,
            )
            .expect("valid fault config");
            let outcome = match &out.stalled {
                Some(s) => format!("STALL@{}", s.at),
                None => "completed".to_string(),
            };
            let dead: u64 = out.per_backup_dead_ns.iter().sum();
            let resync: u64 = out.per_backup_resync_lines.iter().sum::<u64>() * pmsm::LINE;
            t.row(vec![
                format!("{num}/{den}"),
                policy.to_string(),
                on_loss.to_string(),
                outcome,
                format!("{:.2}x", out.makespan as f64 / base as f64),
                format!("{}", out.txns),
                format!("{dead}"),
                format!("{resync}"),
            ]);
        }
    }
    println!(
        "Figure 7 — Transact 4-1 failover dynamics at backups=3 \
         (kill backup 2, rejoin +20% of run; time vs fault-free)\n{}",
        t.render()
    );

    // ---- Simulator throughput while fault-injecting (perf tracking).
    let mut b = Bencher::new();
    let kill_at = base / 2;
    let rejoin_at = kill_at + base / 5;
    let plan = format!("kill:2@{kill_at},rejoin:2@{rejoin_at}");
    for (name, policy, on_loss) in [
        ("all-halt", AckPolicy::All, OnLoss::Halt),
        ("all-degrade", AckPolicy::All, OnLoss::Degrade),
        ("quorum2-halt", AckPolicy::Quorum(2), OnLoss::Halt),
    ] {
        let writes = cfg.txns * 4;
        b.bench_elems(
            &format!("transact/4-1/sm-ob/failover/{name}"),
            (writes * 3) as f64,
            || {
                run_transact_faulted(
                    &plat,
                    StrategyKind::SmOb,
                    repl(policy),
                    faults(&plan, on_loss),
                    cfg,
                )
                .expect("valid fault config")
                .makespan
            },
        );
    }
    pmsm::bench::emit_json(&b, "fig7_failover");
}
