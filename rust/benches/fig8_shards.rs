//! Figure-8 bench (ours): address-space sharding — the Transact
//! microbenchmark swept over `shards ∈ {1, 2, 4, 8}` × `backups ∈
//! {1, 2}` × ack policy, reporting per-txn cost relative to the
//! unsharded run of the same group shape, plus the per-shard
//! [`ShardedReport`] rollup (write skew, per-shard fence profiles) and
//! simulator throughput while routing. Emits `BENCH_fig8_shards.json`
//! for run-over-run perf tracking; CI's bench-smoke job validates it
//! with `python/check_bench_json.py`.
//!
//! Run: `cargo bench --bench fig8_shards`
//! Scale with PMSM_BENCH_TXNS (default 2000 transactions per cell) and
//! PMSM_BENCH_ITERS (wall-clock repetitions per timing).

use pmsm::bench::Bencher;
use pmsm::config::{AckPolicy, Platform, ReplicationConfig, StrategyKind};
use pmsm::coordinator::{Mirror, ShardMapSpec, ShardingConfig};
use pmsm::metrics::report::Table;
use pmsm::metrics::ShardedReport;
use pmsm::net::FaultsConfig;
use pmsm::workloads::transact::run_transact_on;
use pmsm::workloads::{run_transact_sharded, TransactConfig};

const SHARDS: [usize; 4] = [1, 2, 4, 8];

fn cell(
    plat: &Platform,
    kind: StrategyKind,
    repl: ReplicationConfig,
    sharding: ShardingConfig,
    cfg: TransactConfig,
) -> u64 {
    run_transact_sharded(plat, kind, repl, sharding, cfg)
        .expect("valid sharding config")
        .makespan
}

fn main() {
    let txns: u64 = std::env::var("PMSM_BENCH_TXNS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2_000);
    let plat = Platform::default();
    let cfg = TransactConfig {
        epochs: 4,
        writes: 2,
        txns,
        ..Default::default()
    };

    // ---- Shard-scaling table: time relative to shards=1 of the same
    // (backups, policy) column, SM-OB and SM-DD. The random working set
    // spreads lines across shards, so cross-shard commit fences (max,
    // not sum) and per-shard wire parallelism set the trend.
    let cols: [(usize, AckPolicy); 3] = [
        (1, AckPolicy::All),
        (2, AckPolicy::All),
        (2, AckPolicy::Quorum(1)),
    ];
    for kind in [StrategyKind::SmOb, StrategyKind::SmDd] {
        let mut t = Table::new(&["shards", "b1/all", "b2/all", "b2/quorum:1"]);
        let base: Vec<f64> = cols
            .iter()
            .map(|&(b, p)| {
                cell(
                    &plat,
                    kind,
                    ReplicationConfig::new(b, p),
                    ShardingConfig::default(),
                    cfg,
                ) as f64
            })
            .collect();
        for &s in &SHARDS {
            let sharding = ShardingConfig::new(s, ShardMapSpec::Modulo);
            let mut cells = vec![format!("{s}")];
            for (i, &(b, p)) in cols.iter().enumerate() {
                // The sim is deterministic: s = 1 IS the baseline run.
                let ms = if s == 1 {
                    base[i]
                } else {
                    cell(&plat, kind, ReplicationConfig::new(b, p), sharding, cfg) as f64
                };
                cells.push(format!("{:.2}x", ms / base[i]));
            }
            t.row(cells);
        }
        println!(
            "Figure 8 — Transact 4-2 shard scaling, {kind} \
             (time vs shards=1 per column)\n{}",
            t.render()
        );
    }

    // ---- Per-shard rollup at the acceptance shape (4 shards x 2
    // backups): balance + fence profile per shard.
    let mut m = Mirror::try_build_sharded(
        plat.clone(),
        StrategyKind::SmOb,
        None,
        ReplicationConfig::new(2, AckPolicy::All),
        FaultsConfig::default(),
        ShardingConfig::new(4, ShardMapSpec::Modulo),
        false,
    )
    .expect("valid sharded mirror");
    let out = run_transact_on(&mut m, cfg);
    assert_eq!(out.txns, cfg.txns, "sharded run must commit every txn");
    print!("{}", ShardedReport::from_mirror(&m).render());

    // ---- Modulo vs contiguous-range map at 4 shards (routing cost and
    // balance differ; both must complete the full workload).
    let mut t = Table::new(&["map", "time", "write skew"]);
    for map in [
        ShardMapSpec::Modulo,
        ShardMapSpec::Range { stripe_lines: 1 << 10 },
    ] {
        let sharding = ShardingConfig::new(4, map);
        let mut m = Mirror::try_build_sharded(
            plat.clone(),
            StrategyKind::SmOb,
            None,
            ReplicationConfig::new(2, AckPolicy::All),
            FaultsConfig::default(),
            sharding,
            false,
        )
        .expect("valid sharded mirror");
        let out = run_transact_on(&mut m, cfg);
        let r = ShardedReport::from_mirror(&m);
        t.row(vec![
            map.to_string(),
            format!("{:.3} ms", out.makespan as f64 / 1e6),
            format!("{:.2}x", r.write_skew()),
        ]);
    }
    println!("map comparison at shards=4, backups=2\n{}", t.render());

    // ---- Simulator throughput while routing (perf tracking): the
    // fan-out hot path the CI bench-smoke gate watches.
    let mut b = Bencher::new();
    for &s in &SHARDS {
        for kind in [StrategyKind::SmOb, StrategyKind::SmDd] {
            let sharding = ShardingConfig::new(s, ShardMapSpec::Modulo);
            let repl = ReplicationConfig::new(2, AckPolicy::All);
            let writes = cfg.txns * (cfg.epochs as u64) * (cfg.writes as u64);
            b.bench_elems(
                &format!("transact/4-2/{kind}/shards-{s}/backups-2"),
                (writes * 2) as f64,
                || cell(&plat, kind, repl, sharding, cfg),
            );
        }
    }
    pmsm::bench::emit_json(&b, "fig8_shards");
}
