//! Figure-9 bench (ours): doorbell batching on the fan-out path — the
//! Transact microbenchmark swept over flush policy (`eager` = the
//! pre-batching anchor, `cap:4`, `cap:16`, `fence`) × backups × SM
//! strategy × shards, reporting the primary-side CPU busy time the
//! staged WQE pipeline recovers from the `N * post_cost` per-line
//! overhead (doorbells rung, mean batch size, busy time relative to
//! eager). Emits `BENCH_fig9_batching.json` with `doorbells` /
//! `posted_wqes` / `busy_ns` counters per cell — busy_ns is the primary
//! CPU cost itself, so the perf trajectory captures the amortization,
//! not just the ratios; CI's bench-smoke job validates the artifact
//! (including `doorbells <= posted_wqes`) with
//! `python/check_bench_json.py`.
//!
//! The bench also *asserts* the tentpole's acceptance shape: at
//! backups >= 2, SM-RC and SM-OB primary busy time strictly decreases
//! as the batch cap grows — so a regression in the amortization model
//! fails the CI gate instead of rotting in a table.
//!
//! Run: `cargo bench --bench fig9_batching`
//! Scale with PMSM_BENCH_TXNS (default 2000 transactions per cell) and
//! PMSM_BENCH_ITERS (wall-clock repetitions per timing).

use pmsm::bench::Bencher;
use pmsm::config::{AckPolicy, Platform, ReplicationConfig, StrategyKind};
use pmsm::coordinator::sched::RunOutcome;
use pmsm::coordinator::{ShardMapSpec, ShardingConfig};
use pmsm::metrics::report::Table;
use pmsm::net::FlushPolicy;
use pmsm::workloads::transact::run_transact_batched;
use pmsm::workloads::TransactConfig;

/// Flush-policy sweep: eager is the `batch_cap = 1` anchor column.
const POLICIES: [FlushPolicy; 4] = [
    FlushPolicy::Eager,
    FlushPolicy::Cap(4),
    FlushPolicy::Cap(16),
    FlushPolicy::Fence,
];

const BACKUPS: [usize; 3] = [1, 2, 4];

fn cell(
    plat: &Platform,
    kind: StrategyKind,
    backups: usize,
    policy: FlushPolicy,
    cfg: TransactConfig,
) -> RunOutcome {
    run_transact_batched(
        plat,
        kind,
        ReplicationConfig::new(backups, AckPolicy::All),
        policy,
        cfg,
    )
    .expect("valid replication config")
}

fn main() {
    let txns: u64 = std::env::var("PMSM_BENCH_TXNS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2_000);
    let plat = Platform::default();
    // Wide epochs (16 writes) so caps 4/16 actually differ before the
    // epoch fence forces a flush.
    let cfg = TransactConfig {
        epochs: 2,
        writes: 16,
        txns,
        ..Default::default()
    };

    // ---- Busy-time table: primary CPU busy relative to eager posting
    // of the same (strategy, backups) row — the N * post_cost headroom
    // the staged pipeline recovers.
    for kind in [StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd] {
        let mut t = Table::new(&[
            "backups",
            "eager",
            "cap:4",
            "cap:16",
            "fence",
            "doorbells(e->f)",
            "batch(f)",
        ]);
        for &b in &BACKUPS {
            let outs: Vec<RunOutcome> = POLICIES
                .iter()
                .map(|&p| cell(&plat, kind, b, p, cfg))
                .collect();
            let eager_busy = outs[0].busy_ns as f64;
            let mut cells = vec![format!("{b}")];
            for out in &outs {
                assert_eq!(out.txns, cfg.txns, "{kind}: every txn must commit");
                assert!(
                    out.doorbells <= out.posted_wqes,
                    "{kind}: doorbells {} > WQEs {}",
                    out.doorbells,
                    out.posted_wqes
                );
                cells.push(format!("{:.3}x", out.busy_ns as f64 / eager_busy));
            }
            cells.push(format!("{}->{}", outs[0].doorbells, outs[3].doorbells));
            cells.push(format!("{:.1}", outs[3].mean_batch()));
            t.row(cells);
            // Acceptance gate: with fan-out (backups >= 2), SM-RC/SM-OB
            // primary busy time strictly decreases with the batch cap.
            if b >= 2 && kind != StrategyKind::SmDd {
                assert!(
                    outs[0].busy_ns > outs[1].busy_ns
                        && outs[1].busy_ns > outs[2].busy_ns,
                    "{kind} backups={b}: busy not strictly decreasing with \
                     cap: eager {} cap4 {} cap16 {}",
                    outs[0].busy_ns,
                    outs[1].busy_ns,
                    outs[2].busy_ns
                );
                assert!(
                    outs[3].busy_ns <= outs[2].busy_ns,
                    "{kind} backups={b}: fence busier than cap:16"
                );
            }
        }
        println!(
            "Figure 9 — Transact 2-16 doorbell batching, {kind} \
             (primary busy vs eager; doorbells eager->fence)\n{}",
            t.render()
        );
    }

    // ---- Sharded fan-out: batching composes with sharding (each line
    // is staged on its owning shard's fabric).
    {
        let mut t = Table::new(&["shards", "eager busy", "fence busy", "recovered"]);
        for shards in [1usize, 2, 4] {
            let sharding = ShardingConfig::new(shards, ShardMapSpec::Modulo);
            let repl = ReplicationConfig::new(2, AckPolicy::All);
            let run = |policy: FlushPolicy| {
                let mut m = pmsm::coordinator::Mirror::try_build_sharded(
                    plat.clone(),
                    StrategyKind::SmOb,
                    None,
                    repl,
                    pmsm::net::FaultsConfig::default(),
                    sharding,
                    false,
                )
                .expect("valid sharded mirror");
                m.set_batching(policy);
                pmsm::workloads::transact::run_transact_on(&mut m, cfg)
            };
            let eager = run(FlushPolicy::Eager);
            let fenced = run(FlushPolicy::Fence);
            assert_eq!(fenced.posted_wqes, eager.posted_wqes);
            assert!(fenced.doorbells < eager.doorbells);
            t.row(vec![
                format!("{shards}"),
                format!("{:.3} ms", eager.busy_ns as f64 / 1e6),
                format!("{:.3} ms", fenced.busy_ns as f64 / 1e6),
                format!(
                    "{:.1}%",
                    100.0 * (1.0 - fenced.busy_ns as f64 / eager.busy_ns as f64)
                ),
            ]);
        }
        println!(
            "sharded fan-out at backups=2, SM-OB (fence vs eager)\n{}",
            t.render()
        );
    }

    // ---- Simulator throughput while staging/flushing (perf tracking):
    // the pipeline choke point the CI bench-smoke gate watches. Each
    // timing cell carries the doorbell/WQE counters of its simulated
    // run so the JSON records the amortization directly.
    let mut b = Bencher::new();
    for &backups in &[2usize, 4] {
        for &policy in &POLICIES {
            let kind = StrategyKind::SmOb;
            let writes = cfg.txns * (cfg.epochs as u64) * (cfg.writes as u64);
            // The sim is deterministic: every timed iteration produces
            // the same counters, so capture them from the last one.
            // `busy_ns` rides along so the perf trajectory records the
            // primary CPU cost batching recovers, not just counters.
            let mut counters = (0u64, 0u64, 0u64);
            b.bench_elems(
                &format!("transact/2-16/{kind}/backups-{backups}/{policy}"),
                (writes * backups as u64) as f64,
                || {
                    let out = cell(&plat, kind, backups, policy, cfg);
                    counters = (out.doorbells, out.posted_wqes, out.busy_ns);
                    out
                },
            );
            b.annotate_last(&[
                ("doorbells", counters.0),
                ("posted_wqes", counters.1),
                ("busy_ns", counters.2),
            ]);
        }
    }
    pmsm::bench::emit_json(&b, "fig9_batching");
}
