//! Mini benchmark harness (the offline registry has no `criterion`).
//!
//! Provides warmup + timed iterations with mean/stddev/min reporting and a
//! `harness = false` entry-point helper used by `rust/benches/*.rs`.

use crate::util::Summary;
use std::time::Instant;

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    /// Optional throughput denominator (elements per iteration).
    pub elems_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let mut s = format!(
            "{:<40} {:>12} /iter (±{:>10}, min {:>12}, n={})",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.stddev_ns),
            fmt_ns(self.min_ns),
            self.iters,
        );
        if let Some(e) = self.elems_per_iter {
            let per_sec = e / (self.mean_ns * 1e-9);
            s.push_str(&format!("  [{} elem/s]", fmt_count(per_sec)));
        }
        s
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

fn fmt_count(c: f64) -> String {
    if c >= 1e9 {
        format!("{:.2}G", c / 1e9)
    } else if c >= 1e6 {
        format!("{:.2}M", c / 1e6)
    } else if c >= 1e3 {
        format!("{:.2}K", c / 1e3)
    } else {
        format!("{c:.0}")
    }
}

/// Benchmark runner with a global time budget per benchmark.
pub struct Bencher {
    warmup_iters: u64,
    measure_iters: u64,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        // Respect PMSM_BENCH_ITERS for quick smoke runs.
        let iters = std::env::var("PMSM_BENCH_ITERS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(10);
        Bencher {
            warmup_iters: 2.min(iters),
            measure_iters: iters,
            results: Vec::new(),
        }
    }

    /// Run `f` and record wall-clock stats. `f` returns an opaque value to
    /// defeat dead-code elimination.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        self.bench_with_elems(name, None, &mut f)
    }

    /// Like [`Bencher::bench`] with a throughput denominator.
    pub fn bench_elems<T, F: FnMut() -> T>(
        &mut self,
        name: &str,
        elems: f64,
        mut f: F,
    ) -> &BenchResult {
        self.bench_with_elems(name, Some(elems), &mut f)
    }

    fn bench_with_elems<T>(
        &mut self,
        name: &str,
        elems: Option<f64>,
        f: &mut dyn FnMut() -> T,
    ) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut s = Summary::new();
        for _ in 0..self.measure_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            s.add(t0.elapsed().as_nanos() as f64);
        }
        let r = BenchResult {
            name: name.to_string(),
            iters: s.count(),
            mean_ns: s.mean(),
            stddev_ns: s.stddev(),
            min_ns: s.min(),
            elems_per_iter: elems,
        };
        println!("{}", r.report());
        self.results.push(r);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("PMSM_BENCH_ITERS", "3");
        let mut b = Bencher::new();
        let r = b.bench("spin", || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(r.mean_ns > 0.0);
        assert_eq!(r.iters, 3);
        std::env::remove_var("PMSM_BENCH_ITERS");
    }

    #[test]
    fn formatting_scales() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1_500.0), "1.500 µs");
        assert_eq!(fmt_ns(2_000_000.0), "2.000 ms");
        assert_eq!(fmt_ns(3e9), "3.000 s");
        assert_eq!(fmt_count(5_000_000.0), "5.00M");
    }
}
