//! Mini benchmark harness (the offline registry has no `criterion`).
//!
//! Provides warmup + timed iterations with mean/stddev/min reporting, a
//! `harness = false` entry-point helper used by `rust/benches/*.rs`, and
//! machine-readable `BENCH_<name>.json` emission (assembled with the
//! shared [`crate::util::json`] helpers — no `serde` offline) so
//! run-over-run perf trajectories can be tracked by tooling instead of
//! scraped from stdout. Every document stamps
//! [`json::SCHEMA_VERSION`](crate::util::json::SCHEMA_VERSION), which
//! CI's `python/check_bench_json.py` asserts on.

use crate::util::json;
use crate::util::Summary;
use std::time::Instant;

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    /// Optional throughput denominator (elements per iteration).
    pub elems_per_iter: Option<f64>,
    /// Extra integer counters attached by the bench (e.g. `doorbells`,
    /// `posted_wqes` for the batching benches); emitted as additional
    /// JSON keys that `python/check_bench_json.py` sanity-checks.
    pub counters: Vec<(String, u64)>,
}

impl BenchResult {
    /// One result as a JSON object (the `BENCH_*.json` schema element).
    pub fn to_json(&self) -> String {
        let elems_per_sec = match self.elems_per_iter {
            Some(e) if self.mean_ns > 0.0 => Some(e / (self.mean_ns * 1e-9)),
            _ => None,
        };
        let mut pairs: Vec<(&str, String)> = vec![
            ("name", json::esc(&self.name)),
            ("iters", self.iters.to_string()),
            ("mean_ns", json::num(self.mean_ns)),
            ("stddev_ns", json::num(self.stddev_ns)),
            ("min_ns", json::num(self.min_ns)),
            ("elems_per_iter", json::opt_num(self.elems_per_iter)),
            ("elems_per_sec", json::opt_num(elems_per_sec)),
        ];
        for (k, v) in &self.counters {
            pairs.push((k.as_str(), v.to_string()));
        }
        json::obj(&pairs)
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "{:<40} {:>12} /iter (±{:>10}, min {:>12}, n={})",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.stddev_ns),
            fmt_ns(self.min_ns),
            self.iters,
        );
        if let Some(e) = self.elems_per_iter {
            let per_sec = e / (self.mean_ns * 1e-9);
            s.push_str(&format!("  [{} elem/s]", fmt_count(per_sec)));
        }
        s
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

fn fmt_count(c: f64) -> String {
    if c >= 1e9 {
        format!("{:.2}G", c / 1e9)
    } else if c >= 1e6 {
        format!("{:.2}M", c / 1e6)
    } else if c >= 1e3 {
        format!("{:.2}K", c / 1e3)
    } else {
        format!("{c:.0}")
    }
}

/// Benchmark runner with a global time budget per benchmark.
pub struct Bencher {
    warmup_iters: u64,
    measure_iters: u64,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        // Respect PMSM_BENCH_ITERS for quick smoke runs.
        let iters = std::env::var("PMSM_BENCH_ITERS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(10);
        Bencher {
            warmup_iters: 2.min(iters),
            measure_iters: iters,
            results: Vec::new(),
        }
    }

    /// Run `f` and record wall-clock stats. `f` returns an opaque value to
    /// defeat dead-code elimination.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        self.bench_with_elems(name, None, &mut f)
    }

    /// Like [`Bencher::bench`] with a throughput denominator.
    pub fn bench_elems<T, F: FnMut() -> T>(
        &mut self,
        name: &str,
        elems: f64,
        mut f: F,
    ) -> &BenchResult {
        self.bench_with_elems(name, Some(elems), &mut f)
    }

    fn bench_with_elems<T>(
        &mut self,
        name: &str,
        elems: Option<f64>,
        f: &mut dyn FnMut() -> T,
    ) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut s = Summary::new();
        for _ in 0..self.measure_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            s.add(t0.elapsed().as_nanos() as f64);
        }
        let r = BenchResult {
            name: name.to_string(),
            iters: s.count(),
            mean_ns: s.mean(),
            stddev_ns: s.stddev(),
            min_ns: s.min(),
            elems_per_iter: elems,
            counters: Vec::new(),
        };
        println!("{}", r.report());
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Attach integer counters to the most recent result (emitted as
    /// extra `BENCH_*.json` keys — e.g. the doorbell/WQE totals of the
    /// simulated run a timing cell corresponds to).
    pub fn annotate_last(&mut self, counters: &[(&str, u64)]) {
        if let Some(r) = self.results.last_mut() {
            r.counters.extend(counters.iter().map(|(k, v)| (k.to_string(), *v)));
        }
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Render all recorded results as the `BENCH_*.json` document:
    /// `{"schema_version": N, "bench": <name>, "results": [...]}`.
    pub fn to_json(&self, bench: &str) -> String {
        let results: Vec<String> = self.results.iter().map(|r| r.to_json()).collect();
        let doc = json::obj(&[
            ("schema_version", json::SCHEMA_VERSION.to_string()),
            ("bench", json::esc(bench)),
            ("results", json::arr(&results)),
        ]);
        format!("{doc}\n")
    }

    /// Write `BENCH_<bench>.json` into `dir`; returns the path written.
    pub fn write_json_to(&self, dir: &str, bench: &str) -> std::io::Result<String> {
        let path = format!("{dir}/BENCH_{bench}.json");
        std::fs::write(&path, self.to_json(bench))?;
        Ok(path)
    }

    /// Write `BENCH_<bench>.json` into `$PMSM_BENCH_JSON_DIR` (default:
    /// the current directory); returns the path written.
    pub fn write_json(&self, bench: &str) -> std::io::Result<String> {
        let dir = std::env::var("PMSM_BENCH_JSON_DIR").unwrap_or_else(|_| ".".to_string());
        self.write_json_to(&dir, bench)
    }
}

/// Emit the bench's JSON artifact, tolerating a read-only working
/// directory (benches must still run in sandboxes).
pub fn emit_json(b: &Bencher, bench: &str) {
    match b.write_json(bench) {
        Ok(path) => println!("wrote {path}"),
        Err(e) => eprintln!("note: could not write BENCH_{bench}.json: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("PMSM_BENCH_ITERS", "3");
        let mut b = Bencher::new();
        let r = b.bench("spin", || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(r.mean_ns > 0.0);
        assert_eq!(r.iters, 3);
        std::env::remove_var("PMSM_BENCH_ITERS");
    }

    #[test]
    fn json_schema_is_well_formed() {
        let r = BenchResult {
            name: "transact/4-1/sm-ob".to_string(),
            iters: 5,
            mean_ns: 1234.5678,
            stddev_ns: f64::NAN, // must not leak NaN into JSON
            min_ns: 1000.0,
            elems_per_iter: Some(2000.0),
            counters: Vec::new(),
        };
        let j = r.to_json();
        assert!(j.contains("\"name\":\"transact/4-1/sm-ob\""), "{j}");
        assert!(j.contains("\"mean_ns\":1234.568"), "{j}");
        assert!(j.contains("\"stddev_ns\":0"), "{j}");
        assert!(j.contains("\"elems_per_sec\":"), "{j}");
        assert!(!j.contains("NaN"), "{j}");
        let mut b = Bencher::new();
        b.results.push(r);
        // Counters attach to the latest result and emit as extra keys.
        b.annotate_last(&[("doorbells", 8), ("posted_wqes", 64)]);
        let j = b.results.last().unwrap().to_json();
        assert!(j.contains("\"doorbells\":8"), "{j}");
        assert!(j.contains("\"posted_wqes\":64"), "{j}");
        let doc = b.to_json("fig_test");
        assert!(
            doc.starts_with(&format!(
                "{{\"schema_version\":{},\"bench\":\"fig_test\",\"results\":[",
                json::SCHEMA_VERSION
            )),
            "{doc}"
        );
        assert!(doc.trim_end().ends_with("]}"), "{doc}");
    }

    #[test]
    fn write_json_emits_a_file() {
        let mut b = Bencher::new();
        b.results.push(BenchResult {
            name: "x".to_string(),
            iters: 1,
            mean_ns: 1.0,
            stddev_ns: 0.0,
            min_ns: 1.0,
            elems_per_iter: None,
            counters: Vec::new(),
        });
        let dir = std::env::temp_dir().join("pmsm_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let dir = dir.to_str().unwrap().to_string();
        let path = b.write_json_to(&dir, "unit").unwrap();
        assert!(path.ends_with("BENCH_unit.json"), "{path}");
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"bench\":\"unit\""), "{text}");
        assert!(text.contains("\"elems_per_iter\":null"), "{text}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn formatting_scales() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1_500.0), "1.500 µs");
        assert_eq!(fmt_ns(2_000_000.0), "2.000 ms");
        assert_eq!(fmt_ns(3e9), "3.000 s");
        assert_eq!(fmt_count(5_000_000.0), "5.00M");
    }
}
