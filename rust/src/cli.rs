//! Command-line interface (hand-rolled; no `clap` in the offline
//! registry).
//!
//! Subcommands:
//!   * `run`      — one experiment (strategy x workload), prints stats.
//!   * `sweep`    — the Figure-4 Transact sweep (`--crossover`,
//!                  `--ablate` for the A1/A2 ablations).
//!   * `whisper`  — the Figure-5 WHISPER suite.
//!   * `analytic` — evaluate the AOT latency model via PJRT
//!                  (`--validate` cross-checks model vs simulator).
//!   * `recover`  — failure injection + recovery check.
//!   * `config`   — print the platform (Table 2).
//!   * `selftest` — Table 1 + quick invariant checks.

use crate::config::{
    AckPolicy, AdaptiveConfig, Experiment, Platform, ReplicationConfig, StrategyKind,
};
use crate::coordinator::{ConcurrencyConfig, MirrorBuilder, ShardingConfig};
use crate::metrics::report::{fig4_table, fig5_tables, Fig4Row, Fig5Row};
use crate::metrics::{GroupReport, ShardedReport};
use crate::net::{
    BatchingConfig, CoalesceMode, CoalescingConfig, FaultsConfig, FlushPolicy, LinkConfig,
    OnLoss, PersistDomain,
};
use crate::recovery;
use crate::replication::{KnobPredictor, Predictor};
use crate::runtime::{fallback_knob_predictor, fallback_predictor, LatencyModel};
use crate::workloads::transact::run_transact_on;
use crate::workloads::whisper::run_whisper_on;
use crate::workloads::{run_transact, run_whisper, TransactConfig, WhisperApp, WhisperConfig};
use anyhow::{bail, Context, Result};

/// Parsed flag set: positionals + `--key value` / `--flag` options.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: std::collections::HashMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Self {
        let mut args = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                // `--key value` unless the next token is another flag or
                // missing -> boolean flag.
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    args.options.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    args.options.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                args.positional.push(a.clone());
                i += 1;
            }
        }
        args
    }

    pub fn flag(&self, key: &str) -> bool {
        self.options.get(key).map(|v| v == "true").unwrap_or(false)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.options.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} {v}")),
            None => Ok(default),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        Ok(self.get_u64(key, default as u64)? as usize)
    }
}

/// Top-level dispatch.
pub fn main_with_args(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv);
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "run" => cmd_run(&args),
        "sweep" => cmd_sweep(&args),
        "whisper" => cmd_whisper(&args),
        "analytic" => cmd_analytic(&args),
        "recover" => cmd_recover(&args),
        "config" => cmd_config(&args),
        "selftest" => cmd_selftest(&args),
        "help" | "-h" | "--help" => {
            println!("{}", help_text());
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{}", help_text()),
    }
}

pub fn help_text() -> &'static str {
    "pmsm — RDMA-based synchronous mirroring of persistent memory (repro)\n\
     \n\
     USAGE: pmsm <command> [options]\n\
     \n\
     COMMANDS:\n\
       run       --strategy no-sm|sm-rc|sm-ob|sm-dd|sm-ad --workload transact|<app>\n\
                 [--epochs N --writes N --txns N --threads N --config FILE]\n\
                 [--backups N --ack-policy all|majority|quorum:K]\n\
                 [--fault-plan SPEC --on-loss halt|degrade]\n\
                 [--handoff-ns N --resync-line-ns N]\n\
                 [--election-handoff-ns N --election-line-ns N]\n\
                 [--shards S --shard-map modulo|range|range:LINES]\n\
                 [--flush-policy eager|cap:K|fence --batch-cap K]\n\
                 [--coalesce none|combine|sg|full]\n\
                 [--commit-pipelines N --group-fence-ns N]\n\
                 [--persist-domain adr|eadr|rpmem-flush|log-structured]\n\
                 [--adaptive [on|off] --adaptive-quorum on|off]\n\
                 [--adaptive-batch on|off --adaptive-feedback on|off]\n\
                 [--link-plan SPEC --transport-timeout-ns N]\n\
                 [--retry-count N --rnr-depth N --link-seed N]\n\
       sweep     Figure-4 Transact sweep  [--txns N] [--crossover] [--ablate]\n\
       whisper   Figure-5 WHISPER suite   [--ops N --threads N --app NAME]\n\
       analytic  AOT latency model via PJRT [--validate]\n\
       recover   failure injection + recovery check [--strategy S --txns N]\n\
                 [--backups N --ack-policy P --fault-plan SPEC --on-loss M]\n\
                 [--shards S --shard-map M --flush-policy P --batch-cap K]\n\
                 [--coalesce M --commit-pipelines N --group-fence-ns N]\n\
                 [--election-handoff-ns N --election-line-ns N]\n\
                 [--persist-domain D --link-plan SPEC]\n\
                 [--transport-timeout-ns N --retry-count N --rnr-depth N]\n\
                 (cross-replica ledger check; fault-aware when a plan is\n\
                 set; per-shard checks + cross-shard merge when sharded)\n\
       config    print platform model parameters (Table 2)\n\
       selftest  Table-1 transformations + invariant smoke checks\n\
     \n\
     REPLICA GROUPS: --backups N mirrors every write to N backups; the\n\
     durability fence completes per --ack-policy (all = true SM;\n\
     quorum:K / majority = K-durable, tolerating K-1 backup losses).\n\
     \n\
     SHARDING: --shards S partitions the PM line-address space over S\n\
     independent replica groups (each with its own backups, ack policy\n\
     and fault plan); --shard-map picks the partition (modulo = line-\n\
     interleaved, range:LINES = contiguous stripes). A transaction's\n\
     commit fence completes at the max across the shards it touched.\n\
     CLI flags override the [sharding] config table.\n\
     \n\
     BATCHING: --flush-policy stages WQEs in a per-thread submit queue\n\
     and rings one doorbell per backup per flush (eager = one doorbell\n\
     per WQE, the pre-batching model; cap:K = flush every K staged line\n\
     writes; fence = flush only at ordering/durability fences).\n\
     --batch-cap K is shorthand for cap:K; cap 1 == eager. Fences always\n\
     flush first, so batching never reorders across persistence points.\n\
     \n\
     COALESCING: --coalesce runs a coalescing stage over each backup's\n\
     chain at flush time (requires a staged flush policy). combine =\n\
     same-line overwrites within one epoch collapse to the last writer;\n\
     sg = address-contiguous same-verb WQEs merge into one multi-line\n\
     span (one QP + NIC slot + wire_line_ns per extra line; every line\n\
     still persists individually on the backup); full = both; none =\n\
     the plain batching pipeline, event-for-event.\n\
     \n\
     CONCURRENCY: --commit-pipelines P runs P concurrent commit\n\
     pipelines per shard; threads are admitted pipeline id % P and\n\
     queue (blocked, not busy) while their pipeline drains. P=1 with a\n\
     group-fence window models the serial primary under the gated\n\
     path; P=1 with window 0 is the legacy loop, event-for-event.\n\
     --group-fence-ns W lets a durability fence issued within W ns of\n\
     the previous one piggyback on it: the requester skips the post\n\
     cost and issue slots but the responder still drains and persists,\n\
     and the ack policy applies unchanged, so per-txn durability acks\n\
     are never weakened. CLI flags override [concurrency] config.\n\
     \n\
     PERSIST DOMAINS: --persist-domain picks what a completed RDMA\n\
     write means for the backup's persistence (overrides the [remote]\n\
     config table). adr = the paper's platform, event-for-event the\n\
     pre-domain model: writes persist once the memory controller\n\
     admits them, so SM-RC still drains via rcommit. eadr =\n\
     battery-backed caches; completion implies persistent, rcommit\n\
     drains collapse and durability verdicts widen. rpmem-flush =\n\
     completions leave lines volatile until an explicit flush verb\n\
     rides the WQE flush choke point (verdicts narrow; flush_verbs <=\n\
     doorbells by construction). log-structured = the backup appends\n\
     sequentially and compacts same-line rewrites in the background\n\
     (compaction_lines). Per-domain counters (flush verbs, compacted\n\
     lines, volatile-window ns) surface in run stats, group reports\n\
     and bench JSON.\n\
     \n\
     ADAPTIVE CONTROL: with --strategy sm-ad, --adaptive turns on the\n\
     online per-class control plane: at each transaction begin the\n\
     controller picks a knob vector — replication mode (SM-OB/SM-DD),\n\
     ack quorum k (never below the configured --ack-policy floor) and\n\
     doorbell batch cap — from the 5-input latency model plus per-class\n\
     EWMAs of measured commit latency (hysteresis suppresses thrash).\n\
     --adaptive-quorum / --adaptive-batch / --adaptive-feedback toggle\n\
     one axis (each implies --adaptive); [adaptive] in --config sets\n\
     ewma_pct / hysteresis_pct. Disabled (the default), sm-ad is the\n\
     static per-txn OB/DD pick, event-for-event.\n\
     \n\
     LOSSY LINKS: --link-plan injects wire faults on the primary->backup\n\
     links (overrides the [link] config table). Tokens: drop:B@T loses\n\
     the message in flight at T; drop:B@T1..T2[:P] loses every (or a\n\
     P-fraction of) messages issued in the window; delay:B@T:D delivers\n\
     D ns late (D past the ack timeout also triggers a spurious\n\
     retransmit); dup:B@T delivers twice; loss:B:P drops a seeded-random\n\
     P-fraction for the whole run (P like 0.5% or 10%). Lost and\n\
     unacked messages arm a per-QP ack timeout\n\
     (--transport-timeout-ns) and retransmit with exponential backoff\n\
     up to --retry-count times; --rnr-depth N makes a backup whose\n\
     remote engine holds >= N pending lines answer RNR NAK (one extra\n\
     round trip, never a timeout). Retry exhaustion moves the QP to an\n\
     error state; the fabric heals it by re-establishing the\n\
     connection and replaying from the last remotely-acked sequence\n\
     number — the same resync path a killed backup rejoins through, so\n\
     --on-loss halt/degrade apply unchanged. Backups deduplicate\n\
     replayed (thread, seq) pairs, so retransmits never double-apply\n\
     and the ledger stays truthful. The durability verdict is\n\
     unchanged: a fence completes only on real remote acks.\n\
     \n\
     FAULT PLANS: --fault-plan \"kill:B@T,rejoin:B@T,...\" kills/rejoins\n\
     backup B at virtual time T (ns). Killed backups leave fan-out and\n\
     ack accounting; --on-loss halt stops at an unsatisfiable fence\n\
     (reported stall) while degrade clamps the quorum to the survivors.\n\
     A rejoining backup resyncs the missed ledger suffix from the\n\
     healthiest peer (--handoff-ns + lines x --resync-line-ns) before\n\
     re-entering the quorum. Under sharding a kill models the loss of\n\
     a backup node: replica B of every shard dies at T.\n\
     \n\
     PRIMARY FAILOVER: kill:p@T kills the primary itself. The fabric\n\
     revokes its write permission (fencing in-flight staged WQE\n\
     chains), runs a deterministic leader election — the surviving\n\
     backup with the longest certified ledger prefix wins, ties to the\n\
     lowest replica id — re-replicates the winner's certified suffix\n\
     to lagging peers, and only then admits new writes (downtime =\n\
     --election-handoff-ns + lines x --election-line-ns). Under\n\
     sharding all S shards fail over as one node. rejoin:p@T brings\n\
     the deposed primary back as a backup via the ordinary catch-up\n\
     resync. A kill:p with no surviving candidate records a stall;\n\
     rejoin:p is rejected under SM-RC (volatile backup state cannot\n\
     host a demoted primary's catch-up resync).\n"
}

fn platform_from(args: &Args) -> Result<Platform> {
    match args.get("config") {
        Some(path) => Ok(Experiment::from_file(path)?.platform),
        None => Ok(Platform::default()),
    }
}

/// Everything a run-style command needs from `--config` + CLI
/// overrides, as one named bundle (it was a 6-tuple once; new knobs
/// land here instead of rippling through every call site).
#[derive(Clone, Debug)]
pub struct RunSetup {
    pub plat: Platform,
    pub repl: ReplicationConfig,
    pub faults: FaultsConfig,
    pub sharding: ShardingConfig,
    pub batching: BatchingConfig,
    pub coalescing: CoalescingConfig,
    pub concurrency: ConcurrencyConfig,
    pub adaptive: AdaptiveConfig,
    pub link: LinkConfig,
}

/// Platform + replica-group shape + failure dynamics + sharding +
/// batching + coalescing + concurrency + adaptive control + link
/// shape: `--config` supplies all nine (via the `[replication]` /
/// `[faults]` / `[sharding]` / `[batching]` / `[coalescing]` /
/// `[concurrency]` / `[adaptive]` / `[link]` sections); `--backups` /
/// `--ack-policy` / `--fault-plan` / `--on-loss` / `--handoff-ns` /
/// `--resync-line-ns` / `--election-handoff-ns` / `--election-line-ns`
/// / `--shards` / `--shard-map` / `--flush-policy` / `--batch-cap` /
/// `--coalesce` / `--commit-pipelines` / `--group-fence-ns` /
/// `--persist-domain` / `--link-plan` / `--transport-timeout-ns` /
/// `--retry-count` / `--rnr-depth` / `--link-seed` override (the
/// election flags land in the `[election]` table's slots inside the
/// faults bundle; the persist domain lands in the platform's
/// `[remote]` slot).
fn setup_from(args: &Args) -> Result<RunSetup> {
    let mut s = match args.get("config") {
        Some(path) => {
            let e = Experiment::from_file(path)?;
            RunSetup {
                plat: e.platform,
                repl: e.replication,
                faults: e.faults,
                sharding: e.sharding,
                batching: e.batching,
                coalescing: e.coalescing,
                concurrency: e.concurrency,
                adaptive: e.adaptive,
                link: e.link,
            }
        }
        None => RunSetup {
            plat: Platform::default(),
            repl: ReplicationConfig::default(),
            faults: FaultsConfig::default(),
            sharding: ShardingConfig::default(),
            batching: BatchingConfig::default(),
            coalescing: CoalescingConfig::default(),
            concurrency: ConcurrencyConfig::default(),
            adaptive: AdaptiveConfig::default(),
            link: LinkConfig::default(),
        },
    };
    if let Some(b) = args.get("backups") {
        s.repl.backups = b.parse().with_context(|| format!("--backups {b}"))?;
    }
    if let Some(v) = args.get("ack-policy") {
        s.repl.ack_policy = v.parse::<AckPolicy>().context("--ack-policy")?;
    }
    if let Some(v) = args.get("fault-plan") {
        s.faults.plan = v.parse().context("--fault-plan")?;
    }
    if let Some(v) = args.get("on-loss") {
        s.faults.on_loss = v.parse().context("--on-loss")?;
    }
    s.faults.handoff_ns = args.get_u64("handoff-ns", s.faults.handoff_ns)?;
    s.faults.resync_line_ns = args.get_u64("resync-line-ns", s.faults.resync_line_ns)?;
    if let Some(v) = args.get("election-handoff-ns") {
        s.faults.election.handoff_ns = v.parse().with_context(|| {
            format!("--election-handoff-ns {v} (must be a duration in ns >= 0)")
        })?;
    }
    if let Some(v) = args.get("election-line-ns") {
        s.faults.election.line_ns = v.parse().with_context(|| {
            format!("--election-line-ns {v} (must be a duration in ns >= 0)")
        })?;
    }
    if let Some(v) = args.get("shards") {
        s.sharding.shards = v
            .parse()
            .with_context(|| format!("--shards {v} (must be a count >= 1)"))?;
    }
    if let Some(v) = args.get("shard-map") {
        s.sharding.map = v.parse().context("--shard-map")?;
    }
    if let Some(v) = args.get("flush-policy") {
        s.batching.policy = v.parse::<FlushPolicy>().context("--flush-policy")?;
    }
    if let Some(v) = args.get("batch-cap") {
        // Shorthand for --flush-policy cap:K (wins when both are given).
        let k: usize = v
            .parse()
            .with_context(|| format!("--batch-cap {v} (must be a count >= 1)"))?;
        s.batching.policy = FlushPolicy::Cap(k);
    }
    if let Some(v) = args.get("coalesce") {
        s.coalescing.mode = v.parse::<CoalesceMode>().context("--coalesce")?;
    }
    if let Some(v) = args.get("persist-domain") {
        s.plat.persist_domain = v
            .parse::<PersistDomain>()
            .map_err(|e| anyhow::anyhow!("--persist-domain {v}: {e}"))?;
    }
    if let Some(v) = args.get("commit-pipelines") {
        s.concurrency.commit_pipelines = v
            .parse()
            .with_context(|| format!("--commit-pipelines {v} (must be a count >= 1)"))?;
    }
    if let Some(v) = args.get("group-fence-ns") {
        s.concurrency.group_fence_ns = v.parse().with_context(|| {
            format!(
                "--group-fence-ns {v} (must be a window in ns, >= 0 and \
                 fitting in 64 bits)"
            )
        })?;
    }
    if let Some(v) = args.get("link-plan") {
        s.link.plan = v.parse().context("--link-plan")?;
    }
    if let Some(v) = args.get("transport-timeout-ns") {
        s.link.transport_timeout_ns = v.parse().with_context(|| {
            format!("--transport-timeout-ns {v} (must be a duration in ns >= 1)")
        })?;
    }
    if let Some(v) = args.get("retry-count") {
        s.link.retry_count = v
            .parse()
            .with_context(|| format!("--retry-count {v} (must be a count >= 0)"))?;
    }
    if let Some(v) = args.get("rnr-depth") {
        s.link.rnr_depth = v
            .parse()
            .with_context(|| format!("--rnr-depth {v} (must be a line count >= 0)"))?;
    }
    if let Some(v) = args.get("link-seed") {
        s.link.seed = v
            .parse()
            .with_context(|| format!("--link-seed {v} (must be a u64 seed)"))?;
    }
    // `--adaptive` turns the control plane on; the per-axis flags
    // enable it implicitly (asking for an axis means asking for the
    // controller) and accept on/off to disable one axis of an
    // [adaptive] config table.
    if args.get("adaptive").is_some() {
        s.adaptive.enabled = parse_switch(args, "adaptive")?;
    }
    if args.get("adaptive-quorum").is_some() {
        s.adaptive.quorum = parse_switch(args, "adaptive-quorum")?;
        s.adaptive.enabled |= s.adaptive.quorum;
    }
    if args.get("adaptive-feedback").is_some() {
        s.adaptive.feedback = parse_switch(args, "adaptive-feedback")?;
        s.adaptive.enabled |= s.adaptive.feedback;
    }
    if args.get("adaptive-batch").is_some() {
        s.adaptive.batch = parse_switch(args, "adaptive-batch")?;
        s.adaptive.enabled |= s.adaptive.batch;
    }
    s.repl.validate()?;
    s.faults.validate(s.repl.backups)?;
    s.sharding.validate()?;
    s.batching.validate()?;
    s.coalescing.validate_with(s.batching.policy)?;
    s.concurrency.validate()?;
    s.adaptive.validate()?;
    s.link.validate(s.repl.backups)?;
    Ok(s)
}

/// Parse an on/off CLI switch: bare `--flag` means on; `--flag on|off`
/// (or true/false) picks a side explicitly.
fn parse_switch(args: &Args, key: &str) -> Result<bool> {
    match args.get(key) {
        None => Ok(false),
        Some("true") | Some("on") | Some("1") => Ok(true),
        Some("false") | Some("off") | Some("0") => Ok(false),
        Some(v) => bail!("--{key} {v}: expected on/off"),
    }
}

/// A predictor for `SmAd` (PJRT model if the artifacts load, else the
/// closed-form fallback), `None` for fixed strategies.
fn predictor_for(plat: &Platform, strategy: StrategyKind) -> Result<Option<Predictor>> {
    if strategy != StrategyKind::SmAd {
        return Ok(None);
    }
    Ok(Some(match LatencyModel::load(plat) {
        Ok(m) => m.predictor()?,
        Err(e) => {
            eprintln!("note: PJRT model unavailable ({e}); using fallback");
            fallback_predictor(plat)
        }
    }))
}

/// The 5-input knob model for the adaptive control plane (PJRT base
/// curve + analytic quorum/batch margins when the artifacts load, else
/// the fully closed-form fallback). `None` unless `sm-ad` runs with
/// `[adaptive]` enabled.
fn knob_predictor_for(
    plat: &Platform,
    strategy: StrategyKind,
    adaptive: AdaptiveConfig,
) -> Result<Option<KnobPredictor>> {
    if strategy != StrategyKind::SmAd || !adaptive.enabled {
        return Ok(None);
    }
    Ok(Some(match LatencyModel::load(plat) {
        Ok(m) => m.knob_predictor(plat)?,
        Err(_) => fallback_knob_predictor(plat),
    }))
}

fn cmd_run(args: &Args) -> Result<()> {
    let RunSetup {
        plat,
        repl,
        faults,
        sharding,
        batching,
        coalescing,
        concurrency,
        adaptive,
        link,
    } = setup_from(args)?;
    let strategy: StrategyKind = args.get("strategy").unwrap_or("sm-ob").parse()?;
    let workload = args.get("workload").unwrap_or("transact");
    let threads = args.get_usize("threads", 1)?;
    let predictor = predictor_for(&plat, strategy)?;
    let knob_predictor = knob_predictor_for(&plat, strategy, adaptive)?;
    let injecting = !faults.plan.is_empty();
    if injecting {
        println!(
            "fault plan: {} (on_loss = {}, handoff {} ns, resync {} ns/line)",
            faults.plan, faults.on_loss, faults.handoff_ns, faults.resync_line_ns
        );
    }
    if faults.plan.has_primary_faults() {
        println!(
            "election: handoff {} ns, re-replication {} ns/line (longest \
             certified prefix wins, ties to lowest id)",
            faults.election.handoff_ns, faults.election.line_ns
        );
    }
    if sharding.shards > 1 {
        println!(
            "sharding: {} shards, map {} (each shard: {} backup(s), ack {})",
            sharding.shards, sharding.map, repl.backups, repl.ack_policy
        );
    }
    if !batching.policy.is_eager() {
        println!(
            "batching: flush policy {} (doorbell {} ns amortized over \
             staged WQEs at {} ns each)",
            batching.policy, plat.doorbell_ns, plat.wqe_stage_ns
        );
    }
    if coalescing.mode != CoalesceMode::None {
        let what = match (coalescing.mode.combining(), coalescing.mode.sg()) {
            (true, true) => "same-epoch write combining + scatter-gather spans",
            (true, false) => "same-epoch write combining",
            _ => "scatter-gather spans",
        };
        // wire_line_ns only matters when spans can form.
        let span_cost = if coalescing.mode.sg() {
            format!("; extra span lines at {} ns each on the wire", plat.wire_line_ns)
        } else {
            String::new()
        };
        println!("coalescing: {} ({what}{span_cost})", coalescing.mode);
    }
    if concurrency.enabled() {
        println!(
            "concurrency: {} commit pipeline(s) per shard, group-fence \
             window {} ns",
            concurrency.commit_pipelines, concurrency.group_fence_ns
        );
    }
    if plat.persist_domain != PersistDomain::Adr {
        println!("persist domain: {} (adr is the paper's anchor)", plat.persist_domain);
    }
    if link.enabled() {
        println!(
            "lossy link: plan {} (ack timeout {} ns, retry {}, rnr depth {}, \
             seed {})",
            link.plan, link.transport_timeout_ns, link.retry_count, link.rnr_depth,
            link.seed
        );
    }
    if adaptive.enabled && strategy == StrategyKind::SmAd {
        println!(
            "adaptive: per-class control plane (quorum {}, batch {}, \
             feedback {}; ewma {}%, hysteresis {}%)",
            if adaptive.quorum { "on" } else { "off" },
            if adaptive.batch { "on" } else { "off" },
            if adaptive.feedback { "on" } else { "off" },
            adaptive.ewma_pct,
            adaptive.hysteresis_pct
        );
    }
    let mut builder = MirrorBuilder::new(plat, strategy)
        .replication(repl)
        .faults(faults)
        .sharding(sharding)
        .batching(batching.policy)
        .coalescing(coalescing.mode)
        .concurrency(concurrency)
        .adaptive(adaptive)
        .link(link.clone());
    if let Some(p) = predictor {
        builder = builder.predictor(p);
    }
    if let Some(p) = knob_predictor {
        builder = builder.knob_predictor(p);
    }
    let mut mirror = builder.build()?;

    let outcome = if workload == "transact" {
        let cfg = TransactConfig {
            epochs: args.get_u64("epochs", 4)? as u32,
            writes: args.get_u64("writes", 1)? as u32,
            txns: args.get_u64("txns", 10_000)?,
            threads,
            seed: args.get_u64("seed", 42)?,
            ..Default::default()
        };
        println!(
            "transact {}-{} x {} txns, {} threads, strategy {}, \
             {} backup(s), ack {}",
            cfg.epochs,
            cfg.writes,
            cfg.txns,
            cfg.threads,
            strategy,
            repl.backups,
            repl.ack_policy
        );
        run_transact_on(&mut mirror, cfg)
    } else {
        let app = WhisperApp::parse(workload)
            .with_context(|| format!("unknown workload {workload:?}"))?;
        let cfg = WhisperConfig {
            app,
            ops: args.get_u64("ops", 2_000)?,
            threads: args.get_usize("threads", 4)?,
            seed: args.get_u64("seed", 42)?,
        };
        println!(
            "whisper {} x {} ops, {} threads, strategy {}, \
             {} backup(s), ack {}",
            app, cfg.ops, cfg.threads, strategy, repl.backups, repl.ack_policy
        );
        run_whisper_on(&mut mirror, cfg)
    };

    println!("  makespan      : {:.3} ms", outcome.makespan as f64 / 1e6);
    println!("  transactions  : {}", outcome.txns);
    println!("  writes        : {}", outcome.writes);
    println!("  epochs/txn    : {:.1}", outcome.epochs_per_txn());
    println!("  writes/epoch  : {:.2}", outcome.writes_per_epoch());
    println!("  throughput    : {:.0} txn/s", outcome.txn_per_sec());
    println!("  cpu busy      : {:.3} ms", outcome.busy_ns as f64 / 1e6);
    println!(
        "  doorbells     : {} over {} lines (mean batch {:.2})",
        outcome.doorbells,
        outcome.posted_wqes,
        outcome.mean_batch()
    );
    println!(
        "  wire          : {} WQEs (mean span {:.2}), {} writes combined",
        outcome.wire_wqes,
        outcome.mean_span(),
        outcome.combined_writes
    );
    if outcome.flush_verbs > 0
        || outcome.compaction_lines > 0
        || outcome.volatile_window_ns > 0
    {
        println!(
            "  persistence   : domain {}, {} flush verb(s), {} compacted \
             line(s), {} ns-line volatile window",
            outcome.persist_domain,
            outcome.flush_verbs,
            outcome.compaction_lines,
            outcome.volatile_window_ns
        );
    }
    if link.enabled() || outcome.retransmits > 0 || outcome.rnr_naks > 0 {
        println!(
            "  transport     : {} retransmit(s) ({} timeout, {} rnr nak), \
             {:.3} ms backoff, {} qp reset(s)",
            outcome.retransmits,
            outcome.transport_timeouts,
            outcome.rnr_naks,
            outcome.backoff_ns as f64 / 1e6,
            outcome.qp_resets
        );
        println!(
            "                  {} duplicate line(s) on the wire, {} dropped \
             by receiver dedup",
            outcome.dups_injected, outcome.dup_drops
        );
    }
    if outcome.decisions.chose_ob + outcome.decisions.chose_dd > 0 {
        let d = &outcome.decisions;
        println!(
            "  adaptive      : {} ob / {} dd, {} switch(es), {} feedback \
             sample(s), mean model err {:.1}%",
            d.chose_ob,
            d.chose_dd,
            d.adaptive_switches,
            d.feedback_samples,
            d.mean_err_pct()
        );
    }
    if concurrency.enabled() {
        println!(
            "  fences        : {} issued + {} piggybacked ({:.2}/txn)",
            outcome.fences_issued,
            outcome.fence_piggybacks,
            outcome.fences_per_txn()
        );
        println!(
            "  pipelines     : {} per shard, {} waits ({:.3} ms queued, \
             occupancy {:.3})",
            outcome.commit_pipelines,
            outcome.pipeline_waits,
            outcome.pipeline_wait_ns as f64 / 1e6,
            outcome.pipeline_occupancy()
        );
    }
    if outcome.membership_epochs > 0 {
        println!(
            "  failover      : {} epoch(s), downtime {:.3} ms, {} line(s) \
             re-replicated, {} staged WQE(s) revoked",
            outcome.membership_epochs,
            outcome.failover_downtime_ns as f64 / 1e6,
            outcome.rereplicated_lines,
            outcome.revoked_wqes
        );
    }
    if let Some(stall) = &outcome.stalled {
        println!("  STALL         : {stall}");
        if stall.on_loss == OnLoss::Halt {
            println!(
                "                  the run stopped at the kill point; \
                 durability was never weakened"
            );
        }
    }
    if sharding.shards > 1 {
        print!("{}", ShardedReport::from_mirror(&mirror).render());
    } else if repl.backups > 1 || injecting {
        let mut r = GroupReport::from_fabric(mirror.fabric());
        r.set_decisions(&mirror.decision_stats());
        print!("{}", r.render());
    }
    Ok(())
}

/// Figure-4 grid used across sweep/bench/analytic commands.
pub const FIG4_EPOCHS: [u32; 5] = [1, 4, 16, 64, 256];
pub const FIG4_WRITES: [u32; 4] = [1, 2, 4, 8];

/// Run the Figure-4 sweep; returns the measured rows.
pub fn fig4_sweep(plat: &Platform, txns: u64, threads: usize) -> Vec<Fig4Row> {
    let mut rows = Vec::new();
    for &w in &FIG4_WRITES {
        for &e in &FIG4_EPOCHS {
            // Keep total writes roughly constant across configs.
            let t = (txns / (e as u64 * w as u64)).max(20);
            let cfg = TransactConfig {
                epochs: e,
                writes: w,
                txns: t,
                threads,
                ..Default::default()
            };
            let base = run_transact(plat, StrategyKind::NoSm, cfg).makespan as f64;
            let rc = run_transact(plat, StrategyKind::SmRc, cfg).makespan as f64;
            let ob = run_transact(plat, StrategyKind::SmOb, cfg).makespan as f64;
            let dd = run_transact(plat, StrategyKind::SmDd, cfg).makespan as f64;
            rows.push(Fig4Row {
                epochs: e,
                writes: w,
                rc: rc / base,
                ob: ob / base,
                dd: dd / base,
            });
        }
    }
    rows
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let plat = platform_from(args)?;
    let txns = args.get_u64("txns", 20_000)?;
    let threads = args.get_usize("threads", 1)?;
    let rows = fig4_sweep(&plat, txns, threads);
    println!("{}", fig4_table(&rows, None));

    if args.flag("crossover") {
        println!("A1 — OB/DD crossover (w=1):");
        for r in rows.iter().filter(|r| r.writes == 1) {
            let winner = if r.ob < r.dd { "SM-OB" } else { "SM-DD" };
            println!(
                "  e={:<4} OB {:5.1}x DD {:5.1}x  -> {winner}",
                r.epochs, r.ob, r.dd
            );
        }
    }
    if args.flag("ablate") {
        println!("\nA2 — sensitivity ablations (Transact 64-1):");
        let cfg = TransactConfig {
            epochs: 64,
            writes: 1,
            txns: 500,
            threads,
            ..Default::default()
        };
        for mcq in [16usize, 64, 256] {
            let mut p = plat.clone();
            p.mcq = mcq;
            let s = crate::workloads::transact::slowdown(&p, StrategyKind::SmDd, cfg);
            println!("  mcq={mcq:<4}         SM-DD {s:5.1}x");
        }
        for ddio in [1usize, 2, 4, 8] {
            let mut p = plat.clone();
            p.ddio_ways = ddio;
            let s = crate::workloads::transact::slowdown(&p, StrategyKind::SmOb, cfg);
            println!("  ddio_ways={ddio:<2}     SM-OB {s:5.1}x");
        }
        for barrier in [25u64, 75, 150, 300] {
            let mut p = plat.clone();
            p.ob_barrier = barrier;
            let s = crate::workloads::transact::slowdown(&p, StrategyKind::SmOb, cfg);
            println!("  ob_barrier={barrier:<4}  SM-OB {s:5.1}x");
        }
        for nt in [110u64, 150, 210, 400] {
            let mut p = plat.clone();
            p.nt_serial = nt;
            let s = crate::workloads::transact::slowdown(&p, StrategyKind::SmDd, cfg);
            println!("  nt_serial={nt:<4}   SM-DD {s:5.1}x");
        }
    }
    Ok(())
}

/// Run the Figure-5 suite; returns per-app rows.
pub fn fig5_suite(
    plat: &Platform,
    ops: u64,
    threads: usize,
    only: Option<WhisperApp>,
) -> Vec<Fig5Row> {
    let mut rows = Vec::new();
    for app in WhisperApp::ALL {
        if let Some(o) = only {
            if app != o {
                continue;
            }
        }
        // Echo batches ~64 updates per txn: scale op count down.
        let app_ops = if app == WhisperApp::Echo {
            (ops / 16).max(10)
        } else {
            ops
        };
        let cfg = WhisperConfig {
            app,
            ops: app_ops,
            threads,
            seed: 42,
        };
        let base = run_whisper(plat, StrategyKind::NoSm, cfg);
        let rc = run_whisper(plat, StrategyKind::SmRc, cfg);
        let ob = run_whisper(plat, StrategyKind::SmOb, cfg);
        let dd = run_whisper(plat, StrategyKind::SmDd, cfg);
        let b = base.makespan as f64;
        rows.push(Fig5Row {
            app: app.name().to_string(),
            time_rc: rc.makespan as f64 / b,
            time_ob: ob.makespan as f64 / b,
            time_dd: dd.makespan as f64 / b,
            tput_rc: rc.txn_per_sec() / base.txn_per_sec(),
            tput_ob: ob.txn_per_sec() / base.txn_per_sec(),
            tput_dd: dd.txn_per_sec() / base.txn_per_sec(),
        });
    }
    rows
}

fn cmd_whisper(args: &Args) -> Result<()> {
    let plat = platform_from(args)?;
    let ops = args.get_u64("ops", 2_000)?;
    let threads = args.get_usize("threads", 4)?;
    let only = match args.get("app") {
        Some(name) => {
            Some(WhisperApp::parse(name).with_context(|| format!("unknown app {name:?}"))?)
        }
        None => None,
    };
    let rows = fig5_suite(&plat, ops, threads, only);
    println!("{}", fig5_tables(&rows));
    Ok(())
}

fn cmd_analytic(args: &Args) -> Result<()> {
    let plat = platform_from(args)?;
    let model = LatencyModel::load(&plat)?;
    let mut e = Vec::new();
    let mut w = Vec::new();
    for &wi in &FIG4_WRITES {
        for &ei in &FIG4_EPOCHS {
            e.push(ei as f32);
            w.push(wi as f32);
        }
    }
    let (_, slow) = model.predict(&e, &w)?;
    let pred: Vec<Fig4Row> = e
        .iter()
        .zip(&w)
        .zip(&slow)
        .map(|((&e, &w), s)| Fig4Row {
            epochs: e as u32,
            writes: w as u32,
            rc: s[0] as f64,
            ob: s[1] as f64,
            dd: s[2] as f64,
        })
        .collect();

    if args.flag("validate") {
        let txns = args.get_u64("txns", 5_000)?;
        let meas = fig4_sweep(&plat, txns, 1);
        println!("{}", fig4_table(&meas, Some(&pred)));
        // A3: model-vs-simulator agreement.
        let mut winners_agree = 0;
        for (m, p) in meas.iter().zip(&pred) {
            if (m.ob < m.dd) == (p.ob < p.dd) {
                winners_agree += 1;
            }
        }
        println!(
            "A3 cross-validation: OB/DD winner agreement {}/{} cells",
            winners_agree,
            meas.len()
        );
    } else {
        println!("{}", fig4_table(&pred, None));
    }
    Ok(())
}

fn cmd_recover(args: &Args) -> Result<()> {
    let RunSetup {
        plat,
        repl,
        faults,
        sharding,
        batching,
        coalescing,
        concurrency,
        adaptive,
        link,
    } = setup_from(args)?;
    let strategy: StrategyKind = args.get("strategy").unwrap_or("sm-ob").parse()?;
    let txns = args.get_u64("txns", 10)?;
    use crate::coordinator::ThreadCtx;
    use crate::txn::Txn;

    let injecting = !faults.plan.is_empty();
    let primary_faults = faults.plan.has_primary_faults();
    let on_loss = faults.on_loss;
    let domain = plat.persist_domain;
    if link.enabled() {
        println!(
            "lossy link: plan {} (ack timeout {} ns, retry {}, rnr depth {}, \
             seed {})",
            link.plan, link.transport_timeout_ns, link.retry_count, link.rnr_depth,
            link.seed
        );
    }
    let mut m = MirrorBuilder::new(plat, strategy)
        .replication(repl)
        .faults(faults)
        .sharding(sharding)
        .batching(batching.policy)
        .coalescing(coalescing.mode)
        .concurrency(concurrency)
        .adaptive(adaptive)
        .link(link)
        .ledger(true)
        .build()?;
    let mut t = ThreadCtx::new(0);
    let log = crate::pstore::log_base_for(0);
    let d0 = 0x20_0000u64;
    let d1 = 0x20_0040u64;
    let mut hist = recovery::TxnHistory::new(Default::default());
    for i in 0..txns {
        let mut tx = Txn::begin(&mut m, &mut t, log, None);
        tx.write(&mut m, &mut t, d0, 100 + i);
        tx.write(&mut m, &mut t, d1, 200 + i);
        tx.commit(&mut m, &mut t);
        if m.stall().is_some() {
            break;
        }
        let mut snap = std::collections::HashMap::new();
        snap.insert(d0, 100 + i);
        snap.insert(d1, 200 + i);
        hist.commit(snap, t.last_dfence);
    }
    m.settle(t.now());
    if let Some(stall) = m.stall() {
        println!(
            "recovery check [{strategy}, {} backup(s), ack {}]: run stopped \
             after {} of {txns} txns — {stall}",
            repl.backups,
            repl.ack_policy,
            hist.committed(),
        );
        if sharding.shards > 1 {
            print!("{}", ShardedReport::from_mirror(&m).render());
        } else {
            print!("{}", GroupReport::from_fabric(m.fabric()).render());
        }
        return Ok(());
    }
    let shard_ledgers = m.shard_ledgers();
    for ledgers in &shard_ledgers {
        recovery::check_group_epoch_ordering(ledgers)?;
    }
    // One builder covers all three shapes (plain / fault-aware /
    // sharded); the persist domain annotates any verdict failure.
    let timelines = m.timelines();
    let timeline = m.fabric().timeline();
    let log_bases = [log];
    let data_addrs = [d0, d1];
    let check = recovery::CrashCheck::new(&hist, &log_bases, &data_addrs)
        .required(repl.required())
        .on_loss(on_loss)
        .persist_domain(domain);
    let checked = if sharding.shards > 1 {
        // Per-shard group checks merged into the cross-shard verdict
        // (fault-aware by construction: the realized timelines feed in).
        check.shards(&shard_ledgers, &timelines, m.shard_map()).sweep()?
    } else if injecting {
        check.ledgers(&shard_ledgers[0]).faults(&timeline).sweep()?
    } else {
        check.ledgers(&shard_ledgers[0]).sweep()?
    };
    if primary_faults {
        // Leader completeness: each elected primary's certified state —
        // merged across shards, which fail over as one node — covers
        // every transaction durably acked by the failover instant.
        let epochs = recovery::check_sharded_leader_completeness(
            &shard_ledgers,
            &m.timelines(),
            &hist,
            &[log],
            &[d0, d1],
        )?;
        println!(
            "leader completeness: {epochs} membership epoch(s) verified \
             (downtime {:.3} ms, {} line(s) re-replicated, {} staged WQE(s) \
             revoked)",
            m.failover_downtime_ns() as f64 / 1e6,
            m.rereplicated_lines(),
            m.revoked_wqes()
        );
    }
    let events: Vec<Vec<usize>> = shard_ledgers
        .iter()
        .map(|ls| ls.iter().map(|l| l.len()).collect())
        .collect();
    println!(
        "recovery check [{strategy}, {} shard(s), {} backup(s), ack {}{}]: \
         {txns} txns, ledger events per shard x backup {events:?}, {checked} \
         crash points verified — failure atomicity + {}group durability hold \
         (tolerates {} backup failure(s) per shard)",
        sharding.shards,
        repl.backups,
        repl.ack_policy,
        if injecting { ", fault-injected" } else { "" },
        if sharding.shards > 1 { "cross-shard " } else { "" },
        repl.required() - 1
    );
    if sharding.shards > 1 {
        print!("{}", ShardedReport::from_mirror(&m).render());
    } else if repl.backups > 1 || injecting {
        print!("{}", GroupReport::from_fabric(m.fabric()).render());
    }
    Ok(())
}

fn cmd_config(args: &Args) -> Result<()> {
    let plat = platform_from(args)?;
    println!("{}", plat.table2());
    println!("\nAOT model parameter vector: {:?}", plat.to_param_vec());
    Ok(())
}

fn cmd_selftest(args: &Args) -> Result<()> {
    println!("{}", crate::net::verbs::table1());
    // Quick end-to-end invariant smoke: every strategy, small Transact.
    let plat = platform_from(args)?;
    for kind in StrategyKind::SM {
        let cfg = TransactConfig {
            epochs: 8,
            writes: 2,
            txns: 50,
            ..Default::default()
        };
        let base = run_transact(&plat, StrategyKind::NoSm, cfg).makespan;
        let sm = run_transact(&plat, kind, cfg).makespan;
        anyhow::ensure!(sm > base, "{kind}: SM must cost more than NO-SM");
        println!(
            "selftest {kind}: slowdown {:.1}x — ok",
            sm as f64 / base as f64
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn args_parse_mixed() {
        let argv: Vec<String> = ["run", "--strategy", "sm-ob", "--crossover", "--txns", "5"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let a = Args::parse(&argv);
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.get("strategy"), Some("sm-ob"));
        assert!(a.flag("crossover"));
        assert_eq!(a.get_u64("txns", 0).unwrap(), 5);
        assert_eq!(a.get_u64("missing", 9).unwrap(), 9);
    }

    #[test]
    fn unknown_command_errors() {
        let argv = vec!["bogus".to_string()];
        assert!(main_with_args(&argv).is_err());
    }

    #[test]
    fn selftest_runs() {
        main_with_args(&["selftest".to_string()]).unwrap();
    }

    #[test]
    fn recover_command_runs_for_all_strategies() {
        for s in ["sm-rc", "sm-ob", "sm-dd"] {
            main_with_args(&[
                "recover".to_string(),
                "--strategy".to_string(),
                s.to_string(),
                "--txns".to_string(),
                "3".to_string(),
            ])
            .unwrap_or_else(|e| panic!("{s}: {e}"));
        }
    }

    #[test]
    fn recover_command_runs_for_replica_groups() {
        for policy in ["all", "quorum:2", "majority"] {
            main_with_args(&[
                "recover".to_string(),
                "--strategy".to_string(),
                "sm-ob".to_string(),
                "--txns".to_string(),
                "3".to_string(),
                "--backups".to_string(),
                "3".to_string(),
                "--ack-policy".to_string(),
                policy.to_string(),
            ])
            .unwrap_or_else(|e| panic!("{policy}: {e}"));
        }
    }

    #[test]
    fn run_command_rejects_invalid_group() {
        let argv: Vec<String> = [
            "run", "--strategy", "sm-ob", "--txns", "5", "--backups", "2",
            "--ack-policy", "quorum:9",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert!(main_with_args(&argv).is_err());
    }

    #[test]
    fn run_command_replica_group_smoke() {
        let argv: Vec<String> = [
            "run", "--strategy", "sm-dd", "--txns", "20", "--backups", "3",
            "--ack-policy", "quorum:2",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        main_with_args(&argv).unwrap();
    }

    #[test]
    fn run_command_fault_plan_smoke() {
        // Degraded run with a mid-run kill + rejoin completes.
        let argv: Vec<String> = [
            "run", "--strategy", "sm-ob", "--txns", "50", "--backups", "3",
            "--ack-policy", "quorum:2", "--fault-plan",
            "kill:1@40000,rejoin:1@120000", "--on-loss", "degrade",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        main_with_args(&argv).unwrap();
    }

    #[test]
    fn run_command_rejects_bad_fault_plan() {
        // Plan names a backup outside the group.
        let argv: Vec<String> = [
            "run", "--strategy", "sm-ob", "--txns", "5", "--backups", "2",
            "--fault-plan", "kill:7@100",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert!(main_with_args(&argv).is_err());
        // Malformed spec string.
        let argv: Vec<String> =
            ["run", "--fault-plan", "explode:0@1"].iter().map(|s| s.to_string()).collect();
        assert!(main_with_args(&argv).is_err());
        // Unknown loss mode.
        let argv: Vec<String> = [
            "run", "--backups", "2", "--fault-plan", "kill:0@1", "--on-loss", "retry",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert!(main_with_args(&argv).is_err());
    }

    fn argv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn cli_shards_override_config_file() {
        use crate::coordinator::ShardMapSpec;
        // `--shards` beats the [sharding] table; the map survives from
        // the file when not overridden.
        let dir = std::env::temp_dir().join("pmsm_cli_sharding_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("exp.toml");
        std::fs::write(
            &path,
            "[sharding]\nshards = 2\nmap = \"range:1024\"\n",
        )
        .unwrap();
        let path = path.to_str().unwrap();
        let a = Args::parse(&argv(&["run", "--config", path, "--shards", "4"]));
        let sharding = setup_from(&a).unwrap().sharding;
        assert_eq!(sharding.shards, 4, "--shards overrides the TOML");
        assert_eq!(
            sharding.map,
            ShardMapSpec::Range { stripe_lines: 1024 },
            "map keeps the TOML value"
        );
        // No override: the file's shape wins entirely.
        let a = Args::parse(&argv(&["run", "--config", path]));
        let sharding = setup_from(&a).unwrap().sharding;
        assert_eq!(sharding.shards, 2);
        // `--shard-map` overrides the file's map.
        let a = Args::parse(&argv(&["run", "--config", path, "--shard-map", "modulo"]));
        let sharding = setup_from(&a).unwrap().sharding;
        assert_eq!(sharding.map, ShardMapSpec::Modulo);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn cli_rejects_invalid_shard_shapes() {
        // shards = 0 carries the clear validation error.
        let a = Args::parse(&argv(&["run", "--shards", "0"]));
        let err = setup_from(&a).unwrap_err();
        assert!(
            format!("{err:#}").contains("sharding.shards must be >= 1"),
            "{err:#}"
        );
        assert!(setup_from(&Args::parse(&argv(&["run", "--shards", "-1"]))).is_err());
        assert!(
            setup_from(&Args::parse(&argv(&["run", "--shard-map", "hash"]))).is_err()
        );
    }

    #[test]
    fn run_command_sharded_smoke() {
        main_with_args(&argv(&[
            "run", "--strategy", "sm-ob", "--txns", "20", "--shards", "4",
            "--backups", "2", "--ack-policy", "all",
        ]))
        .unwrap();
    }

    #[test]
    fn recover_command_sharded_check() {
        // The acceptance shape: shards=4, backups=2 commits and recovers.
        main_with_args(&argv(&[
            "recover", "--strategy", "sm-ob", "--txns", "4", "--shards", "4",
            "--backups", "2", "--ack-policy", "all",
        ]))
        .unwrap();
        // Contiguous-range map too.
        main_with_args(&argv(&[
            "recover", "--strategy", "sm-dd", "--txns", "3", "--shards", "2",
            "--shard-map", "range:1",
        ]))
        .unwrap();
    }

    #[test]
    fn run_command_batching_smoke() {
        // Fence-policy batching across a replica group completes.
        main_with_args(&argv(&[
            "run", "--strategy", "sm-ob", "--txns", "20", "--backups", "2",
            "--flush-policy", "fence",
        ]))
        .unwrap();
        // --batch-cap shorthand on the shared-QP strategy.
        main_with_args(&argv(&[
            "run", "--strategy", "sm-dd", "--txns", "10", "--batch-cap", "4",
        ]))
        .unwrap();
    }

    #[test]
    fn cli_rejects_invalid_batching() {
        assert!(setup_from(&Args::parse(&argv(&["run", "--batch-cap", "0"]))).is_err());
        assert!(setup_from(&Args::parse(&argv(&["run", "--flush-policy", "lazy"]))).is_err());
        // --batch-cap is the more specific knob: it wins over
        // --flush-policy, mirroring the TOML precedence.
        let a = Args::parse(&argv(&["run", "--flush-policy", "fence", "--batch-cap", "8"]));
        let batching = setup_from(&a).unwrap().batching;
        assert_eq!(batching.policy, FlushPolicy::Cap(8));
    }

    #[test]
    fn run_command_coalescing_smoke() {
        // Full coalescing over a staged pipeline completes for every
        // strategy shape the coalescer touches.
        main_with_args(&argv(&[
            "run", "--strategy", "sm-ob", "--txns", "20", "--backups", "2",
            "--flush-policy", "fence", "--coalesce", "full",
        ]))
        .unwrap();
        main_with_args(&argv(&[
            "run", "--strategy", "sm-dd", "--txns", "10", "--batch-cap", "4",
            "--coalesce", "sg",
        ]))
        .unwrap();
    }

    #[test]
    fn cli_rejects_invalid_coalescing() {
        // Unknown mode.
        assert!(setup_from(&Args::parse(&argv(&[
            "run", "--flush-policy", "fence", "--coalesce", "both"
        ])))
        .is_err());
        // Coalescing without a staged flush policy (default = eager).
        let err = setup_from(&Args::parse(&argv(&["run", "--coalesce", "sg"]))).unwrap_err();
        assert!(
            format!("{err:#}").contains("requires a staged flush policy"),
            "{err:#}"
        );
        // A valid pairing parses to the requested mode.
        let a = Args::parse(&argv(&["run", "--flush-policy", "fence", "--coalesce", "combine"]));
        let coalescing = setup_from(&a).unwrap().coalescing;
        assert_eq!(coalescing.mode, CoalesceMode::Combine);
    }

    #[test]
    fn recover_command_coalesced_check() {
        // The recovery invariants must hold under full coalescing too:
        // combining keeps the last writer per epoch, sg only merges
        // transport — the ledger recovery sees is equivalent.
        for mode in ["combine", "sg", "full"] {
            main_with_args(&argv(&[
                "recover", "--strategy", "sm-ob", "--txns", "4", "--backups", "2",
                "--flush-policy", "fence", "--coalesce", mode,
            ]))
            .unwrap_or_else(|e| panic!("{mode}: {e}"));
        }
        // Sharded + coalesced.
        main_with_args(&argv(&[
            "recover", "--strategy", "sm-dd", "--txns", "3", "--shards", "2",
            "--shard-map", "range:1", "--flush-policy", "fence", "--coalesce", "full",
        ]))
        .unwrap();
    }

    #[test]
    fn cli_concurrency_flags_roundtrip() {
        // Flags land in the RunSetup bundle.
        let a = Args::parse(&argv(&[
            "run", "--commit-pipelines", "4", "--group-fence-ns", "2600",
        ]));
        let conc = setup_from(&a).unwrap().concurrency;
        assert_eq!(conc.commit_pipelines, 4);
        assert_eq!(conc.group_fence_ns, 2600);
        assert!(conc.enabled());
        // Defaults are the serial primary: disabled.
        let conc = setup_from(&Args::parse(&argv(&["run"]))).unwrap().concurrency;
        assert_eq!(conc, ConcurrencyConfig::default());
        assert!(!conc.enabled());
        // CLI overrides the [concurrency] config table.
        let dir = std::env::temp_dir().join("pmsm_cli_concurrency_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("exp.toml");
        std::fs::write(
            &path,
            "[concurrency]\ncommit_pipelines = 2\ngroup_fence_ns = 500\n",
        )
        .unwrap();
        let path = path.to_str().unwrap();
        let a = Args::parse(&argv(&["run", "--config", path, "--commit-pipelines", "8"]));
        let conc = setup_from(&a).unwrap().concurrency;
        assert_eq!(conc.commit_pipelines, 8, "--commit-pipelines overrides the TOML");
        assert_eq!(conc.group_fence_ns, 500, "window keeps the TOML value");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn cli_rejects_invalid_concurrency() {
        let err = setup_from(&Args::parse(&argv(&["run", "--commit-pipelines", "0"])))
            .unwrap_err();
        assert!(
            format!("{err:#}").contains("commit_pipelines must be >= 1"),
            "{err:#}"
        );
        assert!(
            setup_from(&Args::parse(&argv(&["run", "--commit-pipelines", "-2"]))).is_err()
        );
        assert!(
            setup_from(&Args::parse(&argv(&["run", "--group-fence-ns", "-1"]))).is_err()
        );
    }

    #[test]
    fn run_command_concurrency_smoke() {
        // Pipelined + group-fenced commit completes across threads,
        // backups and shards.
        main_with_args(&argv(&[
            "run", "--strategy", "sm-ob", "--txns", "40", "--threads", "4",
            "--commit-pipelines", "2", "--group-fence-ns", "2600", "--backups", "2",
        ]))
        .unwrap();
        main_with_args(&argv(&[
            "run", "--strategy", "sm-ob", "--txns", "20", "--threads", "2",
            "--shards", "2", "--commit-pipelines", "2",
        ]))
        .unwrap();
        // recover path applies the knobs too.
        main_with_args(&argv(&[
            "recover", "--strategy", "sm-ob", "--txns", "4", "--backups", "2",
            "--group-fence-ns", "2600",
        ]))
        .unwrap();
    }

    #[test]
    fn cli_election_flags_roundtrip() {
        let a = Args::parse(&argv(&[
            "run", "--election-handoff-ns", "12000", "--election-line-ns", "40",
        ]));
        let f = setup_from(&a).unwrap().faults;
        assert_eq!(f.election.handoff_ns, 12_000);
        assert_eq!(f.election.line_ns, 40);
        // CLI overrides the [election] config table; the other knob keeps
        // the file's value.
        let dir = std::env::temp_dir().join("pmsm_cli_election_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("exp.toml");
        std::fs::write(&path, "[election]\nhandoff_ns = 9000\nline_ns = 70\n").unwrap();
        let path = path.to_str().unwrap();
        let a = Args::parse(&argv(&[
            "run", "--config", path, "--election-handoff-ns", "4000",
        ]));
        let f = setup_from(&a).unwrap().faults;
        assert_eq!(f.election.handoff_ns, 4000, "flag overrides the TOML");
        assert_eq!(f.election.line_ns, 70, "line cost keeps the TOML value");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn cli_rejects_degenerate_duration_knobs() {
        // Negative and u64-overflowing --group-fence-ns fail with the
        // flag and constraint named (not a bare parse error).
        for bad in ["-1", "99999999999999999999999"] {
            let err = setup_from(&Args::parse(&argv(&[
                "run", "--group-fence-ns", bad,
            ])))
            .unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains("--group-fence-ns"), "{msg}");
            assert!(msg.contains("must be a window in ns"), "{msg}");
        }
        // The election knobs reject the same degenerate shapes.
        let err = setup_from(&Args::parse(&argv(&[
            "run", "--election-handoff-ns", "-5",
        ])))
        .unwrap_err();
        assert!(
            format!("{err:#}").contains("--election-handoff-ns"),
            "{err:#}"
        );
        assert!(setup_from(&Args::parse(&argv(&[
            "run", "--election-line-ns", "99999999999999999999999",
        ])))
        .is_err());
    }

    #[test]
    fn run_command_primary_failover_smoke() {
        main_with_args(&argv(&[
            "run", "--strategy", "sm-ob", "--txns", "80", "--backups", "3",
            "--ack-policy", "majority", "--fault-plan", "kill:p@40000",
        ]))
        .unwrap();
        // Sharded: all shards fail over as one node.
        main_with_args(&argv(&[
            "run", "--strategy", "sm-ob", "--txns", "40", "--shards", "2",
            "--backups", "3", "--ack-policy", "quorum:2", "--fault-plan",
            "kill:p@40000",
        ]))
        .unwrap();
    }

    #[test]
    fn recover_command_primary_failover_check() {
        // Failover mid-run: crash sweep + leader completeness both pass.
        main_with_args(&argv(&[
            "recover", "--strategy", "sm-ob", "--txns", "6", "--backups", "3",
            "--ack-policy", "quorum:2", "--fault-plan", "kill:p@20000",
        ]))
        .unwrap();
        // Deposed primary rejoining as a backup passes too.
        main_with_args(&argv(&[
            "recover", "--strategy", "sm-ob", "--txns", "8", "--backups", "3",
            "--ack-policy", "majority", "--fault-plan",
            "kill:p@20000,rejoin:p@60000",
        ]))
        .unwrap();
    }

    #[test]
    fn cli_persist_domain_flag_roundtrip() {
        let a = Args::parse(&argv(&["run", "--persist-domain", "eadr"]));
        assert_eq!(setup_from(&a).unwrap().plat.persist_domain, PersistDomain::Eadr);
        // Default stays the paper's anchor.
        assert_eq!(
            setup_from(&Args::parse(&argv(&["run"]))).unwrap().plat.persist_domain,
            PersistDomain::Adr
        );
        // CLI overrides the [remote] config table.
        let dir = std::env::temp_dir().join("pmsm_cli_persist_domain_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("exp.toml");
        std::fs::write(&path, "[remote]\npersist_domain = \"rpmem-flush\"\n").unwrap();
        let path = path.to_str().unwrap();
        let a = Args::parse(&argv(&["run", "--config", path]));
        assert_eq!(
            setup_from(&a).unwrap().plat.persist_domain,
            PersistDomain::RpmemFlush
        );
        let a = Args::parse(&argv(&[
            "run", "--config", path, "--persist-domain", "log-structured",
        ]));
        assert_eq!(
            setup_from(&a).unwrap().plat.persist_domain,
            PersistDomain::LogStructured,
            "--persist-domain overrides the TOML"
        );
        std::fs::remove_file(path).ok();
        // Unknown domain fails naming the flag.
        let err = setup_from(&Args::parse(&argv(&["run", "--persist-domain", "nvdimm"])))
            .unwrap_err();
        assert!(format!("{err:#}").contains("--persist-domain"), "{err:#}");
    }

    #[test]
    fn run_command_persist_domain_smoke() {
        // Every non-anchor domain completes under the drain-heavy
        // strategy (SM-RC exercises rcommit collapse and flush verbs).
        for d in ["eadr", "rpmem-flush", "log-structured"] {
            main_with_args(&argv(&[
                "run", "--strategy", "sm-rc", "--txns", "20", "--backups", "2",
                "--persist-domain", d,
            ]))
            .unwrap_or_else(|e| panic!("{d}: {e}"));
        }
    }

    #[test]
    fn recover_command_persist_domain_check() {
        // The crash sweep holds under every domain: fences force the
        // domain's persistence verb, so acked == durable throughout.
        for d in ["adr", "eadr", "rpmem-flush", "log-structured"] {
            main_with_args(&argv(&[
                "recover", "--strategy", "sm-ob", "--txns", "4", "--backups", "2",
                "--persist-domain", d,
            ]))
            .unwrap_or_else(|e| panic!("{d}: {e}"));
        }
        // Sharded and fault-injected shapes hold off-anchor too.
        main_with_args(&argv(&[
            "recover", "--strategy", "sm-dd", "--txns", "3", "--shards", "2",
            "--persist-domain", "eadr",
        ]))
        .unwrap();
        main_with_args(&argv(&[
            "recover", "--strategy", "sm-ob", "--txns", "4", "--backups", "3",
            "--ack-policy", "quorum:2", "--fault-plan", "kill:2@20000",
            "--persist-domain", "rpmem-flush",
        ]))
        .unwrap();
    }

    #[test]
    fn cli_adaptive_flags_roundtrip() {
        // Off by default.
        let a = setup_from(&Args::parse(&argv(&["run"]))).unwrap().adaptive;
        assert_eq!(a, AdaptiveConfig::default());
        assert!(!a.enabled);
        // Bare --adaptive enables with all axes on.
        let a = setup_from(&Args::parse(&argv(&["run", "--adaptive"]))).unwrap().adaptive;
        assert!(a.enabled && a.quorum && a.batch && a.feedback);
        // A per-axis off survives; asking for an axis implies enabled.
        let a = setup_from(&Args::parse(&argv(&[
            "run", "--adaptive", "--adaptive-quorum", "off",
        ])))
        .unwrap()
        .adaptive;
        assert!(a.enabled && !a.quorum && a.batch);
        let a = setup_from(&Args::parse(&argv(&["run", "--adaptive-feedback", "on"])))
            .unwrap()
            .adaptive;
        assert!(a.enabled && a.feedback);
        // Junk values fail naming the flag.
        let err = setup_from(&Args::parse(&argv(&["run", "--adaptive", "maybe"])))
            .unwrap_err();
        assert!(format!("{err:#}").contains("--adaptive maybe"), "{err:#}");
        // CLI overrides the [adaptive] config table; tuning knobs keep
        // the file's values.
        let dir = std::env::temp_dir().join("pmsm_cli_adaptive_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("exp.toml");
        std::fs::write(
            &path,
            "[adaptive]\nenabled = true\newma_pct = 35\nhysteresis_pct = 5\n",
        )
        .unwrap();
        let path = path.to_str().unwrap();
        let a = setup_from(&Args::parse(&argv(&["run", "--config", path])))
            .unwrap()
            .adaptive;
        assert!(a.enabled);
        assert_eq!(a.ewma_pct, 35);
        let a = setup_from(&Args::parse(&argv(&["run", "--config", path, "--adaptive", "off"])))
            .unwrap()
            .adaptive;
        assert!(!a.enabled, "--adaptive off overrides the TOML");
        assert_eq!(a.hysteresis_pct, 5, "tuning keeps the TOML value");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn run_command_adaptive_smoke() {
        // The full control plane (quorum + batch + feedback) completes
        // end-to-end over a replica group with quorum headroom.
        main_with_args(&argv(&[
            "run", "--strategy", "sm-ad", "--txns", "40", "--backups", "2",
            "--ack-policy", "quorum:1", "--adaptive",
        ]))
        .unwrap();
        // Disabled default: sm-ad still runs the static path.
        main_with_args(&argv(&[
            "run", "--strategy", "sm-ad", "--txns", "20", "--backups", "2",
        ]))
        .unwrap();
    }

    #[test]
    fn recover_command_batched_check() {
        // The recovery invariants must hold under doorbell batching too
        // (ledger equivalence makes this the eager check, shifted).
        main_with_args(&argv(&[
            "recover", "--strategy", "sm-ob", "--txns", "4", "--backups", "2",
            "--flush-policy", "fence",
        ]))
        .unwrap();
    }

    #[test]
    fn recover_command_fault_aware_check() {
        // Tolerated loss: quorum:2 of 3 with one backup killed mid-run
        // still verifies (fault-aware sweep).
        main_with_args(&[
            "recover".to_string(),
            "--strategy".to_string(),
            "sm-ob".to_string(),
            "--txns".to_string(),
            "4".to_string(),
            "--backups".to_string(),
            "3".to_string(),
            "--ack-policy".to_string(),
            "quorum:2".to_string(),
            "--fault-plan".to_string(),
            "kill:2@20000".to_string(),
        ])
        .unwrap();
        // Intolerable loss under halt: the run stalls but the command
        // still reports cleanly (no error).
        main_with_args(&[
            "recover".to_string(),
            "--txns".to_string(),
            "4".to_string(),
            "--backups".to_string(),
            "3".to_string(),
            "--ack-policy".to_string(),
            "all".to_string(),
            "--fault-plan".to_string(),
            "kill:2@20000".to_string(),
            "--on-loss".to_string(),
            "halt".to_string(),
        ])
        .unwrap();
    }

    #[test]
    fn cli_link_flags_roundtrip() {
        // Disabled by default: the reliable-wire anchor.
        let l = setup_from(&Args::parse(&argv(&["run"]))).unwrap().link;
        assert_eq!(l, LinkConfig::default());
        assert!(!l.enabled());
        // All five flags land in the config.
        let l = setup_from(&Args::parse(&argv(&[
            "run", "--backups", "2", "--link-plan", "drop:1@40000,loss:0:1%",
            "--transport-timeout-ns", "6000", "--retry-count", "5",
            "--rnr-depth", "32", "--link-seed", "7",
        ])))
        .unwrap()
        .link;
        assert!(l.enabled());
        assert_eq!(l.plan.to_string(), "drop:1@40000,loss:0:1%");
        assert_eq!(l.transport_timeout_ns, 6_000);
        assert_eq!(l.retry_count, 5);
        assert_eq!(l.rnr_depth, 32);
        assert_eq!(l.seed, 7);
        // CLI overrides the [link] config table; the other knobs keep
        // the file's values.
        let dir = std::env::temp_dir().join("pmsm_cli_link_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("exp.toml");
        std::fs::write(
            &path,
            "[link]\nplan = \"drop:0@10000\"\ntransport_timeout_ns = 5000\n\
             retry_count = 4\n",
        )
        .unwrap();
        let path = path.to_str().unwrap();
        let l = setup_from(&Args::parse(&argv(&[
            "run", "--config", path, "--retry-count", "9",
        ])))
        .unwrap()
        .link;
        assert_eq!(l.retry_count, 9, "flag overrides the TOML");
        assert_eq!(l.transport_timeout_ns, 5_000, "timeout keeps the TOML value");
        assert_eq!(l.plan.to_string(), "drop:0@10000", "plan keeps the TOML value");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn cli_rejects_bad_link_shapes() {
        // Plan names a backup outside the group.
        let err = setup_from(&Args::parse(&argv(&[
            "run", "--backups", "2", "--link-plan", "drop:5@100",
        ])))
        .unwrap_err();
        assert!(format!("{err:#}").contains("backup 5"), "{err:#}");
        // Malformed token names the flag.
        let err = setup_from(&Args::parse(&argv(&[
            "run", "--link-plan", "snip:0@100",
        ])))
        .unwrap_err();
        assert!(format!("{err:#}").contains("--link-plan"), "{err:#}");
        // Out-of-range probability and degenerate knobs.
        assert!(setup_from(&Args::parse(&argv(&[
            "run", "--backups", "2", "--link-plan", "loss:0:150%",
        ])))
        .is_err());
        assert!(setup_from(&Args::parse(&argv(&[
            "run", "--retry-count", "-1",
        ])))
        .is_err());
        assert!(setup_from(&Args::parse(&argv(&[
            "run", "--backups", "2", "--link-plan", "drop:0@100",
            "--transport-timeout-ns", "0",
        ])))
        .is_err());
    }

    #[test]
    fn run_command_lossy_link_smoke() {
        // One-shot drops + run-long loss complete under degrade.
        main_with_args(&argv(&[
            "run", "--strategy", "sm-ob", "--txns", "40", "--backups", "2",
            "--ack-policy", "quorum:1", "--on-loss", "degrade",
            "--link-plan", "drop:1@40000,loss:0:0.5%",
        ]))
        .unwrap();
        // Sharded + RNR-bounded receiver.
        main_with_args(&argv(&[
            "run", "--strategy", "sm-dd", "--txns", "20", "--shards", "2",
            "--link-plan", "delay:0@30000:20000", "--rnr-depth", "64",
        ]))
        .unwrap();
    }

    #[test]
    fn recover_command_lossy_link_check() {
        // The crash sweep holds under wire loss: retransmits and dedup
        // never weaken durability verdicts.
        main_with_args(&argv(&[
            "recover", "--strategy", "sm-ob", "--txns", "6", "--backups", "2",
            "--ack-policy", "quorum:1", "--on-loss", "degrade",
            "--link-plan", "drop:1@20000,dup:0@30000",
        ]))
        .unwrap();
    }
}
