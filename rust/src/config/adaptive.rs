//! `[adaptive]` section: the online adaptive mirroring control plane.
//!
//! When enabled, SM-AD grows from a static two-way OB/DD switch into a
//! per-transaction-class controller that picks a full knob vector —
//! replication mode, ack quorum, doorbell batch cap — from the extended
//! analytic cost model ([`crate::runtime::fallback_knob_predictor`]),
//! corrected online by per-class EWMAs of *measured* commit latency.
//! Disabled (the default) is the regression anchor: SM-AD runs the
//! original static predictor path event-for-event.

use anyhow::{bail, Result};

/// Online adaptive control-plane knobs (`[adaptive]` TOML section).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AdaptiveConfig {
    /// Master switch. `false` (default) keeps SM-AD on the static
    /// two-input predictor path — the event-for-event anchor.
    pub enabled: bool,
    /// Tune the per-transaction ack quorum within
    /// `[configured policy, all]`. The configured policy is a floor:
    /// the controller can only *raise* the acks a commit waits for,
    /// never weaken the user's durability contract.
    pub quorum: bool,
    /// Tune the per-transaction doorbell batch cap (overrides the
    /// `[batching]` flush policy for the transaction's duration).
    pub batch: bool,
    /// Online feedback: per-(class, knob-cell) EWMAs of measured
    /// commit latency replace the model's prediction for cells with
    /// data, and a per-class scale correction transfers the observed
    /// model error to unmeasured cells.
    pub feedback: bool,
    /// EWMA weight of a new measurement, percent (1..=100).
    pub ewma_pct: u32,
    /// Hysteresis guard band, percent (0..=100): the controller leaves
    /// a class's current knob vector only when the best candidate's
    /// corrected score improves on it by more than this margin, so
    /// borderline classes don't thrash between near-tied cells.
    pub hysteresis_pct: u32,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig {
            enabled: false,
            quorum: true,
            batch: true,
            feedback: true,
            ewma_pct: 20,
            hysteresis_pct: 10,
        }
    }
}

impl AdaptiveConfig {
    /// An enabled config with the default tuning knobs.
    pub fn enabled() -> Self {
        AdaptiveConfig {
            enabled: true,
            ..Default::default()
        }
    }

    /// EWMA weight as a fraction.
    pub fn alpha(&self) -> f32 {
        self.ewma_pct as f32 / 100.0
    }

    /// Hysteresis guard band as a fraction.
    pub fn guard(&self) -> f32 {
        self.hysteresis_pct as f32 / 100.0
    }

    pub fn validate(&self) -> Result<()> {
        if self.ewma_pct < 1 || self.ewma_pct > 100 {
            bail!(
                "adaptive.ewma_pct must be in 1..=100, got {}",
                self.ewma_pct
            );
        }
        if self.hysteresis_pct > 100 {
            bail!(
                "adaptive.hysteresis_pct must be in 0..=100, got {}",
                self.hysteresis_pct
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_disabled_anchor() {
        let cfg = AdaptiveConfig::default();
        assert!(!cfg.enabled);
        cfg.validate().unwrap();
    }

    #[test]
    fn enabled_turns_all_knobs_on() {
        let cfg = AdaptiveConfig::enabled();
        assert!(cfg.enabled && cfg.quorum && cfg.batch && cfg.feedback);
        cfg.validate().unwrap();
    }

    #[test]
    fn fractions() {
        let cfg = AdaptiveConfig::default();
        assert!((cfg.alpha() - 0.20).abs() < 1e-6);
        assert!((cfg.guard() - 0.10).abs() < 1e-6);
    }

    #[test]
    fn validate_rejects_bad_percentages() {
        let mut cfg = AdaptiveConfig::default();
        cfg.ewma_pct = 0;
        assert!(cfg.validate().is_err());
        cfg.ewma_pct = 101;
        assert!(cfg.validate().is_err());
        cfg.ewma_pct = 100;
        cfg.hysteresis_pct = 101;
        assert!(cfg.validate().is_err());
        cfg.hysteresis_pct = 0;
        cfg.validate().unwrap();
    }
}
