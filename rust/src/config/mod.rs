//! Configuration system: typed configs + a TOML-subset parser.
//!
//! No `serde`/`toml` crates exist in the offline registry, so parsing is
//! implemented in-repo (`toml.rs` — sections, scalars, arrays; enough for
//! platform/workload files). Defaults mirror the paper's §6.1 model
//! parameters and Table 2 platform, and are kept in lock-step with
//! `python/compile/kernels/params.py` (the AOT model's parameter vector).

pub mod adaptive;
pub mod platform;
pub mod toml;

pub use adaptive::AdaptiveConfig;
pub use platform::{AckPolicy, Platform, ReplicationConfig, StrategyKind};
pub use crate::net::PersistDomain;

use crate::coordinator::pipeline::ConcurrencyConfig;
use crate::coordinator::shard::ShardingConfig;
use crate::net::faults::FaultsConfig;
use crate::net::link::LinkConfig;
use crate::net::wqe::{BatchingConfig, CoalescingConfig, FlushPolicy};
use anyhow::{bail, Context, Result};

/// Workload selection for the CLI / experiment driver.
#[derive(Clone, Debug, PartialEq)]
pub enum WorkloadSpec {
    /// Transact microbenchmark: epochs/txn, writes/epoch, #transactions.
    Transact { epochs: u32, writes: u32, txns: u64 },
    /// A WHISPER application by name (ctree|echo|hashmap|ycsb|tpcc).
    Whisper { app: String, ops: u64, threads: usize },
}

/// Top-level experiment configuration.
#[derive(Clone, Debug)]
pub struct Experiment {
    pub platform: Platform,
    pub strategy: StrategyKind,
    pub workload: WorkloadSpec,
    /// Replica-group shape (`[replication]` section; defaults to the
    /// paper's single fully-synchronous backup).
    pub replication: ReplicationConfig,
    /// Failure dynamics (`[faults]` section: a deterministic kill/rejoin
    /// plan — backups and, via `kill:p`/`rejoin:p`, the primary — plus
    /// the on-loss mode and resync cost knobs; defaults to no faults,
    /// `on_loss = halt`). The `[election]` section's failover knobs
    /// (`handoff_ns`, `line_ns`) land in `faults.election`.
    pub faults: FaultsConfig,
    /// Address-space sharding (`[sharding]` section: shard count +
    /// routing map; defaults to one shard — sharding off).
    pub sharding: ShardingConfig,
    /// Staged WQE pipeline (`[batching]` section: flush policy /
    /// batch cap; defaults to eager posting — batching off, the
    /// pre-batching cost model).
    pub batching: BatchingConfig,
    /// Flush-time chain coalescing (`[coalescing]` section: write
    /// combining / scatter-gather mode; defaults to `none` — the
    /// doorbell-batching pipeline untouched. Any other mode requires a
    /// staged flush policy in `[batching]`).
    pub coalescing: CoalescingConfig,
    /// Concurrent-primary shape (`[concurrency]` section: commit
    /// pipelines per shard + cross-thread group-fence window; defaults
    /// to one pipeline and no window — the serial commit path).
    pub concurrency: ConcurrencyConfig,
    /// Online adaptive control plane (`[adaptive]` section: per-class
    /// mode/quorum/batch tuning with measured-latency feedback;
    /// defaults to disabled — the static SM-AD predictor path).
    pub adaptive: AdaptiveConfig,
    /// Lossy-link fault injection (`[link]` section: per-backup
    /// drop/delay/dup plan + the RC retry knobs that mask it —
    /// `transport_timeout_ns`, `retry_count`, `rnr_depth`, `seed`;
    /// defaults to a perfectly reliable wire — link layer off).
    pub link: LinkConfig,
    pub seed: u64,
    /// Record the durability ledger (needed for recovery checks; off for
    /// large benches).
    pub ledger: bool,
}

impl Default for Experiment {
    fn default() -> Self {
        Experiment {
            platform: Platform::default(),
            strategy: StrategyKind::NoSm,
            workload: WorkloadSpec::Transact {
                epochs: 4,
                writes: 1,
                txns: 10_000,
            },
            replication: ReplicationConfig::default(),
            faults: FaultsConfig::default(),
            sharding: ShardingConfig::default(),
            batching: BatchingConfig::default(),
            coalescing: CoalescingConfig::default(),
            concurrency: ConcurrencyConfig::default(),
            adaptive: AdaptiveConfig::default(),
            link: LinkConfig::default(),
            seed: 42,
            ledger: false,
        }
    }
}

impl Experiment {
    /// Load an experiment from a TOML-subset file.
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        Self::from_str(&text)
    }

    /// Parse from TOML-subset text.
    pub fn from_str(text: &str) -> Result<Self> {
        let doc = toml::parse(text)?;
        let mut exp = Experiment::default();

        exp.platform = Platform::from_doc(&doc)?;
        if let Some(v) = doc.get("experiment.seed") {
            exp.seed = v.as_int()? as u64;
        }
        if let Some(v) = doc.get("experiment.ledger") {
            exp.ledger = v.as_bool()?;
        }
        if let Some(v) = doc.get("experiment.strategy") {
            exp.strategy = v.as_str()?.parse()?;
        }
        if let Some(v) = doc.get("replication.backups") {
            let b = v.as_int()?;
            if b < 0 {
                bail!("replication.backups must be >= 1, got {b}");
            }
            exp.replication.backups = b as usize;
        }
        if let Some(v) = doc.get("replication.ack_policy") {
            exp.replication.ack_policy = v.as_str()?.parse()?;
        }
        exp.replication
            .validate()
            .context("invalid [replication] section")?;
        if let Some(v) = doc.get("faults.plan") {
            exp.faults.plan = v.as_str()?.parse().context("faults.plan")?;
        }
        if let Some(v) = doc.get("faults.on_loss") {
            exp.faults.on_loss = v.as_str()?.parse()?;
        }
        if let Some(v) = doc.get("faults.handoff_ns") {
            let n = v.as_int()?;
            if n < 0 {
                bail!("faults.handoff_ns must be >= 0, got {n}");
            }
            exp.faults.handoff_ns = n as u64;
        }
        if let Some(v) = doc.get("faults.resync_line_ns") {
            let n = v.as_int()?;
            if n < 0 {
                bail!("faults.resync_line_ns must be >= 0, got {n}");
            }
            exp.faults.resync_line_ns = n as u64;
        }
        if let Some(v) = doc.get("election.handoff_ns") {
            let n = v.as_int()?;
            if n < 0 {
                bail!("election.handoff_ns must be >= 0, got {n}");
            }
            exp.faults.election.handoff_ns = n as u64;
        }
        if let Some(v) = doc.get("election.line_ns") {
            let n = v.as_int()?;
            if n < 0 {
                bail!("election.line_ns must be >= 0, got {n}");
            }
            exp.faults.election.line_ns = n as u64;
        }
        exp.faults
            .validate(exp.replication.backups)
            .context("invalid [faults] section")?;
        if let Some(v) = doc.get("sharding.shards") {
            let n = v.as_int()?;
            if n < 1 {
                bail!("sharding.shards must be >= 1, got {n}");
            }
            exp.sharding.shards = n as usize;
        }
        if let Some(v) = doc.get("sharding.map") {
            exp.sharding.map = v.as_str()?.parse().context("sharding.map")?;
        }
        exp.sharding
            .validate()
            .context("invalid [sharding] section")?;
        if let Some(v) = doc.get("batching.flush_policy") {
            exp.batching.policy = v.as_str()?.parse().context("batching.flush_policy")?;
        }
        if let Some(v) = doc.get("batching.batch_cap") {
            // Shorthand for flush_policy = "cap:K"; wins when both are
            // given (it is the more specific knob).
            let k = v.as_int()?;
            if k < 1 {
                bail!("batching.batch_cap must be >= 1, got {k}");
            }
            exp.batching.policy = FlushPolicy::Cap(k as usize);
        }
        exp.batching
            .validate()
            .context("invalid [batching] section")?;
        if let Some(v) = doc.get("coalescing.mode") {
            exp.coalescing.mode = v.as_str()?.parse().context("coalescing.mode")?;
        }
        exp.coalescing
            .validate_with(exp.batching.policy)
            .context("invalid [coalescing] section")?;
        if let Some(v) = doc.get("concurrency.commit_pipelines") {
            let n = v.as_int()?;
            if n < 1 {
                bail!("concurrency.commit_pipelines must be >= 1, got {n}");
            }
            exp.concurrency.commit_pipelines = n as usize;
        }
        if let Some(v) = doc.get("concurrency.group_fence_ns") {
            let n = v.as_int()?;
            if n < 0 {
                bail!("concurrency.group_fence_ns must be >= 0, got {n}");
            }
            exp.concurrency.group_fence_ns = n as u64;
        }
        exp.concurrency
            .validate()
            .context("invalid [concurrency] section")?;
        if let Some(v) = doc.get("adaptive.enabled") {
            exp.adaptive.enabled = v.as_bool()?;
        }
        if let Some(v) = doc.get("adaptive.quorum") {
            exp.adaptive.quorum = v.as_bool()?;
        }
        if let Some(v) = doc.get("adaptive.batch") {
            exp.adaptive.batch = v.as_bool()?;
        }
        if let Some(v) = doc.get("adaptive.feedback") {
            exp.adaptive.feedback = v.as_bool()?;
        }
        if let Some(v) = doc.get("adaptive.ewma_pct") {
            let n = v.as_int()?;
            if n < 1 || n > 100 {
                bail!("adaptive.ewma_pct must be in 1..=100, got {n}");
            }
            exp.adaptive.ewma_pct = n as u32;
        }
        if let Some(v) = doc.get("adaptive.hysteresis_pct") {
            let n = v.as_int()?;
            if n < 0 || n > 100 {
                bail!("adaptive.hysteresis_pct must be in 0..=100, got {n}");
            }
            exp.adaptive.hysteresis_pct = n as u32;
        }
        exp.adaptive
            .validate()
            .context("invalid [adaptive] section")?;
        if let Some(v) = doc.get("link.plan") {
            exp.link.plan = v.as_str()?.parse().context("link.plan")?;
        }
        if let Some(v) = doc.get("link.transport_timeout_ns") {
            let n = v.as_int()?;
            if n < 1 {
                bail!("link.transport_timeout_ns must be >= 1, got {n}");
            }
            exp.link.transport_timeout_ns = n as u64;
        }
        if let Some(v) = doc.get("link.retry_count") {
            let n = v.as_int()?;
            if n < 0 {
                bail!("link.retry_count must be >= 0, got {n}");
            }
            exp.link.retry_count = n as u32;
        }
        if let Some(v) = doc.get("link.rnr_depth") {
            let n = v.as_int()?;
            if n < 0 {
                bail!("link.rnr_depth must be >= 0, got {n}");
            }
            exp.link.rnr_depth = n as usize;
        }
        if let Some(v) = doc.get("link.seed") {
            let n = v.as_int()?;
            if n < 0 {
                bail!("link.seed must be >= 0, got {n}");
            }
            exp.link.seed = n as u64;
        }
        exp.link
            .validate(exp.replication.backups)
            .context("invalid [link] section")?;
        if let Some(v) = doc.get("workload.kind") {
            match v.as_str()? {
                "transact" => {
                    let epochs = doc
                        .get("workload.epochs")
                        .map(|v| v.as_int())
                        .transpose()?
                        .unwrap_or(4) as u32;
                    let writes = doc
                        .get("workload.writes")
                        .map(|v| v.as_int())
                        .transpose()?
                        .unwrap_or(1) as u32;
                    let txns = doc
                        .get("workload.txns")
                        .map(|v| v.as_int())
                        .transpose()?
                        .unwrap_or(10_000) as u64;
                    exp.workload = WorkloadSpec::Transact {
                        epochs,
                        writes,
                        txns,
                    };
                }
                "whisper" => {
                    let app = doc
                        .get("workload.app")
                        .map(|v| v.as_str().map(str::to_string))
                        .transpose()?
                        .unwrap_or_else(|| "ctree".into());
                    let ops = doc
                        .get("workload.ops")
                        .map(|v| v.as_int())
                        .transpose()?
                        .unwrap_or(10_000) as u64;
                    let threads = doc
                        .get("workload.threads")
                        .map(|v| v.as_int())
                        .transpose()?
                        .unwrap_or(4) as usize;
                    exp.workload = WorkloadSpec::Whisper { app, ops, threads };
                }
                other => bail!("unknown workload.kind {other:?}"),
            }
        }
        Ok(exp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_roundtrip() {
        let exp = Experiment::default();
        assert_eq!(exp.strategy, StrategyKind::NoSm);
        assert_eq!(exp.seed, 42);
    }

    #[test]
    fn parse_full_config() {
        let text = r#"
# experiment file
[experiment]
seed = 7
strategy = "sm-ob"
ledger = true

[workload]
kind = "transact"
epochs = 16
writes = 2
txns = 500

[platform]
rtt = 2000
nqp = 8
"#;
        let exp = Experiment::from_str(text).unwrap();
        assert_eq!(exp.seed, 7);
        assert_eq!(exp.strategy, StrategyKind::SmOb);
        assert!(exp.ledger);
        assert_eq!(
            exp.workload,
            WorkloadSpec::Transact {
                epochs: 16,
                writes: 2,
                txns: 500
            }
        );
        assert_eq!(exp.platform.rtt, 2000);
        assert_eq!(exp.platform.nqp, 8);
    }

    #[test]
    fn parse_whisper_config() {
        let text = r#"
[experiment]
strategy = "sm-dd"
[workload]
kind = "whisper"
app = "echo"
ops = 123
threads = 2
"#;
        let exp = Experiment::from_str(text).unwrap();
        assert_eq!(
            exp.workload,
            WorkloadSpec::Whisper {
                app: "echo".into(),
                ops: 123,
                threads: 2
            }
        );
    }

    #[test]
    fn bad_workload_kind_rejected() {
        assert!(Experiment::from_str("[workload]\nkind = \"nope\"").is_err());
    }

    #[test]
    fn replication_defaults_when_section_missing() {
        let exp = Experiment::from_str("[experiment]\nseed = 1").unwrap();
        assert_eq!(exp.replication, ReplicationConfig::default());
        assert_eq!(exp.replication.backups, 1);
        assert_eq!(exp.replication.ack_policy, AckPolicy::All);
    }

    #[test]
    fn replication_section_roundtrip() {
        let text = r#"
[replication]
backups = 3
ack_policy = "quorum:2"
"#;
        let exp = Experiment::from_str(text).unwrap();
        assert_eq!(exp.replication.backups, 3);
        assert_eq!(exp.replication.ack_policy, AckPolicy::Quorum(2));
        assert_eq!(exp.replication.required(), 2);

        let text = "[replication]\nbackups = 5\nack_policy = \"majority\"";
        let exp = Experiment::from_str(text).unwrap();
        assert_eq!(exp.replication.ack_policy, AckPolicy::Majority);
        assert_eq!(exp.replication.required(), 3);
    }

    #[test]
    fn faults_section_roundtrip() {
        use crate::net::faults::OnLoss;
        let text = r#"
[replication]
backups = 3
ack_policy = "quorum:2"

[faults]
plan = "kill:1@50000,rejoin:1@120000"
on_loss = "degrade"
handoff_ns = 5000
resync_line_ns = 50
"#;
        let exp = Experiment::from_str(text).unwrap();
        assert_eq!(exp.faults.plan.len(), 2);
        assert_eq!(
            exp.faults.plan.to_string(),
            "kill:1@50000,rejoin:1@120000"
        );
        assert_eq!(exp.faults.on_loss, OnLoss::Degrade);
        assert_eq!(exp.faults.handoff_ns, 5000);
        assert_eq!(exp.faults.resync_line_ns, 50);
    }

    #[test]
    fn faults_default_when_section_missing() {
        use crate::net::faults::{FaultsConfig, OnLoss};
        let exp = Experiment::from_str("[experiment]\nseed = 1").unwrap();
        assert_eq!(exp.faults, FaultsConfig::default());
        assert!(exp.faults.plan.is_empty());
        assert_eq!(exp.faults.on_loss, OnLoss::Halt);
    }

    #[test]
    fn faults_section_rejects_bad_shapes() {
        // Plan names a backup outside the group.
        let text = "[replication]\nbackups = 2\n[faults]\nplan = \"kill:2@100\"";
        assert!(Experiment::from_str(text).is_err());
        // Rejoin without a prior kill.
        let text = "[faults]\nplan = \"rejoin:0@100\"";
        assert!(Experiment::from_str(text).is_err());
        // Unknown loss mode and malformed plan strings.
        assert!(Experiment::from_str("[faults]\non_loss = \"explode\"").is_err());
        assert!(Experiment::from_str("[faults]\nplan = \"kill:0\"").is_err());
        // Negative knobs.
        assert!(Experiment::from_str("[faults]\nhandoff_ns = -1").is_err());
        assert!(Experiment::from_str("[faults]\nresync_line_ns = -1").is_err());
    }

    #[test]
    fn election_section_roundtrip() {
        use crate::net::faults::ElectionConfig;
        let text = r#"
[replication]
backups = 3
ack_policy = "majority"

[faults]
plan = "kill:p@40000"

[election]
handoff_ns = 12000
line_ns = 40
"#;
        let exp = Experiment::from_str(text).unwrap();
        assert!(exp.faults.plan.has_primary_faults());
        assert_eq!(exp.faults.election.handoff_ns, 12_000);
        assert_eq!(exp.faults.election.line_ns, 40);
        // Defaults when the section is missing.
        let exp = Experiment::from_str("[experiment]\nseed = 1").unwrap();
        assert_eq!(exp.faults.election, ElectionConfig::default());
    }

    #[test]
    fn election_section_rejects_negative_knobs() {
        let err = Experiment::from_str("[election]\nhandoff_ns = -1").unwrap_err();
        assert!(
            format!("{err:#}").contains("election.handoff_ns must be >= 0"),
            "{err:#}"
        );
        let err = Experiment::from_str("[election]\nline_ns = -5").unwrap_err();
        assert!(
            format!("{err:#}").contains("election.line_ns must be >= 0"),
            "{err:#}"
        );
    }

    #[test]
    fn primary_fault_plan_parses_through_config() {
        let text = r#"
[replication]
backups = 3
ack_policy = "quorum:2"

[faults]
plan = "kill:1@2000,kill:p@40000,rejoin:p@90000"
on_loss = "degrade"
"#;
        let exp = Experiment::from_str(text).unwrap();
        assert_eq!(exp.faults.plan.primary_events().len(), 2);
        assert_eq!(
            exp.faults.plan.to_string(),
            "kill:1@2000,kill:p@40000,rejoin:p@90000"
        );
        // Contradictory primary plans are parse-time errors.
        assert!(Experiment::from_str(
            "[faults]\nplan = \"kill:p@100,kill:p@200\""
        )
        .is_err());
        assert!(Experiment::from_str("[faults]\nplan = \"rejoin:p@100\"").is_err());
    }

    #[test]
    fn sharding_section_roundtrip() {
        use crate::coordinator::shard::ShardMapSpec;
        let text = r#"
[sharding]
shards = 4
map = "range:2048"
"#;
        let exp = Experiment::from_str(text).unwrap();
        assert_eq!(exp.sharding.shards, 4);
        assert_eq!(exp.sharding.map, ShardMapSpec::Range { stripe_lines: 2048 });
        // Display of the spec round-trips through the parser.
        let text = format!(
            "[sharding]\nshards = 4\nmap = \"{}\"",
            exp.sharding.map
        );
        assert_eq!(Experiment::from_str(&text).unwrap().sharding, exp.sharding);
    }

    #[test]
    fn sharding_defaults_when_section_missing() {
        let exp = Experiment::from_str("[experiment]\nseed = 1").unwrap();
        assert_eq!(exp.sharding, ShardingConfig::default());
        assert_eq!(exp.sharding.shards, 1);
    }

    #[test]
    fn sharding_section_rejects_bad_shapes() {
        // Zero/negative shard counts carry a clear error.
        let err = Experiment::from_str("[sharding]\nshards = 0").unwrap_err();
        assert!(
            format!("{err:#}").contains("sharding.shards must be >= 1"),
            "{err:#}"
        );
        assert!(Experiment::from_str("[sharding]\nshards = -3").is_err());
        assert!(Experiment::from_str("[sharding]\nshards = 65").is_err());
        // Unknown / malformed maps.
        assert!(Experiment::from_str("[sharding]\nmap = \"hash\"").is_err());
        assert!(Experiment::from_str("[sharding]\nmap = \"range:0\"").is_err());
    }

    #[test]
    fn batching_section_roundtrip() {
        let exp = Experiment::from_str("[batching]\nflush_policy = \"fence\"").unwrap();
        assert_eq!(exp.batching.policy, FlushPolicy::Fence);
        let exp = Experiment::from_str("[batching]\nflush_policy = \"cap:8\"").unwrap();
        assert_eq!(exp.batching.policy, FlushPolicy::Cap(8));
        let exp = Experiment::from_str("[batching]\nbatch_cap = 4").unwrap();
        assert_eq!(exp.batching.policy, FlushPolicy::Cap(4));
        // batch_cap is the more specific knob: it wins over flush_policy.
        let exp = Experiment::from_str(
            "[batching]\nflush_policy = \"fence\"\nbatch_cap = 16",
        )
        .unwrap();
        assert_eq!(exp.batching.policy, FlushPolicy::Cap(16));
        // Display round-trips through the parser.
        let text = format!("[batching]\nflush_policy = \"{}\"", FlushPolicy::Cap(16));
        assert_eq!(
            Experiment::from_str(&text).unwrap().batching.policy,
            FlushPolicy::Cap(16)
        );
    }

    #[test]
    fn batching_defaults_to_eager_when_section_missing() {
        let exp = Experiment::from_str("[experiment]\nseed = 1").unwrap();
        assert_eq!(exp.batching, BatchingConfig::default());
        assert_eq!(exp.batching.policy, FlushPolicy::Eager);
        assert!(exp.batching.policy.is_eager());
    }

    #[test]
    fn coalescing_section_roundtrip() {
        use crate::net::wqe::CoalesceMode;
        let text = "[batching]\nflush_policy = \"fence\"\n[coalescing]\nmode = \"full\"";
        let exp = Experiment::from_str(text).unwrap();
        assert_eq!(exp.coalescing.mode, CoalesceMode::Full);
        for mode in ["none", "combine", "sg", "full"] {
            let text = format!(
                "[batching]\nbatch_cap = 8\n[coalescing]\nmode = \"{mode}\""
            );
            let exp = Experiment::from_str(&text).unwrap();
            assert_eq!(exp.coalescing.mode.to_string(), mode);
        }
        // Default: coalescing off.
        let exp = Experiment::from_str("[experiment]\nseed = 1").unwrap();
        assert_eq!(exp.coalescing.mode, CoalesceMode::None);
    }

    #[test]
    fn coalescing_section_rejects_bad_shapes() {
        // Unknown mode.
        assert!(Experiment::from_str(
            "[batching]\nflush_policy = \"fence\"\n[coalescing]\nmode = \"both\""
        )
        .is_err());
        // Coalescing without a staged flush policy is a config error
        // (eager posting stages nothing to coalesce) — including the
        // cap:1 == eager normalization.
        let err = Experiment::from_str("[coalescing]\nmode = \"sg\"").unwrap_err();
        assert!(
            format!("{err:#}").contains("requires a staged flush policy"),
            "{err:#}"
        );
        assert!(Experiment::from_str(
            "[batching]\nbatch_cap = 1\n[coalescing]\nmode = \"combine\""
        )
        .is_err());
        // mode = none composes with anything.
        assert!(Experiment::from_str("[coalescing]\nmode = \"none\"").is_ok());
    }

    #[test]
    fn concurrency_section_roundtrip() {
        let text = r#"
[concurrency]
commit_pipelines = 4
group_fence_ns = 2600
"#;
        let exp = Experiment::from_str(text).unwrap();
        assert_eq!(exp.concurrency, ConcurrencyConfig::new(4, 2600));
        assert!(exp.concurrency.enabled());
    }

    #[test]
    fn concurrency_defaults_to_serial_when_section_missing() {
        let exp = Experiment::from_str("[experiment]\nseed = 1").unwrap();
        assert_eq!(exp.concurrency, ConcurrencyConfig::default());
        assert!(!exp.concurrency.enabled());
    }

    #[test]
    fn concurrency_section_rejects_bad_shapes() {
        let err =
            Experiment::from_str("[concurrency]\ncommit_pipelines = 0").unwrap_err();
        assert!(
            format!("{err:#}").contains("commit_pipelines must be >= 1"),
            "{err:#}"
        );
        assert!(Experiment::from_str("[concurrency]\ncommit_pipelines = -2").is_err());
        assert!(Experiment::from_str("[concurrency]\ncommit_pipelines = 65").is_err());
        assert!(Experiment::from_str("[concurrency]\ngroup_fence_ns = -1").is_err());
    }

    #[test]
    fn adaptive_section_roundtrip() {
        let text = r#"
[adaptive]
enabled = true
quorum = false
feedback = true
ewma_pct = 35
hysteresis_pct = 5
"#;
        let exp = Experiment::from_str(text).unwrap();
        assert!(exp.adaptive.enabled);
        assert!(!exp.adaptive.quorum);
        assert!(exp.adaptive.batch, "batch keeps its default");
        assert!(exp.adaptive.feedback);
        assert_eq!(exp.adaptive.ewma_pct, 35);
        assert_eq!(exp.adaptive.hysteresis_pct, 5);
    }

    #[test]
    fn adaptive_defaults_to_disabled_when_section_missing() {
        let exp = Experiment::from_str("[experiment]\nseed = 1").unwrap();
        assert_eq!(exp.adaptive, AdaptiveConfig::default());
        assert!(!exp.adaptive.enabled);
    }

    #[test]
    fn adaptive_section_rejects_bad_shapes() {
        assert!(Experiment::from_str("[adaptive]\newma_pct = 0").is_err());
        assert!(Experiment::from_str("[adaptive]\newma_pct = 101").is_err());
        assert!(Experiment::from_str("[adaptive]\nhysteresis_pct = -1").is_err());
        assert!(Experiment::from_str("[adaptive]\nhysteresis_pct = 200").is_err());
        assert!(Experiment::from_str("[adaptive]\nenabled = 3").is_err());
    }

    #[test]
    fn batching_section_rejects_bad_shapes() {
        assert!(Experiment::from_str("[batching]\nbatch_cap = 0").is_err());
        assert!(Experiment::from_str("[batching]\nbatch_cap = -4").is_err());
        assert!(Experiment::from_str("[batching]\nflush_policy = \"cap:0\"").is_err());
        assert!(Experiment::from_str("[batching]\nflush_policy = \"lazy\"").is_err());
    }

    #[test]
    fn remote_section_roundtrip() {
        // The `[remote]` table flows through Platform::from_doc into the
        // experiment's platform.
        let exp =
            Experiment::from_str("[remote]\npersist_domain = \"log-structured\"").unwrap();
        assert_eq!(exp.platform.persist_domain, PersistDomain::LogStructured);
        // Default: the ADR anchor.
        let exp = Experiment::from_str("[experiment]\nseed = 1").unwrap();
        assert_eq!(exp.platform.persist_domain, PersistDomain::Adr);
        // Malformed values are experiment-load errors.
        assert!(Experiment::from_str("[remote]\npersist_domain = \"dax\"").is_err());
        assert!(Experiment::from_str("[remote]\npersist_domain = 3").is_err());
    }

    #[test]
    fn link_section_roundtrip() {
        let text = r#"
[replication]
backups = 3
ack_policy = "quorum:2"

[link]
plan = "drop:1@50000,loss:2:0.5%"
transport_timeout_ns = 6000
retry_count = 5
rnr_depth = 32
seed = 99
"#;
        let exp = Experiment::from_str(text).unwrap();
        assert_eq!(exp.link.plan.to_string(), "drop:1@50000,loss:2:0.5%");
        assert_eq!(exp.link.transport_timeout_ns, 6000);
        assert_eq!(exp.link.retry_count, 5);
        assert_eq!(exp.link.rnr_depth, 32);
        assert_eq!(exp.link.seed, 99);
        assert!(exp.link.enabled());
    }

    #[test]
    fn link_defaults_to_reliable_wire_when_section_missing() {
        let exp = Experiment::from_str("[experiment]\nseed = 1").unwrap();
        assert_eq!(exp.link, LinkConfig::default());
        assert!(!exp.link.enabled());
    }

    #[test]
    fn link_section_rejects_bad_shapes() {
        // Plan names a backup outside the group (default: 1 backup).
        assert!(Experiment::from_str("[link]\nplan = \"drop:1@100\"").is_err());
        // Malformed plan tokens.
        assert!(Experiment::from_str("[link]\nplan = \"drop:0\"").is_err());
        assert!(Experiment::from_str("[link]\nplan = \"loss:0:150%\"").is_err());
        // Degenerate knobs.
        assert!(
            Experiment::from_str("[link]\ntransport_timeout_ns = 0").is_err()
        );
        assert!(Experiment::from_str("[link]\nretry_count = -1").is_err());
        assert!(Experiment::from_str("[link]\nrnr_depth = -2").is_err());
        assert!(Experiment::from_str("[link]\nseed = -7").is_err());
    }

    #[test]
    fn replication_bad_policy_string_rejected() {
        let text = "[replication]\nbackups = 2\nack_policy = \"most-of-them\"";
        assert!(Experiment::from_str(text).is_err());
    }

    #[test]
    fn replication_quorum_larger_than_group_rejected() {
        let text = "[replication]\nbackups = 2\nack_policy = \"quorum:3\"";
        let err = Experiment::from_str(text).unwrap_err();
        assert!(
            format!("{err:#}").contains("quorum:3"),
            "error should name the policy: {err:#}"
        );
        // Zero and negative backups are also invalid (no usize wrap).
        assert!(Experiment::from_str("[replication]\nbackups = 0").is_err());
        assert!(Experiment::from_str("[replication]\nbackups = -1").is_err());
    }
}
