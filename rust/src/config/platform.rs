//! Platform model parameters — the paper's §6.1 model inputs + Table 2
//! test-bed geometry, kept in lock-step with
//! `python/compile/kernels/params.py` (see `to_param_vec`).

use super::toml::Doc;
use crate::Ns;
use anyhow::{bail, Result};
use std::str::FromStr;

/// Replication strategy selector (paper §5 + our adaptive extension).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum StrategyKind {
    /// Local persistence only (hypothetical upper bound).
    NoSm,
    /// SM using the remote-commit verb (Talpey & Pinkerton draft).
    SmRc,
    /// SM using ordered buffering (rwtw + rofence + rdfence) — ours.
    SmOb,
    /// SM with DDIO disabled (rntw on a single QP + read fence) — ours.
    SmDd,
    /// Model-driven adaptive OB/DD selection (extension, uses the AOT
    /// latency model through PJRT).
    SmAd,
}

impl StrategyKind {
    /// The paper's Table-1 strategies: the fixed four that run without a
    /// predictor (NO-SM baseline + the three SM designs). Sweeps that
    /// build strategies with `make_strategy(kind, None)` iterate this.
    pub const TABLE: [StrategyKind; 4] =
        [Self::NoSm, Self::SmRc, Self::SmOb, Self::SmDd];
    /// Every strategy, *including* the adaptive `SmAd` (which needs a
    /// predictor — see `runtime::fallback_predictor`). Sweeps iterating
    /// this must supply one, or they silently skip adaptive runs — the
    /// bug the old 4-entry `ALL` had.
    pub const ALL: [StrategyKind; 5] =
        [Self::NoSm, Self::SmRc, Self::SmOb, Self::SmDd, Self::SmAd];
    pub const SM: [StrategyKind; 3] = [Self::SmRc, Self::SmOb, Self::SmDd];

    pub fn name(self) -> &'static str {
        match self {
            Self::NoSm => "no-sm",
            Self::SmRc => "sm-rc",
            Self::SmOb => "sm-ob",
            Self::SmDd => "sm-dd",
            Self::SmAd => "sm-ad",
        }
    }
}

impl FromStr for StrategyKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "no-sm" | "nosm" | "none" => Self::NoSm,
            "sm-rc" | "rc" => Self::SmRc,
            "sm-ob" | "ob" => Self::SmOb,
            "sm-dd" | "dd" => Self::SmDd,
            "sm-ad" | "ad" | "adaptive" => Self::SmAd,
            other => bail!("unknown strategy {other:?}"),
        })
    }
}

impl std::fmt::Display for StrategyKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Acknowledgement policy of a replica group: when is a durability fence
/// on the primary allowed to complete?
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AckPolicy {
    /// True synchronous mirroring: every backup must be durable.
    All,
    /// Majority-durable: `floor(backups/2) + 1` backups must be durable.
    Majority,
    /// At least `k` backups must be durable (`1 <= k <= backups`).
    Quorum(usize),
}

impl AckPolicy {
    /// Number of durable backups this policy requires out of `backups`.
    pub fn required(self, backups: usize) -> usize {
        match self {
            AckPolicy::All => backups,
            AckPolicy::Majority => backups / 2 + 1,
            AckPolicy::Quorum(k) => k,
        }
    }
}

impl FromStr for AckPolicy {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        let s = s.trim().to_ascii_lowercase();
        match s.as_str() {
            "all" => return Ok(AckPolicy::All),
            "majority" => return Ok(AckPolicy::Majority),
            _ => {}
        }
        if let Some(rest) = s.strip_prefix("quorum") {
            // Exactly one separator then K — quorum:K, quorum(K),
            // quorum-K, quorum=K, quorum K — with K parsed strictly, so
            // "quorum2", "quorum:-2" and "quorum:2)" all error.
            let k_str = if let Some(inner) = rest.strip_prefix('(') {
                inner.strip_suffix(')')
            } else {
                rest.strip_prefix(|c: char| ":=- ".contains(c))
            };
            if let Some(k) = k_str.and_then(|d| d.trim().parse::<usize>().ok()) {
                return Ok(AckPolicy::Quorum(k));
            }
            bail!("malformed quorum ack policy {s:?}; use \"quorum:K\"");
        }
        bail!("unknown ack policy {s:?}; expected all | majority | quorum:K")
    }
}

impl std::fmt::Display for AckPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AckPolicy::All => f.write_str("all"),
            AckPolicy::Majority => f.write_str("majority"),
            AckPolicy::Quorum(k) => write!(f, "quorum:{k}"),
        }
    }
}

/// Replica-group shape: how many backups a [`crate::net::Fabric`] drives
/// and the acknowledgement policy governing durability fences.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReplicationConfig {
    pub backups: usize,
    pub ack_policy: AckPolicy,
}

impl Default for ReplicationConfig {
    /// The paper's topology: one backup, fully synchronous.
    fn default() -> Self {
        ReplicationConfig {
            backups: 1,
            ack_policy: AckPolicy::All,
        }
    }
}

impl ReplicationConfig {
    pub fn new(backups: usize, ack_policy: AckPolicy) -> Self {
        ReplicationConfig { backups, ack_policy }
    }

    /// Number of durable backups required at a durability fence.
    pub fn required(&self) -> usize {
        self.ack_policy.required(self.backups)
    }

    /// Sanity-check invariants (`1 <= required <= backups`).
    pub fn validate(&self) -> Result<()> {
        if self.backups == 0 {
            bail!("replication.backups must be >= 1");
        }
        let req = self.required();
        if req == 0 {
            bail!("ack policy {} requires at least one ack", self.ack_policy);
        }
        if req > self.backups {
            bail!(
                "ack policy {} needs {req} durable backups but the group \
                 only has {}",
                self.ack_policy,
                self.backups
            );
        }
        Ok(())
    }
}

/// Default Intel complex-addressing slice-hash masks for an 8-slice LLC
/// (Maurice et al., "Reverse engineering Intel last-level cache complex
/// addressing using performance counters").
pub const INTEL_8SLICE_MASKS: [u64; 3] =
    [0x1B5F_5754_40, 0x2EB5_FAA8_80, 0x3CCC_C931_00];

/// All model latencies in ns; geometry in entries/ways/lines.
#[derive(Clone, Debug, PartialEq)]
pub struct Platform {
    // ---- network (ConnectX-3-like)
    /// RDMA small-message round trip (ns).
    pub rtt: Ns,
    /// Per-WQE issue gap on one QP (ns).
    pub gap: Ns,
    /// QPs used by multi-QP strategies (SM-RC, SM-OB).
    pub nqp: usize,
    /// Per-WQE pipeline depth of a QP before posting stalls.
    pub qp_depth: usize,
    /// CPU cost of the MMIO doorbell launching a chain of staged WQEs
    /// (ns) — paid once per flush per backup; the former `post_cost`
    /// split as `doorbell_ns + wqe_stage_ns` (see [`crate::net::wqe`]).
    pub doorbell_ns: Ns,
    /// CPU cost to build and stage one WQE in host memory (ns) — paid
    /// per WQE regardless of batching.
    pub wqe_stage_ns: Ns,
    /// Wire/issue serialization of each *additional* line carried by a
    /// scatter-gather span WQE (ns) — see [`crate::net::wqe`]. The
    /// legacy default equals `gap` (each extra line costs a full
    /// per-WQE issue slot, the pre-coalescing per-line wire cost), so
    /// enabling `--coalesce sg` on an untouched config saves NIC
    /// message slots and doorbells without silently changing the wire
    /// bandwidth model; set it lower (a 64 B line is ~13 ns at 40 Gb/s)
    /// to model real SG DMA amortization. Note the gap-tracking default
    /// is enforced by the TOML loader ([`Platform::from_doc`]); code
    /// that overrides `gap` programmatically via struct-update keeps
    /// the stock 150 ns here unless it sets this field too.
    pub wire_line_ns: Ns,
    /// CPU cost of one CQ poll iteration (ns).
    pub poll_cost: Ns,

    // ---- PCIe / DDIO
    /// PCIe write round trip to the LLC (ns) — paper: 200.
    pub pcie_rt: Ns,
    /// Occupancy of one posted PCIe write on the shared root-complex port
    /// (pipelined burst rate, ns/line).
    pub pcie_occ: Ns,
    /// Serialized per-line cost of an ordered non-temporal (non-posted)
    /// PCIe write beyond the NIC pipeline depth (ns).
    pub nt_serial: Ns,

    // ---- memory subsystem (paper §6.1)
    /// LLC -> memory-controller queue transfer (ns) — paper: 10.
    pub llc_mc: Ns,
    /// MC queue -> PM write latency per line (ns) — paper: 150.
    pub mc_pm: Ns,
    /// MC write queue depth (entries) — paper: 64.
    pub mcq: usize,
    /// MC drain bank parallelism.
    pub mc_banks: usize,

    // ---- LLC geometry (Xeon E5-2630 v3: 20 MB, 20-way)
    /// Cache slices.
    pub llc_slices: usize,
    /// Sets per slice.
    pub llc_sets_per_slice: usize,
    /// Ways per set.
    pub llc_ways: usize,
    /// Ways per set available to DDIO traffic — paper: 2 of 20.
    pub ddio_ways: usize,
    /// Slice-hash XOR masks.
    pub slice_masks: Vec<u64>,

    // ---- local CPU persistence path
    /// Store issue (ns).
    pub store: Ns,
    /// clwb/clflush issue (ns).
    pub flush: Ns,
    /// sfence base cost (ns).
    pub sfence: Ns,

    // ---- strategy model constants
    /// Remote cross-QP ordering barrier bubble charged per rofence (ns).
    pub ob_barrier: Ns,

    // ---- remote persistence
    /// Persistence discipline of the backup PM (`[remote] persist_domain`
    /// TOML key / `--persist-domain` CLI) — see
    /// [`crate::net::PersistDomain`]. Default `adr` is the paper's model
    /// and the bit-exact pre-domain anchor.
    pub persist_domain: crate::net::PersistDomain,
}

impl Default for Platform {
    fn default() -> Self {
        Platform {
            rtt: 2600,
            gap: 150,
            nqp: 4,
            qp_depth: 64,
            doorbell_ns: 20,
            wqe_stage_ns: 10,
            wire_line_ns: 150, // legacy default: the full per-line cost (= gap)
            poll_cost: 20,
            pcie_rt: 200,
            pcie_occ: 25,
            nt_serial: 210,
            llc_mc: 10,
            mc_pm: 150,
            mcq: 64,
            mc_banks: 4,
            llc_slices: 8,
            llc_sets_per_slice: 2048,
            llc_ways: 20,
            ddio_ways: 2,
            slice_masks: INTEL_8SLICE_MASKS.to_vec(),
            store: 10,
            flush: 25,
            sfence: 20,
            ob_barrier: 75,
            persist_domain: crate::net::PersistDomain::Adr,
        }
    }
}

impl Platform {
    /// Lines the DDIO ways can buffer across the whole LLC (paper: ~2 MB).
    pub fn ddio_lines(&self) -> u64 {
        (self.llc_slices * self.llc_sets_per_slice * self.ddio_ways) as u64
    }

    /// Full CPU cost of one eager (unbatched) WQE post: build + stage
    /// the WQE and ring its own doorbell. This is the pre-batching
    /// `post_cost` (30 ns by default); `batch_cap = 1` charges exactly
    /// this per WQE, which anchors the staged pipeline to the old model.
    pub fn post_cost(&self) -> Ns {
        self.doorbell_ns + self.wqe_stage_ns
    }

    /// The f32[16] parameter vector consumed by the AOT latency model —
    /// indices must match `python/compile/kernels/params.py`.
    pub fn to_param_vec(&self) -> [f32; 16] {
        let mut p = [0f32; 16];
        p[0] = self.rtt as f32;
        p[1] = self.gap as f32;
        p[2] = self.nqp as f32;
        p[3] = self.pcie_rt as f32;
        p[4] = self.llc_mc as f32;
        p[5] = self.mc_pm as f32;
        p[6] = self.mcq as f32;
        p[7] = self.store as f32;
        p[8] = self.flush as f32;
        p[9] = self.sfence as f32;
        p[10] = self.mc_banks as f32;
        p[11] = self.ob_barrier as f32;
        p[12] = self.qp_depth as f32;
        p[13] = self.nt_serial as f32;
        p[14] = self.ddio_lines() as f32;
        p[15] = self.wire_line_ns as f32;
        p
    }

    /// The extended f32[18] parameter vector for the knob-aware latency
    /// model (`predict(epochs, writes, backups, quorum, batch_cap)` —
    /// see [`crate::runtime::fallback_knob_predictor`]): the legacy 16
    /// slots followed by the staged-pipeline CPU cost split the batching
    /// knob amortizes. Indices must match
    /// `python/compile/kernels/params.py` (`P_DOORBELL` /
    /// `P_WQE_STAGE`).
    pub fn to_param_vec_ext(&self) -> [f32; 18] {
        let base = self.to_param_vec();
        let mut p = [0f32; 18];
        p[..16].copy_from_slice(&base);
        p[16] = self.doorbell_ns as f32;
        p[17] = self.wqe_stage_ns as f32;
        p
    }

    /// Override fields from a parsed config document (`[platform]` table).
    pub fn from_doc(doc: &Doc) -> Result<Self> {
        let mut p = Platform::default();
        macro_rules! ns_field {
            ($key:literal, $field:ident) => {
                if let Some(v) = doc.get(concat!("platform.", $key)) {
                    p.$field = v.as_int()? as Ns;
                }
            };
        }
        macro_rules! usize_field {
            ($key:literal, $field:ident) => {
                if let Some(v) = doc.get(concat!("platform.", $key)) {
                    p.$field = v.as_int()? as usize;
                }
            };
        }
        ns_field!("rtt", rtt);
        ns_field!("gap", gap);
        // Legacy default: a config that never heard of scatter-gather
        // keeps the full per-line wire cost — `wire_line_ns` tracks the
        // (possibly overridden) gap unless set explicitly below.
        p.wire_line_ns = p.gap;
        ns_field!("wire_line_ns", wire_line_ns);
        ns_field!("pcie_rt", pcie_rt);
        ns_field!("pcie_occ", pcie_occ);
        ns_field!("nt_serial", nt_serial);
        ns_field!("llc_mc", llc_mc);
        ns_field!("mc_pm", mc_pm);
        ns_field!("store", store);
        ns_field!("flush", flush);
        ns_field!("sfence", sfence);
        ns_field!("ob_barrier", ob_barrier);
        // Legacy alias from before the doorbell/stage split: assign the
        // whole per-post cost to the doorbell so eager runs reproduce
        // old configs bit-exactly. The explicit keys below override.
        if let Some(v) = doc.get("platform.post_cost") {
            p.doorbell_ns = v.as_int()? as Ns;
            p.wqe_stage_ns = 0;
        }
        ns_field!("doorbell_ns", doorbell_ns);
        ns_field!("wqe_stage_ns", wqe_stage_ns);
        ns_field!("poll_cost", poll_cost);
        usize_field!("nqp", nqp);
        usize_field!("qp_depth", qp_depth);
        usize_field!("mcq", mcq);
        usize_field!("mc_banks", mc_banks);
        usize_field!("llc_slices", llc_slices);
        usize_field!("llc_sets_per_slice", llc_sets_per_slice);
        usize_field!("llc_ways", llc_ways);
        usize_field!("ddio_ways", ddio_ways);
        if let Some(v) = doc.get("platform.slice_masks") {
            p.slice_masks = v.as_u64_array()?;
        }
        // The `[remote]` table holds the backup-side persistence
        // discipline (its cost constants live under `[platform]` with
        // the rest of the memory subsystem).
        if let Some(v) = doc.get("remote.persist_domain") {
            p.persist_domain = v
                .as_str()?
                .parse()
                .map_err(|e: String| anyhow::anyhow!("remote.persist_domain: {e}"))?;
        }
        p.validate()?;
        Ok(p)
    }

    /// Sanity-check invariants.
    pub fn validate(&self) -> Result<()> {
        if self.ddio_ways > self.llc_ways {
            bail!(
                "ddio_ways ({}) exceeds llc_ways ({})",
                self.ddio_ways,
                self.llc_ways
            );
        }
        if !self.llc_sets_per_slice.is_power_of_two() {
            bail!("llc_sets_per_slice must be a power of two");
        }
        if self.nqp == 0 || self.mcq == 0 || self.mc_banks == 0 {
            bail!("nqp/mcq/mc_banks must be positive");
        }
        if (1usize << self.slice_masks.len().min(63)) < self.llc_slices {
            bail!(
                "{} slice masks cannot address {} slices",
                self.slice_masks.len(),
                self.llc_slices
            );
        }
        Ok(())
    }

    /// Render a Table-2-style summary (experiment T2).
    pub fn table2(&self) -> String {
        format!(
            "Platform (paper Table 2 analogue)\n\
               network   : RDMA rtt={}ns gap={}ns nqp={} qp_depth={} \
             wire_line={}ns\n\
               pcie/ddio : pcie_rt={}ns nt_serial={}ns ddio_ways={}/{}\n\
               llc       : {} slices x {} sets x {} ways (64B lines)\n\
               memctrl   : queue={} banks={} llc->mc={}ns mc->pm={}ns \
             persist_domain={}\n\
               cpu       : store={}ns flush={}ns sfence={}ns \
             doorbell={}ns wqe_stage={}ns poll={}ns",
            self.rtt,
            self.gap,
            self.nqp,
            self.qp_depth,
            self.wire_line_ns,
            self.pcie_rt,
            self.nt_serial,
            self.ddio_ways,
            self.llc_ways,
            self.llc_slices,
            self.llc_sets_per_slice,
            self.llc_ways,
            self.mcq,
            self.mc_banks,
            self.llc_mc,
            self.mc_pm,
            self.persist_domain,
            self.store,
            self.flush,
            self.sfence,
            self.doorbell_ns,
            self.wqe_stage_ns,
            self.poll_cost,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_python_params() {
        // Lock-step with python/compile/kernels/params.py default_params().
        let p = Platform::default().to_param_vec();
        assert_eq!(p[0], 2600.0); // rtt
        assert_eq!(p[1], 150.0); // gap
        assert_eq!(p[2], 4.0); // nqp
        assert_eq!(p[3], 200.0); // pcie_rt
        assert_eq!(p[4], 10.0); // llc_mc
        assert_eq!(p[5], 150.0); // mc_pm
        assert_eq!(p[6], 64.0); // mcq
        assert_eq!(p[7], 10.0); // store
        assert_eq!(p[8], 25.0); // flush
        assert_eq!(p[9], 20.0); // sfence
        assert_eq!(p[10], 4.0); // banks
        assert_eq!(p[11], 75.0); // ob_barrier
        assert_eq!(p[12], 64.0); // qp_depth
        assert_eq!(p[13], 210.0); // nt_serial
        assert_eq!(p[14], 32768.0); // ddio lines = 8*2048*2
        assert_eq!(p[15], 150.0); // wire_line_ns (= gap, legacy per-line)
    }

    #[test]
    fn strategy_parse() {
        assert_eq!("sm-ob".parse::<StrategyKind>().unwrap(), StrategyKind::SmOb);
        assert_eq!("RC".parse::<StrategyKind>().unwrap(), StrategyKind::SmRc);
        assert!("bogus".parse::<StrategyKind>().is_err());
    }

    #[test]
    fn strategy_sets_cover_adaptive() {
        // TABLE is the predictor-free fixed four; ALL adds SM-AD — the
        // old 4-entry ALL silently skipped adaptive runs in sweeps.
        assert_eq!(StrategyKind::TABLE.len(), 4);
        assert!(!StrategyKind::TABLE.contains(&StrategyKind::SmAd));
        assert_eq!(StrategyKind::ALL.len(), 5);
        assert!(StrategyKind::ALL.contains(&StrategyKind::SmAd));
        for k in StrategyKind::TABLE {
            assert!(StrategyKind::ALL.contains(&k));
        }
        for k in StrategyKind::SM {
            assert!(StrategyKind::TABLE.contains(&k));
        }
    }

    #[test]
    fn wire_line_defaults_follow_gap() {
        use crate::config::toml;
        // No keys: the legacy default is the full per-line cost (gap).
        let p = Platform::default();
        assert_eq!(p.wire_line_ns, p.gap);
        // An overridden gap drags the default along...
        let doc = toml::parse("[platform]\ngap = 200").unwrap();
        let p = Platform::from_doc(&doc).unwrap();
        assert_eq!((p.gap, p.wire_line_ns), (200, 200));
        // ...until wire_line_ns is set explicitly.
        let doc = toml::parse("[platform]\ngap = 200\nwire_line_ns = 16").unwrap();
        let p = Platform::from_doc(&doc).unwrap();
        assert_eq!((p.gap, p.wire_line_ns), (200, 16));
    }

    #[test]
    fn validation_catches_bad_geometry() {
        let mut p = Platform::default();
        p.ddio_ways = 30;
        assert!(p.validate().is_err());
        let mut p = Platform::default();
        p.llc_sets_per_slice = 1000;
        assert!(p.validate().is_err());
        let mut p = Platform::default();
        p.slice_masks = vec![1];
        assert!(p.validate().is_err());
    }

    #[test]
    fn ext_param_vec_extends_the_legacy_vector() {
        // Lock-step with python/compile/kernels/params.py: the first 16
        // slots are the legacy vector unchanged, then the doorbell /
        // stage split (P_DOORBELL = 16, P_WQE_STAGE = 17).
        let plat = Platform::default();
        let base = plat.to_param_vec();
        let ext = plat.to_param_vec_ext();
        assert_eq!(&ext[..16], &base[..]);
        assert_eq!(ext[16], 20.0); // doorbell_ns
        assert_eq!(ext[17], 10.0); // wqe_stage_ns
    }

    #[test]
    fn post_cost_split_sums_to_legacy_value() {
        // The staged-pipeline split must reproduce the pre-batching
        // 30 ns per eager post (the batch_cap = 1 anchor).
        let p = Platform::default();
        assert_eq!(p.doorbell_ns, 20);
        assert_eq!(p.wqe_stage_ns, 10);
        assert_eq!(p.post_cost(), 30);
    }

    #[test]
    fn table2_prints_batching_knobs() {
        // Bench logs must record the doorbell/stage split (the batching
        // knobs) alongside the other cpu costs.
        let t = Platform::default().table2();
        assert!(t.contains("doorbell=20ns"), "{t}");
        assert!(t.contains("wqe_stage=10ns"), "{t}");
        assert!(t.contains("wire_line=150ns"), "{t}");
        assert!(t.contains("store=10ns"), "{t}");
    }

    #[test]
    fn doc_post_cost_alias_and_split_keys() {
        use crate::config::toml;
        // Legacy key: whole cost lands on the doorbell (eager-exact).
        let doc = toml::parse("[platform]\npost_cost = 45").unwrap();
        let p = Platform::from_doc(&doc).unwrap();
        assert_eq!((p.doorbell_ns, p.wqe_stage_ns), (45, 0));
        assert_eq!(p.post_cost(), 45);
        // Explicit split keys override the alias.
        let doc = toml::parse("[platform]\npost_cost = 45\ndoorbell_ns = 25\nwqe_stage_ns = 5")
            .unwrap();
        let p = Platform::from_doc(&doc).unwrap();
        assert_eq!((p.doorbell_ns, p.wqe_stage_ns), (25, 5));
        assert_eq!(p.post_cost(), 30);
    }

    #[test]
    fn remote_persist_domain_key() {
        use crate::config::toml;
        use crate::net::PersistDomain;
        // Absent: the ADR anchor.
        assert_eq!(Platform::default().persist_domain, PersistDomain::Adr);
        let doc = toml::parse("[platform]\nrtt = 2600").unwrap();
        let p = Platform::from_doc(&doc).unwrap();
        assert_eq!(p.persist_domain, PersistDomain::Adr);
        // The `[remote]` table selects the discipline.
        let doc = toml::parse("[remote]\npersist_domain = \"eadr\"").unwrap();
        let p = Platform::from_doc(&doc).unwrap();
        assert_eq!(p.persist_domain, PersistDomain::Eadr);
        let doc = toml::parse("[remote]\npersist_domain = \"rpmem-flush\"").unwrap();
        let p = Platform::from_doc(&doc).unwrap();
        assert_eq!(p.persist_domain, PersistDomain::RpmemFlush);
        // Malformed values are rejected with the key in the error.
        let doc = toml::parse("[remote]\npersist_domain = \"bogus\"").unwrap();
        let err = Platform::from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("persist_domain"), "{err}");
        // Table-2 output records the discipline.
        let mut p = Platform::default();
        p.persist_domain = PersistDomain::LogStructured;
        assert!(p.table2().contains("persist_domain=log-structured"));
    }

    #[test]
    fn ddio_capacity_is_2mb() {
        let p = Platform::default();
        assert_eq!(p.ddio_lines() * crate::LINE, 2 * 1024 * 1024);
    }

    #[test]
    fn ack_policy_parse() {
        assert_eq!("all".parse::<AckPolicy>().unwrap(), AckPolicy::All);
        assert_eq!("ALL".parse::<AckPolicy>().unwrap(), AckPolicy::All);
        assert_eq!(
            "majority".parse::<AckPolicy>().unwrap(),
            AckPolicy::Majority
        );
        for s in ["quorum:2", "quorum(2)", "quorum-2", "quorum 2"] {
            assert_eq!(s.parse::<AckPolicy>().unwrap(), AckPolicy::Quorum(2), "{s}");
        }
        assert!("bogus".parse::<AckPolicy>().is_err());
        assert!("quorum:x".parse::<AckPolicy>().is_err());
        assert!("quorum".parse::<AckPolicy>().is_err());
        assert!("quorum:-2".parse::<AckPolicy>().is_err());
        assert!("quorum--2".parse::<AckPolicy>().is_err());
        assert!("quorum2".parse::<AckPolicy>().is_err());
        assert!("quorum:2)".parse::<AckPolicy>().is_err());
        assert!("quorum(2".parse::<AckPolicy>().is_err());
    }

    #[test]
    fn ack_policy_required_counts() {
        assert_eq!(AckPolicy::All.required(3), 3);
        assert_eq!(AckPolicy::Majority.required(3), 2);
        assert_eq!(AckPolicy::Majority.required(5), 3);
        assert_eq!(AckPolicy::Majority.required(1), 1);
        assert_eq!(AckPolicy::Quorum(2).required(5), 2);
    }

    #[test]
    fn replication_validation() {
        assert!(ReplicationConfig::default().validate().is_ok());
        assert_eq!(ReplicationConfig::default().backups, 1);
        let ok = ReplicationConfig::new(3, AckPolicy::Quorum(2));
        assert!(ok.validate().is_ok());
        assert_eq!(ok.required(), 2);
        // k > backups, k = 0, backups = 0 all rejected.
        assert!(ReplicationConfig::new(2, AckPolicy::Quorum(3))
            .validate()
            .is_err());
        assert!(ReplicationConfig::new(2, AckPolicy::Quorum(0))
            .validate()
            .is_err());
        assert!(ReplicationConfig::new(0, AckPolicy::All).validate().is_err());
    }

    #[test]
    fn ack_policy_display_roundtrip() {
        for p in [AckPolicy::All, AckPolicy::Majority, AckPolicy::Quorum(4)] {
            assert_eq!(p.to_string().parse::<AckPolicy>().unwrap(), p);
        }
    }
}
