//! Minimal TOML-subset parser.
//!
//! Supports the subset used by pmsm config files:
//!   * `[section]` and `[section.sub]` headers;
//!   * `key = value` with integers, floats, booleans, quoted strings and
//!     flat arrays of those;
//!   * `#` comments and blank lines.
//!
//! Keys are exposed flattened as `"section.key"`. Duplicate keys: last one
//! wins (same as TOML's behaviour is an error, but for config overrides the
//! last-wins rule is friendlier and we document it).

use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;

/// A parsed scalar or array value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_int(&self) -> Result<i64> {
        match self {
            Value::Int(i) => Ok(*i),
            _ => bail!("expected integer, got {self:?}"),
        }
    }
    pub fn as_float(&self) -> Result<f64> {
        match self {
            Value::Float(f) => Ok(*f),
            Value::Int(i) => Ok(*i as f64),
            _ => bail!("expected float, got {self:?}"),
        }
    }
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }
    pub fn as_array(&self) -> Result<&[Value]> {
        match self {
            Value::Array(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }
    /// Array of u64 (accepting ints and hex strings).
    pub fn as_u64_array(&self) -> Result<Vec<u64>> {
        self.as_array()?
            .iter()
            .map(|v| match v {
                Value::Int(i) => Ok(*i as u64),
                Value::Str(s) => parse_u64_literal(s),
                _ => bail!("expected integer array element, got {v:?}"),
            })
            .collect()
    }
}

fn parse_u64_literal(s: &str) -> Result<u64> {
    if let Some(hex) = s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        u64::from_str_radix(&hex.replace('_', ""), 16)
            .map_err(|e| anyhow!("bad hex literal {s:?}: {e}"))
    } else {
        s.replace('_', "")
            .parse::<u64>()
            .map_err(|e| anyhow!("bad integer literal {s:?}: {e}"))
    }
}

/// A parsed document: flattened `section.key -> Value`.
#[derive(Clone, Debug, Default)]
pub struct Doc {
    map: HashMap<String, Value>,
}

impl Doc {
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }
    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }
    pub fn len(&self) -> usize {
        self.map.len()
    }
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// Parse TOML-subset text.
pub fn parse(text: &str) -> Result<Doc> {
    let mut doc = Doc::default();
    let mut section = String::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| anyhow!("line {}: malformed section {raw:?}", ln + 1))?
                .trim();
            if name.is_empty() {
                bail!("line {}: empty section name", ln + 1);
            }
            section = name.to_string();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| anyhow!("line {}: expected `key = value`: {raw:?}", ln + 1))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            bail!("line {}: empty key", ln + 1);
        }
        let val = parse_value(line[eq + 1..].trim())
            .map_err(|e| anyhow!("line {}: {e}", ln + 1))?;
        let full = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        doc.map.insert(full, val);
    }
    Ok(doc)
}

/// Remove a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| anyhow!("unterminated array: {s:?}"))?;
        let mut items = Vec::new();
        for part in split_array_items(inner) {
            let p = part.trim();
            if !p.is_empty() {
                items.push(parse_value(p)?);
            }
        }
        return Ok(Value::Array(items));
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| anyhow!("unterminated string: {s:?}"))?;
        return Ok(Value::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if s.starts_with("0x") || s.starts_with("0X") {
        return Ok(Value::Int(parse_u64_literal(s)? as i64));
    }
    let cleaned = s.replace('_', "");
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value {s:?}")
}

/// Split a flat array body on commas (no nested arrays in the subset).
fn split_array_items(s: &str) -> Vec<&str> {
    let mut items = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                items.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    items.push(&s[start..]);
    items
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        let d = parse("a = 1\nb = 2.5\nc = true\nd = \"hi\"").unwrap();
        assert_eq!(d.get("a").unwrap().as_int().unwrap(), 1);
        assert_eq!(d.get("b").unwrap().as_float().unwrap(), 2.5);
        assert!(d.get("c").unwrap().as_bool().unwrap());
        assert_eq!(d.get("d").unwrap().as_str().unwrap(), "hi");
    }

    #[test]
    fn sections_flatten() {
        let d = parse("[x]\na = 1\n[x.y]\nb = 2").unwrap();
        assert_eq!(d.get("x.a").unwrap().as_int().unwrap(), 1);
        assert_eq!(d.get("x.y.b").unwrap().as_int().unwrap(), 2);
    }

    #[test]
    fn comments_and_blanks() {
        let d = parse("# header\n\na = 1 # trailing\nb = \"x # not comment\"").unwrap();
        assert_eq!(d.get("a").unwrap().as_int().unwrap(), 1);
        assert_eq!(d.get("b").unwrap().as_str().unwrap(), "x # not comment");
    }

    #[test]
    fn arrays() {
        let d = parse("m = [1, 2, 3]\nh = [\"0x1B\", \"0x2E\"]").unwrap();
        assert_eq!(
            d.get("m").unwrap().as_u64_array().unwrap(),
            vec![1, 2, 3]
        );
        assert_eq!(
            d.get("h").unwrap().as_u64_array().unwrap(),
            vec![0x1B, 0x2E]
        );
    }

    #[test]
    fn hex_and_underscores() {
        let d = parse("a = 0xFF\nb = 1_000_000").unwrap();
        assert_eq!(d.get("a").unwrap().as_int().unwrap(), 255);
        assert_eq!(d.get("b").unwrap().as_int().unwrap(), 1_000_000);
    }

    #[test]
    fn errors_are_reported() {
        assert!(parse("[unclosed").is_err());
        assert!(parse("novalue").is_err());
        assert!(parse("x = ").is_err());
        assert!(parse("x = [1, 2").is_err());
        assert!(parse("x = \"unterminated").is_err());
    }

    #[test]
    fn last_key_wins() {
        let d = parse("a = 1\na = 2").unwrap();
        assert_eq!(d.get("a").unwrap().as_int().unwrap(), 2);
    }
}
