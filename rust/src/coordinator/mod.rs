//! The mirroring coordinator: binds a primary node's persistency-model
//! traffic to a replica group of backups over the simulated RDMA fabric
//! (paper Fig. 2, generalized from one backup to N).
//!
//! [`Mirror`] exposes the persistency-model API the paper assumes
//! (Intel-style `store`/`clwb`/`sfence` plus an explicit durability fence
//! at transaction end); every `clwb` simultaneously (1) persists the line
//! locally through the primary's memory controller and (2) hands the dirty
//! line to the active replication [`Strategy`](crate::replication::Strategy)
//! for remote replication across the group's [`Fabric`]. Durability
//! fences complete per the group's ack policy; per-backup fence
//! completions are tracked on the [`ThreadCtx`] for lag analysis.
//! Multi-threaded workloads are executed by the conservative min-clock
//! scheduler in [`sched`].

pub mod sched;

use crate::config::{Platform, ReplicationConfig, StrategyKind};
use crate::net::{Fabric, FaultKind, FaultsConfig, RemoteEngine, WriteMeta};
use crate::replication::{self, Predictor, Strategy, TxnShape};
use crate::sim::{RateLimiter, ThreadClock};
use crate::util::FastMap;
use crate::{line_of, Addr, Ns};
use anyhow::{bail, Result};

/// Per-thread execution context: virtual clock + transactional counters.
#[derive(Debug)]
pub struct ThreadCtx {
    pub clock: ThreadClock,
    /// Local persist completions awaiting the next sfence.
    pending_local: Vec<Ns>,
    /// Transaction / epoch / write-sequence coordinates.
    pub txn: u64,
    pub epoch: u32,
    pub seq: u64,
    /// Completed transactions and their total writes (stats).
    pub txns_done: u64,
    pub writes_done: u64,
    pub epochs_done: u64,
    /// Completion time of the last durability fence (ack-policy level).
    pub last_dfence: Ns,
    /// Per-backup completion instants of the last durability fence
    /// (index = backup id; all zeros under NO-SM).
    pub last_dfence_per_backup: Vec<Ns>,
    /// Virtual time at which stats were last reset (steady-state marker).
    pub stats_zero_at: Ns,
}

impl ThreadCtx {
    pub fn new(id: usize) -> Self {
        ThreadCtx {
            clock: ThreadClock::new(id),
            pending_local: Vec::with_capacity(16),
            txn: 0,
            epoch: 0,
            seq: 0,
            txns_done: 0,
            writes_done: 0,
            epochs_done: 0,
            last_dfence: 0,
            last_dfence_per_backup: Vec::new(),
            stats_zero_at: 0,
        }
    }

    /// Drop warm-up/load-phase counters: measurement starts now.
    pub fn reset_stats(&mut self) {
        self.txns_done = 0;
        self.writes_done = 0;
        self.epochs_done = 0;
        self.stats_zero_at = self.clock.now;
    }

    pub fn id(&self) -> usize {
        self.clock.id
    }
    pub fn now(&self) -> Ns {
        self.clock.now
    }
}

/// The primary node + replication pipeline.
pub struct Mirror {
    pub plat: Platform,
    /// Primary's memory-controller ingress (local persistence path):
    /// time-indexed so multi-threaded clwb streams don't false-serialize
    /// (see sim::rate). Admission to the MC queue == persistence (ADR).
    local_mc: RateLimiter,
    local_mc_lat: Ns,
    /// Primary PM contents (line address -> word value).
    image: FastMap<Addr, u64>,
    /// Replica-group fabric: one RDMA stack per backup.
    pub fabric: Fabric,
    strategy: Box<dyn Strategy>,
    kind: StrategyKind,
    repl: ReplicationConfig,
    /// Load latency from the primary image (ns).
    load_cost: Ns,
}

impl Mirror {
    /// Build a single-backup mirror with a fixed strategy (the paper's
    /// topology; no predictor needed).
    pub fn new(plat: Platform, kind: StrategyKind, ledger: bool) -> Self {
        assert!(
            kind != StrategyKind::SmAd,
            "use Mirror::with_predictor for SM-AD"
        );
        Self::try_build(plat, kind, None, ReplicationConfig::default(), ledger)
            .expect("fixed strategy + default replication cannot fail")
    }

    /// Build a single-backup mirror with the adaptive strategy wired to
    /// `predictor`.
    pub fn with_predictor(
        plat: Platform,
        kind: StrategyKind,
        predictor: Predictor,
        ledger: bool,
    ) -> Self {
        Self::try_build(
            plat,
            kind,
            Some(predictor),
            ReplicationConfig::default(),
            ledger,
        )
        .expect("strategy with predictor + default replication cannot fail")
    }

    /// Build a mirror driving an N-way replica group (for `SmAd`, use
    /// [`Mirror::try_build`] with a predictor — this errors without one).
    pub fn with_replication(
        plat: Platform,
        kind: StrategyKind,
        repl: ReplicationConfig,
        ledger: bool,
    ) -> Result<Self> {
        Self::try_build(plat, kind, None, repl, ledger)
    }

    /// Fully general fault-free constructor: any strategy, any
    /// replica-group shape. Fails on an invalid replication config or on
    /// `SmAd` without a predictor.
    pub fn try_build(
        plat: Platform,
        kind: StrategyKind,
        predictor: Option<Predictor>,
        repl: ReplicationConfig,
        ledger: bool,
    ) -> Result<Self> {
        Self::try_build_faulted(plat, kind, predictor, repl, FaultsConfig::default(), ledger)
    }

    /// Fully general constructor with runtime failure dynamics: the
    /// fabric consults `faults` on every post/fence (backup kills,
    /// catch-up rejoins, halt/degrade loss handling — see
    /// [`crate::net::faults`]). Fails on an invalid replication config,
    /// a fault plan that does not fit the group, or `SmAd` without a
    /// predictor.
    pub fn try_build_faulted(
        plat: Platform,
        kind: StrategyKind,
        predictor: Option<Predictor>,
        repl: ReplicationConfig,
        faults: FaultsConfig,
        ledger: bool,
    ) -> Result<Self> {
        repl.validate()?;
        faults.validate(repl.backups)?;
        if kind == StrategyKind::SmRc
            && faults
                .plan
                .events()
                .iter()
                .any(|e| e.kind == FaultKind::Rejoin)
        {
            // SM-RC replicates into volatile backup state (dirty DDIO
            // lines drained by rcommit); a killed backup loses that
            // state and no peer holds it durably, so a rejoin catch-up
            // cannot be faithful. Real deployments re-replicate from
            // the primary on failback — not modeled yet.
            bail!(
                "sm-rc cannot resync a rejoining backup (replicated-but-\
                 undrained lines are volatile); use a kill-only fault \
                 plan or sm-ob / sm-dd"
            );
        }
        let strategy = replication::make_strategy(kind, predictor)?;
        let fabric = Fabric::with_faults(&plat, &repl, faults, ledger);
        let local_mc = RateLimiter::new(plat.llc_mc);
        let local_mc_lat = plat.llc_mc;
        Ok(Mirror {
            plat,
            local_mc,
            local_mc_lat,
            image: FastMap::default(),
            fabric,
            strategy,
            kind,
            repl,
            load_cost: 5,
        })
    }

    pub fn kind(&self) -> StrategyKind {
        self.kind
    }

    /// The replica-group shape this mirror drives.
    pub fn replication(&self) -> &ReplicationConfig {
        &self.repl
    }

    /// Backup `i`'s remote engine (shorthand for `fabric.backup(i)`).
    pub fn backup(&self, i: usize) -> &RemoteEngine {
        self.fabric.backup(i)
    }

    /// Read a word from the primary PM image (0 when never written).
    pub fn load(&mut self, t: &mut ThreadCtx, addr: Addr) -> u64 {
        t.clock.busy(self.load_cost);
        self.image.get(&line_of(addr)).copied().unwrap_or(0)
    }

    /// Peek without advancing time (assertion/recovery helpers).
    pub fn peek(&self, addr: Addr) -> u64 {
        self.image.get(&line_of(addr)).copied().unwrap_or(0)
    }

    /// Store a word to a line of persistent memory (volatile until clwb'd).
    pub fn store(&mut self, t: &mut ThreadCtx, addr: Addr, val: u64) {
        t.clock.busy(self.plat.store);
        self.image.insert(line_of(addr), val);
    }

    /// Volatile compute — advances the thread without touching PM.
    pub fn compute(&mut self, t: &mut ThreadCtx, ns: Ns) {
        t.clock.busy(ns);
    }

    /// `clwb`: persist the line locally (eager write-back into the local
    /// MC queue) and replicate it per the active strategy.
    pub fn clwb(&mut self, t: &mut ThreadCtx, addr: Addr) {
        let line = line_of(addr);
        t.clock.busy(self.plat.flush);
        let persist = self.local_mc.submit(t.clock.now) + self.local_mc_lat;
        t.pending_local.push(persist);
        let meta = WriteMeta {
            addr: line,
            val: self.image.get(&line).copied().unwrap_or(0),
            thread: t.id() as u32,
            txn: t.txn,
            epoch: t.epoch,
            seq: t.seq,
        };
        t.seq += 1;
        t.writes_done += 1;
        self.strategy.on_clwb(&mut self.fabric, &mut t.clock, meta);
    }

    /// `sfence`: ordering point — wait for local persists, signal the
    /// strategy's ordering primitive, and open the next epoch.
    pub fn sfence(&mut self, t: &mut ThreadCtx) {
        t.clock.busy(self.plat.sfence);
        if let Some(&max) = t.pending_local.iter().max() {
            t.clock.wait_until(max);
        }
        t.pending_local.clear();
        self.strategy.on_ofence(&mut self.fabric, &mut t.clock);
        t.epoch += 1;
        t.epochs_done += 1;
    }

    /// Transaction begin: resets epoch numbering; passes the shape hint to
    /// adaptive strategies.
    pub fn txn_begin(&mut self, t: &mut ThreadCtx, hint: Option<TxnShape>) {
        t.epoch = 0;
        self.strategy
            .on_txn_begin(&mut self.fabric, &mut t.clock, hint);
    }

    /// Transaction end: durability point (local drain + strategy fence).
    /// Records both the ack-policy completion and the per-backup fence
    /// completions. A transaction whose durability fence stalled (fault
    /// injection under `on_loss = halt`, or a fully dead group) was
    /// never durably acked and is NOT counted as committed.
    pub fn txn_commit(&mut self, t: &mut ThreadCtx) {
        t.clock.busy(self.plat.sfence);
        if let Some(&max) = t.pending_local.iter().max() {
            t.clock.wait_until(max);
        }
        t.pending_local.clear();
        self.strategy.on_dfence(&mut self.fabric, &mut t.clock);
        if self.fabric.stall().is_some() {
            return;
        }
        t.last_dfence = t.clock.now;
        t.last_dfence_per_backup.clear();
        t.last_dfence_per_backup
            .extend_from_slice(self.fabric.last_fence());
        t.txn += 1;
        t.txns_done += 1;
    }

    /// The primary PM image (golden state for recovery comparison).
    pub fn image(&self) -> &FastMap<Addr, u64> {
        &self.image
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AckPolicy;
    use std::collections::HashMap;

    fn run_transact_txn(m: &mut Mirror, t: &mut ThreadCtx, epochs: u32, writes: u32) {
        m.txn_begin(t, None);
        for e in 0..epochs {
            for w in 0..writes {
                let addr = 0x1000 + ((e * writes + w) as u64) * 64;
                m.store(t, addr, 1);
                m.clwb(t, addr);
            }
            m.sfence(t);
        }
        m.txn_commit(t);
    }

    #[test]
    fn no_sm_txn_costs_local_only() {
        let mut m = Mirror::new(Platform::default(), StrategyKind::NoSm, false);
        let mut t = ThreadCtx::new(0);
        run_transact_txn(&mut m, &mut t, 4, 1);
        // 4 epochs x ~(store+flush+sfence+drain) + commit fence: well under
        // a single RTT.
        assert!(t.now() < 2600, "NO-SM txn took {}", t.now());
        assert_eq!(t.txns_done, 1);
        assert_eq!(t.writes_done, 4);
    }

    #[test]
    fn sm_strategies_rank_as_paper_for_4_1() {
        // Transact 4-1: RC should be ~3x+ worse than OB/DD (paper Fig. 4).
        let mut times = HashMap::new();
        for kind in [StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd] {
            let mut m = Mirror::new(Platform::default(), kind, false);
            let mut t = ThreadCtx::new(0);
            for _ in 0..20 {
                run_transact_txn(&mut m, &mut t, 4, 1);
            }
            times.insert(kind, t.now());
        }
        let rc = times[&StrategyKind::SmRc] as f64;
        let ob = times[&StrategyKind::SmOb] as f64;
        let dd = times[&StrategyKind::SmDd] as f64;
        assert!(rc / ob > 2.0, "rc/ob = {}", rc / ob);
        assert!(rc / dd > 2.0, "rc/dd = {}", rc / dd);
    }

    #[test]
    fn store_then_load_round_trips() {
        let mut m = Mirror::new(Platform::default(), StrategyKind::NoSm, false);
        let mut t = ThreadCtx::new(0);
        m.store(&mut t, 0x40, 77);
        assert_eq!(m.load(&mut t, 0x40), 77);
        assert_eq!(m.load(&mut t, 0x7f), 77, "same line");
        assert_eq!(m.load(&mut t, 0x80), 0, "next line untouched");
    }

    #[test]
    fn ledger_captures_replica_writes_with_coordinates() {
        let mut m = Mirror::new(Platform::default(), StrategyKind::SmDd, true);
        let mut t = ThreadCtx::new(3);
        run_transact_txn(&mut m, &mut t, 2, 2);
        let evs = m.backup(0).ledger.events();
        assert_eq!(evs.len(), 4);
        assert!(evs.iter().all(|e| e.thread == 3));
        assert_eq!(evs.iter().filter(|e| e.epoch == 0).count(), 2);
        assert_eq!(evs.iter().filter(|e| e.epoch == 1).count(), 2);
    }

    #[test]
    fn dfence_completion_covers_all_persists() {
        for kind in [StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd] {
            let mut m = Mirror::new(Platform::default(), kind, true);
            let mut t = ThreadCtx::new(0);
            run_transact_txn(&mut m, &mut t, 8, 2);
            let horizon = m.backup(0).persist_horizon();
            assert!(
                t.last_dfence >= horizon,
                "{kind:?}: dfence at {} < persist horizon {}",
                t.last_dfence,
                horizon
            );
            assert_eq!(m.backup(0).ledger.len(), 16, "{kind:?}");
        }
    }

    #[test]
    fn replica_group_mirrors_every_backup() {
        let repl = ReplicationConfig::new(3, AckPolicy::All);
        for kind in [StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd] {
            let mut m =
                Mirror::with_replication(Platform::default(), kind, repl, true).unwrap();
            let mut t = ThreadCtx::new(0);
            run_transact_txn(&mut m, &mut t, 4, 2);
            assert_eq!(m.fabric.backups(), 3);
            for b in 0..3 {
                assert_eq!(m.backup(b).ledger.len(), 8, "{kind:?} backup {b}");
            }
            // All policy: the dfence covers every backup's horizon, and
            // per-backup completions are recorded.
            assert_eq!(t.last_dfence_per_backup.len(), 3);
            for b in 0..3 {
                assert!(
                    t.last_dfence >= m.backup(b).persist_horizon(),
                    "{kind:?} backup {b}"
                );
            }
        }
    }

    #[test]
    fn quorum_dfence_may_lead_slowest_backup() {
        // With quorum:1 of 3, the fence completes at the fastest backup;
        // per-backup completion times expose the laggards.
        let repl = ReplicationConfig::new(3, AckPolicy::Quorum(1));
        let mut m =
            Mirror::with_replication(Platform::default(), StrategyKind::SmOb, repl, true)
                .unwrap();
        let mut t = ThreadCtx::new(0);
        for _ in 0..5 {
            run_transact_txn(&mut m, &mut t, 4, 1);
        }
        let fences = t.last_dfence_per_backup.clone();
        assert_eq!(fences.len(), 3);
        let fastest = *fences.iter().min().unwrap();
        let slowest = *fences.iter().max().unwrap();
        assert!(fastest <= slowest);
        // The policy-level dfence equals the fastest completion (+ poll).
        assert!(
            t.last_dfence >= fastest && t.last_dfence <= slowest + 1000,
            "dfence {} outside [{fastest}, {slowest}+poll]",
            t.last_dfence
        );
    }

    #[test]
    fn faulted_mirror_halts_or_degrades_on_backup_loss() {
        use crate::net::{FaultsConfig, OnLoss};
        let repl = ReplicationConfig::new(3, AckPolicy::All);
        let faults = |mode| FaultsConfig::with_plan("kill:1@0", mode).unwrap();
        // Halt: the first durability fence records a stall.
        let mut m = Mirror::try_build_faulted(
            Platform::default(),
            StrategyKind::SmOb,
            None,
            repl,
            faults(OnLoss::Halt),
            false,
        )
        .unwrap();
        let mut t = ThreadCtx::new(0);
        run_transact_txn(&mut m, &mut t, 2, 1);
        let stall = m.fabric.stall().expect("all + halt must stall");
        assert_eq!(stall.alive, 2);
        assert_eq!(stall.required, 3);
        // Degrade: the run completes on the survivors.
        let mut m = Mirror::try_build_faulted(
            Platform::default(),
            StrategyKind::SmOb,
            None,
            repl,
            faults(OnLoss::Degrade),
            true,
        )
        .unwrap();
        let mut t = ThreadCtx::new(0);
        run_transact_txn(&mut m, &mut t, 2, 1);
        assert!(m.fabric.stall().is_none());
        assert_eq!(t.txns_done, 1);
        assert_eq!(m.backup(0).ledger.len(), 2);
        assert_eq!(m.backup(2).ledger.len(), 2);
        assert_eq!(m.backup(1).ledger.len(), 0, "dead backup sees nothing");
    }

    #[test]
    fn sm_rc_rejoin_plans_rejected_but_kill_only_allowed() {
        use crate::net::{FaultsConfig, OnLoss};
        let repl = ReplicationConfig::new(3, AckPolicy::Quorum(2));
        // Rejoin catch-up is impossible for SM-RC's volatile pending.
        let rejoin = FaultsConfig::with_plan("kill:1@100,rejoin:1@200", OnLoss::Degrade)
            .unwrap();
        assert!(Mirror::try_build_faulted(
            Platform::default(),
            StrategyKind::SmRc,
            None,
            repl,
            rejoin.clone(),
            false,
        )
        .is_err());
        // Kill-only plans are fine for SM-RC; rejoin plans are fine for
        // the write-through strategies.
        let kill_only = FaultsConfig::with_plan("kill:1@100", OnLoss::Degrade).unwrap();
        Mirror::try_build_faulted(
            Platform::default(),
            StrategyKind::SmRc,
            None,
            repl,
            kill_only,
            false,
        )
        .unwrap();
        Mirror::try_build_faulted(
            Platform::default(),
            StrategyKind::SmOb,
            None,
            repl,
            rejoin,
            false,
        )
        .unwrap();
    }

    #[test]
    fn stalled_commit_is_not_counted() {
        use crate::net::{FaultsConfig, OnLoss};
        let repl = ReplicationConfig::new(2, AckPolicy::All);
        let faults = FaultsConfig::with_plan("kill:0@0", OnLoss::Halt).unwrap();
        let mut m = Mirror::try_build_faulted(
            Platform::default(),
            StrategyKind::SmOb,
            None,
            repl,
            faults,
            false,
        )
        .unwrap();
        let mut t = ThreadCtx::new(0);
        run_transact_txn(&mut m, &mut t, 2, 1);
        assert!(m.fabric.stall().is_some());
        assert_eq!(t.txns_done, 0, "a stalled fence is not a commit");
        assert_eq!(t.last_dfence, 0, "no durability instant was reached");
    }

    #[test]
    fn fault_plan_outside_group_rejected_at_build() {
        use crate::net::{FaultsConfig, OnLoss};
        let faults = FaultsConfig::with_plan("kill:5@100", OnLoss::Halt).unwrap();
        assert!(Mirror::try_build_faulted(
            Platform::default(),
            StrategyKind::SmOb,
            None,
            ReplicationConfig::new(3, AckPolicy::All),
            faults,
            false,
        )
        .is_err());
    }

    #[test]
    fn invalid_replication_rejected_at_build() {
        let repl = ReplicationConfig::new(2, AckPolicy::Quorum(5));
        assert!(Mirror::with_replication(
            Platform::default(),
            StrategyKind::SmOb,
            repl,
            false
        )
        .is_err());
    }
}
