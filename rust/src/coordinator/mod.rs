//! The mirroring coordinator: binds a primary node's persistency-model
//! traffic to one or more replica groups of backups over the simulated
//! RDMA fabric (paper Fig. 2, generalized from one backup to N — and
//! from one group to `S` address-space [`shard`]s).
//!
//! [`Mirror`] exposes the persistency-model API the paper assumes
//! (Intel-style `store`/`clwb`/`sfence` plus an explicit durability fence
//! at transaction end); every `clwb` simultaneously (1) persists the line
//! locally through the primary's memory controller and (2) hands the dirty
//! line to the owning shard's replication
//! [`Strategy`](crate::replication::Strategy) for remote replication
//! across that shard's [`Fabric`]. The [`ShardMap`] routes each line to
//! exactly one shard; with `shards = 1` (the default) the router is a
//! pass-through and the coordinator is event-for-event identical to the
//! pre-sharding single-fabric path (pinned by `rust/tests/sharding.rs`).
//!
//! **Cross-shard fence semantics.** Each shard's fabric completes its
//! fences independently, per its own ack policy. A thread's ordering
//! fence (`sfence`) reaches every shard it wrote since the previous
//! fence; its durability fence (transaction commit) reaches every shard
//! the transaction touched, and the transaction's commit instant is the
//! **max** across those shards' fence completions — the fences are
//! issued concurrently (each shard has its own QPs and wire; nothing is
//! shared between shards), and the thread blocks until the last one
//! completes. Per-backup fence completions are tracked shard-major on
//! the [`ThreadCtx`] for lag analysis. Note the atomicity caveat:
//! remote-persistence *ordering* is per-fabric, so an in-flight
//! transaction whose undo log and data straddle shards can be torn by
//! a crash — only durably acked transactions are guaranteed whole
//! across shards (DESIGN.md §Sharding). Multi-threaded workloads are
//! executed by the conservative min-clock scheduler in [`sched`].

pub mod pipeline;
pub mod sched;
pub mod shard;

pub use pipeline::{ConcurrencyConfig, MAX_PIPELINES};
pub use shard::{ShardMap, ShardMapSpec, ShardingConfig};

use crate::config::{AdaptiveConfig, Platform, ReplicationConfig, StrategyKind};
use crate::mem::DurabilityLog;
use crate::metrics::LogHistogram;
use crate::net::{
    elect, BatchingConfig, Candidate, CoalesceMode, CoalescingConfig, Fabric, FaultKind,
    FaultTimeline, FaultsConfig, FlushPolicy, LinkConfig, PersistDomain, RemoteEngine,
    Stall, WriteMeta,
};
use crate::replication::{
    self, ControlPlane, DecisionStats, KnobPredictor, Predictor, SmAd, Strategy, TxnShape,
};
use crate::sim::{RateLimiter, ThreadClock};
use crate::util::FastMap;
use crate::{line_of, Addr, Ns};
use anyhow::{bail, Result};
use std::rc::Rc;

/// Per-thread execution context: virtual clock + transactional counters.
#[derive(Debug)]
pub struct ThreadCtx {
    pub clock: ThreadClock,
    /// Local persist completions awaiting the next sfence.
    pending_local: Vec<Ns>,
    /// Transaction / epoch / write-sequence coordinates.
    pub txn: u64,
    pub epoch: u32,
    pub seq: u64,
    /// Completed transactions and their total writes (stats).
    pub txns_done: u64,
    pub writes_done: u64,
    pub epochs_done: u64,
    /// Completion time of the last durability fence (ack-policy level,
    /// max across the shards the transaction touched).
    pub last_dfence: Ns,
    /// Per-backup completion instants of the last durability fence,
    /// flattened shard-major (index = `shard * backups + backup`; all
    /// zeros under NO-SM; shards untouched by the transaction keep
    /// their previous fence instants).
    pub last_dfence_per_backup: Vec<Ns>,
    /// Shards written since the last ordering fence (bitmask).
    touched_epoch: u64,
    /// Shards written since the transaction began (bitmask).
    touched_txn: u64,
    /// Virtual time at which stats were last reset (steady-state marker).
    pub stats_zero_at: Ns,
    /// Busy-time watermark at the last stats reset (steady-state CPU
    /// cost is `clock.busy_ns - busy_zero`).
    pub busy_zero: Ns,
    /// Instant the current transaction began (commit-latency feedback
    /// for the adaptive control plane).
    txn_begin_at: Ns,
    /// The (shard-scaled) shape hint of the current transaction, echoed
    /// back to the strategies at commit so feedback lands on the same
    /// class the decision was made for.
    txn_hint: Option<TxnShape>,
}

impl ThreadCtx {
    pub fn new(id: usize) -> Self {
        ThreadCtx {
            clock: ThreadClock::new(id),
            pending_local: Vec::with_capacity(16),
            txn: 0,
            epoch: 0,
            seq: 0,
            txns_done: 0,
            writes_done: 0,
            epochs_done: 0,
            last_dfence: 0,
            last_dfence_per_backup: Vec::new(),
            touched_epoch: 0,
            touched_txn: 0,
            stats_zero_at: 0,
            busy_zero: 0,
            txn_begin_at: 0,
            txn_hint: None,
        }
    }

    /// Drop warm-up/load-phase counters: measurement starts now.
    pub fn reset_stats(&mut self) {
        self.txns_done = 0;
        self.writes_done = 0;
        self.epochs_done = 0;
        self.stats_zero_at = self.clock.now;
        self.busy_zero = self.clock.busy_ns;
    }

    pub fn id(&self) -> usize {
        self.clock.id
    }
    pub fn now(&self) -> Ns {
        self.clock.now
    }
}

/// One shard of the replication pipeline: an independent replica-group
/// fabric plus its own (shard-local) strategy instance.
struct ShardLane {
    fabric: Fabric,
    strategy: Box<dyn Strategy>,
}

/// The primary node + replication pipeline.
pub struct Mirror {
    pub plat: Platform,
    /// Primary's memory-controller ingress (local persistence path):
    /// time-indexed so multi-threaded clwb streams don't false-serialize
    /// (see sim::rate). Admission to the MC queue == persistence (ADR).
    local_mc: RateLimiter,
    local_mc_lat: Ns,
    /// Primary PM contents (line address -> word value).
    image: FastMap<Addr, u64>,
    /// One lane per shard: shard `s` owns the lines `map` routes to it.
    lanes: Vec<ShardLane>,
    map: ShardMap,
    kind: StrategyKind,
    repl: ReplicationConfig,
    sharding: ShardingConfig,
    /// Concurrent-primary shape (commit pipelines + group-fence window;
    /// the default is the serial anchor — see [`pipeline`]).
    conc: ConcurrencyConfig,
    /// Per-shard, per-pipeline free-at instants (`pipes[shard][p]`):
    /// a committing thread is admitted to pipeline `id % P` of each
    /// touched shard and waits until it frees (wait time only — never
    /// CPU busy time).
    pipes: Vec<Vec<Ns>>,
    /// Commits that found their pipeline occupied.
    pipe_waits: u64,
    /// Total virtual time commits spent waiting for a pipeline slot.
    pipe_wait_ns: Ns,
    /// Total virtual time pipelines spent occupied by commit fences
    /// (the occupancy numerator).
    pipe_busy_ns: Ns,
    /// The fault plan schedules primary kills/rejoins — gates the
    /// membership poll on the hot paths (false = guard-clause
    /// pass-through, event-for-event the pre-failover coordinator).
    primary_faults: bool,
    /// Lossy-link shape every shard's fabric runs under (disabled by
    /// default — the perfectly-reliable-wire anchor; see
    /// [`crate::net::link`]).
    link: LinkConfig,
    /// Online adaptive control-plane shape (disabled by default — the
    /// static SM-AD anchor; see [`crate::replication::adaptive`]).
    adaptive: AdaptiveConfig,
    /// Load latency from the primary image (ns).
    load_cost: Ns,
}

impl Mirror {
    /// Build a single-backup mirror with a fixed strategy (the paper's
    /// topology; no predictor needed).
    pub fn new(plat: Platform, kind: StrategyKind, ledger: bool) -> Self {
        assert!(
            kind != StrategyKind::SmAd,
            "use Mirror::with_predictor for SM-AD"
        );
        Self::try_build(plat, kind, None, ReplicationConfig::default(), ledger)
            .expect("fixed strategy + default replication cannot fail")
    }

    /// Build a single-backup mirror with the adaptive strategy wired to
    /// `predictor`.
    pub fn with_predictor(
        plat: Platform,
        kind: StrategyKind,
        predictor: Predictor,
        ledger: bool,
    ) -> Self {
        Self::try_build(
            plat,
            kind,
            Some(predictor),
            ReplicationConfig::default(),
            ledger,
        )
        .expect("strategy with predictor + default replication cannot fail")
    }

    /// Build a mirror driving an N-way replica group (for `SmAd`, use
    /// [`Mirror::try_build`] with a predictor — this errors without one).
    pub fn with_replication(
        plat: Platform,
        kind: StrategyKind,
        repl: ReplicationConfig,
        ledger: bool,
    ) -> Result<Self> {
        Self::try_build(plat, kind, None, repl, ledger)
    }

    /// Fully general fault-free, unsharded constructor: any strategy,
    /// any replica-group shape. Fails on an invalid replication config
    /// or on `SmAd` without a predictor.
    pub fn try_build(
        plat: Platform,
        kind: StrategyKind,
        predictor: Option<Predictor>,
        repl: ReplicationConfig,
        ledger: bool,
    ) -> Result<Self> {
        Self::try_build_faulted(plat, kind, predictor, repl, FaultsConfig::default(), ledger)
    }

    /// General unsharded constructor with runtime failure dynamics: the
    /// fabric consults `faults` on every post/fence (backup kills,
    /// catch-up rejoins, halt/degrade loss handling — see
    /// [`crate::net::faults`]). Fails on an invalid replication config,
    /// a fault plan that does not fit the group, or `SmAd` without a
    /// predictor.
    pub fn try_build_faulted(
        plat: Platform,
        kind: StrategyKind,
        predictor: Option<Predictor>,
        repl: ReplicationConfig,
        faults: FaultsConfig,
        ledger: bool,
    ) -> Result<Self> {
        Self::try_build_sharded(
            plat,
            kind,
            predictor,
            repl,
            faults,
            ShardingConfig::default(),
            ledger,
        )
    }

    /// The fully general constructor: `sharding.shards` independent
    /// replica groups, each with its own fabric (backups, ack policy,
    /// durability ledgers) and its own shard-local strategy instance.
    /// The `repl` shape and `faults` plan apply to **every** shard: a
    /// `kill:B@T` event models the loss of backup *node* B, which hosts
    /// replica B of every shard, so all shards lose that backup at once.
    /// Fails on an invalid replication/faults/sharding config or on
    /// `SmAd` without a predictor.
    #[allow(clippy::too_many_arguments)]
    pub fn try_build_sharded(
        plat: Platform,
        kind: StrategyKind,
        predictor: Option<Predictor>,
        repl: ReplicationConfig,
        faults: FaultsConfig,
        sharding: ShardingConfig,
        ledger: bool,
    ) -> Result<Self> {
        Self::build_full(
            plat,
            kind,
            predictor,
            repl,
            faults,
            sharding,
            LinkConfig::default(),
            ledger,
            AdaptiveConfig::default(),
            None,
        )
    }

    /// The real constructor behind [`Mirror::try_build_sharded`] and
    /// [`MirrorBuilder::build`]: additionally wires the SM-AD online
    /// control plane when `[adaptive]` is enabled. `knob_predictor` is
    /// the knob-aware model (AOT or fallback); `None` with adaptive
    /// enabled uses [`crate::runtime::fallback_knob_predictor`]. With
    /// adaptive disabled (the default) both extra arguments are inert
    /// and the constructor is event-for-event the pre-adaptive path.
    #[allow(clippy::too_many_arguments)]
    fn build_full(
        plat: Platform,
        kind: StrategyKind,
        predictor: Option<Predictor>,
        repl: ReplicationConfig,
        faults: FaultsConfig,
        sharding: ShardingConfig,
        link: LinkConfig,
        ledger: bool,
        adaptive: AdaptiveConfig,
        knob_predictor: Option<KnobPredictor>,
    ) -> Result<Self> {
        repl.validate()?;
        faults.validate(repl.backups)?;
        sharding.validate()?;
        link.validate(repl.backups)?;
        if kind == StrategyKind::SmRc
            && (faults
                .plan
                .events()
                .iter()
                .any(|e| e.kind == FaultKind::Rejoin)
                || faults
                    .plan
                    .primary_events()
                    .iter()
                    .any(|e| e.kind == FaultKind::Rejoin))
        {
            // SM-RC replicates into volatile backup state (dirty DDIO
            // lines drained by rcommit); a killed backup loses that
            // state and no peer holds it durably, so a rejoin catch-up
            // cannot be faithful. Real deployments re-replicate from
            // the primary on failback — not modeled yet.
            bail!(
                "sm-rc cannot resync a rejoining backup (replicated-but-\
                 undrained lines are volatile); use a kill-only fault \
                 plan or sm-ob / sm-dd"
            );
        }
        adaptive.validate()?;
        // The predictor is a boxed closure; with several shards it is
        // shared behind an Rc so every shard-local SmAd instance
        // consults the same model. The knob-aware model of the adaptive
        // control plane is shared the same way.
        let mut predictor = predictor;
        let shared: Option<Rc<dyn Fn(f32, f32) -> (f32, f32)>> =
            if kind == StrategyKind::SmAd && sharding.shards > 1 {
                predictor.take().map(Rc::from)
            } else {
                None
            };
        let wire_control = kind == StrategyKind::SmAd && adaptive.enabled;
        let mut knob_predictor = knob_predictor;
        let shared_knob: Option<Rc<dyn Fn(f32, f32, f32, f32, f32) -> (f32, f32)>> =
            if wire_control && sharding.shards > 1 {
                knob_predictor.take().map(Rc::from)
            } else {
                None
            };
        let mut lanes = Vec::with_capacity(sharding.shards);
        for s in 0..sharding.shards {
            let pred: Option<Predictor> = match &shared {
                Some(rc) => {
                    let rc = Rc::clone(rc);
                    Some(Box::new(move |e: f32, w: f32| (*rc)(e, w)))
                }
                None => predictor.take(),
            };
            let strategy: Box<dyn Strategy> = if wire_control {
                let Some(legacy) = pred else {
                    bail!("SmAd requires a predictor; see runtime::model");
                };
                let model: KnobPredictor = match &shared_knob {
                    Some(rc) => {
                        let rc = Rc::clone(rc);
                        Box::new(move |e, w, b, k, c| (*rc)(e, w, b, k, c))
                    }
                    None => knob_predictor
                        .take()
                        .unwrap_or_else(|| crate::runtime::fallback_knob_predictor(&plat)),
                };
                Box::new(SmAd::with_control(
                    legacy,
                    ControlPlane::new(adaptive, model, repl.backups, repl.required()),
                ))
            } else {
                replication::make_strategy(kind, pred)?
            };
            // `with_shard` before `with_link`: the shard id salts the
            // link's per-backup hash streams, so shards flip
            // independent loss coins under one seed.
            let mut fabric = Fabric::with_faults(&plat, &repl, faults.clone(), ledger)
                .with_shard(s)
                .with_link(&link);
            // Primary events are coordinator business: all S shards must
            // fail over to ONE cross-shard winner, so each lane's fabric
            // treats them as barriers and the mirror consumes them in
            // `poll_membership`.
            fabric.set_coordinated(true);
            lanes.push(ShardLane { fabric, strategy });
        }
        let primary_faults = faults.plan.has_primary_faults();
        let local_mc = RateLimiter::new(plat.llc_mc);
        let local_mc_lat = plat.llc_mc;
        let shards = sharding.shards;
        Ok(Mirror {
            plat,
            local_mc,
            local_mc_lat,
            image: FastMap::default(),
            lanes,
            map: sharding.build_map(),
            kind,
            repl,
            sharding,
            conc: ConcurrencyConfig::default(),
            pipes: vec![vec![0; 1]; shards],
            pipe_waits: 0,
            pipe_wait_ns: 0,
            pipe_busy_ns: 0,
            primary_faults,
            link,
            adaptive,
            load_cost: 5,
        })
    }

    /// Consume primary plan events due by `now` (see
    /// [`crate::net::membership`]): backup events and resyncs settle
    /// first, then a kill elects ONE winner across all shards — each
    /// candidate node campaigns with the *sum* of its per-shard certified
    /// prefixes and must be in quorum on every shard — and every lane
    /// fails over to it; a rejoin returns the deposed primary on every
    /// lane. The node admits writes only when its slowest shard finishes
    /// re-replicating. A no-op without primary faults in the plan — the
    /// guard-clause anchor pinned by `rust/tests/failover_primary.rs`.
    fn poll_membership(&mut self, now: Ns) {
        if !self.primary_faults {
            return;
        }
        while let Some((at, kind)) = self.lanes[0].fabric.pending_primary_event(now) {
            for lane in &mut self.lanes {
                lane.fabric.settle(at);
            }
            match kind {
                FaultKind::Kill => {
                    let field: Vec<Candidate> = (0..self.repl.backups)
                        .filter(|&i| {
                            self.lanes.iter().all(|l| l.fabric.state(i).is_alive())
                        })
                        .map(|i| Candidate {
                            id: i,
                            certified: self
                                .lanes
                                .iter()
                                .map(|l| l.fabric.certified_prefix(i))
                                .sum(),
                        })
                        .collect();
                    let winner = elect(&field);
                    for lane in &mut self.lanes {
                        lane.fabric.failover_to(winner, at);
                    }
                    let admit = self
                        .lanes
                        .iter()
                        .map(|l| l.fabric.admit_at())
                        .max()
                        .unwrap_or(0);
                    for lane in &mut self.lanes {
                        lane.fabric.hold_admission(admit);
                    }
                }
                FaultKind::Rejoin => {
                    for lane in &mut self.lanes {
                        lane.fabric.primary_rejoin_at(at);
                    }
                }
            }
        }
    }

    pub fn kind(&self) -> StrategyKind {
        self.kind
    }

    /// The replica-group shape every shard drives.
    pub fn replication(&self) -> &ReplicationConfig {
        &self.repl
    }

    /// The sharding shape this mirror routes over.
    pub fn sharding(&self) -> &ShardingConfig {
        &self.sharding
    }

    /// The address-to-shard routing function.
    pub fn shard_map(&self) -> &ShardMap {
        &self.map
    }

    /// Number of independent shards (1 = sharding off).
    pub fn shard_count(&self) -> usize {
        self.lanes.len()
    }

    /// Set the staged WQE pipeline's flush policy on every shard's
    /// fabric (see [`crate::net::wqe`]; `cap:1` normalizes to `eager`,
    /// the anchor). Call before any traffic. Cap accounting is per
    /// (shard, thread) stage — a line counts toward the cap of the
    /// shard that owns it.
    pub fn set_batching(&mut self, policy: FlushPolicy) {
        for lane in &mut self.lanes {
            lane.fabric.set_batching(policy);
        }
    }

    /// The flush policy the shards' staged pipelines run under.
    pub fn batching(&self) -> FlushPolicy {
        self.lanes[0].fabric.batching()
    }

    /// Set the flush-time coalescing mode (write combining /
    /// scatter-gather — see [`crate::net::wqe`]) on every shard's
    /// fabric. Call before any traffic; pair with a staged flush
    /// policy ([`Mirror::set_batching`]) — the config layer rejects
    /// coalescing under eager posting.
    pub fn set_coalescing(&mut self, mode: CoalesceMode) {
        for lane in &mut self.lanes {
            lane.fabric.set_coalescing(mode);
        }
    }

    /// The coalescing mode flushed chains run through.
    pub fn coalescing(&self) -> CoalesceMode {
        self.lanes[0].fabric.coalescing()
    }

    /// Set the concurrent-primary shape: `commit_pipelines` per shard
    /// and the cross-thread group-fence window (pushed to every shard's
    /// fabric). Call before any traffic, like [`Mirror::set_batching`].
    /// The default shape (`1`, `0`) keeps the serial commit path
    /// structurally untouched (pinned by `rust/tests/concurrency.rs`).
    pub fn set_concurrency(&mut self, conc: ConcurrencyConfig) {
        conc.validate()
            .expect("ConcurrencyConfig must be validated before set_concurrency");
        self.conc = conc;
        for lane in &mut self.lanes {
            lane.fabric.set_group_fence(conc.group_fence_ns);
        }
        self.pipes = vec![vec![0; conc.commit_pipelines]; self.lanes.len()];
    }

    /// The concurrent-primary shape this mirror commits under.
    pub fn concurrency(&self) -> ConcurrencyConfig {
        self.conc
    }

    /// The adaptive control-plane shape this mirror runs under
    /// (disabled by default).
    pub fn adaptive(&self) -> AdaptiveConfig {
        self.adaptive
    }

    /// Controller decision/feedback counters aggregated across shards
    /// (all zeros for fixed strategies and for SM-AD with the control
    /// plane off, except SM-AD's mode-dwell counts).
    pub fn decision_stats(&self) -> DecisionStats {
        let mut d = DecisionStats::default();
        for lane in &self.lanes {
            d.add(&lane.strategy.decision_stats());
        }
        d
    }

    /// Blocking fences that issued their own verb, across all shards.
    pub fn fences_issued(&self) -> u64 {
        self.lanes.iter().map(|l| l.fabric.fences_issued).sum()
    }

    /// Blocking fences that piggybacked on another in-flight fence,
    /// across all shards (0 unless a group-fence window is set).
    pub fn fence_piggybacks(&self) -> u64 {
        self.lanes.iter().map(|l| l.fabric.fence_piggybacks).sum()
    }

    /// Commits that found their pipeline slot occupied.
    pub fn pipeline_waits(&self) -> u64 {
        self.pipe_waits
    }

    /// Total virtual time commits spent queued for a pipeline slot.
    pub fn pipeline_wait_ns(&self) -> Ns {
        self.pipe_wait_ns
    }

    /// Total virtual time pipelines were occupied by commit fences.
    pub fn pipeline_busy_ns(&self) -> Ns {
        self.pipe_busy_ns
    }

    /// Data-path doorbells rung across all shards and backups.
    pub fn doorbells(&self) -> u64 {
        self.lanes.iter().map(|l| l.fabric.doorbells_total()).sum()
    }

    /// Data *lines* posted across all shards and backups (the doorbell
    /// amortization denominator: `doorbells() <= posted_wqes()`).
    pub fn posted_wqes(&self) -> u64 {
        self.lanes.iter().map(|l| l.fabric.posted_writes()).sum()
    }

    /// Data WQEs launched on the wire across all shards and backups (a
    /// coalesced span counts once): `doorbells() <= wire_wqes() <=
    /// posted_wqes()`.
    pub fn wire_wqes(&self) -> u64 {
        self.lanes.iter().map(|l| l.fabric.wire_wqes_total()).sum()
    }

    /// Line writes elided by write combining across all shards and
    /// backups.
    pub fn combined_writes(&self) -> u64 {
        self.lanes.iter().map(|l| l.fabric.combined_writes).sum()
    }

    /// The remote persistence domain every backup engine runs under
    /// (uniform across shards — it comes from one [`Platform`]).
    pub fn persist_domain(&self) -> PersistDomain {
        self.plat.persist_domain
    }

    /// Explicit flush verbs emitted by the fence path across all shards
    /// and backups (0 outside [`PersistDomain::RpmemFlush`]; bounded by
    /// [`Mirror::doorbells`] — a counted flush always trails staged
    /// data).
    pub fn flush_verbs(&self) -> u64 {
        self.lanes.iter().map(|l| l.fabric.flush_verbs_total()).sum()
    }

    /// Lines rewritten into the log and later compacted, across all
    /// shards and backups (0 outside [`PersistDomain::LogStructured`]).
    pub fn compaction_lines(&self) -> u64 {
        self.lanes
            .iter()
            .map(|l| l.fabric.compaction_lines_total())
            .sum()
    }

    /// Accumulated completion-to-persistence exposure across all shards
    /// and backups (ns·line): how long acknowledged writes sat volatile
    /// before their persist instant.
    pub fn volatile_window_ns(&self) -> u64 {
        self.lanes
            .iter()
            .map(|l| l.fabric.volatile_window_ns_total())
            .sum()
    }

    /// The lossy-link shape every shard runs under (disabled by
    /// default).
    pub fn link(&self) -> &LinkConfig {
        &self.link
    }

    /// Wire re-sends across all shards and backups, any cause (0 on a
    /// reliable wire; always `>= transport_timeouts()`).
    pub fn retransmits(&self) -> u64 {
        self.lanes.iter().map(|l| l.fabric.retransmits_total()).sum()
    }

    /// ACK-timeout expiries across all shards and backups.
    pub fn transport_timeouts(&self) -> u64 {
        self.lanes.iter().map(|l| l.fabric.timeouts_total()).sum()
    }

    /// RNR NAKs taken at saturated backups across all shards.
    pub fn rnr_naks(&self) -> u64 {
        self.lanes.iter().map(|l| l.fabric.rnr_naks_total()).sum()
    }

    /// QP error-state transitions healed via transient kill + rejoin,
    /// across all shards and backups.
    pub fn qp_resets(&self) -> u64 {
        self.lanes.iter().map(|l| l.fabric.qp_resets_total()).sum()
    }

    /// Total timeout/backoff ns the transport spent masking lossy
    /// links, across all shards and backups.
    pub fn backoff_ns(&self) -> Ns {
        self.lanes.iter().map(|l| l.fabric.backoff_ns_total()).sum()
    }

    /// Duplicate line deliveries injected (dup events and spurious
    /// retransmits) across all shards and backups.
    pub fn dups_injected(&self) -> u64 {
        self.lanes
            .iter()
            .map(|l| l.fabric.dups_injected_total())
            .sum()
    }

    /// Duplicate line deliveries dropped by the remote PSN dedup across
    /// all shards and backups (`<= retransmits() + dups_injected()`).
    pub fn dup_drops(&self) -> u64 {
        self.lanes.iter().map(|l| l.fabric.dup_drops_total()).sum()
    }

    /// Completed membership-epoch changes. All shards fail over together,
    /// so this is the max (= every lane's count), not a sum.
    pub fn membership_epochs(&self) -> u64 {
        self.lanes
            .iter()
            .map(|l| l.fabric.membership_epochs)
            .max()
            .unwrap_or(0)
    }

    /// Write-admission downtime across failovers. The node admits when
    /// its slowest shard does ([`Fabric::hold_admission`] syncs the
    /// lanes), so this is the max over lanes, not a sum.
    pub fn failover_downtime_ns(&self) -> Ns {
        self.lanes
            .iter()
            .map(|l| l.fabric.failover_downtime_ns)
            .max()
            .unwrap_or(0)
    }

    /// Certified-suffix lines re-replicated by elected primaries, summed
    /// across shards.
    pub fn rereplicated_lines(&self) -> u64 {
        self.lanes.iter().map(|l| l.fabric.rereplicated_lines).sum()
    }

    /// Staged WQEs fenced by permission revocation at failovers, summed
    /// across shards.
    pub fn revoked_wqes(&self) -> u64 {
        self.lanes.iter().map(|l| l.fabric.revoked_wqes).sum()
    }

    /// Lines-per-WQE distribution merged across every shard and backup.
    pub fn span_hist(&self) -> LogHistogram {
        let mut h = LogHistogram::new();
        for lane in &self.lanes {
            h.merge(&lane.fabric.span_hist());
        }
        h
    }

    /// Shard 0's fabric — *the* fabric when sharding is off (the common
    /// case for the paper's experiments and the regression anchor).
    pub fn fabric(&self) -> &Fabric {
        &self.lanes[0].fabric
    }

    /// Shard `s`'s replica-group fabric.
    pub fn shard_fabric(&self, s: usize) -> &Fabric {
        &self.lanes[s].fabric
    }

    /// Backup `i`'s remote engine on shard 0 (shorthand for
    /// `fabric().backup(i)`).
    pub fn backup(&self, i: usize) -> &RemoteEngine {
        self.lanes[0].fabric.backup(i)
    }

    /// The earliest unsatisfiable durability fence across all shards,
    /// if any — the run stops there (see [`Fabric::stall`]).
    pub fn stall(&self) -> Option<&Stall> {
        self.lanes
            .iter()
            .filter_map(|l| l.fabric.stall())
            .min_by_key(|s| s.at)
    }

    /// Advance every shard's fault state to `now` without issuing any
    /// verb (end-of-run bookkeeping before metrics/recovery). Pending
    /// primary events due by `now` are consumed first so the realized
    /// epoch log is complete.
    pub fn settle(&mut self, now: Ns) {
        self.poll_membership(now);
        for lane in &mut self.lanes {
            lane.fabric.settle(now);
        }
    }

    /// Per-shard backup ledgers: `[shard][backup]`, for the sharded
    /// recovery checks.
    pub fn shard_ledgers(&self) -> Vec<Vec<&DurabilityLog>> {
        self.lanes.iter().map(|l| l.fabric.ledgers()).collect()
    }

    /// Per-shard realized fault timelines (call [`Mirror::settle`]
    /// first so late events/resyncs have taken effect).
    pub fn timelines(&self) -> Vec<FaultTimeline> {
        self.lanes.iter().map(|l| l.fabric.timeline()).collect()
    }

    /// Per-backup persist horizons, flattened shard-major
    /// (index = `shard * backups + backup`).
    pub fn persist_horizons(&self) -> Vec<Ns> {
        self.lanes
            .iter()
            .flat_map(|l| l.fabric.persist_horizons())
            .collect()
    }

    /// Per-backup out-of-quorum time as of `now`, flattened shard-major.
    pub fn accrued_dead_ns(&self, now: Ns) -> Vec<Ns> {
        self.lanes
            .iter()
            .flat_map(|l| l.fabric.accrued_dead_ns(now))
            .collect()
    }

    /// Per-backup catch-up resync volume (lines), flattened shard-major.
    pub fn resync_lines(&self) -> Vec<u64> {
        self.lanes
            .iter()
            .flat_map(|l| l.fabric.backup_stats().into_iter().map(|s| s.resync_lines))
            .collect()
    }

    /// Read a word from the primary PM image (0 when never written).
    pub fn load(&mut self, t: &mut ThreadCtx, addr: Addr) -> u64 {
        t.clock.busy(self.load_cost);
        self.image.get(&line_of(addr)).copied().unwrap_or(0)
    }

    /// Peek without advancing time (assertion/recovery helpers).
    pub fn peek(&self, addr: Addr) -> u64 {
        self.image.get(&line_of(addr)).copied().unwrap_or(0)
    }

    /// Store a word to a line of persistent memory (volatile until clwb'd).
    pub fn store(&mut self, t: &mut ThreadCtx, addr: Addr, val: u64) {
        t.clock.busy(self.plat.store);
        self.image.insert(line_of(addr), val);
    }

    /// Volatile compute — advances the thread without touching PM.
    pub fn compute(&mut self, t: &mut ThreadCtx, ns: Ns) {
        t.clock.busy(ns);
    }

    /// `clwb`: persist the line locally (eager write-back into the local
    /// MC queue) and replicate it per the owning shard's strategy.
    pub fn clwb(&mut self, t: &mut ThreadCtx, addr: Addr) {
        self.poll_membership(t.clock.now);
        let line = line_of(addr);
        t.clock.busy(self.plat.flush);
        let persist = self.local_mc.submit(t.clock.now) + self.local_mc_lat;
        t.pending_local.push(persist);
        let meta = WriteMeta {
            addr: line,
            val: self.image.get(&line).copied().unwrap_or(0),
            thread: t.id() as u32,
            txn: t.txn,
            epoch: t.epoch,
            seq: t.seq,
        };
        t.seq += 1;
        t.writes_done += 1;
        let s = self.map.shard_of(line);
        t.touched_epoch |= 1 << s;
        t.touched_txn |= 1 << s;
        let lane = &mut self.lanes[s];
        lane.strategy.on_clwb(&mut lane.fabric, &mut t.clock, meta);
    }

    /// Issue a fence on every shard in `mask` with cross-shard
    /// concurrency: the shards share no simulated resources (each has
    /// its own QPs, wire, and backups), so each shard's fence is run
    /// from the same start instant and the thread lands on the **max**
    /// completion. Rewinding the clock between shards is safe: this
    /// thread's prior verbs on each shard were issued at times <=
    /// `start`, and the sim's resources serialize in submission order
    /// while tolerating out-of-order arrival instants — the same
    /// bounded-error discipline the min-clock scheduler relies on
    /// ([`sched`]). With one shard in the mask this degenerates to the
    /// plain single-fabric call.
    fn fan_fence(
        &mut self,
        t: &mut ThreadCtx,
        mask: u64,
        issue: fn(&mut dyn Strategy, &mut Fabric, &mut ThreadClock),
    ) {
        let start = t.clock.now;
        let mut done = start;
        for (s, lane) in self.lanes.iter_mut().enumerate() {
            if mask & (1 << s) == 0 {
                continue;
            }
            t.clock.now = start;
            issue(lane.strategy.as_mut(), &mut lane.fabric, &mut t.clock);
            done = done.max(t.clock.now);
        }
        t.clock.now = done;
    }

    /// Durability-fence fan-out through the per-shard commit pipelines
    /// (the concurrent-primary model, active when
    /// [`ConcurrencyConfig::enabled`]). Identical to [`Mirror::fan_fence`]
    /// except each touched shard admits the commit to pipeline
    /// `thread % P` first: if that pipeline is still occupied by an
    /// earlier commit, the thread *waits* (virtual time only — a queued
    /// commit burns no CPU, so pipeline contention never inflates
    /// `busy_ns`). `P = 1` models the serial primary — every commit on
    /// a shard funnels through one pipeline; raising `P` is the tentpole
    /// scaling axis measured by `fig11_concurrency`.
    fn fan_dfence_piped(&mut self, t: &mut ThreadCtx, mask: u64) {
        let p = t.id() % self.conc.commit_pipelines;
        let start = t.clock.now;
        let mut done = start;
        for (s, lane) in self.lanes.iter_mut().enumerate() {
            if mask & (1 << s) == 0 {
                continue;
            }
            let free = self.pipes[s][p];
            let begin = start.max(free);
            if free > start {
                self.pipe_waits += 1;
                self.pipe_wait_ns += free - start;
            }
            t.clock.now = begin;
            lane.strategy.on_dfence(&mut lane.fabric, &mut t.clock);
            self.pipes[s][p] = t.clock.now;
            self.pipe_busy_ns += t.clock.now - begin;
            done = done.max(t.clock.now);
        }
        t.clock.now = done;
    }

    /// Shards a fence must reach: the touched set, or shard 0 when the
    /// window saw no writes (preserving the pre-sharding behaviour of
    /// unconditional fence issue; with `shards = 1` the two coincide).
    fn fence_mask(&self, touched: u64) -> u64 {
        if self.lanes.len() == 1 || touched == 0 {
            1
        } else {
            touched
        }
    }

    /// `sfence`: ordering point — wait for local persists, signal the
    /// ordering primitive of every shard written this epoch, and open
    /// the next epoch. The per-shard ordering verbs are staged-pipeline
    /// flush points (`rofence`/`rcommit` ring any pending doorbells
    /// before issuing; SM-DD's implicit ordering needs no flush — its
    /// single QP issues staged writes in program order at the next
    /// durability point).
    pub fn sfence(&mut self, t: &mut ThreadCtx) {
        self.poll_membership(t.clock.now);
        t.clock.busy(self.plat.sfence);
        if let Some(&max) = t.pending_local.iter().max() {
            t.clock.wait_until(max);
        }
        t.pending_local.clear();
        let mask = self.fence_mask(t.touched_epoch);
        self.fan_fence(t, mask, |s, f, c| s.on_ofence(f, c));
        t.touched_epoch = 0;
        t.epoch += 1;
        t.epochs_done += 1;
    }

    /// Transaction begin: resets epoch numbering; passes the shape hint
    /// to every shard's strategy (adaptive strategies pick their mode
    /// here — no verbs are issued, so this is free on the wire). With
    /// several shards, a shard-local strategy serves only ~1/S of the
    /// transaction's writes under a spreading map, so the hint's
    /// writes-per-epoch is scaled to the expected per-shard share
    /// before the adaptive predictor sees it (exact pass-through at
    /// `shards = 1`).
    pub fn txn_begin(&mut self, t: &mut ThreadCtx, hint: Option<TxnShape>) {
        t.epoch = 0;
        t.touched_epoch = 0;
        t.touched_txn = 0;
        let hint = hint.map(|h| TxnShape {
            epochs: h.epochs,
            writes: h.writes / self.lanes.len() as f32,
        });
        t.txn_begin_at = t.clock.now;
        t.txn_hint = hint;
        for lane in &mut self.lanes {
            lane.strategy
                .on_txn_begin(&mut lane.fabric, &mut t.clock, hint);
        }
    }

    /// Transaction end: durability point (local drain + per-shard
    /// strategy fence on every shard the transaction touched; the
    /// commit instant is the max across those shards). Every shard's
    /// durability fence flushes its staged WQE pipeline first, so a
    /// committed transaction never leaves writes parked behind an
    /// un-rung doorbell. Records both the
    /// ack-policy completion and the per-backup fence completions. A
    /// transaction whose durability fence stalled on any shard (fault
    /// injection under `on_loss = halt`, or a fully dead group) was
    /// never durably acked and is NOT counted as committed.
    pub fn txn_commit(&mut self, t: &mut ThreadCtx) {
        self.poll_membership(t.clock.now);
        t.clock.busy(self.plat.sfence);
        if let Some(&max) = t.pending_local.iter().max() {
            t.clock.wait_until(max);
        }
        t.pending_local.clear();
        let mask = self.fence_mask(t.touched_txn);
        if self.conc.enabled() {
            self.fan_dfence_piped(t, mask);
        } else {
            self.fan_fence(t, mask, |s, f, c| s.on_dfence(f, c));
        }
        t.touched_txn = 0;
        t.touched_epoch = 0;
        if self.stall().is_some() {
            return;
        }
        t.last_dfence = t.clock.now;
        t.last_dfence_per_backup.clear();
        for lane in &self.lanes {
            t.last_dfence_per_backup
                .extend_from_slice(lane.fabric.last_fence());
        }
        t.txn += 1;
        t.txns_done += 1;
        // Measured commit latency feedback for the adaptive control
        // plane (a default no-op on fixed strategies and on SM-AD with
        // the control plane off): begin-to-durable, the steady-state
        // signal the controller's EWMAs absorb.
        let commit_ns = t.clock.now.saturating_sub(t.txn_begin_at);
        let hint = t.txn_hint;
        for lane in &mut self.lanes {
            lane.strategy.on_txn_end(hint, commit_ns);
        }
    }

    /// The primary PM image (golden state for recovery comparison).
    pub fn image(&self) -> &FastMap<Addr, u64> {
        &self.image
    }
}

/// One-validated-step [`Mirror`] construction: collect the full run
/// shape — strategy, replica group, fault plan, sharding, staged-WQE
/// knobs (batching / coalescing / concurrency) and the remote
/// persistence domain — then validate it *as a whole* in
/// [`MirrorBuilder::build`]. Cross-knob rules the old
/// `set_batching`/`set_coalescing`/`set_concurrency` setter chain could
/// only catch at apply time (or never) are rejected up front: eager
/// posting + coalescing is a build error here, not a runtime surprise.
/// `cli::RunSetup` consumes one of these; the individual setters remain
/// on [`Mirror`] for incremental reconfiguration (pinned to stay
/// equivalent by `serial_shape_bypasses_the_piped_path` and the
/// builder tests below).
///
/// Every knob defaults to the regression anchor: single backup, no
/// faults, one shard, eager posting, no coalescing, serial commits,
/// ADR persistence, no ledger.
pub struct MirrorBuilder {
    plat: Platform,
    kind: StrategyKind,
    predictor: Option<Predictor>,
    repl: ReplicationConfig,
    faults: FaultsConfig,
    sharding: ShardingConfig,
    link: LinkConfig,
    batching: FlushPolicy,
    coalescing: CoalesceMode,
    concurrency: ConcurrencyConfig,
    adaptive: AdaptiveConfig,
    knob_predictor: Option<KnobPredictor>,
    ledger: bool,
}

impl MirrorBuilder {
    pub fn new(plat: Platform, kind: StrategyKind) -> Self {
        MirrorBuilder {
            plat,
            kind,
            predictor: None,
            repl: ReplicationConfig::default(),
            faults: FaultsConfig::default(),
            sharding: ShardingConfig::default(),
            link: LinkConfig::default(),
            batching: FlushPolicy::Eager,
            coalescing: CoalesceMode::None,
            concurrency: ConcurrencyConfig::default(),
            adaptive: AdaptiveConfig::default(),
            knob_predictor: None,
            ledger: false,
        }
    }

    /// Wire the adaptive strategy's predictor (required for `SmAd`).
    pub fn predictor(mut self, p: Predictor) -> Self {
        self.predictor = Some(p);
        self
    }

    /// Online adaptive control-plane shape (`[adaptive]`; disabled by
    /// default — the static SM-AD anchor). Only meaningful with
    /// `StrategyKind::SmAd`.
    pub fn adaptive(mut self, cfg: AdaptiveConfig) -> Self {
        self.adaptive = cfg;
        self
    }

    /// Knob-aware latency model for the adaptive control plane
    /// (`predict(epochs, writes, backups, quorum, batch_cap)`). When
    /// adaptive is enabled and none is supplied, the closed-form
    /// [`crate::runtime::fallback_knob_predictor`] is used.
    pub fn knob_predictor(mut self, p: KnobPredictor) -> Self {
        self.knob_predictor = Some(p);
        self
    }

    /// Replica-group shape every shard drives.
    pub fn replication(mut self, repl: ReplicationConfig) -> Self {
        self.repl = repl;
        self
    }

    /// Deterministic failure dynamics (backup kills/rejoins, primary
    /// failover).
    pub fn faults(mut self, faults: FaultsConfig) -> Self {
        self.faults = faults;
        self
    }

    /// Address-space sharding shape.
    pub fn sharding(mut self, sharding: ShardingConfig) -> Self {
        self.sharding = sharding;
        self
    }

    /// Lossy-link shape (per-backup drop/delay/dup plan + RC retry
    /// knobs; the disabled default is the reliable-wire anchor — see
    /// [`crate::net::link`]).
    pub fn link(mut self, link: LinkConfig) -> Self {
        self.link = link;
        self
    }

    /// Staged WQE pipeline flush policy (`cap:1` normalizes to eager).
    pub fn batching(mut self, policy: FlushPolicy) -> Self {
        self.batching = policy;
        self
    }

    /// Flush-time coalescing mode; requires a staged flush policy —
    /// [`MirrorBuilder::build`] rejects coalescing under eager posting.
    pub fn coalescing(mut self, mode: CoalesceMode) -> Self {
        self.coalescing = mode;
        self
    }

    /// Concurrent-primary shape (commit pipelines + group-fence window).
    pub fn concurrency(mut self, conc: ConcurrencyConfig) -> Self {
        self.concurrency = conc;
        self
    }

    /// Remote persistence domain the backup engines run under
    /// (overrides the platform's `[remote] persist_domain`).
    pub fn persist_domain(mut self, d: PersistDomain) -> Self {
        self.plat.persist_domain = d;
        self
    }

    /// Record per-backup durability ledgers (needed for recovery
    /// checks; costs memory proportional to the write count).
    pub fn ledger(mut self, on: bool) -> Self {
        self.ledger = on;
        self
    }

    /// Validate the whole shape, then construct. Fails on any invalid
    /// component config, on cross-knob conflicts (eager + coalescing,
    /// SM-RC + rejoin, `SmAd` without a predictor), never panics on
    /// config input.
    pub fn build(self) -> Result<Mirror> {
        BatchingConfig::new(self.batching).validate()?;
        CoalescingConfig::new(self.coalescing).validate_with(self.batching)?;
        self.concurrency.validate()?;
        self.adaptive.validate()?;
        let mut m = Mirror::build_full(
            self.plat,
            self.kind,
            self.predictor,
            self.repl,
            self.faults,
            self.sharding,
            self.link,
            self.ledger,
            self.adaptive,
            self.knob_predictor,
        )?;
        m.set_batching(self.batching);
        m.set_coalescing(self.coalescing);
        m.set_concurrency(self.concurrency);
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AckPolicy;
    use std::collections::HashMap;

    fn run_transact_txn(m: &mut Mirror, t: &mut ThreadCtx, epochs: u32, writes: u32) {
        m.txn_begin(t, None);
        for e in 0..epochs {
            for w in 0..writes {
                let addr = 0x1000 + ((e * writes + w) as u64) * 64;
                m.store(t, addr, 1);
                m.clwb(t, addr);
            }
            m.sfence(t);
        }
        m.txn_commit(t);
    }

    #[test]
    fn no_sm_txn_costs_local_only() {
        let mut m = Mirror::new(Platform::default(), StrategyKind::NoSm, false);
        let mut t = ThreadCtx::new(0);
        run_transact_txn(&mut m, &mut t, 4, 1);
        // 4 epochs x ~(store+flush+sfence+drain) + commit fence: well under
        // a single RTT.
        assert!(t.now() < 2600, "NO-SM txn took {}", t.now());
        assert_eq!(t.txns_done, 1);
        assert_eq!(t.writes_done, 4);
    }

    #[test]
    fn sm_strategies_rank_as_paper_for_4_1() {
        // Transact 4-1: RC should be ~3x+ worse than OB/DD (paper Fig. 4).
        let mut times = HashMap::new();
        for kind in [StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd] {
            let mut m = Mirror::new(Platform::default(), kind, false);
            let mut t = ThreadCtx::new(0);
            for _ in 0..20 {
                run_transact_txn(&mut m, &mut t, 4, 1);
            }
            times.insert(kind, t.now());
        }
        let rc = times[&StrategyKind::SmRc] as f64;
        let ob = times[&StrategyKind::SmOb] as f64;
        let dd = times[&StrategyKind::SmDd] as f64;
        assert!(rc / ob > 2.0, "rc/ob = {}", rc / ob);
        assert!(rc / dd > 2.0, "rc/dd = {}", rc / dd);
    }

    #[test]
    fn store_then_load_round_trips() {
        let mut m = Mirror::new(Platform::default(), StrategyKind::NoSm, false);
        let mut t = ThreadCtx::new(0);
        m.store(&mut t, 0x40, 77);
        assert_eq!(m.load(&mut t, 0x40), 77);
        assert_eq!(m.load(&mut t, 0x7f), 77, "same line");
        assert_eq!(m.load(&mut t, 0x80), 0, "next line untouched");
    }

    #[test]
    fn ledger_captures_replica_writes_with_coordinates() {
        let mut m = Mirror::new(Platform::default(), StrategyKind::SmDd, true);
        let mut t = ThreadCtx::new(3);
        run_transact_txn(&mut m, &mut t, 2, 2);
        let evs = m.backup(0).ledger.events();
        assert_eq!(evs.len(), 4);
        assert!(evs.iter().all(|e| e.thread == 3));
        assert_eq!(evs.iter().filter(|e| e.epoch == 0).count(), 2);
        assert_eq!(evs.iter().filter(|e| e.epoch == 1).count(), 2);
    }

    #[test]
    fn dfence_completion_covers_all_persists() {
        for kind in [StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd] {
            let mut m = Mirror::new(Platform::default(), kind, true);
            let mut t = ThreadCtx::new(0);
            run_transact_txn(&mut m, &mut t, 8, 2);
            let horizon = m.backup(0).persist_horizon();
            assert!(
                t.last_dfence >= horizon,
                "{kind:?}: dfence at {} < persist horizon {}",
                t.last_dfence,
                horizon
            );
            assert_eq!(m.backup(0).ledger.len(), 16, "{kind:?}");
        }
    }

    #[test]
    fn replica_group_mirrors_every_backup() {
        let repl = ReplicationConfig::new(3, AckPolicy::All);
        for kind in [StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd] {
            let mut m =
                Mirror::with_replication(Platform::default(), kind, repl, true).unwrap();
            let mut t = ThreadCtx::new(0);
            run_transact_txn(&mut m, &mut t, 4, 2);
            assert_eq!(m.fabric().backups(), 3);
            for b in 0..3 {
                assert_eq!(m.backup(b).ledger.len(), 8, "{kind:?} backup {b}");
            }
            // All policy: the dfence covers every backup's horizon, and
            // per-backup completions are recorded.
            assert_eq!(t.last_dfence_per_backup.len(), 3);
            for b in 0..3 {
                assert!(
                    t.last_dfence >= m.backup(b).persist_horizon(),
                    "{kind:?} backup {b}"
                );
            }
        }
    }

    #[test]
    fn quorum_dfence_may_lead_slowest_backup() {
        // With quorum:1 of 3, the fence completes at the fastest backup;
        // per-backup completion times expose the laggards.
        let repl = ReplicationConfig::new(3, AckPolicy::Quorum(1));
        let mut m =
            Mirror::with_replication(Platform::default(), StrategyKind::SmOb, repl, true)
                .unwrap();
        let mut t = ThreadCtx::new(0);
        for _ in 0..5 {
            run_transact_txn(&mut m, &mut t, 4, 1);
        }
        let fences = t.last_dfence_per_backup.clone();
        assert_eq!(fences.len(), 3);
        let fastest = *fences.iter().min().unwrap();
        let slowest = *fences.iter().max().unwrap();
        assert!(fastest <= slowest);
        // The policy-level dfence equals the fastest completion (+ poll).
        assert!(
            t.last_dfence >= fastest && t.last_dfence <= slowest + 1000,
            "dfence {} outside [{fastest}, {slowest}+poll]",
            t.last_dfence
        );
    }

    #[test]
    fn faulted_mirror_halts_or_degrades_on_backup_loss() {
        use crate::net::{FaultsConfig, OnLoss};
        let repl = ReplicationConfig::new(3, AckPolicy::All);
        let faults = |mode| FaultsConfig::with_plan("kill:1@0", mode).unwrap();
        // Halt: the first durability fence records a stall.
        let mut m = Mirror::try_build_faulted(
            Platform::default(),
            StrategyKind::SmOb,
            None,
            repl,
            faults(OnLoss::Halt),
            false,
        )
        .unwrap();
        let mut t = ThreadCtx::new(0);
        run_transact_txn(&mut m, &mut t, 2, 1);
        let stall = m.stall().expect("all + halt must stall");
        assert_eq!(stall.alive, 2);
        assert_eq!(stall.required, 3);
        // Degrade: the run completes on the survivors.
        let mut m = Mirror::try_build_faulted(
            Platform::default(),
            StrategyKind::SmOb,
            None,
            repl,
            faults(OnLoss::Degrade),
            true,
        )
        .unwrap();
        let mut t = ThreadCtx::new(0);
        run_transact_txn(&mut m, &mut t, 2, 1);
        assert!(m.stall().is_none());
        assert_eq!(t.txns_done, 1);
        assert_eq!(m.backup(0).ledger.len(), 2);
        assert_eq!(m.backup(2).ledger.len(), 2);
        assert_eq!(m.backup(1).ledger.len(), 0, "dead backup sees nothing");
    }

    #[test]
    fn sm_rc_rejoin_plans_rejected_but_kill_only_allowed() {
        use crate::net::{FaultsConfig, OnLoss};
        let repl = ReplicationConfig::new(3, AckPolicy::Quorum(2));
        // Rejoin catch-up is impossible for SM-RC's volatile pending.
        let rejoin = FaultsConfig::with_plan("kill:1@100,rejoin:1@200", OnLoss::Degrade)
            .unwrap();
        assert!(Mirror::try_build_faulted(
            Platform::default(),
            StrategyKind::SmRc,
            None,
            repl,
            rejoin.clone(),
            false,
        )
        .is_err());
        // Kill-only plans are fine for SM-RC; rejoin plans are fine for
        // the write-through strategies.
        let kill_only = FaultsConfig::with_plan("kill:1@100", OnLoss::Degrade).unwrap();
        Mirror::try_build_faulted(
            Platform::default(),
            StrategyKind::SmRc,
            None,
            repl,
            kill_only,
            false,
        )
        .unwrap();
        Mirror::try_build_faulted(
            Platform::default(),
            StrategyKind::SmOb,
            None,
            repl,
            rejoin,
            false,
        )
        .unwrap();
    }

    #[test]
    fn stalled_commit_is_not_counted() {
        use crate::net::{FaultsConfig, OnLoss};
        let repl = ReplicationConfig::new(2, AckPolicy::All);
        let faults = FaultsConfig::with_plan("kill:0@0", OnLoss::Halt).unwrap();
        let mut m = Mirror::try_build_faulted(
            Platform::default(),
            StrategyKind::SmOb,
            None,
            repl,
            faults,
            false,
        )
        .unwrap();
        let mut t = ThreadCtx::new(0);
        run_transact_txn(&mut m, &mut t, 2, 1);
        assert!(m.stall().is_some());
        assert_eq!(t.txns_done, 0, "a stalled fence is not a commit");
        assert_eq!(t.last_dfence, 0, "no durability instant was reached");
    }

    #[test]
    fn fault_plan_outside_group_rejected_at_build() {
        use crate::net::{FaultsConfig, OnLoss};
        let faults = FaultsConfig::with_plan("kill:5@100", OnLoss::Halt).unwrap();
        assert!(Mirror::try_build_faulted(
            Platform::default(),
            StrategyKind::SmOb,
            None,
            ReplicationConfig::new(3, AckPolicy::All),
            faults,
            false,
        )
        .is_err());
    }

    #[test]
    fn invalid_replication_rejected_at_build() {
        let repl = ReplicationConfig::new(2, AckPolicy::Quorum(5));
        assert!(Mirror::with_replication(
            Platform::default(),
            StrategyKind::SmOb,
            repl,
            false
        )
        .is_err());
    }

    // ---- sharding --------------------------------------------------------

    /// Build a sharded SM-OB mirror over `shards` modulo-mapped groups.
    fn sharded(shards: usize, backups: usize, ledger: bool) -> Mirror {
        Mirror::try_build_sharded(
            Platform::default(),
            StrategyKind::SmOb,
            None,
            ReplicationConfig::new(backups, AckPolicy::All),
            FaultsConfig::default(),
            ShardingConfig::new(shards, ShardMapSpec::Modulo),
            ledger,
        )
        .unwrap()
    }

    #[test]
    fn invalid_sharding_rejected_at_build() {
        for shards in [0usize, 65] {
            assert!(Mirror::try_build_sharded(
                Platform::default(),
                StrategyKind::SmOb,
                None,
                ReplicationConfig::default(),
                FaultsConfig::default(),
                ShardingConfig::new(shards, ShardMapSpec::Modulo),
                false,
            )
            .is_err());
        }
    }

    #[test]
    fn clwb_routes_lines_to_owning_shards() {
        let mut m = sharded(4, 1, true);
        let mut t = ThreadCtx::new(0);
        m.txn_begin(&mut t, None);
        // Lines 0..8 land modulo-4: shards 0..3 twice each.
        for i in 0..8u64 {
            let addr = i * 64;
            m.store(&mut t, addr, i);
            m.clwb(&mut t, addr);
        }
        m.sfence(&mut t);
        m.txn_commit(&mut t);
        for s in 0..4 {
            assert_eq!(
                m.shard_fabric(s).backup(0).ledger.len(),
                2,
                "shard {s} write count"
            );
        }
        assert_eq!(t.txns_done, 1);
    }

    #[test]
    fn commit_fence_is_max_across_touched_shards() {
        // A txn touching 2 of 4 shards must not fence the other two,
        // and its commit instant covers both touched shards' horizons.
        let mut m = sharded(4, 1, true);
        let mut t = ThreadCtx::new(0);
        m.txn_begin(&mut t, None);
        for addr in [0u64, 64] {
            // shards 0 and 1
            m.store(&mut t, addr, 7);
            m.clwb(&mut t, addr);
        }
        m.sfence(&mut t);
        m.txn_commit(&mut t);
        for s in [0usize, 1] {
            assert!(
                t.last_dfence >= m.shard_fabric(s).group_horizon(),
                "shard {s} horizon not covered"
            );
            assert_eq!(m.shard_fabric(s).blocking_waits, 1, "shard {s}");
        }
        for s in [2usize, 3] {
            assert_eq!(
                m.shard_fabric(s).blocking_waits,
                0,
                "untouched shard {s} must not fence"
            );
            assert_eq!(m.shard_fabric(s).backup(0).ledger.len(), 0);
        }
        // Per-backup fence record is shard-major over all 4 shards.
        assert_eq!(t.last_dfence_per_backup.len(), 4);
    }

    #[test]
    fn concurrent_shard_fences_cost_max_not_sum() {
        // One write per shard: the commit fence spans all shards but is
        // issued concurrently, so the txn costs ~one fence, not S.
        let span = |shards: usize| {
            let mut m = sharded(shards, 1, false);
            let mut t = ThreadCtx::new(0);
            m.txn_begin(&mut t, None);
            for s in 0..shards as u64 {
                let addr = s * 64; // modulo: one line per shard
                m.store(&mut t, addr, s);
                m.clwb(&mut t, addr);
            }
            m.sfence(&mut t);
            m.txn_commit(&mut t);
            t.now()
        };
        let one = span(1);
        let four = span(4);
        // Same number of writes would cost ~4x the wire time if fences
        // serialized; concurrent fences keep it well under 2x.
        assert!(
            four < one * 2,
            "4-shard fence should overlap: 1 shard {one}, 4 shards {four}"
        );
    }

    #[test]
    fn coalescing_applies_per_owning_shard() {
        use crate::net::CoalesceMode;
        // 64-line stripes put the hot header (line 1) on shard 0 and
        // the append run (lines 64..68) on shard 1: combining must fire
        // on shard 0's fabric, scatter-gather on shard 1's — per-shard
        // application, not just shard 0 (contiguity survives because
        // the whole run sits inside one stripe).
        let mut m = Mirror::try_build_sharded(
            Platform::default(),
            StrategyKind::SmOb,
            None,
            ReplicationConfig::new(2, AckPolicy::All),
            FaultsConfig::default(),
            ShardingConfig::new(2, ShardMapSpec::Range { stripe_lines: 64 }),
            true,
        )
        .unwrap();
        m.set_batching(FlushPolicy::Fence);
        m.set_coalescing(CoalesceMode::Full);
        assert_eq!(m.coalescing(), CoalesceMode::Full);
        let mut t = ThreadCtx::new(0);
        m.txn_begin(&mut t, None);
        let hot = 0x40u64;
        // Hot header rewrites first, then a contiguous append run (the
        // surviving hot write stays at its own chain position, so
        // interleaving them would split the span).
        for i in 0..4u64 {
            m.store(&mut t, hot, i);
            m.clwb(&mut t, hot);
        }
        for i in 0..4u64 {
            let addr = 0x1000 + i * 64;
            m.store(&mut t, addr, i);
            m.clwb(&mut t, addr);
        }
        m.sfence(&mut t);
        m.txn_commit(&mut t);
        assert_eq!(t.txns_done, 1);
        assert!(m.combined_writes() > 0, "hot header rewrites must combine");
        assert!(m.wire_wqes() < m.posted_wqes(), "append run must merge");
        assert!(m.doorbells() <= m.wire_wqes());
        assert!(m.span_hist().max() >= 4, "4-line append span expected");
        // Per-shard placement: combining fired on the hot line's shard,
        // span formation on the append run's shard — not all on shard 0.
        assert_eq!(m.shard_fabric(0).combined_writes, 6, "3 dropped x 2 backups");
        assert_eq!(m.shard_fabric(0).span_hist().max(), 1, "shard 0 has no runs");
        assert_eq!(m.shard_fabric(1).combined_writes, 0, "no rewrites on shard 1");
        assert_eq!(m.shard_fabric(1).span_hist().max(), 4, "append span on shard 1");
        // The hot line's final value survives on its shard's ledger.
        let img = m.backup(0).ledger.image_at(u64::MAX);
        assert_eq!(img.get(&hot), Some(&3));
    }

    // ---- concurrent primary ----------------------------------------------

    /// The serial anchor shape (`pipelines = 1`, `window = 0`) must not
    /// route commits through the piped path at all — event-for-event
    /// identity with a mirror that never heard of concurrency.
    #[test]
    fn serial_shape_bypasses_the_piped_path() {
        let mut base = Mirror::new(Platform::default(), StrategyKind::SmOb, true);
        let mut gated = Mirror::new(Platform::default(), StrategyKind::SmOb, true);
        gated.set_concurrency(ConcurrencyConfig::default());
        let mut tb = ThreadCtx::new(0);
        let mut tg = ThreadCtx::new(0);
        for _ in 0..5 {
            run_transact_txn(&mut base, &mut tb, 4, 1);
            run_transact_txn(&mut gated, &mut tg, 4, 1);
        }
        assert_eq!(tb.now(), tg.now());
        assert_eq!(tb.clock.busy_ns, tg.clock.busy_ns);
        assert_eq!(
            base.backup(0).ledger.events(),
            gated.backup(0).ledger.events()
        );
        assert_eq!(gated.pipeline_waits(), 0);
        // One blocking dfence per commit on the SM-OB path.
        assert_eq!(gated.fences_issued(), 5);
        assert_eq!(gated.fence_piggybacks(), 0);
    }

    /// Pipeline contention is queueing, not CPU: a commit that finds
    /// its pipeline occupied waits in virtual time (visible in
    /// `pipeline_wait_ns`) but burns no `busy_ns`.
    #[test]
    fn shared_pipeline_serializes_commits_without_burning_cpu() {
        let mut m = Mirror::new(Platform::default(), StrategyKind::SmOb, false);
        m.set_concurrency(ConcurrencyConfig::new(2, 0));
        // Threads 0 and 2 share pipeline 0; thread 1 owns pipeline 1.
        let mut ts: Vec<ThreadCtx> = (0..3).map(ThreadCtx::new).collect();
        for _ in 0..3 {
            for t in &mut ts {
                run_transact_txn(&mut m, t, 2, 1);
            }
        }
        assert!(m.pipeline_waits() > 0, "colliding commits must queue");
        assert!(m.pipeline_wait_ns() > 0);
        assert!(m.pipeline_busy_ns() > 0);
        assert_eq!(
            ts[0].clock.busy_ns, ts[2].clock.busy_ns,
            "queued thread must not burn CPU waiting"
        );
        assert_eq!(m.fences_issued(), 9, "one dfence per commit");
        assert_eq!(m.fence_piggybacks(), 0, "no window, no piggybacks");
    }

    #[test]
    fn single_shard_stall_is_visible_at_mirror_level() {
        use crate::net::{FaultsConfig, OnLoss};
        let mut m = Mirror::try_build_sharded(
            Platform::default(),
            StrategyKind::SmOb,
            None,
            ReplicationConfig::new(2, AckPolicy::All),
            FaultsConfig::with_plan("kill:0@0", OnLoss::Halt).unwrap(),
            ShardingConfig::new(2, ShardMapSpec::Modulo),
            false,
        )
        .unwrap();
        let mut t = ThreadCtx::new(0);
        run_transact_txn(&mut m, &mut t, 2, 1);
        let stall = m.stall().expect("both shards lost backup node 0");
        assert_eq!(stall.required, 2);
        assert_eq!(t.txns_done, 0, "stalled commit not counted");
    }

    // ---- builder ---------------------------------------------------------

    /// The builder's default shape is the setter path's default shape:
    /// event-for-event identity with `Mirror::new` + no setter calls.
    #[test]
    fn builder_defaults_match_the_setter_path() {
        let mut base = Mirror::new(Platform::default(), StrategyKind::SmOb, true);
        let mut built = MirrorBuilder::new(Platform::default(), StrategyKind::SmOb)
            .ledger(true)
            .build()
            .unwrap();
        let mut tb = ThreadCtx::new(0);
        let mut tg = ThreadCtx::new(0);
        for _ in 0..5 {
            run_transact_txn(&mut base, &mut tb, 4, 2);
            run_transact_txn(&mut built, &mut tg, 4, 2);
        }
        assert_eq!(tb.now(), tg.now());
        assert_eq!(tb.clock.busy_ns, tg.clock.busy_ns);
        assert_eq!(
            base.backup(0).ledger.events(),
            built.backup(0).ledger.events()
        );
        assert_eq!(built.persist_domain(), PersistDomain::Adr);
    }

    /// A fully loaded builder applies every knob exactly as the setter
    /// chain would.
    #[test]
    fn builder_applies_every_knob() {
        let mut setters = Mirror::try_build_sharded(
            Platform::default(),
            StrategyKind::SmOb,
            None,
            ReplicationConfig::new(2, AckPolicy::All),
            FaultsConfig::default(),
            ShardingConfig::new(2, ShardMapSpec::Modulo),
            true,
        )
        .unwrap();
        setters.set_batching(FlushPolicy::Fence);
        setters.set_coalescing(CoalesceMode::Full);
        setters.set_concurrency(ConcurrencyConfig::new(2, 0));
        let mut built = MirrorBuilder::new(Platform::default(), StrategyKind::SmOb)
            .replication(ReplicationConfig::new(2, AckPolicy::All))
            .sharding(ShardingConfig::new(2, ShardMapSpec::Modulo))
            .batching(FlushPolicy::Fence)
            .coalescing(CoalesceMode::Full)
            .concurrency(ConcurrencyConfig::new(2, 0))
            .ledger(true)
            .build()
            .unwrap();
        assert_eq!(built.batching(), setters.batching());
        assert_eq!(built.coalescing(), setters.coalescing());
        assert_eq!(built.concurrency(), setters.concurrency());
        assert_eq!(built.shard_count(), 2);
        let mut ts = ThreadCtx::new(0);
        let mut tg = ThreadCtx::new(0);
        for _ in 0..4 {
            run_transact_txn(&mut setters, &mut ts, 2, 4);
            run_transact_txn(&mut built, &mut tg, 2, 4);
        }
        assert_eq!(ts.now(), tg.now());
        assert_eq!(setters.doorbells(), built.doorbells());
        assert_eq!(setters.combined_writes(), built.combined_writes());
    }

    /// The cross-knob rule the setter chain never enforced: coalescing
    /// needs a staged flush policy, and the builder rejects the eager
    /// pairing before any fabric exists.
    #[test]
    fn builder_rejects_eager_plus_coalescing() {
        let err = MirrorBuilder::new(Platform::default(), StrategyKind::SmOb)
            .coalescing(CoalesceMode::Full)
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("eager"), "{err}");
        // cap:1 is the eager model and must be rejected identically.
        let err = MirrorBuilder::new(Platform::default(), StrategyKind::SmOb)
            .batching(FlushPolicy::Cap(1))
            .coalescing(CoalesceMode::Combine)
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("eager"), "{err}");
    }

    /// `.persist_domain` overrides the platform key, and the domain +
    /// per-domain counters surface through the mirror aggregators.
    #[test]
    fn builder_persist_domain_reaches_every_backup() {
        let mut m = MirrorBuilder::new(Platform::default(), StrategyKind::SmOb)
            .replication(ReplicationConfig::new(2, AckPolicy::All))
            .persist_domain(PersistDomain::RpmemFlush)
            .build()
            .unwrap();
        assert_eq!(m.persist_domain(), PersistDomain::RpmemFlush);
        assert_eq!(m.fabric().persist_domain(), PersistDomain::RpmemFlush);
        let mut t = ThreadCtx::new(0);
        run_transact_txn(&mut m, &mut t, 2, 2);
        assert!(m.flush_verbs() > 0, "rpmem fences must emit flush verbs");
        assert!(m.flush_verbs() <= m.doorbells());
        assert!(m.volatile_window_ns() > 0);
        assert_eq!(m.compaction_lines(), 0, "no log, no compaction");
    }

    // ---- primary failover ------------------------------------------------

    /// All S shards fail over as one node: same winner, same epoch log,
    /// and a single admission instant synced to the slowest shard.
    #[test]
    fn primary_failover_spans_all_shards_as_one_node() {
        use crate::net::{FaultsConfig, OnLoss};
        let mut m = Mirror::try_build_sharded(
            Platform::default(),
            StrategyKind::SmOb,
            None,
            ReplicationConfig::new(3, AckPolicy::Quorum(2)),
            FaultsConfig::with_plan("kill:p@40000", OnLoss::Halt).unwrap(),
            ShardingConfig::new(2, ShardMapSpec::Modulo),
            true,
        )
        .unwrap();
        let mut t = ThreadCtx::new(0);
        while t.now() < 60_000 {
            run_transact_txn(&mut m, &mut t, 2, 4);
        }
        m.settle(t.now());
        assert!(m.stall().is_none(), "quorum:2 survives the promotion");
        assert_eq!(m.membership_epochs(), 1);
        let w0 = m.shard_fabric(0).primary_slot();
        assert_eq!(w0, Some(0), "equal summed prefixes tie to the lowest id");
        assert_eq!(m.shard_fabric(1).primary_slot(), w0, "one winner, all shards");
        assert_eq!(
            m.shard_fabric(0).epoch_log(),
            m.shard_fabric(1).epoch_log(),
            "epoch transitions must agree across shards"
        );
        assert_eq!(
            m.shard_fabric(0).admit_at(),
            m.shard_fabric(1).admit_at(),
            "the node admits writes as one"
        );
        assert!(m.failover_downtime_ns() > 0);
        assert!(t.txns_done > 0, "the run continues after failover");
    }
}
