//! Concurrent commit pipelines + cross-thread group fencing config.
//!
//! PR 6 replaces the primary's single global txn loop with per-shard
//! concurrent commit pipelines: each shard's fabric accepts durability
//! fences from up to `commit_pipelines` threads concurrently, modeling a
//! primary whose commit path is no longer one global critical section.
//! Orthogonally, `group_fence_ns` opens a piggyback window per shard:
//! a thread closing its transaction within the window of another
//! thread's in-flight remote fence rides that fence's completion instead
//! of issuing its own (requester-side post elided; responder-side drain
//! semantics still run — see `net::fabric`).
//!
//! The default shape (`commit_pipelines = 1`, `group_fence_ns = 0`)
//! structurally bypasses every new code path, so the serial coordinator
//! is preserved event-for-event (pinned by `rust/tests/concurrency.rs`).

use crate::Ns;
use anyhow::{bail, Result};
use std::fmt;
use std::str::FromStr;

/// Upper bound on commit pipelines per shard (same cap as shards: the
/// occupancy vectors stay small and a bitmask-free loop suffices).
pub const MAX_PIPELINES: usize = 64;

/// `[concurrency]` section / `--commit-pipelines` + `--group-fence-ns`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConcurrencyConfig {
    /// Concurrent commit pipelines per shard (1 = the serial txn loop,
    /// the regression anchor).
    pub commit_pipelines: usize,
    /// Group-fence piggyback window (ns); 0 = every thread issues its
    /// own remote fence (the pre-PR-6 model).
    pub group_fence_ns: Ns,
}

impl Default for ConcurrencyConfig {
    fn default() -> Self {
        ConcurrencyConfig {
            commit_pipelines: 1,
            group_fence_ns: 0,
        }
    }
}

impl ConcurrencyConfig {
    pub fn new(commit_pipelines: usize, group_fence_ns: Ns) -> Self {
        ConcurrencyConfig {
            commit_pipelines,
            group_fence_ns,
        }
    }

    /// Shape check: pipelines in `1..=MAX_PIPELINES`.
    pub fn validate(&self) -> Result<()> {
        if self.commit_pipelines == 0 {
            bail!("concurrency.commit_pipelines must be >= 1, got 0");
        }
        if self.commit_pipelines > MAX_PIPELINES {
            bail!(
                "concurrency.commit_pipelines must be <= {MAX_PIPELINES}, \
                 got {}",
                self.commit_pipelines
            );
        }
        Ok(())
    }

    /// True when any concurrent path is active (pipeline gating or a
    /// group-fence window); false = the serial anchor shape.
    pub fn enabled(&self) -> bool {
        self.commit_pipelines > 1 || self.group_fence_ns > 0
    }
}

impl fmt::Display for ConcurrencyConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "pipelines:{},window:{}",
            self.commit_pipelines, self.group_fence_ns
        )
    }
}

impl FromStr for ConcurrencyConfig {
    type Err = anyhow::Error;

    /// Parse `"pipelines:P,window:W"` (either part optional).
    fn from_str(s: &str) -> Result<Self> {
        let mut cfg = ConcurrencyConfig::default();
        for part in s.split(',').filter(|p| !p.is_empty()) {
            match part.split_once(':') {
                Some(("pipelines", v)) => {
                    cfg.commit_pipelines = v
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad pipelines count {v:?}"))?;
                }
                Some(("window", v)) => {
                    cfg.group_fence_ns = v
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad window ns {v:?}"))?;
                }
                _ => bail!("unknown concurrency part {part:?} (want pipelines:P,window:W)"),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_the_serial_anchor() {
        let c = ConcurrencyConfig::default();
        assert_eq!(c.commit_pipelines, 1);
        assert_eq!(c.group_fence_ns, 0);
        assert!(!c.enabled());
        c.validate().unwrap();
    }

    #[test]
    fn enabled_when_either_knob_moves() {
        assert!(ConcurrencyConfig::new(2, 0).enabled());
        assert!(ConcurrencyConfig::new(1, 500).enabled());
        assert!(!ConcurrencyConfig::new(1, 0).enabled());
    }

    #[test]
    fn validate_rejects_bad_shapes() {
        assert!(ConcurrencyConfig::new(0, 0).validate().is_err());
        assert!(ConcurrencyConfig::new(MAX_PIPELINES + 1, 0).validate().is_err());
        ConcurrencyConfig::new(MAX_PIPELINES, 0).validate().unwrap();
    }

    #[test]
    fn display_roundtrips_through_fromstr() {
        for c in [
            ConcurrencyConfig::default(),
            ConcurrencyConfig::new(4, 2600),
            ConcurrencyConfig::new(64, 0),
        ] {
            let s = c.to_string();
            assert_eq!(s.parse::<ConcurrencyConfig>().unwrap(), c, "{s}");
        }
        assert!("pipelines:0".parse::<ConcurrencyConfig>().is_err());
        assert!("pipes:2".parse::<ConcurrencyConfig>().is_err());
        assert!("window:abc".parse::<ConcurrencyConfig>().is_err());
    }
}
