//! Conservative min-clock scheduler for multi-threaded workloads.
//!
//! Shared resources in the timestamp-calculus simulator serialize requests
//! in *submission* order, so submission order should approximate virtual-
//! time order. The scheduler achieves this by always stepping the thread
//! with the smallest virtual clock, one transaction at a time — the same
//! conservative discipline used in parallel discrete-event simulation,
//! with transaction granularity as the lookahead window. Cross-thread
//! ordering error is bounded by one transaction's span.

use super::{Mirror, ThreadCtx};
use crate::metrics::LogHistogram;
use crate::net::Stall;
use crate::replication::DecisionStats;
use crate::Ns;

/// A per-thread transaction source: executes ONE transaction per call and
/// returns `false` when the thread has no more work. The optional warmup
/// phase (data loading, structure pre-population) runs to completion on
/// ALL threads before measurement starts: the scheduler then aligns every
/// thread's clock to the slowest loader (a barrier) and resets stats, so
/// load traffic never contaminates the measured steady state.
pub trait TxnSource {
    /// One warmup step; return true while more warmup work remains.
    fn warmup(&mut self, _m: &mut Mirror, _t: &mut ThreadCtx) -> bool {
        false
    }
    fn step(&mut self, m: &mut Mirror, t: &mut ThreadCtx) -> bool;
}

impl<F: FnMut(&mut Mirror, &mut ThreadCtx) -> bool> TxnSource for F {
    fn step(&mut self, m: &mut Mirror, t: &mut ThreadCtx) -> bool {
        self(m, t)
    }
}

/// Combinator pairing a warmup closure with a steady-state closure
/// (shared state goes in an `Rc<RefCell<..>>` captured by both).
pub struct Phased<W, S> {
    pub warmup: W,
    pub step: S,
}

impl<W, S> TxnSource for Phased<W, S>
where
    W: FnMut(&mut Mirror, &mut ThreadCtx) -> bool,
    S: FnMut(&mut Mirror, &mut ThreadCtx) -> bool,
{
    fn warmup(&mut self, m: &mut Mirror, t: &mut ThreadCtx) -> bool {
        (self.warmup)(m, t)
    }
    fn step(&mut self, m: &mut Mirror, t: &mut ThreadCtx) -> bool {
        (self.step)(m, t)
    }
}

/// Result of a multi-threaded run.
#[derive(Clone, Debug, Default)]
pub struct RunOutcome {
    /// Makespan: max thread completion time (ns).
    pub makespan: Ns,
    /// Sum of transactions completed across threads.
    pub txns: u64,
    /// Sum of replicated line writes.
    pub writes: u64,
    /// Sum of epochs executed.
    pub epochs: u64,
    /// Sum of primary-side CPU busy time across threads (ns, steady
    /// state) — excludes blocked waits; the figure doorbell batching
    /// shrinks (`fig9_batching`).
    pub busy_ns: Ns,
    /// Data-path doorbells rung across all shards and backups (steady
    /// state — load-phase traffic excluded, like `busy_ns`).
    pub doorbells: u64,
    /// Data lines posted across all shards and backups, steady state
    /// (`doorbells <= posted_wqes`; equal under eager posting).
    pub posted_wqes: u64,
    /// Data WQEs launched on the wire, steady state — a coalesced
    /// scatter-gather span counts once, so `wire_wqes <= posted_wqes`
    /// (equal without coalescing); the figure `fig10_coalescing`
    /// watches.
    pub wire_wqes: u64,
    /// Line writes elided by flush-time write combining, steady state.
    pub combined_writes: u64,
    /// Blocking fences that issued their own remote verb, steady state
    /// (with a zero group-fence window this is simply the blocking-fence
    /// count; the figure `fig11_concurrency` watches it shrink).
    pub fences_issued: u64,
    /// Blocking fences that piggybacked on another thread's in-flight
    /// fence, steady state (0 unless a group-fence window is set).
    pub fence_piggybacks: u64,
    /// Commit pipelines per shard the run was configured with (1 = the
    /// serial anchor; the occupancy denominator).
    pub commit_pipelines: usize,
    /// Commits that found their pipeline slot occupied, steady state.
    pub pipeline_waits: u64,
    /// Total virtual time commits spent queued for a pipeline slot,
    /// steady state (queueing only — never part of `busy_ns`).
    pub pipeline_wait_ns: Ns,
    /// Total virtual time pipelines were occupied by commit fences,
    /// steady state (the occupancy numerator).
    pub pipeline_busy_ns: Ns,
    /// Completed membership-epoch changes (primary failovers won; 0
    /// without primary faults in the plan).
    pub membership_epochs: u64,
    /// Write-admission downtime across failovers: kill instant to the
    /// instant the elected primary admitted writes, maxed over shards
    /// (all S shards fail over as one node). The figure
    /// `fig12_failover_primary` sweeps.
    pub failover_downtime_ns: Ns,
    /// Certified-suffix lines elected primaries re-replicated to lagging
    /// peers before admitting writes, summed over shards.
    pub rereplicated_lines: u64,
    /// Staged WQEs fenced by permission revocation at failovers (they
    /// retry through the new primary), summed over shards.
    pub revoked_wqes: u64,
    /// The remote persistence domain the run's backups operated under
    /// (name string, e.g. `"adr"` — see
    /// [`crate::net::PersistDomain`]).
    pub persist_domain: &'static str,
    /// Explicit flush verbs emitted by the fence path, steady state
    /// (0 outside the `rpmem-flush` domain; `flush_verbs <=
    /// doorbells`).
    pub flush_verbs: u64,
    /// Log-structured rewrites compacted in the background, steady
    /// state (0 outside the `log-structured` domain).
    pub compaction_lines: u64,
    /// Accumulated completion-to-persistence exposure (ns·line),
    /// steady state: how long replicated lines sat volatile before
    /// their persist instant (SM-RC's DDIO-to-drain gap under ADR,
    /// the write-to-flush gap under `rpmem-flush`; 0 under eADR
    /// where completion implies persistence).
    pub volatile_window_ns: u64,
    /// Wire re-sends across all shards and backups, steady state (0 on
    /// a reliable wire; always `>= transport_timeouts` — RNR retries
    /// re-send without an ACK timeout). The figure `fig15_lossy_links`
    /// sweeps.
    pub retransmits: u64,
    /// ACK-timeout expiries, steady state.
    pub transport_timeouts: u64,
    /// RNR NAKs taken at saturated backups, steady state.
    pub rnr_naks: u64,
    /// QP error-state transitions healed via transient kill + rejoin
    /// episodes, steady state (retry exhaustion — see
    /// [`crate::net::link`]).
    pub qp_resets: u64,
    /// Total timeout/backoff ns the transport spent masking lossy
    /// links, steady state (NIC hardware time — never CPU busy time).
    pub backoff_ns: Ns,
    /// Duplicate line deliveries injected by the link (dup events and
    /// spurious retransmits), steady state.
    pub dups_injected: u64,
    /// Duplicate line deliveries the remote PSN dedup dropped, steady
    /// state (`dup_drops <= retransmits + dups_injected`).
    pub dup_drops: u64,
    /// Lines-per-WQE distribution of the whole run (including any
    /// warmup/load phase — unlike the counters above, a histogram
    /// cannot be watermarked; Transact-style workloads have no load
    /// traffic, so the two views coincide there).
    pub span_hist: LogHistogram,
    /// Per-thread completion times.
    pub per_thread: Vec<Ns>,
    /// Shards the mirror routed over (1 = sharding off). The
    /// `per_backup_*` vectors below are flattened shard-major: index
    /// `shard * backups + backup`, length `shards * backups`.
    pub shards: usize,
    /// Per-backup persist horizons at the end of the run.
    pub per_backup_horizon: Vec<Ns>,
    /// Per-backup out-of-quorum time accrued by the end of the run
    /// (fault-injection runs; all zeros otherwise).
    pub per_backup_dead_ns: Vec<Ns>,
    /// Per-backup catch-up resync volume (lines streamed from a peer on
    /// rejoin; fault-injection runs, zeros otherwise).
    pub per_backup_resync_lines: Vec<u64>,
    /// The earliest unsatisfiable durability fence that stopped the
    /// run, if any (fault-injection runs under `on_loss = halt`, or a
    /// fully dead group). When set, the workload did NOT run to
    /// completion.
    pub stalled: Option<Stall>,
    /// Adaptive control-plane decision/feedback counters, steady state
    /// (mode dwells, knob-vector switches, per-quorum/per-cap decision
    /// histograms, model-vs-measured feedback error). All zeros for
    /// fixed strategies; SM-AD always reports its OB/DD dwells.
    pub decisions: DecisionStats,
}

impl RunOutcome {
    /// Aggregate throughput in transactions per simulated second.
    pub fn txn_per_sec(&self) -> f64 {
        if self.makespan == 0 {
            return 0.0;
        }
        self.txns as f64 / (self.makespan as f64 * 1e-9)
    }

    /// Mean writes per epoch (workload-characterization stat, paper §7.2).
    pub fn writes_per_epoch(&self) -> f64 {
        if self.epochs == 0 {
            return 0.0;
        }
        self.writes as f64 / self.epochs as f64
    }

    /// Mean epochs per transaction.
    pub fn epochs_per_txn(&self) -> f64 {
        if self.txns == 0 {
            return 0.0;
        }
        self.epochs as f64 / self.txns as f64
    }

    /// Mean data WQEs launched per doorbell (the staged pipeline's
    /// amortization factor — see [`crate::net::wqe::mean_batch`]).
    pub fn mean_batch(&self) -> f64 {
        crate::net::wqe::mean_batch(self.posted_wqes, self.doorbells)
    }

    /// Mean lines per wire WQE (the scatter-gather amortization factor
    /// — see [`crate::net::wqe::mean_span`]; 1.0 without coalescing).
    pub fn mean_span(&self) -> f64 {
        crate::net::wqe::mean_span(self.posted_wqes, self.wire_wqes)
    }

    /// Mean remote fences actually issued per committed transaction —
    /// 1.0 for a single-shard blocking-fence strategy without group
    /// fencing; a piggyback window pushes it below 1.0
    /// (`fig11_concurrency`'s amortization factor).
    pub fn fences_per_txn(&self) -> f64 {
        if self.txns == 0 {
            return 0.0;
        }
        self.fences_issued as f64 / self.txns as f64
    }

    /// Mean fraction of pipeline capacity (makespan x pipelines x
    /// shards) occupied by commit fences — the pipeline-occupancy
    /// counter the tentpole surfaces (0.0 on the serial anchor, whose
    /// commits bypass the piped path).
    pub fn pipeline_occupancy(&self) -> f64 {
        let cap = self.makespan as f64
            * self.commit_pipelines.max(1) as f64
            * self.shards.max(1) as f64;
        if cap == 0.0 {
            return 0.0;
        }
        self.pipeline_busy_ns as f64 / cap
    }

    /// Replica lag: spread between the slowest and fastest backup's
    /// persist horizon across all shards (0 for a single backup or
    /// NO-SM).
    pub fn backup_lag(&self) -> Ns {
        let max = self.per_backup_horizon.iter().copied().max().unwrap_or(0);
        let min = self.per_backup_horizon.iter().copied().min().unwrap_or(0);
        max - min
    }
}

/// Run `sources` (one per thread) to completion on `mirror`.
pub fn run_threads(mirror: &mut Mirror, sources: &mut [Box<dyn TxnSource>]) -> RunOutcome {
    let n = sources.len();
    let mut ctxs: Vec<ThreadCtx> = (0..n).map(ThreadCtx::new).collect();
    let mut alive: Vec<bool> = vec![true; n];
    let mut remaining = n;

    // ---- warmup phase: run every thread's loader to completion.
    {
        let mut warming: Vec<bool> = vec![true; n];
        let mut left = n;
        while left > 0 && mirror.stall().is_none() {
            let i = (0..n)
                .filter(|&i| warming[i])
                .min_by_key(|&i| ctxs[i].now())
                .expect("left > 0");
            if !sources[i].warmup(mirror, &mut ctxs[i]) {
                warming[i] = false;
                left -= 1;
            }
        }
        // Barrier: align clocks to the slowest loader; measurement
        // starts here.
        let tmax = ctxs.iter().map(|c| c.now()).max().unwrap_or(0);
        for c in ctxs.iter_mut() {
            c.clock.wait_until(tmax);
            c.reset_stats();
        }
    }
    // Watermark the fabric counters too, so the reported doorbell/WQE
    // totals cover the same steady-state span as busy_ns and txns
    // (load-phase fan-out traffic is excluded).
    let doorbells_zero = mirror.doorbells();
    let posted_wqes_zero = mirror.posted_wqes();
    let wire_wqes_zero = mirror.wire_wqes();
    let combined_zero = mirror.combined_writes();
    let fences_zero = mirror.fences_issued();
    let piggybacks_zero = mirror.fence_piggybacks();
    let pipe_waits_zero = mirror.pipeline_waits();
    let pipe_wait_ns_zero = mirror.pipeline_wait_ns();
    let pipe_busy_ns_zero = mirror.pipeline_busy_ns();
    let epochs_zero = mirror.membership_epochs();
    let downtime_zero = mirror.failover_downtime_ns();
    let rerepl_zero = mirror.rereplicated_lines();
    let revoked_zero = mirror.revoked_wqes();
    let flush_verbs_zero = mirror.flush_verbs();
    let compaction_zero = mirror.compaction_lines();
    let volatile_zero = mirror.volatile_window_ns();
    let retransmits_zero = mirror.retransmits();
    let timeouts_zero = mirror.transport_timeouts();
    let rnr_naks_zero = mirror.rnr_naks();
    let qp_resets_zero = mirror.qp_resets();
    let backoff_zero = mirror.backoff_ns();
    let dups_injected_zero = mirror.dups_injected();
    let dup_drops_zero = mirror.dup_drops();
    let decisions_zero = mirror.decision_stats();

    // A stalled fabric on any shard (halt-mode fault injection) stops
    // the run at the kill point: remaining transactions are abandoned,
    // and the outcome reports the stall.
    while remaining > 0 && mirror.stall().is_none() {
        // Pick the live thread with the smallest clock.
        let i = (0..n)
            .filter(|&i| alive[i])
            .min_by_key(|&i| ctxs[i].now())
            .expect("remaining > 0");
        if !sources[i].step(mirror, &mut ctxs[i]) {
            alive[i] = false;
            remaining -= 1;
        }
    }

    // Realize any fault events / resync completions the verb stream never
    // reached (e.g. a rejoin scheduled after the last write) — on every
    // shard's fabric.
    let wall = ctxs.iter().map(|c| c.now()).max().unwrap_or(0);
    mirror.settle(wall);

    let mut out = RunOutcome::default();
    for c in &ctxs {
        // Steady-state span: excludes any load phase before reset_stats.
        out.makespan = out.makespan.max(c.now() - c.stats_zero_at);
        out.txns += c.txns_done;
        out.writes += c.writes_done;
        out.epochs += c.epochs_done;
        out.busy_ns += c.clock.busy_ns - c.busy_zero;
        out.per_thread.push(c.now() - c.stats_zero_at);
    }
    out.shards = mirror.shard_count();
    out.doorbells = mirror.doorbells() - doorbells_zero;
    out.posted_wqes = mirror.posted_wqes() - posted_wqes_zero;
    out.wire_wqes = mirror.wire_wqes() - wire_wqes_zero;
    out.combined_writes = mirror.combined_writes() - combined_zero;
    out.fences_issued = mirror.fences_issued() - fences_zero;
    out.fence_piggybacks = mirror.fence_piggybacks() - piggybacks_zero;
    out.commit_pipelines = mirror.concurrency().commit_pipelines;
    out.pipeline_waits = mirror.pipeline_waits() - pipe_waits_zero;
    out.pipeline_wait_ns = mirror.pipeline_wait_ns() - pipe_wait_ns_zero;
    out.pipeline_busy_ns = mirror.pipeline_busy_ns() - pipe_busy_ns_zero;
    out.membership_epochs = mirror.membership_epochs() - epochs_zero;
    out.failover_downtime_ns = mirror.failover_downtime_ns() - downtime_zero;
    out.rereplicated_lines = mirror.rereplicated_lines() - rerepl_zero;
    out.revoked_wqes = mirror.revoked_wqes() - revoked_zero;
    out.persist_domain = mirror.persist_domain().name();
    out.flush_verbs = mirror.flush_verbs() - flush_verbs_zero;
    out.compaction_lines = mirror.compaction_lines() - compaction_zero;
    out.volatile_window_ns = mirror.volatile_window_ns() - volatile_zero;
    out.retransmits = mirror.retransmits() - retransmits_zero;
    out.transport_timeouts = mirror.transport_timeouts() - timeouts_zero;
    out.rnr_naks = mirror.rnr_naks() - rnr_naks_zero;
    out.qp_resets = mirror.qp_resets() - qp_resets_zero;
    out.backoff_ns = mirror.backoff_ns() - backoff_zero;
    out.dups_injected = mirror.dups_injected() - dups_injected_zero;
    out.dup_drops = mirror.dup_drops() - dup_drops_zero;
    out.decisions = mirror.decision_stats().minus(&decisions_zero);
    out.span_hist = mirror.span_hist();
    out.per_backup_horizon = mirror.persist_horizons();
    out.per_backup_dead_ns = mirror.accrued_dead_ns(wall);
    out.per_backup_resync_lines = mirror.resync_lines();
    out.stalled = mirror.stall().copied();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Platform, StrategyKind};

    fn transact_source(txns: u64, epochs: u32, writes: u32, base: u64) -> Box<dyn TxnSource> {
        let mut done = 0u64;
        Box::new(move |m: &mut Mirror, t: &mut ThreadCtx| {
            if done >= txns {
                return false;
            }
            m.txn_begin(t, None);
            for e in 0..epochs {
                for w in 0..writes {
                    let addr = base + ((done * 64 + (e * writes + w) as u64) % 1024) * 64;
                    m.store(t, addr, done);
                    m.clwb(t, addr);
                }
                m.sfence(t);
            }
            m.txn_commit(t);
            done += 1;
            true
        })
    }

    #[test]
    fn all_threads_complete() {
        let mut m = Mirror::new(Platform::default(), StrategyKind::SmOb, false);
        let mut srcs: Vec<Box<dyn TxnSource>> = (0..4)
            .map(|i| transact_source(10, 2, 1, 0x10000 * (i + 1) as u64))
            .collect();
        let out = run_threads(&mut m, &mut srcs);
        assert_eq!(out.txns, 40);
        assert_eq!(out.writes, 80);
        assert_eq!(out.per_thread.len(), 4);
        assert!(out.makespan > 0);
        assert!(out.txn_per_sec() > 0.0);
    }

    #[test]
    fn workload_stats_are_consistent() {
        let mut m = Mirror::new(Platform::default(), StrategyKind::NoSm, false);
        let mut srcs: Vec<Box<dyn TxnSource>> = vec![transact_source(5, 4, 2, 0)];
        let out = run_threads(&mut m, &mut srcs);
        assert_eq!(out.epochs_per_txn(), 4.0);
        assert_eq!(out.writes_per_epoch(), 2.0);
    }

    #[test]
    fn contention_slows_shared_qp_strategies() {
        // SM-DD routes every thread through QP0: 4 threads must be slower
        // than 1 thread doing a quarter of the work... i.e. scaling is
        // sublinear. Compare per-txn cost at 1 vs 4 threads.
        let cost = |threads: usize| {
            let mut m = Mirror::new(Platform::default(), StrategyKind::SmDd, false);
            let mut srcs: Vec<Box<dyn TxnSource>> = (0..threads)
                .map(|i| transact_source(50, 4, 1, 0x100000 * (i + 1) as u64))
                .collect();
            let out = run_threads(&mut m, &mut srcs);
            out.makespan as f64 / (out.txns as f64 / threads as f64)
        };
        let solo = cost(1);
        let contended = cost(4);
        assert!(
            contended > solo,
            "expected QP0 contention: solo={solo} contended={contended}"
        );
    }

    #[test]
    fn outcome_reports_per_backup_horizons() {
        use crate::config::{AckPolicy, ReplicationConfig};
        let repl = ReplicationConfig::new(3, AckPolicy::Quorum(2));
        let mut m = Mirror::with_replication(
            Platform::default(),
            StrategyKind::SmOb,
            repl,
            false,
        )
        .unwrap();
        let mut srcs: Vec<Box<dyn TxnSource>> = vec![transact_source(10, 2, 1, 0x10000)];
        let out = run_threads(&mut m, &mut srcs);
        assert_eq!(out.per_backup_horizon.len(), 3);
        for (i, &h) in out.per_backup_horizon.iter().enumerate() {
            assert!(h > 0, "backup {i} never persisted");
        }
        // Lag is bounded by the run itself.
        assert!(out.backup_lag() <= out.makespan);
    }

    #[test]
    fn stalled_fabric_stops_the_run_at_the_kill_point() {
        use crate::config::{AckPolicy, ReplicationConfig};
        use crate::net::{FaultsConfig, OnLoss};
        let repl = ReplicationConfig::new(2, AckPolicy::All);
        let faults = FaultsConfig::with_plan("kill:0@5000", OnLoss::Halt).unwrap();
        let mut m = Mirror::try_build_faulted(
            Platform::default(),
            StrategyKind::SmOb,
            None,
            repl,
            faults,
            false,
        )
        .unwrap();
        let mut srcs: Vec<Box<dyn TxnSource>> = vec![transact_source(1000, 2, 1, 0x10000)];
        let out = run_threads(&mut m, &mut srcs);
        let stall = out.stalled.expect("all + halt must stall the run");
        assert!(stall.at >= 5000, "stall at {} before the kill", stall.at);
        assert!(out.txns < 1000, "run must stop early, did {} txns", out.txns);
        assert_eq!(out.per_backup_dead_ns.len(), 2);
        assert!(out.per_backup_dead_ns[0] > 0, "killed backup accrues dead time");
        assert_eq!(out.per_backup_dead_ns[1], 0);
    }

    #[test]
    fn failover_counters_surface_through_run_outcome() {
        use crate::config::{AckPolicy, ReplicationConfig};
        use crate::net::{FaultsConfig, OnLoss};
        let repl = ReplicationConfig::new(3, AckPolicy::Majority);
        let faults = FaultsConfig::with_plan("kill:p@20000", OnLoss::Halt).unwrap();
        let mut m = Mirror::try_build_faulted(
            Platform::default(),
            StrategyKind::SmOb,
            None,
            repl,
            faults,
            true,
        )
        .unwrap();
        let mut srcs: Vec<Box<dyn TxnSource>> = vec![transact_source(400, 2, 2, 0x10000)];
        let out = run_threads(&mut m, &mut srcs);
        assert!(out.stalled.is_none(), "majority survives a primary kill");
        assert_eq!(out.membership_epochs, 1, "one failover must be recorded");
        assert!(
            out.failover_downtime_ns > 0,
            "handoff must accrue write-admission downtime"
        );
        assert!(out.txns > 0, "run continues under the elected primary");

        // Fault-free control: every failover counter stays zero.
        let mut quiet = Mirror::try_build_faulted(
            Platform::default(),
            StrategyKind::SmOb,
            None,
            ReplicationConfig::new(3, AckPolicy::Majority),
            FaultsConfig::default(),
            true,
        )
        .unwrap();
        let mut srcs: Vec<Box<dyn TxnSource>> = vec![transact_source(50, 2, 2, 0x10000)];
        let out = run_threads(&mut quiet, &mut srcs);
        assert_eq!(out.membership_epochs, 0);
        assert_eq!(out.failover_downtime_ns, 0);
        assert_eq!(out.rereplicated_lines, 0);
        assert_eq!(out.revoked_wqes, 0);
    }

    #[test]
    fn outcome_flattens_per_backup_vectors_shard_major() {
        use crate::config::{AckPolicy, ReplicationConfig};
        use crate::coordinator::{ShardMapSpec, ShardingConfig};
        use crate::net::FaultsConfig;
        let mut m = Mirror::try_build_sharded(
            Platform::default(),
            StrategyKind::SmOb,
            None,
            ReplicationConfig::new(2, AckPolicy::All),
            FaultsConfig::default(),
            ShardingConfig::new(3, ShardMapSpec::Modulo),
            false,
        )
        .unwrap();
        let mut srcs: Vec<Box<dyn TxnSource>> = vec![transact_source(20, 2, 2, 0x10000)];
        let out = run_threads(&mut m, &mut srcs);
        assert_eq!(out.shards, 3);
        assert_eq!(out.per_backup_horizon.len(), 6, "3 shards x 2 backups");
        assert_eq!(out.per_backup_dead_ns.len(), 6);
        assert_eq!(out.txns, 20);
        // A spread of line addresses reaches more than one shard.
        assert!(
            out.per_backup_horizon.iter().filter(|&&h| h > 0).count() > 2,
            "writes should spread across shards: {:?}",
            out.per_backup_horizon
        );
    }

    #[test]
    fn outcome_tracks_busy_and_doorbell_amortization() {
        use crate::config::{AckPolicy, ReplicationConfig};
        use crate::net::FlushPolicy;
        let run = |policy: FlushPolicy| {
            let mut m = Mirror::with_replication(
                Platform::default(),
                StrategyKind::SmOb,
                ReplicationConfig::new(2, AckPolicy::All),
                false,
            )
            .unwrap();
            m.set_batching(policy);
            let mut srcs: Vec<Box<dyn TxnSource>> = vec![transact_source(10, 2, 8, 0x10000)];
            run_threads(&mut m, &mut srcs)
        };
        let eager = run(FlushPolicy::Eager);
        let fenced = run(FlushPolicy::Fence);
        assert!(eager.busy_ns > 0);
        assert_eq!(
            eager.doorbells, eager.posted_wqes,
            "eager rings one doorbell per WQE"
        );
        assert!((eager.mean_batch() - 1.0).abs() < 1e-9);
        assert_eq!(fenced.posted_wqes, eager.posted_wqes);
        assert!(fenced.doorbells < eager.doorbells);
        assert!(fenced.mean_batch() > 1.0);
        assert!(
            fenced.busy_ns < eager.busy_ns,
            "batching must cut primary CPU busy: {} vs {}",
            fenced.busy_ns,
            eager.busy_ns
        );
        assert_eq!(fenced.txns, eager.txns);
    }

    #[test]
    fn outcome_tracks_coalescing_counters() {
        use crate::config::{AckPolicy, ReplicationConfig};
        use crate::net::{CoalesceMode, FlushPolicy};
        use crate::workloads::transact::{run_append_on, AppendConfig};
        // The shared contiguous-append workload (fig10's) gives
        // scatter-gather runs to merge — the random transact_source
        // rarely produces adjacency.
        let cfg = AppendConfig {
            epochs: 1,
            writes: 8,
            rewrites: 0,
            txns: 10,
            threads: 1,
        };
        let run = |mode: CoalesceMode| {
            let mut m = Mirror::with_replication(
                Platform::default(),
                StrategyKind::SmOb,
                ReplicationConfig::new(2, AckPolicy::All),
                false,
            )
            .unwrap();
            m.set_batching(FlushPolicy::Fence);
            m.set_coalescing(mode);
            run_append_on(&mut m, cfg)
        };
        let none = run(CoalesceMode::None);
        let sg = run(CoalesceMode::Sg);
        assert_eq!(none.wire_wqes, none.posted_wqes, "no coalescing: 1 line/WQE");
        assert!((none.mean_span() - 1.0).abs() < 1e-9);
        assert_eq!(none.combined_writes, 0);
        assert_eq!(sg.posted_wqes, none.posted_wqes, "sg drops nothing");
        assert!(sg.wire_wqes < none.wire_wqes, "appends must merge into spans");
        assert!(sg.mean_span() > 1.0);
        assert!(sg.doorbells <= sg.wire_wqes);
        assert!(sg.span_hist.max() >= 8, "8-line epoch spans expected");
        assert_eq!(sg.txns, none.txns);
    }

    #[test]
    fn outcome_reports_persist_domain_counters() {
        use crate::coordinator::MirrorBuilder;
        use crate::net::PersistDomain;
        let mut m = MirrorBuilder::new(Platform::default(), StrategyKind::SmOb)
            .persist_domain(PersistDomain::RpmemFlush)
            .build()
            .unwrap();
        let mut srcs: Vec<Box<dyn TxnSource>> = vec![transact_source(10, 2, 2, 0x10000)];
        let out = run_threads(&mut m, &mut srcs);
        assert_eq!(out.persist_domain, "rpmem-flush");
        assert!(out.flush_verbs > 0, "rpmem commits must emit flush verbs");
        assert!(out.flush_verbs <= out.doorbells);
        assert!(out.volatile_window_ns > 0);
        assert_eq!(out.compaction_lines, 0);

        // The default domain reports quiet counters.
        let mut m = Mirror::new(Platform::default(), StrategyKind::SmOb, false);
        let mut srcs: Vec<Box<dyn TxnSource>> = vec![transact_source(5, 2, 2, 0x10000)];
        let out = run_threads(&mut m, &mut srcs);
        assert_eq!(out.persist_domain, "adr");
        assert_eq!(out.flush_verbs, 0, "adr has no explicit flush verb");
        assert_eq!(out.compaction_lines, 0);
    }

    #[test]
    fn min_clock_keeps_threads_balanced() {
        let mut m = Mirror::new(Platform::default(), StrategyKind::SmOb, false);
        let mut srcs: Vec<Box<dyn TxnSource>> = (0..4)
            .map(|i| transact_source(20, 2, 1, 0x10000 * (i + 1) as u64))
            .collect();
        let out = run_threads(&mut m, &mut srcs);
        let min = *out.per_thread.iter().min().unwrap() as f64;
        let max = *out.per_thread.iter().max().unwrap() as f64;
        assert!(max / min < 1.5, "thread imbalance: {min} vs {max}");
    }
}
