//! Sharding the PM line-address space across independent replica groups.
//!
//! The paper mirrors one primary's persistent memory to one replica
//! group. Scaling that design to many users means partitioning the PM
//! address space over `S` **shards**, each served by its own
//! [`Fabric`](crate::net::Fabric) — its own backups, ack policy,
//! durability ledgers, and fault plan — so write traffic spreads across
//! independent groups and per-group quorums stay small.
//!
//! This module holds the *routing* half of that design:
//!
//! * [`ShardMapSpec`] — the pluggable partitioning function (`modulo`
//!   line-interleaving, or `range:N` contiguous striping);
//! * [`ShardMap`] — a spec bound to a shard count, mapping any PM
//!   address to the shard that owns its cache line;
//! * [`ShardingConfig`] — the `[sharding]` config table /
//!   `--shards` / `--shard-map` CLI surface.
//!
//! The [`Mirror`](super::Mirror) consults the map on every `clwb` and
//! routes ordering/durability fences to the shards a thread actually
//! touched; see the coordinator docs for the cross-shard fence
//! semantics. With `shards = 1` every map degenerates to the identity
//! and the coordinator passes verbs through to the single fabric
//! unchanged — the pre-sharding behaviour, pinned by
//! `rust/tests/sharding.rs`.
//!
//! Doorbell batching composes per shard: each shard's fabric owns its
//! own staged WQE pipeline (see [`crate::net::wqe`]), a line counts
//! toward the flush cap of the shard that owns it, and a fence routed
//! to a shard flushes only that shard's stage — shards a thread never
//! wrote hold nothing to flush, so the touched-shard fence routing
//! above is also the complete set of flush points.

use crate::{line_of, Addr, LINE};
use anyhow::{anyhow, bail, Result};
use std::fmt;
use std::str::FromStr;

/// Routing bitmask width: shards a single thread can address. The
/// coordinator tracks touched shards in a `u64` mask, so group counts
/// beyond this are rejected at validation.
pub const MAX_SHARDS: usize = 64;

/// Default stripe width of the contiguous-range map (lines): 16 Ki
/// lines = 1 MiB runs per shard before the next shard takes over.
pub const DEFAULT_STRIPE_LINES: u64 = 1 << 14;

/// The partitioning function family (pluggable shard map).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ShardMapSpec {
    /// Line-interleaved: consecutive cache lines round-robin across
    /// shards (finest spread; every multi-line object is scattered).
    #[default]
    Modulo,
    /// Contiguous-range striping: runs of `stripe_lines` consecutive
    /// lines stay on one shard before rotating to the next, so objects
    /// smaller than a stripe are shard-local (`stripe_lines >= 1`).
    Range { stripe_lines: u64 },
}

impl ShardMapSpec {
    pub fn validate(&self) -> Result<()> {
        if let ShardMapSpec::Range { stripe_lines: 0 } = self {
            bail!("shard map range stripe must be >= 1 line");
        }
        Ok(())
    }
}

impl FromStr for ShardMapSpec {
    type Err = anyhow::Error;

    /// Parse a `--shard-map` spec: `modulo`, `range`, or `range:LINES`
    /// (stripe width in cache lines, underscores allowed).
    fn from_str(s: &str) -> Result<Self> {
        let s = s.trim().to_ascii_lowercase();
        match s.as_str() {
            "modulo" | "mod" => return Ok(ShardMapSpec::Modulo),
            "range" => {
                return Ok(ShardMapSpec::Range {
                    stripe_lines: DEFAULT_STRIPE_LINES,
                })
            }
            _ => {}
        }
        if let Some(rest) = s.strip_prefix("range:") {
            let stripe_lines: u64 = rest
                .trim()
                .replace('_', "")
                .parse()
                .map_err(|e| anyhow!("shard map {s:?}: bad stripe width: {e}"))?;
            let spec = ShardMapSpec::Range { stripe_lines };
            spec.validate()?;
            return Ok(spec);
        }
        bail!("unknown shard map {s:?}; expected modulo | range | range:LINES")
    }
}

impl fmt::Display for ShardMapSpec {
    /// Round-trips through [`FromStr`]: `modulo` / `range:N`.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ShardMapSpec::Modulo => f.write_str("modulo"),
            ShardMapSpec::Range { stripe_lines } => write!(f, "range:{stripe_lines}"),
        }
    }
}

/// Sharding shape: `[sharding]` table / `--shards` + `--shard-map`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardingConfig {
    /// Number of independent replica groups the PM space is split over.
    pub shards: usize,
    pub map: ShardMapSpec,
}

impl Default for ShardingConfig {
    /// One shard: the paper's topology (sharding off).
    fn default() -> Self {
        ShardingConfig {
            shards: 1,
            map: ShardMapSpec::default(),
        }
    }
}

impl ShardingConfig {
    pub fn new(shards: usize, map: ShardMapSpec) -> Self {
        ShardingConfig { shards, map }
    }

    pub fn validate(&self) -> Result<()> {
        if self.shards == 0 {
            bail!("sharding.shards must be >= 1 (0 shards cannot own the PM space)");
        }
        if self.shards > MAX_SHARDS {
            bail!(
                "sharding.shards must be <= {MAX_SHARDS}, got {}",
                self.shards
            );
        }
        self.map.validate()
    }

    /// Bind the spec to the shard count, yielding the runtime router.
    pub fn build_map(&self) -> ShardMap {
        ShardMap {
            spec: self.map,
            shards: self.shards,
        }
    }
}

/// A partitioning function bound to a shard count: maps every PM
/// address to the shard owning its cache line. Total — every address
/// has exactly one owner — so the shard images are disjoint and their
/// union reconstructs the full PM space (the property cross-shard
/// recovery relies on).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardMap {
    spec: ShardMapSpec,
    shards: usize,
}

impl ShardMap {
    pub fn new(shards: usize, spec: ShardMapSpec) -> Self {
        ShardMap { spec, shards }
    }

    /// The identity map (sharding off).
    pub fn single() -> Self {
        ShardMap {
            spec: ShardMapSpec::Modulo,
            shards: 1,
        }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    pub fn spec(&self) -> ShardMapSpec {
        self.spec
    }

    /// The shard owning `addr`'s cache line.
    #[inline]
    pub fn shard_of(&self, addr: Addr) -> usize {
        if self.shards == 1 {
            return 0;
        }
        let line_idx = line_of(addr) / LINE;
        match self.spec {
            ShardMapSpec::Modulo => (line_idx % self.shards as u64) as usize,
            ShardMapSpec::Range { stripe_lines } => {
                ((line_idx / stripe_lines) % self.shards as u64) as usize
            }
        }
    }
}

impl fmt::Display for ShardMap {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} x{}", self.spec, self.shards)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parse_and_display_round_trip() {
        assert_eq!("modulo".parse::<ShardMapSpec>().unwrap(), ShardMapSpec::Modulo);
        assert_eq!("MOD".parse::<ShardMapSpec>().unwrap(), ShardMapSpec::Modulo);
        assert_eq!(
            "range".parse::<ShardMapSpec>().unwrap(),
            ShardMapSpec::Range {
                stripe_lines: DEFAULT_STRIPE_LINES
            }
        );
        assert_eq!(
            "range:4_096".parse::<ShardMapSpec>().unwrap(),
            ShardMapSpec::Range { stripe_lines: 4096 }
        );
        for spec in [
            ShardMapSpec::Modulo,
            ShardMapSpec::Range { stripe_lines: 128 },
        ] {
            assert_eq!(spec.to_string().parse::<ShardMapSpec>().unwrap(), spec);
        }
    }

    #[test]
    fn spec_parse_rejects_malformed() {
        for bad in ["", "hash", "range:", "range:abc", "range:0", "modulo:4"] {
            assert!(bad.parse::<ShardMapSpec>().is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn config_validation() {
        ShardingConfig::default().validate().unwrap();
        ShardingConfig::new(64, ShardMapSpec::Modulo).validate().unwrap();
        let err = ShardingConfig::new(0, ShardMapSpec::Modulo)
            .validate()
            .unwrap_err();
        assert!(err.to_string().contains(">= 1"), "{err}");
        assert!(ShardingConfig::new(65, ShardMapSpec::Modulo).validate().is_err());
        assert!(
            ShardingConfig::new(2, ShardMapSpec::Range { stripe_lines: 0 })
                .validate()
                .is_err()
        );
    }

    #[test]
    fn maps_are_total_and_stable() {
        for cfg in [
            ShardingConfig::new(4, ShardMapSpec::Modulo),
            ShardingConfig::new(4, ShardMapSpec::Range { stripe_lines: 4 }),
            ShardingConfig::new(3, ShardMapSpec::Range { stripe_lines: 16 }),
        ] {
            let map = cfg.build_map();
            for i in 0..1000u64 {
                let addr = 0x4000_0000_0000 + i * LINE;
                let s = map.shard_of(addr);
                assert!(s < cfg.shards, "{map}: {addr:#x} -> {s}");
                // Same line (any byte offset) -> same shard.
                assert_eq!(map.shard_of(addr + 63), s, "{map}");
                // Deterministic.
                assert_eq!(map.shard_of(addr), s, "{map}");
            }
        }
    }

    #[test]
    fn modulo_interleaves_adjacent_lines() {
        let map = ShardingConfig::new(4, ShardMapSpec::Modulo).build_map();
        let base = 0x1000u64;
        let shards: Vec<usize> =
            (0..8).map(|i| map.shard_of(base + i * LINE)).collect();
        assert_eq!(shards, vec![0, 1, 2, 3, 0, 1, 2, 3]);
    }

    #[test]
    fn range_keeps_stripes_contiguous() {
        let map = ShardingConfig::new(2, ShardMapSpec::Range { stripe_lines: 4 })
            .build_map();
        let shards: Vec<usize> = (0..12).map(|i| map.shard_of(i * LINE)).collect();
        assert_eq!(shards, vec![0, 0, 0, 0, 1, 1, 1, 1, 0, 0, 0, 0]);
    }

    #[test]
    fn single_shard_owns_everything() {
        let map = ShardMap::single();
        for addr in [0u64, 0x40, 0x4000_0000_0000, u64::MAX - 63] {
            assert_eq!(map.shard_of(addr), 0);
        }
    }
}
