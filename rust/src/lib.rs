//! # pmsm — RDMA-based Synchronous Mirroring of Persistent Memory Transactions
//!
//! A full-system reproduction of Tavakkol et al., *"Enabling Efficient
//! RDMA-based Synchronous Mirroring of Persistent Memory Transactions"*
//! (2018). The crate provides:
//!
//! * a cycle-approximate model of the paper's test bed — LLC (with DDIO
//!   ways and complex-addressing set hash), memory-controller write queue,
//!   persistent memory, PCIe, RNIC queue pairs and an InfiniBand-like
//!   fabric ([`mem`], [`net`], [`sim`]);
//! * the persistency-model transaction runtime (store/clwb/sfence undo-log
//!   transactions, [`txn`]);
//! * the paper's four replication strategies — NO-SM, SM-RC, SM-OB, SM-DD —
//!   plus a model-driven adaptive strategy ([`replication`]);
//! * an N-way **replica-group fabric** generalizing the paper's single
//!   backup: every verb fans out to N independent backups (each with its
//!   own LLC/MC/durability ledger) and durability fences complete per a
//!   pluggable **ack policy** — `all` (true synchronous mirroring),
//!   `quorum:k` / `majority` (k-durable, tolerating `k-1` backup
//!   losses); `backups = 1` + `all` reproduces the paper's numbers
//!   bit-exactly ([`net::Fabric`], `[replication] backups/ack_policy`
//!   config keys, per-backup latency breakdowns in [`metrics`]);
//! * deterministic **failure dynamics** on the replica group: sim-clock
//!   fault plans kill and rejoin backups mid-run, with catch-up resync
//!   from the healthiest peer, halt/degrade handling of intolerable
//!   losses, and fault-aware recovery checks over the realized
//!   alive/dead timeline ([`net::faults`], `[faults]` config keys,
//!   `--fault-plan` CLI);
//! * **address-space sharding**: the PM line space partitions over `S`
//!   independent replica groups (pluggable [`coordinator::ShardMap`]:
//!   modulo line-interleave or contiguous-range striping), each shard
//!   with its own fabric, ack policy, ledgers and fault plan; a
//!   transaction's commit fence completes at the max across the shards
//!   it touched, and cross-shard recovery merges per-shard verdicts
//!   ([`coordinator::shard`], `[sharding]` config keys, `--shards` /
//!   `--shard-map` CLI; `shards = 1` reproduces the single-fabric path
//!   event-for-event);
//! * a **staged WQE submission pipeline with doorbell batching** on the
//!   fan-out path: all data verbs flow through per-thread submit queues
//!   that chain WQEs in host memory and ring one doorbell per backup
//!   per flush (`eager` / `cap:k` / `fence` flush policies), splitting
//!   the old `post_cost` into `doorbell_ns + wqe_stage_ns` to recover
//!   the `S * N * post_cost` primary-side overhead; every ordering /
//!   durability fence is a flush point, so semantics are unchanged and
//!   `batch_cap = 1` reproduces the eager model bit-exactly
//!   ([`net::wqe`], `[batching]` config keys, `--batch-cap` /
//!   `--flush-policy` CLI, doorbell/mean-batch metrics, the
//!   `fig9_batching` bench);
//! * **flush-time coalescing** on the staged pipeline: write combining
//!   collapses same-line overwrites within an epoch to the last writer
//!   and scatter-gather merging fuses address-contiguous WQEs into
//!   multi-line spans that pay one QP/NIC slot plus `wire_line_ns` per
//!   extra line — amortizing the wire itself, on top of batching's CPU
//!   amortization — while every line still persists individually on
//!   the backups, so ledgers and recovery verdicts are unchanged
//!   (`--coalesce none|combine|sg|full`, `[coalescing]` config key,
//!   wire-WQE/combined/span metrics, the `fig10_coalescing` bench;
//!   `none` reproduces the batching pipeline event-for-event);
//! * an **online adaptive mirroring control plane** growing SM-AD from
//!   a binary OB/DD chooser into a per-transaction-class controller:
//!   at each txn begin it picks replication mode, ack quorum (clamped
//!   to `[configured floor, backups]` — the fence blocks on the k-th
//!   ack, stragglers complete async) and doorbell batch cap from the
//!   knob-aware analytic model plus per-class EWMAs of measured commit
//!   latency with a hysteresis guard ([`replication::adaptive`],
//!   `[adaptive]` config keys, `--adaptive*` CLI, decision histograms
//!   in reports and the `fig14_adaptive` bench; disabled — the
//!   default — reproduces the legacy SM-AD path event-for-event);
//! * **lossy-link fault injection with a reliable RC transport**: a
//!   deterministic per-backup link plan (one-shot drops/dups/delays,
//!   loss windows, seeded run-long loss rates with common random
//!   numbers, so makespan is monotone in the loss rate) masked by
//!   ACK-timeout retransmission with exponential backoff, RNR NAK
//!   backpressure, PSN-style duplicate suppression at the ledger
//!   boundary, and QP-reset healing that replays the lost suffix
//!   through the ordinary transient kill + rejoin resync — loss costs
//!   time, never durability truth ([`net::link`], `[link]` config
//!   keys, `--link-plan` CLI, transport counters in reports and the
//!   `fig15_lossy_links` bench; an empty plan reproduces the reliable
//!   wire event-for-event);
//! * the mirroring coordinator that binds a primary node's persistency
//!   traffic to the replica groups over the simulated fabric
//!   ([`coordinator`]);
//! * failure injection and recovery checking, including the
//!   cross-replica ledger consistency check (every committed txn durable
//!   on the ack-policy-required set) and its sharded merge ([`recovery`]);
//! * persistent data structures and the WHISPER-like workload suite
//!   ([`pstore`], [`workloads`]);
//! * an AOT-compiled analytic performance model executed through PJRT
//!   ([`runtime`]), used by the adaptive strategy and for
//!   model-vs-simulator cross validation;
//! * infrastructure substrates built in-repo (no external crates are
//!   available offline): config parsing ([`config`]), metrics
//!   ([`metrics`]), a micro-benchmark harness ([`bench`]), a property
//!   testing harness ([`ptest`]) and a PCG PRNG ([`util`]).
//!
//! See DESIGN.md for the experiment index and EXPERIMENTS.md for
//! paper-vs-measured results.

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod mem;
pub mod metrics;
pub mod net;
pub mod pstore;
pub mod ptest;
pub mod recovery;
pub mod replication;
pub mod runtime;
pub mod sim;
pub mod txn;
pub mod util;
pub mod workloads;

/// Simulated time in nanoseconds.
pub type Ns = u64;

/// A 64-byte-aligned physical line address in the simulated PM space.
pub type Addr = u64;

/// Cache line size used throughout (bytes).
pub const LINE: u64 = 64;

/// Align an address down to its cache line.
#[inline]
pub fn line_of(addr: Addr) -> Addr {
    addr & !(LINE - 1)
}
