//! pmsm leader binary — see `pmsm help`.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = pmsm::cli::main_with_args(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
