//! Intel complex-addressing LLC set-index hash (Maurice et al. [41]).
//!
//! Slice bit `i` is the XOR-fold (popcount parity) of the physical address
//! masked with `masks[i]`; the local set index comes from address bits
//! `[6, 6+log2(sets_per_slice))`. This mirrors the L1 Pallas kernel
//! `python/compile/kernels/cache_index.py` exactly — the rust integration
//! test `pjrt_model.rs` cross-checks the two on random batches.

use crate::Addr;

/// Configured slice hash + set geometry.
#[derive(Clone, Debug)]
pub struct SliceHash {
    masks: Vec<u64>,
    sets_per_slice: usize,
    set_mask: u64,
    slices: usize,
}

impl SliceHash {
    pub fn new(masks: &[u64], slices: usize, sets_per_slice: usize) -> Self {
        assert!(sets_per_slice.is_power_of_two());
        assert!(
            (1usize << masks.len().min(63)) >= slices,
            "not enough mask bits for {slices} slices"
        );
        SliceHash {
            masks: masks.to_vec(),
            sets_per_slice,
            set_mask: sets_per_slice as u64 - 1,
            slices,
        }
    }

    /// Slice index of a physical address.
    #[inline]
    pub fn slice(&self, addr: Addr) -> usize {
        let mut s = 0usize;
        for (i, &m) in self.masks.iter().enumerate() {
            s |= (((addr & m).count_ones() & 1) as usize) << i;
        }
        // Non-power-of-two slice counts fold the hash (matches how Intel
        // maps 6/10/12-slice parts); for power-of-two counts this is exact.
        s % self.slices
    }

    /// Local set index within a slice.
    #[inline]
    pub fn local_set(&self, addr: Addr) -> usize {
        ((addr >> 6) & self.set_mask) as usize
    }

    /// Global set index: `slice * sets_per_slice + local`.
    #[inline]
    pub fn global_set(&self, addr: Addr) -> usize {
        self.slice(addr) * self.sets_per_slice + self.local_set(addr)
    }

    pub fn total_sets(&self) -> usize {
        self.slices * self.sets_per_slice
    }
    pub fn sets_per_slice(&self) -> usize {
        self.sets_per_slice
    }
    pub fn slices(&self) -> usize {
        self.slices
    }
    pub fn masks(&self) -> &[u64] {
        &self.masks
    }
}

impl From<&crate::config::Platform> for SliceHash {
    fn from(p: &crate::config::Platform) -> Self {
        SliceHash::new(&p.slice_masks, p.llc_slices, p.llc_sets_per_slice)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::platform::INTEL_8SLICE_MASKS;
    use crate::util::Pcg64;

    fn intel() -> SliceHash {
        SliceHash::new(&INTEL_8SLICE_MASKS, 8, 2048)
    }

    #[test]
    fn global_set_in_range() {
        let h = intel();
        let mut r = Pcg64::new(1);
        for _ in 0..10_000 {
            let a = r.next_u64() & ((1 << 40) - 1);
            assert!(h.global_set(a) < h.total_sets());
        }
    }

    #[test]
    fn line_offset_does_not_change_set() {
        let h = intel();
        // Bits [0,6) are the line offset; the masks have zero low bits so
        // any offset within a line maps identically.
        for base in [0u64, 0x1234_5680, 0xdead_bec0] {
            let base = base & !63;
            let s = h.global_set(base);
            for off in 0..64 {
                assert_eq!(h.global_set(base + off), s);
            }
        }
    }

    #[test]
    fn sequential_lines_walk_sets() {
        let h = intel();
        let a = 0x4000_0000u64;
        let s1 = h.local_set(a);
        let s2 = h.local_set(a + 64);
        assert_eq!((s1 + 1) % 2048, s2);
    }

    #[test]
    fn slices_are_roughly_balanced() {
        let h = intel();
        let mut counts = vec![0u32; 8];
        for i in 0..65_536u64 {
            counts[h.slice(i * 64)] += 1;
        }
        let mean = 65_536.0 / 8.0;
        for &c in &counts {
            assert!((c as f64) > 0.5 * mean, "slice count {c}");
            assert!((c as f64) < 1.5 * mean, "slice count {c}");
        }
    }

    #[test]
    fn matches_reference_parity_definition() {
        let h = intel();
        let addr = 0x0123_4567_89ab_cdefu64;
        let mut want = 0usize;
        for (i, &m) in INTEL_8SLICE_MASKS.iter().enumerate() {
            want |= (((addr & m).count_ones() as usize) & 1) << i;
        }
        assert_eq!(h.slice(addr), want % 8);
    }
}
