//! Set-associative last-level cache model with DDIO way restriction.
//!
//! Paper §6.1: on the remote (backup) node, DDIO writes from the RNIC land
//! in the LLC but may only allocate in a fixed subset of ways per set
//! (2 of 20 on the Xeon E5-2630 v3); LRU replacement within that subset;
//! dirty evictions flow to the memory-controller write queue.
//!
//! The model tracks per-set way state (tag, dirty, LRU stamp) lazily —
//! sets are materialized on first touch so a 16K-set LLC costs nothing
//! until the workload actually touches it.

use super::addr::SliceHash;
use crate::util::FastMap;
use crate::{line_of, Addr, Ns};

#[derive(Clone, Copy, Debug, Default)]
struct Line {
    tag: Addr, // full line address (tag+index combined — simpler, exact)
    valid: bool,
    dirty: bool,
    lru: Ns,
}

/// Outcome of a DDIO write.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DdioWrite {
    /// Hit an existing line (possibly re-dirtying it).
    Hit,
    /// Allocated into a free DDIO way.
    Fill,
    /// Evicted a clean line.
    EvictClean,
    /// Evicted a dirty line whose address must be written back.
    EvictDirty(Addr),
}

/// LLC model (one node's cache).
#[derive(Clone, Debug)]
pub struct Llc {
    hash: SliceHash,
    ways: usize,
    ddio_ways: usize,
    sets: FastMap<u32, Vec<Line>>,
    // stats
    pub hits: u64,
    pub misses: u64,
    pub evictions_dirty: u64,
}

impl Llc {
    pub fn new(hash: SliceHash, ways: usize, ddio_ways: usize) -> Self {
        assert!(ddio_ways > 0 && ddio_ways <= ways);
        Llc {
            hash,
            ways,
            ddio_ways,
            sets: FastMap::default(),
            hits: 0,
            misses: 0,
            evictions_dirty: 0,
        }
    }

    pub fn from_platform(p: &crate::config::Platform) -> Self {
        Llc::new(SliceHash::from(p), p.llc_ways, p.ddio_ways)
    }

    fn set_of(&mut self, line: Addr) -> &mut Vec<Line> {
        let idx = self.hash.global_set(line) as u32;
        let ways = self.ways;
        self.sets
            .entry(idx)
            .or_insert_with(|| vec![Line::default(); ways])
    }

    /// A DDIO write from the RNIC at time `t`: allocates/updates within the
    /// DDIO ways only. Returns what happened (the caller routes dirty
    /// evictions into the MC model).
    pub fn ddio_write(&mut self, addr: Addr, t: Ns) -> DdioWrite {
        let line = line_of(addr);
        let ddio_ways = self.ddio_ways;
        let outcome = {
            let set = self.set_of(line);
            if let Some(l) = set.iter_mut().find(|l| l.valid && l.tag == line) {
                // Hit anywhere in the set (even outside DDIO ways).
                l.dirty = true;
                l.lru = t;
                DdioWrite::Hit
            } else if let Some(l) = set[..ddio_ways].iter_mut().find(|l| !l.valid) {
                // Fill a free DDIO way.
                *l = Line {
                    tag: line,
                    valid: true,
                    dirty: true,
                    lru: t,
                };
                DdioWrite::Fill
            } else {
                // Evict LRU among the DDIO ways.
                let victim = set[..ddio_ways]
                    .iter_mut()
                    .min_by_key(|l| l.lru)
                    .expect("ddio_ways > 0");
                let was_dirty = victim.dirty;
                let old = victim.tag;
                *victim = Line {
                    tag: line,
                    valid: true,
                    dirty: true,
                    lru: t,
                };
                if was_dirty {
                    DdioWrite::EvictDirty(old)
                } else {
                    DdioWrite::EvictClean
                }
            }
        };
        match outcome {
            DdioWrite::Hit => self.hits += 1,
            DdioWrite::EvictDirty(_) => {
                self.misses += 1;
                self.evictions_dirty += 1;
            }
            _ => self.misses += 1,
        }
        outcome
    }

    /// Write back a line (clwb/rcommit/write-through): clears its dirty
    /// bit. Returns true if the line was present and dirty (i.e. a transfer
    /// to the MC queue actually happens).
    pub fn writeback(&mut self, addr: Addr, _t: Ns) -> bool {
        let line = line_of(addr);
        let set = self.set_of(line);
        if let Some(l) = set.iter_mut().find(|l| l.valid && l.tag == line) {
            let was = l.dirty;
            l.dirty = false;
            was
        } else {
            false
        }
    }

    /// Is the line currently cached?
    pub fn contains(&mut self, addr: Addr) -> bool {
        let line = line_of(addr);
        self.set_of(line).iter().any(|l| l.valid && l.tag == line)
    }

    /// Is the line cached *and dirty*?
    pub fn is_dirty(&mut self, addr: Addr) -> bool {
        let line = line_of(addr);
        self.set_of(line)
            .iter()
            .any(|l| l.valid && l.tag == line && l.dirty)
    }

    /// Number of dirty lines currently held (O(sets touched); stats/tests).
    pub fn dirty_count(&self) -> usize {
        self.sets
            .values()
            .flat_map(|s| s.iter())
            .filter(|l| l.valid && l.dirty)
            .count()
    }

    pub fn hash(&self) -> &SliceHash {
        &self.hash
    }
    pub fn ddio_ways(&self) -> usize {
        self.ddio_ways
    }

    pub fn reset(&mut self) {
        self.sets.clear();
        self.hits = 0;
        self.misses = 0;
        self.evictions_dirty = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::platform::INTEL_8SLICE_MASKS;

    fn small_llc() -> Llc {
        // 1 slice x 4 sets x 4 ways, 2 DDIO ways -> tiny and easy to force
        // conflicts.
        Llc::new(SliceHash::new(&[0], 1, 4), 4, 2)
    }

    #[test]
    fn fill_then_hit() {
        let mut c = small_llc();
        assert_eq!(c.ddio_write(0x0, 1), DdioWrite::Fill);
        assert_eq!(c.ddio_write(0x0, 2), DdioWrite::Hit);
        assert!(c.is_dirty(0x0));
    }

    #[test]
    fn ddio_ways_limit_forces_eviction() {
        let mut c = small_llc();
        // Three lines mapping to the same set (set stride = 4 sets * 64B).
        let stride = 4 * 64;
        assert_eq!(c.ddio_write(0, 1), DdioWrite::Fill);
        assert_eq!(c.ddio_write(stride, 2), DdioWrite::Fill);
        // Third conflicting line evicts the LRU dirty line (addr 0).
        assert_eq!(c.ddio_write(2 * stride, 3), DdioWrite::EvictDirty(0));
    }

    #[test]
    fn writeback_clears_dirty_once() {
        let mut c = small_llc();
        c.ddio_write(0x40, 1);
        assert!(c.writeback(0x40, 2));
        assert!(!c.writeback(0x40, 3)); // already clean
        assert!(c.contains(0x40));
        assert!(!c.is_dirty(0x40));
    }

    #[test]
    fn clean_eviction_reported() {
        let mut c = small_llc();
        let stride = 4 * 64;
        c.ddio_write(0, 1);
        c.writeback(0, 2); // clean it
        c.ddio_write(stride, 3);
        assert_eq!(c.ddio_write(2 * stride, 4), DdioWrite::EvictClean);
    }

    #[test]
    fn lru_respects_recency() {
        let mut c = small_llc();
        let stride = 4 * 64;
        c.ddio_write(0, 1);
        c.ddio_write(stride, 2);
        c.ddio_write(0, 5); // refresh addr 0
        // Eviction should pick addr `stride` (older).
        assert_eq!(
            c.ddio_write(2 * stride, 6),
            DdioWrite::EvictDirty(stride)
        );
    }

    #[test]
    fn full_geometry_smoke() {
        let mut c = Llc::new(SliceHash::new(&INTEL_8SLICE_MASKS, 8, 2048), 20, 2);
        let mut evicted = 0;
        for i in 0..100_000u64 {
            if let DdioWrite::EvictDirty(_) = c.ddio_write(i * 64, i) {
                evicted += 1;
            }
        }
        // 100K distinct lines vs 32K DDIO-way capacity: most must evict.
        assert!(evicted > 50_000, "evicted {evicted}");
        assert!(c.dirty_count() <= 8 * 2048 * 2);
    }
}
