//! Memory-controller model: ingress stage + bounded write queue.
//!
//! Paper §6.1: writes enter the MC write queue from the LLC (10 ns) or
//! directly from the PCIe root complex (DDIO disabled); the queue holds 64
//! entries, drains to PM at 150 ns per line (with `mc_banks`-way drain
//! parallelism), and exerts back-pressure when full. Under ADR the queue
//! itself is inside the persistence domain, so *admission* to the queue is
//! the durability instant.
//!
//! Implementation: time-indexed rate limiters (see [`crate::sim::rate`])
//! for both the ingress transfer and the PM drain, so multi-threaded
//! submission order does not false-serialize. Back-pressure is the ADR
//! window rule: a line cannot be admitted more than `queue_depth` drain
//! slots ahead of its own drain — i.e. `admit >= drain_slot - queue_span`
//! where `queue_span = depth * (drain latency / banks)`.

use crate::sim::RateLimiter;
use crate::Ns;

/// Memory controller with bounded write queue.
#[derive(Clone, Debug)]
pub struct MemCtrl {
    /// Ingress transfer stage (LLC->MC or PCIe->MC).
    ingress: RateLimiter,
    ingress_lat: Ns,
    /// PM drain stage (sustained rate = mc_pm / banks).
    drain: RateLimiter,
    drain_lat: Ns,
    /// Time to drain a full queue: admission may lead drain by this much.
    queue_span: Ns,
    /// Stats.
    pushed: u64,
    stall_ns: Ns,
    max_pm_done: Ns,
}

impl MemCtrl {
    pub fn new(queue_depth: usize, banks: usize, drain_lat: Ns, ingress_lat: Ns) -> Self {
        let svc = (drain_lat / banks as Ns).max(1);
        MemCtrl {
            ingress: RateLimiter::new(ingress_lat.max(1)),
            ingress_lat,
            drain: RateLimiter::new(svc),
            drain_lat,
            // An entry may be admitted while at most `depth-1` earlier
            // entries are still draining: admit >= own_slot - span where
            // span = depth*svc - drain_lat (completion of the entry that
            // must have left the queue).
            queue_span: (queue_depth as Ns * svc).saturating_sub(drain_lat).max(1),
            pushed: 0,
            stall_ns: 0,
            max_pm_done: 0,
        }
    }

    pub fn from_platform(p: &crate::config::Platform) -> Self {
        MemCtrl::new(p.mcq, p.mc_banks, p.mc_pm, p.llc_mc)
    }

    /// Push one line arriving at `at` through ingress into the queue.
    /// Returns `(persist, pm_done)` — `persist` is the ADR durability
    /// instant (queue admission), `pm_done` when the cell write completes.
    pub fn push(&mut self, at: Ns) -> (Ns, Ns) {
        let x = self.ingress.submit(at) + self.ingress_lat;
        let slot = self.drain.submit(x);
        // ADR back-pressure: admission can lead the drain slot by at most
        // one full queue's worth of drain time.
        let admit = x.max(slot.saturating_sub(self.queue_span));
        self.stall_ns += admit.saturating_sub(x);
        let pm_done = slot + self.drain_lat;
        self.max_pm_done = self.max_pm_done.max(pm_done);
        self.pushed += 1;
        (admit, pm_done)
    }

    /// Latest PM landing seen.
    pub fn drained_at(&self) -> Ns {
        self.max_pm_done
    }

    /// Total lines pushed.
    pub fn pushed(&self) -> u64 {
        self.pushed
    }

    /// Accumulated back-pressure stall (ns).
    pub fn stall_ns(&self) -> Ns {
        self.stall_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ingress_serializes_at_its_rate() {
        let mut mc = MemCtrl::new(64, 1, 150, 10);
        let (p1, _) = mc.push(0);
        let (p2, _) = mc.push(0);
        assert_eq!(p1, 10);
        assert!(p2 >= 20, "p2={p2}");
    }

    #[test]
    fn persistence_is_admission_not_pm_landing() {
        let mut mc = MemCtrl::new(64, 1, 150, 10);
        let (persist, pm_done) = mc.push(0);
        assert_eq!(persist, 10);
        assert!(pm_done >= 150 + 10);
        assert!(persist < pm_done);
    }

    #[test]
    fn backpressure_at_queue_depth() {
        // Depth 2, slow drain: the 3rd push must wait (admission can lead
        // its drain slot by at most 2 x 1000 ns).
        let mut mc = MemCtrl::new(2, 1, 1000, 10);
        mc.push(0);
        mc.push(0);
        let (p3, _) = mc.push(0);
        assert!(p3 >= 1000, "expected backpressure, admitted at {p3}");
        assert!(mc.stall_ns() > 0);
    }

    #[test]
    fn sustained_rate_is_drain_limited() {
        let mut mc = MemCtrl::new(64, 4, 150, 10);
        let n = 10_000u64;
        let mut last = 0;
        for _ in 0..n {
            last = mc.push(0).0;
        }
        // 4 banks x 150ns -> one line per (150/4 = 37, integer) ns
        // sustained, minus the queue-depth lead.
        let expect = (n - 64) * (150 / 4) - 64 * 150;
        assert!(last >= expect, "last admit {last} < {expect}");
        assert!(last <= expect + 64 * 150 + 10_000, "last admit {last} too slow");
    }

    #[test]
    fn out_of_order_pushes_do_not_false_serialize() {
        let mut mc = MemCtrl::new(64, 4, 150, 10);
        // A far-future push first...
        mc.push(10_000_000);
        // ...must not delay an earlier push.
        let (p, _) = mc.push(100);
        assert!(p < 1_000, "false serialization: {p}");
    }

    #[test]
    fn drained_at_moves_forward() {
        let mut mc = MemCtrl::new(64, 1, 150, 10);
        mc.push(0);
        let d1 = mc.drained_at();
        mc.push(0);
        assert!(mc.drained_at() > d1);
    }
}
