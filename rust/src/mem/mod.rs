//! Memory-subsystem models: LLC set hashing, the set-associative LLC with
//! DDIO-restricted ways, the memory-controller write queue, and the PM
//! durability ledger — the paper's §6.1 model.

pub mod addr;
pub mod llc;
pub mod memctrl;
pub mod pmem;

pub use addr::SliceHash;
pub use llc::Llc;
pub use memctrl::MemCtrl;
pub use pmem::{DurEvent, DurabilityLog};
