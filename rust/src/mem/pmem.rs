//! Persistent-memory durability ledger.
//!
//! Records, for every persisted line write, *when* it became durable
//! (= admission into the MC write queue under ADR) together with its
//! transactional coordinates (thread, txn, epoch, per-thread sequence) and
//! the value written. The recovery checker ([`crate::recovery`]) replays
//! this ledger up to an arbitrary crash instant to reconstruct the backup's
//! surviving PM image and verify the paper's Guarantee-1/-2 (failure
//! atomicity + durability).
//!
//! The ledger is optional (off for the large benches) — recording is O(1)
//! amortized push into a Vec.

use crate::{Addr, Ns};

/// One durable line-write event.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DurEvent {
    pub addr: Addr,
    /// Value carried by the line write (pstore writes a word per line).
    pub val: u64,
    /// Durability instant (MC-queue admission on the owning node).
    pub at: Ns,
    /// Issuing thread.
    pub thread: u32,
    /// Transaction number within the thread.
    pub txn: u64,
    /// Epoch number within the transaction (0-based).
    pub epoch: u32,
    /// Global per-thread write sequence (issue order).
    pub seq: u64,
}

/// Durability ledger for one node.
#[derive(Clone, Debug, Default)]
pub struct DurabilityLog {
    enabled: bool,
    events: Vec<DurEvent>,
}

impl DurabilityLog {
    pub fn new(enabled: bool) -> Self {
        DurabilityLog {
            enabled,
            events: Vec::new(),
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Record an event (no-op when disabled).
    #[inline]
    pub fn record(&mut self, ev: DurEvent) {
        if self.enabled {
            self.events.push(ev);
        }
    }

    pub fn events(&self) -> &[DurEvent] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Reconstruct the PM image visible after a crash at `t`: for each
    /// address, the last-durable value with `at <= t` (ties broken by issue
    /// sequence, matching MC FIFO order).
    pub fn image_at(&self, t: Ns) -> std::collections::HashMap<Addr, u64> {
        let mut img = std::collections::HashMap::new();
        let mut stamp: std::collections::HashMap<Addr, (Ns, u32, u64)> =
            std::collections::HashMap::new();
        for ev in &self.events {
            if ev.at > t {
                continue;
            }
            let key = (ev.at, ev.thread, ev.seq);
            match stamp.get(&ev.addr) {
                Some(&prev) if prev >= key => {}
                _ => {
                    stamp.insert(ev.addr, key);
                    img.insert(ev.addr, ev.val);
                }
            }
        }
        img
    }

    /// Latest durability instant in the ledger (0 when empty).
    pub fn horizon(&self) -> Ns {
        self.events.iter().map(|e| e.at).max().unwrap_or(0)
    }

    pub fn clear(&mut self) {
        self.events.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(addr: Addr, val: u64, at: Ns, seq: u64) -> DurEvent {
        DurEvent {
            addr,
            val,
            at,
            thread: 0,
            txn: 0,
            epoch: 0,
            seq,
        }
    }

    #[test]
    fn disabled_log_records_nothing() {
        let mut log = DurabilityLog::new(false);
        log.record(ev(0, 1, 10, 0));
        assert!(log.is_empty());
    }

    #[test]
    fn image_respects_crash_time() {
        let mut log = DurabilityLog::new(true);
        log.record(ev(0x40, 1, 10, 0));
        log.record(ev(0x40, 2, 20, 1));
        log.record(ev(0x80, 7, 30, 2));
        let img = log.image_at(15);
        assert_eq!(img.get(&0x40), Some(&1));
        assert_eq!(img.get(&0x80), None);
        let img = log.image_at(30);
        assert_eq!(img.get(&0x40), Some(&2));
        assert_eq!(img.get(&0x80), Some(&7));
    }

    #[test]
    fn same_instant_ties_break_by_sequence() {
        let mut log = DurabilityLog::new(true);
        log.record(ev(0x40, 1, 10, 5));
        log.record(ev(0x40, 2, 10, 6));
        assert_eq!(log.image_at(10).get(&0x40), Some(&2));
        // Order of recording should not matter.
        let mut log2 = DurabilityLog::new(true);
        log2.record(ev(0x40, 2, 10, 6));
        log2.record(ev(0x40, 1, 10, 5));
        assert_eq!(log2.image_at(10).get(&0x40), Some(&2));
    }

    #[test]
    fn horizon_tracks_max() {
        let mut log = DurabilityLog::new(true);
        assert_eq!(log.horizon(), 0);
        log.record(ev(0, 0, 100, 0));
        log.record(ev(0, 0, 50, 1));
        assert_eq!(log.horizon(), 100);
    }
}
