//! Power-of-two bucketed latency histogram (constant memory, O(1) insert).

use crate::Ns;

/// Log2-bucketed histogram over ns values: bucket i holds [2^i, 2^(i+1)).
#[derive(Clone, Debug)]
pub struct LogHistogram {
    buckets: [u64; 64],
    count: u64,
    sum: u128,
    min: Ns,
    max: Ns,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        LogHistogram {
            buckets: [0; 64],
            count: 0,
            sum: 0,
            min: Ns::MAX,
            max: 0,
        }
    }

    #[inline]
    pub fn record(&mut self, v: Ns) {
        let b = 63 - (v | 1).leading_zeros() as usize;
        self.buckets[b] += 1;
        self.count += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
    pub fn min(&self) -> Ns {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }
    pub fn max(&self) -> Ns {
        self.max
    }

    /// Approximate percentile via bucket interpolation.
    pub fn percentile(&self, p: f64) -> Ns {
        if self.count == 0 {
            return 0;
        }
        let target = (p / 100.0 * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if seen + c >= target {
                // Interpolate within the bucket [2^i, 2^(i+1)).
                let lo = 1u64 << i;
                let frac = (target - seen) as f64 / c as f64;
                return lo + (lo as f64 * frac) as u64;
            }
            seen += c;
        }
        self.max
    }

    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_zeroes() {
        let h = LogHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.percentile(50.0), 0);
    }

    #[test]
    fn mean_min_max() {
        let mut h = LogHistogram::new();
        for v in [10, 20, 30] {
            h.record(v);
        }
        assert_eq!(h.count(), 3);
        assert_eq!(h.mean(), 20.0);
        assert_eq!(h.min(), 10);
        assert_eq!(h.max(), 30);
    }

    #[test]
    fn percentile_monotone() {
        let mut h = LogHistogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        let p50 = h.percentile(50.0);
        let p99 = h.percentile(99.0);
        assert!(p50 <= p99);
        assert!((256..=1024).contains(&p50), "p50 = {p50}");
    }

    #[test]
    fn merge_combines() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        a.record(8);
        b.record(16);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.mean(), 12.0);
        assert_eq!(a.max(), 16);
    }

    #[test]
    fn zero_value_is_safe() {
        let mut h = LogHistogram::new();
        h.record(0);
        assert_eq!(h.count(), 1);
        assert_eq!(h.min(), 0);
    }
}
