//! Metrics: counters, log-scale histograms, and the report formatters that
//! regenerate the paper's figures as text tables.

pub mod hist;
pub mod report;

pub use hist::LogHistogram;
pub use report::{Fig4Row, Fig5Row, Table};
