//! Metrics: counters, log-scale histograms, the report formatters that
//! regenerate the paper's figures as text tables, and the replica-group
//! (per-backup + group-level) breakdown report with its per-shard
//! rollup.

pub mod hist;
pub mod replica;
pub mod report;

pub use hist::LogHistogram;
pub use replica::{GroupReport, ShardedReport};
pub use report::{Fig4Row, Fig5Row, Table};
