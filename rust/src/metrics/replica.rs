//! Replica-group metrics: per-backup and group-level latency breakdowns
//! for an N-way mirroring run (the replica-group analogue of the Fig. 4/5
//! report formatters).

use crate::net::{BackupStats, Fabric};
use crate::Ns;

use super::report::Table;

/// Snapshot of a replica group after a run: per-backup stats plus the
/// group-level blocking profile.
#[derive(Clone, Debug)]
pub struct GroupReport {
    /// Rendered ack policy (e.g. `all`, `quorum:2`).
    pub policy: String,
    /// Durable backups required at a fence.
    pub required: usize,
    pub stats: Vec<BackupStats>,
    /// Blocking fences executed (group level).
    pub blocking_waits: u64,
    /// Total ns the workload threads spent blocked on group fences.
    pub blocked_ns: Ns,
}

impl GroupReport {
    /// Capture a report from a fabric (typically after a run).
    pub fn from_fabric(fabric: &Fabric) -> GroupReport {
        GroupReport {
            policy: fabric.policy().to_string(),
            required: fabric.required(),
            stats: fabric.backup_stats(),
            blocking_waits: fabric.blocking_waits,
            blocked_ns: fabric.blocked_ns,
        }
    }

    /// Number of backups in the group.
    pub fn backups(&self) -> usize {
        self.stats.len()
    }

    /// Spread between the slowest and fastest backup's persist horizon.
    pub fn horizon_lag(&self) -> Ns {
        let max = self.stats.iter().map(|s| s.persist_horizon).max().unwrap_or(0);
        let min = self.stats.iter().map(|s| s.persist_horizon).min().unwrap_or(0);
        max - min
    }

    /// Spread between the slowest and fastest backup's completion of the
    /// most recent durability fence.
    pub fn fence_lag(&self) -> Ns {
        let max = self.stats.iter().map(|s| s.last_fence).max().unwrap_or(0);
        let min = self.stats.iter().map(|s| s.last_fence).min().unwrap_or(0);
        max - min
    }

    /// Mean blocked time per fence (ns).
    pub fn mean_block_ns(&self) -> f64 {
        if self.blocking_waits == 0 {
            return 0.0;
        }
        self.blocked_ns as f64 / self.blocking_waits as f64
    }

    /// Render the per-backup table + group summary.
    pub fn render(&self) -> String {
        let mut t = Table::new(&[
            "backup",
            "writes",
            "persists",
            "barriers",
            "pending",
            "horizon(ns)",
            "fence(ns)",
            "stall(ns)",
        ]);
        for s in &self.stats {
            t.row(vec![
                format!("{}", s.id),
                format!("{}", s.writes),
                format!("{}", s.persists),
                format!("{}", s.barriers),
                format!("{}", s.pending_lines),
                format!("{}", s.persist_horizon),
                format!("{}", s.last_fence),
                format!("{}", s.window_stall_ns),
            ]);
        }
        format!(
            "Replica group — {} backups, ack policy {} (required {})\n{}\
             group: {} blocking fences, {:.0} ns mean block, \
             horizon lag {} ns, fence lag {} ns\n",
            self.backups(),
            self.policy,
            self.required,
            t.render(),
            self.blocking_waits,
            self.mean_block_ns(),
            self.horizon_lag(),
            self.fence_lag(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AckPolicy, Platform, ReplicationConfig};
    use crate::net::WriteMeta;
    use crate::sim::ThreadClock;

    #[test]
    fn report_captures_group_shape() {
        let p = Platform::default();
        let repl = ReplicationConfig::new(3, AckPolicy::Quorum(2));
        let mut f = Fabric::new(&p, &repl, true);
        let mut t = ThreadClock::new(0);
        for s in 0..3u64 {
            f.post_write_wt(
                &mut t,
                WriteMeta {
                    addr: 0x40 * (1 + s),
                    val: s,
                    thread: 0,
                    txn: 0,
                    epoch: 0,
                    seq: s,
                },
            );
        }
        f.rdfence(&mut t);
        let r = GroupReport::from_fabric(&f);
        assert_eq!(r.backups(), 3);
        assert_eq!(r.required, 2);
        assert_eq!(r.policy, "quorum:2");
        assert_eq!(r.blocking_waits, 1);
        assert!(r.mean_block_ns() >= 0.0);
        let text = r.render();
        assert!(text.contains("3 backups"));
        assert!(text.contains("quorum:2"));
        // One line per backup plus header/rule/summary.
        assert!(text.lines().count() >= 6, "{text}");
    }

    #[test]
    fn lag_zero_for_single_backup_before_any_fence() {
        let p = Platform::default();
        let f = Fabric::single(&p, false);
        let r = GroupReport::from_fabric(&f);
        assert_eq!(r.backups(), 1);
        assert_eq!(r.horizon_lag(), 0);
        assert_eq!(r.fence_lag(), 0);
        assert_eq!(r.mean_block_ns(), 0.0);
    }
}
