//! Replica-group metrics: per-backup and group-level latency breakdowns
//! for an N-way mirroring run (the replica-group analogue of the Fig. 4/5
//! report formatters), including the failure-dynamics view — per-backup
//! state, out-of-quorum (dead) time, catch-up resync volume and hand-off
//! latency, and the stall that stopped a halt-mode run — plus the
//! sharded rollup ([`ShardedReport`]): one [`GroupReport`] per address-
//! space shard with group totals and a machine-readable JSON dump.

use crate::coordinator::Mirror;
use crate::metrics::LogHistogram;
use crate::net::{BackupStats, Fabric, Stall};
use crate::replication::DecisionStats;
use crate::util::json;
use crate::{Ns, LINE};

use super::report::Table;

/// Snapshot of a replica group after a run: per-backup stats plus the
/// group-level blocking profile.
#[derive(Clone, Debug)]
pub struct GroupReport {
    /// Rendered ack policy (e.g. `all`, `quorum:2`).
    pub policy: String,
    /// Durable backups required at a fence.
    pub required: usize,
    /// Rendered loss mode (`halt` / `degrade`).
    pub on_loss: String,
    /// Rendered staged-pipeline flush policy (`eager` / `cap:K` /
    /// `fence`).
    pub flush_policy: String,
    /// Rendered flush-time coalescing mode (`none` / `combine` / `sg` /
    /// `full`).
    pub coalesce: String,
    /// Rendered remote persistence domain (`adr` / `eadr` /
    /// `rpmem-flush` / `log-structured`).
    pub persist_domain: String,
    pub stats: Vec<BackupStats>,
    /// Cross-thread group-fence piggyback window (ns; 0 = disabled).
    pub group_fence_ns: Ns,
    /// Blocking fences that issued their own remote verb.
    pub fences_issued: u64,
    /// Blocking fences that piggybacked on another thread's in-flight
    /// fence (0 unless a window is set).
    pub fence_piggybacks: u64,
    /// Blocking fences executed (group level).
    pub blocking_waits: u64,
    /// Total ns the workload threads spent blocked on group fences.
    pub blocked_ns: Ns,
    /// Data lines posted across the group (doorbell amortization
    /// denominator).
    pub posted_wqes: u64,
    /// Line writes elided by write combining across the group.
    pub combined_writes: u64,
    /// Lines-per-WQE distribution across the group's wire WQEs.
    pub span_hist: LogHistogram,
    /// Completed membership-epoch changes (primary failovers won).
    pub membership_epochs: u64,
    /// Write-admission downtime accumulated across failovers (ns).
    pub failover_downtime_ns: Ns,
    /// Certified-suffix lines re-replicated by elected primaries.
    pub rereplicated_lines: u64,
    /// Staged WQEs fenced by permission revocation at failovers.
    pub revoked_wqes: u64,
    /// The unsatisfiable fence that stopped the run, if any.
    pub stalled: Option<Stall>,
    /// Adaptive-controller decision/feedback counters (all zeros unless
    /// attached via [`GroupReport::set_decisions`]; the fabric does not
    /// carry them — strategies do).
    pub decisions: DecisionStats,
}

impl GroupReport {
    /// Capture a report from a fabric (typically after a run).
    pub fn from_fabric(fabric: &Fabric) -> GroupReport {
        GroupReport {
            policy: fabric.policy().to_string(),
            required: fabric.required(),
            on_loss: fabric.on_loss().to_string(),
            flush_policy: fabric.batching().to_string(),
            coalesce: fabric.coalescing().to_string(),
            persist_domain: fabric.persist_domain().to_string(),
            stats: fabric.backup_stats(),
            group_fence_ns: fabric.group_fence(),
            fences_issued: fabric.fences_issued,
            fence_piggybacks: fabric.fence_piggybacks,
            blocking_waits: fabric.blocking_waits,
            blocked_ns: fabric.blocked_ns,
            posted_wqes: fabric.posted_writes(),
            combined_writes: fabric.combined_writes,
            span_hist: fabric.span_hist(),
            membership_epochs: fabric.membership_epochs,
            failover_downtime_ns: fabric.failover_downtime_ns,
            rereplicated_lines: fabric.rereplicated_lines,
            revoked_wqes: fabric.revoked_wqes,
            stalled: fabric.stall().copied(),
            decisions: DecisionStats::default(),
        }
    }

    /// Attach adaptive-controller counters (they live on the strategy
    /// lanes, not the fabric, so the coordinator supplies them).
    pub fn set_decisions(&mut self, d: &DecisionStats) {
        self.decisions = d.clone();
    }

    /// Data-path doorbells rung across the group.
    pub fn doorbells(&self) -> u64 {
        self.stats.iter().map(|s| s.doorbells).sum()
    }

    /// Data WQEs launched on the wire across the group (spans count
    /// once).
    pub fn wire_wqes(&self) -> u64 {
        self.stats.iter().map(|s| s.wire_wqes).sum()
    }

    /// Explicit flush verbs emitted across the group (0 outside the
    /// `rpmem-flush` domain; bounded by [`GroupReport::doorbells`]).
    pub fn flush_verbs(&self) -> u64 {
        self.stats.iter().map(|s| s.flush_verbs).sum()
    }

    /// Log-structured compaction volume across the group (lines; 0
    /// outside the `log-structured` domain).
    pub fn compaction_lines(&self) -> u64 {
        self.stats.iter().map(|s| s.compaction_lines).sum()
    }

    /// Accumulated replicated-but-volatile exposure across the group
    /// (ns·line).
    pub fn volatile_window_ns(&self) -> u64 {
        self.stats.iter().map(|s| s.volatile_window_ns).sum()
    }

    /// Transport retransmissions across the group (timeout + RNR; 0 on
    /// a reliable wire).
    pub fn retransmits(&self) -> u64 {
        self.stats.iter().map(|s| s.retransmits).sum()
    }

    /// ACK-timeout expiries across the group (bounded by
    /// [`GroupReport::retransmits`]).
    pub fn timeouts(&self) -> u64 {
        self.stats.iter().map(|s| s.timeouts).sum()
    }

    /// RNR NAKs taken at saturated receivers across the group.
    pub fn rnr_naks(&self) -> u64 {
        self.stats.iter().map(|s| s.rnr_naks).sum()
    }

    /// Retry-exhaustion QP resets across the group.
    pub fn qp_resets(&self) -> u64 {
        self.stats.iter().map(|s| s.qp_resets).sum()
    }

    /// Total timeout/backoff wait across the group (ns).
    pub fn backoff_ns(&self) -> Ns {
        self.stats.iter().map(|s| s.backoff_ns).sum()
    }

    /// Duplicate line deliveries put on the wire across the group.
    pub fn dups_injected(&self) -> u64 {
        self.stats.iter().map(|s| s.dups_injected).sum()
    }

    /// Duplicate deliveries dropped by receiver-side PSN dedup.
    pub fn dup_drops(&self) -> u64 {
        self.stats.iter().map(|s| s.dup_drops).sum()
    }

    /// Mean data WQEs per doorbell (see [`crate::net::wqe::mean_batch`]).
    pub fn mean_batch(&self) -> f64 {
        crate::net::wqe::mean_batch(self.posted_wqes, self.doorbells())
    }

    /// Mean lines per wire WQE (see [`crate::net::wqe::mean_span`]).
    pub fn mean_span(&self) -> f64 {
        crate::net::wqe::mean_span(self.posted_wqes, self.wire_wqes())
    }

    /// Number of backups in the group.
    pub fn backups(&self) -> usize {
        self.stats.len()
    }

    /// Spread between the slowest and fastest backup's persist horizon.
    pub fn horizon_lag(&self) -> Ns {
        let max = self.stats.iter().map(|s| s.persist_horizon).max().unwrap_or(0);
        let min = self.stats.iter().map(|s| s.persist_horizon).min().unwrap_or(0);
        max - min
    }

    /// Spread between the slowest and fastest backup's completion of the
    /// most recent durability fence.
    pub fn fence_lag(&self) -> Ns {
        let max = self.stats.iter().map(|s| s.last_fence).max().unwrap_or(0);
        let min = self.stats.iter().map(|s| s.last_fence).min().unwrap_or(0);
        max - min
    }

    /// Fraction of blocking fences that piggybacked instead of issuing
    /// (0.0 without a group-fence window).
    pub fn piggyback_ratio(&self) -> f64 {
        let total = self.fences_issued + self.fence_piggybacks;
        if total == 0 {
            return 0.0;
        }
        self.fence_piggybacks as f64 / total as f64
    }

    /// Mean blocked time per fence (ns).
    pub fn mean_block_ns(&self) -> f64 {
        if self.blocking_waits == 0 {
            return 0.0;
        }
        self.blocked_ns as f64 / self.blocking_waits as f64
    }

    /// Total catch-up resync volume across the group (bytes).
    pub fn resync_bytes(&self) -> u64 {
        self.stats.iter().map(|s| s.resync_lines * LINE).sum()
    }

    /// Total out-of-quorum time across the group (closed intervals, ns).
    pub fn total_dead_ns(&self) -> Ns {
        self.stats.iter().map(|s| s.dead_ns).sum()
    }

    /// Render the per-backup table + group summary.
    pub fn render(&self) -> String {
        let mut t = Table::new(&[
            "backup",
            "state",
            "writes",
            "persists",
            "barriers",
            "doorbells",
            "wire",
            "pending",
            "horizon(ns)",
            "fence(ns)",
            "stall(ns)",
            "dead(ns)",
            "resync(B)",
            "handoff(ns)",
        ]);
        for s in &self.stats {
            t.row(vec![
                format!("{}", s.id),
                s.state.name().to_string(),
                format!("{}", s.writes),
                format!("{}", s.persists),
                format!("{}", s.barriers),
                format!("{}", s.doorbells),
                format!("{}", s.wire_wqes),
                format!("{}", s.pending_lines),
                format!("{}", s.persist_horizon),
                format!("{}", s.last_fence),
                format!("{}", s.window_stall_ns),
                format!("{}", s.dead_ns),
                format!("{}", s.resync_lines * LINE),
                format!("{}", s.last_handoff_ns),
            ]);
        }
        let mut out = format!(
            "Replica group — {} backups, ack policy {} (required {}, \
             on_loss {}, flush {}, coalesce {}, domain {})\n{}\
             group: {} blocking fences, {:.0} ns mean block, \
             {} issued + {} piggybacked ({:.2} ratio), \
             horizon lag {} ns, fence lag {} ns, dead {} ns, resync {} B, \
             {} doorbells, mean batch {:.2}\n\
             wire: {} WQEs over {} lines (mean span {:.2}, p99 {}, max {}), \
             {} combined\n",
            self.backups(),
            self.policy,
            self.required,
            self.on_loss,
            self.flush_policy,
            self.coalesce,
            self.persist_domain,
            t.render(),
            self.blocking_waits,
            self.mean_block_ns(),
            self.fences_issued,
            self.fence_piggybacks,
            self.piggyback_ratio(),
            self.horizon_lag(),
            self.fence_lag(),
            self.total_dead_ns(),
            self.resync_bytes(),
            self.doorbells(),
            self.mean_batch(),
            self.wire_wqes(),
            self.posted_wqes,
            self.mean_span(),
            self.span_hist.percentile(99.0),
            self.span_hist.max(),
            self.combined_writes,
        );
        if self.membership_epochs > 0 {
            out.push_str(&format!(
                "group: failover — {} membership epoch(s), downtime {} ns, \
                 {} line(s) re-replicated, {} staged WQE(s) revoked\n",
                self.membership_epochs,
                self.failover_downtime_ns,
                self.rereplicated_lines,
                self.revoked_wqes,
            ));
        }
        if self.flush_verbs() > 0
            || self.compaction_lines() > 0
            || self.volatile_window_ns() > 0
        {
            out.push_str(&format!(
                "group: persistence — {} flush verb(s), {} compacted \
                 line(s), {} ns·line volatile window\n",
                self.flush_verbs(),
                self.compaction_lines(),
                self.volatile_window_ns(),
            ));
        }
        if self.retransmits() > 0 || self.rnr_naks() > 0 || self.dup_drops() > 0 {
            out.push_str(&format!(
                "group: transport — {} retransmit(s) ({} timeout, {} rnr \
                 nak), {} ns backoff, {} qp reset(s), {} dup(s) on the \
                 wire / {} dropped by dedup\n",
                self.retransmits(),
                self.timeouts(),
                self.rnr_naks(),
                self.backoff_ns(),
                self.qp_resets(),
                self.dups_injected(),
                self.dup_drops(),
            ));
        }
        if self.decisions.chose_ob + self.decisions.chose_dd > 0 {
            out.push_str(&format!(
                "group: adaptive — {}\n",
                adaptive_summary(&self.decisions)
            ));
        }
        if let Some(stall) = &self.stalled {
            out.push_str(&format!("group: STALLED — {stall}\n"));
        }
        out
    }

    /// One group as a JSON object (element of the sharded dump).
    pub fn to_json(&self) -> String {
        let backups: Vec<String> = self
            .stats
            .iter()
            .map(|s| {
                json::obj(&[
                    ("id", s.id.to_string()),
                    ("state", json::esc(s.state.name())),
                    ("writes", s.writes.to_string()),
                    ("persists", s.persists.to_string()),
                    ("persist_horizon_ns", s.persist_horizon.to_string()),
                    ("last_fence_ns", s.last_fence.to_string()),
                    ("dead_ns", s.dead_ns.to_string()),
                    ("resync_lines", s.resync_lines.to_string()),
                    ("doorbells", s.doorbells.to_string()),
                    ("wire_wqes", s.wire_wqes.to_string()),
                    ("flush_verbs", s.flush_verbs.to_string()),
                    ("compaction_lines", s.compaction_lines.to_string()),
                    ("volatile_window_ns", s.volatile_window_ns.to_string()),
                    ("retransmits", s.retransmits.to_string()),
                    ("timeouts", s.timeouts.to_string()),
                    ("rnr_naks", s.rnr_naks.to_string()),
                    ("qp_resets", s.qp_resets.to_string()),
                    ("backoff_ns", s.backoff_ns.to_string()),
                    ("dups_injected", s.dups_injected.to_string()),
                    ("dup_drops", s.dup_drops.to_string()),
                ])
            })
            .collect();
        json::obj(&[
            ("policy", json::esc(&self.policy)),
            ("required", self.required.to_string()),
            ("on_loss", json::esc(&self.on_loss)),
            ("flush_policy", json::esc(&self.flush_policy)),
            ("coalesce", json::esc(&self.coalesce)),
            ("persist_domain", json::esc(&self.persist_domain)),
            ("group_fence_ns", self.group_fence_ns.to_string()),
            ("fences_issued", self.fences_issued.to_string()),
            ("fence_piggybacks", self.fence_piggybacks.to_string()),
            ("blocking_waits", self.blocking_waits.to_string()),
            ("blocked_ns", self.blocked_ns.to_string()),
            ("doorbells", self.doorbells().to_string()),
            ("posted_wqes", self.posted_wqes.to_string()),
            ("wire_wqes", self.wire_wqes().to_string()),
            ("combined_writes", self.combined_writes.to_string()),
            ("mean_batch", json::num(self.mean_batch())),
            ("mean_span", json::num(self.mean_span())),
            ("span_p99", self.span_hist.percentile(99.0).to_string()),
            ("span_max", self.span_hist.max().to_string()),
            ("membership_epochs", self.membership_epochs.to_string()),
            (
                "failover_downtime_ns",
                self.failover_downtime_ns.to_string(),
            ),
            ("rereplicated_lines", self.rereplicated_lines.to_string()),
            ("revoked_wqes", self.revoked_wqes.to_string()),
            ("flush_verbs", self.flush_verbs().to_string()),
            ("compaction_lines", self.compaction_lines().to_string()),
            ("volatile_window_ns", self.volatile_window_ns().to_string()),
            ("retransmits", self.retransmits().to_string()),
            ("timeouts", self.timeouts().to_string()),
            ("rnr_naks", self.rnr_naks().to_string()),
            ("qp_resets", self.qp_resets().to_string()),
            ("backoff_ns", self.backoff_ns().to_string()),
            ("dups_injected", self.dups_injected().to_string()),
            ("dup_drops", self.dup_drops().to_string()),
            ("stalled", self.stalled.is_some().to_string()),
            ("chose_ob", self.decisions.chose_ob.to_string()),
            ("chose_dd", self.decisions.chose_dd.to_string()),
            (
                "adaptive_switches",
                self.decisions.adaptive_switches.to_string(),
            ),
            (
                "quorum_hist",
                json::arr(
                    &self
                        .decisions
                        .quorum_hist
                        .iter()
                        .map(|n| n.to_string())
                        .collect::<Vec<_>>(),
                ),
            ),
            (
                "cap_hist",
                json::arr(
                    &self
                        .decisions
                        .cap_hist
                        .iter()
                        .map(|&(cap, n)| {
                            json::obj(&[
                                ("cap", cap.to_string()),
                                ("count", n.to_string()),
                            ])
                        })
                        .collect::<Vec<_>>(),
                ),
            ),
            (
                "feedback_samples",
                self.decisions.feedback_samples.to_string(),
            ),
            ("mean_err_pct", json::num(self.decisions.mean_err_pct())),
            ("backups", json::arr(&backups)),
        ])
    }
}

/// One-line prose summary of adaptive-controller counters (shared by
/// the group and sharded renderers).
fn adaptive_summary(d: &DecisionStats) -> String {
    let quorums: Vec<String> = d
        .quorum_hist
        .iter()
        .enumerate()
        .filter(|(_, n)| **n > 0)
        .map(|(k, n)| format!("k={k}:{n}"))
        .collect();
    let caps: Vec<String> = d
        .cap_hist
        .iter()
        .filter(|(_, n)| *n > 0)
        .map(|(c, n)| format!("c={c}:{n}"))
        .collect();
    format!(
        "{} ob / {} dd, {} switch(es), quorum [{}], cap [{}], \
         {} feedback sample(s), mean model err {:.1}%",
        d.chose_ob,
        d.chose_dd,
        d.adaptive_switches,
        quorums.join(" "),
        caps.join(" "),
        d.feedback_samples,
        d.mean_err_pct(),
    )
}

/// Sharded rollup: one [`GroupReport`] per shard of a sharded
/// [`Mirror`], with the routing map and cross-shard totals.
#[derive(Clone, Debug)]
pub struct ShardedReport {
    /// Rendered shard map (e.g. `modulo x4`).
    pub map: String,
    pub per_shard: Vec<GroupReport>,
    /// Node-level adaptive-controller counters (decisions live on the
    /// strategy lanes, which span shards — so this is captured once per
    /// mirror, not per shard).
    pub decisions: DecisionStats,
}

impl ShardedReport {
    /// Capture per-shard reports from a (possibly sharded) mirror.
    pub fn from_mirror(m: &Mirror) -> ShardedReport {
        ShardedReport {
            map: m.shard_map().to_string(),
            per_shard: (0..m.shard_count())
                .map(|s| GroupReport::from_fabric(m.shard_fabric(s)))
                .collect(),
            decisions: m.decision_stats(),
        }
    }

    pub fn shards(&self) -> usize {
        self.per_shard.len()
    }

    /// Total replicated line writes across all shards and backups.
    pub fn total_writes(&self) -> u64 {
        self.per_shard
            .iter()
            .flat_map(|r| r.stats.iter().map(|s| s.writes))
            .sum()
    }

    /// Total data-path doorbells rung across all shards and backups.
    pub fn total_doorbells(&self) -> u64 {
        self.per_shard.iter().map(|r| r.doorbells()).sum()
    }

    /// Mean data WQEs per doorbell across the whole deployment.
    pub fn mean_batch(&self) -> f64 {
        let wqes: u64 = self.per_shard.iter().map(|r| r.posted_wqes).sum();
        crate::net::wqe::mean_batch(wqes, self.total_doorbells())
    }

    /// Total wire WQEs launched across all shards and backups.
    pub fn total_wire_wqes(&self) -> u64 {
        self.per_shard.iter().map(|r| r.wire_wqes()).sum()
    }

    /// Total combined (elided) line writes across all shards.
    pub fn total_combined_writes(&self) -> u64 {
        self.per_shard.iter().map(|r| r.combined_writes).sum()
    }

    /// Total blocking fences issued across all shards.
    pub fn total_fences_issued(&self) -> u64 {
        self.per_shard.iter().map(|r| r.fences_issued).sum()
    }

    /// Total piggybacked blocking fences across all shards.
    pub fn total_fence_piggybacks(&self) -> u64 {
        self.per_shard.iter().map(|r| r.fence_piggybacks).sum()
    }

    /// Membership epochs of the node (shards fail over as one unit, so
    /// this is the max — normally every shard agrees — not a sum).
    pub fn membership_epochs(&self) -> u64 {
        self.per_shard
            .iter()
            .map(|r| r.membership_epochs)
            .max()
            .unwrap_or(0)
    }

    /// Node-level failover downtime (max over shards: lanes synchronize
    /// their write admission to the slowest shard's instant).
    pub fn failover_downtime_ns(&self) -> Ns {
        self.per_shard
            .iter()
            .map(|r| r.failover_downtime_ns)
            .max()
            .unwrap_or(0)
    }

    /// Total certified-suffix lines re-replicated across all shards.
    pub fn total_rereplicated_lines(&self) -> u64 {
        self.per_shard.iter().map(|r| r.rereplicated_lines).sum()
    }

    /// Total staged WQEs revoked at failovers across all shards.
    pub fn total_revoked_wqes(&self) -> u64 {
        self.per_shard.iter().map(|r| r.revoked_wqes).sum()
    }

    /// Total explicit flush verbs across all shards and backups.
    pub fn total_flush_verbs(&self) -> u64 {
        self.per_shard.iter().map(|r| r.flush_verbs()).sum()
    }

    /// Total log-compaction volume across all shards and backups.
    pub fn total_compaction_lines(&self) -> u64 {
        self.per_shard.iter().map(|r| r.compaction_lines()).sum()
    }

    /// Total replicated-but-volatile exposure across all shards and
    /// backups (ns·line).
    pub fn total_volatile_window_ns(&self) -> u64 {
        self.per_shard.iter().map(|r| r.volatile_window_ns()).sum()
    }

    /// Total transport retransmissions across all shards and backups.
    pub fn total_retransmits(&self) -> u64 {
        self.per_shard.iter().map(|r| r.retransmits()).sum()
    }

    /// Total ACK-timeout expiries across all shards and backups.
    pub fn total_timeouts(&self) -> u64 {
        self.per_shard.iter().map(|r| r.timeouts()).sum()
    }

    /// Total RNR NAKs across all shards and backups.
    pub fn total_rnr_naks(&self) -> u64 {
        self.per_shard.iter().map(|r| r.rnr_naks()).sum()
    }

    /// Total retry-exhaustion QP resets across all shards and backups.
    pub fn total_qp_resets(&self) -> u64 {
        self.per_shard.iter().map(|r| r.qp_resets()).sum()
    }

    /// Total duplicate deliveries dropped by dedup across all shards.
    pub fn total_dup_drops(&self) -> u64 {
        self.per_shard.iter().map(|r| r.dup_drops()).sum()
    }

    /// Mean lines per wire WQE across the whole deployment.
    pub fn mean_span(&self) -> f64 {
        let lines: u64 = self.per_shard.iter().map(|r| r.posted_wqes).sum();
        crate::net::wqe::mean_span(lines, self.total_wire_wqes())
    }

    /// Shard-imbalance factor: max over mean of per-shard write counts
    /// (1.0 = perfectly balanced; meaningful only for `shards > 1`).
    pub fn write_skew(&self) -> f64 {
        let per_shard: Vec<u64> = self
            .per_shard
            .iter()
            .map(|r| r.stats.iter().map(|s| s.writes).sum::<u64>())
            .collect();
        let max = per_shard.iter().copied().max().unwrap_or(0) as f64;
        let mean = per_shard.iter().sum::<u64>() as f64 / per_shard.len().max(1) as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    /// Render every shard's table plus the rollup line.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (s, r) in self.per_shard.iter().enumerate() {
            out.push_str(&format!("shard {s}: "));
            out.push_str(&r.render());
        }
        out.push_str(&format!(
            "shards: {} over map {}, {} total writes, write skew {:.2}x, \
             {} doorbells (mean batch {:.2}), {} wire WQEs \
             (mean span {:.2}), {} combined\n",
            self.shards(),
            self.map,
            self.total_writes(),
            self.write_skew(),
            self.total_doorbells(),
            self.mean_batch(),
            self.total_wire_wqes(),
            self.mean_span(),
            self.total_combined_writes(),
        ));
        if self.membership_epochs() > 0 {
            out.push_str(&format!(
                "shards: failover — {} membership epoch(s) as one node, \
                 downtime {} ns, {} line(s) re-replicated, {} staged \
                 WQE(s) revoked\n",
                self.membership_epochs(),
                self.failover_downtime_ns(),
                self.total_rereplicated_lines(),
                self.total_revoked_wqes(),
            ));
        }
        if self.total_retransmits() > 0 || self.total_rnr_naks() > 0 {
            out.push_str(&format!(
                "shards: transport — {} retransmit(s) ({} timeout, {} rnr \
                 nak), {} qp reset(s), {} dropped by dedup\n",
                self.total_retransmits(),
                self.total_timeouts(),
                self.total_rnr_naks(),
                self.total_qp_resets(),
                self.total_dup_drops(),
            ));
        }
        if self.decisions.chose_ob + self.decisions.chose_dd > 0 {
            out.push_str(&format!(
                "shards: adaptive — {}\n",
                adaptive_summary(&self.decisions)
            ));
        }
        out
    }

    /// The machine-readable dump (same schema stamp as `BENCH_*.json`;
    /// see [`json::SCHEMA_VERSION`]).
    pub fn to_json(&self) -> String {
        let shards: Vec<String> = self.per_shard.iter().map(|r| r.to_json()).collect();
        let d = &self.decisions;
        let decisions = json::obj(&[
            ("chose_ob", d.chose_ob.to_string()),
            ("chose_dd", d.chose_dd.to_string()),
            ("adaptive_switches", d.adaptive_switches.to_string()),
            (
                "quorum_hist",
                json::arr(
                    &d.quorum_hist.iter().map(|n| n.to_string()).collect::<Vec<_>>(),
                ),
            ),
            (
                "cap_hist",
                json::arr(
                    &d.cap_hist
                        .iter()
                        .map(|&(cap, n)| {
                            json::obj(&[
                                ("cap", cap.to_string()),
                                ("count", n.to_string()),
                            ])
                        })
                        .collect::<Vec<_>>(),
                ),
            ),
            ("feedback_samples", d.feedback_samples.to_string()),
            ("mean_err_pct", json::num(d.mean_err_pct())),
        ]);
        let doc = json::obj(&[
            ("schema_version", json::SCHEMA_VERSION.to_string()),
            ("map", json::esc(&self.map)),
            ("decisions", decisions),
            ("shards", json::arr(&shards)),
        ]);
        format!("{doc}\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AckPolicy, Platform, ReplicationConfig};
    use crate::net::{FaultsConfig, OnLoss, WriteMeta};
    use crate::sim::ThreadClock;

    #[test]
    fn report_captures_group_shape() {
        let p = Platform::default();
        let repl = ReplicationConfig::new(3, AckPolicy::Quorum(2));
        let mut f = Fabric::new(&p, &repl, true);
        let mut t = ThreadClock::new(0);
        for s in 0..3u64 {
            f.post_write_wt(
                &mut t,
                WriteMeta {
                    addr: 0x40 * (1 + s),
                    val: s,
                    thread: 0,
                    txn: 0,
                    epoch: 0,
                    seq: s,
                },
            );
        }
        f.rdfence(&mut t);
        let r = GroupReport::from_fabric(&f);
        assert_eq!(r.backups(), 3);
        assert_eq!(r.required, 2);
        assert_eq!(r.policy, "quorum:2");
        assert_eq!(r.blocking_waits, 1);
        assert_eq!(r.group_fence_ns, 0);
        assert_eq!(r.fences_issued, 1, "the rdfence issued its own verb");
        assert_eq!(r.fence_piggybacks, 0);
        assert_eq!(r.piggyback_ratio(), 0.0);
        assert!(r.mean_block_ns() >= 0.0);
        assert_eq!(r.resync_bytes(), 0);
        assert_eq!(r.total_dead_ns(), 0);
        assert!(r.stalled.is_none());
        // Eager posting: one doorbell per WQE, batch factor exactly 1,
        // every wire WQE single-line, nothing coalesced.
        assert_eq!(r.flush_policy, "eager");
        assert_eq!(r.coalesce, "none");
        assert_eq!(r.doorbells(), 9, "3 writes x 3 backups");
        assert_eq!(r.posted_wqes, 9);
        assert_eq!(r.wire_wqes(), 9);
        assert_eq!(r.combined_writes, 0);
        assert!((r.mean_batch() - 1.0).abs() < 1e-9);
        assert!((r.mean_span() - 1.0).abs() < 1e-9);
        assert_eq!(r.span_hist.max(), 1);
        let text = r.render();
        assert!(text.contains("3 backups"));
        assert!(text.contains("quorum:2"));
        assert!(text.contains("alive"));
        assert!(text.contains("doorbells"), "{text}");
        assert!(text.contains("mean batch"), "{text}");
        // One line per backup plus header/rule/summary.
        assert!(text.lines().count() >= 6, "{text}");
    }

    #[test]
    fn lag_zero_for_single_backup_before_any_fence() {
        let p = Platform::default();
        let f = Fabric::single(&p, false);
        let r = GroupReport::from_fabric(&f);
        assert_eq!(r.backups(), 1);
        assert_eq!(r.horizon_lag(), 0);
        assert_eq!(r.fence_lag(), 0);
        assert_eq!(r.mean_block_ns(), 0.0);
    }

    #[test]
    fn sharded_report_rolls_up_per_shard_groups() {
        use crate::config::StrategyKind;
        use crate::coordinator::{ShardMapSpec, ShardingConfig, ThreadCtx};
        use crate::net::FaultsConfig;
        let mut m = Mirror::try_build_sharded(
            Platform::default(),
            StrategyKind::SmOb,
            None,
            ReplicationConfig::new(2, AckPolicy::All),
            FaultsConfig::default(),
            ShardingConfig::new(2, ShardMapSpec::Modulo),
            true,
        )
        .unwrap();
        let mut t = ThreadCtx::new(0);
        m.txn_begin(&mut t, None);
        for i in 0..4u64 {
            let addr = i * 64; // two lines per shard under modulo-2
            m.store(&mut t, addr, i);
            m.clwb(&mut t, addr);
        }
        m.sfence(&mut t);
        m.txn_commit(&mut t);
        let r = ShardedReport::from_mirror(&m);
        assert_eq!(r.shards(), 2);
        assert_eq!(r.total_writes(), 8, "2 lines x 2 backups x 2 shards");
        assert!((r.write_skew() - 1.0).abs() < 1e-9, "balanced: {}", r.write_skew());
        let text = r.render();
        assert!(text.contains("shard 0:"), "{text}");
        assert!(text.contains("shard 1:"), "{text}");
        assert!(text.contains("write skew"), "{text}");
        let j = r.to_json();
        assert!(j.contains("\"schema_version\":"), "{j}");
        assert!(j.contains("\"map\":\"modulo x2\""), "{j}");
        assert!(j.contains("\"backups\":["), "{j}");
        assert!(j.matches("\"policy\":\"all\"").count() == 2, "{j}");
        assert!(j.contains("\"doorbells\":"), "{j}");
        assert!(j.contains("\"group_fence_ns\":0"), "{j}");
        assert!(j.contains("\"fences_issued\":"), "{j}");
        assert!(j.contains("\"fence_piggybacks\":0"), "{j}");
        assert_eq!(r.total_fences_issued(), 2, "one commit rdfence per touched shard");
        assert_eq!(r.total_fence_piggybacks(), 0);
        assert!(j.contains("\"mean_batch\":"), "{j}");
        assert!(j.contains("\"wire_wqes\":"), "{j}");
        assert!(j.contains("\"combined_writes\":"), "{j}");
        assert!(j.contains("\"mean_span\":"), "{j}");
        assert!(j.contains("\"span_max\":"), "{j}");
        assert!(j.matches("\"flush_policy\":\"eager\"").count() == 2, "{j}");
        assert!(j.matches("\"coalesce\":\"none\"").count() == 2, "{j}");
        assert_eq!(r.total_doorbells(), 8, "eager: one doorbell per WQE");
        assert!((r.mean_batch() - 1.0).abs() < 1e-9);
        assert_eq!(r.total_wire_wqes(), 8);
        assert_eq!(r.total_combined_writes(), 0);
        assert!((r.mean_span() - 1.0).abs() < 1e-9);
        let text = r.render();
        assert!(text.contains("mean batch"), "{text}");
    }

    #[test]
    fn report_shows_doorbell_amortization_under_batching() {
        use crate::net::FlushPolicy;
        let p = Platform::default();
        let repl = ReplicationConfig::new(2, AckPolicy::All);
        let mut f = Fabric::new(&p, &repl, true).with_batching(FlushPolicy::Fence);
        let mut t = ThreadClock::new(0);
        for s in 0..6u64 {
            f.post_write_wt(
                &mut t,
                WriteMeta {
                    addr: 0x40 * (1 + s),
                    val: s,
                    thread: 0,
                    txn: 0,
                    epoch: 0,
                    seq: s,
                },
            );
        }
        f.rdfence(&mut t);
        let r = GroupReport::from_fabric(&f);
        assert_eq!(r.flush_policy, "fence");
        assert_eq!(r.posted_wqes, 12, "6 lines x 2 backups");
        assert_eq!(r.doorbells(), 2, "one doorbell per backup per flush");
        assert!((r.mean_batch() - 6.0).abs() < 1e-9, "{}", r.mean_batch());
        assert!(r.doorbells() <= r.posted_wqes);
        let text = r.render();
        assert!(text.contains("flush fence"), "{text}");
    }

    #[test]
    fn report_shows_span_amortization_under_coalescing() {
        use crate::net::{CoalesceMode, FlushPolicy};
        let p = Platform::default();
        let repl = ReplicationConfig::new(2, AckPolicy::All);
        let mut f = Fabric::new(&p, &repl, true)
            .with_batching(FlushPolicy::Fence)
            .with_coalescing(CoalesceMode::Full);
        let mut t = ThreadClock::new(0);
        // One hot rewrite + a 4-line contiguous run.
        for s in 0..2u64 {
            f.post_write_wt(
                &mut t,
                WriteMeta {
                    addr: 0x40,
                    val: s,
                    thread: 0,
                    txn: 0,
                    epoch: 0,
                    seq: s,
                },
            );
        }
        for s in 0..4u64 {
            f.post_write_wt(
                &mut t,
                WriteMeta {
                    addr: 0x1000 + 0x40 * s,
                    val: s,
                    thread: 0,
                    txn: 0,
                    epoch: 0,
                    seq: 2 + s,
                },
            );
        }
        f.rdfence(&mut t);
        let r = GroupReport::from_fabric(&f);
        assert_eq!(r.coalesce, "full");
        assert_eq!(r.combined_writes, 2, "1 dead hot write x 2 backups");
        assert_eq!(r.posted_wqes, 10, "5 surviving lines x 2 backups");
        assert_eq!(r.wire_wqes(), 4, "(hot + 4-line span) x 2 backups");
        assert!((r.mean_span() - 2.5).abs() < 1e-9, "{}", r.mean_span());
        assert_eq!(r.span_hist.max(), 4);
        assert!(r.wire_wqes() <= r.posted_wqes);
        assert!(r.doorbells() <= r.wire_wqes());
        let text = r.render();
        assert!(text.contains("coalesce full"), "{text}");
        assert!(text.contains("combined"), "{text}");
        let j = r.to_json();
        assert!(j.contains("\"coalesce\":\"full\""), "{j}");
        assert!(j.contains("\"combined_writes\":2"), "{j}");
        assert!(j.contains("\"wire_wqes\":4"), "{j}");
    }

    #[test]
    fn report_surfaces_failover_counters() {
        let p = Platform::default();
        let repl = ReplicationConfig::new(3, AckPolicy::Quorum(2));
        let faults = FaultsConfig::with_plan("kill:p@1000", OnLoss::Halt).unwrap();
        let mut f = Fabric::with_faults(&p, &repl, faults, true);
        let mut t = ThreadClock::new(0);
        f.post_write_wt(
            &mut t,
            WriteMeta {
                addr: 0x40,
                val: 0,
                thread: 0,
                txn: 0,
                epoch: 0,
                seq: 0,
            },
        );
        f.rdfence(&mut t);
        // Drive past the kill so the (direct-driven) fabric self-elects.
        t.wait_until(5_000);
        f.post_write_wt(
            &mut t,
            WriteMeta {
                addr: 0x80,
                val: 1,
                thread: 0,
                txn: 0,
                epoch: 1,
                seq: 1,
            },
        );
        f.rdfence(&mut t);
        let r = GroupReport::from_fabric(&f);
        assert_eq!(r.membership_epochs, 1);
        assert!(r.failover_downtime_ns > 0);
        assert!(r.stalled.is_none(), "quorum:2 survives a primary kill");
        let text = r.render();
        assert!(text.contains("membership epoch(s)"), "{text}");
        let j = r.to_json();
        assert!(j.contains("\"membership_epochs\":1"), "{j}");
        assert!(j.contains("\"failover_downtime_ns\":"), "{j}");
        assert!(j.contains("\"rereplicated_lines\":"), "{j}");
        assert!(j.contains("\"revoked_wqes\":"), "{j}");
        // Fault-free groups report zeros and stay silent in render.
        let quiet = Fabric::new(&p, &repl, true);
        let r = GroupReport::from_fabric(&quiet);
        assert_eq!(r.membership_epochs, 0);
        assert_eq!(r.failover_downtime_ns, 0);
        assert!(!r.render().contains("failover"), "{}", r.render());
    }

    #[test]
    fn report_surfaces_persist_domain_counters() {
        use crate::config::StrategyKind;
        use crate::coordinator::{MirrorBuilder, ThreadCtx};
        use crate::net::PersistDomain;
        let mut m = MirrorBuilder::new(Platform::default(), StrategyKind::SmOb)
            .replication(ReplicationConfig::new(2, AckPolicy::All))
            .persist_domain(PersistDomain::RpmemFlush)
            .build()
            .unwrap();
        let mut t = ThreadCtx::new(0);
        m.txn_begin(&mut t, None);
        for i in 0..4u64 {
            let addr = 0x1000 + i * 64;
            m.store(&mut t, addr, i);
            m.clwb(&mut t, addr);
        }
        m.sfence(&mut t);
        m.txn_commit(&mut t);
        let r = GroupReport::from_fabric(m.fabric());
        assert_eq!(r.persist_domain, "rpmem-flush");
        assert!(r.flush_verbs() > 0, "the commit fence must flush");
        assert!(r.flush_verbs() <= r.doorbells());
        assert!(r.volatile_window_ns() > 0);
        assert_eq!(r.compaction_lines(), 0);
        let text = r.render();
        assert!(text.contains("domain rpmem-flush"), "{text}");
        assert!(text.contains("flush verb(s)"), "{text}");
        let j = r.to_json();
        assert!(j.contains("\"persist_domain\":\"rpmem-flush\""), "{j}");
        assert!(j.contains("\"flush_verbs\":"), "{j}");
        assert!(j.contains("\"compaction_lines\":"), "{j}");
        assert!(j.contains("\"volatile_window_ns\":"), "{j}");

        // The default domain renders quietly: header names it, no
        // counter line appears.
        let quiet = Fabric::new(&Platform::default(), &ReplicationConfig::default(), false);
        let r = GroupReport::from_fabric(&quiet);
        assert_eq!(r.persist_domain, "adr");
        assert_eq!(r.flush_verbs(), 0);
        assert!(r.render().contains("domain adr"), "{}", r.render());
        assert!(!r.render().contains("flush verb"), "{}", r.render());
    }

    #[test]
    fn report_surfaces_adaptive_decisions() {
        let p = Platform::default();
        let repl = ReplicationConfig::new(2, AckPolicy::All);
        let f = Fabric::new(&p, &repl, true);
        let mut r = GroupReport::from_fabric(&f);
        // Fixed strategies leave the counters at zero: JSON carries the
        // keys, render stays silent.
        assert_eq!(r.decisions, DecisionStats::default());
        assert!(!r.render().contains("adaptive"), "{}", r.render());
        let j = r.to_json();
        assert!(j.contains("\"chose_ob\":0"), "{j}");
        assert!(j.contains("\"chose_dd\":0"), "{j}");
        assert!(j.contains("\"adaptive_switches\":0"), "{j}");
        assert!(j.contains("\"feedback_samples\":0"), "{j}");

        let d = DecisionStats {
            chose_ob: 5,
            chose_dd: 7,
            adaptive_switches: 2,
            quorum_hist: vec![0, 10, 2],
            cap_hist: vec![(1, 7), (32, 5)],
            feedback_samples: 12,
            err_pct_sum: 120.0,
        };
        r.set_decisions(&d);
        assert_eq!(r.decisions, d);
        let text = r.render();
        assert!(text.contains("adaptive — 5 ob / 7 dd, 2 switch(es)"), "{text}");
        assert!(text.contains("k=1:10"), "{text}");
        assert!(text.contains("c=32:5"), "{text}");
        assert!(text.contains("mean model err 10.0%"), "{text}");
        let j = r.to_json();
        assert!(j.contains("\"chose_ob\":5"), "{j}");
        assert!(j.contains("\"chose_dd\":7"), "{j}");
        assert!(j.contains("\"adaptive_switches\":2"), "{j}");
        assert!(j.contains("\"quorum_hist\":[0,10,2]"), "{j}");
        assert!(j.contains("\"cap\":32"), "{j}");
        assert!(j.contains("\"feedback_samples\":12"), "{j}");
        assert!(j.contains("\"mean_err_pct\":"), "{j}");
    }

    #[test]
    fn report_surfaces_transport_counters() {
        use crate::net::LinkConfig;
        let p = Platform::default();
        let repl = ReplicationConfig::new(2, AckPolicy::All);
        // Backup 1's first message is lost (one timeout + retransmit);
        // backup 0's is duplicated (dedup drops the extra copy).
        let link = LinkConfig::with_plan("drop:1@0,dup:0@0").unwrap();
        let mut f = Fabric::new(&p, &repl, true).with_link(&link);
        let mut t = ThreadClock::new(0);
        for s in 0..3u64 {
            f.post_write_wt(
                &mut t,
                WriteMeta {
                    addr: 0x40 * (1 + s),
                    val: s,
                    thread: 0,
                    txn: 0,
                    epoch: 0,
                    seq: s,
                },
            );
        }
        f.rdfence(&mut t);
        let r = GroupReport::from_fabric(&f);
        assert_eq!(r.retransmits(), 1);
        assert_eq!(r.timeouts(), 1);
        assert!(r.retransmits() >= r.timeouts());
        assert_eq!(r.rnr_naks(), 0);
        assert_eq!(r.qp_resets(), 0);
        assert!(r.backoff_ns() > 0);
        assert_eq!(r.dups_injected(), 1);
        assert_eq!(r.dup_drops(), 1);
        assert!(r.dup_drops() <= r.retransmits() + r.dups_injected());
        // Per-backup attribution: the drop sits on backup 1, the dup on
        // backup 0.
        assert_eq!(r.stats[1].retransmits, 1);
        assert_eq!(r.stats[0].dup_drops, 1);
        // Dedup never inflates the applied-write count.
        assert_eq!(r.stats[0].writes, r.stats[1].writes);
        let text = r.render();
        assert!(text.contains("group: transport"), "{text}");
        assert!(text.contains("1 retransmit(s)"), "{text}");
        let j = r.to_json();
        assert!(j.contains("\"retransmits\":1"), "{j}");
        assert!(j.contains("\"dup_drops\":1"), "{j}");
        assert!(j.contains("\"rnr_naks\":0"), "{j}");
        assert!(j.contains("\"backoff_ns\":"), "{j}");
        // A reliable wire reports zeros and stays silent in render.
        let quiet = Fabric::new(&p, &repl, true);
        let r = GroupReport::from_fabric(&quiet);
        assert_eq!(r.retransmits(), 0);
        assert_eq!(r.dup_drops(), 0);
        assert!(!r.render().contains("transport"), "{}", r.render());
    }

    #[test]
    fn report_surfaces_faults_and_stalls() {
        let p = Platform::default();
        let repl = ReplicationConfig::new(2, AckPolicy::All);
        let faults = FaultsConfig::with_plan("kill:1@0", OnLoss::Halt).unwrap();
        let mut f = Fabric::with_faults(&p, &repl, faults, true);
        let mut t = ThreadClock::new(0);
        f.post_write_wt(
            &mut t,
            WriteMeta {
                addr: 0x40,
                val: 0,
                thread: 0,
                txn: 0,
                epoch: 0,
                seq: 0,
            },
        );
        f.rdfence(&mut t);
        let r = GroupReport::from_fabric(&f);
        assert!(r.stalled.is_some());
        assert_eq!(r.on_loss, "halt");
        let text = r.render();
        assert!(text.contains("STALLED"), "{text}");
        assert!(text.contains("dead"), "{text}");
    }
}
