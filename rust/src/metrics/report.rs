//! Report formatters: text tables that mirror the paper's figures.
//!
//! * Figure 4: Transact slowdowns per `e-w` configuration and strategy.
//! * Figure 5a/5b: WHISPER normalized execution time and throughput.

use crate::util::stats::geomean;

/// Generic fixed-width text table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "column mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:>width$}", cells[i], width = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

/// One Figure-4 series point: Transact `e-w` slowdowns over NO-SM.
#[derive(Clone, Copy, Debug)]
pub struct Fig4Row {
    pub epochs: u32,
    pub writes: u32,
    pub rc: f64,
    pub ob: f64,
    pub dd: f64,
}

/// Render the Figure-4 table (+ per-strategy analytic prediction columns
/// when available).
pub fn fig4_table(rows: &[Fig4Row], predicted: Option<&[Fig4Row]>) -> String {
    let mut t = match predicted {
        Some(_) => Table::new(&[
            "cfg", "SM-RC", "SM-OB", "SM-DD", "~RC", "~OB", "~DD",
        ]),
        None => Table::new(&["cfg", "SM-RC", "SM-OB", "SM-DD"]),
    };
    for (i, r) in rows.iter().enumerate() {
        let mut cells = vec![
            format!("{}-{}", r.epochs, r.writes),
            format!("{:.1}x", r.rc),
            format!("{:.1}x", r.ob),
            format!("{:.1}x", r.dd),
        ];
        if let Some(pred) = predicted {
            let p = &pred[i];
            cells.push(format!("{:.1}x", p.rc));
            cells.push(format!("{:.1}x", p.ob));
            cells.push(format!("{:.1}x", p.dd));
        }
        t.row(cells);
    }
    format!(
        "Figure 4 — Transact slowdown over NO-SM (e-w = epochs/txn - writes/epoch)\n{}",
        t.render()
    )
}

/// One Figure-5 row: a WHISPER app's normalized results.
#[derive(Clone, Debug)]
pub struct Fig5Row {
    pub app: String,
    /// Execution time normalized to NO-SM (>= 1).
    pub time_rc: f64,
    pub time_ob: f64,
    pub time_dd: f64,
    /// Throughput normalized to NO-SM (<= 1).
    pub tput_rc: f64,
    pub tput_ob: f64,
    pub tput_dd: f64,
}

/// Render Figure 5a/5b + the headline summary (H1).
pub fn fig5_tables(rows: &[Fig5Row]) -> String {
    let mut a = Table::new(&["app", "SM-RC", "SM-OB", "SM-DD"]);
    let mut b = Table::new(&["app", "SM-RC", "SM-OB", "SM-DD"]);
    for r in rows {
        a.row(vec![
            r.app.clone(),
            format!("{:.1}x", r.time_rc),
            format!("{:.1}x", r.time_ob),
            format!("{:.1}x", r.time_dd),
        ]);
        b.row(vec![
            r.app.clone(),
            format!("{:.0}%", 100.0 * (1.0 - r.tput_rc)),
            format!("{:.0}%", 100.0 * (1.0 - r.tput_ob)),
            format!("{:.0}%", 100.0 * (1.0 - r.tput_dd)),
        ]);
    }
    let rc: Vec<f64> = rows.iter().map(|r| r.time_rc).collect();
    let ob: Vec<f64> = rows.iter().map(|r| r.time_ob).collect();
    let dd: Vec<f64> = rows.iter().map(|r| r.time_dd).collect();
    let (grc, gob, gdd) = (geomean(&rc), geomean(&ob), geomean(&dd));
    let trc: Vec<f64> = rows.iter().map(|r| r.tput_rc).collect();
    let tob: Vec<f64> = rows.iter().map(|r| r.tput_ob).collect();
    let tdd: Vec<f64> = rows.iter().map(|r| r.tput_dd).collect();
    format!(
        "Figure 5a — execution time normalized to NO-SM\n{}\n\
         Figure 5b — throughput decrease vs NO-SM\n{}\n\
         Headline (H1): exec-time overhead geomean RC={:.1}x OB={:.1}x DD={:.1}x\n\
                        OB beats RC by {:.1}x, DD beats RC by {:.1}x\n\
                        throughput drop mean RC={:.0}% OB={:.0}% DD={:.0}%\n",
        a.render(),
        b.render(),
        grc,
        gob,
        gdd,
        grc / gob,
        grc / gdd,
        100.0 * (1.0 - trc.iter().sum::<f64>() / trc.len().max(1) as f64),
        100.0 * (1.0 - tob.iter().sum::<f64>() / tob.len().max(1) as f64),
        100.0 * (1.0 - tdd.iter().sum::<f64>() / tdd.len().max(1) as f64),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["a", "long-header"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["100".into(), "2000".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[1].len(), lines[0].len());
    }

    #[test]
    #[should_panic(expected = "column mismatch")]
    fn row_width_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn fig4_renders_all_configs() {
        let rows = vec![
            Fig4Row { epochs: 1, writes: 1, rc: 44.0, ob: 40.0, dd: 39.0 },
            Fig4Row { epochs: 256, writes: 8, rc: 10.0, ob: 1.2, dd: 4.4 },
        ];
        let s = fig4_table(&rows, None);
        assert!(s.contains("1-1"));
        assert!(s.contains("256-8"));
        assert!(s.contains("44.0x"));
    }

    #[test]
    fn fig5_headline_math() {
        let rows = vec![Fig5Row {
            app: "ctree".into(),
            time_rc: 6.0,
            time_ob: 3.0,
            time_dd: 2.0,
            tput_rc: 0.15,
            tput_ob: 0.3,
            tput_dd: 0.5,
        }];
        let s = fig5_tables(&rows);
        assert!(s.contains("OB beats RC by 2.0x"));
        assert!(s.contains("DD beats RC by 3.0x"));
        assert!(s.contains("85%"));
    }
}
