//! Replica-group fabric: one requester stack ([`Rdma`] — QP set, wire,
//! remote engine with its own LLC/MC/durability ledger) **per backup**,
//! with verb fan-out, a pluggable acknowledgement policy, and runtime
//! failure dynamics.
//!
//! The paper defines its SM strategies for a single primary→backup pair;
//! enterprise SM deployments mirror to N replicas. The fabric generalizes
//! the verb layer without touching per-backup semantics: posted verbs
//! (writes, `rofence`) are fanned out to every replica — each backup
//! independently enforces its own ordering floors and drain behaviour —
//! while blocking verbs (`rcommit`, `rdfence`, sentinel reads) are
//! *issued* on every replica and the calling thread blocks once, until
//! the [`AckPolicy`] is satisfied:
//!
//! * [`AckPolicy::All`] — true synchronous mirroring; the fence completes
//!   at the **max** replica completion;
//! * [`AckPolicy::Quorum`]`(k)` / [`AckPolicy::Majority`] — the fence
//!   completes at the k-th smallest replica completion, so up to
//!   `k - 1` backup losses still leave a durable acked replica.
//!
//! **Failure dynamics** (see [`super::faults`]): a [`FaultsConfig`] plan
//! is consulted on every post and fence. Killed backups leave the fan-out
//! and the ack accounting; when the surviving count can no longer satisfy
//! the policy, [`OnLoss::Halt`] records a [`Stall`] and stops the run at
//! the kill point while [`OnLoss::Degrade`] clamps the requirement to the
//! survivors. A rejoining backup streams the ledger suffix it missed from
//! the healthiest surviving peer (hand-off latency + per-line streaming
//! cost on the simulated clock) and only re-enters the quorum once the
//! stream completes.
//!
//! **Staged WQE pipeline** (see [`super::wqe`]): all data verbs flow
//! through one choke point, `Fabric::post_data`'s staged dispatch. With
//! the default [`FlushPolicy::Eager`] every post rings one doorbell per
//! live backup — the pre-batching model, bit-exact. Under `cap:k` /
//! `fence` policies the fan-out *stages* one WQE per live backup in the
//! calling thread's [`SubmitQueue`] (charging only `wqe_stage_ns` each)
//! and [`Fabric::flush`] later posts each backup's chain with a single
//! `doorbell_ns` charge per backup — one logical batch coalesced across
//! the whole group. Every ordering/durability fence flushes the stage
//! before issuing, so batches never leak across persistence points, and
//! a backup killed between stage and doorbell has its staged WQEs
//! dropped (they never reached the wire — no ghost ledger entries).
//!
//! **Flush-time coalescing** (see [`super::wqe::CoalesceMode`]): each
//! backup's chain runs through [`super::wqe::coalesce_chain`] right
//! before its doorbell rings — write combining drops same-line
//! overwrites within an epoch (last writer survives) and scatter-gather
//! merging fuses address-contiguous runs into multi-line span WQEs.
//! Fault-drop semantics are per *chain*, and therefore per span: a
//! backup killed between stage and doorbell loses its whole chain
//! before coalescing even runs, and once a chain's doorbell rang its
//! spans are on the wire whole — a span never partially applies across
//! a kill. [`CoalesceMode::None`] leaves every chain untouched — the
//! event-for-event anchor against the plain batching pipeline.
//!
//! [`CoalesceMode::None`]: super::wqe::CoalesceMode::None
//!
//! **Primary failover** (see [`super::membership`]): `kill:p@T` in the
//! fault plan kills the *primary*. The fabric (or, sharded, the
//! coordinator — all S shards fail over as one node) elects the
//! surviving backup with the longest certified ledger prefix (ties to
//! the lowest id), revokes the old primary's write permission — staged
//! WQE chains in flight at the flush choke point are fenced and counted
//! in [`Fabric::revoked_wqes`]; they retry through the new primary —
//! re-replicates the winner's certified suffix to the lagging peers, and
//! only then admits new writes (`admit_at`). The winner's slot leaves
//! the backup group (it *is* the primary now), and the deposed primary
//! may take that slot back as a backup via `rejoin:p@T`, riding the
//! PR 2 resync path unchanged. Epoch transitions are recorded for the
//! fault-aware recovery checks ([`FaultTimeline::epochs`]).
//!
//! With `backups = 1`, `ack_policy = "all"` and an **empty fault plan**
//! the fabric is event-for-event identical to driving the single [`Rdma`]
//! stack directly (the pre-replica-group behaviour); the unit tests below
//! pin that equivalence, which is the refactor's regression anchor.

use super::faults::{
    effective_required, BackupState, FaultKind, FaultTimeline, FaultsConfig, OnLoss, Stall,
};
use super::membership::{elect, Candidate};
use super::rdma::Rdma;
use super::remote::RemoteEngine;
use super::verbs::{Verb, WriteMeta};
use super::wqe::{coalesce_chain, CoalesceMode, FlushPolicy, SubmitQueue, Wqe};
use crate::config::{AckPolicy, Platform, ReplicationConfig};
use crate::mem::{DurEvent, DurabilityLog};
use crate::metrics::LogHistogram;
use crate::sim::ThreadClock;
use crate::Ns;
use std::collections::HashSet;

/// Per-backup snapshot for metrics reports.
#[derive(Clone, Debug)]
pub struct BackupStats {
    pub id: usize,
    /// Replicated line writes received.
    pub writes: u64,
    /// Durable line writes (MC-queue admissions).
    pub persists: u64,
    /// Ordering barriers executed.
    pub barriers: u64,
    /// Replicated-but-not-yet-persistent lines (SM-RC exposure).
    pub pending_lines: usize,
    /// Latest persist instant on this backup.
    pub persist_horizon: Ns,
    /// Send-window stall attributable to this backup's stack.
    pub window_stall_ns: Ns,
    /// This backup's completion of the most recent durability fence.
    pub last_fence: Ns,
    /// Failover state at snapshot time.
    pub state: BackupState,
    /// Out-of-quorum time (ns): closed dead→alive intervals plus the
    /// still-open one, as of the fabric's last verb/settle instant (call
    /// [`Fabric::settle`] at end of run for an exact figure).
    pub dead_ns: Ns,
    /// Catch-up resyncs started.
    pub resyncs: u64,
    /// Lines streamed by catch-up resyncs (bulk + tail delta).
    pub resync_lines: u64,
    /// Hand-off latency of the most recent resync (ns).
    pub last_handoff_ns: Ns,
    /// Data-path doorbells rung toward this backup (one per WQE when
    /// eager; one per flushed chain when batching).
    pub doorbells: u64,
    /// Data WQEs launched on the wire toward this backup (a coalesced
    /// multi-line span counts once; `doorbells <= wire_wqes <= writes`).
    pub wire_wqes: u64,
    /// Explicit flush verbs that drained volatile lines on this backup
    /// (RpmemFlush domain only; `flush_verbs <= doorbells` — a non-empty
    /// drain implies at least one prior data doorbell here).
    pub flush_verbs: u64,
    /// Superseded log versions queued for background compaction
    /// (LogStructured domain only).
    pub compaction_lines: u64,
    /// Total replicated-but-volatile ns accumulated by drained lines.
    pub volatile_window_ns: u64,
    // ---- lossy-link transport (all 0 without a `[link]` config)
    /// Wire re-sends toward this backup, any cause (`>= timeouts`).
    pub retransmits: u64,
    /// ACK-timeout expiries on this backup's QPs.
    pub timeouts: u64,
    /// RNR NAKs taken at this backup's saturated pending buffer.
    pub rnr_naks: u64,
    /// QP error-state transitions healed via transient kill + rejoin.
    pub qp_resets: u64,
    /// Total timeout/backoff ns the transport spent masking this link.
    pub backoff_ns: Ns,
    /// Duplicate line deliveries injected toward this backup.
    pub dups_injected: u64,
    /// Duplicate line deliveries its PSN dedup dropped.
    pub dup_drops: u64,
}

/// N-way mirroring fabric (see module docs).
pub struct Fabric {
    replicas: Vec<Rdma>,
    policy: AckPolicy,
    /// Durable-backup count the policy statically requires (validated
    /// against `replicas.len()` at construction).
    required: usize,
    poll_cost: Ns,
    /// Per-backup completion instants of the most recent blocking fence
    /// (index = backup id; dead backups keep their last value).
    last_fence: Vec<Ns>,
    // ---- failure dynamics
    faults: FaultsConfig,
    /// Next unprocessed plan event.
    cursor: usize,
    states: Vec<BackupState>,
    /// Backups currently in `Resyncing` (cheap guard for the hot path).
    resyncing: usize,
    /// Closed out-of-quorum intervals accumulated per backup (ns).
    dead_ns: Vec<Ns>,
    resyncs: Vec<u64>,
    resync_lines: Vec<u64>,
    last_handoff_ns: Vec<Ns>,
    /// Realized alive/dead transitions `(at, backup, alive-after)`.
    transitions: Vec<(Ns, usize, bool)>,
    /// Latest instant fault state was advanced to (verbs + settle) —
    /// the "as of" point for open-interval dead-time in snapshots.
    seen: Ns,
    /// Which shard of the coordinator's address-space partition this
    /// fabric serves (0 when sharding is off); stamps [`Stall`]s so a
    /// multi-shard run attributes the unsatisfiable fence.
    shard: usize,
    stall: Option<Stall>,
    // ---- staged WQE pipeline (see `super::wqe`)
    /// When staged doorbells ring (`Eager` bypasses staging entirely).
    batching: FlushPolicy,
    /// Flush-time chain coalescing (write combining / scatter-gather);
    /// inert under eager policies — nothing is ever staged.
    coalesce: CoalesceMode,
    /// Line writes elided by write combining, summed over every
    /// backup's chains (an overwrite dropped from an N-backup flush
    /// counts N times, matching the per-backup WQE accounting).
    pub combined_writes: u64,
    /// Per-thread staging queues (index = thread id; grown on demand).
    stages: Vec<SubmitQueue>,
    /// CPU cost split of an eager post (`wqe_stage_ns + doorbell_ns`
    /// equals the pre-batching `post_cost`).
    wqe_stage_ns: Ns,
    doorbell_ns: Ns,
    // ---- per-transaction adaptive overrides (see `replication::adaptive`)
    /// Ack-quorum override for blocking fences, clamped at set time to
    /// `[required, backups]`: the configured policy is a durability
    /// floor the controller can only raise. `None` = the static policy,
    /// event-for-event (the anchor).
    txn_quorum: Option<usize>,
    /// Doorbell batch-cap override for the staged pipeline (`Some(1)` =
    /// eager). `None` = the configured [`FlushPolicy`], event-for-event.
    txn_cap: Option<usize>,
    /// Data-path doorbells rung, per backup.
    doorbells: Vec<u64>,
    /// WQEs that went through the staging queue (vs. eager posts).
    pub staged_wqes: u64,
    // ---- cross-thread group fencing (see `coordinator::pipeline`)
    /// Piggyback window (ns); 0 = every blocking fence issues its own
    /// verb (the pre-PR-6 model, event-for-event).
    group_fence_ns: Ns,
    /// Virtual instant the most recent *issued* blocking fence opened
    /// the piggyback window.
    gf_open_at: Ns,
    /// An issued fence has opened a window at least once.
    gf_armed: bool,
    // stats
    /// Blocking fences that issued their own verb (counted in every
    /// mode; with `group_fence_ns = 0` this is simply the blocking-fence
    /// count).
    pub fences_issued: u64,
    /// Blocking fences that piggybacked on another thread's in-flight
    /// fence instead of issuing (0 unless `group_fence_ns > 0`).
    pub fence_piggybacks: u64,
    pub blocking_waits: u64,
    pub blocked_ns: Ns,
    // ---- primary failover (see `super::membership`)
    /// Next unprocessed primary plan event.
    p_cursor: usize,
    /// When true, primary events are *barriers*: [`Fabric::apply_faults`]
    /// leaves them pending and the coordinator drives
    /// [`Fabric::failover_to`] / [`Fabric::primary_rejoin_at`] itself so
    /// all S shards fail over to one cross-shard winner.
    coordinated: bool,
    /// Slot whose machine currently serves as primary (`None` = the
    /// original, unelected primary). The slot itself is `Dead` while its
    /// machine holds the primary role.
    primary_slot: Option<usize>,
    /// Instant before which no new work is admitted to the wire: the
    /// election + re-replication window of the latest failover (0 = no
    /// failover yet — the clamp is a no-op, the anchor).
    admit_at: Ns,
    /// Realized epoch transitions `(at, epoch-after, winner-slot)`.
    epoch_log: Vec<(Ns, u64, usize)>,
    /// Completed membership-epoch changes (elections won).
    pub membership_epochs: u64,
    /// Total write-admission downtime across failovers (kill instant to
    /// `admit_at`).
    pub failover_downtime_ns: Ns,
    /// Certified-suffix lines the elected primaries streamed to lagging
    /// peers before admitting writes.
    pub rereplicated_lines: u64,
    /// Staged WQEs fenced by permission revocation at failover. Counted,
    /// not dropped: the lines were never on the wire under the old
    /// permission and retry through the new primary after `admit_at`.
    pub revoked_wqes: u64,
    // ---- lossy links (see `super::link`)
    /// A lossy link is configured somewhere in the group: the data
    /// dispatch points poll for QP error state after posting. False is
    /// the guard-clause anchor — no polling, no healing, no dedup.
    lossy: bool,
}

impl Fabric {
    /// Build a fault-free fabric for `repl` (the config must be
    /// pre-validated — see [`ReplicationConfig::validate`]; invalid
    /// shapes panic here).
    pub fn new(p: &Platform, repl: &ReplicationConfig, ledger: bool) -> Self {
        Self::with_faults(p, repl, FaultsConfig::default(), ledger)
    }

    /// Build a fabric with a fault plan. Both configs must be
    /// pre-validated (`faults` against `repl.backups`); invalid shapes
    /// panic here.
    pub fn with_faults(
        p: &Platform,
        repl: &ReplicationConfig,
        faults: FaultsConfig,
        ledger: bool,
    ) -> Self {
        repl.validate()
            .expect("ReplicationConfig must be validated before Fabric::new");
        faults
            .validate(repl.backups)
            .expect("FaultsConfig must be validated before Fabric::with_faults");
        let replicas: Vec<Rdma> = (0..repl.backups).map(|_| Rdma::new(p, ledger)).collect();
        let n = replicas.len();
        Fabric {
            last_fence: vec![0; n],
            replicas,
            policy: repl.ack_policy,
            required: repl.required(),
            poll_cost: p.poll_cost,
            faults,
            cursor: 0,
            states: vec![BackupState::Alive; n],
            resyncing: 0,
            dead_ns: vec![0; n],
            resyncs: vec![0; n],
            resync_lines: vec![0; n],
            last_handoff_ns: vec![0; n],
            transitions: Vec::new(),
            seen: 0,
            shard: 0,
            stall: None,
            batching: FlushPolicy::Eager,
            coalesce: CoalesceMode::None,
            combined_writes: 0,
            stages: Vec::new(),
            wqe_stage_ns: p.wqe_stage_ns,
            doorbell_ns: p.doorbell_ns,
            txn_quorum: None,
            txn_cap: None,
            doorbells: vec![0; n],
            staged_wqes: 0,
            group_fence_ns: 0,
            gf_open_at: 0,
            gf_armed: false,
            fences_issued: 0,
            fence_piggybacks: 0,
            blocking_waits: 0,
            blocked_ns: 0,
            p_cursor: 0,
            coordinated: false,
            primary_slot: None,
            admit_at: 0,
            epoch_log: Vec::new(),
            membership_epochs: 0,
            failover_downtime_ns: 0,
            rereplicated_lines: 0,
            revoked_wqes: 0,
            lossy: false,
        }
    }

    /// Attach a lossy-link config: every replica stack gets its slice
    /// of the plan, the RC retry machinery, and PSN dedup on its remote
    /// engine. Call after [`Fabric::with_shard`] — the shard salts the
    /// probabilistic modes' hash streams so sharded lanes roll
    /// independently. A disabled config is the no-op anchor. The config
    /// must be pre-validated against the group size.
    pub fn set_link(&mut self, cfg: &super::link::LinkConfig) {
        cfg.validate(self.replicas.len())
            .expect("LinkConfig must be validated before Fabric::set_link");
        if !cfg.enabled() {
            return;
        }
        let salt = self.shard as u64;
        for (b, r) in self.replicas.iter_mut().enumerate() {
            r.set_link(cfg, b, salt);
        }
        self.lossy = true;
    }

    /// Builder form of [`Fabric::set_link`].
    pub fn with_link(mut self, cfg: &super::link::LinkConfig) -> Self {
        self.set_link(cfg);
        self
    }

    /// Set the staged pipeline's flush policy (`cap:1` normalizes to
    /// `eager`, the regression anchor). Must be called before any
    /// traffic — switching mid-run would strand staged WQEs.
    pub fn set_batching(&mut self, policy: FlushPolicy) {
        debug_assert!(self.staged_pending() == 0, "set_batching mid-run");
        self.batching = policy.normalized();
    }

    /// Builder form of [`Fabric::set_batching`].
    pub fn with_batching(mut self, policy: FlushPolicy) -> Self {
        self.set_batching(policy);
        self
    }

    /// The flush policy the staged WQE pipeline runs under.
    pub fn batching(&self) -> FlushPolicy {
        self.batching
    }

    /// Set the flush-time coalescing mode (write combining /
    /// scatter-gather — see [`super::wqe::CoalesceMode`]). Must be
    /// called before any traffic, like [`Fabric::set_batching`]; inert
    /// under an eager flush policy (nothing is staged — the config
    /// layer rejects that pairing up front).
    pub fn set_coalescing(&mut self, mode: CoalesceMode) {
        debug_assert!(self.staged_pending() == 0, "set_coalescing mid-run");
        self.coalesce = mode;
    }

    /// Builder form of [`Fabric::set_coalescing`].
    pub fn with_coalescing(mut self, mode: CoalesceMode) -> Self {
        self.set_coalescing(mode);
        self
    }

    /// The coalescing mode flushed chains run through.
    pub fn coalescing(&self) -> CoalesceMode {
        self.coalesce
    }

    /// Set the cross-thread group-fence piggyback window (0 disables —
    /// the regression anchor: every blocking fence issues its own
    /// verb, event-for-event with the pre-window model). Must be
    /// called before any traffic, like [`Fabric::set_batching`].
    pub fn set_group_fence(&mut self, window: Ns) {
        debug_assert!(self.staged_pending() == 0, "set_group_fence mid-run");
        self.group_fence_ns = window;
    }

    /// Builder form of [`Fabric::set_group_fence`].
    pub fn with_group_fence(mut self, window: Ns) -> Self {
        self.set_group_fence(window);
        self
    }

    /// The group-fence piggyback window (ns; 0 = disabled).
    pub fn group_fence(&self) -> Ns {
        self.group_fence_ns
    }

    /// Per-transaction ack-quorum override (adaptive control plane).
    /// Clamped to `[required, backups]` at set time: the configured
    /// policy is a durability floor the controller may only raise.
    /// Unlike the `set_batching` family this is a per-transaction knob —
    /// it may change while other threads have staged WQEs in flight
    /// (staged lines flush under whatever policy is live at flush time;
    /// fences always cover them).
    pub fn set_txn_quorum(&mut self, k: Option<usize>) {
        self.txn_quorum = k.map(|k| k.clamp(self.required, self.replicas.len()));
    }

    /// The live per-transaction quorum override, if any.
    pub fn txn_quorum(&self) -> Option<usize> {
        self.txn_quorum
    }

    /// Per-transaction doorbell batch-cap override (adaptive control
    /// plane). `Some(1)` behaves as an eager post; under a coalescing
    /// mode the cap is clamped to >= 2 (a chain of one cannot combine —
    /// mirrors the config-layer pairing rule).
    pub fn set_txn_batch_cap(&mut self, cap: Option<usize>) {
        self.txn_cap = cap.map(|c| {
            if self.coalesce == CoalesceMode::None {
                c.max(1)
            } else {
                c.max(2)
            }
        });
    }

    /// The live per-transaction batch-cap override, if any.
    pub fn txn_batch_cap(&self) -> Option<usize> {
        self.txn_cap
    }

    /// The flush policy the data path runs under right now: the
    /// per-transaction override when one is live, else the configured
    /// policy (the anchor).
    fn effective_batching(&self) -> FlushPolicy {
        match self.txn_cap {
            Some(c) => FlushPolicy::Cap(c).normalized(),
            None => self.batching,
        }
    }

    /// The batch cap the analytic knob model should assume for this
    /// fabric's *configured* policy (used when the controller's batch
    /// knob is off): eager posts ring per line, `Fence` defers the whole
    /// epoch's writes.
    pub fn model_batch_cap(&self, writes_per_epoch: f32) -> f32 {
        match self.batching {
            FlushPolicy::Eager => 1.0,
            FlushPolicy::Cap(k) => k as f32,
            FlushPolicy::Fence => writes_per_epoch.max(1.0),
        }
    }

    /// Tag this fabric as serving shard `s` of a sharded coordinator
    /// (see [`crate::coordinator::shard`]); stalls it records carry the
    /// tag. Purely diagnostic — no behaviour depends on it.
    pub fn with_shard(mut self, s: usize) -> Self {
        self.shard = s;
        self
    }

    /// The shard this fabric serves (0 when sharding is off).
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// The paper's topology: one backup, fully synchronous.
    pub fn single(p: &Platform, ledger: bool) -> Self {
        Self::new(p, &ReplicationConfig::default(), ledger)
    }

    pub fn backups(&self) -> usize {
        self.replicas.len()
    }

    pub fn policy(&self) -> AckPolicy {
        self.policy
    }

    /// Durable backups the policy statically requires at a fence.
    pub fn required(&self) -> usize {
        self.required
    }

    /// Loss-handling mode for fences that cannot gather `required` acks.
    pub fn on_loss(&self) -> OnLoss {
        self.faults.on_loss
    }

    /// The fault configuration this fabric runs under.
    pub fn faults(&self) -> &FaultsConfig {
        &self.faults
    }

    /// Backup `i`'s remote engine (LLC/MC/ledger).
    pub fn backup(&self, i: usize) -> &RemoteEngine {
        &self.replicas[i].remote
    }

    /// Backup `i`'s full requester stack.
    pub fn replica(&self, i: usize) -> &Rdma {
        &self.replicas[i]
    }

    /// Backup `i`'s failover state.
    pub fn state(&self, i: usize) -> BackupState {
        self.states[i]
    }

    /// All backup failover states, in backup order.
    pub fn states(&self) -> &[BackupState] {
        &self.states
    }

    /// Backups currently in the quorum.
    pub fn alive_count(&self) -> usize {
        self.states.iter().filter(|s| s.is_alive()).count()
    }

    /// The first unsatisfiable durability fence, if any (the run stops
    /// there under [`OnLoss::Halt`] or a fully dead group).
    pub fn stall(&self) -> Option<&Stall> {
        self.stall.as_ref()
    }

    /// All backup durability ledgers, in backup order.
    pub fn ledgers(&self) -> Vec<&DurabilityLog> {
        self.replicas.iter().map(|r| &r.remote.ledger).collect()
    }

    /// Per-backup persist horizons, in backup order.
    pub fn persist_horizons(&self) -> Vec<Ns> {
        self.replicas
            .iter()
            .map(|r| r.remote.persist_horizon())
            .collect()
    }

    /// Latest persist instant across the whole group.
    pub fn group_horizon(&self) -> Ns {
        self.persist_horizons().into_iter().max().unwrap_or(0)
    }

    /// Per-backup completions of the most recent blocking fence.
    pub fn last_fence(&self) -> &[Ns] {
        &self.last_fence
    }

    /// Aggregate send-window stall across all backups' stacks.
    pub fn window_stall_ns(&self) -> Ns {
        self.replicas.iter().map(|r| r.window_stall_ns()).sum()
    }

    /// Aggregate posted writes across all backups' stacks.
    pub fn posted_writes(&self) -> u64 {
        self.replicas.iter().map(|r| r.posted_writes).sum()
    }

    /// Data-path doorbells rung across the whole group. Eager posts ring
    /// one per backup per WQE; staged flushes ring one per backup per
    /// chain. Fence verbs ring their own doorbells and are not counted,
    /// so `doorbells_total() <= posted_writes()` always holds.
    pub fn doorbells_total(&self) -> u64 {
        self.doorbells.iter().sum()
    }

    /// Mean data WQEs launched per doorbell (see [`super::wqe::mean_batch`]).
    pub fn mean_batch(&self) -> f64 {
        super::wqe::mean_batch(self.posted_writes(), self.doorbells_total())
    }

    /// Data WQEs launched on the wire across the whole group (a
    /// multi-line span counts once): `doorbells_total() <=
    /// wire_wqes_total() <= posted_writes()`, all three equal under
    /// eager posting.
    pub fn wire_wqes_total(&self) -> u64 {
        self.replicas.iter().map(|r| r.wire_wqes).sum()
    }

    /// Mean lines per wire WQE across the group (the scatter-gather
    /// amortization factor; see [`super::wqe::mean_span`]).
    pub fn mean_span(&self) -> f64 {
        super::wqe::mean_span(self.posted_writes(), self.wire_wqes_total())
    }

    /// The persistence discipline the backup group runs under (uniform
    /// across the group — every replica is built from one Platform).
    pub fn persist_domain(&self) -> super::remote::PersistDomain {
        self.replicas
            .first()
            .map(|r| r.persist_domain())
            .unwrap_or_default()
    }

    /// Explicit flush verbs across the group (RpmemFlush domain; each
    /// counted verb drained at least one volatile line, so
    /// `flush_verbs_total() <= doorbells_total()` holds per run).
    pub fn flush_verbs_total(&self) -> u64 {
        self.replicas.iter().map(|r| r.remote.flush_verbs).sum()
    }

    /// Superseded log versions queued for compaction across the group
    /// (LogStructured domain).
    pub fn compaction_lines_total(&self) -> u64 {
        self.replicas.iter().map(|r| r.remote.compaction_lines).sum()
    }

    /// Total replicated-but-volatile ns across the group's drained lines.
    pub fn volatile_window_ns_total(&self) -> u64 {
        self.replicas.iter().map(|r| r.remote.volatile_window_ns).sum()
    }

    /// Wire re-sends across the group, any cause (0 without a lossy
    /// link; `retransmits_total() >= timeouts_total()` always — RNR
    /// retries re-send without an ACK timeout).
    pub fn retransmits_total(&self) -> u64 {
        self.replicas
            .iter()
            .filter_map(|r| r.link())
            .map(|l| l.retransmits)
            .sum()
    }

    /// ACK-timeout expiries across the group.
    pub fn timeouts_total(&self) -> u64 {
        self.replicas
            .iter()
            .filter_map(|r| r.link())
            .map(|l| l.timeouts)
            .sum()
    }

    /// RNR NAKs across the group.
    pub fn rnr_naks_total(&self) -> u64 {
        self.replicas
            .iter()
            .filter_map(|r| r.link())
            .map(|l| l.rnr_naks)
            .sum()
    }

    /// QP error-state transitions healed across the group.
    pub fn qp_resets_total(&self) -> u64 {
        self.replicas
            .iter()
            .filter_map(|r| r.link())
            .map(|l| l.qp_resets)
            .sum()
    }

    /// Total timeout/backoff ns the transport spent masking the links.
    pub fn backoff_ns_total(&self) -> Ns {
        self.replicas
            .iter()
            .filter_map(|r| r.link())
            .map(|l| l.backoff_ns)
            .sum()
    }

    /// Duplicate line deliveries injected across the group.
    pub fn dups_injected_total(&self) -> u64 {
        self.replicas
            .iter()
            .filter_map(|r| r.link())
            .map(|l| l.dups_injected)
            .sum()
    }

    /// Duplicate line deliveries dropped by the PSN dedup across the
    /// group (`<= retransmits_total() + dups_injected_total()`).
    pub fn dup_drops_total(&self) -> u64 {
        self.replicas.iter().map(|r| r.remote.dup_drops).sum()
    }

    /// Lines-per-WQE distribution merged across every backup's stack.
    pub fn span_hist(&self) -> LogHistogram {
        let mut h = LogHistogram::new();
        for r in &self.replicas {
            h.merge(&r.span_hist);
        }
        h
    }

    /// Backup WQEs staged and awaiting a doorbell, across all threads.
    pub fn staged_pending(&self) -> usize {
        self.stages.iter().map(|q| q.len()).sum()
    }

    /// The realized alive/dead timeline (kills + resync completions) for
    /// fault-aware recovery checks. Call [`Fabric::settle`] first so
    /// events and resyncs up to the end of the run have taken effect.
    pub fn timeline(&self) -> FaultTimeline {
        FaultTimeline::new(self.replicas.len(), self.transitions.clone())
            .with_epochs(self.epoch_log.clone())
    }

    /// Slot whose machine currently serves as primary (`None` until the
    /// first failover — the original primary has no backup slot).
    pub fn primary_slot(&self) -> Option<usize> {
        self.primary_slot
    }

    /// Instant the latest failover admitted writes again (0 = none).
    pub fn admit_at(&self) -> Ns {
        self.admit_at
    }

    /// Extend the admission barrier to `until` (coordinated mode: all S
    /// shards fail over as one node, so the node admits writes only when
    /// its slowest shard finishes re-replicating). The extension counts
    /// toward this fabric's failover downtime so every lane reports the
    /// realized node-level figure.
    pub fn hold_admission(&mut self, until: Ns) {
        if until > self.admit_at {
            self.failover_downtime_ns += until - self.admit_at;
            self.admit_at = until;
        }
    }

    /// Realized membership-epoch transitions `(at, epoch-after, winner)`.
    pub fn epoch_log(&self) -> &[(Ns, u64, usize)] {
        &self.epoch_log
    }

    /// Backup `i`'s certified prefix: the durably persisted lines it can
    /// prove at an election — ledger length, or the persist counter when
    /// ledgers are off.
    pub fn certified_prefix(&self, i: usize) -> u64 {
        self.replicas[i].remote.certified_lines()
    }

    /// Advance fault state to `now` without issuing any verb (end-of-run
    /// bookkeeping before metrics/recovery).
    pub fn settle(&mut self, now: Ns) {
        self.seen = self.seen.max(now);
        self.heal_qp_errors(now);
        self.apply_faults(now);
    }

    /// Per-backup out-of-quorum time as of `now`: closed intervals plus
    /// the still-open one for backups currently dead or resyncing.
    pub fn accrued_dead_ns(&self, now: Ns) -> Vec<Ns> {
        (0..self.replicas.len())
            .map(|b| self.dead_ns_at(b, now))
            .collect()
    }

    fn dead_ns_at(&self, b: usize, now: Ns) -> Ns {
        self.dead_ns[b]
            + match self.states[b] {
                BackupState::Alive => 0,
                BackupState::Dead { since } | BackupState::Resyncing { since, .. } => {
                    now.saturating_sub(since)
                }
            }
    }

    /// Per-backup metric snapshots.
    pub fn backup_stats(&self) -> Vec<BackupStats> {
        self.replicas
            .iter()
            .enumerate()
            .map(|(id, r)| BackupStats {
                id,
                writes: r.remote.writes,
                persists: r.remote.persists,
                barriers: r.remote.barriers,
                pending_lines: r.remote.pending_lines(),
                persist_horizon: r.remote.persist_horizon(),
                window_stall_ns: r.window_stall_ns(),
                last_fence: self.last_fence[id],
                state: self.states[id],
                dead_ns: self.dead_ns_at(id, self.seen),
                resyncs: self.resyncs[id],
                resync_lines: self.resync_lines[id],
                last_handoff_ns: self.last_handoff_ns[id],
                doorbells: self.doorbells[id],
                wire_wqes: r.wire_wqes,
                flush_verbs: r.remote.flush_verbs,
                compaction_lines: r.remote.compaction_lines,
                volatile_window_ns: r.remote.volatile_window_ns,
                retransmits: r.link().map_or(0, |l| l.retransmits),
                timeouts: r.link().map_or(0, |l| l.timeouts),
                rnr_naks: r.link().map_or(0, |l| l.rnr_naks),
                qp_resets: r.link().map_or(0, |l| l.qp_resets),
                backoff_ns: r.link().map_or(0, |l| l.backoff_ns),
                dups_injected: r.link().map_or(0, |l| l.dups_injected),
                dup_drops: r.remote.dup_drops,
            })
            .collect()
    }

    // ---- failure dynamics -----------------------------------------------

    /// Advance fault state to virtual instant `now`: plan events whose
    /// time has come take effect and resyncs whose catch-up stream has
    /// finished return their backup to the quorum — merged in
    /// chronological order so the realized timeline is well-defined.
    /// Primary events join the merge too (ties: resync completions, then
    /// backup events, then primary events), except in coordinated mode
    /// where they are barriers the coordinator consumes itself.
    fn apply_faults(&mut self, now: Ns) {
        // `seen` (host-side bookkeeping only — no simulated time) must
        // advance even once the plan is exhausted, so open dead
        // intervals in snapshots stay fresh up to the last verb.
        self.seen = self.seen.max(now);
        if self.cursor >= self.faults.plan.events().len()
            && self.resyncing == 0
            && (self.coordinated || self.p_cursor >= self.faults.plan.primary_events().len())
        {
            return;
        }
        loop {
            let next_event = self
                .faults
                .plan
                .events()
                .get(self.cursor)
                .filter(|e| e.at <= now)
                .map_or(Ns::MAX, |e| e.at);
            let next_primary = if self.coordinated {
                Ns::MAX
            } else {
                self.faults
                    .plan
                    .primary_events()
                    .get(self.p_cursor)
                    .filter(|e| e.at <= now)
                    .map_or(Ns::MAX, |e| e.at)
            };
            let next_ready = (0..self.replicas.len())
                .filter_map(|b| match self.states[b] {
                    BackupState::Resyncing { ready_at, .. } if ready_at <= now => {
                        Some((ready_at, b))
                    }
                    _ => None,
                })
                .min();
            let ready_at = next_ready.map_or(Ns::MAX, |(ra, _)| ra);
            if next_event == Ns::MAX && next_primary == Ns::MAX && ready_at == Ns::MAX {
                break;
            }
            if ready_at <= next_event && ready_at <= next_primary {
                let (_, b) = next_ready.expect("ready_at < MAX implies a resyncing backup");
                self.finish_resync(b);
            } else if next_event <= next_primary {
                let ev = self.faults.plan.events()[self.cursor];
                self.cursor += 1;
                match ev.kind {
                    FaultKind::Kill => self.kill(ev.backup, ev.at),
                    FaultKind::Rejoin => self.begin_rejoin(ev.backup, ev.at),
                }
            } else {
                let ev = self.faults.plan.primary_events()[self.p_cursor];
                self.p_cursor += 1;
                match ev.kind {
                    FaultKind::Kill => self.fail_over(None, ev.at),
                    FaultKind::Rejoin => self.primary_rejoin(ev.at),
                }
            }
        }
    }

    fn kill(&mut self, b: usize, at: Ns) {
        match self.states[b] {
            BackupState::Alive => {
                // Replicated-but-undrained lines are volatile: they die
                // with the backup and must not drain after a rejoin.
                self.replicas[b].remote.drop_volatile();
                self.states[b] = BackupState::Dead { since: at };
                self.transitions.push((at, b, false));
            }
            BackupState::Resyncing { since, .. } => {
                // Killed again mid-resync: the catch-up is lost; the
                // original out-of-quorum interval keeps running.
                self.replicas[b].remote.drop_volatile();
                self.resyncing -= 1;
                self.states[b] = BackupState::Dead { since };
            }
            BackupState::Dead { .. } => {}
        }
    }

    /// The ledger suffix `b` is missing relative to the healthiest
    /// fully-alive peer (`(events, lines)`; events empty but lines
    /// counted when ledgers are disabled; nothing when no peer survives —
    /// the backup rejoins with only its own pre-kill state). An elected
    /// primary's image (its slot is `Dead` while it serves) is a valid
    /// source too — the leader certifies every acked line, so resyncs
    /// stream from it even when no backup peer survives.
    fn missed(&self, b: usize) -> (Vec<DurEvent>, u64) {
        let src = (0..self.replicas.len())
            .filter(|&i| {
                i != b && (self.states[i].is_alive() || Some(i) == self.primary_slot)
            })
            .max_by_key(|&i| (self.replicas[i].remote.persists, std::cmp::Reverse(i)));
        let Some(src) = src else {
            return (Vec::new(), 0);
        };
        self.missing_from(src, b)
    }

    /// The ledger suffix `dst` is missing relative to `src` (`(events,
    /// lines)`; events empty but lines counted when ledgers are
    /// disabled).
    fn missing_from(&self, src: usize, dst: usize) -> (Vec<DurEvent>, u64) {
        let src_r = &self.replicas[src].remote;
        let own = &self.replicas[dst].remote;
        if !own.ledger.enabled() || !src_r.ledger.enabled() {
            return (Vec::new(), src_r.persists.saturating_sub(own.persists));
        }
        let have: HashSet<(u32, u64)> = own
            .ledger
            .events()
            .iter()
            .map(|e| (e.thread, e.seq))
            .collect();
        let missing: Vec<DurEvent> = src_r
            .ledger
            .events()
            .iter()
            .filter(|e| !have.contains(&(e.thread, e.seq)))
            .copied()
            .collect();
        let lines = missing.len() as u64;
        (missing, lines)
    }

    fn begin_rejoin(&mut self, b: usize, at: Ns) {
        let since = match self.states[b] {
            BackupState::Dead { since } => since,
            // Rejoin of a live/resyncing backup: validated away; ignore.
            _ => return,
        };
        // The missing suffix *sizes* the transfer; nothing lands until
        // the stream completes (a kill mid-resync loses the catch-up).
        let (_, lines) = self.missed(b);
        let cost = self.faults.handoff_ns + lines * self.faults.resync_line_ns;
        let ready_at = at + cost;
        self.resyncs[b] += 1;
        self.last_handoff_ns[b] = cost;
        self.states[b] = BackupState::Resyncing { since, ready_at };
        self.resyncing += 1;
    }

    fn finish_resync(&mut self, b: usize) {
        let BackupState::Resyncing { since, ready_at } = self.states[b] else {
            return;
        };
        // The whole catch-up lands now: the bulk suffix that sized the
        // window, plus the tail delta fanned out while the stream ran
        // (the tail is charged no extra latency — it piggybacks on the
        // live stream the backup re-enters).
        let (missing, lines) = self.missed(b);
        self.replicas[b].remote.absorb_resync(&missing, lines, ready_at);
        self.resync_lines[b] += lines;
        self.resyncing -= 1;
        self.states[b] = BackupState::Alive;
        self.dead_ns[b] += ready_at.saturating_sub(since);
        self.transitions.push((ready_at, b, true));
    }

    /// Heal QP error states accrued since the last dispatch (see
    /// `super::link`): a replica whose link exhausted `retry_count`
    /// sits in QP error — nothing more reaches its wire — until the
    /// fabric tears the connection down and re-establishes it here.
    /// Healing is modeled as a transient kill + rejoin episode at
    /// `at`: [`Rdma::reset_qps`] clears the per-lane windows and the
    /// error flag, and the rejoin replays everything past the last
    /// remotely-acked line via the resync machinery (ledger diff from
    /// the healthiest peer). A flapping link thereby degrades into an
    /// ordinary out-of-quorum interval without any `kill:` plan event,
    /// and [`OnLoss`]::{`Halt`,`Degrade`} apply to links unchanged.
    /// Guarded by `self.lossy` so the no-link anchor never takes the
    /// extra scan.
    fn heal_qp_errors(&mut self, at: Ns) {
        if !self.lossy {
            return;
        }
        for b in 0..self.replicas.len() {
            if self.replicas[b].qp_error() {
                self.replicas[b].reset_qps();
                // A plan `kill:` may already have taken the backup out
                // between the exhaustion and this heal — then the plan's
                // own rejoin resyncs it; nothing more to do here.
                if self.states[b].is_alive() {
                    self.kill(b, at);
                    self.begin_rejoin(b, at);
                }
            }
        }
    }

    // ---- primary failover (see `super::membership`) ----------------------

    /// The primary died at `at`: revoke its permission, elect a successor
    /// (`winner` pre-elected by a sharded coordinator, or `None` to run
    /// the per-fabric election among alive slots), re-replicate the
    /// winner's certified suffix, and open the admission barrier.
    fn fail_over(&mut self, winner: Option<usize>, at: Ns) {
        // Permission revocation: the dead primary's staged-but-unrung WQE
        // chains are fenced at the flush choke point. The lines are not
        // lost — they stay staged and flush through the new primary once
        // it admits writes — but they could not have reached the wire
        // under the revoked permission, which is what the counter records.
        self.revoked_wqes += self.staged_pending() as u64;
        let winner = winner.or_else(|| {
            let field: Vec<Candidate> = (0..self.replicas.len())
                .filter(|&i| self.states[i].is_alive())
                .map(|i| Candidate {
                    id: i,
                    certified: self.certified_prefix(i),
                })
                .collect();
            elect(&field)
        });
        let Some(w) = winner else {
            // Nobody can campaign: the group is unrecoverable here.
            if self.stall.is_none() {
                self.stall = Some(Stall {
                    at,
                    alive: 0,
                    required: self.required,
                    policy: self.policy,
                    on_loss: self.faults.on_loss,
                    shard: self.shard,
                });
            }
            return;
        };
        // Re-replication: the winner streams the certified suffix each
        // lagging peer is missing before admitting writes. Streams run in
        // parallel, so the admission point tracks the largest gap.
        let mut max_lines = 0u64;
        for i in 0..self.replicas.len() {
            if i == w || !self.states[i].is_alive() {
                continue;
            }
            let (missing, lines) = self.missing_from(w, i);
            let land_at =
                at + self.faults.election.handoff_ns + lines * self.faults.election.line_ns;
            self.replicas[i].remote.absorb_resync(&missing, lines, land_at);
            self.rereplicated_lines += lines;
            max_lines = max_lines.max(lines);
        }
        let admit =
            at + self.faults.election.handoff_ns + max_lines * self.faults.election.line_ns;
        self.failover_downtime_ns += admit.saturating_sub(at);
        self.admit_at = self.admit_at.max(admit);
        // The winner's machine leaves the backup group to serve as
        // primary. No `drop_volatile`: nothing crashed — its replicated
        // state *becomes* the new primary's local image. The deposed
        // primary may take this slot back via `rejoin:p@T`.
        self.membership_epochs += 1;
        self.epoch_log.push((at, self.membership_epochs, w));
        self.primary_slot = Some(w);
        self.states[w] = BackupState::Dead { since: at };
        self.transitions.push((at, w, false));
    }

    /// The deposed primary returns as a backup, taking the slot the
    /// current primary vacated at its election; from there it rides the
    /// PR 2 resync path unchanged (hand-off + per-line catch-up stream).
    fn primary_rejoin(&mut self, at: Ns) {
        // Validated at parse time: `rejoin:p` requires a prior `kill:p`,
        // so a failover has happened and the slot exists (unless the
        // election itself found no candidate — then there is nothing to
        // rejoin into and the run is already stalled). Once the deposed
        // machine takes the slot back, the serving primary holds no slot
        // in the backup group at all (`primary_slot = None`, like the
        // original primary) — the slot's image seeds the rejoiner with
        // the group state certified at the failover instant, and the
        // PR 2 resync streams everything since.
        if let Some(w) = self.primary_slot.take() {
            self.begin_rejoin(w, at);
        }
    }

    /// When true, [`Fabric::apply_faults`] leaves primary events pending
    /// for the coordinator to consume via [`Fabric::failover_to`] /
    /// [`Fabric::primary_rejoin_at`] (one election across all shards).
    pub fn set_coordinated(&mut self, on: bool) {
        self.coordinated = on;
    }

    /// The next primary plan event due at or before `now`, if any — the
    /// coordinator polls this at op boundaries in coordinated mode.
    pub fn pending_primary_event(&self, now: Ns) -> Option<(Ns, FaultKind)> {
        self.faults
            .plan
            .primary_events()
            .get(self.p_cursor)
            .filter(|e| e.at <= now)
            .map(|e| (e.at, e.kind))
    }

    /// Consume a pending `kill:p` with a pre-elected winner (`None` when
    /// no candidate survived anywhere — records the stall). Backup events
    /// and resync completions due by `at` take effect first.
    pub fn failover_to(&mut self, winner: Option<usize>, at: Ns) {
        debug_assert!(self.coordinated, "failover_to outside coordinated mode");
        debug_assert!(
            matches!(
                self.faults.plan.primary_events().get(self.p_cursor),
                Some(e) if e.kind == FaultKind::Kill && e.at <= at
            ),
            "failover_to without a pending primary kill"
        );
        self.apply_faults(at);
        self.p_cursor += 1;
        self.fail_over(winner, at);
    }

    /// Consume a pending `rejoin:p` (coordinated mode).
    pub fn primary_rejoin_at(&mut self, at: Ns) {
        debug_assert!(self.coordinated, "primary_rejoin_at outside coordinated mode");
        self.apply_faults(at);
        self.p_cursor += 1;
        self.primary_rejoin(at);
    }

    /// Hold the calling thread at the failover admission barrier: during
    /// an election + re-replication window no new work reaches the wire
    /// (the old permission is revoked; the new primary admits writes only
    /// once its suffix is re-replicated). A no-op until a failover
    /// happens — the guard-clause anchor.
    fn admit(&self, t: &mut ThreadClock) {
        if t.now < self.admit_at {
            t.wait_until(self.admit_at);
        }
    }

    // ---- verb fan-out ----------------------------------------------------

    /// Block the calling thread until `completion` (same cost model as
    /// the single-stack path: CQ poll after the wait).
    fn block(&mut self, t: &mut ThreadClock, completion: Ns) {
        self.blocking_waits += 1;
        self.blocked_ns += completion.saturating_sub(t.now);
        t.wait_until(completion);
        t.busy(self.poll_cost);
    }

    /// Run `f` on every in-quorum backup's requester stack, in backup
    /// order — the single alive-backup fan-out helper behind every verb
    /// (the four formerly copy-pasted loops route through here or
    /// through [`Fabric::post_data`]'s staged dispatch).
    fn for_each_alive<F: FnMut(usize, &mut Rdma)>(&mut self, mut f: F) {
        for i in 0..self.replicas.len() {
            if self.states[i].is_alive() {
                f(i, &mut self.replicas[i]);
            }
        }
    }

    /// Ring one data doorbell per in-quorum backup (eager accounting —
    /// side-effect-free on simulated time; the `busy` charge is paid at
    /// the post itself).
    fn ring_alive_doorbells(&mut self) {
        for i in 0..self.replicas.len() {
            if self.states[i].is_alive() {
                self.doorbells[i] += 1;
            }
        }
    }

    /// The staged data-path dispatch all three write verbs flow through.
    ///
    /// * `Eager` (default): one stage+doorbell (`post_cost`) charge and
    ///   one wire submission per live backup, immediately — event-for-
    ///   event the pre-batching fan-out.
    /// * `Cap(k)` / `Fence`: one WQE per live backup is staged in the
    ///   calling thread's queue at `wqe_stage_ns` each; doorbells ring
    ///   at [`Fabric::flush`] (cap reached, or the next fence).
    fn post_data(&mut self, t: &mut ThreadClock, verb: Verb, meta: WriteMeta) {
        self.apply_faults(t.now);
        self.admit(t);
        // The adaptive per-txn cap (when live) substitutes for the
        // configured policy on both the eager check and the cap
        // threshold; `None` is the event-for-event anchor.
        let policy = self.effective_batching();
        if policy.is_eager() {
            let cost = self.wqe_stage_ns + self.doorbell_ns;
            self.for_each_alive(|_, r| {
                t.busy(cost);
                r.submit_data(t, verb, meta);
            });
            self.ring_alive_doorbells();
            self.heal_qp_errors(t.now);
            return;
        }
        let id = t.id;
        if self.stages.len() <= id {
            self.stages.resize_with(id + 1, SubmitQueue::default);
        }
        let mut staged = 0u64;
        for (i, state) in self.states.iter().enumerate() {
            if state.is_alive() {
                t.busy(self.wqe_stage_ns);
                self.stages[id].push(Wqe::single(verb, meta, i));
                staged += 1;
            }
        }
        self.staged_wqes += staged;
        self.stages[id].note_line();
        if let FlushPolicy::Cap(cap) = policy {
            if self.stages[id].lines() >= cap {
                self.flush(t);
            }
        }
    }

    /// Ring the staged pipeline's doorbells for the calling thread:
    /// fault state advances before every chain launch, so staged WQEs
    /// whose target died between stage and doorbell are dropped (they
    /// never reached the wire — no ghost ledger entries, and a later
    /// resync streams the lines from a peer that did flush); each
    /// surviving backup's chain is posted under a single `doorbell_ns`
    /// charge — the amortization the pipeline exists to model. A no-op
    /// when nothing is staged (always, under eager policies).
    pub fn flush(&mut self, t: &mut ThreadClock) {
        let id = t.id;
        match self.stages.get(id) {
            Some(q) if !q.is_empty() => {}
            _ => return,
        }
        // A pending failover revokes the old primary's permission before
        // any of these chains can ring: advance fault state first, then
        // hold at the admission barrier (both no-ops without primary
        // faults — `apply_faults` is idempotent and costs no sim time).
        self.apply_faults(t.now);
        self.admit(t);
        let wqes = self.stages[id].take();
        for b in 0..self.replicas.len() {
            // Each chain launch is a verb boundary: fault state advances
            // before every doorbell, so a kill crossed while an earlier
            // backup's chain posted (its window stalls advance the
            // clock) drops the later chains too. Within ONE chain the
            // granularity is the eager model's per-verb discretization
            // — once its doorbell rang, the chain is on the wire.
            self.apply_faults(t.now);
            if !self.states[b].is_alive() {
                continue;
            }
            let chain: Vec<Wqe> = wqes.iter().filter(|w| w.backup == b).cloned().collect();
            if chain.is_empty() {
                continue;
            }
            // The coalescing stage (no-op under `CoalesceMode::None`,
            // the anchor): write combining may drop superseded lines,
            // scatter-gather may fuse contiguous runs into spans. The
            // chain is already alive-filtered, so a span is launched
            // whole or not at all.
            let (chain, combined) = coalesce_chain(self.coalesce, chain);
            self.combined_writes += combined;
            t.busy(self.doorbell_ns);
            self.doorbells[b] += 1;
            self.replicas[b].post_batch(t, &chain);
        }
        self.heal_qp_errors(t.now);
    }

    /// Posted one-sided DDIO write to every live backup (SM-RC data path).
    pub fn post_write(&mut self, t: &mut ThreadClock, meta: WriteMeta) {
        self.post_data(t, Verb::Write, meta);
    }

    /// Posted write-through write to every live backup (SM-OB data path).
    pub fn post_write_wt(&mut self, t: &mut ThreadClock, meta: WriteMeta) {
        self.post_data(t, Verb::WriteWT, meta);
    }

    /// Non-temporal write on every live backup's shared QP (SM-DD data
    /// path).
    pub fn post_write_nt(&mut self, t: &mut ThreadClock, meta: WriteMeta) {
        self.post_data(t, Verb::WriteNT, meta);
    }

    /// Posted remote ordering fence on every live backup (SM-OB epochs).
    /// Ordering is a per-backup property, so no ack policy applies. A
    /// flush point: the epoch barrier must order after every staged
    /// write, so the stage's doorbells ring first.
    pub fn rofence(&mut self, t: &mut ThreadClock) {
        self.flush(t);
        self.apply_faults(t.now);
        self.admit(t);
        self.for_each_alive(|_, r| r.rofence(t));
    }

    /// Shared blocking-fence protocol: flush the staged pipeline (the
    /// writes logically precede the fence), issue the verb on every live
    /// backup, record per-backup completions, then block once per the ack
    /// policy — or record a [`Stall`] when the survivors cannot satisfy
    /// it (halt mode, or nobody left).
    fn fence(
        &mut self,
        t: &mut ThreadClock,
        issue: fn(&mut Rdma, &mut ThreadClock) -> Ns,
        join: fn(&mut Rdma, &mut ThreadClock) -> Ns,
    ) {
        if self.stall.is_some() {
            // Already stalled: the run is over; let the caller wind down.
            return;
        }
        // Durability/ordering fences are flush points: staged doorbells
        // ring before the fence verb issues (no-op under eager). Fault
        // state advances inside the flush (per chain) or just after.
        self.flush(t);
        self.apply_faults(t.now);
        self.admit(t);
        // Decide satisfiability BEFORE issuing: a fence that stalls must
        // leave no trace on the survivors (no drains, no completions).
        let alive = self.alive_count();
        // Per-txn adaptive quorum: raise the ack requirement above the
        // configured floor, never below it, and never beyond the current
        // survivor count the static policy would tolerate — so the
        // override cannot introduce a stall the static run wouldn't hit
        // (when `alive < required` the clamp collapses to `required` and
        // the fence behaves exactly as configured).
        let required = self
            .txn_quorum
            .map_or(self.required, |k| k.clamp(self.required, alive.max(self.required)));
        let eff = effective_required(required, alive, self.faults.on_loss);
        if eff == 0 {
            self.stall = Some(Stall {
                at: t.now,
                alive,
                required: self.required,
                policy: self.policy,
                on_loss: self.faults.on_loss,
                shard: self.shard,
            });
            return;
        }
        // Cross-thread group fencing: a thread reaching its durability
        // point within `group_fence_ns` of the last *issued* fence rides
        // that fence instead of posting its own — requester-side issue
        // cost (post + QP/NIC slots) is elided, but the responder-side
        // verb semantics (DDIO drain, persist waits, ledger) still run
        // for THIS thread's lines, and the ack policy below is applied
        // unchanged, so per-txn durability acks are never weakened.
        let piggyback = self.group_fence_ns > 0
            && self.gf_armed
            && t.now <= self.gf_open_at.saturating_add(self.group_fence_ns);
        if piggyback {
            self.fence_piggybacks += 1;
        } else {
            self.fences_issued += 1;
            if self.group_fence_ns > 0 {
                self.gf_open_at = t.now;
                self.gf_armed = true;
            }
        }
        let verb = if piggyback { join } else { issue };
        let mut times = Vec::with_capacity(alive);
        for i in 0..self.replicas.len() {
            if self.states[i].is_alive() {
                let c = verb(&mut self.replicas[i], t);
                self.last_fence[i] = c;
                times.push(c);
            }
        }
        times.sort_unstable();
        let done = times[eff - 1];
        self.block(t, done);
    }

    /// Blocking remote commit across the group (SM-RC fence).
    pub fn rcommit(&mut self, t: &mut ThreadClock) {
        self.fence(t, Rdma::rcommit_issue, Rdma::rcommit_piggyback);
    }

    /// Blocking remote durability fence across the group (SM-OB).
    pub fn rdfence(&mut self, t: &mut ThreadClock) {
        self.fence(t, Rdma::rdfence_issue, Rdma::rdfence_piggyback);
    }

    /// Blocking sentinel read across the group (SM-DD durability point).
    pub fn read_fence(&mut self, t: &mut ThreadClock) {
        self.fence(t, Rdma::read_fence_issue, Rdma::read_fence_piggyback);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(addr: u64, epoch: u32, seq: u64) -> WriteMeta {
        WriteMeta {
            addr,
            val: seq,
            thread: 0,
            txn: 0,
            epoch,
            seq,
        }
    }

    fn repl(backups: usize, policy: AckPolicy) -> ReplicationConfig {
        ReplicationConfig::new(backups, policy)
    }

    fn faults(plan: &str, on_loss: OnLoss) -> FaultsConfig {
        FaultsConfig::with_plan(plan, on_loss).unwrap()
    }

    /// The regression anchor: with one backup and `All`, the fabric must
    /// be event-for-event identical to driving the raw `Rdma` stack —
    /// same thread time after every verb, same ledger events, same
    /// backup counters.
    #[test]
    fn single_backup_identical_to_raw_rdma() {
        type Step = fn(&mut Rdma, &mut Fabric, &mut ThreadClock, &mut ThreadClock);
        // Each sequence mirrors one strategy's verb pattern.
        let sequences: Vec<(&str, Vec<Step>)> = vec![
            (
                "sm-rc",
                vec![
                    |r, f, tr, tf| {
                        r.post_write(tr, meta(0x40, 0, 0));
                        f.post_write(tf, meta(0x40, 0, 0));
                    },
                    |r, f, tr, tf| {
                        r.rcommit(tr);
                        f.rcommit(tf);
                    },
                    |r, f, tr, tf| {
                        r.post_write(tr, meta(0x80, 1, 1));
                        f.post_write(tf, meta(0x80, 1, 1));
                    },
                    |r, f, tr, tf| {
                        r.rcommit(tr);
                        f.rcommit(tf);
                    },
                ],
            ),
            (
                "sm-ob",
                vec![
                    |r, f, tr, tf| {
                        r.post_write_wt(tr, meta(0x40, 0, 0));
                        f.post_write_wt(tf, meta(0x40, 0, 0));
                    },
                    |r, f, tr, tf| {
                        r.rofence(tr);
                        f.rofence(tf);
                    },
                    |r, f, tr, tf| {
                        r.post_write_wt(tr, meta(0x80, 1, 1));
                        f.post_write_wt(tf, meta(0x80, 1, 1));
                    },
                    |r, f, tr, tf| {
                        r.rdfence(tr);
                        f.rdfence(tf);
                    },
                ],
            ),
            (
                "sm-dd",
                vec![
                    |r, f, tr, tf| {
                        for s in 0..6u64 {
                            r.post_write_nt(tr, meta(0x40 * (1 + s), 0, s));
                            f.post_write_nt(tf, meta(0x40 * (1 + s), 0, s));
                        }
                    },
                    |r, f, tr, tf| {
                        r.read_fence(tr);
                        f.read_fence(tf);
                    },
                ],
            ),
        ];
        for (name, steps) in sequences {
            let p = Platform::default();
            let mut r = Rdma::new(&p, true);
            let mut f = Fabric::single(&p, true);
            let mut tr = ThreadClock::new(0);
            let mut tf = ThreadClock::new(0);
            for (i, step) in steps.into_iter().enumerate() {
                step(&mut r, &mut f, &mut tr, &mut tf);
                assert_eq!(
                    tr.now, tf.now,
                    "{name} step {i}: raw {} vs fabric {}",
                    tr.now, tf.now
                );
            }
            assert_eq!(
                r.remote.ledger.events(),
                f.backup(0).ledger.events(),
                "{name}: ledgers diverged"
            );
            assert_eq!(r.remote.writes, f.backup(0).writes, "{name}");
            assert_eq!(r.remote.persists, f.backup(0).persists, "{name}");
            assert_eq!(r.remote.barriers, f.backup(0).barriers, "{name}");
            assert_eq!(
                r.remote.persist_horizon(),
                f.backup(0).persist_horizon(),
                "{name}"
            );
        }
    }

    #[test]
    fn fan_out_replicates_to_every_backup() {
        let p = Platform::default();
        let mut f = Fabric::new(&p, &repl(3, AckPolicy::All), true);
        let mut t = ThreadClock::new(0);
        for s in 0..4u64 {
            f.post_write_wt(&mut t, meta(0x40 * (1 + s), 0, s));
        }
        f.rdfence(&mut t);
        for i in 0..3 {
            assert_eq!(f.backup(i).ledger.len(), 4, "backup {i}");
        }
        // The fence completion covers every backup's persists.
        for (i, &fence) in f.last_fence().iter().enumerate() {
            assert!(
                fence >= f.backup(i).persist_horizon(),
                "backup {i}: fence {fence} < horizon {}",
                f.backup(i).persist_horizon()
            );
        }
        assert!(t.now >= f.group_horizon(), "All must cover the group");
    }

    #[test]
    fn quorum_completes_no_later_than_all() {
        let run = |policy: AckPolicy| {
            let p = Platform::default();
            let mut f = Fabric::new(&p, &repl(3, policy), false);
            let mut t = ThreadClock::new(0);
            for e in 0..4u32 {
                f.post_write_wt(&mut t, meta(0x40 * (1 + e as u64), e, e as u64));
                f.rofence(&mut t);
            }
            f.rdfence(&mut t);
            t.now
        };
        let all = run(AckPolicy::All);
        let q2 = run(AckPolicy::Quorum(2));
        let q1 = run(AckPolicy::Quorum(1));
        assert!(q2 <= all, "quorum:2 {q2} vs all {all}");
        assert!(q1 <= q2, "quorum:1 {q1} vs quorum:2 {q2}");
    }

    #[test]
    fn quorum_fence_covers_required_backups() {
        let p = Platform::default();
        let mut f = Fabric::new(&p, &repl(3, AckPolicy::Quorum(2)), true);
        let mut t = ThreadClock::new(0);
        for s in 0..5u64 {
            f.post_write_nt(&mut t, meta(0x40 * (1 + s), 0, s));
        }
        f.read_fence(&mut t);
        // At the thread's post-fence instant, at least `required` backups
        // must have completed their fence (and thus be fully durable for
        // this thread's writes).
        let covered = f
            .last_fence()
            .iter()
            .filter(|&&c| c <= t.now)
            .count();
        assert!(covered >= 2, "only {covered} backups covered at fence");
    }

    #[test]
    fn backup_stats_snapshot() {
        let p = Platform::default();
        let mut f = Fabric::new(&p, &repl(2, AckPolicy::All), true);
        let mut t = ThreadClock::new(0);
        f.post_write_wt(&mut t, meta(0x40, 0, 0));
        f.rdfence(&mut t);
        let stats = f.backup_stats();
        assert_eq!(stats.len(), 2);
        for s in &stats {
            assert_eq!(s.writes, 1);
            assert_eq!(s.persists, 1);
            assert!(s.last_fence > 0);
            assert!(s.persist_horizon > 0);
            assert_eq!(s.state, BackupState::Alive);
            assert_eq!(s.dead_ns, 0);
            assert_eq!(s.resyncs, 0);
        }
        assert_eq!(f.blocking_waits, 1);
    }

    // ---- cross-thread group fencing --------------------------------------

    /// With a zero window the fence path is the pre-window model
    /// event-for-event; `fences_issued` simply counts blocking fences
    /// (the CI invariant `fences_issued <= txns_committed` reduces to
    /// one fence per commit on the serial path).
    #[test]
    fn zero_window_counts_fences_without_changing_events() {
        let p = Platform::default();
        let mut base = Fabric::new(&p, &repl(2, AckPolicy::All), true);
        let mut gated = Fabric::new(&p, &repl(2, AckPolicy::All), true).with_group_fence(0);
        let mut tb = ThreadClock::new(0);
        let mut tg = ThreadClock::new(0);
        for e in 0..3u32 {
            base.post_write_wt(&mut tb, meta(0x40 * (1 + e as u64), e, e as u64));
            gated.post_write_wt(&mut tg, meta(0x40 * (1 + e as u64), e, e as u64));
            base.rdfence(&mut tb);
            gated.rdfence(&mut tg);
            assert_eq!(tb.now, tg.now, "epoch {e} diverged");
            assert_eq!(tb.busy_ns, tg.busy_ns, "epoch {e} busy diverged");
        }
        for b in 0..2 {
            assert_eq!(
                base.backup(b).ledger.events(),
                gated.backup(b).ledger.events(),
                "backup {b}"
            );
        }
        assert_eq!(gated.fences_issued, 3);
        assert_eq!(gated.fence_piggybacks, 0);
        assert_eq!(base.fences_issued, 3);
    }

    /// A second thread fencing within the window piggybacks: requester
    /// side issue cost is elided (busy drops vs. the serial run), but
    /// its own lines still drain and persist on every backup before it
    /// unblocks — the ack policy is applied to the joined completion
    /// unchanged.
    #[test]
    fn group_fence_window_piggybacks_across_threads() {
        let mt = |addr: u64, thread: u32, seq: u64| WriteMeta {
            addr,
            val: seq,
            thread,
            txn: 0,
            epoch: 0,
            seq,
        };
        let run = |window: Ns| {
            let p = Platform::default();
            let mut f = Fabric::new(&p, &repl(2, AckPolicy::All), true).with_group_fence(window);
            let mut t0 = ThreadClock::new(0);
            let mut t1 = ThreadClock::new(1);
            f.post_write_wt(&mut t0, mt(0x40, 0, 0));
            f.rdfence(&mut t0);
            f.post_write_wt(&mut t1, mt(0x80, 1, 1));
            f.rdfence(&mut t1);
            (f, t1)
        };
        let (serial, s1) = run(0);
        let (grouped, g1) = run(100_000);
        assert_eq!(serial.fences_issued, 2);
        assert_eq!(serial.fence_piggybacks, 0);
        assert_eq!(grouped.fences_issued, 1);
        assert_eq!(grouped.fence_piggybacks, 1);
        // Requester-side post cost elided on the piggybacked fence.
        assert!(
            g1.busy_ns < s1.busy_ns,
            "piggyback busy {} !< serial busy {}",
            g1.busy_ns,
            s1.busy_ns
        );
        // Durability never weakened: both threads' lines are persistent
        // on both backups no later than thread 1's unblock instant.
        for s in grouped.backup_stats() {
            assert_eq!(s.persists, 2);
            assert!(
                s.persist_horizon <= g1.now,
                "horizon {} past unblock {}",
                s.persist_horizon,
                g1.now
            );
        }
        // A fence landing beyond the window opens a fresh one.
        let (mut grouped, mut g1) = run(100_000);
        g1.wait_until(1_000_000);
        grouped.post_write_wt(&mut g1, mt(0xC0, 1, 2));
        grouped.rdfence(&mut g1);
        assert_eq!(grouped.fences_issued, 2);
        assert_eq!(grouped.fence_piggybacks, 1);
    }

    // ---- staged WQE pipeline ---------------------------------------------

    /// The batching anchor: `cap:1` IS the eager model. A fabric built
    /// with `Cap(1)` must normalize to `Eager` and stay event-for-event
    /// identical to the default fabric — same thread time after every
    /// verb, same ledger.
    #[test]
    fn cap_one_normalizes_to_the_eager_anchor() {
        let p = Platform::default();
        let mut base = Fabric::new(&p, &repl(2, AckPolicy::All), true);
        let mut anchored =
            Fabric::new(&p, &repl(2, AckPolicy::All), true).with_batching(FlushPolicy::Cap(1));
        assert_eq!(anchored.batching(), FlushPolicy::Eager);
        let mut tb = ThreadClock::new(0);
        let mut ta = ThreadClock::new(0);
        for e in 0..4u32 {
            base.post_write_wt(&mut tb, meta(0x40 * (1 + e as u64), e, e as u64));
            anchored.post_write_wt(&mut ta, meta(0x40 * (1 + e as u64), e, e as u64));
            assert_eq!(tb.now, ta.now, "epoch {e} diverged");
            base.rofence(&mut tb);
            anchored.rofence(&mut ta);
        }
        base.rdfence(&mut tb);
        anchored.rdfence(&mut ta);
        assert_eq!(tb.now, ta.now);
        for b in 0..2 {
            assert_eq!(
                base.backup(b).ledger.events(),
                anchored.backup(b).ledger.events(),
                "backup {b}"
            );
        }
        assert_eq!(base.doorbells_total(), anchored.doorbells_total());
    }

    /// Fence-policy batching must reproduce the eager path's per-backup
    /// ledger order exactly (only instants move) while ringing one
    /// doorbell per backup per epoch instead of one per WQE.
    #[test]
    fn fence_policy_preserves_ledger_order_with_fewer_doorbells() {
        let p = Platform::default();
        let drive = |f: &mut Fabric| -> Ns {
            let mut t = ThreadClock::new(0);
            for e in 0..3u32 {
                for w in 0..4u64 {
                    let s = e as u64 * 4 + w;
                    f.post_write_wt(&mut t, meta(0x40 * (1 + s), e, s));
                }
                f.rofence(&mut t);
            }
            f.rdfence(&mut t);
            t.now
        };
        let mut eager = Fabric::new(&p, &repl(2, AckPolicy::All), true);
        drive(&mut eager);
        let mut batched =
            Fabric::new(&p, &repl(2, AckPolicy::All), true).with_batching(FlushPolicy::Fence);
        drive(&mut batched);
        let proj = |f: &Fabric, b: usize| -> Vec<(u32, u64, u64)> {
            f.backup(b).ledger.events().iter().map(|e| (e.thread, e.seq, e.addr)).collect()
        };
        for b in 0..2 {
            assert_eq!(proj(&eager, b), proj(&batched, b), "backup {b}");
        }
        // 12 WQEs per backup: eager rings 12 doorbells each, fence-mode
        // rings one per epoch flush (3 each).
        assert_eq!(eager.doorbells_total(), 24);
        assert_eq!(batched.doorbells_total(), 6);
        assert_eq!(batched.posted_writes(), eager.posted_writes());
        assert_eq!(batched.staged_wqes, 24);
        assert_eq!(batched.staged_pending(), 0, "fences must drain the stage");
        assert!(batched.mean_batch() > eager.mean_batch());
        assert!(batched.doorbells_total() <= batched.posted_writes());
    }

    #[test]
    fn cap_policy_flushes_mid_epoch() {
        let p = Platform::default();
        let mut f =
            Fabric::new(&p, &repl(2, AckPolicy::All), true).with_batching(FlushPolicy::Cap(2));
        let mut t = ThreadClock::new(0);
        for s in 0..3u64 {
            f.post_write_wt(&mut t, meta(0x40 * (1 + s), 0, s));
        }
        // Cap 2: one flush after the second line; the third stays staged.
        assert_eq!(f.staged_pending(), 2, "one line x 2 backups staged");
        assert_eq!(f.doorbells_total(), 2);
        f.rdfence(&mut t);
        assert_eq!(f.staged_pending(), 0);
        assert_eq!(f.doorbells_total(), 4);
        for b in 0..2 {
            assert_eq!(f.backup(b).ledger.len(), 3, "backup {b}");
        }
        assert!((f.mean_batch() - 1.5).abs() < 1e-9, "{}", f.mean_batch());
    }

    /// A kill landing between stage and doorbell drops only the dead
    /// backup's staged WQEs: survivors get the full chain, the corpse's
    /// ledger shows nothing from the batch.
    #[test]
    fn kill_between_stage_and_doorbell_drops_only_dead_wqes() {
        let p = Platform::default();
        let mut f = Fabric::with_faults(
            &p,
            &repl(3, AckPolicy::Quorum(2)),
            faults("kill:2@5000", OnLoss::Halt),
            true,
        )
        .with_batching(FlushPolicy::Fence);
        let mut t = ThreadClock::new(0);
        // Staged before the kill instant...
        for s in 0..4u64 {
            f.post_write_wt(&mut t, meta(0x40 * (1 + s), 0, s));
        }
        assert!(t.now < 5_000, "staging must predate the kill, t={}", t.now);
        assert_eq!(f.staged_pending(), 12, "4 lines x 3 backups");
        // ...doorbell rung after it: the dead backup's WQEs are dropped.
        t.wait_until(6_000);
        f.rdfence(&mut t);
        assert!(f.stall().is_none(), "quorum:2 tolerates the loss");
        for b in 0..2 {
            assert_eq!(f.backup(b).ledger.len(), 4, "survivor {b}");
        }
        assert_eq!(f.backup(2).ledger.len(), 0, "dead backup saw a staged WQE");
        assert_eq!(f.state(2), BackupState::Dead { since: 5_000 });
        assert_eq!(f.staged_pending(), 0, "dropped WQEs must not linger");
    }

    // ---- flush-time coalescing -------------------------------------------

    /// Scatter-gather on a contiguous append run: fewer wire WQEs, the
    /// exact same per-backup ledger events as the uncoalesced chain.
    #[test]
    fn sg_coalescing_merges_contiguous_chains() {
        let p = Platform::default();
        let drive = |f: &mut Fabric| {
            let mut t = ThreadClock::new(0);
            for s in 0..6u64 {
                f.post_write_wt(&mut t, meta(0x1000 + 0x40 * s, 0, s));
            }
            f.rdfence(&mut t);
        };
        let mut plain =
            Fabric::new(&p, &repl(2, AckPolicy::All), true).with_batching(FlushPolicy::Fence);
        drive(&mut plain);
        let mut sg = Fabric::new(&p, &repl(2, AckPolicy::All), true)
            .with_batching(FlushPolicy::Fence)
            .with_coalescing(CoalesceMode::Sg);
        assert_eq!(sg.coalescing(), CoalesceMode::Sg);
        drive(&mut sg);
        let proj = |f: &Fabric, b: usize| -> Vec<(u64, u64)> {
            f.backup(b).ledger.events().iter().map(|e| (e.addr, e.seq)).collect()
        };
        for b in 0..2 {
            assert_eq!(proj(&plain, b), proj(&sg, b), "backup {b}: sg changed events");
        }
        // 6 contiguous lines x 2 backups: one 6-line span per backup.
        assert_eq!(plain.wire_wqes_total(), 12);
        assert_eq!(sg.wire_wqes_total(), 2);
        assert_eq!(sg.posted_writes(), plain.posted_writes());
        assert_eq!(sg.combined_writes, 0, "sg drops nothing");
        assert!((sg.mean_span() - 6.0).abs() < 1e-9, "{}", sg.mean_span());
        assert_eq!(sg.span_hist().max(), 6);
        assert!(sg.doorbells_total() <= sg.wire_wqes_total());
    }

    /// Write combining on a hot line: the superseded overwrites never
    /// reach the wire, the last writer's ledger entry survives.
    #[test]
    fn combine_coalescing_drops_superseded_overwrites() {
        let p = Platform::default();
        let mut f = Fabric::new(&p, &repl(2, AckPolicy::All), true)
            .with_batching(FlushPolicy::Fence)
            .with_coalescing(CoalesceMode::Combine);
        let mut t = ThreadClock::new(0);
        // Hot line 0x40 rewritten 3x in the epoch, one cold line.
        for s in 0..3u64 {
            f.post_write_wt(&mut t, meta(0x40, 0, s));
        }
        f.post_write_wt(&mut t, meta(0x200, 0, 3));
        f.rdfence(&mut t);
        for b in 0..2 {
            let evs = f.backup(b).ledger.events();
            assert_eq!(evs.len(), 2, "backup {b}");
            let hot = evs.iter().find(|e| e.addr == 0x40).unwrap();
            assert_eq!((hot.seq, hot.val), (2, 2), "last writer must survive");
        }
        assert_eq!(f.combined_writes, 4, "2 dropped lines x 2 backups");
        assert_eq!(f.posted_writes(), 4, "2 surviving lines x 2 backups");
        assert_eq!(f.staged_wqes, 8, "staging saw all 4 lines x 2 backups");
    }

    /// The anchor: `CoalesceMode::None` under any staged policy is
    /// event-for-event the plain batching pipeline — identical thread
    /// timeline, ledger, and counters.
    #[test]
    fn coalesce_none_is_bit_exact_with_plain_batching() {
        let p = Platform::default();
        let drive = |f: &mut Fabric| -> Ns {
            let mut t = ThreadClock::new(0);
            for e in 0..3u32 {
                for w in 0..4u64 {
                    let s = e as u64 * 4 + w;
                    // A mix of contiguous and hot-line traffic: the
                    // None mode must not touch any of it.
                    let addr = if w == 3 { 0x40 } else { 0x1000 + 0x40 * s };
                    f.post_write_wt(&mut t, meta(addr, e, s));
                }
                f.rofence(&mut t);
            }
            f.rdfence(&mut t);
            t.now
        };
        let mut plain =
            Fabric::new(&p, &repl(2, AckPolicy::All), true).with_batching(FlushPolicy::Fence);
        let t_plain = drive(&mut plain);
        let mut none = Fabric::new(&p, &repl(2, AckPolicy::All), true)
            .with_batching(FlushPolicy::Fence)
            .with_coalescing(CoalesceMode::None);
        let t_none = drive(&mut none);
        assert_eq!(t_plain, t_none, "None mode moved the thread timeline");
        for b in 0..2 {
            assert_eq!(
                plain.backup(b).ledger.events(),
                none.backup(b).ledger.events(),
                "backup {b}"
            );
        }
        assert_eq!(plain.wire_wqes_total(), none.wire_wqes_total());
        assert_eq!(plain.doorbells_total(), none.doorbells_total());
        assert_eq!(none.combined_writes, 0);
    }

    // ---- failure dynamics ------------------------------------------------

    #[test]
    fn killed_backup_leaves_fanout_and_acks() {
        let p = Platform::default();
        let mut f = Fabric::with_faults(
            &p,
            &repl(3, AckPolicy::All),
            faults("kill:2@0", OnLoss::Degrade),
            true,
        );
        let mut t = ThreadClock::new(0);
        for s in 0..4u64 {
            f.post_write_wt(&mut t, meta(0x40 * (1 + s), 0, s));
        }
        f.rdfence(&mut t);
        assert_eq!(f.backup(0).ledger.len(), 4);
        assert_eq!(f.backup(1).ledger.len(), 4);
        assert_eq!(f.backup(2).ledger.len(), 0, "dead backup must see nothing");
        assert!(f.stall().is_none(), "degrade mode must not stall");
        assert_eq!(f.last_fence()[2], 0, "dead backup never fenced");
        assert_eq!(f.state(2), BackupState::Dead { since: 0 });
        assert_eq!(f.alive_count(), 2);
        // The degraded All fence still covers both survivors.
        for i in 0..2 {
            assert!(t.now >= f.backup(i).persist_horizon(), "backup {i}");
        }
    }

    #[test]
    fn halt_mode_stalls_when_all_cannot_ack() {
        let p = Platform::default();
        let mut f = Fabric::with_faults(
            &p,
            &repl(2, AckPolicy::All),
            faults("kill:0@0", OnLoss::Halt),
            false,
        );
        let mut t = ThreadClock::new(0);
        f.post_write_wt(&mut t, meta(0x40, 0, 0));
        let before = t.now;
        f.rdfence(&mut t);
        let s = *f.stall().expect("all + halt with a dead backup must stall");
        assert_eq!(s.required, 2);
        assert_eq!(s.alive, 1);
        assert_eq!(s.policy, AckPolicy::All);
        assert_eq!(s.on_loss, OnLoss::Halt);
        // A stalled fence does not block the thread on the wire.
        assert!(t.now < before + 2600, "stalled fence must not pay the RTT");
        // Subsequent fences short-circuit; the stall is stable.
        f.rdfence(&mut t);
        assert_eq!(f.stall().unwrap().at, s.at);
        assert_eq!(f.blocking_waits, 0);
    }

    #[test]
    fn quorum_survives_tolerated_loss_under_halt() {
        let p = Platform::default();
        let mut f = Fabric::with_faults(
            &p,
            &repl(3, AckPolicy::Quorum(2)),
            faults("kill:1@0", OnLoss::Halt),
            false,
        );
        let mut t = ThreadClock::new(0);
        f.post_write_wt(&mut t, meta(0x40, 0, 0));
        f.rdfence(&mut t);
        assert!(f.stall().is_none(), "2 survivors satisfy quorum:2");
        assert!(t.now >= 2600, "fence must still pay the round trip");
    }

    #[test]
    fn fully_dead_group_stalls_even_in_degrade() {
        let p = Platform::default();
        let mut f = Fabric::with_faults(
            &p,
            &repl(2, AckPolicy::Quorum(1)),
            faults("kill:0@0,kill:1@0", OnLoss::Degrade),
            false,
        );
        let mut t = ThreadClock::new(0);
        f.post_write_wt(&mut t, meta(0x40, 0, 0));
        f.rdfence(&mut t);
        let s = f.stall().expect("no survivors: must stall");
        assert_eq!(s.alive, 0);
    }

    #[test]
    fn rejoin_streams_missed_suffix_and_reenters_quorum() {
        let p = Platform::default();
        let mut f = Fabric::with_faults(
            &p,
            &repl(3, AckPolicy::Quorum(2)),
            faults("kill:1@10000,rejoin:1@40000", OnLoss::Halt),
            true,
        );
        let mut t = ThreadClock::new(0);
        // Epoch 0 reaches all three backups.
        f.post_write_wt(&mut t, meta(0x40, 0, 0));
        f.rdfence(&mut t);
        // Jump past the kill: epoch 1 reaches only the survivors.
        t.wait_until(10_001);
        f.post_write_wt(&mut t, meta(0x80, 1, 1));
        f.rdfence(&mut t);
        assert_eq!(f.backup(1).ledger.len(), 1, "missed while dead");
        assert_eq!(f.state(1), BackupState::Dead { since: 10_000 });
        // Jump past the rejoin: the resync starts; not yet in the quorum.
        t.wait_until(40_001);
        f.post_write_wt(&mut t, meta(0xc0, 2, 2));
        assert!(
            matches!(f.state(1), BackupState::Resyncing { .. }),
            "resync must be running, got {:?}",
            f.state(1)
        );
        // Jump past the resync window (handoff + lines * per-line cost).
        t.wait_until(200_000);
        f.post_write_wt(&mut t, meta(0x100, 3, 3));
        f.rdfence(&mut t);
        assert_eq!(f.state(1), BackupState::Alive);
        assert!(f.stall().is_none());
        // Bulk + tail delta caught the backup fully up.
        assert_eq!(f.backup(1).ledger.len(), 4, "resync must close the gap");
        let stats = f.backup_stats();
        assert_eq!(stats[1].resyncs, 1);
        assert!(stats[1].resync_lines >= 2, "missed epoch-1/2 lines streamed");
        assert!(stats[1].last_handoff_ns >= f.faults().handoff_ns);
        assert!(stats[1].dead_ns > 0, "out-of-quorum time recorded");
        // The replayed suffix respects per-thread epoch order: nothing
        // replays before what the backup already held.
        crate::recovery::check_epoch_ordering(&f.backup(1).ledger).unwrap();
        // Realized timeline: down at the kill, up at resync completion.
        let tl = f.timeline();
        assert_eq!(tl.alive_count_at(10_000), 2);
        assert_eq!(tl.alive_count_at(200_000), 3);
    }

    #[test]
    fn kill_during_resync_loses_the_catch_up() {
        // ready_at = 2000 + handoff(10_000) + lines*100 lands after the
        // second kill at 3000, so the kill aborts the resync: nothing
        // from the catch-up stream may remain on the backup.
        let p = Platform::default();
        let mut f = Fabric::with_faults(
            &p,
            &repl(3, AckPolicy::Quorum(1)),
            faults("kill:1@1000,rejoin:1@2000,kill:1@3000", OnLoss::Degrade),
            true,
        );
        let mut t = ThreadClock::new(0);
        f.post_write_wt(&mut t, meta(0x40, 0, 0)); // reaches all three
        t.wait_until(1_500);
        f.post_write_wt(&mut t, meta(0x80, 1, 1)); // missed by backup 1
        t.wait_until(5_000);
        f.post_write_wt(&mut t, meta(0xc0, 2, 2)); // rejoin + mid-resync kill
        f.rdfence(&mut t);
        assert!(
            matches!(f.state(1), BackupState::Dead { .. }),
            "killed mid-resync, got {:?}",
            f.state(1)
        );
        // The aborted transfer left no events, counters, or horizon.
        assert_eq!(f.backup(1).ledger.len(), 1, "catch-up must be lost");
        assert_eq!(f.backup(1).persists, 1);
        assert!(f.backup(1).persist_horizon() < 2_000);
        let stats = f.backup_stats();
        assert_eq!(stats[1].resyncs, 1, "the attempt itself is counted");
        assert_eq!(stats[1].resync_lines, 0, "but nothing was streamed");
        // A later missed() must still see those lines as missing: settle
        // far in the future with a fresh rejoin impossible (plan is
        // spent), so just confirm the survivors are intact.
        assert_eq!(f.alive_count(), 2);
        assert_eq!(f.timeline().alive_count_at(5_000), 2);
    }

    #[test]
    fn empty_plan_with_kill_free_run_keeps_full_quorum() {
        let p = Platform::default();
        let mut f = Fabric::with_faults(
            &p,
            &repl(3, AckPolicy::All),
            FaultsConfig::default(),
            false,
        );
        let mut t = ThreadClock::new(0);
        f.post_write_wt(&mut t, meta(0x40, 0, 0));
        f.rdfence(&mut t);
        assert_eq!(f.alive_count(), 3);
        assert!(f.stall().is_none());
        assert!(f.timeline().transitions().is_empty());
        assert_eq!(f.accrued_dead_ns(t.now), vec![0, 0, 0]);
        assert_eq!(f.membership_epochs, 0);
        assert_eq!(f.primary_slot(), None);
        assert_eq!(f.admit_at(), 0, "no failover: the admission clamp is inert");
    }

    // ---- primary failover ------------------------------------------------

    #[test]
    fn primary_kill_elects_and_holds_writes_until_admission() {
        let p = Platform::default();
        let mut f = Fabric::with_faults(
            &p,
            &repl(3, AckPolicy::Quorum(2)),
            faults("kill:p@10000", OnLoss::Halt),
            true,
        );
        let mut t = ThreadClock::new(0);
        f.post_write_wt(&mut t, meta(0x40, 0, 0));
        f.post_write_wt(&mut t, meta(0x80, 0, 1));
        f.rdfence(&mut t);
        assert_eq!(f.alive_count(), 3);
        // Cross the kill: the next verb runs the election. All three
        // candidates hold equal certified prefixes (the synchronous
        // fan-out keeps live peers converged), so the tie breaks to the
        // lowest id.
        t.wait_until(10_001);
        f.post_write_wt(&mut t, meta(0xc0, 1, 2));
        assert_eq!(f.membership_epochs, 1);
        assert_eq!(f.primary_slot(), Some(0));
        assert_eq!(f.state(0), BackupState::Dead { since: 10_000 });
        assert_eq!(f.epoch_log(), &[(10_000, 1, 0)]);
        // Converged peers: nothing to re-replicate, so the admission
        // barrier is the bare election hand-off.
        assert_eq!(f.rereplicated_lines, 0);
        assert_eq!(f.admit_at(), 10_000 + f.faults().election.handoff_ns);
        assert_eq!(f.failover_downtime_ns, f.faults().election.handoff_ns);
        assert!(
            t.now >= f.admit_at(),
            "the write must wait out the admission barrier: t={} admit={}",
            t.now,
            f.admit_at()
        );
        f.rdfence(&mut t);
        assert!(f.stall().is_none(), "2 surviving backups satisfy quorum:2");
        // Survivors carry the post-failover write; the promoted slot's
        // image stays at the failover instant.
        assert_eq!(f.backup(1).ledger.len(), 3);
        assert_eq!(f.backup(2).ledger.len(), 3);
        assert_eq!(f.backup(0).ledger.len(), 2);
        let tl = f.timeline();
        assert_eq!(tl.epoch_at(9_999), 0);
        assert_eq!(tl.epoch_at(10_000), 1);
        assert_eq!(tl.primary_at(9_999), None);
        assert_eq!(tl.primary_at(10_000), Some(0));
        assert_eq!(tl.alive_count_at(10_000), 2);
    }

    #[test]
    fn primary_kill_with_no_candidates_stalls() {
        let p = Platform::default();
        let mut f = Fabric::with_faults(
            &p,
            &repl(2, AckPolicy::Quorum(1)),
            faults("kill:0@0,kill:1@0,kill:p@100", OnLoss::Degrade),
            false,
        );
        let mut t = ThreadClock::new(0);
        t.wait_until(200);
        f.post_write_wt(&mut t, meta(0x40, 0, 0));
        let s = *f.stall().expect("no candidate can campaign: must stall");
        assert_eq!(s.at, 100, "the stall sits at the kill instant");
        assert_eq!(s.alive, 0);
        assert_eq!(f.membership_epochs, 0, "no election completed");
        assert_eq!(f.primary_slot(), None);
        f.rdfence(&mut t);
        assert_eq!(f.stall().unwrap().at, 100, "the stall is stable");
    }

    /// Permission revocation at the flush choke point: WQE chains staged
    /// by the old primary are fenced (counted) at the failover and flush
    /// through the new primary only after the admission barrier; the
    /// promoted slot, dead to the fan-out, never sees them.
    #[test]
    fn revocation_fences_staged_chains_until_admission() {
        let p = Platform::default();
        let mut f = Fabric::with_faults(
            &p,
            &repl(3, AckPolicy::Quorum(2)),
            faults("kill:p@5000", OnLoss::Halt),
            true,
        )
        .with_batching(FlushPolicy::Fence);
        let mut t = ThreadClock::new(0);
        for s in 0..4u64 {
            f.post_write_wt(&mut t, meta(0x40 * (1 + s), 0, s));
        }
        assert!(t.now < 5_000, "staging must predate the kill, t={}", t.now);
        assert_eq!(f.staged_pending(), 12, "4 lines x 3 backups");
        t.wait_until(6_000);
        f.rdfence(&mut t);
        assert_eq!(f.revoked_wqes, 12, "the staged chains were fenced");
        assert_eq!(f.staged_pending(), 0, "and retried after admission");
        assert!(f.stall().is_none());
        let admit = 5_000 + f.faults().election.handoff_ns;
        assert_eq!(f.admit_at(), admit);
        assert!(t.now >= admit, "the fence waited out the barrier");
        // The retried chains landed on the surviving backups only.
        assert_eq!(f.backup(1).ledger.len(), 4);
        assert_eq!(f.backup(2).ledger.len(), 4);
        assert_eq!(f.backup(0).ledger.len(), 0, "promoted slot left the fan-out");
    }

    /// Driving the election through the coordinated-mode API
    /// ([`Fabric::pending_primary_event`] + [`Fabric::failover_to`], the
    /// sharded coordinator's path) must land event-for-event where the
    /// fabric's own in-band election does.
    #[test]
    fn coordinated_failover_matches_self_election() {
        let p = Platform::default();
        let drive = |f: &mut Fabric, coordinate: bool| -> Ns {
            let mut t = ThreadClock::new(0);
            f.post_write_wt(&mut t, meta(0x40, 0, 0));
            f.rdfence(&mut t);
            t.wait_until(10_001);
            if coordinate {
                if let Some((at, FaultKind::Kill)) = f.pending_primary_event(t.now) {
                    f.settle(at);
                    let field: Vec<Candidate> = (0..f.backups())
                        .filter(|&i| f.state(i).is_alive())
                        .map(|i| Candidate { id: i, certified: f.certified_prefix(i) })
                        .collect();
                    f.failover_to(elect(&field), at);
                }
            }
            f.post_write_wt(&mut t, meta(0x80, 1, 1));
            f.rdfence(&mut t);
            t.now
        };
        let plan = || faults("kill:p@10000", OnLoss::Halt);
        let mut auto = Fabric::with_faults(&p, &repl(3, AckPolicy::Quorum(2)), plan(), true);
        let t_auto = drive(&mut auto, false);
        let mut coord = Fabric::with_faults(&p, &repl(3, AckPolicy::Quorum(2)), plan(), true);
        coord.set_coordinated(true);
        let t_coord = drive(&mut coord, true);
        assert_eq!(t_auto, t_coord, "coordinated election moved the timeline");
        assert_eq!(auto.epoch_log(), coord.epoch_log());
        assert_eq!(auto.admit_at(), coord.admit_at());
        assert_eq!(auto.failover_downtime_ns, coord.failover_downtime_ns);
        for b in 0..3 {
            assert_eq!(
                auto.backup(b).ledger.events(),
                coord.backup(b).ledger.events(),
                "backup {b}"
            );
        }
    }

    /// `rejoin:p@T`: the deposed primary takes the vacated slot back as
    /// a backup, seeded with the image certified at the failover, and
    /// rides the PR 2 resync path to catch up.
    #[test]
    fn deposed_primary_rejoins_via_resync_path() {
        let p = Platform::default();
        let mut f = Fabric::with_faults(
            &p,
            &repl(3, AckPolicy::Quorum(2)),
            faults("kill:p@10000,rejoin:p@50000", OnLoss::Halt),
            true,
        );
        let mut t = ThreadClock::new(0);
        f.post_write_wt(&mut t, meta(0x40, 0, 0));
        f.rdfence(&mut t);
        t.wait_until(10_001);
        f.post_write_wt(&mut t, meta(0x80, 1, 1)); // waits out the barrier
        f.rdfence(&mut t);
        assert_eq!(f.primary_slot(), Some(0));
        t.wait_until(50_001);
        f.post_write_wt(&mut t, meta(0xc0, 2, 2));
        assert!(
            matches!(f.state(0), BackupState::Resyncing { .. }),
            "deposed primary must be resyncing, got {:?}",
            f.state(0)
        );
        assert_eq!(f.primary_slot(), None, "the serving primary holds no slot");
        t.wait_until(300_000);
        f.post_write_wt(&mut t, meta(0x100, 3, 3));
        f.rdfence(&mut t);
        assert_eq!(f.state(0), BackupState::Alive);
        assert_eq!(f.alive_count(), 3);
        assert_eq!(f.backup(0).ledger.len(), 4, "resync closed the gap");
        let stats = f.backup_stats();
        assert_eq!(stats[0].resyncs, 1);
        assert!(stats[0].resync_lines >= 2, "missed lines streamed back");
        crate::recovery::check_epoch_ordering(&f.backup(0).ledger).unwrap();
        assert_eq!(f.membership_epochs, 1, "one election, one epoch");
    }

    /// Leader completeness at the fabric level: whoever wins holds every
    /// line a quorum fence acked before the kill.
    #[test]
    fn elected_primary_covers_all_acked_lines() {
        let p = Platform::default();
        let mut f = Fabric::with_faults(
            &p,
            &repl(3, AckPolicy::Quorum(2)),
            faults("kill:1@2000,kill:p@20000", OnLoss::Degrade),
            true,
        );
        let mut t = ThreadClock::new(0);
        f.post_write_wt(&mut t, meta(0x40, 0, 0));
        f.rdfence(&mut t); // acked by backups 0 and 2 at least
        t.wait_until(2_001);
        f.post_write_wt(&mut t, meta(0x80, 1, 1));
        f.rdfence(&mut t); // backup 1 dead: acked by 0 and 2
        let acked = 2u64;
        t.wait_until(20_001);
        f.post_write_wt(&mut t, meta(0xc0, 2, 2));
        let w = f.primary_slot().expect("election must complete");
        assert_eq!(w, 0, "equal prefixes tie to the lowest alive id");
        assert!(
            f.certified_prefix(w) >= acked,
            "leader completeness: winner certifies {} < {} acked",
            f.certified_prefix(w),
            acked
        );
    }

    // ---- per-transaction adaptive overrides ----

    /// The quorum override is clamped to the configured floor at set
    /// time: the control plane can raise durability, never weaken it.
    #[test]
    fn txn_quorum_clamps_to_the_policy_floor() {
        let p = Platform::default();
        let mut f = Fabric::new(&p, &repl(3, AckPolicy::Quorum(2)), true);
        f.set_txn_quorum(Some(1));
        assert_eq!(f.txn_quorum(), Some(2), "cannot undercut the floor");
        f.set_txn_quorum(Some(5));
        assert_eq!(f.txn_quorum(), Some(3), "cannot exceed the group");
        f.set_txn_quorum(None);
        assert_eq!(f.txn_quorum(), None);
    }

    /// Raising the quorum makes the fence wait for the k-th completion:
    /// with identical backups the completion instants tie, so drive the
    /// point with `Quorum(1)` vs an override of all 3 after one backup
    /// lags (more acks can only move the fence later or equal).
    #[test]
    fn txn_quorum_override_waits_for_more_acks() {
        let p = Platform::default();
        let drive = |q: Option<usize>| {
            let mut f = Fabric::new(&p, &repl(3, AckPolicy::Quorum(1)), true);
            f.set_txn_quorum(q);
            let mut t = ThreadClock::new(0);
            for s in 0..4u64 {
                f.post_write_wt(&mut t, meta(0x40 * (1 + s), 0, s));
            }
            f.rdfence(&mut t);
            t.now
        };
        let base = drive(None);
        assert_eq!(drive(Some(1)), base, "k=floor is the static fence");
        assert!(drive(Some(3)) >= base, "k=all cannot finish earlier");
        // Ledger contents are identical either way: stragglers still
        // complete, only the block point moves.
        let mut f = Fabric::new(&p, &repl(3, AckPolicy::Quorum(1)), true);
        f.set_txn_quorum(Some(3));
        let mut t = ThreadClock::new(0);
        f.post_write_wt(&mut t, meta(0x40, 0, 0));
        f.rdfence(&mut t);
        for b in 0..3 {
            assert_eq!(f.backup(b).ledger.len(), 1, "backup {b}");
        }
    }

    /// The batch-cap override substitutes for the configured flush
    /// policy: `Some(1)` turns a capped fabric eager, `Some(k)` stages
    /// on an eager fabric; `None` restores the configured policy.
    #[test]
    fn txn_batch_cap_overrides_the_flush_policy() {
        let p = Platform::default();
        let mut f = Fabric::new(&p, &ReplicationConfig::default(), true);
        let mut t = ThreadClock::new(0);
        // Configured eager; override stages 4 lines, fence flushes them.
        f.set_txn_batch_cap(Some(8));
        for s in 0..4u64 {
            f.post_write_nt(&mut t, meta(0x40 * (1 + s), 0, s));
        }
        assert_eq!(f.staged_wqes, 4, "override must stage");
        assert_eq!(f.staged_pending(), 4);
        f.read_fence(&mut t);
        assert_eq!(f.staged_pending(), 0, "fence is a flush point");
        assert_eq!(f.backup(0).ledger.len(), 4);
        // Back to None: eager again, nothing staged.
        f.set_txn_batch_cap(None);
        f.post_write_nt(&mut t, meta(0x400, 1, 4));
        assert_eq!(f.staged_wqes, 4, "anchor: eager posts bypass staging");
        // Some(1) normalizes to eager even on a capped fabric.
        let mut g = Fabric::new(&p, &ReplicationConfig::default(), true)
            .with_batching(FlushPolicy::Cap(8));
        g.set_txn_batch_cap(Some(1));
        let mut t2 = ThreadClock::new(0);
        g.post_write_nt(&mut t2, meta(0x40, 0, 0));
        assert_eq!(g.staged_wqes, 0, "cap=1 override is an eager post");
        assert_eq!(g.backup(0).ledger.len(), 1);
    }

    /// Under a coalescing mode the override clamps to >= 2 (a chain of
    /// one cannot combine), mirroring the config-layer pairing rule.
    #[test]
    fn txn_batch_cap_respects_coalescing_minimum() {
        let p = Platform::default();
        let mut f = Fabric::new(&p, &ReplicationConfig::default(), true)
            .with_batching(FlushPolicy::Cap(8))
            .with_coalescing(CoalesceMode::Combine);
        f.set_txn_batch_cap(Some(1));
        assert_eq!(f.txn_batch_cap(), Some(2), "coalescing needs chains");
    }
}
