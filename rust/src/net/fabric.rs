//! Replica-group fabric: one requester stack ([`Rdma`] — QP set, wire,
//! remote engine with its own LLC/MC/durability ledger) **per backup**,
//! with verb fan-out and a pluggable acknowledgement policy.
//!
//! The paper defines its SM strategies for a single primary→backup pair;
//! enterprise SM deployments mirror to N replicas. The fabric generalizes
//! the verb layer without touching per-backup semantics: posted verbs
//! (writes, `rofence`) are fanned out to every replica — each backup
//! independently enforces its own ordering floors and drain behaviour —
//! while blocking verbs (`rcommit`, `rdfence`, sentinel reads) are
//! *issued* on every replica and the calling thread blocks once, until
//! the [`AckPolicy`] is satisfied:
//!
//! * [`AckPolicy::All`] — true synchronous mirroring; the fence completes
//!   at the **max** replica completion;
//! * [`AckPolicy::Quorum`]`(k)` / [`AckPolicy::Majority`] — the fence
//!   completes at the k-th smallest replica completion, so up to
//!   `k - 1` backup losses still leave a durable acked replica.
//!
//! With `backups = 1` and `ack_policy = "all"` the fabric is
//! event-for-event identical to driving the single [`Rdma`] stack
//! directly (the pre-replica-group behaviour); the unit tests below pin
//! that equivalence, which is the refactor's regression anchor.

use super::rdma::Rdma;
use super::remote::RemoteEngine;
use super::verbs::WriteMeta;
use crate::config::{AckPolicy, Platform, ReplicationConfig};
use crate::mem::DurabilityLog;
use crate::sim::ThreadClock;
use crate::Ns;

/// Per-backup snapshot for metrics reports.
#[derive(Clone, Debug)]
pub struct BackupStats {
    pub id: usize,
    /// Replicated line writes received.
    pub writes: u64,
    /// Durable line writes (MC-queue admissions).
    pub persists: u64,
    /// Ordering barriers executed.
    pub barriers: u64,
    /// Replicated-but-not-yet-persistent lines (SM-RC exposure).
    pub pending_lines: usize,
    /// Latest persist instant on this backup.
    pub persist_horizon: Ns,
    /// Send-window stall attributable to this backup's stack.
    pub window_stall_ns: Ns,
    /// This backup's completion of the most recent durability fence.
    pub last_fence: Ns,
}

/// N-way mirroring fabric (see module docs).
pub struct Fabric {
    replicas: Vec<Rdma>,
    policy: AckPolicy,
    /// Durable-backup count required at a fence (validated against
    /// `replicas.len()` at construction).
    required: usize,
    poll_cost: Ns,
    /// Per-backup completion instants of the most recent blocking fence
    /// (index = backup id).
    last_fence: Vec<Ns>,
    // stats
    pub blocking_waits: u64,
    pub blocked_ns: Ns,
}

impl Fabric {
    /// Build a fabric for `repl` (the config must be pre-validated —
    /// see [`ReplicationConfig::validate`]; invalid shapes panic here).
    pub fn new(p: &Platform, repl: &ReplicationConfig, ledger: bool) -> Self {
        repl.validate()
            .expect("ReplicationConfig must be validated before Fabric::new");
        let replicas: Vec<Rdma> = (0..repl.backups).map(|_| Rdma::new(p, ledger)).collect();
        Fabric {
            last_fence: vec![0; replicas.len()],
            replicas,
            policy: repl.ack_policy,
            required: repl.required(),
            poll_cost: p.poll_cost,
            blocking_waits: 0,
            blocked_ns: 0,
        }
    }

    /// The paper's topology: one backup, fully synchronous.
    pub fn single(p: &Platform, ledger: bool) -> Self {
        Self::new(p, &ReplicationConfig::default(), ledger)
    }

    pub fn backups(&self) -> usize {
        self.replicas.len()
    }

    pub fn policy(&self) -> AckPolicy {
        self.policy
    }

    /// Durable backups required at a durability fence.
    pub fn required(&self) -> usize {
        self.required
    }

    /// Backup `i`'s remote engine (LLC/MC/ledger).
    pub fn backup(&self, i: usize) -> &RemoteEngine {
        &self.replicas[i].remote
    }

    /// Backup `i`'s full requester stack.
    pub fn replica(&self, i: usize) -> &Rdma {
        &self.replicas[i]
    }

    /// All backup durability ledgers, in backup order.
    pub fn ledgers(&self) -> Vec<&DurabilityLog> {
        self.replicas.iter().map(|r| &r.remote.ledger).collect()
    }

    /// Per-backup persist horizons, in backup order.
    pub fn persist_horizons(&self) -> Vec<Ns> {
        self.replicas
            .iter()
            .map(|r| r.remote.persist_horizon())
            .collect()
    }

    /// Latest persist instant across the whole group.
    pub fn group_horizon(&self) -> Ns {
        self.persist_horizons().into_iter().max().unwrap_or(0)
    }

    /// Per-backup completions of the most recent blocking fence.
    pub fn last_fence(&self) -> &[Ns] {
        &self.last_fence
    }

    /// Aggregate send-window stall across all backups' stacks.
    pub fn window_stall_ns(&self) -> Ns {
        self.replicas.iter().map(|r| r.window_stall_ns()).sum()
    }

    /// Aggregate posted writes across all backups' stacks.
    pub fn posted_writes(&self) -> u64 {
        self.replicas.iter().map(|r| r.posted_writes).sum()
    }

    /// Per-backup metric snapshots.
    pub fn backup_stats(&self) -> Vec<BackupStats> {
        self.replicas
            .iter()
            .enumerate()
            .map(|(id, r)| BackupStats {
                id,
                writes: r.remote.writes,
                persists: r.remote.persists,
                barriers: r.remote.barriers,
                pending_lines: r.remote.pending_lines(),
                persist_horizon: r.remote.persist_horizon(),
                window_stall_ns: r.window_stall_ns(),
                last_fence: self.last_fence[id],
            })
            .collect()
    }

    /// Ack-policy completion over per-backup fence completions: the
    /// `required`-th smallest instant.
    fn policy_completion(&self, times: &[Ns]) -> Ns {
        debug_assert_eq!(times.len(), self.replicas.len());
        let mut sorted = times.to_vec();
        sorted.sort_unstable();
        sorted[self.required - 1]
    }

    /// Block the calling thread until `completion` (same cost model as
    /// the single-stack path: CQ poll after the wait).
    fn block(&mut self, t: &mut ThreadClock, completion: Ns) {
        self.blocking_waits += 1;
        self.blocked_ns += completion.saturating_sub(t.now);
        t.wait_until(completion);
        t.busy(self.poll_cost);
    }

    // ---- verb fan-out ----------------------------------------------------

    /// Posted one-sided DDIO write to every backup (SM-RC data path).
    pub fn post_write(&mut self, t: &mut ThreadClock, meta: WriteMeta) {
        for r in &mut self.replicas {
            r.post_write(t, meta);
        }
    }

    /// Posted write-through write to every backup (SM-OB data path).
    pub fn post_write_wt(&mut self, t: &mut ThreadClock, meta: WriteMeta) {
        for r in &mut self.replicas {
            r.post_write_wt(t, meta);
        }
    }

    /// Non-temporal write on every backup's shared QP (SM-DD data path).
    pub fn post_write_nt(&mut self, t: &mut ThreadClock, meta: WriteMeta) {
        for r in &mut self.replicas {
            r.post_write_nt(t, meta);
        }
    }

    /// Posted remote ordering fence on every backup (SM-OB epochs).
    /// Ordering is a per-backup property, so no ack policy applies.
    pub fn rofence(&mut self, t: &mut ThreadClock) {
        for r in &mut self.replicas {
            r.rofence(t);
        }
    }

    /// Shared blocking-fence protocol: issue the verb on every backup,
    /// record per-backup completions, block once per the ack policy.
    fn fence(&mut self, t: &mut ThreadClock, issue: fn(&mut Rdma, &mut ThreadClock) -> Ns) {
        let mut times = Vec::with_capacity(self.replicas.len());
        for r in &mut self.replicas {
            times.push(issue(r, t));
        }
        let done = self.policy_completion(&times);
        self.last_fence.clone_from(&times);
        self.block(t, done);
    }

    /// Blocking remote commit across the group (SM-RC fence).
    pub fn rcommit(&mut self, t: &mut ThreadClock) {
        self.fence(t, Rdma::rcommit_issue);
    }

    /// Blocking remote durability fence across the group (SM-OB).
    pub fn rdfence(&mut self, t: &mut ThreadClock) {
        self.fence(t, Rdma::rdfence_issue);
    }

    /// Blocking sentinel read across the group (SM-DD durability point).
    pub fn read_fence(&mut self, t: &mut ThreadClock) {
        self.fence(t, Rdma::read_fence_issue);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(addr: u64, epoch: u32, seq: u64) -> WriteMeta {
        WriteMeta {
            addr,
            val: seq,
            thread: 0,
            txn: 0,
            epoch,
            seq,
        }
    }

    fn repl(backups: usize, policy: AckPolicy) -> ReplicationConfig {
        ReplicationConfig::new(backups, policy)
    }

    /// The regression anchor: with one backup and `All`, the fabric must
    /// be event-for-event identical to driving the raw `Rdma` stack —
    /// same thread time after every verb, same ledger events, same
    /// backup counters.
    #[test]
    fn single_backup_identical_to_raw_rdma() {
        type Step = fn(&mut Rdma, &mut Fabric, &mut ThreadClock, &mut ThreadClock);
        // Each sequence mirrors one strategy's verb pattern.
        let sequences: Vec<(&str, Vec<Step>)> = vec![
            (
                "sm-rc",
                vec![
                    |r, f, tr, tf| {
                        r.post_write(tr, meta(0x40, 0, 0));
                        f.post_write(tf, meta(0x40, 0, 0));
                    },
                    |r, f, tr, tf| {
                        r.rcommit(tr);
                        f.rcommit(tf);
                    },
                    |r, f, tr, tf| {
                        r.post_write(tr, meta(0x80, 1, 1));
                        f.post_write(tf, meta(0x80, 1, 1));
                    },
                    |r, f, tr, tf| {
                        r.rcommit(tr);
                        f.rcommit(tf);
                    },
                ],
            ),
            (
                "sm-ob",
                vec![
                    |r, f, tr, tf| {
                        r.post_write_wt(tr, meta(0x40, 0, 0));
                        f.post_write_wt(tf, meta(0x40, 0, 0));
                    },
                    |r, f, tr, tf| {
                        r.rofence(tr);
                        f.rofence(tf);
                    },
                    |r, f, tr, tf| {
                        r.post_write_wt(tr, meta(0x80, 1, 1));
                        f.post_write_wt(tf, meta(0x80, 1, 1));
                    },
                    |r, f, tr, tf| {
                        r.rdfence(tr);
                        f.rdfence(tf);
                    },
                ],
            ),
            (
                "sm-dd",
                vec![
                    |r, f, tr, tf| {
                        for s in 0..6u64 {
                            r.post_write_nt(tr, meta(0x40 * (1 + s), 0, s));
                            f.post_write_nt(tf, meta(0x40 * (1 + s), 0, s));
                        }
                    },
                    |r, f, tr, tf| {
                        r.read_fence(tr);
                        f.read_fence(tf);
                    },
                ],
            ),
        ];
        for (name, steps) in sequences {
            let p = Platform::default();
            let mut r = Rdma::new(&p, true);
            let mut f = Fabric::single(&p, true);
            let mut tr = ThreadClock::new(0);
            let mut tf = ThreadClock::new(0);
            for (i, step) in steps.into_iter().enumerate() {
                step(&mut r, &mut f, &mut tr, &mut tf);
                assert_eq!(
                    tr.now, tf.now,
                    "{name} step {i}: raw {} vs fabric {}",
                    tr.now, tf.now
                );
            }
            assert_eq!(
                r.remote.ledger.events(),
                f.backup(0).ledger.events(),
                "{name}: ledgers diverged"
            );
            assert_eq!(r.remote.writes, f.backup(0).writes, "{name}");
            assert_eq!(r.remote.persists, f.backup(0).persists, "{name}");
            assert_eq!(r.remote.barriers, f.backup(0).barriers, "{name}");
            assert_eq!(
                r.remote.persist_horizon(),
                f.backup(0).persist_horizon(),
                "{name}"
            );
        }
    }

    #[test]
    fn fan_out_replicates_to_every_backup() {
        let p = Platform::default();
        let mut f = Fabric::new(&p, &repl(3, AckPolicy::All), true);
        let mut t = ThreadClock::new(0);
        for s in 0..4u64 {
            f.post_write_wt(&mut t, meta(0x40 * (1 + s), 0, s));
        }
        f.rdfence(&mut t);
        for i in 0..3 {
            assert_eq!(f.backup(i).ledger.len(), 4, "backup {i}");
        }
        // The fence completion covers every backup's persists.
        for (i, &fence) in f.last_fence().iter().enumerate() {
            assert!(
                fence >= f.backup(i).persist_horizon(),
                "backup {i}: fence {fence} < horizon {}",
                f.backup(i).persist_horizon()
            );
        }
        assert!(t.now >= f.group_horizon(), "All must cover the group");
    }

    #[test]
    fn quorum_completes_no_later_than_all() {
        let run = |policy: AckPolicy| {
            let p = Platform::default();
            let mut f = Fabric::new(&p, &repl(3, policy), false);
            let mut t = ThreadClock::new(0);
            for e in 0..4u32 {
                f.post_write_wt(&mut t, meta(0x40 * (1 + e as u64), e, e as u64));
                f.rofence(&mut t);
            }
            f.rdfence(&mut t);
            t.now
        };
        let all = run(AckPolicy::All);
        let q2 = run(AckPolicy::Quorum(2));
        let q1 = run(AckPolicy::Quorum(1));
        assert!(q2 <= all, "quorum:2 {q2} vs all {all}");
        assert!(q1 <= q2, "quorum:1 {q1} vs quorum:2 {q2}");
    }

    #[test]
    fn quorum_fence_covers_required_backups() {
        let p = Platform::default();
        let mut f = Fabric::new(&p, &repl(3, AckPolicy::Quorum(2)), true);
        let mut t = ThreadClock::new(0);
        for s in 0..5u64 {
            f.post_write_nt(&mut t, meta(0x40 * (1 + s), 0, s));
        }
        f.read_fence(&mut t);
        // At the thread's post-fence instant, at least `required` backups
        // must have completed their fence (and thus be fully durable for
        // this thread's writes).
        let covered = f
            .last_fence()
            .iter()
            .filter(|&&c| c <= t.now)
            .count();
        assert!(covered >= 2, "only {covered} backups covered at fence");
    }

    #[test]
    fn backup_stats_snapshot() {
        let p = Platform::default();
        let mut f = Fabric::new(&p, &repl(2, AckPolicy::All), true);
        let mut t = ThreadClock::new(0);
        f.post_write_wt(&mut t, meta(0x40, 0, 0));
        f.rdfence(&mut t);
        let stats = f.backup_stats();
        assert_eq!(stats.len(), 2);
        for s in &stats {
            assert_eq!(s.writes, 1);
            assert_eq!(s.persists, 1);
            assert!(s.last_fence > 0);
            assert!(s.persist_horizon > 0);
        }
        assert_eq!(f.blocking_waits, 1);
    }
}
