//! Deterministic fault injection for replica groups.
//!
//! A [`FaultPlan`] is a sim-clock-scheduled list of `Kill { backup, at }` /
//! `Rejoin { backup, at }` events. The [`crate::net::Fabric`] consults the
//! plan on every post and fence: a killed backup drops out of verb fan-out
//! and out of ack-policy accounting, and a rejoining backup first streams
//! the ledger suffix it missed from the healthiest surviving peer (the
//! catch-up resync), re-entering the quorum only once the stream completes
//! — hand-off latency plus a per-line streaming cost, charged on the
//! simulated clock.
//!
//! Losing more backups than the ack policy tolerates is governed by
//! [`OnLoss`]:
//!
//! * [`OnLoss::Halt`] — true synchronous-mirroring semantics: the first
//!   durability fence that cannot gather its required acks records a
//!   [`Stall`] and the run stops at the kill point (no weakened acks are
//!   ever reported durable);
//! * [`OnLoss::Degrade`] — availability-first: the fence degrades to the
//!   surviving backups (`required` clamps to the alive count), durability
//!   is temporarily weakened, and the run continues.
//!
//! The *primary* can die too: `kill:p@T` / `rejoin:p@T` events target
//! the primary instead of a backup index. On a primary kill the fabric
//! runs a deterministic leader election (see [`crate::net::membership`])
//! — the surviving backup with the longest certified ledger prefix wins,
//! ties broken by the lowest replica id — revokes the old primary's
//! write permission at the staged-WQE flush choke point, re-replicates
//! the winner's certified suffix to its peers, and only then admits new
//! writes; the old primary may come back later as a backup through the
//! ordinary catch-up resync. Election costs are governed by
//! [`ElectionConfig`].
//!
//! The fabric records the *realized* alive/dead transitions (kills, and
//! resync completions whose instants are only known at run time) as a
//! [`FaultTimeline`], which the fault-aware recovery checks consume to
//! know which backups can serve a crash at a given instant; the timeline
//! also carries the membership-epoch transitions (one per completed
//! failover) so recovery verdicts can be scoped to a primary epoch.

use crate::config::AckPolicy;
use crate::Ns;
use anyhow::{anyhow, bail, Result};
use std::fmt;
use std::str::FromStr;

/// What happens to a backup at a plan event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The backup dies: no further verbs reach it, its completions drop
    /// out of ack accounting.
    Kill,
    /// The backup comes back and starts its catch-up resync.
    Rejoin,
}

/// One scheduled fault event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    /// Virtual instant at which the event takes effect (ns).
    pub at: Ns,
    /// Backup index within the replica group.
    pub backup: usize,
    pub kind: FaultKind,
}

/// One scheduled fault event targeting the *primary* (`kill:p@T` /
/// `rejoin:p@T`): a kill triggers leader election and failover, a rejoin
/// brings the deposed primary back as a backup through the ordinary
/// catch-up resync.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PrimaryEvent {
    /// Virtual instant at which the event takes effect (ns).
    pub at: Ns,
    pub kind: FaultKind,
}

/// A deterministic, time-sorted fault schedule: backup events plus
/// primary events, kept in separate streams (backups are addressed by
/// index, the primary by role).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
    primary_events: Vec<PrimaryEvent>,
}

impl FaultPlan {
    /// Build a plan (events are sorted by time; per-backup shape is
    /// checked by [`FaultPlan::validate`]).
    pub fn new(mut events: Vec<FaultEvent>) -> Self {
        events.sort_by_key(|e| e.at);
        FaultPlan {
            events,
            primary_events: Vec::new(),
        }
    }

    /// Attach primary kill/rejoin events (sorted by time; shape is
    /// checked by [`FaultPlan::validate`]).
    pub fn with_primary(mut self, mut events: Vec<PrimaryEvent>) -> Self {
        events.sort_by_key(|e| e.at);
        self.primary_events = events;
        self
    }

    /// Backup (index-addressed) events only.
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Primary (role-addressed) events only.
    pub fn primary_events(&self) -> &[PrimaryEvent] {
        &self.primary_events
    }

    /// Whether any event targets the primary (the failover guard clause:
    /// plans without primary faults take the pre-election path
    /// unchanged).
    pub fn has_primary_faults(&self) -> bool {
        !self.primary_events.is_empty()
    }

    pub fn len(&self) -> usize {
        self.events.len() + self.primary_events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty() && self.primary_events.is_empty()
    }

    /// Shape check that needs no group size: each target's events must be
    /// strictly increasing in time and alternate kill → rejoin → kill →
    /// …, starting with a kill. Contradictory plans (a kill and rejoin at
    /// the same tick, a double kill of an already-dead target) are
    /// rejected here — and therefore already at parse time.
    pub fn validate_shape(&self) -> Result<()> {
        let mut targets: Vec<usize> = self.events.iter().map(|e| e.backup).collect();
        targets.sort_unstable();
        targets.dedup();
        for b in targets {
            check_alternation(
                &format!("backup {b}"),
                self.events
                    .iter()
                    .filter(|e| e.backup == b)
                    .map(|e| (e.at, e.kind)),
            )?;
        }
        check_alternation(
            "the primary",
            self.primary_events.iter().map(|e| (e.at, e.kind)),
        )?;
        Ok(())
    }

    /// Check the plan against a group of `backups` replicas: the shape
    /// rules of [`FaultPlan::validate_shape`] plus indices in range.
    pub fn validate(&self, backups: usize) -> Result<()> {
        self.validate_shape()?;
        if let Some(ev) = self.events.iter().find(|e| e.backup >= backups) {
            bail!(
                "fault plan names backup {} but the group only has {backups}",
                ev.backup
            );
        }
        Ok(())
    }
}

/// The per-target shape rule shared by backups and the primary: strictly
/// increasing times, kill/rejoin alternation starting with a kill.
fn check_alternation(
    who: &str,
    events: impl Iterator<Item = (Ns, FaultKind)>,
) -> Result<()> {
    let mut last_at: Option<Ns> = None;
    let mut expect = FaultKind::Kill;
    for (at, kind) in events {
        if let Some(prev) = last_at {
            if at <= prev {
                bail!(
                    "fault plan: {who} has contradictory events at the same \
                     or non-increasing times ({prev} then {at})"
                );
            }
        }
        if kind != expect {
            match kind {
                FaultKind::Kill => bail!(
                    "fault plan: {who} is killed at t={at} while already dead \
                     (no rejoin since the previous kill)"
                ),
                FaultKind::Rejoin => bail!(
                    "fault plan: {who} rejoins at t={at} without a prior kill"
                ),
            }
        }
        expect = match kind {
            FaultKind::Kill => FaultKind::Rejoin,
            FaultKind::Rejoin => FaultKind::Kill,
        };
        last_at = Some(at);
    }
    Ok(())
}

impl FromStr for FaultPlan {
    type Err = anyhow::Error;

    /// Parse a `--fault-plan` spec: comma-separated `kill:B@T` /
    /// `rejoin:B@T` entries (`T` in ns, underscores allowed), where `B`
    /// is a backup index or the literal `p` for the primary. The empty
    /// string is the empty plan. Contradictory shapes (same-tick
    /// kill+rejoin, double kill) are rejected here at parse time.
    fn from_str(s: &str) -> Result<Self> {
        let mut events = Vec::new();
        let mut primary = Vec::new();
        for tok in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let (kind_s, rest) = tok
                .split_once(':')
                .ok_or_else(|| anyhow!("fault event {tok:?}: expected kill:B@T or rejoin:B@T"))?;
            let kind = match kind_s.trim().to_ascii_lowercase().as_str() {
                "kill" => FaultKind::Kill,
                "rejoin" => FaultKind::Rejoin,
                other => bail!("unknown fault kind {other:?}; expected kill | rejoin"),
            };
            let (backup_s, at_s) = rest
                .split_once('@')
                .ok_or_else(|| anyhow!("fault event {tok:?}: missing @time"))?;
            let at: Ns = at_s
                .trim()
                .replace('_', "")
                .parse()
                .map_err(|e| anyhow!("fault event {tok:?}: bad time: {e}"))?;
            if backup_s.trim().eq_ignore_ascii_case("p") {
                primary.push(PrimaryEvent { at, kind });
            } else {
                let backup: usize = backup_s
                    .trim()
                    .parse()
                    .map_err(|e| anyhow!("fault event {tok:?}: bad backup index: {e}"))?;
                events.push(FaultEvent { at, backup, kind });
            }
        }
        let plan = FaultPlan::new(events).with_primary(primary);
        plan.validate_shape()?;
        Ok(plan)
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let kind_str = |k: FaultKind| match k {
            FaultKind::Kill => "kill",
            FaultKind::Rejoin => "rejoin",
        };
        let mut items: Vec<(Ns, String)> = self
            .events
            .iter()
            .map(|ev| (ev.at, format!("{}:{}@{}", kind_str(ev.kind), ev.backup, ev.at)))
            .collect();
        items.extend(
            self.primary_events
                .iter()
                .map(|ev| (ev.at, format!("{}:p@{}", kind_str(ev.kind), ev.at))),
        );
        items.sort_by_key(|(at, _)| *at);
        for (i, (_, item)) in items.iter().enumerate() {
            if i > 0 {
                f.write_str(",")?;
            }
            f.write_str(item)?;
        }
        Ok(())
    }
}

/// Behaviour when backup losses exceed what the ack policy tolerates.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum OnLoss {
    /// Stop at the kill point (record a [`Stall`]); never report a
    /// weakened ack as durable.
    #[default]
    Halt,
    /// Degrade the fence to the surviving backups and continue.
    Degrade,
}

impl FromStr for OnLoss {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "halt" => Ok(OnLoss::Halt),
            "degrade" => Ok(OnLoss::Degrade),
            other => bail!("unknown on_loss {other:?}; expected halt | degrade"),
        }
    }
}

impl fmt::Display for OnLoss {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            OnLoss::Halt => "halt",
            OnLoss::Degrade => "degrade",
        })
    }
}

/// Acks a fence must gather given `alive` surviving backups, under a
/// policy statically requiring `required`. Returns 0 when the fence is
/// unsatisfiable (the stall condition).
pub fn effective_required(required: usize, alive: usize, on_loss: OnLoss) -> usize {
    match on_loss {
        OnLoss::Halt => {
            if alive < required {
                0
            } else {
                required
            }
        }
        OnLoss::Degrade => required.min(alive),
    }
}

/// Default hand-off latency charged when a rejoin starts its catch-up
/// stream (ns) — connection re-establishment + source selection.
pub const DEFAULT_HANDOFF_NS: Ns = 10_000;
/// Default per-line streaming cost of the catch-up resync (ns/line).
pub const DEFAULT_RESYNC_LINE_NS: Ns = 100;
/// Default fixed latency of a primary failover (ns): failure detection,
/// the one-sided CAS election round, and permission revocation across
/// the surviving replicas (arXiv:1905.12143-style agreement — cheaper
/// than message-passing consensus but not free).
pub const DEFAULT_ELECTION_HANDOFF_NS: Ns = 25_000;
/// Default per-line cost of the elected primary re-replicating its
/// certified ledger suffix to a lagging peer before admitting writes
/// (ns/line).
pub const DEFAULT_ELECTION_LINE_NS: Ns = 100;

/// Leader-election cost knobs (`[election]` table / `--election-*`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ElectionConfig {
    /// Fixed detection + election + permission-revocation latency charged
    /// at a primary kill (ns).
    pub handoff_ns: Ns,
    /// Re-replication streaming cost per certified-suffix line the winner
    /// pushes to a lagging peer (ns/line).
    pub line_ns: Ns,
}

impl Default for ElectionConfig {
    fn default() -> Self {
        ElectionConfig {
            handoff_ns: DEFAULT_ELECTION_HANDOFF_NS,
            line_ns: DEFAULT_ELECTION_LINE_NS,
        }
    }
}

/// Failure-dynamics configuration (`[faults]` table / `--fault-plan`).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultsConfig {
    pub plan: FaultPlan,
    pub on_loss: OnLoss,
    /// Fixed hand-off latency at the start of a catch-up resync (ns).
    pub handoff_ns: Ns,
    /// Streaming cost per missed line during resync (ns/line).
    pub resync_line_ns: Ns,
    /// Primary-failover election costs (used only by `kill:p@T` plans).
    pub election: ElectionConfig,
}

impl Default for FaultsConfig {
    fn default() -> Self {
        FaultsConfig {
            plan: FaultPlan::default(),
            on_loss: OnLoss::default(),
            handoff_ns: DEFAULT_HANDOFF_NS,
            resync_line_ns: DEFAULT_RESYNC_LINE_NS,
            election: ElectionConfig::default(),
        }
    }
}

impl FaultsConfig {
    /// Parse `spec` as the fault plan, with default cost knobs — the
    /// common construction across tests, benches, and examples.
    pub fn with_plan(spec: &str, on_loss: OnLoss) -> Result<Self> {
        Ok(FaultsConfig {
            plan: spec.parse()?,
            on_loss,
            ..FaultsConfig::default()
        })
    }

    /// Validate the plan against the replica-group size.
    pub fn validate(&self, backups: usize) -> Result<()> {
        self.plan.validate(backups)
    }
}

/// Runtime state of one backup in the failover state machine
/// (alive → dead → resyncing → alive).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackupState {
    /// In the quorum: receives fan-out, counts toward acks.
    Alive,
    /// Killed at `since`: receives nothing, counts toward nothing.
    Dead { since: Ns },
    /// Rejoined and streaming the missed ledger suffix; back in the
    /// quorum at `ready_at`. Still excluded from fan-out and acks.
    Resyncing { since: Ns, ready_at: Ns },
}

impl BackupState {
    pub fn is_alive(&self) -> bool {
        matches!(self, BackupState::Alive)
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackupState::Alive => "alive",
            BackupState::Dead { .. } => "dead",
            BackupState::Resyncing { .. } => "resyncing",
        }
    }
}

impl fmt::Display for BackupState {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// A durability fence that could not gather its required acks (halt mode
/// or a fully dead group): the run stops here.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Stall {
    /// Virtual instant of the unsatisfiable fence.
    pub at: Ns,
    /// Backups alive (in-quorum) at the fence.
    pub alive: usize,
    /// Acks the policy statically requires.
    pub required: usize,
    pub policy: AckPolicy,
    pub on_loss: OnLoss,
    /// Shard whose fabric recorded the stall (0 when sharding is off —
    /// see `coordinator::shard`).
    pub shard: usize,
}

impl fmt::Display for Stall {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "durability stalled at t={}: policy {} requires {} durable \
             backup(s) but only {} alive (on_loss = {})",
            self.at, self.policy, self.required, self.alive, self.on_loss
        )?;
        if self.shard > 0 {
            write!(f, " [shard {}]", self.shard)?;
        }
        Ok(())
    }
}

/// Realized alive/dead transitions of a run — kills at their scheduled
/// instants plus resync completions at their computed `ready_at`s — used
/// by fault-aware recovery to know which backups can serve a crash at a
/// given instant.
#[derive(Clone, Debug, Default)]
pub struct FaultTimeline {
    backups: usize,
    /// `(instant, backup, alive-after)`, time-sorted.
    transitions: Vec<(Ns, usize, bool)>,
    /// `(instant, epoch-after, winner-slot)` membership-epoch
    /// transitions, time-sorted: one per completed primary failover. The
    /// winner slot is the backup index that was promoted (and therefore
    /// left the backup group at the same instant). Empty for runs without
    /// primary faults — epoch 0 throughout.
    epochs: Vec<(Ns, u64, usize)>,
}

impl FaultTimeline {
    pub fn new(backups: usize, mut transitions: Vec<(Ns, usize, bool)>) -> Self {
        transitions.sort_by_key(|t| t.0);
        FaultTimeline {
            backups,
            transitions,
            epochs: Vec::new(),
        }
    }

    /// Attach the realized membership-epoch transitions (builder so the
    /// epoch-free `new` call sites stay valid).
    pub fn with_epochs(mut self, mut epochs: Vec<(Ns, u64, usize)>) -> Self {
        epochs.sort_by_key(|e| e.0);
        self.epochs = epochs;
        self
    }

    pub fn backups(&self) -> usize {
        self.backups
    }

    pub fn transitions(&self) -> &[(Ns, usize, bool)] {
        &self.transitions
    }

    /// The realized membership-epoch transitions (empty without primary
    /// faults).
    pub fn epochs(&self) -> &[(Ns, u64, usize)] {
        &self.epochs
    }

    /// Membership epoch in force at `t` (0 before any failover).
    pub fn epoch_at(&self, t: Ns) -> u64 {
        let mut epoch = 0;
        for &(at, e, _) in &self.epochs {
            if at > t {
                break;
            }
            epoch = e;
        }
        epoch
    }

    /// Slot acting as primary at `t`: `None` is the original primary,
    /// `Some(w)` the backup slot promoted by the latest failover.
    pub fn primary_at(&self, t: Ns) -> Option<usize> {
        let mut primary = None;
        for &(at, _, w) in &self.epochs {
            if at > t {
                break;
            }
            primary = Some(w);
        }
        primary
    }

    /// Which backups are in the quorum (alive, fully resynced) at `t`.
    pub fn alive_at(&self, t: Ns) -> Vec<bool> {
        let mut alive = vec![true; self.backups];
        for &(at, b, up) in &self.transitions {
            if at > t {
                break;
            }
            alive[b] = up;
        }
        alive
    }

    pub fn alive_count_at(&self, t: Ns) -> usize {
        self.alive_at(t).into_iter().filter(|&a| a).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_parse_and_display_round_trip() {
        let plan: FaultPlan = "kill:1@5_000, rejoin:1@9000,kill:2@12000".parse().unwrap();
        assert_eq!(plan.len(), 3);
        assert_eq!(plan.to_string(), "kill:1@5000,rejoin:1@9000,kill:2@12000");
        let again: FaultPlan = plan.to_string().parse().unwrap();
        assert_eq!(plan, again);
        assert!("".parse::<FaultPlan>().unwrap().is_empty());
        assert!("  ".parse::<FaultPlan>().unwrap().is_empty());
    }

    #[test]
    fn plan_parse_rejects_malformed_specs() {
        for bad in [
            "kill",
            "kill:1",
            "kill:@100",
            "kill:x@100",
            "kill:1@",
            "kill:1@abc",
            "explode:1@100",
            "kill:1@-5",
            "kill:p",
            "rejoin:p@abc",
        ] {
            assert!(bad.parse::<FaultPlan>().is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn plan_parse_rejects_contradictory_shapes() {
        // Same-tick kill + rejoin of one backup.
        let err = "kill:0@100,rejoin:0@100".parse::<FaultPlan>().unwrap_err();
        assert!(
            format!("{err:#}").contains("contradictory"),
            "want a contradiction error, got: {err:#}"
        );
        // Double kill of an already-dead backup.
        let err = "kill:0@100,kill:0@200".parse::<FaultPlan>().unwrap_err();
        assert!(
            format!("{err:#}").contains("already dead"),
            "want an already-dead error, got: {err:#}"
        );
        // Rejoin with no prior kill.
        let err = "rejoin:1@100".parse::<FaultPlan>().unwrap_err();
        assert!(
            format!("{err:#}").contains("without a prior kill"),
            "{err:#}"
        );
        // The same shape rules bind the primary stream.
        assert!("kill:p@100,kill:p@200".parse::<FaultPlan>().is_err());
        assert!("kill:p@100,rejoin:p@100".parse::<FaultPlan>().is_err());
        assert!("rejoin:p@100".parse::<FaultPlan>().is_err());
        // Well-shaped plans still parse.
        assert!("kill:0@100,rejoin:0@200,kill:0@300".parse::<FaultPlan>().is_ok());
        assert!("kill:p@100,rejoin:p@200".parse::<FaultPlan>().is_ok());
    }

    #[test]
    fn primary_events_parse_and_round_trip() {
        let plan: FaultPlan = "kill:1@5000,kill:P@8_000,rejoin:p@20000".parse().unwrap();
        assert_eq!(plan.events().len(), 1);
        assert_eq!(plan.primary_events().len(), 2);
        assert!(plan.has_primary_faults());
        assert_eq!(plan.len(), 3);
        assert_eq!(
            plan.primary_events(),
            &[
                PrimaryEvent {
                    at: 8_000,
                    kind: FaultKind::Kill
                },
                PrimaryEvent {
                    at: 20_000,
                    kind: FaultKind::Rejoin
                },
            ]
        );
        // Display merges both streams chronologically and re-parses to
        // the same plan.
        assert_eq!(plan.to_string(), "kill:1@5000,kill:p@8000,rejoin:p@20000");
        let again: FaultPlan = plan.to_string().parse().unwrap();
        assert_eq!(plan, again);
        // Backup-only plans don't see the primary stream.
        let plain: FaultPlan = "kill:1@100".parse().unwrap();
        assert!(!plain.has_primary_faults());
    }

    #[test]
    fn plan_events_sorted_by_time() {
        let plan: FaultPlan = "kill:2@900,kill:0@100,kill:1@500".parse().unwrap();
        let ats: Vec<u64> = plan.events().iter().map(|e| e.at).collect();
        assert_eq!(ats, vec![100, 500, 900]);
    }

    #[test]
    fn plan_validation() {
        let ok: FaultPlan = "kill:0@100,rejoin:0@200,kill:0@300".parse().unwrap();
        ok.validate(1).unwrap();
        // Index out of range.
        let oob: FaultPlan = "kill:3@100".parse().unwrap();
        assert!(oob.validate(3).is_err());
        oob.validate(4).unwrap();
        // Contradictory shapes no longer survive parsing (see
        // plan_parse_rejects_contradictory_shapes), but plans built
        // programmatically through `new` are still caught by validate:
        // rejoin before any kill, double kill, equal times.
        let rj = FaultPlan::new(vec![FaultEvent {
            at: 100,
            backup: 0,
            kind: FaultKind::Rejoin,
        }]);
        assert!(rj.validate(1).is_err());
        let dk = FaultPlan::new(vec![
            FaultEvent {
                at: 100,
                backup: 0,
                kind: FaultKind::Kill,
            },
            FaultEvent {
                at: 200,
                backup: 0,
                kind: FaultKind::Kill,
            },
        ]);
        assert!(dk.validate(1).is_err());
        let eq = FaultPlan::new(vec![
            FaultEvent {
                at: 100,
                backup: 0,
                kind: FaultKind::Kill,
            },
            FaultEvent {
                at: 100,
                backup: 0,
                kind: FaultKind::Rejoin,
            },
        ]);
        assert!(eq.validate(1).is_err());
        // A contradictory primary stream is caught the same way.
        let pk = FaultPlan::new(Vec::new()).with_primary(vec![
            PrimaryEvent {
                at: 100,
                kind: FaultKind::Kill,
            },
            PrimaryEvent {
                at: 200,
                kind: FaultKind::Kill,
            },
        ]);
        assert!(pk.validate(1).is_err());
        // Distinct backups may share instants; so may a backup and the
        // primary.
        let share: FaultPlan = "kill:0@100,kill:1@100".parse().unwrap();
        share.validate(2).unwrap();
        let share: FaultPlan = "kill:0@100,kill:p@100".parse().unwrap();
        share.validate(2).unwrap();
    }

    #[test]
    fn on_loss_parse_and_display() {
        assert_eq!("halt".parse::<OnLoss>().unwrap(), OnLoss::Halt);
        assert_eq!("DEGRADE".parse::<OnLoss>().unwrap(), OnLoss::Degrade);
        assert!("panic".parse::<OnLoss>().is_err());
        for m in [OnLoss::Halt, OnLoss::Degrade] {
            assert_eq!(m.to_string().parse::<OnLoss>().unwrap(), m);
        }
    }

    #[test]
    fn effective_required_table() {
        // Halt: all-or-nothing.
        assert_eq!(effective_required(3, 3, OnLoss::Halt), 3);
        assert_eq!(effective_required(3, 2, OnLoss::Halt), 0);
        assert_eq!(effective_required(2, 2, OnLoss::Halt), 2);
        assert_eq!(effective_required(2, 3, OnLoss::Halt), 2);
        // Degrade: clamp to survivors; zero survivors still stalls.
        assert_eq!(effective_required(3, 2, OnLoss::Degrade), 2);
        assert_eq!(effective_required(2, 3, OnLoss::Degrade), 2);
        assert_eq!(effective_required(3, 0, OnLoss::Degrade), 0);
        assert_eq!(effective_required(1, 0, OnLoss::Degrade), 0);
    }

    #[test]
    fn faults_config_default_is_empty_halt() {
        let f = FaultsConfig::default();
        assert!(f.plan.is_empty());
        assert_eq!(f.on_loss, OnLoss::Halt);
        assert_eq!(f.election, ElectionConfig::default());
        assert_eq!(f.election.handoff_ns, DEFAULT_ELECTION_HANDOFF_NS);
        assert_eq!(f.election.line_ns, DEFAULT_ELECTION_LINE_NS);
        f.validate(1).unwrap();
    }

    #[test]
    fn timeline_alive_tracking() {
        let tl = FaultTimeline::new(
            3,
            vec![(100, 1, false), (500, 1, true), (300, 2, false)],
        );
        assert_eq!(tl.alive_at(0), vec![true, true, true]);
        assert_eq!(tl.alive_at(100), vec![true, false, true]);
        assert_eq!(tl.alive_at(350), vec![true, false, false]);
        assert_eq!(tl.alive_at(500), vec![true, true, false]);
        assert_eq!(tl.alive_count_at(350), 1);
        assert_eq!(tl.alive_count_at(10_000), 2);
        // Epoch-free timelines stay at epoch 0 under the original
        // primary.
        assert_eq!(tl.epoch_at(10_000), 0);
        assert_eq!(tl.primary_at(10_000), None);
        assert!(tl.epochs().is_empty());
    }

    #[test]
    fn timeline_epoch_tracking() {
        let tl = FaultTimeline::new(2, vec![(400, 0, false)])
            .with_epochs(vec![(400, 1, 0), (900, 2, 1)]);
        assert_eq!(tl.epoch_at(0), 0);
        assert_eq!(tl.primary_at(0), None);
        assert_eq!(tl.epoch_at(400), 1);
        assert_eq!(tl.primary_at(400), Some(0));
        assert_eq!(tl.epoch_at(899), 1);
        assert_eq!(tl.epoch_at(900), 2);
        assert_eq!(tl.primary_at(900), Some(1));
        assert_eq!(tl.epochs().len(), 2);
    }

    #[test]
    fn stall_renders_the_shortfall() {
        let s = Stall {
            at: 1234,
            alive: 1,
            required: 3,
            policy: AckPolicy::All,
            on_loss: OnLoss::Halt,
            shard: 0,
        };
        let text = s.to_string();
        assert!(text.contains("t=1234"), "{text}");
        assert!(text.contains("requires 3"), "{text}");
        assert!(text.contains("only 1 alive"), "{text}");
        assert!(!text.contains("shard"), "shard 0 is elided: {text}");
        let text = Stall { shard: 2, ..s }.to_string();
        assert!(text.contains("[shard 2]"), "{text}");
    }
}
