//! Deterministic lossy-link injection and the reliable-transport
//! machinery that masks it.
//!
//! A [`LinkPlan`] is the wire-level sibling of the node-level
//! [`super::faults::FaultPlan`]: a per-backup schedule of link faults
//! consulted at the wire-issue point of every data WQE (see
//! [`super::rdma::Rdma`]). Three event families compose:
//!
//! * **One-shot events** — `drop:B@T` (the first message issued at or
//!   after `T` is lost), `delay:B@T:D` (it arrives `D` ns late; a delay
//!   past the ACK timeout also triggers a spurious retransmit whose
//!   duplicate delivery the remote dedup drops), `dup:B@T` (it is
//!   delivered twice). Each event is consumed by exactly one message.
//! * **Loss windows** — `drop:B@T1..T2:p`: every transmission attempt
//!   issued inside `[T1, T2)` is dropped with probability `p`.
//! * **Run-long random loss** — `loss:B:p%`: every attempt toward `B`
//!   is dropped with probability `p`, for chaos sweeps.
//!
//! Probabilistic fates use *common random numbers*: attempt `k` of
//! message `m` rolls a pure hash of `(seed, salt, m, k)`, independent of
//! the loss probability, so for a fixed seed the drop set at rate `p1`
//! is a subset of the drop set at any `p2 > p1` — per-message delivery
//! latency, and therefore makespan, is deterministically monotone in the
//! loss rate (the `fig15_lossy_links` invariant).
//!
//! The masking side is the RC transport state machine, one
//! [`LinkState`] per requester stack:
//!
//! * a lost message arms the per-QP ACK timeout (`transport_timeout_ns`)
//!   and retransmits with exponential backoff (`timeout << attempt`), up
//!   to `retry_count` retransmissions;
//! * a saturated receiver (the remote engine's volatile pending buffer
//!   at `rnr_depth` lines — this is what finally gives `rpmem-flush`'s
//!   buffer a real capacity) answers RNR NAK: the message is retried
//!   after a NAK round-trip plus one backoff period, counted as a
//!   retransmit but not a timeout (hence `retransmits >= timeouts`);
//! * retry exhaustion transitions the QP to **error state**: nothing
//!   more reaches this backup's wire until the fabric heals the
//!   connection — re-establishment plus replay from the last
//!   remotely-acked sequence number, modeled as a transient
//!   kill + rejoin episode through the PR 2 resync machinery (see
//!   `Fabric::heal_qp_errors`). [`super::faults::OnLoss`] semantics
//!   extend to links unchanged: the episode is just a backup leaving
//!   and re-entering the quorum.
//!
//! Because a retransmitted or duplicated message must not double-apply,
//! the remote engines run PSN-style duplicate suppression on
//! `(thread, seq)` at the ledger boundary whenever a link is configured
//! (see [`super::remote::RemoteEngine`]) — the at-least-once →
//! exactly-once step real RC hardware does with packet sequence numbers.
//!
//! The empty [`LinkConfig`] (no plan, unbounded receiver) is the
//! guard-clause anchor: [`LinkConfig::enabled`] is false, no
//! [`LinkState`] is attached anywhere, and the wire path is
//! event-for-event the pre-link tree.

use crate::util::Pcg64;
use crate::Ns;
use anyhow::{anyhow, bail, Result};
use std::fmt;
use std::str::FromStr;

/// What happens to the one message that consumes a one-shot link event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkEventKind {
    /// The message is lost on the wire (its retransmit is re-consulted).
    Drop,
    /// The message arrives this many ns late. A delay of at least the
    /// ACK timeout also triggers a spurious retransmit — the requester
    /// cannot tell a slow ack from a lost one.
    Delay(Ns),
    /// The message is delivered twice (fabric-level duplication).
    Dup,
}

/// One scheduled one-shot link event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LinkEvent {
    /// Consumed by the first message issued at or after this instant.
    pub at: Ns,
    /// Backup index whose link the event sits on.
    pub backup: usize,
    pub kind: LinkEventKind,
}

/// A probabilistic drop window: attempts issued in `[from, until)`
/// toward `backup` are dropped with probability `ppm / 1e6`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LossWindow {
    pub backup: usize,
    pub from: Ns,
    pub until: Ns,
    /// Drop probability in parts per million (exact round-tripping).
    pub ppm: u64,
}

/// Run-long random loss on one backup's link (`loss:B:p%`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LossRate {
    pub backup: usize,
    /// Drop probability in parts per million.
    pub ppm: u64,
}

/// A deterministic per-backup link-fault schedule (see module docs).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct LinkPlan {
    events: Vec<LinkEvent>,
    windows: Vec<LossWindow>,
    rates: Vec<LossRate>,
}

/// Parse a probability: `30%` / `0.125%` (percent) or `0.3` (fraction),
/// returned in parts per million.
fn parse_ppm(s: &str) -> Result<u64> {
    let s = s.trim();
    let (num, scale) = match s.strip_suffix('%') {
        Some(pct) => (pct.trim(), 10_000.0),
        None => (s, 1_000_000.0),
    };
    let p: f64 = num
        .parse()
        .map_err(|e| anyhow!("bad probability {s:?}: {e}"))?;
    let ppm = (p * scale).round();
    if !(0.0..=1_000_000.0).contains(&ppm) {
        bail!("probability {s:?} out of range (expected 0..=100% or 0..=1)");
    }
    Ok(ppm as u64)
}

/// Render a ppm probability in canonical percent form (`300_000` →
/// `"30%"`; f64 Display picks the shortest round-tripping repr).
fn fmt_ppm(ppm: u64) -> String {
    format!("{}%", ppm as f64 / 10_000.0)
}

impl LinkPlan {
    /// Build a plan from parts (events are sorted by time; shape is
    /// checked by [`LinkPlan::validate`]).
    pub fn new(
        mut events: Vec<LinkEvent>,
        windows: Vec<LossWindow>,
        rates: Vec<LossRate>,
    ) -> Self {
        events.sort_by_key(|e| e.at);
        LinkPlan {
            events,
            windows,
            rates,
        }
    }

    pub fn events(&self) -> &[LinkEvent] {
        &self.events
    }

    pub fn windows(&self) -> &[LossWindow] {
        &self.windows
    }

    pub fn rates(&self) -> &[LossRate] {
        &self.rates
    }

    pub fn len(&self) -> usize {
        self.events.len() + self.windows.len() + self.rates.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty() && self.windows.is_empty() && self.rates.is_empty()
    }

    /// Shape check that needs no group size: well-formed windows
    /// (`from < until`), probabilities already range-checked at parse
    /// time, at most one run-long loss rate per backup.
    pub fn validate_shape(&self) -> Result<()> {
        for w in &self.windows {
            if w.from >= w.until {
                bail!(
                    "link plan: empty loss window {}..{} on backup {}",
                    w.from,
                    w.until,
                    w.backup
                );
            }
        }
        let mut seen: Vec<usize> = self.rates.iter().map(|r| r.backup).collect();
        seen.sort_unstable();
        for pair in seen.windows(2) {
            if pair[0] == pair[1] {
                bail!("link plan: duplicate loss rate for backup {}", pair[0]);
            }
        }
        Ok(())
    }

    /// Shape rules plus indices in range for a group of `backups`.
    pub fn validate(&self, backups: usize) -> Result<()> {
        self.validate_shape()?;
        let oob = self
            .events
            .iter()
            .map(|e| e.backup)
            .chain(self.windows.iter().map(|w| w.backup))
            .chain(self.rates.iter().map(|r| r.backup))
            .find(|&b| b >= backups);
        if let Some(b) = oob {
            bail!("link plan names backup {b} but the group only has {backups}");
        }
        Ok(())
    }
}

impl FromStr for LinkPlan {
    type Err = anyhow::Error;

    /// Parse a `--link-plan` spec: comma-separated `drop:B@T`,
    /// `drop:B@T1..T2:p`, `delay:B@T:D`, `dup:B@T`, `loss:B:p%` entries
    /// (times in ns, underscores allowed; probabilities as `30%` or
    /// `0.3`). The empty string is the empty plan.
    fn from_str(s: &str) -> Result<Self> {
        let parse_ns = |tok: &str, what: &str, v: &str| -> Result<Ns> {
            v.trim()
                .replace('_', "")
                .parse()
                .map_err(|e| anyhow!("link event {tok:?}: bad {what}: {e}"))
        };
        let mut events = Vec::new();
        let mut windows = Vec::new();
        let mut rates = Vec::new();
        for tok in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let (kind_s, rest) = tok.split_once(':').ok_or_else(|| {
                anyhow!("link event {tok:?}: expected drop:/delay:/dup:/loss:")
            })?;
            let kind_s = kind_s.trim().to_ascii_lowercase();
            if kind_s == "loss" {
                let (backup_s, p_s) = rest
                    .split_once(':')
                    .ok_or_else(|| anyhow!("link event {tok:?}: expected loss:B:p%"))?;
                let backup: usize = backup_s
                    .trim()
                    .parse()
                    .map_err(|e| anyhow!("link event {tok:?}: bad backup index: {e}"))?;
                rates.push(LossRate {
                    backup,
                    ppm: parse_ppm(p_s)
                        .map_err(|e| anyhow!("link event {tok:?}: {e}"))?,
                });
                continue;
            }
            let (backup_s, time_s) = rest
                .split_once('@')
                .ok_or_else(|| anyhow!("link event {tok:?}: missing @time"))?;
            let backup: usize = backup_s
                .trim()
                .parse()
                .map_err(|e| anyhow!("link event {tok:?}: bad backup index: {e}"))?;
            match kind_s.as_str() {
                "drop" => {
                    if let Some((from_s, rest2)) = time_s.split_once("..") {
                        // Windowed probabilistic drop: drop:B@T1..T2:p.
                        let (until_s, p_s) = rest2.split_once(':').ok_or_else(|| {
                            anyhow!("link event {tok:?}: expected drop:B@T1..T2:p")
                        })?;
                        windows.push(LossWindow {
                            backup,
                            from: parse_ns(tok, "window start", from_s)?,
                            until: parse_ns(tok, "window end", until_s)?,
                            ppm: parse_ppm(p_s)
                                .map_err(|e| anyhow!("link event {tok:?}: {e}"))?,
                        });
                    } else {
                        events.push(LinkEvent {
                            at: parse_ns(tok, "time", time_s)?,
                            backup,
                            kind: LinkEventKind::Drop,
                        });
                    }
                }
                "delay" => {
                    let (at_s, d_s) = time_s.split_once(':').ok_or_else(|| {
                        anyhow!("link event {tok:?}: expected delay:B@T:D")
                    })?;
                    events.push(LinkEvent {
                        at: parse_ns(tok, "time", at_s)?,
                        backup,
                        kind: LinkEventKind::Delay(parse_ns(tok, "delay", d_s)?),
                    });
                }
                "dup" => events.push(LinkEvent {
                    at: parse_ns(tok, "time", time_s)?,
                    backup,
                    kind: LinkEventKind::Dup,
                }),
                other => {
                    bail!("unknown link fault {other:?}; expected drop | delay | dup | loss")
                }
            }
        }
        let plan = LinkPlan::new(events, windows, rates);
        plan.validate_shape()?;
        Ok(plan)
    }
}

impl fmt::Display for LinkPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut items: Vec<(Ns, String)> = self
            .events
            .iter()
            .map(|e| {
                let s = match e.kind {
                    LinkEventKind::Drop => format!("drop:{}@{}", e.backup, e.at),
                    LinkEventKind::Delay(d) => format!("delay:{}@{}:{}", e.backup, e.at, d),
                    LinkEventKind::Dup => format!("dup:{}@{}", e.backup, e.at),
                };
                (e.at, s)
            })
            .collect();
        items.extend(self.windows.iter().map(|w| {
            (
                w.from,
                format!("drop:{}@{}..{}:{}", w.backup, w.from, w.until, fmt_ppm(w.ppm)),
            )
        }));
        items.sort_by_key(|(at, _)| *at);
        let mut first = true;
        for (_, item) in &items {
            if !first {
                f.write_str(",")?;
            }
            f.write_str(item)?;
            first = false;
        }
        for r in &self.rates {
            if !first {
                f.write_str(",")?;
            }
            write!(f, "loss:{}:{}", r.backup, fmt_ppm(r.ppm))?;
            first = false;
        }
        Ok(())
    }
}

/// Default per-QP ACK timeout (ns): comfortably above the default RTT
/// (2600 ns) so a healthy link never times out spuriously.
pub const DEFAULT_TRANSPORT_TIMEOUT_NS: Ns = 8_000;
/// Default retransmission budget before the QP enters error state —
/// the RC verbs' maximum `retry_cnt`.
pub const DEFAULT_RETRY_COUNT: u32 = 7;
/// Cap on the exponential-backoff shift (keeps `timeout << attempt`
/// well inside u64 for any plausible retry budget).
const BACKOFF_SHIFT_CAP: u32 = 20;

/// Lossy-link configuration (`[link]` table / `--link-plan` +
/// transport knobs). The default — empty plan, unbounded receiver — is
/// disabled: no link state is attached and the wire path is the
/// pre-link tree, event-for-event.
#[derive(Clone, Debug, PartialEq)]
pub struct LinkConfig {
    pub plan: LinkPlan,
    /// Per-QP ACK timeout arming retransmission (ns).
    pub transport_timeout_ns: Ns,
    /// Retransmissions allowed before the QP enters error state.
    pub retry_count: u32,
    /// Remote pending-buffer capacity in lines (0 = unbounded): at or
    /// above it the receiver answers RNR NAK. Gives `rpmem-flush`'s
    /// volatile buffer a real capacity.
    pub rnr_depth: usize,
    /// Seed of the probabilistic modes' hash stream.
    pub seed: u64,
}

impl Default for LinkConfig {
    fn default() -> Self {
        LinkConfig {
            plan: LinkPlan::default(),
            transport_timeout_ns: DEFAULT_TRANSPORT_TIMEOUT_NS,
            retry_count: DEFAULT_RETRY_COUNT,
            rnr_depth: 0,
            seed: 0,
        }
    }
}

impl LinkConfig {
    /// Parse `spec` as the link plan, with default transport knobs —
    /// the common construction across tests and benches.
    pub fn with_plan(spec: &str) -> Result<Self> {
        Ok(LinkConfig {
            plan: spec.parse()?,
            ..LinkConfig::default()
        })
    }

    /// Whether any link machinery is active. False is the guard-clause
    /// anchor: no [`LinkState`] is attached, no duplicate suppression,
    /// the pre-link wire path bit for bit.
    pub fn enabled(&self) -> bool {
        !self.plan.is_empty() || self.rnr_depth > 0
    }

    /// Validate against the replica-group size.
    pub fn validate(&self, backups: usize) -> Result<()> {
        self.plan.validate(backups)?;
        if self.enabled() && self.transport_timeout_ns == 0 {
            bail!("[link] transport_timeout_ns must be > 0 when the link is enabled");
        }
        Ok(())
    }
}

/// The wire fate of one message after the link layer and the RC retry
/// machinery have spoken.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TxOutcome {
    /// Delivered: `first` is the arrival instant of the copy the remote
    /// applies; `dup` (arriving at or after `first`) is a duplicate
    /// delivery the remote's PSN dedup will drop.
    Deliver { first: Ns, dup: Option<Ns> },
    /// Retry exhaustion: nothing arrived and the QP is now in error
    /// state (see `Fabric::heal_qp_errors`).
    Lost,
}

enum Fate {
    Deliver,
    Drop,
    Delay(Ns),
    Dup,
}

/// Per-requester-stack RC transport state: this backup's slice of the
/// [`LinkPlan`] plus the retry machinery and its counters. Lives inside
/// [`super::rdma::Rdma`] only when [`LinkConfig::enabled`].
#[derive(Clone, Debug)]
pub struct LinkState {
    /// This backup's one-shot events, time-sorted; `cursor` is the next
    /// unconsumed one.
    events: Vec<(Ns, LinkEventKind)>,
    cursor: usize,
    /// This backup's loss windows `(from, until, ppm)`.
    windows: Vec<(Ns, Ns, u64)>,
    /// Run-long loss probability (ppm; 0 = none).
    rate_ppm: u64,
    timeout_ns: Ns,
    retry_count: u32,
    rnr_depth: usize,
    /// Hash-stream key: seed mixed with the backup id and the owning
    /// fabric's shard, so replica stacks roll independent streams.
    stream: u64,
    /// Messages transmitted (the hash stream's message index).
    msg: u64,
    /// QP in error state: retry budget exhausted; nothing reaches the
    /// wire until the fabric heals the connection.
    pub qp_error: bool,
    // stats
    /// Re-sends of any cause (timeout or RNR) — `>= timeouts`.
    pub retransmits: u64,
    /// ACK-timeout expiries (lost messages and over-delayed acks).
    pub timeouts: u64,
    /// RNR NAKs taken at a saturated receiver.
    pub rnr_naks: u64,
    /// Transitions into QP error state (each heals via a transient
    /// kill + rejoin episode).
    pub qp_resets: u64,
    /// Total ns spent in timeout/backoff waits (shifts arrivals only —
    /// retransmission is NIC hardware, not CPU time).
    pub backoff_ns: Ns,
    /// Duplicate line deliveries injected (dup events and spurious
    /// retransmits, counted per line by the caller).
    pub dups_injected: u64,
}

impl LinkState {
    /// Build the per-stack state for `backup`; `salt` (the owning
    /// fabric's shard) decorrelates hash streams across sharded lanes.
    pub fn new(cfg: &LinkConfig, backup: usize, salt: u64) -> Self {
        LinkState {
            events: cfg
                .plan
                .events
                .iter()
                .filter(|e| e.backup == backup)
                .map(|e| (e.at, e.kind))
                .collect(),
            cursor: 0,
            windows: cfg
                .plan
                .windows
                .iter()
                .filter(|w| w.backup == backup)
                .map(|w| (w.from, w.until, w.ppm))
                .collect(),
            rate_ppm: cfg
                .plan
                .rates
                .iter()
                .find(|r| r.backup == backup)
                .map_or(0, |r| r.ppm),
            timeout_ns: cfg.transport_timeout_ns,
            retry_count: cfg.retry_count,
            rnr_depth: cfg.rnr_depth,
            stream: cfg
                .seed
                .wrapping_mul(0x9e37_79b9_7f4a_7c15)
                .wrapping_add((backup as u64) << 32 | salt),
            msg: 0,
            qp_error: false,
            retransmits: 0,
            timeouts: 0,
            rnr_naks: 0,
            qp_resets: 0,
            backoff_ns: 0,
            dups_injected: 0,
        }
    }

    /// Remote pending-buffer capacity (0 = unbounded; the caller checks
    /// saturation against its remote engine).
    pub fn rnr_depth(&self) -> usize {
        self.rnr_depth
    }

    /// Common-random-numbers roll for attempt `attempt` of the current
    /// message: a pure function of (seed, backup/shard, message,
    /// attempt), independent of any loss probability — see module docs.
    fn roll(&self, attempt: u32) -> u64 {
        let mut g = Pcg64::with_stream(self.stream, (self.msg << 8) | attempt as u64);
        g.next_u64() % 1_000_000
    }

    /// The plan's verdict for one transmission attempt issued at
    /// `send_at`: a pending one-shot event is consumed first; otherwise
    /// a covering loss window, else the run-long rate, rolls a drop.
    fn consult(&mut self, send_at: Ns, attempt: u32) -> Fate {
        if let Some(&(at, kind)) = self.events.get(self.cursor) {
            if at <= send_at {
                self.cursor += 1;
                return match kind {
                    LinkEventKind::Drop => Fate::Drop,
                    LinkEventKind::Delay(d) => Fate::Delay(d),
                    LinkEventKind::Dup => Fate::Dup,
                };
            }
        }
        let ppm = self
            .windows
            .iter()
            .find(|&&(from, until, _)| from <= send_at && send_at < until)
            .map(|&(_, _, ppm)| ppm)
            .unwrap_or(self.rate_ppm);
        if ppm > 0 && self.roll(attempt) < ppm {
            Fate::Drop
        } else {
            Fate::Deliver
        }
    }

    /// Resolve the wire fate of one message issued at `iss` over a
    /// one-way latency of `half` ns. `saturated` is the receiver's RNR
    /// condition at issue time. Retransmission shifts arrival instants
    /// only — it is NIC hardware, so no thread clock is touched.
    pub fn transmit(&mut self, iss: Ns, half: Ns, saturated: bool) -> TxOutcome {
        if self.qp_error {
            // Error state: the send queue is frozen until the fabric
            // re-establishes the connection (no counters — nothing was
            // transmitted).
            return TxOutcome::Lost;
        }
        self.msg += 1;
        let mut send_at = iss;
        if saturated {
            // RNR NAK: the receiver refuses the message; the requester
            // learns after a NAK round-trip and retries one backoff
            // period later. One NAK per message — the buffer admits the
            // retry (the penalty models the concurrent drain).
            let wait = 2 * half + self.timeout_ns;
            self.rnr_naks += 1;
            self.retransmits += 1;
            self.backoff_ns += wait;
            send_at += wait;
        }
        let mut attempt: u32 = 0;
        loop {
            match self.consult(send_at, attempt) {
                Fate::Deliver => {
                    return TxOutcome::Deliver {
                        first: send_at + half,
                        dup: None,
                    }
                }
                Fate::Dup => {
                    let a = send_at + half;
                    return TxOutcome::Deliver {
                        first: a,
                        dup: Some(a),
                    };
                }
                Fate::Delay(d) => {
                    if d >= self.timeout_ns {
                        // The ack misses the timeout window: the
                        // requester retransmits although the original
                        // is still in flight — the classic duplicate
                        // the PSN dedup exists for.
                        self.timeouts += 1;
                        self.retransmits += 1;
                        self.backoff_ns += self.timeout_ns;
                        let original = send_at + half + d;
                        let retx = send_at + self.timeout_ns + half;
                        let (first, dup) = if retx <= original {
                            (retx, original)
                        } else {
                            (original, retx)
                        };
                        return TxOutcome::Deliver {
                            first,
                            dup: Some(dup),
                        };
                    }
                    return TxOutcome::Deliver {
                        first: send_at + half + d,
                        dup: None,
                    };
                }
                Fate::Drop => {
                    if attempt >= self.retry_count {
                        self.qp_error = true;
                        self.qp_resets += 1;
                        return TxOutcome::Lost;
                    }
                    // The ACK timeout expires, then the retransmit goes
                    // out with exponential backoff.
                    let wait = self.timeout_ns << attempt.min(BACKOFF_SHIFT_CAP);
                    self.timeouts += 1;
                    self.retransmits += 1;
                    self.backoff_ns += wait;
                    send_at += wait;
                    attempt += 1;
                }
            }
        }
    }

    /// Connection re-establishment after retry exhaustion: clear the
    /// error state (the owning fabric resets the QPs and replays the
    /// lost suffix through the resync machinery).
    pub fn clear_error(&mut self) {
        self.qp_error = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_parse_and_display_round_trip() {
        let plan: LinkPlan = "drop:1@5_000, delay:0@2000:300,dup:1@9000".parse().unwrap();
        assert_eq!(plan.events().len(), 3);
        assert_eq!(plan.to_string(), "delay:0@2000:300,drop:1@5000,dup:1@9000");
        let again: LinkPlan = plan.to_string().parse().unwrap();
        assert_eq!(plan, again);
        assert!("".parse::<LinkPlan>().unwrap().is_empty());
        assert!("  ".parse::<LinkPlan>().unwrap().is_empty());
    }

    #[test]
    fn windows_and_rates_round_trip() {
        let plan: LinkPlan = "drop:0@1000..5000:30%,loss:1:5%".parse().unwrap();
        assert_eq!(plan.windows().len(), 1);
        assert_eq!(plan.windows()[0].ppm, 300_000);
        assert_eq!(plan.rates().len(), 1);
        assert_eq!(plan.rates()[0].ppm, 50_000);
        assert_eq!(plan.to_string(), "drop:0@1000..5000:30%,loss:1:5%");
        let again: LinkPlan = plan.to_string().parse().unwrap();
        assert_eq!(plan, again);
        // Fractional probabilities parse too, and sub-percent rates
        // survive the round trip.
        let plan: LinkPlan = "drop:0@1..9:0.3,loss:2:0.125%".parse().unwrap();
        assert_eq!(plan.windows()[0].ppm, 300_000);
        assert_eq!(plan.rates()[0].ppm, 1_250);
        assert_eq!(plan.to_string().parse::<LinkPlan>().unwrap(), plan);
    }

    #[test]
    fn plan_parse_rejects_malformed_specs() {
        for bad in [
            "drop",
            "drop:1",
            "drop:@100",
            "drop:x@100",
            "drop:1@",
            "drop:1@abc",
            "snip:1@100",
            "delay:1@100",
            "delay:1@100:x",
            "dup:1",
            "loss:1",
            "loss:1:200%",
            "loss:1:1.5",
            "drop:1@100..50:10%",
            "drop:1@100..100:10%",
            "loss:0:1%,loss:0:2%",
        ] {
            assert!(bad.parse::<LinkPlan>().is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn plan_validation_checks_indices() {
        let plan: LinkPlan = "drop:2@100".parse().unwrap();
        assert!(plan.validate(2).is_err());
        plan.validate(3).unwrap();
        let plan: LinkPlan = "loss:1:10%".parse().unwrap();
        assert!(plan.validate(1).is_err());
        plan.validate(2).unwrap();
    }

    #[test]
    fn config_default_is_disabled() {
        let cfg = LinkConfig::default();
        assert!(!cfg.enabled());
        cfg.validate(1).unwrap();
        // A plan or a bounded receiver enables the machinery.
        assert!(LinkConfig::with_plan("drop:0@100").unwrap().enabled());
        assert!(LinkConfig {
            rnr_depth: 8,
            ..LinkConfig::default()
        }
        .enabled());
        // An enabled link needs a live timeout.
        let cfg = LinkConfig {
            transport_timeout_ns: 0,
            ..LinkConfig::with_plan("drop:0@100").unwrap()
        };
        assert!(cfg.validate(1).is_err());
    }

    fn state(spec: &str) -> LinkState {
        LinkState::new(&LinkConfig::with_plan(spec).unwrap(), 0, 0)
    }

    #[test]
    fn clean_link_is_identity() {
        let mut s = state("");
        assert_eq!(
            s.transmit(1_000, 1_300, false),
            TxOutcome::Deliver {
                first: 2_300,
                dup: None
            }
        );
        assert_eq!(s.retransmits, 0);
        assert_eq!(s.timeouts, 0);
    }

    #[test]
    fn one_shot_drop_costs_one_timeout_backoff() {
        let mut s = state("drop:0@500");
        // Issued before the event: untouched, event stays armed.
        assert_eq!(
            s.transmit(100, 1_300, false),
            TxOutcome::Deliver {
                first: 1_400,
                dup: None
            }
        );
        // First message at/after t=500 consumes the drop: one timeout,
        // retransmit delivered one backoff later.
        let out = s.transmit(600, 1_300, false);
        assert_eq!(
            out,
            TxOutcome::Deliver {
                first: 600 + DEFAULT_TRANSPORT_TIMEOUT_NS + 1_300,
                dup: None
            }
        );
        assert_eq!(s.timeouts, 1);
        assert_eq!(s.retransmits, 1);
        assert_eq!(s.backoff_ns, DEFAULT_TRANSPORT_TIMEOUT_NS);
        // Event consumed: the next message sails through.
        assert_eq!(
            s.transmit(700, 1_300, false),
            TxOutcome::Deliver {
                first: 2_000,
                dup: None
            }
        );
    }

    #[test]
    fn short_delay_shifts_arrival_long_delay_duplicates() {
        let mut s = state("delay:0@0:500");
        assert_eq!(
            s.transmit(100, 1_300, false),
            TxOutcome::Deliver {
                first: 100 + 1_300 + 500,
                dup: None
            }
        );
        assert_eq!(s.retransmits, 0);
        // A delay past the ACK timeout triggers a spurious retransmit:
        // the retransmit's copy arrives first, the original becomes the
        // duplicate.
        let mut s = state("delay:0@0:20000");
        let out = s.transmit(100, 1_300, false);
        let retx = 100 + DEFAULT_TRANSPORT_TIMEOUT_NS + 1_300;
        let original = 100 + 1_300 + 20_000;
        assert_eq!(
            out,
            TxOutcome::Deliver {
                first: retx,
                dup: Some(original)
            }
        );
        assert_eq!(s.timeouts, 1);
        assert_eq!(s.retransmits, 1);
    }

    #[test]
    fn dup_event_delivers_twice() {
        let mut s = state("dup:0@0");
        assert_eq!(
            s.transmit(100, 1_300, false),
            TxOutcome::Deliver {
                first: 1_400,
                dup: Some(1_400)
            }
        );
    }

    #[test]
    fn rnr_nak_retries_after_nak_round_trip() {
        let mut s = LinkState::new(
            &LinkConfig {
                rnr_depth: 4,
                ..LinkConfig::default()
            },
            0,
            0,
        );
        let wait = 2 * 1_300 + DEFAULT_TRANSPORT_TIMEOUT_NS;
        assert_eq!(
            s.transmit(100, 1_300, true),
            TxOutcome::Deliver {
                first: 100 + wait + 1_300,
                dup: None
            }
        );
        assert_eq!(s.rnr_naks, 1);
        assert_eq!(s.retransmits, 1);
        assert_eq!(s.timeouts, 0, "an RNR NAK is not an ACK timeout");
        assert!(s.retransmits >= s.timeouts);
    }

    #[test]
    fn certain_loss_window_exhausts_retries_into_qp_error() {
        let mut cfg = LinkConfig::with_plan("drop:0@0..100000000:100%").unwrap();
        cfg.retry_count = 3;
        let mut s = LinkState::new(&cfg, 0, 0);
        assert_eq!(s.transmit(1_000, 1_300, false), TxOutcome::Lost);
        assert!(s.qp_error);
        assert_eq!(s.qp_resets, 1);
        assert_eq!(s.retransmits, 3);
        assert_eq!(s.timeouts, 3);
        // Exponential backoff: t + t<<1 + t<<2.
        assert_eq!(s.backoff_ns, DEFAULT_TRANSPORT_TIMEOUT_NS * 7);
        // Error state freezes the send queue without new counters.
        assert_eq!(s.transmit(2_000, 1_300, false), TxOutcome::Lost);
        assert_eq!(s.qp_resets, 1);
        // Healing re-opens the wire.
        s.clear_error();
        assert!(matches!(
            s.transmit(200_000_000, 1_300, false),
            TxOutcome::Deliver { .. }
        ));
    }

    #[test]
    fn window_escapes_after_until() {
        let mut cfg = LinkConfig::with_plan("drop:0@0..10000:100%").unwrap();
        cfg.retry_count = 7;
        let mut s = LinkState::new(&cfg, 0, 0);
        // First attempts drop inside the window; backoff walks the
        // retransmit out of it and the message finally lands.
        let out = s.transmit(0, 1_300, false);
        match out {
            TxOutcome::Deliver { first, .. } => {
                assert!(first >= 10_000, "delivered inside the window: {first}")
            }
            TxOutcome::Lost => panic!("retry budget should outlast the window"),
        }
        assert!(s.retransmits >= 1);
        assert!(!s.qp_error);
    }

    #[test]
    fn random_loss_is_deterministic_and_monotone_in_rate() {
        // Same seed, increasing loss rates: common random numbers make
        // each message's delivery latency monotone in the rate.
        let run = |ppm: u64| -> (Vec<Ns>, u64) {
            let cfg = LinkConfig {
                plan: LinkPlan::new(
                    Vec::new(),
                    Vec::new(),
                    vec![LossRate { backup: 0, ppm }],
                ),
                seed: 42,
                ..LinkConfig::default()
            };
            let mut s = LinkState::new(&cfg, 0, 0);
            let arrivals: Vec<Ns> = (0..200u64)
                .map(|i| match s.transmit(i * 3_000, 1_300, false) {
                    TxOutcome::Deliver { first, .. } => first,
                    TxOutcome::Lost => Ns::MAX,
                })
                .collect();
            (arrivals, s.retransmits)
        };
        let (a10, r10) = run(100_000);
        let (a10b, _) = run(100_000);
        assert_eq!(a10, a10b, "same seed must replay identically");
        let (a30, r30) = run(300_000);
        for (i, (x, y)) in a10.iter().zip(&a30).enumerate() {
            assert!(x <= y, "message {i}: latency not monotone ({x} > {y})");
        }
        assert!(r30 > r10, "higher rate must retransmit more");
        // A different seed rolls a different realization.
        let cfg = LinkConfig {
            plan: LinkPlan::new(
                Vec::new(),
                Vec::new(),
                vec![LossRate {
                    backup: 0,
                    ppm: 100_000,
                }],
            ),
            seed: 43,
            ..LinkConfig::default()
        };
        let mut s = LinkState::new(&cfg, 0, 0);
        let a_other: Vec<Ns> = (0..200u64)
            .map(|i| match s.transmit(i * 3_000, 1_300, false) {
                TxOutcome::Deliver { first, .. } => first,
                TxOutcome::Lost => Ns::MAX,
            })
            .collect();
        assert_ne!(a10, a_other, "seed must steer the realization");
    }
}
