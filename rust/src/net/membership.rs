//! Deterministic leader election over certified durability ledgers.
//!
//! When the primary dies (`kill:p@T` in a [`crate::net::FaultPlan`]),
//! the surviving backups elect a new primary with the one-sided
//! CAS-and-permissions protocol of *The Impact of RDMA on Agreement*
//! (arXiv:1905.12143): each candidate campaigns with its **certified
//! prefix** — the number of lines its durability ledger has made
//! persistent — and the longest prefix wins, ties broken by the lowest
//! replica id. Because every durably-acked transaction reached at least
//! the ack policy's `required` backups before its commit returned, the
//! longest certified prefix necessarily covers every acked transaction
//! (leader completeness; checked end-to-end by
//! [`crate::recovery::check_leader_completeness`]).
//!
//! This module is the pure decision rule; the fabric drives it at the
//! kill instant and charges the election/revocation/re-replication costs
//! ([`crate::net::faults::ElectionConfig`]). A sharded mirror sums each
//! node's per-shard prefixes first so all S shards fail over to the same
//! winner as one node (see `coordinator`).

/// One election candidate: a surviving backup and the length of its
/// certified ledger prefix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Candidate {
    /// Replica id (backup slot index).
    pub id: usize,
    /// Certified prefix length: durably persisted lines this replica can
    /// prove (ledger length, or the persist counter when ledgers are
    /// off).
    pub certified: u64,
}

/// Elect a leader: the candidate with the longest certified prefix wins,
/// ties broken by the lowest id. Returns `None` when no candidate
/// survives (the group is unrecoverable — the caller stalls).
pub fn elect(candidates: &[Candidate]) -> Option<usize> {
    candidates
        .iter()
        .max_by(|a, b| {
            a.certified
                .cmp(&b.certified)
                // Reverse the id order so max_by prefers the LOWEST id on
                // equal prefixes.
                .then(b.id.cmp(&a.id))
        })
        .map(|c| c.id)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn c(id: usize, certified: u64) -> Candidate {
        Candidate { id, certified }
    }

    #[test]
    fn longest_certified_prefix_wins() {
        assert_eq!(elect(&[c(0, 10), c(1, 25), c(2, 7)]), Some(1));
        assert_eq!(elect(&[c(2, 3), c(0, 9)]), Some(0));
    }

    #[test]
    fn ties_break_to_the_lowest_id() {
        assert_eq!(elect(&[c(2, 10), c(0, 10), c(1, 10)]), Some(0));
        assert_eq!(elect(&[c(2, 10), c(1, 10), c(0, 3)]), Some(1));
    }

    #[test]
    fn empty_field_elects_nobody() {
        assert_eq!(elect(&[]), None);
    }

    #[test]
    fn order_of_candidates_is_irrelevant() {
        let mut field = vec![c(3, 5), c(1, 9), c(2, 9), c(0, 1)];
        let winner = elect(&field);
        field.reverse();
        assert_eq!(elect(&field), winner);
        assert_eq!(winner, Some(1));
    }
}
