//! RDMA network models: local queue pairs, the per-backup requester
//! stack, the remote (backup) NIC engine with its memory subsystem, the
//! verb layer tying them together with the paper's §6.2 latency
//! semantics, the staged WQE submission pipeline with doorbell batching
//! and flush-time coalescing — write combining + scatter-gather spans
//! ([`wqe`]) — and the N-way replica-group [`Fabric`] with pluggable
//! ack policies and deterministic failure dynamics ([`faults`]): backups
//! can be killed and rejoin mid-run, with catch-up resync and
//! halt/degrade loss handling. The primary can die too: [`membership`]
//! holds the deterministic leader-election rule (longest certified
//! ledger prefix, ties to the lowest id) the fabric runs on `kill:p@T`,
//! fencing the old primary's staged WQE chains via permission revocation
//! and re-replicating the winner's suffix before admitting writes.
//! The wire itself can misbehave: [`link`] injects deterministic
//! per-backup loss/delay/duplication plans at the wire-issue point,
//! masked by RC retry machinery (ACK timeout + exponential backoff,
//! RNR NAKs at a bounded receiver buffer, QP error state healed via a
//! transient kill + rejoin episode) with PSN-style duplicate
//! suppression at the remote ledger boundary.

pub mod fabric;
pub mod faults;
pub mod link;
pub mod membership;
pub mod qp;
pub mod rdma;
pub mod remote;
pub mod verbs;
pub mod wqe;

pub use fabric::{BackupStats, Fabric};
pub use faults::{
    effective_required, BackupState, ElectionConfig, FaultEvent, FaultKind, FaultPlan,
    FaultTimeline, FaultsConfig, OnLoss, PrimaryEvent, Stall,
};
pub use link::{LinkConfig, LinkEvent, LinkEventKind, LinkPlan, LinkState, TxOutcome};
pub use membership::{elect, Candidate};
pub use qp::LocalQp;
pub use rdma::Rdma;
pub use remote::{PersistDomain, RemoteEngine};
pub use verbs::WriteMeta;
pub use wqe::{
    BatchingConfig, CoalesceMode, CoalescingConfig, FlushPolicy, SubmitQueue, Wqe,
};
