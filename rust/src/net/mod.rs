//! RDMA network models: local queue pairs, the fabric, the remote (backup)
//! NIC engine with its memory subsystem, and the verb layer tying them
//! together with the paper's §6.2 latency semantics.

pub mod qp;
pub mod rdma;
pub mod remote;
pub mod verbs;

pub use qp::LocalQp;
pub use rdma::Rdma;
pub use remote::RemoteEngine;
pub use verbs::WriteMeta;
