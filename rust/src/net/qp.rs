//! Local (requester-side) queue-pair model.
//!
//! A QP issues one WQE every `gap` ns (message-rate limit of the RNIC) and
//! tracks a bounded window of outstanding (un-completed) WQEs: posting
//! blocks when `depth` requests are in flight — this is how remote-side
//! back-pressure (e.g. a full MC write queue under SM-DD) propagates back
//! to the issuing thread, producing the paper's "frequent pauses".
//!
//! Doorbell batching (see [`crate::net::wqe`]) lives *above* this model:
//! a flushed chain drives [`LocalQp::post`] once per WQE, so the gap,
//! window and back-pressure semantics are identical whether a WQE was
//! posted eagerly or launched as part of a coalesced chain — batching
//! amortizes only the CPU-side doorbell cost, never the wire model.
//! A scatter-gather *span* (one WQE carrying several contiguous lines)
//! posts through [`LocalQp::post_with`]: it takes one window slot and
//! one issue-pipeline slot like any WQE, but occupies the issue stage
//! for `gap + extra` where `extra` is the span's additional per-line
//! serialization — the amortization the coalescer buys on the wire.

use crate::sim::FifoResource;
use crate::Ns;
use std::collections::VecDeque;

/// Requester-side queue pair.
#[derive(Clone, Debug)]
pub struct LocalQp {
    issue: FifoResource,
    gap: Ns,
    depth: usize,
    /// Completion times of outstanding WQEs (ascending — completions on a
    /// QP are ordered by the RDMA spec).
    inflight: VecDeque<Ns>,
    /// Stats: total WQEs posted and total stall waiting for window space.
    pub posted: u64,
    pub window_stall_ns: Ns,
}

impl LocalQp {
    pub fn new(gap: Ns, depth: usize) -> Self {
        assert!(depth > 0);
        LocalQp {
            issue: FifoResource::new(),
            gap,
            depth,
            inflight: VecDeque::with_capacity(depth + 1),
            posted: 0,
            window_stall_ns: 0,
        }
    }

    /// Post a WQE at thread-time `at`. Returns `(ready, start)`: `ready`
    /// is when the posting CPU regains control (later than `at` only when
    /// the send window was full — remote back-pressure reaching the
    /// thread), `start` the instant the WQE leaves the NIC toward the
    /// wire. The caller must later call [`LocalQp::complete`] with the
    /// WQE's completion time.
    pub fn post(&mut self, at: Ns) -> (Ns, Ns) {
        self.post_with(at, 0)
    }

    /// Post a WQE whose issue stage is occupied `extra` ns beyond the
    /// per-WQE gap — a scatter-gather span serializing its additional
    /// lines onto the wire. The window cost is identical to [`post`]:
    /// one slot per WQE, regardless of span size.
    ///
    /// [`post`]: LocalQp::post
    pub fn post_with(&mut self, at: Ns, extra: Ns) -> (Ns, Ns) {
        // Retire completions that have already arrived.
        while let Some(&head) = self.inflight.front() {
            if head <= at {
                self.inflight.pop_front();
            } else {
                break;
            }
        }
        let mut ready = at;
        if self.inflight.len() >= self.depth {
            // Window full: wait for the oldest outstanding completion.
            let head = self.inflight.pop_front().expect("depth > 0");
            self.window_stall_ns += head.saturating_sub(at);
            ready = ready.max(head);
        }
        let (start, _done) = self.issue.submit(ready, self.gap + extra);
        self.posted += 1;
        (ready, start)
    }

    /// Register the completion time of the most recently posted WQE.
    /// Completion times on a QP must be monotone (RDMA ordered channel);
    /// the model clamps to enforce it.
    pub fn complete(&mut self, done: Ns) {
        let done = self
            .inflight
            .back()
            .map_or(done, |&last| done.max(last));
        self.inflight.push_back(done);
    }

    /// Completion time of the newest outstanding WQE (0 if none ever).
    pub fn last_completion(&self) -> Ns {
        self.inflight.back().copied().unwrap_or(0)
    }

    /// Time the issue pipeline next frees up.
    pub fn next_issue(&self) -> Ns {
        self.issue.next_free()
    }

    pub fn reset(&mut self) {
        self.issue.reset();
        self.inflight.clear();
        self.posted = 0;
        self.window_stall_ns = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn issue_rate_is_gap_limited() {
        let mut qp = LocalQp::new(150, 64);
        let (_, s1) = qp.post(0);
        qp.complete(10_000);
        let (_, s2) = qp.post(0);
        qp.complete(10_000);
        assert_eq!(s1, 0);
        assert_eq!(s2, 150);
    }

    #[test]
    fn window_blocks_when_full() {
        let mut qp = LocalQp::new(10, 2);
        let (_, s1) = qp.post(0);
        qp.complete(1_000);
        let (_, s2) = qp.post(0);
        qp.complete(2_000);
        assert_eq!((s1, s2), (0, 10));
        // Third post must wait for the first completion (t=1000).
        let (r3, s3) = qp.post(0);
        assert!(s3 >= 1_000, "expected window stall, got {s3}");
        assert!(r3 >= 1_000, "thread must block too, got {r3}");
        assert!(qp.window_stall_ns > 0);
    }

    #[test]
    fn completions_clamped_monotone() {
        let mut qp = LocalQp::new(10, 8);
        qp.post(0);
        qp.complete(500);
        qp.post(0);
        qp.complete(300); // out of order: clamped up to 500
        assert_eq!(qp.last_completion(), 500);
    }

    #[test]
    fn span_occupies_issue_stage_longer() {
        let mut qp = LocalQp::new(150, 64);
        // A 4-line span (3 extra lines x 20 ns) holds the issue stage
        // for 150 + 60 ns; the next WQE issues after it.
        let (_, s1) = qp.post_with(0, 60);
        qp.complete(10_000);
        let (_, s2) = qp.post(0);
        qp.complete(10_000);
        assert_eq!(s1, 0);
        assert_eq!(s2, 210);
        // post() is exactly post_with(extra = 0).
        let mut a = LocalQp::new(150, 2);
        let mut b = LocalQp::new(150, 2);
        for t in [0u64, 10, 400] {
            assert_eq!(a.post(t), b.post_with(t, 0));
            a.complete(t + 500);
            b.complete(t + 500);
        }
    }

    #[test]
    fn retired_completions_free_window() {
        let mut qp = LocalQp::new(10, 1);
        qp.post(0);
        qp.complete(100);
        // At t=200 the previous WQE has completed; no stall.
        let (_, s) = qp.post(200);
        assert_eq!(s, 200);
        assert_eq!(qp.window_stall_ns, 0);
    }
}
