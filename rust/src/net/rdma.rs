//! Requester-side RDMA facade: the verb API used by replication
//! strategies.
//!
//! Owns the local QPs and the remote engine; implements the end-to-end
//! latency of every verb (thread post cost -> QP issue -> fabric ->
//! remote processing -> completion) with the paper's §6.2 semantics.
//!
//! QP topology: multi-QP strategies (SM-RC, SM-OB) use `nqp` QPs *per
//! thread* (the standard RDMA idiom — QPs are per-connection resources),
//! so per-thread issue streams are time-ordered and artifact-free, while
//! a NIC-wide rate limiter models the adapter's aggregate message rate.
//! SM-DD deliberately routes **all threads through one shared QP** (its
//! ordering trick — and its stated scalability weakness): a shared
//! rate limiter carries that bottleneck, with per-thread send windows
//! coupling remote back-pressure to the issuing threads.
//!
//! Data WQEs traverse the (optionally lossy) link layer on their way to
//! the remote engine — see [`super::link`] for the drop/delay/dup plan
//! and the RC retry machinery. Fence verbs are modeled reliable: by the
//! time a fence could observe a broken link, the retry exhaustion has
//! already put the QP in error state and the fabric has taken the
//! backup out of the quorum, so the fence never issues toward it.

use super::link::{LinkConfig, LinkState, TxOutcome};
use super::qp::LocalQp;
use super::remote::RemoteEngine;
use super::verbs::{Verb, WriteMeta};
use super::wqe::Wqe;
use crate::config::Platform;
use crate::metrics::LogHistogram;
use crate::sim::{RateLimiter, ThreadClock};
use crate::Ns;
use std::collections::HashMap;

/// Requester NIC + fabric + responder engine.
pub struct Rdma {
    /// Per-(thread, lane) queue pairs for multi-QP strategies.
    lanes: HashMap<(u32, usize), LocalQp>,
    /// Per-thread round-robin lane cursor.
    rr: HashMap<u32, usize>,
    nqp: usize,
    gap: Ns,
    qp_depth: usize,
    /// NIC-wide doorbell/DMA-read aggregate rate (all QPs share the
    /// adapter's message-processing pipeline).
    nic: RateLimiter,
    /// SM-DD's single shared QP: aggregate issue rate across all threads.
    dd_issue: RateLimiter,
    /// Per-thread outstanding-completion windows on the shared QP.
    dd_windows: HashMap<u32, std::collections::VecDeque<Ns>>,
    pub dd_window_stall_ns: Ns,
    /// One-way fabric latency (ns).
    half: Ns,
    post_cost: Ns,
    poll_cost: Ns,
    /// Wire/issue serialization of each extra line in a scatter-gather
    /// span (see [`crate::net::wqe`] and `Platform::wire_line_ns`).
    wire_line_ns: Ns,
    pub remote: RemoteEngine,
    /// Lossy-link + RC transport state (`None` = perfectly reliable
    /// wire, the pre-link anchor: every data WQE arrives exactly once
    /// at `issue + half`).
    link: Option<LinkState>,
    // stats
    /// Data *lines* submitted to the wire (a span counts once per line).
    pub posted_writes: u64,
    /// Data WQEs launched on the wire (a span counts once) —
    /// `wire_wqes <= posted_writes`, equal without scatter-gather.
    pub wire_wqes: u64,
    /// Lines-per-WQE distribution of everything launched (all 1s
    /// without scatter-gather).
    pub span_hist: LogHistogram,
    pub posted_fences: u64,
    pub blocking_waits: u64,
    pub blocked_ns: Ns,
}

impl Rdma {
    pub fn new(p: &Platform, ledger: bool) -> Self {
        Rdma {
            lanes: HashMap::new(),
            rr: HashMap::new(),
            nqp: p.nqp,
            gap: p.gap,
            qp_depth: p.qp_depth,
            // The adapter pipeline sustains ~nqp concurrent QP streams.
            nic: RateLimiter::new((p.gap / p.nqp as Ns).max(1)),
            dd_issue: RateLimiter::new(p.gap),
            dd_windows: HashMap::new(),
            dd_window_stall_ns: 0,
            half: p.rtt / 2,
            post_cost: p.post_cost(),
            poll_cost: p.poll_cost,
            wire_line_ns: p.wire_line_ns,
            remote: RemoteEngine::new(p, ledger),
            link: None,
            posted_writes: 0,
            wire_wqes: 0,
            span_hist: LogHistogram::new(),
            posted_fences: 0,
            blocking_waits: 0,
            blocked_ns: 0,
        }
    }

    /// Next round-robin lane for a thread.
    fn next_lane(&mut self, thread: u32) -> usize {
        let cur = self.rr.entry(thread).or_insert(0);
        let lane = *cur;
        *cur = (*cur + 1) % self.nqp;
        lane
    }

    /// Post on a per-thread lane QP: per-lane gap + NIC-wide rate.
    /// `extra` is a scatter-gather span's additional issue-stage
    /// serialization (0 for the ordinary single-line WQE). Returns
    /// `(ready, issue)`.
    fn post_lane(&mut self, thread: u32, lane: usize, at: Ns, extra: Ns) -> (Ns, Ns) {
        let gap = self.gap;
        let depth = self.qp_depth;
        let qp = self
            .lanes
            .entry((thread, lane))
            .or_insert_with(|| LocalQp::new(gap, depth));
        let (ready, start) = qp.post_with(at, extra);
        let issue = self.nic.submit(start);
        (ready, issue)
    }

    fn complete_lane(&mut self, thread: u32, lane: usize, done: Ns) {
        if let Some(qp) = self.lanes.get_mut(&(thread, lane)) {
            qp.complete(done);
        }
    }

    /// Post on the shared SM-DD QP: per-thread window + shared rate.
    /// `extra` is a scatter-gather span's additional issue-stage
    /// serialization (0 for a single-line WQE): the ordered QP keeps
    /// serializing the span's extra lines after its issue start, so a
    /// time-filtered floor — anchored at this WQE's *arrival*, like the
    /// rofence floors — charges every later-arriving WQE the same
    /// per-extra-line cost the lane QPs charge via FIFO occupancy.
    fn post_dd(&mut self, thread: u32, at: Ns, extra: Ns) -> (Ns, Ns) {
        let win = self.dd_windows.entry(thread).or_default();
        while let Some(&head) = win.front() {
            if head <= at {
                win.pop_front();
            } else {
                break;
            }
        }
        let mut ready = at;
        // Each thread may keep a share of the QP's send queue in flight.
        let share = (self.qp_depth / 4).max(1);
        if win.len() >= share {
            let head = win.pop_front().expect("share >= 1");
            self.dd_window_stall_ns += head.saturating_sub(at);
            ready = ready.max(head);
        }
        let start = self.dd_issue.submit(ready);
        if extra > 0 {
            self.dd_issue.add_floor(ready, start + extra);
        }
        let issue = self.nic.submit(start);
        (ready, issue)
    }

    fn complete_dd(&mut self, thread: u32, done: Ns) {
        let win = self.dd_windows.entry(thread).or_default();
        let done = win.back().map_or(done, |&last| done.max(last));
        win.push_back(done);
    }

    // ---- lossy link + RC transport (see `super::link`) ------------------

    /// Attach a lossy link: this stack's slice of the plan plus the RC
    /// retry machinery, and PSN-style duplicate suppression on the
    /// remote. A no-op when the config is disabled — the guard-clause
    /// anchor.
    pub fn set_link(&mut self, cfg: &LinkConfig, backup: usize, salt: u64) {
        if cfg.enabled() {
            self.link = Some(LinkState::new(cfg, backup, salt));
            self.remote.enable_dedup();
        }
    }

    /// The link transport state, if one is attached.
    pub fn link(&self) -> Option<&LinkState> {
        self.link.as_ref()
    }

    /// Whether the QP sits in error state (retry budget exhausted) and
    /// needs the fabric to heal the connection.
    pub fn qp_error(&self) -> bool {
        self.link.as_ref().is_some_and(|l| l.qp_error)
    }

    /// Connection re-establishment after retry exhaustion: every local
    /// QP resets (in-flight WQEs are gone — the fabric replays the lost
    /// suffix through the resync machinery) and the link leaves error
    /// state.
    pub fn reset_qps(&mut self) {
        for qp in self.lanes.values_mut() {
            qp.reset();
        }
        self.dd_windows.clear();
        if let Some(l) = self.link.as_mut() {
            l.clear_error();
        }
    }

    /// The wire fate of one message issued at `iss`: without a link it
    /// arrives exactly once at `iss + half` (the anchor); with one, the
    /// plan and the RC retry machinery decide (see
    /// [`LinkState::transmit`]).
    fn wire(&mut self, iss: Ns) -> TxOutcome {
        match self.link.as_mut() {
            None => TxOutcome::Deliver {
                first: iss + self.half,
                dup: None,
            },
            Some(l) => {
                let saturated =
                    l.rnr_depth() > 0 && self.remote.pending_lines() >= l.rnr_depth();
                l.transmit(iss, self.half, saturated)
            }
        }
    }

    /// Per-line duplicate-injection accounting (dup events and spurious
    /// retransmits deliver every line of the WQE twice).
    fn note_dup_lines(&mut self, lines: u64) {
        if let Some(l) = self.link.as_mut() {
            l.dups_injected += lines;
        }
    }

    fn block(&mut self, t: &mut ThreadClock, completion: Ns) {
        self.blocking_waits += 1;
        self.blocked_ns += completion.saturating_sub(t.now);
        t.wait_until(completion);
        t.busy(self.poll_cost);
    }

    /// Submit one single-line data WQE through the QP/wire/remote
    /// pipeline WITHOUT charging any CPU post cost — the caller has
    /// already paid the staging (and, per chain, doorbell) cost; see
    /// [`crate::net::wqe`]. The per-WQE gap, send window and remote
    /// back-pressure model is exactly the eager path's.
    pub fn submit_data(&mut self, t: &mut ThreadClock, verb: Verb, meta: WriteMeta) {
        let thread = t.id as u32;
        match verb {
            Verb::Write => {
                let lane = self.next_lane(thread);
                let (ready, iss) = self.post_lane(thread, lane, t.now, 0);
                t.wait_until(ready);
                if let TxOutcome::Deliver { first, dup } = self.wire(iss) {
                    self.remote.write_ddio(lane, first, meta);
                    if let Some(d) = dup {
                        // The duplicate delivery hits the PSN dedup.
                        self.remote.write_ddio(lane, d, meta);
                        self.note_dup_lines(1);
                    }
                    // Posted: the ack returns as soon as the remote NIC
                    // receives it.
                    self.complete_lane(thread, lane, first + self.half);
                }
            }
            Verb::WriteWT => {
                let lane = self.next_lane(thread);
                let (ready, iss) = self.post_lane(thread, lane, t.now, 0);
                t.wait_until(ready);
                if let TxOutcome::Deliver { first, dup } = self.wire(iss) {
                    self.remote.write_wt(lane, first, meta);
                    if let Some(d) = dup {
                        self.remote.write_wt(lane, d, meta);
                        self.note_dup_lines(1);
                    }
                    self.complete_lane(thread, lane, first + self.half);
                }
            }
            Verb::WriteNT => {
                let (ready, iss) = self.post_dd(thread, t.now, 0);
                t.wait_until(ready);
                if let TxOutcome::Deliver { first, dup } = self.wire(iss) {
                    let (_proc, persist) = self.remote.write_nt(0, first, meta);
                    if let Some(d) = dup {
                        self.remote.write_nt(0, d, meta);
                        self.note_dup_lines(1);
                    }
                    self.complete_dd(thread, persist + self.half);
                }
            }
            other => unreachable!("submit_data: {other:?} is not a data verb"),
        }
        self.posted_writes += 1;
        self.wire_wqes += 1;
        self.span_hist.record(1);
    }

    /// Submit one staged WQE — a multi-line scatter-gather span pays a
    /// single QP window slot, a single NIC message slot, and occupies
    /// the QP issue stage `wire_line_ns` per *extra* line; every line
    /// still persists individually on the remote, under one completion
    /// (last line in, one ack out). A single-line WQE takes exactly the
    /// [`Rdma::submit_data`] path.
    pub fn submit_wqe(&mut self, t: &mut ThreadClock, w: &Wqe) {
        if w.tail.is_empty() {
            return self.submit_data(t, w.verb, w.meta);
        }
        let thread = t.id as u32;
        let lines = w.lines() as Ns;
        let extra = (lines - 1) * self.wire_line_ns;
        match w.verb {
            Verb::Write => {
                let lane = self.next_lane(thread);
                let (ready, iss) = self.post_lane(thread, lane, t.now, extra);
                t.wait_until(ready);
                if let TxOutcome::Deliver { first, dup } = self.wire(iss) {
                    self.remote
                        .write_ddio_span(lane, first, self.wire_line_ns, w.meta, &w.tail);
                    if let Some(d) = dup {
                        // The whole span is redelivered; every line hits
                        // the PSN dedup.
                        self.remote
                            .write_ddio_span(lane, d, self.wire_line_ns, w.meta, &w.tail);
                        self.note_dup_lines(lines as u64);
                    }
                    // Posted span: one ack once the last line is received.
                    self.complete_lane(thread, lane, first + extra + self.half);
                }
            }
            Verb::WriteWT => {
                let lane = self.next_lane(thread);
                let (ready, iss) = self.post_lane(thread, lane, t.now, extra);
                t.wait_until(ready);
                if let TxOutcome::Deliver { first, dup } = self.wire(iss) {
                    self.remote
                        .write_wt_span(lane, first, self.wire_line_ns, w.meta, &w.tail);
                    if let Some(d) = dup {
                        self.remote
                            .write_wt_span(lane, d, self.wire_line_ns, w.meta, &w.tail);
                        self.note_dup_lines(lines as u64);
                    }
                    self.complete_lane(thread, lane, first + extra + self.half);
                }
            }
            Verb::WriteNT => {
                // `post_dd` floors the shared QP's issue stage for the
                // span's extra serialization (see its doc comment).
                let (ready, iss) = self.post_dd(thread, t.now, extra);
                t.wait_until(ready);
                if let TxOutcome::Deliver { first, dup } = self.wire(iss) {
                    let (_proc, last_persist) =
                        self.remote
                            .write_nt_span(0, first, self.wire_line_ns, w.meta, &w.tail);
                    if let Some(d) = dup {
                        self.remote
                            .write_nt_span(0, d, self.wire_line_ns, w.meta, &w.tail);
                        self.note_dup_lines(lines as u64);
                    }
                    // Non-posted span: the single completion carries the
                    // persistence of every line (window slot freed then).
                    self.complete_dd(thread, last_persist + self.half);
                }
            }
            other => unreachable!("submit_wqe: {other:?} is not a data verb"),
        }
        self.posted_writes += lines;
        self.wire_wqes += 1;
        self.span_hist.record(lines);
    }

    /// Post a doorbell-coalesced chain of staged WQEs in stage (FIFO)
    /// order. No CPU cost is charged here — the caller rings one
    /// doorbell for the whole chain (see [`crate::net::Fabric`]); each
    /// WQE still pays its full gap/window/back-pressure submission cost
    /// (spans pay it once per WQE plus `wire_line_ns` per extra line).
    pub fn post_batch(&mut self, t: &mut ThreadClock, wqes: &[Wqe]) {
        for w in wqes {
            self.submit_wqe(t, w);
        }
    }

    /// Posted one-sided RDMA write via DDIO (SM-RC's data path).
    pub fn post_write(&mut self, t: &mut ThreadClock, meta: WriteMeta) {
        t.busy(self.post_cost);
        self.submit_data(t, Verb::Write, meta);
    }

    /// Posted write-through write (SM-OB's data path).
    pub fn post_write_wt(&mut self, t: &mut ThreadClock, meta: WriteMeta) {
        t.busy(self.post_cost);
        self.submit_data(t, Verb::WriteWT, meta);
    }

    /// Non-temporal write on the shared QP (SM-DD's data path; the single
    /// QP preserves program order end-to-end). Non-posted: the ack
    /// carries persistence, so the window couples thread progress to
    /// remote MC back-pressure.
    pub fn post_write_nt(&mut self, t: &mut ThreadClock, meta: WriteMeta) {
        t.busy(self.post_cost);
        self.submit_data(t, Verb::WriteNT, meta);
    }

    /// Issue a remote commit without blocking the thread; returns the
    /// completion instant. Used by [`crate::net::Fabric`] so the caller
    /// can combine completions across a replica group before blocking
    /// once per its ack policy.
    pub fn rcommit_issue(&mut self, t: &mut ThreadClock) -> Ns {
        t.busy(self.post_cost);
        let thread = t.id as u32;
        let lane = self.next_lane(thread);
        let (ready, iss) = self.post_lane(thread, lane, t.now, 0);
        t.wait_until(ready);
        let arrive = iss + self.half;
        let done_remote = self.remote.rcommit(lane, arrive, thread);
        let completion = done_remote + self.half;
        self.complete_lane(thread, lane, completion);
        completion
    }

    /// Blocking remote commit (SM-RC's overloaded fence).
    pub fn rcommit(&mut self, t: &mut ThreadClock) {
        let completion = self.rcommit_issue(t);
        self.block(t, completion);
    }

    /// Posted remote ordering fence (SM-OB's epoch boundary).
    pub fn rofence(&mut self, t: &mut ThreadClock) {
        t.busy(self.post_cost);
        let thread = t.id as u32;
        let lane = self.next_lane(thread);
        let (ready, iss) = self.post_lane(thread, lane, t.now, 0);
        t.wait_until(ready);
        let arrive = iss + self.half;
        self.remote.rofence(arrive, thread);
        self.complete_lane(thread, lane, arrive + self.half);
        self.posted_fences += 1;
    }

    /// Issue a remote durability fence without blocking; returns the
    /// completion instant (see [`Rdma::rcommit_issue`]).
    pub fn rdfence_issue(&mut self, t: &mut ThreadClock) -> Ns {
        t.busy(self.post_cost);
        let thread = t.id as u32;
        let lane = self.next_lane(thread);
        let (ready, iss) = self.post_lane(thread, lane, t.now, 0);
        t.wait_until(ready);
        let arrive = iss + self.half;
        let done_remote = self.remote.rdfence(lane, arrive, thread);
        let completion = done_remote + self.half;
        self.complete_lane(thread, lane, completion);
        completion
    }

    /// Blocking remote durability fence (SM-OB's transaction end).
    pub fn rdfence(&mut self, t: &mut ThreadClock) {
        let completion = self.rdfence_issue(t);
        self.block(t, completion);
    }

    /// Issue a sentinel read on the shared QP without blocking; returns
    /// the completion instant (see [`Rdma::rcommit_issue`]).
    pub fn read_fence_issue(&mut self, t: &mut ThreadClock) -> Ns {
        t.busy(self.post_cost);
        let thread = t.id as u32;
        let (ready, iss) = self.post_dd(thread, t.now, 0);
        t.wait_until(ready);
        let arrive = iss + self.half;
        let done_remote = self.remote.read(0, arrive, thread);
        let completion = done_remote + self.half;
        self.complete_dd(thread, completion);
        completion
    }

    /// Blocking sentinel read on the shared QP (SM-DD's durability point).
    pub fn read_fence(&mut self, t: &mut ThreadClock) {
        let completion = self.read_fence_issue(t);
        self.block(t, completion);
    }

    // ---- group-fence piggyback issue paths ------------------------------
    //
    // A piggybacked fence rides another thread's in-flight fence WQE: no
    // CPU post cost, no QP lane slot, no NIC message slot — the caller's
    // request is carried in the already-issued verb. The responder-side
    // semantics still run via the remote `*_join` verbs (the caller's
    // lines drain / its persists are waited on), anchored at the same
    // one-way fabric latency, so the returned completion is a true
    // durability instant — never weaker than an issued fence's.

    /// Piggybacked remote commit: responder drain without an issue slot.
    pub fn rcommit_piggyback(&mut self, t: &mut ThreadClock) -> Ns {
        let arrive = t.now + self.half;
        self.remote.rcommit_join(arrive, t.id as u32) + self.half
    }

    /// Piggybacked remote durability fence.
    pub fn rdfence_piggyback(&mut self, t: &mut ThreadClock) -> Ns {
        let arrive = t.now + self.half;
        self.remote.rdfence_join(arrive, t.id as u32) + self.half
    }

    /// Piggybacked sentinel-read fence.
    pub fn read_fence_piggyback(&mut self, t: &mut ThreadClock) -> Ns {
        let arrive = t.now + self.half;
        self.remote.read_join(arrive, t.id as u32) + self.half
    }

    /// Aggregate window-stall across QPs (back-pressure exposure metric).
    pub fn window_stall_ns(&self) -> Ns {
        self.dd_window_stall_ns
            + self
                .lanes
                .values()
                .map(|q| q.window_stall_ns)
                .sum::<Ns>()
    }

    /// Persistence discipline of this stack's remote engine. Note the
    /// domain changes requester-visible timing too: under eADR an NT
    /// completion arrives at `persist + rtt/2` with `persist = proc`,
    /// while RpmemFlush defers durability to the fence path entirely —
    /// see [`super::remote::PersistDomain`].
    pub fn persist_domain(&self) -> super::remote::PersistDomain {
        self.remote.persist_domain()
    }

    pub fn nqp(&self) -> usize {
        self.nqp
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(addr: u64, seq: u64) -> WriteMeta {
        WriteMeta {
            addr,
            val: seq,
            thread: 0,
            txn: 0,
            epoch: 0,
            seq,
        }
    }

    fn rdma() -> Rdma {
        Rdma::new(&Platform::default(), true)
    }

    #[test]
    fn posted_write_does_not_block() {
        let mut r = rdma();
        let mut t = ThreadClock::new(0);
        r.post_write(&mut t, meta(0x40, 0));
        // Thread only paid the post cost (30ns), not the RTT.
        assert_eq!(t.now, 30);
    }

    #[test]
    fn rcommit_blocks_for_at_least_rtt() {
        let mut r = rdma();
        let mut t = ThreadClock::new(0);
        r.post_write(&mut t, meta(0x40, 0));
        r.rcommit(&mut t);
        assert!(t.now >= 2600, "rcommit must cost >= rtt, t={}", t.now);
        assert_eq!(r.remote.ledger.len(), 1);
    }

    #[test]
    fn ob_sequence_persists_in_epoch_order() {
        let mut r = rdma();
        let mut t = ThreadClock::new(0);
        r.post_write_wt(&mut t, meta(0x40, 0));
        r.rofence(&mut t);
        r.post_write_wt(
            &mut t,
            WriteMeta {
                epoch: 1,
                ..meta(0x80, 1)
            },
        );
        r.rdfence(&mut t);
        let evs = r.remote.ledger.events();
        assert_eq!(evs.len(), 2);
        let e0 = evs.iter().find(|e| e.epoch == 0).unwrap();
        let e1 = evs.iter().find(|e| e.epoch == 1).unwrap();
        assert!(e0.at <= e1.at, "epoch order violated: {} > {}", e0.at, e1.at);
        assert!(t.now >= 2600, "rdfence must block for the RTT");
    }

    #[test]
    fn dd_sequence_all_persisted_after_read() {
        let mut r = rdma();
        let mut t = ThreadClock::new(0);
        for i in 0..10 {
            r.post_write_nt(&mut t, meta(0x40 * (i + 1), i));
        }
        r.read_fence(&mut t);
        assert_eq!(r.remote.ledger.len(), 10);
        let horizon = r.remote.persist_horizon();
        assert!(t.now >= horizon, "read fence returned before persistence");
        // Program order == persist order on the single QP.
        let evs = r.remote.ledger.events();
        for w in evs.windows(2) {
            assert!(w[0].at <= w[1].at, "NT persist order violated");
        }
    }

    #[test]
    fn rtt_dominates_blocking_fence_latency() {
        let mut r = rdma();
        let mut t = ThreadClock::new(0);
        r.rdfence(&mut t);
        // Empty pipeline: fence ~ rtt + post + poll.
        assert!((2600..3200).contains(&t.now), "t={}", t.now);
    }

    #[test]
    fn multi_qp_round_robin_spreads_writes() {
        let mut r = rdma();
        let mut t = ThreadClock::new(0);
        for i in 0..8 {
            r.post_write(&mut t, meta(0x40 * (i + 1), i));
        }
        // 8 writes over 4 QPs: 2 per QP. Thread time = 8 posts.
        assert_eq!(t.now, 8 * 30);
        assert_eq!(r.posted_writes, 8);
    }

    #[test]
    fn post_batch_submits_like_eager_minus_cpu_cost() {
        // A doorbell-coalesced chain must drive the QP/wire/remote model
        // exactly like the eager posts, differing only in the CPU cost
        // the caller charges (stage/doorbell instead of per-post).
        let mut eager = rdma();
        let mut te = ThreadClock::new(0);
        for i in 0..6u64 {
            eager.post_write_wt(&mut te, meta(0x40 * (i + 1), i));
        }
        let mut batched = rdma();
        let mut tb = ThreadClock::new(0);
        // Same start instant as the eager run's first wire submission.
        tb.busy(30);
        let wqes: Vec<Wqe> = (0..6u64)
            .map(|i| Wqe::single(Verb::WriteWT, meta(0x40 * (i + 1), i), 0))
            .collect();
        batched.post_batch(&mut tb, &wqes);
        assert_eq!(batched.posted_writes, 6);
        assert_eq!(batched.remote.ledger.len(), eager.remote.ledger.len());
        // Same per-thread order of (addr, seq) on the remote side.
        let proj = |r: &Rdma| -> Vec<(u64, u64)> {
            r.remote.ledger.events().iter().map(|e| (e.addr, e.seq)).collect()
        };
        assert_eq!(proj(&batched), proj(&eager));
        // The batched thread paid no per-WQE post cost.
        assert!(tb.now < te.now, "batched {} vs eager {}", tb.now, te.now);
    }

    #[test]
    fn span_submits_per_line_persists_under_one_wqe() {
        // A 4-line WT span: one wire WQE, one QP slot, per-line ledger
        // entries arriving wire_line_ns apart — vs 4 single-line WQEs.
        let p = Platform {
            wire_line_ns: 20,
            ..Platform::default()
        };
        let span = {
            let mut r = Rdma::new(&p, true);
            let mut t = ThreadClock::new(0);
            let mut w = Wqe::single(Verb::WriteWT, meta(0x40, 0), 0);
            for i in 1..4u64 {
                w.tail.push(meta(0x40 * (1 + i), i));
            }
            r.submit_wqe(&mut t, &w);
            assert_eq!(r.wire_wqes, 1);
            assert_eq!(r.posted_writes, 4);
            assert_eq!(r.span_hist.max(), 4);
            assert_eq!(r.remote.ledger.len(), 4);
            // Arrival spacing on the remote: wire_line_ns apart, in
            // span order.
            let evs = r.remote.ledger.events().to_vec();
            for w in evs.windows(2) {
                assert!(w[1].at >= w[0].at, "span persists out of order");
            }
            r
        };
        let singles = {
            let mut r = Rdma::new(&p, true);
            let mut t = ThreadClock::new(0);
            for i in 0..4u64 {
                r.submit_data(&mut t, Verb::WriteWT, meta(0x40 * (1 + i), i));
            }
            assert_eq!(r.wire_wqes, 4);
            r
        };
        // Same lines persisted either way; the span's wire footprint is
        // smaller (1 WQE, and 150 + 3*20 ns of issue occupancy instead
        // of 4 * 150 ns).
        let proj = |r: &Rdma| -> Vec<u64> {
            r.remote.ledger.events().iter().map(|e| e.addr).collect()
        };
        assert_eq!(proj(&span), proj(&singles));
        assert!(span.wire_wqes < singles.wire_wqes);
        assert_eq!(span.posted_writes, singles.posted_writes);
    }

    #[test]
    fn nt_span_completes_at_last_persist() {
        let p = Platform::default();
        let mut r = Rdma::new(&p, true);
        let mut t = ThreadClock::new(0);
        let mut w = Wqe::single(Verb::WriteNT, meta(0x40, 0), 0);
        w.tail.push(meta(0x80, 1));
        w.tail.push(meta(0xc0, 2));
        r.submit_wqe(&mut t, &w);
        assert_eq!(r.remote.ledger.len(), 3);
        // Every line persisted; the single completion (registered in the
        // shared-QP window) covers the last persist.
        let horizon = r.remote.persist_horizon();
        let evs = r.remote.ledger.events();
        assert!(evs.iter().all(|e| e.at <= horizon));
        assert_eq!(r.wire_wqes, 1);
        assert_eq!(r.posted_writes, 3);
    }

    #[test]
    fn piggyback_fences_skip_issue_cost_but_keep_durability() {
        // rcommit_piggyback drains the caller's lines (real durability)
        // without CPU post cost, QP slot, or NIC slot.
        let mut r = rdma();
        let mut t = ThreadClock::new(0);
        r.post_write(&mut t, meta(0x40, 0));
        let busy_before = t.busy_ns;
        let now_before = t.now;
        let completion = r.rcommit_piggyback(&mut t);
        assert_eq!(t.busy_ns, busy_before, "piggyback must not charge CPU");
        assert_eq!(t.now, now_before, "piggyback must not advance the clock");
        assert_eq!(r.remote.ledger.len(), 1, "caller's line still drains");
        assert!(completion > t.now, "completion covers a full RTT");
        // rdfence_piggyback covers the caller's write-through persists.
        let mut r = rdma();
        let mut t = ThreadClock::new(0);
        r.post_write_wt(&mut t, meta(0x40, 0));
        let c = r.rdfence_piggyback(&mut t);
        assert!(c >= r.remote.persist_horizon(), "durability not weakened");
        // read_fence_piggyback likewise for the NT path.
        let mut r = rdma();
        let mut t = ThreadClock::new(0);
        r.post_write_nt(&mut t, meta(0x40, 0));
        let c = r.read_fence_piggyback(&mut t);
        assert!(c >= r.remote.persist_horizon());
    }

    #[test]
    fn nt_backpressure_reaches_thread() {
        // Shrink the QP depth so the window fills quickly.
        let mut p = Platform::default();
        p.qp_depth = 2;
        let mut r = Rdma::new(&p, false);
        let mut t = ThreadClock::new(0);
        for i in 0..50 {
            r.post_write_nt(&mut t, meta(0x40 * (i + 1), i));
        }
        // With depth 2 and ~210ns serialized remote processing + rtt-coupled
        // acks, the thread must have stalled on the window repeatedly.
        assert!(r.window_stall_ns() > 0, "expected NT window stalls");
        assert!(t.now > 50 * 30, "thread time must exceed pure post cost");
    }
}
