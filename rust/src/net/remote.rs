//! Remote (backup-side) NIC engine + memory subsystem.
//!
//! Implements the responder half of every verb with the paper's §6.2
//! latency decomposition: per-QP arrival ordering, a shared PCIe
//! root-complex port, the DDIO path into the LLC model, the direct
//! (DDIO-disabled) path into the MC write queue, the ordered FIFO +
//! cross-QP barrier behaviour of `rofence`, and the drain semantics of
//! `rcommit` / `rdfence`. Every line that reaches the MC write queue is
//! recorded in the durability ledger with its transactional coordinates.

use super::verbs::WriteMeta;
use crate::mem::{llc::DdioWrite, DurEvent, DurabilityLog, Llc, MemCtrl};
use crate::sim::RateLimiter;
use crate::{config::Platform, line_of, Addr, Ns};
use std::collections::{HashMap, HashSet};

/// Remote persistence domain: what hardware boundary a mirror write must
/// cross before it is durable on the backup. The paper's §6.2 model is
/// ADR (persistence at MC write-queue admission); *Correct, Fast Remote
/// Persistence* (arXiv:1909.02092) and *Write-Optimized and Consistent
/// RDMA-based NVM Systems* (arXiv:1906.08173) catalogue the rest. The
/// domain owns the persist-instant computation for every write verb and
/// the drain/wait semantics of every fence verb — see the per-variant
/// notes and DESIGN.md §Remote persistence.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PersistDomain {
    /// ADR: the MC write queue is inside the persistence domain, the LLC
    /// is not. DDIO writes land volatile and drain on rcommit;
    /// write-throughs persist at queue admission. Bit-exact anchor for
    /// the pre-domain remote path.
    Adr,
    /// eADR: the LLC is inside the persistence domain. Remote processing
    /// completion implies persistence; rcommit drains collapse to a
    /// no-op and rdfence loses its PM-landing tail.
    Eadr,
    /// RPMEM-style explicit flush: nothing — not even the MC queue — is
    /// persistent until an explicit flush verb, which the fence path
    /// emits at the WQE flush choke point. Writes buffer volatile;
    /// rcommit/rdfence/read-fence all carry flush semantics.
    RpmemFlush,
    /// Log-structured remote PM: every mirror write becomes a sequential
    /// append at `wire_line_ns`-friendly addresses (no MC bank
    /// conflicts, no queue wait); superseded versions are rewritten by a
    /// background compactor that steals MC drain bandwidth off the
    /// critical path.
    LogStructured,
}

impl Default for PersistDomain {
    fn default() -> Self {
        PersistDomain::Adr
    }
}

impl PersistDomain {
    pub const ALL: [PersistDomain; 4] = [
        PersistDomain::Adr,
        PersistDomain::Eadr,
        PersistDomain::RpmemFlush,
        PersistDomain::LogStructured,
    ];

    pub fn name(self) -> &'static str {
        match self {
            PersistDomain::Adr => "adr",
            PersistDomain::Eadr => "eadr",
            PersistDomain::RpmemFlush => "rpmem-flush",
            PersistDomain::LogStructured => "log-structured",
        }
    }
}

impl std::str::FromStr for PersistDomain {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.trim().to_ascii_lowercase().as_str() {
            "adr" => Ok(PersistDomain::Adr),
            "eadr" => Ok(PersistDomain::Eadr),
            "rpmem-flush" | "rpmem_flush" | "rpmem" | "flush" => Ok(PersistDomain::RpmemFlush),
            "log-structured" | "log_structured" | "logstructured" | "log" => {
                Ok(PersistDomain::LogStructured)
            }
            other => Err(format!(
                "unknown persist domain {other:?} (expected adr, eadr, rpmem-flush or log-structured)"
            )),
        }
    }
}

impl std::fmt::Display for PersistDomain {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Remote engine: one backup node.
#[derive(Clone, Debug)]
pub struct RemoteEngine {
    /// Per-(QP, thread) last ordered instant — RDMA guarantees ordering
    /// only within a QP's stream; per-thread scoping avoids false
    /// cross-thread serialization from out-of-order submission (see
    /// sim::rate).
    order: HashMap<(usize, u32), Ns>,
    /// Shared PCIe root-complex port (posted-write burst rate) —
    /// time-indexed so cross-thread contention is conserved but
    /// submission order is irrelevant.
    shared_pcie: RateLimiter,
    /// Serialized non-temporal processing stage (ordered non-posted
    /// writes; SM-DD routes everything through QP 0 + this stage).
    nt_proc: RateLimiter,
    pcie_occ: Ns,
    /// One-way latency of a non-posted NT PCIe write (occupancy is
    /// `nt_serial`; latency is shorter — the serialization limits *rate*).
    nt_latency: Ns,
    ob_barrier: Ns,
    /// Last-line PM landing charged by rdfence (rcommit-like drain tail).
    mc_pm: Ns,
    /// Backup LLC + memory controller.
    pub llc: Llc,
    pub mc: MemCtrl,
    /// Persistence discipline of this backup's PM (see [`PersistDomain`]).
    domain: PersistDomain,
    /// Lines replicated but not yet persistent, with the remote
    /// processing instant each became volatile at — under ADR these are
    /// dirty DDIO lines drained by `rcommit`; under RpmemFlush *every*
    /// write buffers here until a flush verb (insertion-ordered).
    pending: Vec<(Addr, WriteMeta, Ns)>,
    pending_idx: crate::util::FastMap<Addr, usize>,
    /// Log-structured append state: line addresses already live in the
    /// log — a re-append supersedes and queues a compaction rewrite.
    log_seen: HashSet<Addr>,
    /// Latency of one sequential log append (ingress + one bank slot; a
    /// `wire_line_ns`-friendly append never waits on the write queue).
    log_append_ns: Ns,
    /// SM-OB per-thread ordering floor: none of the thread's later-epoch
    /// WTs may persist before its floor.
    persist_floor: HashMap<u32, Ns>,
    /// Running max persist instant (any path).
    max_persist: Ns,
    /// Per-QP latest persist instant (read-fence semantics).
    per_qp_persist: Vec<Ns>,
    /// Per-thread latest remote processing / persist instants (rcommit and
    /// rdfence are scoped to the caller's own writes — the rcommit draft
    /// takes an address *range*, i.e. the caller's region).
    per_thread_proc: HashMap<u32, Ns>,
    per_thread_persist: HashMap<u32, Ns>,
    /// Durability ledger of the backup PM.
    pub ledger: DurabilityLog,
    /// PSN-style duplicate suppression, active only when a lossy link
    /// is configured (see [`crate::net::link`]): the `(thread, seq)` of
    /// every line this engine has applied. A redelivered line —
    /// fabric duplication or a spurious retransmit — is detected here,
    /// at the ledger boundary, and dropped without any side effect:
    /// the at-least-once transport becomes exactly-once storage.
    dedup: Option<HashSet<(u32, u64)>>,
    // stats
    pub writes: u64,
    pub persists: u64,
    pub barriers: u64,
    /// Explicit flush verbs that drained at least one volatile line
    /// (RpmemFlush only — an empty drain emits no verb on the wire).
    pub flush_verbs: u64,
    /// Superseded log versions queued for background compaction
    /// (LogStructured only).
    pub compaction_lines: u64,
    /// Total ns lines spent replicated-but-volatile before persisting
    /// (Σ persist_at − proc_at over drained/flushed lines).
    pub volatile_window_ns: u64,
    /// Duplicate line deliveries dropped by the PSN dedup (0 unless a
    /// lossy link is configured; `dup_drops <= retransmits +
    /// dups_injected` on the requester side).
    pub dup_drops: u64,
}

impl RemoteEngine {
    pub fn new(p: &Platform, ledger: bool) -> Self {
        RemoteEngine {
            order: HashMap::new(),
            shared_pcie: RateLimiter::new(p.pcie_occ),
            nt_proc: RateLimiter::new(p.nt_serial),
            pcie_occ: p.pcie_occ,
            nt_latency: p.pcie_rt / 2 + p.llc_mc,
            ob_barrier: p.ob_barrier,
            mc_pm: p.mc_pm,
            llc: Llc::from_platform(p),
            mc: MemCtrl::from_platform(p),
            domain: p.persist_domain,
            pending: Vec::new(),
            pending_idx: crate::util::FastMap::default(),
            log_seen: HashSet::new(),
            log_append_ns: p.llc_mc + (p.mc_pm / p.mc_banks as Ns).max(1),
            persist_floor: HashMap::new(),
            max_persist: 0,
            per_qp_persist: vec![0; p.nqp],
            per_thread_proc: HashMap::new(),
            per_thread_persist: HashMap::new(),
            ledger: DurabilityLog::new(ledger),
            dedup: None,
            writes: 0,
            persists: 0,
            barriers: 0,
            flush_verbs: 0,
            compaction_lines: 0,
            volatile_window_ns: 0,
            dup_drops: 0,
        }
    }

    /// Turn on PSN-style duplicate suppression (lossy-link runs only —
    /// the lossless anchor never pays for the seen-set).
    pub fn enable_dedup(&mut self) {
        if self.dedup.is_none() {
            self.dedup = Some(HashSet::new());
        }
    }

    /// Whether `meta`'s line is a duplicate delivery. First sight
    /// registers the line and admits it; a repeat is counted and the
    /// verb returns without any side effect. `false` always when dedup
    /// is off (the anchor: no set maintenance, no behavior change).
    fn dedup_drop(&mut self, meta: &WriteMeta) -> bool {
        let Some(seen) = self.dedup.as_mut() else {
            return false;
        };
        if seen.insert((meta.thread, meta.seq)) {
            false
        } else {
            self.dup_drops += 1;
            true
        }
    }

    /// This backup's persistence discipline.
    pub fn persist_domain(&self) -> PersistDomain {
        self.domain
    }

    fn record_persist(&mut self, meta: &WriteMeta, at: Ns) {
        self.persists += 1;
        self.max_persist = self.max_persist.max(at);
        self.ledger.record(DurEvent {
            addr: meta.addr,
            val: meta.val,
            at,
            thread: meta.thread,
            txn: meta.txn,
            epoch: meta.epoch,
            seq: meta.seq,
        });
    }

    /// Remote processing instant for a verb from `thread` arriving on
    /// `qp` at `arrive`: per-(qp, thread) stream ordering, then the shared
    /// PCIe port's capacity.
    fn process(&mut self, qp: usize, thread: u32, arrive: Ns) -> Ns {
        let slot = self.order.entry((qp, thread)).or_insert(0);
        let ordered = arrive.max(*slot);
        let start = self.shared_pcie.submit(ordered);
        let proc_done = start + self.pcie_occ;
        *slot = start;
        proc_done
    }

    /// Posted one-sided write via DDIO (paper Fig. 3a left). Returns the
    /// remote processing instant. Under ADR the line lands dirty in the
    /// LLC and a dirty DDIO-way eviction pushes the *evicted* line into
    /// the MC queue; the other domains reroute the persist instant (see
    /// [`PersistDomain`]).
    pub fn write_ddio(&mut self, qp: usize, arrive: Ns, meta: WriteMeta) -> Ns {
        if self.dedup_drop(&meta) {
            return arrive;
        }
        self.writes += 1;
        let proc = self.process(qp, meta.thread, arrive);
        let line = line_of(meta.addr);
        if self.domain == PersistDomain::Adr {
            // Bit-exact pre-domain path: volatile in the LLC until
            // rcommit; evicting a dirty DDIO way persists the old line.
            match self.llc.ddio_write(line, proc) {
                DdioWrite::EvictDirty(old) => {
                    // The evicted (older) line persists now.
                    let (persist, _) = self.mc.push(proc);
                    if let Some((old_meta, was_volatile_at)) = self.remove_pending(old) {
                        self.record_persist(&old_meta, persist);
                        self.per_qp_persist[qp] = self.per_qp_persist[qp].max(persist);
                        self.volatile_window_ns += persist.saturating_sub(was_volatile_at);
                    }
                }
                DdioWrite::Hit | DdioWrite::Fill | DdioWrite::EvictClean => {}
            }
            let e = self.per_thread_proc.entry(meta.thread).or_insert(0);
            *e = (*e).max(proc);
            self.insert_pending(line, meta, proc);
            return proc;
        }
        match self.domain {
            PersistDomain::Eadr => {
                // The LLC is inside the persistence domain: landing
                // dirty in it *is* the durability instant. Evictions
                // need no persist — the victim was already durable.
                self.llc.ddio_write(line, proc);
                self.record_persist(&meta, proc);
                self.per_qp_persist[qp] = self.per_qp_persist[qp].max(proc);
                let e = self.per_thread_persist.entry(meta.thread).or_insert(0);
                *e = (*e).max(proc);
            }
            PersistDomain::RpmemFlush => {
                // Nothing persists without an explicit flush: the line
                // stays in the volatile buffer even when evicted from
                // the LLC into the (volatile) MC queue.
                self.llc.ddio_write(line, proc);
                self.insert_pending(line, meta, proc);
            }
            PersistDomain::LogStructured => {
                // Mirror write becomes a sequential log append — durable
                // after the append latency, no LLC residency, no queue.
                let persist = self.log_append(line, proc);
                self.record_persist(&meta, persist);
                self.per_qp_persist[qp] = self.per_qp_persist[qp].max(persist);
                let e = self.per_thread_persist.entry(meta.thread).or_insert(0);
                *e = (*e).max(persist);
            }
            PersistDomain::Adr => unreachable!("handled by the guard clause above"),
        }
        let e = self.per_thread_proc.entry(meta.thread).or_insert(0);
        *e = (*e).max(proc);
        proc
    }

    /// Write-through write (paper Fig. 3b): DDIO into the LLC then an
    /// immediate write-through to the MC queue; the LLC copy stays clean.
    /// Returns `(proc, persist)`.
    pub fn write_wt(&mut self, qp: usize, arrive: Ns, meta: WriteMeta) -> (Ns, Ns) {
        if self.dedup_drop(&meta) {
            return (arrive, arrive);
        }
        self.writes += 1;
        let proc = self.process(qp, meta.thread, arrive);
        let line = line_of(meta.addr);
        if self.domain == PersistDomain::Adr {
            // Bit-exact pre-domain path: persist at MC-queue admission.
            match self.llc.ddio_write(line, proc) {
                DdioWrite::EvictDirty(old) => {
                    let (persist, _) = self.mc.push(proc);
                    if let Some((old_meta, was_volatile_at)) = self.remove_pending(old) {
                        self.record_persist(&old_meta, persist);
                        self.volatile_window_ns += persist.saturating_sub(was_volatile_at);
                    }
                }
                _ => {}
            }
            // Write through: push this line now; the ordering floor from
            // the issuing thread's prior rofence epochs applies (the
            // NIC's ordered FIFO delays the WT).
            let floor = self.persist_floor.get(&meta.thread).copied().unwrap_or(0);
            let (raw_persist, _) = self.mc.push(proc.max(floor));
            let persist = raw_persist.max(floor);
            self.llc.writeback(line, persist); // LLC copy now clean
            self.record_persist(&meta, persist);
            self.per_qp_persist[qp] = self.per_qp_persist[qp].max(persist);
            let e = self.per_thread_persist.entry(meta.thread).or_insert(0);
            *e = (*e).max(persist);
            return (proc, persist);
        }
        let floor = self.persist_floor.get(&meta.thread).copied().unwrap_or(0);
        let persist = match self.domain {
            PersistDomain::Eadr => {
                // Acceptance into the (persistent) cache hierarchy is the
                // durability instant — no MC-queue wait, only the
                // ordering floor applies.
                self.llc.ddio_write(line, proc);
                self.llc.writeback(line, proc);
                proc.max(floor)
            }
            PersistDomain::RpmemFlush => {
                // The write-through reaches the (volatile) MC queue but
                // is not durable until an explicit flush verb.
                self.llc.ddio_write(line, proc);
                self.llc.writeback(line, proc);
                self.insert_pending(line, meta, proc);
                let e = self.per_thread_proc.entry(meta.thread).or_insert(0);
                *e = (*e).max(proc);
                return (proc, proc);
            }
            PersistDomain::LogStructured => self.log_append(line, proc.max(floor)),
            PersistDomain::Adr => unreachable!("handled by the guard clause above"),
        };
        self.record_persist(&meta, persist);
        self.per_qp_persist[qp] = self.per_qp_persist[qp].max(persist);
        let e = self.per_thread_persist.entry(meta.thread).or_insert(0);
        *e = (*e).max(persist);
        (proc, persist)
    }

    /// Non-temporal write (paper Fig. 3c): bypasses the LLC; ordered
    /// non-posted PCIe transaction serialized at `nt_serial` per line.
    /// Returns `(proc, persist)` — completion is non-posted (at persist).
    pub fn write_nt(&mut self, qp: usize, arrive: Ns, meta: WriteMeta) -> (Ns, Ns) {
        if self.dedup_drop(&meta) {
            return (arrive, arrive);
        }
        self.writes += 1;
        let slot = self.order.entry((qp, meta.thread)).or_insert(0);
        let ordered = arrive.max(*slot);
        // Ordered non-posted transactions limit the *rate* to one per
        // `nt_serial`; each write's own latency is the shorter PCIe+MC
        // ingress path.
        let start = self.nt_proc.submit(ordered);
        *slot = start;
        let proc = start + self.nt_latency;
        if self.domain == PersistDomain::Adr {
            // Bit-exact pre-domain path: straight into the MC queue.
            let (persist, _) = self.mc.push(proc);
            self.record_persist(&meta, persist);
            self.per_qp_persist[qp] = self.per_qp_persist[qp].max(persist);
            let e = self.per_thread_persist.entry(meta.thread).or_insert(0);
            *e = (*e).max(persist);
            return (proc, persist);
        }
        let line = line_of(meta.addr);
        let persist = match self.domain {
            // Non-posted completion implies persistence the instant the
            // write is processed — the whole path is in the domain.
            PersistDomain::Eadr => proc,
            PersistDomain::RpmemFlush => {
                // The non-posted ack only means "received": the line
                // buffers volatile until the read fence flushes it.
                self.insert_pending(line, meta, proc);
                let e = self.per_thread_proc.entry(meta.thread).or_insert(0);
                *e = (*e).max(proc);
                return (proc, proc);
            }
            PersistDomain::LogStructured => self.log_append(line, proc),
            PersistDomain::Adr => unreachable!("handled by the guard clause above"),
        };
        self.record_persist(&meta, persist);
        self.per_qp_persist[qp] = self.per_qp_persist[qp].max(persist);
        let e = self.per_thread_persist.entry(meta.thread).or_insert(0);
        *e = (*e).max(persist);
        (proc, persist)
    }

    // ---- scatter-gather spans -------------------------------------------
    //
    // A multi-line span WQE (see `crate::net::wqe`) is ONE message on
    // the wire but lands as per-line persists: each line arrives
    // `line_ns` after its predecessor (the span's wire serialization),
    // pays its own PCIe/LLC/MC occupancy, and records its own ledger
    // entry — only the requester-side completion is shared. The span
    // helpers below are thin per-line loops over the single-line verbs,
    // so every ordering/floor/back-pressure rule applies unchanged.

    /// The one span-stagger rule: apply `verb` to the head at `arrive`
    /// and to each tail line `line_ns` after its predecessor, folding
    /// the per-line `(proc, persist)` with a component-wise max.
    fn span_fold(
        &mut self,
        qp: usize,
        arrive: Ns,
        line_ns: Ns,
        head: WriteMeta,
        tail: &[WriteMeta],
        verb: fn(&mut Self, usize, Ns, WriteMeta) -> (Ns, Ns),
    ) -> (Ns, Ns) {
        let (mut proc, mut persist) = verb(self, qp, arrive, head);
        for (i, m) in tail.iter().enumerate() {
            let at = arrive + (i as Ns + 1) * line_ns;
            let (p, d) = verb(self, qp, at, *m);
            proc = proc.max(p);
            persist = persist.max(d);
        }
        (proc, persist)
    }

    /// Apply a DDIO write span; returns the last line's processing
    /// instant (DDIO lands volatile — nothing persists here).
    pub fn write_ddio_span(
        &mut self,
        qp: usize,
        arrive: Ns,
        line_ns: Ns,
        head: WriteMeta,
        tail: &[WriteMeta],
    ) -> Ns {
        let (proc, _) = self.span_fold(qp, arrive, line_ns, head, tail, |e, qp, at, m| {
            (e.write_ddio(qp, at, m), 0)
        });
        proc
    }

    /// Apply a write-through span; returns the last line's
    /// `(proc, persist)` (both clamped monotone over the span).
    pub fn write_wt_span(
        &mut self,
        qp: usize,
        arrive: Ns,
        line_ns: Ns,
        head: WriteMeta,
        tail: &[WriteMeta],
    ) -> (Ns, Ns) {
        self.span_fold(qp, arrive, line_ns, head, tail, Self::write_wt)
    }

    /// Apply a non-temporal span; returns the last line's
    /// `(proc, persist)` — the non-posted completion the shared QP
    /// reports for the whole span.
    pub fn write_nt_span(
        &mut self,
        qp: usize,
        arrive: Ns,
        line_ns: Ns,
        head: WriteMeta,
        tail: &[WriteMeta],
    ) -> (Ns, Ns) {
        self.span_fold(qp, arrive, line_ns, head, tail, Self::write_nt)
    }

    /// Remote ordering fence (paper Fig. 3b): cross-QP barrier in the
    /// remote NIC's ordered FIFO. Writes on *any* QP arriving after the
    /// fence process after the barrier (time-filtered floor on the shared
    /// port — §6.2's "serializes the commands received from multiple
    /// independent threads"); the issuing thread's persistence floor
    /// rises to everything it has persisted so far.
    pub fn rofence(&mut self, arrive: Ns, thread: u32) -> Ns {
        self.barriers += 1;
        let own = self
            .per_thread_persist
            .get(&thread)
            .copied()
            .unwrap_or(0)
            .max(self.per_thread_proc.get(&thread).copied().unwrap_or(0));
        let barrier = arrive.max(own) + self.ob_barrier;
        self.shared_pcie.add_floor(arrive, barrier);
        let f = self.persist_floor.entry(thread).or_insert(0);
        *f = (*f).max(barrier);
        barrier
    }

    /// rcommit's drain semantics: flush the *caller's* pending (dirty)
    /// RDMA-written lines from the LLC into the MC queue starting at
    /// `start`, recording each line's ledger persist. Shared by the
    /// issued verb ([`RemoteEngine::rcommit`]) and the group-fence
    /// piggyback ([`RemoteEngine::rcommit_join`]) — a joined fence still
    /// makes the caller's lines durable; only the requester-side issue
    /// path is elided.
    fn drain_pending(&mut self, start: Ns, thread: u32) -> Ns {
        // The caller's prior writes must have been processed remotely.
        let start = start.max(self.per_thread_proc.get(&thread).copied().unwrap_or(0));
        let mut done = start;
        let all: Vec<(Addr, WriteMeta, Ns)> = std::mem::take(&mut self.pending);
        self.pending_idx.clear();
        for (line, meta, proc_at) in all {
            if meta.thread != thread {
                self.insert_pending(line, meta, proc_at); // keep others' lines
                continue;
            }
            if self.llc.writeback(line, start) {
                let (persist, _) = self.mc.push(start);
                self.record_persist(&meta, persist);
                self.volatile_window_ns += persist.saturating_sub(proc_at);
                done = done.max(persist);
            }
        }
        let e = self.per_thread_persist.entry(thread).or_insert(0);
        *e = (*e).max(done);
        self.max_persist = self.max_persist.max(done);
        done
    }

    /// RpmemFlush's explicit flush verb: persist every volatile line of
    /// the caller, regardless of LLC residency — unlike
    /// [`RemoteEngine::drain_pending`] it must not skip lines whose
    /// cached copy is gone (NT writes never had one, evicted DDIO lines
    /// lost theirs), because under this domain the volatile buffer *is*
    /// the authority on what has not yet persisted. Counted in
    /// `flush_verbs` only when it drains at least one line (an empty
    /// flush is elided from the wire).
    fn flush_volatile(&mut self, start: Ns, thread: u32) -> Ns {
        let start = start.max(self.per_thread_proc.get(&thread).copied().unwrap_or(0));
        let floor = self.persist_floor.get(&thread).copied().unwrap_or(0);
        let mut done = start;
        let mut flushed = 0u64;
        let all: Vec<(Addr, WriteMeta, Ns)> = std::mem::take(&mut self.pending);
        self.pending_idx.clear();
        for (line, meta, proc_at) in all {
            if meta.thread != thread {
                self.insert_pending(line, meta, proc_at); // keep others' lines
                continue;
            }
            self.llc.writeback(line, start); // cache-state bookkeeping only
            let (raw_persist, _) = self.mc.push(start.max(floor));
            let persist = raw_persist.max(floor);
            self.record_persist(&meta, persist);
            self.volatile_window_ns += persist.saturating_sub(proc_at);
            done = done.max(persist);
            flushed += 1;
        }
        if flushed > 0 {
            self.flush_verbs += 1;
        }
        let e = self.per_thread_persist.entry(thread).or_insert(0);
        *e = (*e).max(done);
        done = *e;
        self.max_persist = self.max_persist.max(done);
        done
    }

    /// Sequential log append: one superseded-version check, then the
    /// fixed append latency. A re-appended line queues a background
    /// compaction rewrite that consumes MC drain bandwidth without
    /// delaying this append.
    fn log_append(&mut self, line: Addr, at: Ns) -> Ns {
        if !self.log_seen.insert(line) {
            self.compaction_lines += 1;
            let _ = self.mc.push(at); // compactor steals a drain slot
        }
        at + self.log_append_ns
    }

    /// Domain dispatch for rcommit's responder semantics: under
    /// RpmemFlush the drain *is* the explicit flush verb; elsewhere it
    /// is the ADR LLC drain (which degenerates to a floor wait under
    /// eADR/log-structured, where nothing ever buffers).
    fn drain_or_flush(&mut self, start: Ns, thread: u32) -> Ns {
        match self.domain {
            PersistDomain::RpmemFlush => self.flush_volatile(start, thread),
            _ => self.drain_pending(start, thread),
        }
    }

    /// rdfence's wait semantics: all the caller's write-throughs
    /// persistent, cross-QP sync bubble, last line's PM landing. eADR
    /// drops the PM-landing tail (the queue is already persistent);
    /// RpmemFlush must first flush the caller's volatile lines.
    fn dfence_wait(&mut self, start: Ns, thread: u32) -> Ns {
        match self.domain {
            PersistDomain::Adr | PersistDomain::LogStructured => {
                start.max(self.per_thread_persist.get(&thread).copied().unwrap_or(0))
                    + self.ob_barrier
                    + self.mc_pm
            }
            PersistDomain::Eadr => {
                start.max(self.per_thread_persist.get(&thread).copied().unwrap_or(0))
                    + self.ob_barrier
            }
            PersistDomain::RpmemFlush => {
                let flushed = self.flush_volatile(start, thread);
                flushed + self.ob_barrier + self.mc_pm
            }
        }
    }

    /// Remote commit (SM-RC): drain the *caller's* pending (dirty)
    /// RDMA-written lines from the LLC into the MC queue (the rcommit
    /// draft scopes the commit to an address range — the caller's own
    /// replication region). Returns the drain-complete instant.
    pub fn rcommit(&mut self, qp: usize, arrive: Ns, thread: u32) -> Ns {
        let start = self.process(qp, thread, arrive);
        let done = self.drain_or_flush(start, thread);
        self.per_qp_persist[qp] = self.per_qp_persist[qp].max(done);
        done
    }

    /// Remote durability fence (SM-OB): completes once all prior writes
    /// (already written-through) are persistent and all barriers executed.
    pub fn rdfence(&mut self, qp: usize, arrive: Ns, thread: u32) -> Ns {
        let start = self.process(qp, thread, arrive);
        let done = self.dfence_wait(start, thread);
        self.per_qp_persist[qp] = self.per_qp_persist[qp].max(done);
        done
    }

    /// One-sided read on `qp`: fences the caller's prior writes on that
    /// QP (RDMA read-after-write ordering); with DDIO disabled their
    /// completion implies persistence (SM-DD's durability point).
    pub fn read(&mut self, qp: usize, arrive: Ns, thread: u32) -> Ns {
        let proc = self.process(qp, thread, arrive);
        if self.domain == PersistDomain::RpmemFlush {
            // SM-DD's durability point: the read fence carries the
            // explicit flush, since NT completions only mean "received".
            let done = self.flush_volatile(proc, thread);
            return proc.max(done);
        }
        proc.max(self.per_thread_persist.get(&thread).copied().unwrap_or(0))
    }

    // ---- group-fence piggyback verbs ------------------------------------
    //
    // A thread whose durability fence lands inside another thread's
    // group-fence window does not issue its own verb: no QP stream slot,
    // no shared-PCIe `process()` slot, no per-QP persist update. The
    // responder-side *semantics* still run — the caller's lines drain /
    // its persists are waited on — so durability is never weakened; only
    // the duplicated issue cost is amortized away (paper §6.2 applied to
    // the fence path the way doorbell batching applied to the post path).

    /// Piggybacked rcommit: drain the caller's pending lines as of
    /// `arrive` without consuming an issue slot.
    pub fn rcommit_join(&mut self, arrive: Ns, thread: u32) -> Ns {
        self.drain_or_flush(arrive, thread)
    }

    /// Piggybacked rdfence: wait for the caller's persists as of
    /// `arrive` without consuming an issue slot.
    pub fn rdfence_join(&mut self, arrive: Ns, thread: u32) -> Ns {
        self.dfence_wait(arrive, thread)
    }

    /// Piggybacked read-fence: the caller's persists as of `arrive`
    /// (flush semantics under RpmemFlush, like the issued variant).
    pub fn read_join(&mut self, arrive: Ns, thread: u32) -> Ns {
        if self.domain == PersistDomain::RpmemFlush {
            let done = self.flush_volatile(arrive, thread);
            return arrive.max(done);
        }
        arrive.max(self.per_thread_persist.get(&thread).copied().unwrap_or(0))
    }

    fn insert_pending(&mut self, line: Addr, meta: WriteMeta, proc_at: Ns) {
        match self.pending_idx.get(&line) {
            // Coalesce in place: newest value wins, but the line has
            // been volatile since its first unflushed write.
            Some(&i) => self.pending[i].1 = meta,
            None => {
                self.pending_idx.insert(line, self.pending.len());
                self.pending.push((line, meta, proc_at));
            }
        }
    }

    fn remove_pending(&mut self, line: Addr) -> Option<(WriteMeta, Ns)> {
        let i = self.pending_idx.remove(&line)?;
        let (_, meta, proc_at) = self.pending[i];
        // O(1) removal: swap with the tail and fix the moved index.
        let last = self.pending.len() - 1;
        self.pending.swap(i, last);
        self.pending.pop();
        if i < self.pending.len() {
            let moved = self.pending[i].0;
            self.pending_idx.insert(moved, i);
        }
        Some((meta, proc_at))
    }

    /// Install a failover catch-up stream from a peer: `events` (empty
    /// when ledgers are off) are re-recorded as persisting at
    /// `max(at, ev.at)` — the stream lands at `at`, but a line the source
    /// itself only persists later cannot become durable here earlier than
    /// there — and the write/persist counters advance by `lines` so group
    /// accounting sees the transfer even without a ledger. Transactional
    /// coordinates are preserved, so per-thread (txn, epoch, seq) order
    /// survives the replay; only the durability instant moves.
    pub fn absorb_resync(&mut self, events: &[DurEvent], lines: u64, at: Ns) {
        for ev in events {
            let stamped = at.max(ev.at);
            self.ledger.record(DurEvent { at: stamped, ..*ev });
            self.max_persist = self.max_persist.max(stamped);
            // Resynced lines register with the PSN dedup too: a delayed
            // duplicate arriving after the replay must still be dropped.
            if let Some(seen) = self.dedup.as_mut() {
                seen.insert((ev.thread, ev.seq));
            }
        }
        self.writes += lines;
        self.persists += lines;
        if lines > 0 && events.is_empty() {
            // Ledger-off sizing: no per-event instants to take a max over.
            self.max_persist = self.max_persist.max(at);
        }
    }

    /// Drop replicated-but-not-yet-persistent state (a killed backup's
    /// dirty DDIO lines are volatile — exactly SM-RC's exposure; they do
    /// not survive the crash and must not drain after a rejoin).
    pub fn drop_volatile(&mut self) {
        self.pending.clear();
        self.pending_idx.clear();
    }

    /// Number of replicated-but-not-yet-persistent lines (SM-RC exposure).
    pub fn pending_lines(&self) -> usize {
        self.pending.len()
    }

    /// Latest persist instant seen on any path.
    pub fn persist_horizon(&self) -> Ns {
        self.max_persist
    }

    /// Certified prefix length this engine can campaign with in a leader
    /// election (see [`crate::net::membership`]): the lines its
    /// durability ledger proves persistent, or the raw persist counter
    /// when ledgers are off.
    pub fn certified_lines(&self) -> u64 {
        if self.ledger.enabled() {
            self.ledger.len() as u64
        } else {
            self.persists
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(addr: Addr, seq: u64) -> WriteMeta {
        WriteMeta {
            addr,
            val: seq,
            thread: 0,
            txn: 0,
            epoch: 0,
            seq,
        }
    }

    fn engine() -> RemoteEngine {
        RemoteEngine::new(&Platform::default(), true)
    }

    #[test]
    fn ddio_write_is_not_persistent() {
        let mut e = engine();
        e.write_ddio(0, 1000, meta(0x40, 0));
        assert_eq!(e.ledger.len(), 0, "plain write must not persist");
        assert_eq!(e.pending_lines(), 1);
    }

    #[test]
    fn rcommit_drains_pending() {
        let mut e = engine();
        e.write_ddio(0, 1000, meta(0x40, 0));
        e.write_ddio(1, 1010, meta(0x80, 1));
        let done = e.rcommit(2, 2000, 0);
        assert_eq!(e.pending_lines(), 0);
        assert_eq!(e.ledger.len(), 2);
        assert!(done >= 2000);
        for ev in e.ledger.events() {
            assert!(ev.at <= done);
        }
    }

    #[test]
    fn wt_write_persists_immediately() {
        let mut e = engine();
        let (proc, persist) = e.write_wt(0, 1000, meta(0x40, 0));
        assert!(persist >= proc);
        assert_eq!(e.ledger.len(), 1);
        assert!(!e.llc.is_dirty(0x40), "WT line must be clean in LLC");
        assert!(e.llc.contains(0x40), "WT line stays cached");
    }

    #[test]
    fn nt_write_bypasses_llc() {
        let mut e = engine();
        let (_, persist) = e.write_nt(0, 1000, meta(0x40, 0));
        assert!(persist > 1000);
        assert_eq!(e.ledger.len(), 1);
        assert!(!e.llc.contains(0x40), "NT write must bypass the LLC");
    }

    #[test]
    fn nt_writes_serialize() {
        let mut e = engine();
        let (_, p1) = e.write_nt(0, 0, meta(0x40, 0));
        let (_, p2) = e.write_nt(0, 0, meta(0x80, 1));
        assert!(p2 >= p1 + 210 - 10, "NT writes must serialize: {p1} {p2}");
    }

    #[test]
    fn spans_apply_per_line_with_staggered_arrivals() {
        // A 3-line WT span at line_ns = 20: three ledger entries, each
        // arriving (and thus persisting) no earlier than its
        // predecessor, all carrying their own metas.
        let mut e = engine();
        let tail = [meta(0x80, 1), meta(0xc0, 2)];
        let (_, last) = e.write_wt_span(0, 1_000, 20, meta(0x40, 0), &tail);
        assert_eq!(e.ledger.len(), 3);
        let evs = e.ledger.events();
        assert_eq!(evs.iter().map(|ev| ev.addr).collect::<Vec<_>>(), vec![0x40, 0x80, 0xc0]);
        for w in evs.windows(2) {
            assert!(w[0].at <= w[1].at, "span persists out of order");
        }
        assert!(evs.iter().all(|ev| ev.at <= last));
        // DDIO span: per-line pending entries, nothing durable yet.
        let mut e = engine();
        e.write_ddio_span(0, 1_000, 20, meta(0x40, 0), &tail);
        assert_eq!(e.pending_lines(), 3);
        assert_eq!(e.ledger.len(), 0);
        // NT span: per-line persists, completion covers them all.
        let mut e = engine();
        let (_, persist) = e.write_nt_span(0, 1_000, 20, meta(0x40, 0), &tail);
        assert_eq!(e.ledger.len(), 3);
        assert_eq!(e.persist_horizon(), persist);
    }

    #[test]
    fn rofence_barriers_all_qps() {
        let mut e = engine();
        e.write_wt(0, 1000, meta(0x40, 0));
        e.write_wt(1, 1000, meta(0x80, 1));
        let barrier = e.rofence(1100, 0);
        // A write on any QP arriving after the fence processes after the
        // barrier (time-filtered floor on the shared port).
        let (proc, _) = e.write_wt(2, 1200, meta(0xc0, 2));
        assert!(proc >= barrier, "proc {proc} < barrier {barrier}");
        // A write that (in virtual time) preceded the fence is unaffected
        // even when submitted later — no false cross-thread serialization.
        let m2 = WriteMeta { thread: 9, ..meta(0x100, 3) };
        let (proc_early, _) = e.write_wt(3, 500, m2);
        assert!(proc_early < barrier);
    }

    #[test]
    fn rofence_orders_epochs_persist() {
        let mut e = engine();
        let (_, p1) = e.write_wt(0, 1000, meta(0x40, 0));
        e.rofence(1100, 0);
        let (_, p2) = e.write_wt(1, 0, meta(0x80, 1)); // early arrival
        assert!(p2 >= p1, "epoch 2 persisted before epoch 1: {p2} < {p1}");
    }

    #[test]
    fn read_fences_prior_qp_writes() {
        let mut e = engine();
        let (_, p1) = e.write_nt(0, 1000, meta(0x40, 0));
        let done = e.read(0, 1001, 0);
        assert!(done >= p1);
    }

    #[test]
    fn rdfence_waits_for_all_persists() {
        let mut e = engine();
        let (_, p1) = e.write_wt(0, 1000, meta(0x40, 0));
        let (_, p2) = e.write_wt(1, 1000, meta(0x80, 1));
        let done = e.rdfence(2, 900, 0);
        assert!(done >= p1.max(p2));
    }

    #[test]
    fn eviction_from_ddio_ways_persists_old_line() {
        // Tiny LLC to force evictions through pending bookkeeping.
        let mut p = Platform::default();
        p.llc_slices = 1;
        p.llc_sets_per_slice = 2;
        p.llc_ways = 2;
        p.ddio_ways = 1;
        p.slice_masks = vec![0];
        let mut e = RemoteEngine::new(&p, true);
        let stride = 2 * 64; // same set
        e.write_ddio(0, 100, meta(0, 0));
        assert_eq!(e.ledger.len(), 0);
        e.write_ddio(0, 200, meta(stride, 1)); // evicts line 0
        assert_eq!(e.ledger.len(), 1);
        assert_eq!(e.ledger.events()[0].addr, 0);
        assert_eq!(e.pending_lines(), 1);
    }

    #[test]
    fn absorb_resync_replays_at_the_given_instant() {
        let mut e = engine();
        e.write_wt(0, 1000, meta(0x40, 0));
        let before = e.persists;
        let missed = [
            DurEvent {
                addr: 0x80,
                val: 7,
                at: 1234, // source-side instant: must be rewritten
                thread: 0,
                txn: 1,
                epoch: 2,
                seq: 1,
            },
            DurEvent {
                addr: 0xc0,
                val: 8,
                at: 1300,
                thread: 0,
                txn: 1,
                epoch: 2,
                seq: 2,
            },
        ];
        e.absorb_resync(&missed, 2, 50_000);
        assert_eq!(e.persists, before + 2);
        assert_eq!(e.ledger.len(), 3);
        assert!(e
            .ledger
            .events()
            .iter()
            .filter(|ev| ev.seq >= 1)
            .all(|ev| ev.at == 50_000));
        assert_eq!(e.persist_horizon(), 50_000);
        // An event the source only persists AFTER the stream completes
        // keeps its later instant — no backdated durability.
        let future = [DurEvent {
            addr: 0x100,
            val: 9,
            at: 55_000,
            thread: 0,
            txn: 2,
            epoch: 3,
            seq: 3,
        }];
        e.absorb_resync(&future, 1, 50_000);
        let late = e.ledger.events().iter().find(|ev| ev.seq == 3).unwrap();
        assert_eq!(late.at, 55_000);
        assert_eq!(e.persist_horizon(), 55_000);
        // Blind (ledger-off style) absorption still moves the counters.
        e.absorb_resync(&[], 3, 60_000);
        assert_eq!(e.persists, before + 6);
        assert_eq!(e.persist_horizon(), 60_000);
    }

    #[test]
    fn drop_volatile_clears_pending_without_persisting() {
        let mut e = engine();
        e.write_ddio(0, 100, meta(0x40, 0));
        e.write_ddio(1, 110, meta(0x80, 1));
        assert_eq!(e.pending_lines(), 2);
        e.drop_volatile();
        assert_eq!(e.pending_lines(), 0);
        assert_eq!(e.ledger.len(), 0, "volatile loss must not persist");
        // A later rcommit has nothing stale to drain.
        e.rcommit(0, 1_000, 0);
        assert_eq!(e.ledger.len(), 0);
    }

    #[test]
    fn join_verbs_run_responder_semantics_without_issue_slots() {
        // rcommit_join drains the caller's pending lines (durability is
        // real), but consumes no QP-stream or shared-PCIe slot: a
        // subsequent write's processing instant is unaffected.
        let mut e = engine();
        e.write_ddio(0, 1000, meta(0x40, 0));
        let mut probe = engine();
        probe.write_ddio(0, 1000, meta(0x40, 0));
        let done = e.rcommit_join(2000, 0);
        assert_eq!(e.pending_lines(), 0);
        assert_eq!(e.ledger.len(), 1);
        assert!(done >= 2000);
        // Same follow-up write in both engines: identical proc instant
        // (the join took no process() slot); the issued variant would
        // have shifted it.
        let p_join = e.write_ddio(0, 3000, meta(0x80, 1));
        let p_base = probe.write_ddio(0, 3000, meta(0x80, 1));
        assert_eq!(p_join, p_base, "join must not consume an issue slot");
        // rdfence_join covers the caller's persists.
        let mut e = engine();
        let (_, p1) = e.write_wt(0, 1000, meta(0x40, 0));
        assert!(e.rdfence_join(900, 0) >= p1);
        // read_join fences prior persists too.
        let mut e = engine();
        let (_, p1) = e.write_nt(0, 1000, meta(0x40, 0));
        assert!(e.read_join(1001, 0) >= p1);
    }

    #[test]
    fn pending_coalesces_same_line() {
        let mut e = engine();
        e.write_ddio(0, 100, meta(0x40, 0));
        e.write_ddio(0, 200, meta(0x40, 1));
        assert_eq!(e.pending_lines(), 1);
        e.rcommit(0, 300, 0);
        // Only the newest value persists.
        assert_eq!(e.ledger.len(), 1);
        assert_eq!(e.ledger.events()[0].val, 1);
    }

    fn engine_with(d: PersistDomain) -> RemoteEngine {
        let mut p = Platform::default();
        p.persist_domain = d;
        RemoteEngine::new(&p, true)
    }

    #[test]
    fn persist_domain_parses_and_displays() {
        for d in PersistDomain::ALL {
            assert_eq!(d.name().parse::<PersistDomain>().unwrap(), d);
            assert_eq!(format!("{d}"), d.name());
        }
        assert_eq!("rpmem".parse::<PersistDomain>().unwrap(), PersistDomain::RpmemFlush);
        assert_eq!("log".parse::<PersistDomain>().unwrap(), PersistDomain::LogStructured);
        assert_eq!(" EADR ".parse::<PersistDomain>().unwrap(), PersistDomain::Eadr);
        assert!("pmem".parse::<PersistDomain>().is_err());
        assert_eq!(PersistDomain::default(), PersistDomain::Adr);
    }

    #[test]
    fn explicit_adr_is_the_default_engine_bit_for_bit() {
        // The guard-clause pass-through: an engine with the domain set
        // to Adr explicitly runs the identical event sequence as the
        // default-platform engine.
        let mut a = engine();
        let mut b = engine_with(PersistDomain::Adr);
        for (i, &(qp, at)) in [(0usize, 100), (1, 150), (0, 160)].iter().enumerate() {
            let pa = a.write_ddio(qp, at, meta(0x40 * (i as Addr + 1), i as u64));
            let pb = b.write_ddio(qp, at, meta(0x40 * (i as Addr + 1), i as u64));
            assert_eq!(pa, pb);
        }
        assert_eq!(a.write_wt(2, 400, meta(0x400, 9)), b.write_wt(2, 400, meta(0x400, 9)));
        assert_eq!(a.write_nt(0, 500, meta(0x440, 10)), b.write_nt(0, 500, meta(0x440, 10)));
        assert_eq!(a.rcommit(1, 900, 0), b.rcommit(1, 900, 0));
        assert_eq!(a.rdfence(1, 950, 0), b.rdfence(1, 950, 0));
        assert_eq!(a.ledger.events(), b.ledger.events());
        assert_eq!(a.flush_verbs, 0);
        assert_eq!(a.compaction_lines, 0);
    }

    #[test]
    fn eadr_completion_implies_persistence() {
        let mut e = engine_with(PersistDomain::Eadr);
        let proc = e.write_ddio(0, 1000, meta(0x40, 0));
        // Durable at the processing instant — nothing buffers.
        assert_eq!(e.ledger.len(), 1);
        assert_eq!(e.ledger.events()[0].at, proc);
        assert_eq!(e.pending_lines(), 0);
        // The rcommit drain collapses: nothing new persists.
        e.rcommit(1, 2000, 0);
        assert_eq!(e.ledger.len(), 1);
    }

    #[test]
    fn eadr_rdfence_drops_the_pm_tail() {
        let mut adr = engine();
        let mut eadr = engine_with(PersistDomain::Eadr);
        adr.write_wt(0, 1000, meta(0x40, 0));
        eadr.write_wt(0, 1000, meta(0x40, 0));
        let d_adr = adr.rdfence(1, 1100, 0);
        let d_eadr = eadr.rdfence(1, 1100, 0);
        assert!(d_eadr < d_adr, "eADR fence {d_eadr} not faster than ADR {d_adr}");
    }

    #[test]
    fn rpmem_flush_buffers_every_write_until_the_flush_verb() {
        let mut e = engine_with(PersistDomain::RpmemFlush);
        e.write_ddio(0, 1000, meta(0x40, 0));
        let (proc_wt, p_wt) = e.write_wt(1, 1010, meta(0x80, 1));
        let (proc_nt, p_nt) = e.write_nt(0, 1020, meta(0xc0, 2));
        // Completions mean "received", not "durable".
        assert_eq!(p_wt, proc_wt);
        assert_eq!(p_nt, proc_nt);
        assert_eq!(e.ledger.len(), 0, "nothing durable before the flush verb");
        assert_eq!(e.pending_lines(), 3);
        assert_eq!(e.flush_verbs, 0);
        // The fence-path flush persists all three, in one verb.
        let done = e.rcommit(2, 5000, 0);
        assert_eq!(e.ledger.len(), 3);
        assert_eq!(e.pending_lines(), 0);
        assert_eq!(e.flush_verbs, 1);
        assert!(e.ledger.events().iter().all(|ev| ev.at <= done));
        assert!(e.volatile_window_ns > 0);
        // An empty flush is elided from the wire — no verb counted.
        e.rcommit(2, 6000, 0);
        assert_eq!(e.flush_verbs, 1);
    }

    #[test]
    fn rpmem_eviction_keeps_the_line_volatile() {
        // Same tiny-LLC geometry as the ADR eviction test: under
        // RpmemFlush the evicted dirty line must NOT persist — it stays
        // in the volatile buffer until the flush verb covers it.
        let mut p = Platform::default();
        p.llc_slices = 1;
        p.llc_sets_per_slice = 2;
        p.llc_ways = 2;
        p.ddio_ways = 1;
        p.slice_masks = vec![0];
        p.persist_domain = PersistDomain::RpmemFlush;
        let mut e = RemoteEngine::new(&p, true);
        let stride = 2 * 64; // same set
        e.write_ddio(0, 100, meta(0, 0));
        e.write_ddio(0, 200, meta(stride, 1)); // evicts line 0
        assert_eq!(e.ledger.len(), 0, "eviction must not persist without ADR");
        assert_eq!(e.pending_lines(), 2);
        e.rcommit(0, 1000, 0);
        assert_eq!(e.ledger.len(), 2, "flush covers evicted lines too");
    }

    #[test]
    fn rpmem_read_fence_carries_the_flush() {
        let mut e = engine_with(PersistDomain::RpmemFlush);
        let (_, p_nt) = e.write_nt(0, 1000, meta(0x40, 0));
        assert_eq!(e.ledger.len(), 0);
        let done = e.read(0, 2000, 0);
        assert_eq!(e.ledger.len(), 1);
        assert_eq!(e.flush_verbs, 1);
        assert!(done >= p_nt);
        // The piggybacked variant carries the same semantics.
        let mut e = engine_with(PersistDomain::RpmemFlush);
        e.write_ddio(0, 1000, meta(0x40, 0));
        let done = e.read_join(2000, 0);
        assert_eq!(e.ledger.len(), 1);
        assert!(done >= 2000);
    }

    #[test]
    fn log_structured_appends_sequentially_and_compacts_rewrites() {
        let mut e = engine_with(PersistDomain::LogStructured);
        let (_, p1) = e.write_wt(0, 1000, meta(0x40, 0));
        let (_, p2) = e.write_wt(0, 1000, meta(0x80, 1));
        // Fresh lines: durable one append-latency after processing,
        // no compaction debt, nothing buffered.
        assert_eq!(e.ledger.len(), 2);
        assert_eq!(e.pending_lines(), 0);
        assert_eq!(e.compaction_lines, 0);
        assert!(p2 >= p1);
        // Rewriting a live line supersedes it: compaction queued.
        e.write_wt(0, 2000, meta(0x40, 2));
        assert_eq!(e.compaction_lines, 1);
        assert_eq!(e.ledger.len(), 3);
        // NT and DDIO paths append too.
        e.write_nt(0, 3000, meta(0x40, 3));
        e.write_ddio(0, 4000, meta(0x40, 4));
        assert_eq!(e.compaction_lines, 3);
        assert_eq!(e.ledger.len(), 5);
    }

    #[test]
    fn drop_volatile_covers_rpmem_buffered_writes() {
        let mut e = engine_with(PersistDomain::RpmemFlush);
        e.write_wt(0, 100, meta(0x40, 0));
        e.write_nt(0, 200, meta(0x80, 1));
        assert_eq!(e.pending_lines(), 2);
        e.drop_volatile();
        assert_eq!(e.pending_lines(), 0);
        e.rcommit(0, 1000, 0);
        assert_eq!(e.ledger.len(), 0, "dropped lines must not flush later");
        assert_eq!(e.flush_verbs, 0);
    }
}
