//! Verb definitions and write metadata.
//!
//! The paper's verb set: the standard one-sided `Write`/`Read`, the
//! proposed `rcommit` (Talpey-Pinkerton draft, used by SM-RC) and the four
//! new primitives — write-through writes (`WriteWT`), non-temporal writes
//! (`WriteNT`), the remote ordering fence (`ROFence`) and the remote
//! durability fence (`RDFence`). Latency semantics live in
//! [`crate::net::rdma::Rdma`]; this module defines the vocabulary and the
//! per-write transactional metadata threaded through to the durability
//! ledger.

use crate::Addr;

/// Transactional coordinates of a replicated line write (durability-ledger
/// attribution; see [`crate::mem::pmem::DurEvent`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WriteMeta {
    pub addr: Addr,
    pub val: u64,
    pub thread: u32,
    pub txn: u64,
    pub epoch: u32,
    pub seq: u64,
}

/// RDMA verbs modeled by the framework (paper §2.3, §5, §6.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Verb {
    /// One-sided RDMA write; lands in the remote LLC via DDIO (posted).
    Write,
    /// One-sided RDMA read; completion fences all prior writes on the QP.
    Read,
    /// Remote commit: flush all prior RDMA-written lines to the remote MC
    /// (blocking; both ordering and durability — the overloaded primitive).
    RCommit,
    /// Write-through write: DDIO into LLC then immediate write-through to
    /// the MC queue (posted) — new primitive, used by SM-OB.
    WriteWT,
    /// Non-temporal write: bypasses the LLC straight to the MC queue
    /// (ordered, non-posted at the root complex) — new primitive, SM-DD.
    WriteNT,
    /// Remote ordering fence: epoch barrier at the remote NIC (posted) —
    /// new primitive, SM-OB.
    ROFence,
    /// Remote durability fence: blocks until all prior writes persist —
    /// new primitive, SM-OB.
    RDFence,
}

impl Verb {
    pub fn name(self) -> &'static str {
        match self {
            Verb::Write => "write",
            Verb::Read => "read",
            Verb::RCommit => "rcommit",
            Verb::WriteWT => "write-wt",
            Verb::WriteNT => "write-nt",
            Verb::ROFence => "rofence",
            Verb::RDFence => "rdfence",
        }
    }

    /// Does the issuing thread block on this verb's completion?
    pub fn is_blocking(self) -> bool {
        matches!(self, Verb::Read | Verb::RCommit | Verb::RDFence)
    }
}

/// Table 1 rendering: the per-strategy code transformation of a 2-epoch
/// transaction (experiment T1; printed by `pmsm selftest --show-table1`).
pub fn table1() -> String {
    let rows = [
        (
            "NO-SM",
            "st A; clwb A; sfence; st B; clwb B; sfence",
        ),
        (
            "SM-RC",
            "st A; clwb A; write(A); rcommit; sfence; st B; clwb B; write(B); rcommit; sfence",
        ),
        (
            "SM-OB",
            "st A; clwb A; write_wt(A); rofence; sfence; st B; clwb B; write_wt(B); rofence; sfence; rdfence",
        ),
        (
            "SM-DD",
            "st A; clwb A; write_nt(A); sfence; st B; clwb B; write_nt(B); sfence; read(sentinel)",
        ),
    ];
    let mut s = String::from("Table 1 — replication code transformations (2 epochs, 1 write each)\n");
    for (name, code) in rows {
        s.push_str(&format!("  {name:<6} : {code}\n"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blocking_classification() {
        assert!(Verb::RCommit.is_blocking());
        assert!(Verb::RDFence.is_blocking());
        assert!(Verb::Read.is_blocking());
        assert!(!Verb::Write.is_blocking());
        assert!(!Verb::WriteWT.is_blocking());
        assert!(!Verb::WriteNT.is_blocking());
        assert!(!Verb::ROFence.is_blocking());
    }

    #[test]
    fn table1_mentions_all_strategies() {
        let t = table1();
        for s in ["NO-SM", "SM-RC", "SM-OB", "SM-DD"] {
            assert!(t.contains(s));
        }
        assert!(t.contains("rcommit"));
        assert!(t.contains("rofence"));
        assert!(t.contains("read(sentinel)"));
    }
}
