//! Staged WQE submission pipeline with doorbell batching.
//!
//! The eager posting model charges the primary a full doorbell
//! (`post_cost`) per replicated line per backup, so an S-shard, N-backup
//! deployment pays `S * N * post_cost` of CPU per line — the opposite of
//! how real RNICs behave, where one MMIO doorbell launches a whole
//! *chain* of WQEs queued in host memory. This module models that
//! amortization explicitly:
//!
//! * a [`Wqe`] is one staged work-queue entry — a data verb
//!   ([`Verb::Write`] / [`Verb::WriteWT`] / [`Verb::WriteNT`]), its
//!   [`WriteMeta`], and the backup it targets;
//! * a [`SubmitQueue`] is the per-thread staging area: WQEs accumulate
//!   in host memory (each costing only `wqe_stage_ns` of CPU) until a
//!   **flush** rings the doorbell — one `doorbell_ns` charge per backup
//!   with staged work, regardless of how many WQEs its chain holds;
//! * a [`FlushPolicy`] decides when flushes happen: [`FlushPolicy::Eager`]
//!   (every post is its own doorbell — the pre-batching model),
//!   [`FlushPolicy::Cap`]`(k)` (flush once `k` logical line writes are
//!   staged), or [`FlushPolicy::Fence`] (flush only at ordering /
//!   durability fences — maximal batching between persistence points).
//!
//! Batches never leak across ordering or durability fences: every
//! `rofence` / `rcommit` / `rdfence` / read-fence (and therefore every
//! epoch boundary and transaction commit) flushes the stage before the
//! fence verb issues, so the remote engine observes the exact same
//! per-thread write/fence order as the eager path and the persistency
//! semantics are unchanged — only arrival *instants* move. With
//! `batch_cap = 1` (normalized to `Eager`) the pipeline reproduces the
//! pre-batching cost model bit-exactly; `rust/tests/batching.rs` pins
//! the ledger equivalence for caps {1, 4, 16} under all three SM
//! strategies.
//!
//! **Flush-time coalescing** (see [`CoalesceMode`]): because a flushed
//! chain sits strictly between two flush points — and every ordering /
//! durability fence is a flush point — no fence ever separates the WQEs
//! of one chain, which makes the chain a legal coalescing window. The
//! [`coalesce_chain`] stage runs per backup chain at flush time and
//! applies, per the configured mode:
//!
//! * **write combining** ([`CoalesceMode::Combine`]) — same-line
//!   overwrites *within the same transaction epoch* collapse to the
//!   last writer (keyed on `(line, txn, epoch, verb)`; the survivor's
//!   `WriteMeta`, with the highest `seq`, is kept), so hot lines
//!   rewritten inside an epoch pay one wire round instead of N. The
//!   epoch restriction is load-bearing: an SM-DD chain spans
//!   epochs (its ordering fence is not a flush point), and collapsing a
//!   cross-epoch rewrite — e.g. an undo-log status word bumped once per
//!   log append — would let a crash observe a mutation without the log
//!   state that guards it. Within one epoch the persistency contract
//!   orders nothing, so the intermediate value was never observable at
//!   a fence and dropping it is sound;
//! * **scatter-gather merging** ([`CoalesceMode::Sg`]) — runs of
//!   address-contiguous, same-verb WQEs that are adjacent in the chain
//!   merge into one multi-line [`Wqe`] span (the extra lines ride in
//!   [`Wqe::tail`]), which pays one QP slot + one NIC message slot +
//!   `wire_line_ns` per extra line instead of a full per-WQE round.
//!   Nothing is dropped: every line still persists individually on the
//!   remote ([`crate::net::RemoteEngine`] applies a span as per-line
//!   persists under one completion), so the ledger is event-identical
//!   to the unmerged chain — only arrival instants move.
//!
//! [`CoalesceMode::None`] is the regression anchor: the chain passes
//! through untouched and the pipeline is event-for-event the doorbell-
//! batching pipeline. `rust/tests/coalescing.rs` pins the anchor and the
//! ledger/recovery equivalence of all four modes.
//!
//! The fan-out half of the pipeline (staging one logical line as N
//! backup WQEs, dropping staged WQEs whose target was killed before the
//! doorbell, per-backup chains) lives in [`crate::net::Fabric`]; the
//! per-WQE gap/window/back-pressure submission model is unchanged in
//! [`crate::net::Rdma::post_batch`].
//!
//! The flush point doubles as the **permission-revocation barrier** of
//! a primary failover (see [`crate::net::membership`]): every staged
//! WQE must pass a doorbell to reach the wire, so revoking the dying
//! primary's write permission at the flush choke point provably fences
//! its in-flight chains — they are counted
//! ([`crate::net::Fabric::revoked_wqes`]) and retried through the new
//! primary once it admits writes.
//!
//! It is also where the **explicit flush verb** of the
//! [`crate::net::PersistDomain::RpmemFlush`] persistence domain rides:
//! every blocking fence flushes the staged chains here first, then its
//! fence verb (issued or group-fence-joined) carries flush semantics on
//! the responder — so by construction no flush verb can overtake data
//! still staged in host memory, and a counted flush verb always trails
//! at least one data doorbell to that backup (the
//! `flush_verbs <= doorbells` invariant CI enforces).

use super::verbs::{Verb, WriteMeta};
use crate::{line_of, LINE};
use anyhow::{anyhow, bail, Result};
use std::fmt;
use std::str::FromStr;

/// Mean data WQEs launched per doorbell — the amortization factor the
/// staged pipeline recovers (1.0 under eager posting; 0 before any
/// data traffic). The shared convention behind every metrics surface
/// (fabric, run outcome, group/sharded reports).
pub fn mean_batch(wqes: u64, doorbells: u64) -> f64 {
    if doorbells == 0 {
        return 0.0;
    }
    wqes as f64 / doorbells as f64
}

/// Mean lines carried per wire WQE — the scatter-gather amortization
/// factor (1.0 when every WQE is single-line; 0 before any traffic).
pub fn mean_span(lines: u64, wire_wqes: u64) -> f64 {
    if wire_wqes == 0 {
        return 0.0;
    }
    lines as f64 / wire_wqes as f64
}

/// One staged work-queue entry: a data verb bound for one backup —
/// single-line as staged, possibly a multi-line scatter-gather span
/// after [`coalesce_chain`] merged address-contiguous neighbours into
/// its [`Wqe::tail`].
#[derive(Clone, Debug, PartialEq)]
pub struct Wqe {
    /// The data verb ([`Verb::Write`], [`Verb::WriteWT`] or
    /// [`Verb::WriteNT`] — fences are flush points, never staged).
    pub verb: Verb,
    /// The head (lowest-addressed) line of the span.
    pub meta: WriteMeta,
    /// Target backup index within the replica group.
    pub backup: usize,
    /// Additional address-contiguous lines merged into this WQE by the
    /// scatter-gather coalescer, in ascending line order (empty for the
    /// common single-line WQE — `Vec::new()` does not allocate).
    pub tail: Vec<WriteMeta>,
}

impl Wqe {
    /// A single-line WQE (the shape the staging queue holds).
    pub fn single(verb: Verb, meta: WriteMeta, backup: usize) -> Self {
        Wqe {
            verb,
            meta,
            backup,
            tail: Vec::new(),
        }
    }

    /// Lines this WQE carries (1 for an unmerged WQE).
    pub fn lines(&self) -> usize {
        1 + self.tail.len()
    }

    /// All line metas of the span, head first.
    pub fn metas(&self) -> impl Iterator<Item = &WriteMeta> {
        std::iter::once(&self.meta).chain(self.tail.iter())
    }

    /// First line address past the span (the contiguity frontier).
    fn frontier(&self) -> u64 {
        line_of(self.meta.addr) + self.lines() as u64 * LINE
    }
}

/// Flush-time coalescing mode of the staged pipeline (see module docs
/// for the semantics argument; `--coalesce` / `[coalescing] mode`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CoalesceMode {
    /// Chains pass through untouched — event-for-event the plain
    /// doorbell-batching pipeline, and the regression anchor.
    #[default]
    None,
    /// Write combining only: same-line overwrites within one epoch of a
    /// chain collapse to the last writer.
    Combine,
    /// Scatter-gather merging only: adjacent address-contiguous
    /// same-verb WQEs merge into multi-line spans.
    Sg,
    /// Both: combine first (drop dead overwrites), then merge the
    /// surviving chain into spans.
    Full,
}

impl CoalesceMode {
    /// Does this mode drop superseded same-line overwrites?
    pub fn combining(&self) -> bool {
        matches!(self, CoalesceMode::Combine | CoalesceMode::Full)
    }

    /// Does this mode merge contiguous WQEs into spans?
    pub fn sg(&self) -> bool {
        matches!(self, CoalesceMode::Sg | CoalesceMode::Full)
    }

    pub fn name(&self) -> &'static str {
        match self {
            CoalesceMode::None => "none",
            CoalesceMode::Combine => "combine",
            CoalesceMode::Sg => "sg",
            CoalesceMode::Full => "full",
        }
    }
}

impl FromStr for CoalesceMode {
    type Err = anyhow::Error;

    /// Parse a `--coalesce` spec: `none | combine | sg | full`.
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s.trim().to_ascii_lowercase().as_str() {
            "none" | "off" => CoalesceMode::None,
            "combine" | "wc" => CoalesceMode::Combine,
            "sg" | "scatter-gather" => CoalesceMode::Sg,
            "full" | "combine+sg" => CoalesceMode::Full,
            other => bail!("unknown coalesce mode {other:?}; expected none | combine | sg | full"),
        })
    }
}

impl fmt::Display for CoalesceMode {
    /// Round-trips through [`FromStr`].
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// The `[coalescing]` config table / `--coalesce` CLI surface.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CoalescingConfig {
    pub mode: CoalesceMode,
}

impl CoalescingConfig {
    pub fn new(mode: CoalesceMode) -> Self {
        CoalescingConfig { mode }
    }

    /// Coalescing operates on flushed chains, so it needs the staged
    /// pipeline: under an eager flush policy every chain is a single
    /// WQE and the coalescer could never fire — reject the shape
    /// instead of silently doing nothing.
    pub fn validate_with(&self, policy: FlushPolicy) -> Result<()> {
        if self.mode != CoalesceMode::None && policy.is_eager() {
            bail!(
                "coalescing.mode = {} requires a staged flush policy \
                 (batching.flush_policy = cap:K | fence); eager posting \
                 stages nothing to coalesce",
                self.mode
            );
        }
        Ok(())
    }
}

/// Run the flush-time coalescing stage over one backup's chain (stage
/// order in, submission order out). Returns the coalesced chain and the
/// number of line writes elided by write combining. The chain must be
/// single-thread (per-thread stages guarantee it) and fence-free (flush
/// boundaries guarantee it); under [`CoalesceMode::None`] the chain is
/// returned untouched — the anchor path allocates and reorders nothing.
pub fn coalesce_chain(mode: CoalesceMode, chain: Vec<Wqe>) -> (Vec<Wqe>, u64) {
    if mode == CoalesceMode::None || chain.len() <= 1 {
        return (chain, 0);
    }
    let mut combined = 0u64;
    let chain = if mode.combining() {
        // Walk back-to-front: a write is dead iff a later write in the
        // chain targets the same line within the same (txn, epoch) with
        // the same verb. The survivor keeps its own (last-writer) meta
        // and position, so per-thread order of surviving events — and
        // the ledger entry at every fence point — is unchanged. Chains
        // are short (bounded by the flush cap or one fence window), so
        // a linear scan over the survivors beats hashing on this hot
        // per-flush path.
        let mut kept: Vec<Wqe> = Vec::with_capacity(chain.len());
        for w in chain.into_iter().rev() {
            let superseded = kept.iter().any(|k| {
                k.verb == w.verb
                    && line_of(k.meta.addr) == line_of(w.meta.addr)
                    && k.meta.txn == w.meta.txn
                    && k.meta.epoch == w.meta.epoch
            });
            if superseded {
                combined += 1;
            } else {
                kept.push(w);
            }
        }
        kept.reverse();
        kept
    } else {
        chain
    };
    if !mode.sg() {
        return (chain, combined);
    }
    // Scatter-gather: merge runs of chain-adjacent, address-contiguous,
    // same-verb WQEs into one span. Only adjacent WQEs merge, so the
    // submission order (and therefore every per-line arrival order) is
    // exactly the unmerged chain's.
    let mut merged: Vec<Wqe> = Vec::with_capacity(chain.len());
    for w in chain {
        match merged.last_mut() {
            Some(prev)
                if prev.verb == w.verb
                    && w.tail.is_empty()
                    && line_of(w.meta.addr) == prev.frontier() =>
            {
                prev.tail.push(w.meta);
            }
            _ => merged.push(w),
        }
    }
    (merged, combined)
}

/// When the staged pipeline rings its doorbells.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FlushPolicy {
    /// No staging: every post rings its own per-backup doorbell — the
    /// pre-batching model, and the regression anchor (`batch_cap = 1`
    /// normalizes to this).
    #[default]
    Eager,
    /// Flush once `k` logical line writes are staged (each fans out to
    /// one WQE per live backup but counts once toward the cap). Fences
    /// still flush early; `Cap(1)` normalizes to [`FlushPolicy::Eager`].
    Cap(usize),
    /// Flush only at ordering/durability fences: maximal batching
    /// between persistence points.
    Fence,
}

impl FlushPolicy {
    /// Reject impossible shapes (`cap:0` never flushes).
    pub fn validate(&self) -> Result<()> {
        if let FlushPolicy::Cap(0) = self {
            bail!("batching cap must be >= 1 line (cap:0 never flushes)");
        }
        Ok(())
    }

    /// Canonical form: `Cap(1)` *is* the eager model (a flush after
    /// every line, one doorbell per backup), so it normalizes to
    /// [`FlushPolicy::Eager`] — the `batch_cap = 1` regression anchor.
    pub fn normalized(self) -> FlushPolicy {
        match self {
            FlushPolicy::Cap(1) => FlushPolicy::Eager,
            other => other,
        }
    }

    /// Does this policy bypass the staging queue entirely?
    pub fn is_eager(&self) -> bool {
        matches!(self.normalized(), FlushPolicy::Eager)
    }
}

impl FromStr for FlushPolicy {
    type Err = anyhow::Error;

    /// Parse a `--flush-policy` spec: `eager`, `fence`, or `cap:K`
    /// (K logical line writes per batch, underscores allowed).
    fn from_str(s: &str) -> Result<Self> {
        let s = s.trim().to_ascii_lowercase();
        match s.as_str() {
            "eager" => return Ok(FlushPolicy::Eager),
            "fence" => return Ok(FlushPolicy::Fence),
            _ => {}
        }
        if let Some(rest) = s.strip_prefix("cap:") {
            let k: usize = rest
                .trim()
                .replace('_', "")
                .parse()
                .map_err(|e| anyhow!("flush policy {s:?}: bad cap: {e}"))?;
            let p = FlushPolicy::Cap(k);
            p.validate()?;
            return Ok(p);
        }
        bail!("unknown flush policy {s:?}; expected eager | cap:K | fence")
    }
}

impl fmt::Display for FlushPolicy {
    /// Round-trips through [`FromStr`]: `eager` / `cap:K` / `fence`.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FlushPolicy::Eager => f.write_str("eager"),
            FlushPolicy::Cap(k) => write!(f, "cap:{k}"),
            FlushPolicy::Fence => f.write_str("fence"),
        }
    }
}

/// The `[batching]` config table / `--batch-cap` / `--flush-policy`
/// CLI surface.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BatchingConfig {
    pub policy: FlushPolicy,
}

impl BatchingConfig {
    pub fn new(policy: FlushPolicy) -> Self {
        BatchingConfig { policy }
    }

    pub fn validate(&self) -> Result<()> {
        self.policy.validate()
    }
}

/// Per-thread staging queue: WQEs chained in host memory awaiting a
/// doorbell. FIFO — flush submits in stage order, which preserves the
/// per-thread issue order the eager path would have produced.
#[derive(Clone, Debug, Default)]
pub struct SubmitQueue {
    wqes: Vec<Wqe>,
    /// Logical line writes staged since the last flush (each fans out
    /// to one WQE per live backup but counts once toward a cap).
    lines: usize,
}

impl SubmitQueue {
    /// Stage one backup WQE (costs `wqe_stage_ns` of CPU at the caller).
    pub fn push(&mut self, w: Wqe) {
        self.wqes.push(w);
    }

    /// Count one logical line write against the flush cap (call once
    /// per fan-out, after pushing its per-backup WQEs).
    pub fn note_line(&mut self) {
        self.lines += 1;
    }

    /// Logical line writes staged since the last flush.
    pub fn lines(&self) -> usize {
        self.lines
    }

    /// Staged backup WQEs awaiting a doorbell.
    pub fn len(&self) -> usize {
        self.wqes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.wqes.is_empty()
    }

    /// Drain the stage for a flush: returns the chained WQEs in stage
    /// order and resets the line count.
    pub fn take(&mut self) -> Vec<Wqe> {
        self.lines = 0;
        std::mem::take(&mut self.wqes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wqe(backup: usize, seq: u64) -> Wqe {
        Wqe::single(
            Verb::WriteWT,
            WriteMeta {
                addr: 0x40 * (1 + seq),
                val: seq,
                thread: 0,
                txn: 0,
                epoch: 0,
                seq,
            },
            backup,
        )
    }

    /// A single-line WQE at an explicit line address / epoch.
    fn at(verb: Verb, addr: u64, epoch: u32, seq: u64) -> Wqe {
        Wqe::single(
            verb,
            WriteMeta {
                addr,
                val: seq,
                thread: 0,
                txn: 0,
                epoch,
                seq,
            },
            0,
        )
    }

    #[test]
    fn flush_policy_parse_roundtrip() {
        for p in [FlushPolicy::Eager, FlushPolicy::Cap(4), FlushPolicy::Fence] {
            assert_eq!(p.to_string().parse::<FlushPolicy>().unwrap(), p);
        }
        assert_eq!("EAGER".parse::<FlushPolicy>().unwrap(), FlushPolicy::Eager);
        assert_eq!("cap:1_024".parse::<FlushPolicy>().unwrap(), FlushPolicy::Cap(1024));
        assert!("cap:0".parse::<FlushPolicy>().is_err());
        assert!("cap:x".parse::<FlushPolicy>().is_err());
        assert!("cap".parse::<FlushPolicy>().is_err());
        assert!("batched".parse::<FlushPolicy>().is_err());
    }

    #[test]
    fn cap_one_normalizes_to_eager() {
        assert_eq!(FlushPolicy::Cap(1).normalized(), FlushPolicy::Eager);
        assert!(FlushPolicy::Cap(1).is_eager());
        assert!(FlushPolicy::Eager.is_eager());
        assert!(!FlushPolicy::Cap(2).is_eager());
        assert!(!FlushPolicy::Fence.is_eager());
        assert_eq!(FlushPolicy::Cap(2).normalized(), FlushPolicy::Cap(2));
    }

    #[test]
    fn mean_batch_convention() {
        assert_eq!(mean_batch(0, 0), 0.0);
        assert_eq!(mean_batch(64, 0), 0.0, "no doorbells: no factor");
        assert!((mean_batch(64, 64) - 1.0).abs() < 1e-9, "eager");
        assert!((mean_batch(64, 4) - 16.0).abs() < 1e-9);
    }

    #[test]
    fn batching_config_validates_cap() {
        assert!(BatchingConfig::default().validate().is_ok());
        assert!(BatchingConfig::new(FlushPolicy::Cap(0)).validate().is_err());
        assert!(BatchingConfig::new(FlushPolicy::Fence).validate().is_ok());
        assert_eq!(BatchingConfig::default().policy, FlushPolicy::Eager);
    }

    #[test]
    fn submit_queue_stages_fifo_and_take_resets() {
        let mut q = SubmitQueue::default();
        assert!(q.is_empty());
        // One logical line fanned out to two backups.
        q.push(wqe(0, 0));
        q.push(wqe(1, 0));
        q.note_line();
        q.push(wqe(0, 1));
        q.push(wqe(1, 1));
        q.note_line();
        assert_eq!(q.len(), 4);
        assert_eq!(q.lines(), 2);
        let drained = q.take();
        assert_eq!(drained.len(), 4);
        // FIFO: stage order preserved per thread.
        assert_eq!(drained[0], wqe(0, 0));
        assert_eq!(drained[3], wqe(1, 1));
        assert!(q.is_empty());
        assert_eq!(q.lines(), 0);
    }

    // ---- coalescing ------------------------------------------------------

    #[test]
    fn coalesce_mode_parse_roundtrip() {
        for m in [
            CoalesceMode::None,
            CoalesceMode::Combine,
            CoalesceMode::Sg,
            CoalesceMode::Full,
        ] {
            assert_eq!(m.to_string().parse::<CoalesceMode>().unwrap(), m);
        }
        assert_eq!("SG".parse::<CoalesceMode>().unwrap(), CoalesceMode::Sg);
        assert_eq!("off".parse::<CoalesceMode>().unwrap(), CoalesceMode::None);
        assert!("both".parse::<CoalesceMode>().is_err());
        assert!(CoalesceMode::Full.combining() && CoalesceMode::Full.sg());
        assert!(!CoalesceMode::Combine.sg());
        assert!(!CoalesceMode::Sg.combining());
    }

    #[test]
    fn coalescing_config_requires_staged_policy() {
        let c = CoalescingConfig::new(CoalesceMode::Full);
        assert!(c.validate_with(FlushPolicy::Fence).is_ok());
        assert!(c.validate_with(FlushPolicy::Cap(4)).is_ok());
        assert!(c.validate_with(FlushPolicy::Eager).is_err());
        assert!(c.validate_with(FlushPolicy::Cap(1)).is_err(), "cap:1 IS eager");
        let none = CoalescingConfig::default();
        assert!(none.validate_with(FlushPolicy::Eager).is_ok());
    }

    #[test]
    fn none_mode_passes_chains_through_untouched() {
        let chain = vec![at(Verb::WriteWT, 0x40, 0, 0), at(Verb::WriteWT, 0x40, 0, 1)];
        let (out, combined) = coalesce_chain(CoalesceMode::None, chain.clone());
        assert_eq!(out, chain);
        assert_eq!(combined, 0);
    }

    #[test]
    fn combine_collapses_same_epoch_rewrites_to_last_writer() {
        // A, B, A' in one epoch: the first A is dead; B and A' survive in
        // chain order with A' keeping the last writer's meta.
        let chain = vec![
            at(Verb::WriteWT, 0x40, 0, 0),
            at(Verb::WriteWT, 0x80, 0, 1),
            at(Verb::WriteWT, 0x40, 0, 2),
        ];
        let (out, combined) = coalesce_chain(CoalesceMode::Combine, chain);
        assert_eq!(combined, 1);
        assert_eq!(out.len(), 2);
        assert_eq!((out[0].meta.addr, out[0].meta.seq), (0x80, 1));
        assert_eq!((out[1].meta.addr, out[1].meta.seq), (0x40, 2));
    }

    #[test]
    fn combine_never_crosses_epoch_boundaries() {
        // The same line rewritten in a LATER epoch of the same chain
        // (an SM-DD chain spans epochs) must keep both copies: dropping
        // the earlier one would let a crash observe epoch-1 state
        // without its epoch-0 prefix.
        let chain = vec![at(Verb::WriteNT, 0x40, 0, 0), at(Verb::WriteNT, 0x40, 1, 1)];
        let (out, combined) = coalesce_chain(CoalesceMode::Full, chain.clone());
        assert_eq!(combined, 0);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].meta.epoch, 0);
        assert_eq!(out[1].meta.epoch, 1);
        // Different transactions are likewise never combined.
        let mut cross_txn = chain;
        cross_txn[1].meta.epoch = 0;
        cross_txn[1].meta.txn = 1;
        let (out, combined) = coalesce_chain(CoalesceMode::Combine, cross_txn);
        assert_eq!((out.len(), combined), (2, 0));
    }

    #[test]
    fn sg_merges_adjacent_contiguous_runs() {
        // [0x40, 0x80, 0xc0] contiguous; 0x200 breaks the run; 0x240
        // starts a new 2-line span.
        let chain = vec![
            at(Verb::WriteWT, 0x40, 0, 0),
            at(Verb::WriteWT, 0x80, 0, 1),
            at(Verb::WriteWT, 0xc0, 0, 2),
            at(Verb::WriteWT, 0x200, 0, 3),
            at(Verb::WriteWT, 0x240, 0, 4),
        ];
        let (out, combined) = coalesce_chain(CoalesceMode::Sg, chain);
        assert_eq!(combined, 0, "sg drops nothing");
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].lines(), 3);
        assert_eq!(out[0].meta.addr, 0x40);
        assert_eq!(out[0].tail[1].addr, 0xc0);
        assert_eq!(out[1].lines(), 2);
        assert_eq!(out[1].meta.addr, 0x200);
        // Total lines conserved.
        assert_eq!(out.iter().map(Wqe::lines).sum::<usize>(), 5);
    }

    #[test]
    fn sg_respects_verb_and_adjacency_boundaries() {
        // Contiguous addresses but a verb change (or a non-adjacent
        // position in the chain) must not merge.
        let chain = vec![
            at(Verb::WriteWT, 0x40, 0, 0),
            at(Verb::Write, 0x80, 0, 1),
            at(Verb::WriteWT, 0xc0, 0, 2),
        ];
        let (out, _) = coalesce_chain(CoalesceMode::Sg, chain);
        assert_eq!(out.len(), 3);
        assert!(out.iter().all(|w| w.lines() == 1));
        // Same line twice is NOT contiguous (next line != same line).
        let chain = vec![at(Verb::WriteWT, 0x40, 0, 0), at(Verb::WriteWT, 0x40, 0, 1)];
        let (out, _) = coalesce_chain(CoalesceMode::Sg, chain);
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn full_combines_then_merges() {
        // Hot header 0x40 rewritten around a contiguous append run:
        // combine drops the first header write, then sg merges the
        // append run [0x1000, 0x1040, 0x1080] into one span.
        let chain = vec![
            at(Verb::WriteWT, 0x40, 0, 0),
            at(Verb::WriteWT, 0x1000, 0, 1),
            at(Verb::WriteWT, 0x1040, 0, 2),
            at(Verb::WriteWT, 0x1080, 0, 3),
            at(Verb::WriteWT, 0x40, 0, 4),
        ];
        let (out, combined) = coalesce_chain(CoalesceMode::Full, chain);
        assert_eq!(combined, 1);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].lines(), 3, "append run merged");
        assert_eq!(out[0].meta.addr, 0x1000);
        assert_eq!(out[1].meta.addr, 0x40);
        assert_eq!(out[1].meta.seq, 4, "last writer survives");
    }

    #[test]
    fn span_accessors_and_mean_span() {
        let mut w = at(Verb::WriteNT, 0x40, 0, 0);
        assert_eq!(w.lines(), 1);
        w.tail.push(WriteMeta { addr: 0x80, ..w.meta });
        assert_eq!(w.lines(), 2);
        let metas: Vec<u64> = w.metas().map(|m| m.addr).collect();
        assert_eq!(metas, vec![0x40, 0x80]);
        assert_eq!(mean_span(0, 0), 0.0);
        assert!((mean_span(6, 6) - 1.0).abs() < 1e-9);
        assert!((mean_span(6, 2) - 3.0).abs() < 1e-9);
    }
}
