//! Staged WQE submission pipeline with doorbell batching.
//!
//! The eager posting model charges the primary a full doorbell
//! (`post_cost`) per replicated line per backup, so an S-shard, N-backup
//! deployment pays `S * N * post_cost` of CPU per line — the opposite of
//! how real RNICs behave, where one MMIO doorbell launches a whole
//! *chain* of WQEs queued in host memory. This module models that
//! amortization explicitly:
//!
//! * a [`Wqe`] is one staged work-queue entry — a data verb
//!   ([`Verb::Write`] / [`Verb::WriteWT`] / [`Verb::WriteNT`]), its
//!   [`WriteMeta`], and the backup it targets;
//! * a [`SubmitQueue`] is the per-thread staging area: WQEs accumulate
//!   in host memory (each costing only `wqe_stage_ns` of CPU) until a
//!   **flush** rings the doorbell — one `doorbell_ns` charge per backup
//!   with staged work, regardless of how many WQEs its chain holds;
//! * a [`FlushPolicy`] decides when flushes happen: [`FlushPolicy::Eager`]
//!   (every post is its own doorbell — the pre-batching model),
//!   [`FlushPolicy::Cap`]`(k)` (flush once `k` logical line writes are
//!   staged), or [`FlushPolicy::Fence`] (flush only at ordering /
//!   durability fences — maximal batching between persistence points).
//!
//! Batches never leak across ordering or durability fences: every
//! `rofence` / `rcommit` / `rdfence` / read-fence (and therefore every
//! epoch boundary and transaction commit) flushes the stage before the
//! fence verb issues, so the remote engine observes the exact same
//! per-thread write/fence order as the eager path and the persistency
//! semantics are unchanged — only arrival *instants* move. With
//! `batch_cap = 1` (normalized to `Eager`) the pipeline reproduces the
//! pre-batching cost model bit-exactly; `rust/tests/batching.rs` pins
//! the ledger equivalence for caps {1, 4, 16} under all three SM
//! strategies.
//!
//! The fan-out half of the pipeline (staging one logical line as N
//! backup WQEs, dropping staged WQEs whose target was killed before the
//! doorbell, per-backup chains) lives in [`crate::net::Fabric`]; the
//! per-WQE gap/window/back-pressure submission model is unchanged in
//! [`crate::net::Rdma::post_batch`].

use super::verbs::{Verb, WriteMeta};
use anyhow::{anyhow, bail, Result};
use std::fmt;
use std::str::FromStr;

/// Mean data WQEs launched per doorbell — the amortization factor the
/// staged pipeline recovers (1.0 under eager posting; 0 before any
/// data traffic). The shared convention behind every metrics surface
/// (fabric, run outcome, group/sharded reports).
pub fn mean_batch(wqes: u64, doorbells: u64) -> f64 {
    if doorbells == 0 {
        return 0.0;
    }
    wqes as f64 / doorbells as f64
}

/// One staged work-queue entry: a data verb bound for one backup.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Wqe {
    /// The data verb ([`Verb::Write`], [`Verb::WriteWT`] or
    /// [`Verb::WriteNT`] — fences are flush points, never staged).
    pub verb: Verb,
    pub meta: WriteMeta,
    /// Target backup index within the replica group.
    pub backup: usize,
}

/// When the staged pipeline rings its doorbells.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FlushPolicy {
    /// No staging: every post rings its own per-backup doorbell — the
    /// pre-batching model, and the regression anchor (`batch_cap = 1`
    /// normalizes to this).
    #[default]
    Eager,
    /// Flush once `k` logical line writes are staged (each fans out to
    /// one WQE per live backup but counts once toward the cap). Fences
    /// still flush early; `Cap(1)` normalizes to [`FlushPolicy::Eager`].
    Cap(usize),
    /// Flush only at ordering/durability fences: maximal batching
    /// between persistence points.
    Fence,
}

impl FlushPolicy {
    /// Reject impossible shapes (`cap:0` never flushes).
    pub fn validate(&self) -> Result<()> {
        if let FlushPolicy::Cap(0) = self {
            bail!("batching cap must be >= 1 line (cap:0 never flushes)");
        }
        Ok(())
    }

    /// Canonical form: `Cap(1)` *is* the eager model (a flush after
    /// every line, one doorbell per backup), so it normalizes to
    /// [`FlushPolicy::Eager`] — the `batch_cap = 1` regression anchor.
    pub fn normalized(self) -> FlushPolicy {
        match self {
            FlushPolicy::Cap(1) => FlushPolicy::Eager,
            other => other,
        }
    }

    /// Does this policy bypass the staging queue entirely?
    pub fn is_eager(&self) -> bool {
        matches!(self.normalized(), FlushPolicy::Eager)
    }
}

impl FromStr for FlushPolicy {
    type Err = anyhow::Error;

    /// Parse a `--flush-policy` spec: `eager`, `fence`, or `cap:K`
    /// (K logical line writes per batch, underscores allowed).
    fn from_str(s: &str) -> Result<Self> {
        let s = s.trim().to_ascii_lowercase();
        match s.as_str() {
            "eager" => return Ok(FlushPolicy::Eager),
            "fence" => return Ok(FlushPolicy::Fence),
            _ => {}
        }
        if let Some(rest) = s.strip_prefix("cap:") {
            let k: usize = rest
                .trim()
                .replace('_', "")
                .parse()
                .map_err(|e| anyhow!("flush policy {s:?}: bad cap: {e}"))?;
            let p = FlushPolicy::Cap(k);
            p.validate()?;
            return Ok(p);
        }
        bail!("unknown flush policy {s:?}; expected eager | cap:K | fence")
    }
}

impl fmt::Display for FlushPolicy {
    /// Round-trips through [`FromStr`]: `eager` / `cap:K` / `fence`.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FlushPolicy::Eager => f.write_str("eager"),
            FlushPolicy::Cap(k) => write!(f, "cap:{k}"),
            FlushPolicy::Fence => f.write_str("fence"),
        }
    }
}

/// The `[batching]` config table / `--batch-cap` / `--flush-policy`
/// CLI surface.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BatchingConfig {
    pub policy: FlushPolicy,
}

impl BatchingConfig {
    pub fn new(policy: FlushPolicy) -> Self {
        BatchingConfig { policy }
    }

    pub fn validate(&self) -> Result<()> {
        self.policy.validate()
    }
}

/// Per-thread staging queue: WQEs chained in host memory awaiting a
/// doorbell. FIFO — flush submits in stage order, which preserves the
/// per-thread issue order the eager path would have produced.
#[derive(Clone, Debug, Default)]
pub struct SubmitQueue {
    wqes: Vec<Wqe>,
    /// Logical line writes staged since the last flush (each fans out
    /// to one WQE per live backup but counts once toward a cap).
    lines: usize,
}

impl SubmitQueue {
    /// Stage one backup WQE (costs `wqe_stage_ns` of CPU at the caller).
    pub fn push(&mut self, w: Wqe) {
        self.wqes.push(w);
    }

    /// Count one logical line write against the flush cap (call once
    /// per fan-out, after pushing its per-backup WQEs).
    pub fn note_line(&mut self) {
        self.lines += 1;
    }

    /// Logical line writes staged since the last flush.
    pub fn lines(&self) -> usize {
        self.lines
    }

    /// Staged backup WQEs awaiting a doorbell.
    pub fn len(&self) -> usize {
        self.wqes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.wqes.is_empty()
    }

    /// Drain the stage for a flush: returns the chained WQEs in stage
    /// order and resets the line count.
    pub fn take(&mut self) -> Vec<Wqe> {
        self.lines = 0;
        std::mem::take(&mut self.wqes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wqe(backup: usize, seq: u64) -> Wqe {
        Wqe {
            verb: Verb::WriteWT,
            meta: WriteMeta {
                addr: 0x40 * (1 + seq),
                val: seq,
                thread: 0,
                txn: 0,
                epoch: 0,
                seq,
            },
            backup,
        }
    }

    #[test]
    fn flush_policy_parse_roundtrip() {
        for p in [FlushPolicy::Eager, FlushPolicy::Cap(4), FlushPolicy::Fence] {
            assert_eq!(p.to_string().parse::<FlushPolicy>().unwrap(), p);
        }
        assert_eq!("EAGER".parse::<FlushPolicy>().unwrap(), FlushPolicy::Eager);
        assert_eq!("cap:1_024".parse::<FlushPolicy>().unwrap(), FlushPolicy::Cap(1024));
        assert!("cap:0".parse::<FlushPolicy>().is_err());
        assert!("cap:x".parse::<FlushPolicy>().is_err());
        assert!("cap".parse::<FlushPolicy>().is_err());
        assert!("batched".parse::<FlushPolicy>().is_err());
    }

    #[test]
    fn cap_one_normalizes_to_eager() {
        assert_eq!(FlushPolicy::Cap(1).normalized(), FlushPolicy::Eager);
        assert!(FlushPolicy::Cap(1).is_eager());
        assert!(FlushPolicy::Eager.is_eager());
        assert!(!FlushPolicy::Cap(2).is_eager());
        assert!(!FlushPolicy::Fence.is_eager());
        assert_eq!(FlushPolicy::Cap(2).normalized(), FlushPolicy::Cap(2));
    }

    #[test]
    fn mean_batch_convention() {
        assert_eq!(mean_batch(0, 0), 0.0);
        assert_eq!(mean_batch(64, 0), 0.0, "no doorbells: no factor");
        assert!((mean_batch(64, 64) - 1.0).abs() < 1e-9, "eager");
        assert!((mean_batch(64, 4) - 16.0).abs() < 1e-9);
    }

    #[test]
    fn batching_config_validates_cap() {
        assert!(BatchingConfig::default().validate().is_ok());
        assert!(BatchingConfig::new(FlushPolicy::Cap(0)).validate().is_err());
        assert!(BatchingConfig::new(FlushPolicy::Fence).validate().is_ok());
        assert_eq!(BatchingConfig::default().policy, FlushPolicy::Eager);
    }

    #[test]
    fn submit_queue_stages_fifo_and_take_resets() {
        let mut q = SubmitQueue::default();
        assert!(q.is_empty());
        // One logical line fanned out to two backups.
        q.push(wqe(0, 0));
        q.push(wqe(1, 0));
        q.note_line();
        q.push(wqe(0, 1));
        q.push(wqe(1, 1));
        q.note_line();
        assert_eq!(q.len(), 4);
        assert_eq!(q.lines(), 2);
        let drained = q.take();
        assert_eq!(drained.len(), 4);
        // FIFO: stage order preserved per thread.
        assert_eq!(drained[0], wqe(0, 0));
        assert_eq!(drained[3], wqe(1, 1));
        assert!(q.is_empty());
        assert_eq!(q.lines(), 0);
    }
}
