//! Persistent crit-bit tree over u64 keys — the `ctree` WHISPER workload
//! (originally released with NVML [25]).
//!
//! A crit-bit (PATRICIA) tree: internal nodes store the index of the most
//! significant bit on which their two subtrees differ; leaves store
//! (key, value). Lookup walks bit decisions; insert finds the critical bit
//! between the new key and the nearest existing key and splices an
//! internal node at the correct depth; delete splices a leaf's parent out.
//!
//! PM layout (one u64 field per line):
//!   * leaf:     [TAG_LEAF,  key,  value]              (3 lines)
//!   * internal: [TAG_INNER | bit, left, right]        (3 lines)
//!   * root pointer: one line in REGION_ROOTS.
//!
//! Every mutation runs inside an undo-log transaction.

use super::{PmHeap, REGION_ROOTS};
use crate::coordinator::{Mirror, ThreadCtx};
use crate::replication::TxnShape;
use crate::txn::Txn;
use crate::{Addr, LINE};

const TAG_LEAF: u64 = 0x4C00_0000_0000_0000;
const TAG_INNER: u64 = 0x4900_0000_0000_0000;
const TAG_MASK: u64 = 0xFF00_0000_0000_0000;

/// Persistent crit-bit tree handle.
#[derive(Clone, Debug)]
pub struct CritBitTree {
    root_ptr: Addr,
    /// Volatile size counter (rebuildable by walking the tree).
    len: u64,
}

impl CritBitTree {
    /// Create a tree whose root pointer lives in slot `root_slot` of the
    /// roots region.
    pub fn new(root_slot: u64) -> Self {
        CritBitTree {
            root_ptr: REGION_ROOTS + root_slot * LINE,
            len: 0,
        }
    }

    pub fn len(&self) -> u64 {
        self.len
    }
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn node_tag(m: &Mirror, node: Addr) -> u64 {
        m.peek(node) & TAG_MASK
    }
    fn inner_bit(m: &Mirror, node: Addr) -> u32 {
        (m.peek(node) & !TAG_MASK) as u32
    }

    /// Walk to the leaf that `key` would reach. Returns leaf address (0 if
    /// the tree is empty). Advances thread time for each node load.
    fn walk(&self, m: &mut Mirror, t: &mut ThreadCtx, key: u64) -> Addr {
        let mut node = m.load(t, self.root_ptr);
        while node != 0 && Self::node_tag(m, node) == TAG_INNER {
            let bit = Self::inner_bit(m, node);
            let side = (key >> bit) & 1;
            node = m.load(t, node + LINE * (1 + side));
        }
        node
    }

    /// Lookup: `Some(value)` if present.
    pub fn get(&self, m: &mut Mirror, t: &mut ThreadCtx, key: u64) -> Option<u64> {
        let leaf = self.walk(m, t, key);
        if leaf != 0 && m.load(t, leaf + LINE) == key {
            Some(m.load(t, leaf + 2 * LINE))
        } else {
            None
        }
    }

    /// Insert or update. Returns true if a new key was inserted.
    pub fn insert(
        &mut self,
        m: &mut Mirror,
        t: &mut ThreadCtx,
        heap: &mut PmHeap,
        key: u64,
        val: u64,
        log: Addr,
        hint: Option<TxnShape>,
    ) -> bool {
        self.insert_inner(m, t, heap, key, val, log, hint, None)
    }

    /// Insert with an optional detectable-op stamp: `Some((slot, seq))`
    /// appends one extra write to the mutation transaction setting
    /// `slot = seq`, so op completion is atomic with the commit (see
    /// [`super::detect`]). `None` is the plain path, event-for-event.
    /// Stamped inserts allocate bump-only ([`PmHeap::alloc_seq`]) so a
    /// replay from the checkpointed mark is address-deterministic.
    #[allow(clippy::too_many_arguments)]
    pub fn insert_inner(
        &mut self,
        m: &mut Mirror,
        t: &mut ThreadCtx,
        heap: &mut PmHeap,
        key: u64,
        val: u64,
        log: Addr,
        hint: Option<TxnShape>,
        stamp: Option<(Addr, u64)>,
    ) -> bool {
        let nearest = self.walk(m, t, key);
        if nearest == 0 {
            // Empty tree: install a leaf as root.
            let leaf = if stamp.is_some() {
                heap.alloc_seq(3)
            } else {
                heap.alloc(3)
            };
            let mut tx = Txn::begin(m, t, log, hint);
            tx.write(m, t, leaf, TAG_LEAF);
            tx.write(m, t, leaf + LINE, key);
            tx.write(m, t, leaf + 2 * LINE, val);
            tx.write(m, t, self.root_ptr, leaf);
            if let Some((slot, seq)) = stamp {
                tx.write(m, t, slot, seq);
            }
            tx.commit(m, t);
            self.len = 1;
            return true;
        }
        let nearest_key = m.load(t, nearest + LINE);
        if nearest_key == key {
            // Update in place.
            let mut tx = Txn::begin(m, t, log, hint);
            tx.write(m, t, nearest + 2 * LINE, val);
            if let Some((slot, seq)) = stamp {
                tx.write(m, t, slot, seq);
            }
            tx.commit(m, t);
            return false;
        }
        // Critical bit: most significant differing bit.
        let crit = 63 - (key ^ nearest_key).leading_zeros();
        let new_side = (key >> crit) & 1;

        // Find the insertion point: walk again until the next node's bit is
        // below the critical bit (bits decrease toward the leaves).
        let mut parent_slot = self.root_ptr; // slot holding the child ptr
        let mut node = m.load(t, self.root_ptr);
        while node != 0
            && Self::node_tag(m, node) == TAG_INNER
            && Self::inner_bit(m, node) > crit
        {
            let bit = Self::inner_bit(m, node);
            let side = (key >> bit) & 1;
            parent_slot = node + LINE * (1 + side);
            node = m.load(t, parent_slot);
        }

        let (leaf, inner) = if stamp.is_some() {
            (heap.alloc_seq(3), heap.alloc_seq(3))
        } else {
            (heap.alloc(3), heap.alloc(3))
        };
        let mut tx = Txn::begin(m, t, log, hint);
        tx.write(m, t, leaf, TAG_LEAF);
        tx.write(m, t, leaf + LINE, key);
        tx.write(m, t, leaf + 2 * LINE, val);
        tx.write(m, t, inner, TAG_INNER | crit as u64);
        let (l, r) = if new_side == 0 {
            (leaf, node)
        } else {
            (node, leaf)
        };
        tx.write(m, t, inner + LINE, l);
        tx.write(m, t, inner + 2 * LINE, r);
        tx.write(m, t, parent_slot, inner); // atomic splice-in
        if let Some((slot, seq)) = stamp {
            tx.write(m, t, slot, seq);
        }
        tx.commit(m, t);
        self.len += 1;
        true
    }

    /// Delete a key. Returns true if it was present.
    pub fn remove(
        &mut self,
        m: &mut Mirror,
        t: &mut ThreadCtx,
        heap: &mut PmHeap,
        key: u64,
        log: Addr,
        hint: Option<TxnShape>,
    ) -> bool {
        let root = m.load(t, self.root_ptr);
        if root == 0 {
            return false;
        }
        // Walk with grandparent tracking.
        let mut gp_slot: Addr = 0; // slot holding parent pointer
        let mut parent: Addr = 0; // internal node above the leaf
        let mut leaf_slot = self.root_ptr;
        let mut node = root;
        while Self::node_tag(m, node) == TAG_INNER {
            let bit = Self::inner_bit(m, node);
            let side = (key >> bit) & 1;
            gp_slot = leaf_slot;
            parent = node;
            leaf_slot = node + LINE * (1 + side);
            node = m.load(t, leaf_slot);
        }
        if m.load(t, node + LINE) != key {
            return false;
        }
        let mut tx = Txn::begin(m, t, log, hint);
        if parent == 0 {
            // Leaf was the root.
            tx.write(m, t, self.root_ptr, 0);
        } else {
            // Splice the sibling into the grandparent slot.
            let side = if leaf_slot == parent + LINE { 0u64 } else { 1 };
            let sibling = m.load(t, parent + LINE * (1 + (1 - side)));
            tx.write(m, t, gp_slot, sibling);
        }
        tx.commit(m, t);
        heap.free(node, 3);
        if parent != 0 {
            heap.free(parent, 3);
        }
        self.len -= 1;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Platform, StrategyKind};
    use crate::pstore::log_base_for;
    use crate::util::Pcg64;

    fn setup() -> (Mirror, ThreadCtx, PmHeap, CritBitTree) {
        (
            Mirror::new(Platform::default(), StrategyKind::NoSm, false),
            ThreadCtx::new(0),
            PmHeap::new(),
            CritBitTree::new(0),
        )
    }

    #[test]
    fn insert_get_roundtrip() {
        let (mut m, mut t, mut h, mut tree) = setup();
        let log = log_base_for(0);
        for k in [5u64, 1, 9, 1 << 40, 0] {
            assert!(tree.insert(&mut m, &mut t, &mut h, k, k * 10, log, None));
        }
        assert_eq!(tree.len(), 5);
        for k in [5u64, 1, 9, 1 << 40, 0] {
            assert_eq!(tree.get(&mut m, &mut t, k), Some(k * 10), "key {k}");
        }
        assert_eq!(tree.get(&mut m, &mut t, 777), None);
    }

    #[test]
    fn update_existing_key() {
        let (mut m, mut t, mut h, mut tree) = setup();
        let log = log_base_for(0);
        assert!(tree.insert(&mut m, &mut t, &mut h, 42, 1, log, None));
        assert!(!tree.insert(&mut m, &mut t, &mut h, 42, 2, log, None));
        assert_eq!(tree.get(&mut m, &mut t, 42), Some(2));
        assert_eq!(tree.len(), 1);
    }

    #[test]
    fn remove_keys() {
        let (mut m, mut t, mut h, mut tree) = setup();
        let log = log_base_for(0);
        for k in 0..20u64 {
            tree.insert(&mut m, &mut t, &mut h, k * 7, k, log, None);
        }
        for k in 0..10u64 {
            assert!(tree.remove(&mut m, &mut t, &mut h, k * 7, log, None));
        }
        assert!(!tree.remove(&mut m, &mut t, &mut h, 3, log, None));
        assert_eq!(tree.len(), 10);
        for k in 0..20u64 {
            let want = if k < 10 { None } else { Some(k) };
            assert_eq!(tree.get(&mut m, &mut t, k * 7), want, "key {}", k * 7);
        }
    }

    #[test]
    fn randomized_against_std_btreemap() {
        let (mut m, mut t, mut h, mut tree) = setup();
        let log = log_base_for(0);
        let mut oracle = std::collections::BTreeMap::new();
        let mut rng = Pcg64::new(1234);
        for _ in 0..500 {
            let k = rng.next_below(100);
            match rng.next_below(3) {
                0 | 1 => {
                    let v = rng.next_u64() | 1;
                    tree.insert(&mut m, &mut t, &mut h, k, v, log, None);
                    oracle.insert(k, v);
                }
                _ => {
                    let a = tree.remove(&mut m, &mut t, &mut h, k, log, None);
                    let b = oracle.remove(&k).is_some();
                    assert_eq!(a, b, "remove {k}");
                }
            }
            assert_eq!(tree.len(), oracle.len() as u64);
        }
        for (&k, &v) in &oracle {
            assert_eq!(tree.get(&mut m, &mut t, k), Some(v));
        }
    }

    #[test]
    fn mutations_produce_epochs_and_writes() {
        let (mut m, mut t, mut h, mut tree) = setup();
        let log = log_base_for(0);
        tree.insert(&mut m, &mut t, &mut h, 1, 1, log, None);
        let epochs_one = t.epochs_done;
        assert!(epochs_one >= 4, "expected multiple epochs, got {epochs_one}");
        tree.insert(&mut m, &mut t, &mut h, 2, 2, log, None);
        assert!(t.epochs_done > epochs_one);
        assert!(t.writes_done > 0);
    }
}
