//! Detectable (persistent lock-free) pstore operations.
//!
//! The serial pstore model assumed one global lock per structure: a
//! crash mid-operation left the structure to whoever replayed the undo
//! log. The concurrent-primary model instead makes each mutation a
//! *detectable operation* (memento-style): before mutating, the thread
//! persists a per-thread **checkpoint** describing the op (sequence
//! number, opcode, arguments, heap watermark), and the mutation
//! transaction's final write stamps `done = seq` — atomic with the
//! commit because it rides the same undo log. A recovering thread can
//! then always decide, from PM alone, whether its in-flight op
//! completed, and if not, replay it deterministically:
//!
//! 1. roll back the active undo log (if any) — this also restores the
//!    `done` stamp of a torn commit ([`rollback_in_image`]);
//! 2. read the checkpoint: `done == seq` means the op committed —
//!    nothing to do; otherwise re-execute the op from the checkpointed
//!    arguments.
//!
//! Two orderings make the decision sound:
//! * the checkpoint payload persists in an epoch **before** the `seq`
//!   publication line, so a persisted `seq` implies complete arguments
//!   (a crash before `seq` persists leaves the previous op's record —
//!   the new op never started, like a client request lost pre-ack);
//! * the `done` stamp is a transactional write, so it is visible iff
//!   the mutation committed.
//!
//! Replay determinism also needs address-deterministic allocation:
//! detectable ops allocate bump-only ([`super::PmHeap::alloc_seq`]) and
//! checkpoint the watermark, so a replay from [`super::PmHeap::at_mark`]
//! lands every node at the original address (free lists are volatile
//! and cannot survive a crash).
//!
//! Contention is modeled, not simulated: a detectable op charges
//! [`CAS_RETRY_NS`] of CPU per *other* contending thread, relieved
//! proportionally by the commit-pipeline count (more pipelines — fewer
//! threads colliding on any one structure's publish CAS).

use super::{ckpt_base_for, CritBitTree, KvStore, PHashMap, PmHeap};
use crate::coordinator::{Mirror, ThreadCtx};
use crate::txn::{rollback_plan, LOG_INVALID};
use crate::{Addr, Ns, LINE};
use std::collections::HashMap;

/// Checkpoint line offsets within a thread's area ([`ckpt_base_for`]).
const SLOT_SEQ: u64 = 0;
const SLOT_OPCODE: u64 = 1;
const SLOT_KEY: u64 = 2;
const SLOT_VAL: u64 = 3;
const SLOT_MARK: u64 = 4;
const SLOT_DONE: u64 = 5;
/// Batch payload starts here: pair `i` at lines `SLOT_ARGS + 2i` (key)
/// and `SLOT_ARGS + 2i + 1` (value).
const SLOT_ARGS: u64 = 6;

/// Operation codes recorded in the checkpoint.
pub const OP_TREE_INSERT: u64 = 1;
pub const OP_MAP_PUT: u64 = 2;
pub const OP_KV_BATCH: u64 = 3;

/// CPU cost of one failed publish-CAS retry (volatile work: reread +
/// recompute the splice). Charged per other contending thread.
pub const CAS_RETRY_NS: Ns = 18;

/// Per-thread detectable-operation context: owns the thread's
/// checkpoint area and sequence numbering.
#[derive(Clone, Debug)]
pub struct DetectCtx {
    base: Addr,
    seq: u64,
    /// Threads contending on the same structure (including this one);
    /// drives the CAS-retry contention charge.
    pub contenders: usize,
}

impl DetectCtx {
    pub fn new(thread: usize, contenders: usize) -> Self {
        Self::resume(thread, contenders, 0)
    }

    /// Rebuild a context after recovery: `completed_seq` is the highest
    /// sequence number the recovered checkpoint accounts for (a replay
    /// of op `S` resumes from `S - 1` so the re-announce reuses `S`).
    pub fn resume(thread: usize, contenders: usize, completed_seq: u64) -> Self {
        DetectCtx {
            base: ckpt_base_for(thread),
            seq: completed_seq,
            contenders: contenders.max(1),
        }
    }

    /// Line holding the completion stamp.
    pub fn done_slot(&self) -> Addr {
        self.base + SLOT_DONE * LINE
    }

    fn slot(&self, s: u64) -> Addr {
        self.base + s * LINE
    }

    /// Modeled CAS-retry burn for one op: every other contender costs
    /// one retry, relieved by the commit-pipeline fan-out.
    fn contention_ns(&self, m: &Mirror) -> Ns {
        CAS_RETRY_NS * (self.contenders as Ns - 1) / m.concurrency().commit_pipelines as Ns
    }

    /// Persist the op record. Payload epoch first, then the `seq`
    /// publication epoch — see the module docs for why this order is
    /// what makes the recovery decision sound. Returns the op's seq.
    fn announce(
        &mut self,
        m: &mut Mirror,
        t: &mut ThreadCtx,
        opcode: u64,
        key: u64,
        val: u64,
        mark: Addr,
        batch: &[(u64, u64)],
    ) -> u64 {
        for (s, v) in [
            (SLOT_OPCODE, opcode),
            (SLOT_KEY, key),
            (SLOT_VAL, val),
            (SLOT_MARK, mark),
        ] {
            m.store(t, self.slot(s), v);
            m.clwb(t, self.slot(s));
        }
        for (i, &(k, v)) in batch.iter().enumerate() {
            let ks = self.slot(SLOT_ARGS + 2 * i as u64);
            m.store(t, ks, k);
            m.clwb(t, ks);
            m.store(t, ks + LINE, v);
            m.clwb(t, ks + LINE);
        }
        m.sfence(t);
        self.seq += 1;
        m.store(t, self.slot(SLOT_SEQ), self.seq);
        m.clwb(t, self.slot(SLOT_SEQ));
        m.sfence(t);
        self.seq
    }
}

/// Detectable crit-bit insert (checkpoint + stamped transaction).
#[allow(clippy::too_many_arguments)]
pub fn tree_insert(
    tree: &mut CritBitTree,
    m: &mut Mirror,
    t: &mut ThreadCtx,
    heap: &mut PmHeap,
    ctx: &mut DetectCtx,
    key: u64,
    val: u64,
    log: Addr,
) -> bool {
    m.compute(t, ctx.contention_ns(m));
    let mark = heap.mark();
    let seq = ctx.announce(m, t, OP_TREE_INSERT, key, val, mark, &[]);
    tree.insert_inner(m, t, heap, key, val, log, None, Some((ctx.done_slot(), seq)))
}

/// Detectable hashmap put.
#[allow(clippy::too_many_arguments)]
pub fn map_put(
    map: &mut PHashMap,
    m: &mut Mirror,
    t: &mut ThreadCtx,
    heap: &mut PmHeap,
    ctx: &mut DetectCtx,
    key: u64,
    val: u64,
    log: Addr,
) -> bool {
    m.compute(t, ctx.contention_ns(m));
    let mark = heap.mark();
    let seq = ctx.announce(m, t, OP_MAP_PUT, key, val, mark, &[]);
    map.put_inner(m, t, heap, key, val, log, None, Some((ctx.done_slot(), seq)))
}

/// Detectable echo batch apply: the whole batch is the op payload, so
/// a replay re-applies exactly the checkpointed client updates.
pub fn kv_apply_batch(
    kv: &mut KvStore,
    m: &mut Mirror,
    t: &mut ThreadCtx,
    heap: &mut PmHeap,
    ctx: &mut DetectCtx,
    batch: &[(u64, u64)],
    log: Addr,
) {
    m.compute(t, ctx.contention_ns(m));
    let mark = heap.mark();
    let seq = ctx.announce(m, t, OP_KV_BATCH, batch.len() as u64, 0, mark, batch);
    kv.apply_batch_inner(m, t, heap, batch, log, Some((ctx.done_slot(), seq)))
}

/// A thread's checkpoint record as read from a (crash) image.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Checkpoint {
    pub seq: u64,
    pub opcode: u64,
    pub key: u64,
    pub val: u64,
    pub mark: Addr,
    pub done: u64,
    /// Batch payload (populated for [`OP_KV_BATCH`]; `key` is its len).
    pub batch: Vec<(u64, u64)>,
}

impl Checkpoint {
    /// True when the announced op did not complete: recovery must
    /// re-execute it from this record (after [`rollback_in_image`]).
    pub fn needs_replay(&self) -> bool {
        self.seq != 0 && self.done != self.seq
    }
}

/// Read `thread`'s checkpoint out of a reconstructed PM image.
pub fn read_checkpoint(image: &HashMap<Addr, u64>, thread: usize) -> Checkpoint {
    let base = ckpt_base_for(thread);
    let get = |s: u64| image.get(&(base + s * LINE)).copied().unwrap_or(0);
    let opcode = get(SLOT_OPCODE);
    let key = get(SLOT_KEY);
    let batch = if opcode == OP_KV_BATCH {
        (0..key)
            .map(|i| (get(SLOT_ARGS + 2 * i), get(SLOT_ARGS + 2 * i + 1)))
            .collect()
    } else {
        Vec::new()
    };
    Checkpoint {
        seq: get(SLOT_SEQ),
        opcode,
        key,
        val: get(SLOT_VAL),
        mark: get(SLOT_MARK),
        done: get(SLOT_DONE),
        batch,
    }
}

/// Undo an active transaction inside a crash image: restore the logged
/// old values newest-first and invalidate the log — the first recovery
/// step, run *before* reading the checkpoint so a torn commit's `done`
/// stamp is rolled back with the rest of the transaction. Returns the
/// number of restored writes (0 when the log was not active).
pub fn rollback_in_image(image: &mut HashMap<Addr, u64>, log_base: Addr) -> usize {
    let plan = rollback_plan(image, log_base);
    for &(addr, old) in &plan {
        image.insert(addr, old);
    }
    image.insert(log_base, LOG_INVALID);
    plan.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Platform, StrategyKind};
    use crate::coordinator::ConcurrencyConfig;
    use crate::pstore::log_base_for;

    fn mirror() -> Mirror {
        Mirror::new(Platform::default(), StrategyKind::NoSm, false)
    }

    #[test]
    fn completed_op_is_detectable_from_pm() {
        let mut m = mirror();
        let mut t = ThreadCtx::new(0);
        let mut h = PmHeap::new();
        let mut tree = CritBitTree::new(0);
        let mut ctx = DetectCtx::new(0, 1);
        let log = log_base_for(0);
        assert!(tree_insert(&mut tree, &mut m, &mut t, &mut h, &mut ctx, 7, 70, log));
        // Checkpoint and stamp are in PM: done == seq == 1.
        assert_eq!(m.peek(ckpt_base_for(0)), 1, "published seq");
        assert_eq!(m.peek(ctx.done_slot()), 1, "stamped done");
        assert_eq!(m.peek(ckpt_base_for(0) + SLOT_OPCODE * LINE), OP_TREE_INSERT);
        let mut t2 = ThreadCtx::new(0);
        assert_eq!(tree.get(&mut m, &mut t2, 7), Some(70));
        // A second op bumps both.
        assert!(!tree_insert(&mut tree, &mut m, &mut t, &mut h, &mut ctx, 7, 71, log));
        assert_eq!(m.peek(ckpt_base_for(0)), 2);
        assert_eq!(m.peek(ctx.done_slot()), 2);
    }

    #[test]
    fn checkpoint_roundtrips_through_an_image() {
        let mut m = mirror();
        let mut t = ThreadCtx::new(0);
        let mut h = PmHeap::new();
        let mut kv = KvStore::create(&mut h, 16, 0);
        let mut ctx = DetectCtx::new(0, 1);
        let log = log_base_for(0);
        let batch = [(1u64, 10u64), (2, 20)];
        kv_apply_batch(&mut kv, &mut m, &mut t, &mut h, &mut ctx, &batch, log);
        // Model "image" = primary PM contents.
        let img: HashMap<Addr, u64> =
            m.image().iter().map(|(&a, &v)| (a, v)).collect();
        let ck = read_checkpoint(&img, 0);
        assert_eq!(ck.seq, 1);
        assert_eq!(ck.opcode, OP_KV_BATCH);
        assert_eq!(ck.batch, vec![(1, 10), (2, 20)]);
        assert!(!ck.needs_replay(), "done stamp covers the batch");
    }

    #[test]
    fn rollback_undoes_a_torn_commit_stamp() {
        // Build an image where op 2's txn logged-and-stamped but never
        // invalidated its log: rollback must restore done = 1 and the
        // data write, flipping needs_replay on.
        use crate::txn::LOG_ACTIVE;
        let base = ckpt_base_for(0);
        let log = log_base_for(0);
        let data = 0x0100_0000_0040u64;
        let mut img: HashMap<Addr, u64> = HashMap::new();
        img.insert(base + SLOT_SEQ * LINE, 2);
        img.insert(base + SLOT_OPCODE * LINE, OP_MAP_PUT);
        img.insert(base + SLOT_DONE * LINE, 2); // torn: stamped...
        img.insert(log, LOG_ACTIVE | 2); // ...but log still active
        img.insert(log + LINE, data);
        img.insert(log + 2 * LINE, 5); // old data value
        img.insert(log + 3 * LINE, base + SLOT_DONE * LINE);
        img.insert(log + 4 * LINE, 1); // old done value
        img.insert(data, 6);
        assert_eq!(rollback_in_image(&mut img, log), 2);
        assert_eq!(img[&data], 5);
        assert_eq!(img[&log], LOG_INVALID);
        let ck = read_checkpoint(&img, 0);
        assert_eq!(ck.done, 1);
        assert!(ck.needs_replay(), "rolled-back op must be re-executed");
    }

    #[test]
    fn contention_burns_cpu_scaled_by_pipelines() {
        let cost = |contenders, pipelines| {
            let mut m = mirror();
            m.set_concurrency(ConcurrencyConfig::new(pipelines, 0));
            let mut t = ThreadCtx::new(0);
            let mut h = PmHeap::new();
            let mut tree = CritBitTree::new(0);
            let mut ctx = DetectCtx::new(0, contenders);
            tree_insert(&mut tree, &mut m, &mut t, &mut h, &mut ctx, 1, 1, log_base_for(0));
            t.clock.busy_ns
        };
        let solo = cost(1, 1);
        let contended = cost(4, 1);
        assert_eq!(contended - solo, 3 * CAS_RETRY_NS, "one retry per rival");
        let piped = cost(4, 4);
        assert!(piped < contended, "pipelines relieve publish contention");
        assert_eq!(piped, solo + 3 * CAS_RETRY_NS / 4);
    }
}
