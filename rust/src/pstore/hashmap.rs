//! Persistent chained hashmap — the `hashmap` WHISPER workload (NVML
//! heritage, like ctree).
//!
//! PM layout (one u64 field per line):
//!   * bucket array: `nbuckets` head-pointer lines (allocated contiguously)
//!   * node: [key, value, next] (3 lines)
//!
//! Collision chains are prepended (new node becomes the bucket head), so
//! an insert is a small transaction (node init + head swap) and a remove
//! splices `next` into the predecessor.

use super::PmHeap;
use crate::coordinator::{Mirror, ThreadCtx};
use crate::replication::TxnShape;
use crate::txn::Txn;
use crate::util::fnv1a_u64;
use crate::{Addr, LINE};

/// Persistent hashmap handle.
#[derive(Clone, Debug)]
pub struct PHashMap {
    buckets: Addr,
    nbuckets: u64,
    len: u64,
}

impl PHashMap {
    /// Allocate the bucket array from `heap` (power-of-two `nbuckets`).
    pub fn create(heap: &mut PmHeap, nbuckets: u64) -> Self {
        assert!(nbuckets.is_power_of_two());
        let buckets = heap.alloc(nbuckets as usize);
        PHashMap {
            buckets,
            nbuckets,
            len: 0,
        }
    }

    pub fn len(&self) -> u64 {
        self.len
    }
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn bucket_slot(&self, key: u64) -> Addr {
        self.buckets + (fnv1a_u64(key) & (self.nbuckets - 1)) * LINE
    }

    /// Bucket slot address (exposed for composite stores like
    /// [`crate::pstore::KvStore`] that inline puts into larger txns).
    pub fn bucket_slot_pub(&self, key: u64) -> Addr {
        self.bucket_slot(key)
    }

    /// Bump the length counter (composite-store insert path).
    pub fn len_inc(&mut self) {
        self.len += 1;
    }

    /// Find `(pred_slot, node)` for a key: `pred_slot` is the line holding
    /// the pointer to `node` (bucket head or predecessor's next field).
    fn find(&self, m: &mut Mirror, t: &mut ThreadCtx, key: u64) -> (Addr, Addr) {
        let mut slot = self.bucket_slot(key);
        let mut node = m.load(t, slot);
        while node != 0 {
            if m.load(t, node) == key {
                return (slot, node);
            }
            slot = node + 2 * LINE;
            node = m.load(t, slot);
        }
        (slot, 0)
    }

    /// Lookup.
    pub fn get(&self, m: &mut Mirror, t: &mut ThreadCtx, key: u64) -> Option<u64> {
        let (_, node) = self.find(m, t, key);
        if node != 0 {
            Some(m.load(t, node + LINE))
        } else {
            None
        }
    }

    /// Insert or update; returns true on fresh insert.
    pub fn put(
        &mut self,
        m: &mut Mirror,
        t: &mut ThreadCtx,
        heap: &mut PmHeap,
        key: u64,
        val: u64,
        log: Addr,
        hint: Option<TxnShape>,
    ) -> bool {
        self.put_inner(m, t, heap, key, val, log, hint, None)
    }

    /// Put with an optional detectable-op stamp: `Some((slot, seq))`
    /// appends one extra write to the mutation transaction setting
    /// `slot = seq`, so op completion is atomic with the commit (see
    /// [`super::detect`]). `None` is the plain path, event-for-event.
    #[allow(clippy::too_many_arguments)]
    pub fn put_inner(
        &mut self,
        m: &mut Mirror,
        t: &mut ThreadCtx,
        heap: &mut PmHeap,
        key: u64,
        val: u64,
        log: Addr,
        hint: Option<TxnShape>,
        stamp: Option<(Addr, u64)>,
    ) -> bool {
        let (_, node) = self.find(m, t, key);
        if node != 0 {
            let mut tx = Txn::begin(m, t, log, hint);
            tx.write(m, t, node + LINE, val);
            if let Some((slot, seq)) = stamp {
                tx.write(m, t, slot, seq);
            }
            tx.commit(m, t);
            return false;
        }
        let head_slot = self.bucket_slot(key);
        let head = m.load(t, head_slot);
        let new = if stamp.is_some() {
            heap.alloc_seq(3)
        } else {
            heap.alloc(3)
        };
        let mut tx = Txn::begin(m, t, log, hint);
        tx.write(m, t, new, key);
        tx.write(m, t, new + LINE, val);
        tx.write(m, t, new + 2 * LINE, head);
        tx.write(m, t, head_slot, new); // atomic publish
        if let Some((slot, seq)) = stamp {
            tx.write(m, t, slot, seq);
        }
        tx.commit(m, t);
        self.len += 1;
        true
    }

    /// Remove; returns true if the key was present.
    pub fn remove(
        &mut self,
        m: &mut Mirror,
        t: &mut ThreadCtx,
        heap: &mut PmHeap,
        key: u64,
        log: Addr,
        hint: Option<TxnShape>,
    ) -> bool {
        let (pred_slot, node) = self.find(m, t, key);
        if node == 0 {
            return false;
        }
        let next = m.load(t, node + 2 * LINE);
        let mut tx = Txn::begin(m, t, log, hint);
        tx.write(m, t, pred_slot, next);
        tx.commit(m, t);
        heap.free(node, 3);
        self.len -= 1;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Platform, StrategyKind};
    use crate::pstore::log_base_for;
    use crate::util::Pcg64;

    fn setup() -> (Mirror, ThreadCtx, PmHeap, PHashMap) {
        let mut heap = PmHeap::new();
        let map = PHashMap::create(&mut heap, 64);
        (
            Mirror::new(Platform::default(), StrategyKind::NoSm, false),
            ThreadCtx::new(0),
            heap,
            map,
        )
    }

    #[test]
    fn put_get_remove() {
        let (mut m, mut t, mut h, mut map) = setup();
        let log = log_base_for(0);
        assert!(map.put(&mut m, &mut t, &mut h, 1, 10, log, None));
        assert!(map.put(&mut m, &mut t, &mut h, 2, 20, log, None));
        assert!(!map.put(&mut m, &mut t, &mut h, 1, 11, log, None));
        assert_eq!(map.get(&mut m, &mut t, 1), Some(11));
        assert_eq!(map.get(&mut m, &mut t, 2), Some(20));
        assert_eq!(map.get(&mut m, &mut t, 3), None);
        assert!(map.remove(&mut m, &mut t, &mut h, 1, log, None));
        assert!(!map.remove(&mut m, &mut t, &mut h, 1, log, None));
        assert_eq!(map.get(&mut m, &mut t, 1), None);
        assert_eq!(map.len(), 1);
    }

    #[test]
    fn chains_survive_collisions() {
        // 64 buckets, 500 keys: heavy chaining.
        let (mut m, mut t, mut h, mut map) = setup();
        let log = log_base_for(0);
        for k in 0..500u64 {
            map.put(&mut m, &mut t, &mut h, k, k + 1000, log, None);
        }
        assert_eq!(map.len(), 500);
        for k in 0..500u64 {
            assert_eq!(map.get(&mut m, &mut t, k), Some(k + 1000), "key {k}");
        }
        // Remove every third key from the middles of chains.
        for k in (0..500u64).step_by(3) {
            assert!(map.remove(&mut m, &mut t, &mut h, k, log, None));
        }
        for k in 0..500u64 {
            let want = if k % 3 == 0 { None } else { Some(k + 1000) };
            assert_eq!(map.get(&mut m, &mut t, k), want, "key {k}");
        }
    }

    #[test]
    fn randomized_against_std_hashmap() {
        let (mut m, mut t, mut h, mut map) = setup();
        let log = log_base_for(0);
        let mut oracle = std::collections::HashMap::new();
        let mut rng = Pcg64::new(99);
        for _ in 0..1000 {
            let k = rng.next_below(200);
            if rng.chance(0.6) {
                let v = rng.next_u64() | 1;
                map.put(&mut m, &mut t, &mut h, k, v, log, None);
                oracle.insert(k, v);
            } else {
                assert_eq!(
                    map.remove(&mut m, &mut t, &mut h, k, log, None),
                    oracle.remove(&k).is_some()
                );
            }
        }
        assert_eq!(map.len(), oracle.len() as u64);
        for (&k, &v) in &oracle {
            assert_eq!(map.get(&mut m, &mut t, k), Some(v));
        }
    }
}
