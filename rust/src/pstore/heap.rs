//! Line-granular bump allocator over the simulated PM space.
//!
//! Allocation metadata is volatile (rebuilt on recovery by rescanning
//! structures — a persistent heap à la libpmemobj is orthogonal to the
//! replication questions studied here; documented as a substitution in
//! DESIGN.md). A small free list supports the delete-heavy WHISPER
//! workloads.

use super::REGION_HEAP;
use crate::{Addr, LINE};

/// Bump + free-list allocator handing out line-aligned PM blocks.
#[derive(Clone, Debug)]
pub struct PmHeap {
    next: Addr,
    end: Addr,
    /// Free lists bucketed by block size in lines (1..=8).
    free: Vec<Vec<Addr>>,
    pub allocated_lines: u64,
    pub freed_lines: u64,
}

impl Default for PmHeap {
    fn default() -> Self {
        Self::new()
    }
}

impl PmHeap {
    pub fn new() -> Self {
        PmHeap {
            next: REGION_HEAP,
            end: REGION_HEAP + 0x0100_0000_0000,
            free: vec![Vec::new(); 9],
            allocated_lines: 0,
            freed_lines: 0,
        }
    }

    /// Rebuild a heap at a recorded bump watermark — the post-crash
    /// rescan model: the bump pointer is recovered from a detectable-op
    /// checkpoint ([`super::detect`]) and the volatile free lists start
    /// empty, so a replayed op re-allocates at the same addresses.
    pub fn at_mark(mark: Addr) -> Self {
        let mut h = Self::new();
        assert!(mark >= h.next && mark <= h.end, "mark outside the heap");
        h.next = mark;
        h
    }

    /// Current bump watermark (detectable-op checkpoints persist this).
    pub fn mark(&self) -> Addr {
        self.next
    }

    /// Allocate `lines` consecutive cache lines; returns the base address.
    pub fn alloc(&mut self, lines: usize) -> Addr {
        assert!(lines > 0);
        self.allocated_lines += lines as u64;
        if lines < self.free.len() {
            if let Some(a) = self.free[lines].pop() {
                return a;
            }
        }
        self.bump(lines)
    }

    /// Bump-only allocation: skips free-list reuse so the address
    /// depends only on the watermark. Detectable ops allocate through
    /// this — replaying a crashed op from its checkpointed mark then
    /// lands every node at the original address (free lists are
    /// volatile, so their contents cannot survive into a replay).
    pub fn alloc_seq(&mut self, lines: usize) -> Addr {
        assert!(lines > 0);
        self.allocated_lines += lines as u64;
        self.bump(lines)
    }

    fn bump(&mut self, lines: usize) -> Addr {
        let a = self.next;
        self.next += (lines as Addr) * LINE;
        assert!(self.next <= self.end, "PM heap exhausted");
        a
    }

    /// Return a block of `lines` lines to the allocator.
    pub fn free(&mut self, addr: Addr, lines: usize) {
        self.freed_lines += lines as u64;
        if lines < self.free.len() {
            self.free[lines].push(addr);
        }
        // Larger blocks are leaked (never produced by current structures).
    }

    /// Lines currently live.
    pub fn live_lines(&self) -> u64 {
        self.allocated_lines - self.freed_lines
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocations_are_disjoint_and_aligned() {
        let mut h = PmHeap::new();
        let a = h.alloc(2);
        let b = h.alloc(3);
        assert_eq!(a % LINE, 0);
        assert_eq!(b % LINE, 0);
        assert!(b >= a + 2 * LINE);
    }

    #[test]
    fn free_list_reuses_blocks() {
        let mut h = PmHeap::new();
        let a = h.alloc(2);
        h.free(a, 2);
        let b = h.alloc(2);
        assert_eq!(a, b);
        assert_eq!(h.live_lines(), 2);
    }

    #[test]
    fn different_sizes_do_not_mix() {
        let mut h = PmHeap::new();
        let a = h.alloc(2);
        h.free(a, 2);
        let b = h.alloc(3);
        assert_ne!(a, b);
    }
}
