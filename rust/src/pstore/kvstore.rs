//! Persistent key-value store — the `echo` WHISPER workload.
//!
//! Echo mimics a civet/scribe-style KV store: a master applies *batches*
//! of client updates as single storage transactions (which is why echo
//! exhibits the largest epochs-per-transaction in WHISPER — hundreds),
//! with a persistent per-store generation counter advanced per batch.
//!
//! Built on [`PHashMap`] for the keyspace plus a dedicated batch-apply
//! path that folds many puts into ONE undo transaction.

use super::{PHashMap, PmHeap, REGION_ROOTS};
use crate::coordinator::{Mirror, ThreadCtx};
use crate::replication::TxnShape;
use crate::txn::Txn;
use crate::{Addr, LINE};

/// Echo-style KV store.
#[derive(Clone, Debug)]
pub struct KvStore {
    map: PHashMap,
    /// Persistent generation counter (one line).
    gen_addr: Addr,
    pub batches_applied: u64,
}

impl KvStore {
    pub fn create(heap: &mut PmHeap, nbuckets: u64, root_slot: u64) -> Self {
        KvStore {
            map: PHashMap::create(heap, nbuckets),
            gen_addr: REGION_ROOTS + (1000 + root_slot) * LINE,
            batches_applied: 0,
        }
    }

    pub fn get(&self, m: &mut Mirror, t: &mut ThreadCtx, key: u64) -> Option<u64> {
        self.map.get(m, t, key)
    }

    pub fn len(&self) -> u64 {
        self.map.len()
    }
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Apply a batch of puts as ONE transaction (the echo master path).
    /// Existing keys are updated in place; new keys get fresh nodes whose
    /// publication rides the same undo log.
    pub fn apply_batch(
        &mut self,
        m: &mut Mirror,
        t: &mut ThreadCtx,
        heap: &mut PmHeap,
        batch: &[(u64, u64)],
        log: Addr,
    ) {
        self.apply_batch_inner(m, t, heap, batch, log, None)
    }

    /// Batch apply with an optional detectable-op stamp: `Some((slot,
    /// seq))` appends one extra write to the batch transaction setting
    /// `slot = seq`, so batch completion is atomic with the commit (see
    /// [`super::detect`]). `None` is the plain path, event-for-event.
    pub fn apply_batch_inner(
        &mut self,
        m: &mut Mirror,
        t: &mut ThreadCtx,
        heap: &mut PmHeap,
        batch: &[(u64, u64)],
        log: Addr,
        stamp: Option<(Addr, u64)>,
    ) {
        // Shape hint: each put is ~2 epochs (log+mutate), + generation.
        let hint = TxnShape {
            epochs: (batch.len() as f32) * 2.0 + 3.0,
            writes: 1.2,
        };
        let mut tx = Txn::begin(m, t, log, Some(hint));
        for &(key, val) in batch {
            // Inline the hashmap put inside the shared transaction.
            let (_, node) = self.map_find(m, t, key);
            if node != 0 {
                tx.write(m, t, node + LINE, val);
            } else {
                let head_slot = self.map_bucket_slot(key);
                let head = m.load(t, head_slot);
                let new = if stamp.is_some() {
                    heap.alloc_seq(3)
                } else {
                    heap.alloc(3)
                };
                tx.write(m, t, new, key);
                tx.write(m, t, new + LINE, val);
                tx.write(m, t, new + 2 * LINE, head);
                tx.write(m, t, head_slot, new);
                self.map_len_inc();
            }
        }
        let gen = m.peek(self.gen_addr);
        tx.write(m, t, self.gen_addr, gen + 1);
        if let Some((slot, seq)) = stamp {
            tx.write(m, t, slot, seq);
        }
        tx.commit(m, t);
        self.batches_applied += 1;
    }

    // --- thin accessors into the inner map (find/bucket reuse) -----------
    fn map_find(&self, m: &mut Mirror, t: &mut ThreadCtx, key: u64) -> (Addr, Addr) {
        // Reimplemented here because PHashMap::find is private; identical
        // walk cost.
        let mut slot = self.map_bucket_slot(key);
        let mut node = m.load(t, slot);
        while node != 0 {
            if m.load(t, node) == key {
                return (slot, node);
            }
            slot = node + 2 * LINE;
            node = m.load(t, slot);
        }
        (slot, 0)
    }
    fn map_bucket_slot(&self, key: u64) -> Addr {
        self.map.bucket_slot_pub(key)
    }
    fn map_len_inc(&mut self) {
        self.map.len_inc();
    }

    /// Persistent generation counter value.
    pub fn generation(&self, m: &Mirror) -> u64 {
        m.peek(self.gen_addr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Platform, StrategyKind};
    use crate::pstore::log_base_for;

    fn setup() -> (Mirror, ThreadCtx, PmHeap, KvStore) {
        let mut heap = PmHeap::new();
        let kv = KvStore::create(&mut heap, 128, 0);
        (
            Mirror::new(Platform::default(), StrategyKind::NoSm, false),
            ThreadCtx::new(0),
            heap,
            kv,
        )
    }

    #[test]
    fn batch_apply_and_get() {
        let (mut m, mut t, mut h, mut kv) = setup();
        let log = log_base_for(0);
        let batch: Vec<(u64, u64)> = (0..50).map(|k| (k, k * 2)).collect();
        kv.apply_batch(&mut m, &mut t, &mut h, &batch, log);
        assert_eq!(kv.len(), 50);
        for k in 0..50u64 {
            assert_eq!(kv.get(&mut m, &mut t, k), Some(k * 2));
        }
        assert_eq!(kv.generation(&m), 1);
        assert_eq!(t.txns_done, 1, "a batch is ONE transaction");
    }

    #[test]
    fn batches_update_existing_keys() {
        let (mut m, mut t, mut h, mut kv) = setup();
        let log = log_base_for(0);
        kv.apply_batch(&mut m, &mut t, &mut h, &[(1, 10), (2, 20)], log);
        kv.apply_batch(&mut m, &mut t, &mut h, &[(1, 11), (3, 30)], log);
        assert_eq!(kv.get(&mut m, &mut t, 1), Some(11));
        assert_eq!(kv.get(&mut m, &mut t, 2), Some(20));
        assert_eq!(kv.get(&mut m, &mut t, 3), Some(30));
        assert_eq!(kv.len(), 3);
        assert_eq!(kv.generation(&m), 2);
    }

    #[test]
    fn echo_profile_has_many_epochs_per_txn() {
        let (mut m, mut t, mut h, mut kv) = setup();
        let log = log_base_for(0);
        let batch: Vec<(u64, u64)> = (0..100).map(|k| (k, k)).collect();
        kv.apply_batch(&mut m, &mut t, &mut h, &batch, log);
        let epochs_per_txn = t.epochs_done as f64 / t.txns_done as f64;
        assert!(
            epochs_per_txn > 150.0,
            "echo should exhibit hundreds of epochs/txn, got {epochs_per_txn}"
        );
    }
}
