//! Persistent data structures — the substrate behind the WHISPER-like
//! application suite (paper §7.2).
//!
//! All structures live in the simulated PM address space and perform every
//! mutation through undo-log transactions ([`crate::txn::Txn`]) over the
//! persistency-model API of [`crate::coordinator::Mirror`] — so the traces
//! they generate (writes/epoch, epochs/txn, persist fraction) are produced
//! by *real* data-structure algorithms, not synthetic replay.
//!
//! Layout convention: every logical field occupies one 64-byte line and
//! holds one u64 word (see DESIGN.md §4 — the simulator models line-
//! granular persistence, which is what the paper's clwb-level analysis
//! observes).

pub mod cbtree;
pub mod detect;
pub mod hashmap;
pub mod heap;
pub mod kvstore;
pub mod nstore;

pub use cbtree::CritBitTree;
pub use detect::DetectCtx;
pub use hashmap::PHashMap;
pub use heap::PmHeap;
pub use kvstore::KvStore;
pub use nstore::NStore;

use crate::Addr;

/// PM address-space layout (per-region bases; regions never overlap for
/// the workload sizes used — asserted by the heap).
pub const REGION_HEAP: Addr = 0x0100_0000_0000;
pub const REGION_LOGS: Addr = 0x0200_0000_0000;
pub const REGION_ROOTS: Addr = 0x0300_0000_0000;
/// Per-thread detectable-operation checkpoints (see [`detect`]).
pub const REGION_CKPT: Addr = 0x0400_0000_0000;

/// Per-thread undo-log base (disjoint 1 MiB log areas).
pub fn log_base_for(thread: usize) -> Addr {
    REGION_LOGS + (thread as Addr) * 0x10_0000
}

/// Per-thread detectable-op checkpoint base (disjoint 1 MiB areas).
pub fn ckpt_base_for(thread: usize) -> Addr {
    REGION_CKPT + (thread as Addr) * 0x10_0000
}
