//! Mini N-store: a relational storage engine for persistent memory — the
//! substrate of the YCSB and TPCC WHISPER workloads (paper §7.2: "two
//! transaction processing workloads operating over N-store, a relational
//! DBMS designed from scratch for persistent memories").
//!
//! Model: fixed-schema tables of u64 tuples. Rows live in PM (one line per
//! field); primary-key indexes are volatile (N-store's opt-NVM variant
//! rebuilds indexes on recovery) and map key -> row base address. All row
//! mutations run under the caller's undo transaction so multi-row business
//! transactions (TPCC new-order) are failure-atomic end to end.

use super::PmHeap;
use crate::coordinator::{Mirror, ThreadCtx};
use crate::txn::Txn;
use crate::{Addr, LINE};
use std::collections::HashMap;

/// A table handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TableId(pub usize);

#[derive(Clone, Debug)]
struct Table {
    name: String,
    fields: usize,
    index: HashMap<u64, Addr>,
}

/// Mini relational store.
#[derive(Clone, Debug, Default)]
pub struct NStore {
    tables: Vec<Table>,
}

impl NStore {
    pub fn new() -> Self {
        NStore { tables: Vec::new() }
    }

    /// Create a table with `fields` u64 columns (column 0 is the key).
    pub fn create_table(&mut self, name: &str, fields: usize) -> TableId {
        assert!(fields >= 1);
        self.tables.push(Table {
            name: name.to_string(),
            fields,
            index: HashMap::new(),
        });
        TableId(self.tables.len() - 1)
    }

    pub fn table_name(&self, t: TableId) -> &str {
        &self.tables[t.0].name
    }
    pub fn rows(&self, t: TableId) -> usize {
        self.tables[t.0].index.len()
    }

    /// Insert a full row inside transaction `tx`. Panics on duplicate key.
    #[allow(clippy::too_many_arguments)]
    pub fn insert(
        &mut self,
        m: &mut Mirror,
        t: &mut ThreadCtx,
        tx: &mut Txn,
        heap: &mut PmHeap,
        table: TableId,
        row: &[u64],
    ) -> Addr {
        let tb = &mut self.tables[table.0];
        assert_eq!(row.len(), tb.fields, "schema mismatch for {}", tb.name);
        let key = row[0];
        assert!(
            !tb.index.contains_key(&key),
            "duplicate key {key} in {}",
            tb.name
        );
        let base = heap.alloc(tb.fields);
        for (i, &v) in row.iter().enumerate() {
            tx.write(m, t, base + (i as Addr) * LINE, v);
        }
        tb.index.insert(key, base);
        base
    }

    /// Point lookup of one field (loads walk the simulated memory).
    pub fn select(
        &self,
        m: &mut Mirror,
        t: &mut ThreadCtx,
        table: TableId,
        key: u64,
        field: usize,
    ) -> Option<u64> {
        let tb = &self.tables[table.0];
        debug_assert!(field < tb.fields);
        tb.index
            .get(&key)
            .map(|&base| m.load(t, base + (field as Addr) * LINE))
    }

    /// Update one field of a row inside transaction `tx`.
    #[allow(clippy::too_many_arguments)]
    pub fn update(
        &mut self,
        m: &mut Mirror,
        t: &mut ThreadCtx,
        tx: &mut Txn,
        table: TableId,
        key: u64,
        field: usize,
        val: u64,
    ) -> bool {
        let tb = &self.tables[table.0];
        debug_assert!(field < tb.fields);
        match tb.index.get(&key) {
            Some(&base) => {
                tx.write(m, t, base + (field as Addr) * LINE, val);
                true
            }
            None => false,
        }
    }

    /// Delete a row inside transaction `tx` (tombstone the key field; the
    /// index entry is dropped; space is reclaimed).
    pub fn delete(
        &mut self,
        m: &mut Mirror,
        t: &mut ThreadCtx,
        tx: &mut Txn,
        heap: &mut PmHeap,
        table: TableId,
        key: u64,
    ) -> bool {
        let tb = &mut self.tables[table.0];
        match tb.index.remove(&key) {
            Some(base) => {
                tx.write(m, t, base, u64::MAX); // tombstone
                heap.free(base, tb.fields);
                true
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Platform, StrategyKind};
    use crate::pstore::log_base_for;

    fn setup() -> (Mirror, ThreadCtx, PmHeap, NStore) {
        (
            Mirror::new(Platform::default(), StrategyKind::NoSm, false),
            ThreadCtx::new(0),
            PmHeap::new(),
            NStore::new(),
        )
    }

    #[test]
    fn insert_select_update() {
        let (mut m, mut t, mut h, mut db) = setup();
        let log = log_base_for(0);
        let users = db.create_table("users", 3);

        let mut tx = Txn::begin(&mut m, &mut t, log, None);
        db.insert(&mut m, &mut t, &mut tx, &mut h, users, &[1, 100, 200]);
        db.insert(&mut m, &mut t, &mut tx, &mut h, users, &[2, 101, 201]);
        tx.commit(&mut m, &mut t);

        assert_eq!(db.select(&mut m, &mut t, users, 1, 1), Some(100));
        assert_eq!(db.select(&mut m, &mut t, users, 2, 2), Some(201));
        assert_eq!(db.select(&mut m, &mut t, users, 9, 0), None);

        let mut tx = Txn::begin(&mut m, &mut t, log, None);
        assert!(db.update(&mut m, &mut t, &mut tx, users, 1, 1, 999));
        tx.commit(&mut m, &mut t);
        assert_eq!(db.select(&mut m, &mut t, users, 1, 1), Some(999));
        assert_eq!(db.rows(users), 2);
    }

    #[test]
    fn delete_removes_row() {
        let (mut m, mut t, mut h, mut db) = setup();
        let log = log_base_for(0);
        let tb = db.create_table("t", 2);
        let mut tx = Txn::begin(&mut m, &mut t, log, None);
        db.insert(&mut m, &mut t, &mut tx, &mut h, tb, &[7, 70]);
        tx.commit(&mut m, &mut t);

        let mut tx = Txn::begin(&mut m, &mut t, log, None);
        assert!(db.delete(&mut m, &mut t, &mut tx, &mut h, tb, 7));
        assert!(!db.delete(&mut m, &mut t, &mut tx, &mut h, tb, 7));
        tx.commit(&mut m, &mut t);
        assert_eq!(db.select(&mut m, &mut t, tb, 7, 1), None);
        assert_eq!(db.rows(tb), 0);
    }

    #[test]
    #[should_panic(expected = "duplicate key")]
    fn duplicate_keys_rejected() {
        let (mut m, mut t, mut h, mut db) = setup();
        let log = log_base_for(0);
        let tb = db.create_table("t", 2);
        let mut tx = Txn::begin(&mut m, &mut t, log, None);
        db.insert(&mut m, &mut t, &mut tx, &mut h, tb, &[1, 1]);
        db.insert(&mut m, &mut t, &mut tx, &mut h, tb, &[1, 2]);
        tx.commit(&mut m, &mut t);
    }

    #[test]
    fn multi_row_txn_is_one_transaction() {
        let (mut m, mut t, mut h, mut db) = setup();
        let log = log_base_for(0);
        let tb = db.create_table("orders", 8);
        let mut tx = Txn::begin(&mut m, &mut t, log, None);
        for k in 0..5u64 {
            let row: Vec<u64> = (0..8).map(|f| k * 10 + f).collect();
            db.insert(&mut m, &mut t, &mut tx, &mut h, tb, &row);
        }
        tx.commit(&mut m, &mut t);
        assert_eq!(t.txns_done, 1);
        assert_eq!(db.rows(tb), 5);
        // 5 rows x 8 fields x 2 epochs + commit.
        assert!(t.epochs_done >= 80, "epochs {}", t.epochs_done);
    }
}
