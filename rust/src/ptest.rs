//! Mini property-testing harness (the offline registry has no `proptest`).
//!
//! Provides seeded random-case generation with failure shrinking over u64
//! tuples: on a failing case, each coordinate is independently bisected
//! toward its minimum to report a small counterexample. Used by the
//! coordinator/recovery invariant tests.
//!
//! ```no_run
//! use pmsm::ptest::{Gen, check};
//! check("addition commutes", 200, |g: &mut Gen| {
//!     let a = g.u64(0, 1000);
//!     let b = g.u64(0, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::Pcg64;

/// Per-case value generator; records drawn values for shrinking.
pub struct Gen {
    rng: Pcg64,
    /// (lo, hi, drawn) per draw site, in draw order.
    trace: Vec<(u64, u64, u64)>,
    /// When replaying a shrunk candidate: forced values per draw index.
    forced: Vec<Option<u64>>,
    cursor: usize,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Gen {
            rng: Pcg64::new(seed),
            trace: Vec::new(),
            forced: Vec::new(),
            cursor: 0,
        }
    }

    fn with_forced(seed: u64, forced: Vec<Option<u64>>) -> Self {
        Gen {
            rng: Pcg64::new(seed),
            trace: Vec::new(),
            forced,
            cursor: 0,
        }
    }

    /// Uniform u64 in `[lo, hi]` (inclusive).
    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi >= lo);
        let raw = if hi == lo {
            lo
        } else {
            lo + self.rng.next_below(hi - lo + 1)
        };
        let v = match self.forced.get(self.cursor).copied().flatten() {
            Some(f) => f.clamp(lo, hi),
            None => raw,
        };
        self.trace.push((lo, hi, v));
        self.cursor += 1;
        v
    }

    /// Uniform usize in `[lo, hi]`.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.u64(lo as u64, hi as u64) as usize
    }

    /// Bernoulli draw.
    pub fn bool(&mut self) -> bool {
        self.u64(0, 1) == 1
    }

    /// Pick an element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.usize(0, xs.len() - 1)]
    }
}

/// Outcome of one property run.
struct CaseResult {
    panicked: bool,
}

fn run_case<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    f: &F,
    seed: u64,
    forced: Vec<Option<u64>>,
) -> CaseResult {
    let result = std::panic::catch_unwind(|| {
        let mut g = Gen::with_forced(seed, forced);
        f(&mut g);
        g.trace
    });
    match result {
        Ok(_trace) => CaseResult { panicked: false },
        Err(_) => CaseResult { panicked: true },
    }
}

/// Run `cases` random cases of property `f`; on failure, shrink and panic
/// with the minimal trace found. Deterministic per (name, case index).
pub fn check<F>(name: &str, cases: u64, f: F)
where
    F: Fn(&mut Gen) + std::panic::RefUnwindSafe,
{
    let base = crate::util::fnv1a(name.as_bytes());
    for i in 0..cases {
        let seed = base.wrapping_add(i);
        // First pass records the trace (un-forced).
        let probe = {
            let mut g = Gen::new(seed);
            // Capture the trace even on panic by re-running below.
            let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                f(&mut g);
            }))
            .is_ok();
            (ok, g.trace)
        };
        if probe.0 {
            continue;
        }
        // Failure: shrink each drawn value toward its lower bound.
        let mut forced: Vec<Option<u64>> = probe.1.iter().map(|&(_, _, v)| Some(v)).collect();
        let bounds: Vec<(u64, u64)> = probe.1.iter().map(|&(lo, hi, _)| (lo, hi)).collect();
        let mut changed = true;
        while changed {
            changed = false;
            for k in 0..forced.len() {
                let (lo, _hi) = bounds[k];
                let cur = forced[k].unwrap_or(lo);
                if cur == lo {
                    continue;
                }
                // Bisect toward lo while still failing.
                let mut hi_fail = cur;
                let mut lo_pass = lo;
                // Try the minimum outright first.
                let mut cand = forced.clone();
                cand[k] = Some(lo);
                if run_case(&f, seed, cand).panicked {
                    forced[k] = Some(lo);
                    changed = true;
                    continue;
                }
                while hi_fail - lo_pass > 1 {
                    let mid = lo_pass + (hi_fail - lo_pass) / 2;
                    let mut cand = forced.clone();
                    cand[k] = Some(mid);
                    if run_case(&f, seed, cand).panicked {
                        hi_fail = mid;
                    } else {
                        lo_pass = mid;
                    }
                }
                if hi_fail != cur {
                    forced[k] = Some(hi_fail);
                    changed = true;
                }
            }
        }
        let shrunk = run_case(&f, seed, forced.clone());
        let vals: Vec<u64> = if shrunk.panicked {
            forced.iter().map(|v| v.unwrap_or(0)).collect()
        } else {
            probe.1.iter().map(|&(_, _, v)| v).collect()
        };
        panic!(
            "property {name:?} failed at case {i} (seed {seed}): \
             minimal draws = {vals:?}"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum-commutes", 50, |g| {
            let a = g.u64(0, 100);
            let b = g.u64(0, 100);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn failing_property_shrinks() {
        let r = std::panic::catch_unwind(|| {
            check("fails-over-10", 100, |g| {
                let v = g.u64(0, 1000);
                assert!(v <= 10, "too big");
            });
        });
        let msg = match r {
            Ok(()) => panic!("property should have failed"),
            Err(e) => e
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_default(),
        };
        // The shrunk counterexample should be exactly 11.
        assert!(msg.contains("[11]"), "shrink failed: {msg}");
    }

    #[test]
    fn gen_respects_bounds() {
        let mut g = Gen::new(1);
        for _ in 0..100 {
            let v = g.u64(5, 9);
            assert!((5..=9).contains(&v));
        }
        assert_eq!(g.u64(3, 3), 3);
    }

    #[test]
    fn pick_and_bool_work() {
        let mut g = Gen::new(2);
        let xs = [1, 2, 3];
        for _ in 0..20 {
            assert!(xs.contains(g.pick(&xs)));
        }
        let mut seen = [false; 2];
        for _ in 0..50 {
            seen[g.bool() as usize] = true;
        }
        assert!(seen[0] && seen[1]);
    }
}
