//! Failure injection + recovery checking.
//!
//! The paper's two transactional guarantees are verified mechanically
//! against the backup's durability ledger:
//!
//! * **Guarantee-1 (failure atomicity)** — crash the system at an
//!   arbitrary instant, reconstruct the backup PM image from the ledger,
//!   run undo-log recovery, and require the resulting data state to equal
//!   the state after some *prefix* of committed transactions.
//! * **Guarantee-2 (durability)** — that prefix must include every
//!   transaction whose durability fence completed before the crash.
//!
//! Plus the epoch-ordering invariant that underpins both: a later-epoch
//! write must never be durable while an earlier-epoch write of the same
//! thread is not.
//!
//! For sharded coordinators (several independent replica groups
//! partitioning the PM space — [`crate::coordinator::shard`]), the
//! group checks run per shard and merge into a cross-shard verdict:
//! see [`check_sharded_group_crash`].
//!
//! Runs with **primary faults** ([`crate::net::membership`]) add a
//! membership-epoch dimension: every faulted verdict reports the epoch
//! in force at the crash instant, and [`check_leader_completeness`]
//! verifies the election rule's defining property — each elected
//! primary's certified ledger covered every transaction durably acked
//! by its failover instant.

use crate::coordinator::ShardMap;
use crate::mem::DurabilityLog;
use crate::net::{effective_required, FaultTimeline, OnLoss, PersistDomain};
use crate::txn::undo::rollback_plan;
use crate::{Addr, Ns};
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;

/// Golden transaction history recorded by a (single-threaded) workload:
/// `snapshots[k]` is the data image after `k` committed transactions;
/// `dfences[k]` the completion time of transaction `k`'s durability fence.
#[derive(Clone, Debug, Default)]
pub struct TxnHistory {
    pub snapshots: Vec<HashMap<Addr, u64>>,
    pub dfences: Vec<Ns>,
}

impl TxnHistory {
    pub fn new(initial: HashMap<Addr, u64>) -> Self {
        TxnHistory {
            snapshots: vec![initial],
            dfences: Vec::new(),
        }
    }

    /// Record a committed transaction's post-image + dfence completion.
    pub fn commit(&mut self, image: HashMap<Addr, u64>, dfence: Ns) {
        self.snapshots.push(image);
        self.dfences.push(dfence);
    }

    pub fn committed(&self) -> usize {
        self.dfences.len()
    }

    /// Transactions durably committed by time `t`.
    pub fn durable_by(&self, t: Ns) -> usize {
        self.dfences.iter().filter(|&&d| d <= t).count()
    }
}

/// Reconstruct the post-crash, post-recovery data image: ledger replay up
/// to `crash_t`, then undo-rollback of any active logs.
pub fn recover_image(
    ledger: &DurabilityLog,
    crash_t: Ns,
    log_bases: &[Addr],
) -> HashMap<Addr, u64> {
    let mut img = ledger.image_at(crash_t);
    for &log in log_bases {
        for (addr, old) in rollback_plan(&img, log) {
            img.insert(crate::line_of(addr), old);
        }
    }
    img
}

/// Compare a recovered image to a snapshot over the given data addresses
/// (absent keys read as 0 — never-written PM).
fn matches_snapshot(
    img: &HashMap<Addr, u64>,
    snap: &HashMap<Addr, u64>,
    data_addrs: &[Addr],
) -> bool {
    data_addrs.iter().all(|a| {
        img.get(a).copied().unwrap_or(0) == snap.get(a).copied().unwrap_or(0)
    })
}

/// Guarantee-1 (failure atomicity) alone: the recovered image must match
/// *some* committed prefix; returns its length. Used per backup inside
/// the group checks, where durability (Guarantee-2) is a property of the
/// ack-policy-required *set* of backups, not of each backup alone.
pub fn best_prefix(
    ledger: &DurabilityLog,
    history: &TxnHistory,
    log_bases: &[Addr],
    data_addrs: &[Addr],
    crash_t: Ns,
) -> Result<usize> {
    let img = recover_image(ledger, crash_t, log_bases);
    // Search newest-first: the recovered state is the *latest* consistent
    // prefix (later snapshots subsume earlier on overwritten addresses).
    let k = (0..history.snapshots.len())
        .rev()
        .find(|&k| matches_snapshot(&img, &history.snapshots[k], data_addrs));
    match k {
        Some(k) => Ok(k),
        None => bail!(
            "failure atomicity violated at crash t={crash_t}: recovered \
             image matches no committed prefix"
        ),
    }
}

/// Check Guarantee-1 + Guarantee-2 for a crash at `crash_t`.
/// Returns the recovered prefix length `k` on success.
pub fn check_crash(
    ledger: &DurabilityLog,
    history: &TxnHistory,
    log_bases: &[Addr],
    data_addrs: &[Addr],
    crash_t: Ns,
) -> Result<usize> {
    let k = best_prefix(ledger, history, log_bases, data_addrs, crash_t)?;
    let durable = history.durable_by(crash_t);
    if k < durable {
        bail!(
            "durability violated at crash t={crash_t}: {durable} txns had \
             completed their dfence but only prefix {k} survived"
        );
    }
    Ok(k)
}

/// Run `sample` at t=0, every instant in `times` (sorted and deduped
/// here), each adjacent midpoint, and one instant past the last event —
/// the shared crash-point sampling grid of all the sweep checks.
/// Returns the number of crash points checked.
fn sweep_crash_points(
    mut times: Vec<Ns>,
    mut sample: impl FnMut(Ns) -> Result<()>,
) -> Result<u64> {
    times.sort_unstable();
    times.dedup();
    let mut checked = 0u64;
    sample(0)?;
    checked += 1;
    for w in times.windows(2) {
        for t in [w[0], w[0] + (w[1] - w[0]) / 2] {
            sample(t)?;
            checked += 1;
        }
    }
    if let Some(&last) = times.last() {
        sample(last)?;
        sample(last + 1)?;
        checked += 2;
    }
    Ok(checked)
}

/// Sweep crash instants across the ledger (every event time, its
/// predecessor instant, and midpoints) and check them all.
pub fn check_all_crashes(
    ledger: &DurabilityLog,
    history: &TxnHistory,
    log_bases: &[Addr],
    data_addrs: &[Addr],
) -> Result<u64> {
    let times: Vec<Ns> = ledger.events().iter().map(|e| e.at).collect();
    sweep_crash_points(times, |t| {
        check_crash(ledger, history, log_bases, data_addrs, t).map(|_| ())
    })
}

/// Unified entry point for the group crash-consistency checks: one
/// builder collecting the workload's golden history, the ledger set,
/// the ack-policy requirement, and the optional fault / sharding /
/// persistence-domain dimensions, replacing the six positional
/// `check_*_group_crash(es)` functions (kept below as thin shims that
/// pin their historical behavior).
///
/// ```text
/// let k = CrashCheck::new(&history, &log_bases, &data_addrs)
///     .ledgers(&ledgers)        // unsharded: the replica group
///     .required(2)              // ack policy (default: all backups)
///     .on_loss(OnLoss::Degrade) // loss handling under faults
///     .faults(&timeline)        // realized alive/dead membership
///     .persist_domain(d)        // annotates verdicts with the domain
///     .at(crash_t)?;            // one instant; .sweep() for all
/// ```
///
/// Exactly one of `.ledgers(..)` (unsharded) or `.shards(..)` (per-
/// shard ledger groups + timelines over a [`ShardMap`]) must be set.
/// The persistence domain is informational: verdict widths already
/// arise from the domain-realized ledger stamps (eADR stamps at
/// completion widen durable sets; `rpmem-flush` stamps at the flush
/// verb narrow them), so the builder threads it into failure context
/// rather than into the decision procedure.
pub struct CrashCheck<'a> {
    history: &'a TxnHistory,
    log_bases: &'a [Addr],
    data_addrs: &'a [Addr],
    required: usize,
    on_loss: OnLoss,
    domain: PersistDomain,
    ledgers: &'a [&'a DurabilityLog],
    faults: Option<&'a FaultTimeline>,
    sharded: Option<ShardedCheck<'a>>,
}

/// The sharded dimension of a [`CrashCheck`]: per-shard ledger groups
/// and realized timelines over the routing map.
struct ShardedCheck<'a> {
    ledgers: &'a [Vec<&'a DurabilityLog>],
    timelines: &'a [FaultTimeline],
    map: &'a ShardMap,
}

impl<'a> CrashCheck<'a> {
    pub fn new(
        history: &'a TxnHistory,
        log_bases: &'a [Addr],
        data_addrs: &'a [Addr],
    ) -> Self {
        CrashCheck {
            history,
            log_bases,
            data_addrs,
            required: 0,
            on_loss: OnLoss::Halt,
            domain: PersistDomain::Adr,
            ledgers: &[],
            faults: None,
            sharded: None,
        }
    }

    /// The unsharded replica group's durability ledgers.
    pub fn ledgers(mut self, ledgers: &'a [&'a DurabilityLog]) -> Self {
        self.ledgers = ledgers;
        self
    }

    /// Durable backups the ack policy required at each fence
    /// (per shard, in sharded mode). Default: the whole group (`all`).
    pub fn required(mut self, required: usize) -> Self {
        self.required = required;
        self
    }

    /// Loss handling the run used ([`OnLoss::Halt`] default).
    pub fn on_loss(mut self, on_loss: OnLoss) -> Self {
        self.on_loss = on_loss;
        self
    }

    /// Fault-aware membership: verdicts consult the realized alive/dead
    /// timeline (unsharded mode; sharded mode carries its own per-shard
    /// timelines).
    pub fn faults(mut self, timeline: &'a FaultTimeline) -> Self {
        self.faults = Some(timeline);
        self
    }

    /// Sharded mode: per-shard ledger groups (`[shard][backup]`) and
    /// realized timelines over the routing `map`.
    pub fn shards(
        mut self,
        ledgers: &'a [Vec<&'a DurabilityLog>],
        timelines: &'a [FaultTimeline],
        map: &'a ShardMap,
    ) -> Self {
        self.sharded = Some(ShardedCheck {
            ledgers,
            timelines,
            map,
        });
        self
    }

    /// The remote persistence domain the run's backups operated under.
    /// Annotates failure context; the durable-set widths themselves are
    /// already encoded in the ledger stamps the domain produced.
    pub fn persist_domain(mut self, d: PersistDomain) -> Self {
        self.domain = d;
        self
    }

    fn required_for(&self, group: usize) -> usize {
        if self.required == 0 {
            group
        } else {
            self.required
        }
    }

    fn wrap(&self, e: anyhow::Error) -> anyhow::Error {
        if self.domain == PersistDomain::Adr {
            e
        } else {
            anyhow!("under persist domain {}: {e}", self.domain)
        }
    }

    /// Check one crash instant; returns the worst-case surviving prefix
    /// length (see [`check_faulted_group_crash`] /
    /// [`check_sharded_group_crash`] for the decision procedure).
    pub fn at(&self, crash_t: Ns) -> Result<usize> {
        if let Some(sh) = &self.sharded {
            if self.faults.is_some() {
                bail!(
                    "CrashCheck: .faults() is the unsharded timeline — \
                     sharded mode takes per-shard timelines via .shards()"
                );
            }
            let group = sh.ledgers.first().map_or(0, |g| g.len());
            return check_sharded_group_crash(
                sh.ledgers,
                sh.timelines,
                self.history,
                self.log_bases,
                self.data_addrs,
                self.required_for(group),
                self.on_loss,
                sh.map,
                crash_t,
            )
            .map_err(|e| self.wrap(e));
        }
        let empty;
        let timeline = match self.faults {
            Some(t) => t,
            None => {
                empty = FaultTimeline::new(self.ledgers.len(), Vec::new());
                &empty
            }
        };
        check_faulted_group_crash(
            self.ledgers,
            self.history,
            self.log_bases,
            self.data_addrs,
            self.required_for(self.ledgers.len()),
            self.on_loss,
            timeline,
            crash_t,
        )
        .map_err(|e| self.wrap(e))
    }

    /// Sweep every interesting crash instant (ledger event times,
    /// midpoints, boundaries, timeline transitions); returns the number
    /// of crash points verified.
    pub fn sweep(&self) -> Result<u64> {
        if let Some(sh) = &self.sharded {
            if self.faults.is_some() {
                bail!(
                    "CrashCheck: .faults() is the unsharded timeline — \
                     sharded mode takes per-shard timelines via .shards()"
                );
            }
            let group = sh.ledgers.first().map_or(0, |g| g.len());
            return check_sharded_group_crashes(
                sh.ledgers,
                sh.timelines,
                self.history,
                self.log_bases,
                self.data_addrs,
                self.required_for(group),
                self.on_loss,
                sh.map,
            )
            .map_err(|e| self.wrap(e));
        }
        let empty;
        let timeline = match self.faults {
            Some(t) => t,
            None => {
                empty = FaultTimeline::new(self.ledgers.len(), Vec::new());
                &empty
            }
        };
        check_faulted_group_crashes(
            self.ledgers,
            self.history,
            self.log_bases,
            self.data_addrs,
            self.required_for(self.ledgers.len()),
            self.on_loss,
            timeline,
        )
        .map_err(|e| self.wrap(e))
    }
}

/// Cross-replica consistency for one crash instant: Guarantee-1 must
/// hold on **every** backup individually (each receives the same ordered
/// verb stream, so each image is some committed prefix), and the
/// ack-policy form of Guarantee-2 must hold on the group: the policy
/// required `required` durable backups at every completed dfence, so
/// after losing any `required - 1` backups some survivor still holds
/// every durably-acked transaction. Returns that worst-case surviving
/// prefix length.
///
/// Deprecated shim — prefer [`CrashCheck`]; this pins the historical
/// positional signature (static membership, halt loss handling).
pub fn check_group_crash(
    ledgers: &[&DurabilityLog],
    history: &TxnHistory,
    log_bases: &[Addr],
    data_addrs: &[Addr],
    required: usize,
    crash_t: Ns,
) -> Result<usize> {
    CrashCheck::new(history, log_bases, data_addrs)
        .ledgers(ledgers)
        .required(required)
        .at(crash_t)
}

/// Sweep crash instants across the union of all backup ledgers (every
/// event time, midpoints, and the boundaries) and run
/// [`check_group_crash`] at each. Returns the number of crash points
/// verified.
///
/// Deprecated shim — prefer [`CrashCheck`] with `.sweep()`.
pub fn check_group_crashes(
    ledgers: &[&DurabilityLog],
    history: &TxnHistory,
    log_bases: &[Addr],
    data_addrs: &[Addr],
    required: usize,
) -> Result<u64> {
    CrashCheck::new(history, log_bases, data_addrs)
        .ledgers(ledgers)
        .required(required)
        .sweep()
}

/// Fault-aware cross-replica consistency for one crash instant: only
/// backups in the quorum at `crash_t` per the realized [`FaultTimeline`]
/// can serve recovery — a backup that was dead (or still resyncing) when
/// the crash hit is unavailable, and a dead-then-rejoined backup is
/// acceptable even though its ledger prefix diverged during the outage
/// (the catch-up resync replayed the missed suffix at its completion
/// instant). Guarantee-1 is checked on every *survivor*; the group
/// Guarantee-2 uses the loss-adjusted requirement: under
/// [`OnLoss::Degrade`] fences issued while `d` backups were down were
/// acked by only `required - d` survivors, so the adversary argument is
/// run with `effective_required(required, alive_at_crash, on_loss)`.
/// Returns the worst-case surviving prefix length.
///
/// Prefer the [`CrashCheck`] builder; this positional form remains as
/// the decision procedure it delegates to.
#[allow(clippy::too_many_arguments)]
pub fn check_faulted_group_crash(
    ledgers: &[&DurabilityLog],
    history: &TxnHistory,
    log_bases: &[Addr],
    data_addrs: &[Addr],
    required: usize,
    on_loss: OnLoss,
    timeline: &FaultTimeline,
    crash_t: Ns,
) -> Result<usize> {
    let n = ledgers.len();
    if required == 0 || required > n {
        bail!("required acks {required} invalid for a {n}-backup group");
    }
    if timeline.backups() != n {
        bail!(
            "timeline covers {} backups but the group has {n}",
            timeline.backups()
        );
    }
    let alive = timeline.alive_at(crash_t);
    let epoch = timeline.epoch_at(crash_t);
    let mut prefixes = Vec::with_capacity(n);
    for (b, ledger) in ledgers.iter().enumerate() {
        if !alive[b] {
            continue;
        }
        let k = best_prefix(ledger, history, log_bases, data_addrs, crash_t)
            .map_err(|e| anyhow::anyhow!("backup {b} (membership epoch {epoch}): {e}"))?;
        prefixes.push(k);
    }
    let eff = effective_required(required, prefixes.len(), on_loss);
    if eff == 0 {
        bail!(
            "no ack-satisfying survivor set at crash t={crash_t} (membership \
             epoch {epoch}): {} of {n} backups alive, policy requires \
             {required} (on_loss = {on_loss})",
            prefixes.len()
        );
    }
    prefixes.sort_unstable_by(|a, b| b.cmp(a)); // descending
    let survivor_best = prefixes[eff - 1];
    let durable = history.durable_by(crash_t);
    if survivor_best < durable {
        bail!(
            "group durability violated at crash t={crash_t} (membership epoch \
             {epoch}): {durable} txns durably acked, but after losing {} \
             further backups the best survivor holds only prefix \
             {survivor_best} (survivor prefixes, desc: {prefixes:?})",
            eff - 1
        );
    }
    Ok(survivor_best)
}

/// Sweep crash instants (union of all ledger event times, midpoints, and
/// boundaries — including each timeline transition) through
/// [`check_faulted_group_crash`]. Returns the number of crash points
/// verified.
pub fn check_faulted_group_crashes(
    ledgers: &[&DurabilityLog],
    history: &TxnHistory,
    log_bases: &[Addr],
    data_addrs: &[Addr],
    required: usize,
    on_loss: OnLoss,
    timeline: &FaultTimeline,
) -> Result<u64> {
    let times: Vec<Ns> = ledgers
        .iter()
        .flat_map(|l| l.events().iter().map(|e| e.at))
        .chain(timeline.transitions().iter().map(|t| t.0))
        .collect();
    sweep_crash_points(times, |t| {
        check_faulted_group_crash(
            ledgers, history, log_bases, data_addrs, required, on_loss, timeline, t,
        )
        .map(|_| ())
    })
}

/// Cross-shard consistency for one crash instant, over a coordinator
/// that partitions the PM line-address space across `S` independent
/// replica groups (see [`crate::coordinator::shard`]).
///
/// Because the [`ShardMap`] is a *partition* — every line has exactly
/// one owning shard — the shards' recovered images are disjoint and
/// their union reconstructs the full PM space. The check runs the
/// group-crash argument **per shard**, then merges:
///
/// * **Guarantee-1 per shard** — every surviving backup of every shard
///   must recover to some committed prefix *restricted to the data
///   addresses that shard owns*. Undo-log lines may live on a
///   different shard than the data they guard, so each candidate image
///   is completed with the healthiest survivor's image of every other
///   shard before rollback (one shard is adversarial at a time; the
///   other shards' durability is covered by their own iteration).
/// * **Group Guarantee-2, merged** — per shard, the adversary removes
///   `effective_required - 1` further backups and the best remaining
///   prefix is taken; the cross-shard verdict is the **min** of the
///   per-shard prefixes and must cover every transaction durably acked
///   by `crash_t` (a commit fence completed only after *every* touched
///   shard acked, so the min is the right merge).
///
/// Returns the merged worst-case surviving prefix length.
///
/// Prefer the [`CrashCheck`] builder (`.shards(..)`); this positional
/// form remains as the decision procedure it delegates to.
#[allow(clippy::too_many_arguments)]
pub fn check_sharded_group_crash(
    shard_ledgers: &[Vec<&DurabilityLog>],
    timelines: &[FaultTimeline],
    history: &TxnHistory,
    log_bases: &[Addr],
    data_addrs: &[Addr],
    required: usize,
    on_loss: OnLoss,
    map: &ShardMap,
    crash_t: Ns,
) -> Result<usize> {
    let s_count = shard_ledgers.len();
    if s_count == 0 {
        bail!("sharded group check needs at least one shard");
    }
    if map.shards() != s_count {
        bail!(
            "shard map covers {} shards but {} ledger groups were given",
            map.shards(),
            s_count
        );
    }
    if timelines.len() != s_count {
        bail!(
            "{} timelines for {s_count} shards",
            timelines.len()
        );
    }
    let n = shard_ledgers[0].len();
    if required == 0 || required > n {
        bail!("required acks {required} invalid for a {n}-backup group");
    }
    // Survivor sets + the healthiest survivor's raw (pre-rollback)
    // image per shard, used to complete other shards' candidates.
    let mut alive_idx: Vec<Vec<usize>> = Vec::with_capacity(s_count);
    let mut best_img: Vec<HashMap<Addr, u64>> = Vec::with_capacity(s_count);
    for s in 0..s_count {
        if shard_ledgers[s].len() != n {
            bail!(
                "shard {s} has {} backups, expected {n}",
                shard_ledgers[s].len()
            );
        }
        if timelines[s].backups() != n {
            bail!(
                "shard {s} timeline covers {} backups but the group has {n}",
                timelines[s].backups()
            );
        }
        let alive = timelines[s].alive_at(crash_t);
        let idx: Vec<usize> = (0..n).filter(|&b| alive[b]).collect();
        if effective_required(required, idx.len(), on_loss) == 0 {
            bail!(
                "shard {s}: no ack-satisfying survivor set at crash \
                 t={crash_t} (membership epoch {}): {} of {n} backups alive, \
                 policy requires {required} (on_loss = {on_loss})",
                timelines[s].epoch_at(crash_t),
                idx.len()
            );
        }
        let healthiest = idx
            .iter()
            .copied()
            .max_by_key(|&b| {
                let drained = shard_ledgers[s][b]
                    .events()
                    .iter()
                    .filter(|e| e.at <= crash_t)
                    .count();
                (drained, std::cmp::Reverse(b))
            })
            .expect("idx nonempty");
        best_img.push(shard_ledgers[s][healthiest].image_at(crash_t));
        alive_idx.push(idx);
    }
    let durable = history.durable_by(crash_t);
    let mut merged = usize::MAX;
    for s in 0..s_count {
        let owned: Vec<Addr> = data_addrs
            .iter()
            .copied()
            .filter(|&a| map.shard_of(a) == s)
            .collect();
        let mut prefixes = Vec::with_capacity(alive_idx[s].len());
        for &b in &alive_idx[s] {
            // Adversarial on shard s, optimistic elsewhere: other
            // shards contribute their healthiest survivor (disjoint
            // address sets, so the union is conflict-free).
            let mut img: HashMap<Addr, u64> = HashMap::new();
            for (o, other) in best_img.iter().enumerate() {
                if o != s {
                    img.extend(other.iter().map(|(&k, &v)| (k, v)));
                }
            }
            img.extend(shard_ledgers[s][b].image_at(crash_t));
            for &log in log_bases {
                for (addr, old) in rollback_plan(&img, log) {
                    img.insert(crate::line_of(addr), old);
                }
            }
            let k = (0..history.snapshots.len())
                .rev()
                .find(|&k| matches_snapshot(&img, &history.snapshots[k], &owned))
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "shard {s} backup {b}: failure atomicity violated at \
                         crash t={crash_t}: recovered image matches no \
                         committed prefix"
                    )
                })?;
            prefixes.push(k);
        }
        let eff = effective_required(required, prefixes.len(), on_loss);
        prefixes.sort_unstable_by(|a, b| b.cmp(a)); // descending
        merged = merged.min(prefixes[eff - 1]);
    }
    if merged < durable {
        bail!(
            "cross-shard durability violated at crash t={crash_t} (per-shard \
             membership epochs {:?}): {durable} txns durably acked, but the \
             merged shard verdict holds only prefix {merged}",
            timelines
                .iter()
                .map(|tl| tl.epoch_at(crash_t))
                .collect::<Vec<_>>()
        );
    }
    Ok(merged)
}

/// Sweep crash instants (union of every shard's ledger event times and
/// timeline transitions, midpoints, and boundaries) through
/// [`check_sharded_group_crash`]. Returns the number of crash points
/// verified.
pub fn check_sharded_group_crashes(
    shard_ledgers: &[Vec<&DurabilityLog>],
    timelines: &[FaultTimeline],
    history: &TxnHistory,
    log_bases: &[Addr],
    data_addrs: &[Addr],
    required: usize,
    on_loss: OnLoss,
    map: &ShardMap,
) -> Result<u64> {
    let times: Vec<Ns> = shard_ledgers
        .iter()
        .flatten()
        .flat_map(|l| l.events().iter().map(|e| e.at))
        .chain(
            timelines
                .iter()
                .flat_map(|tl| tl.transitions().iter().map(|t| t.0)),
        )
        .collect();
    sweep_crash_points(times, |t| {
        check_sharded_group_crash(
            shard_ledgers,
            timelines,
            history,
            log_bases,
            data_addrs,
            required,
            on_loss,
            map,
            t,
        )
        .map(|_| ())
    })
}

/// Leader completeness across every membership epoch of a realized
/// [`FaultTimeline`]: for each failover transition `(at, epoch, winner)`
/// the elected primary's ledger — certified line by line before the
/// election ([`crate::net::membership`]) — must recover, at the election
/// instant, to a committed prefix covering every transaction durably
/// acked by `at`. This is the property the election rule (longest
/// certified prefix wins) exists to guarantee: promoting any candidate
/// that fails it would silently drop acked transactions even though no
/// quorum was lost. Returns the number of epoch transitions checked
/// (0 for a fault-free timeline — trivially complete).
pub fn check_leader_completeness(
    ledgers: &[&DurabilityLog],
    history: &TxnHistory,
    log_bases: &[Addr],
    data_addrs: &[Addr],
    timeline: &FaultTimeline,
) -> Result<u64> {
    for &(at, epoch, winner) in timeline.epochs() {
        if winner >= ledgers.len() {
            bail!(
                "epoch {epoch} elected slot {winner} but the group has only \
                 {} backups",
                ledgers.len()
            );
        }
        let k = best_prefix(ledgers[winner], history, log_bases, data_addrs, at)
            .map_err(|e| {
                anyhow::anyhow!("epoch {epoch} leader (slot {winner}): {e}")
            })?;
        let durable = history.durable_by(at);
        if k < durable {
            bail!(
                "leader completeness violated at epoch {epoch} (t={at}): \
                 {durable} txns durably acked by the failover instant, but \
                 the elected primary (slot {winner}) recovers only prefix {k}"
            );
        }
    }
    Ok(timeline.epochs().len() as u64)
}

/// Leader completeness for a sharded coordinator: all `S` shards of a
/// replica node fail over as one unit, so every shard must realize the
/// **same** epoch log, and the elected node's recovered state is the
/// union of the winner slot's per-shard images (disjoint address sets —
/// the [`ShardMap`] is a partition). That merged image, rolled back
/// through any active undo logs, must cover every transaction durably
/// acked by each failover instant. Returns the number of epoch
/// transitions checked.
pub fn check_sharded_leader_completeness(
    shard_ledgers: &[Vec<&DurabilityLog>],
    timelines: &[FaultTimeline],
    history: &TxnHistory,
    log_bases: &[Addr],
    data_addrs: &[Addr],
) -> Result<u64> {
    let Some(first) = timelines.first() else {
        bail!("sharded leader completeness needs at least one shard");
    };
    if shard_ledgers.len() != timelines.len() {
        bail!(
            "{} ledger groups for {} timelines",
            shard_ledgers.len(),
            timelines.len()
        );
    }
    let eps = first.epochs();
    for (s, tl) in timelines.iter().enumerate().skip(1) {
        if tl.epochs() != eps {
            bail!(
                "shard {s} epoch log {:?} diverges from shard 0 {eps:?}: all \
                 shards of a node must fail over as one unit",
                tl.epochs()
            );
        }
    }
    for &(at, epoch, winner) in eps {
        let mut img: HashMap<Addr, u64> = HashMap::new();
        for (s, ledgers) in shard_ledgers.iter().enumerate() {
            if winner >= ledgers.len() {
                bail!(
                    "epoch {epoch} elected slot {winner} but shard {s} has \
                     only {} backups",
                    ledgers.len()
                );
            }
            img.extend(ledgers[winner].image_at(at));
        }
        for &log in log_bases {
            for (addr, old) in rollback_plan(&img, log) {
                img.insert(crate::line_of(addr), old);
            }
        }
        let k = (0..history.snapshots.len())
            .rev()
            .find(|&k| matches_snapshot(&img, &history.snapshots[k], data_addrs))
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "epoch {epoch} leader (slot {winner}): merged recovered \
                     image matches no committed prefix at t={at}"
                )
            })?;
        let durable = history.durable_by(at);
        if k < durable {
            bail!(
                "leader completeness violated at epoch {epoch} (t={at}): \
                 {durable} txns durably acked by the failover instant, but \
                 the elected primary (slot {winner}) recovers only prefix {k} \
                 across {} shards",
                shard_ledgers.len()
            );
        }
    }
    Ok(eps.len() as u64)
}

/// Epoch-ordering invariant across a whole replica group: each backup's
/// ledger must satisfy [`check_epoch_ordering`] independently.
pub fn check_group_epoch_ordering(ledgers: &[&DurabilityLog]) -> Result<()> {
    for (b, ledger) in ledgers.iter().enumerate() {
        check_epoch_ordering(ledger).map_err(|e| anyhow::anyhow!("backup {b}: {e}"))?;
    }
    Ok(())
}

/// Epoch-ordering invariant over the ledger: for any two events of the
/// same thread, lexicographically earlier (txn, epoch) must not persist
/// strictly later. O(n log n) via per-thread sort.
pub fn check_epoch_ordering(ledger: &DurabilityLog) -> Result<()> {
    let mut per_thread: HashMap<u32, Vec<(u64, u32, Ns, u64)>> = HashMap::new();
    for e in ledger.events() {
        per_thread
            .entry(e.thread)
            .or_default()
            .push((e.txn, e.epoch, e.at, e.seq));
    }
    for (thread, mut evs) in per_thread {
        evs.sort_unstable_by_key(|&(txn, epoch, _, seq)| (txn, epoch, seq));
        // Walk in (txn, epoch) order; persist times of *later* epochs must
        // never fall below the running max of earlier epochs.
        let mut prev_epoch_max: Ns = 0; // max persist over all earlier epochs
        let mut cur_coord = (u64::MAX, u32::MAX);
        let mut cur_max: Ns = 0;
        for (txn, epoch, at, _) in evs {
            if (txn, epoch) != cur_coord {
                prev_epoch_max = prev_epoch_max.max(cur_max);
                cur_coord = (txn, epoch);
                cur_max = 0;
            }
            if at < prev_epoch_max {
                bail!(
                    "epoch ordering violated for thread {thread}: \
                     (txn {txn}, epoch {epoch}) persisted at {at} before an \
                     earlier epoch's write at {prev_epoch_max}"
                );
            }
            cur_max = cur_max.max(at);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Platform, StrategyKind};
    use crate::coordinator::{Mirror, ThreadCtx};
    use crate::txn::Txn;

    const LOG: Addr = 0x10_0000;
    const D0: Addr = 0x20_0000;
    const D1: Addr = 0x20_0040;

    /// Run `n` txns alternating writes to D0/D1; return (mirror, history).
    fn run_workload(kind: StrategyKind, n: u64) -> (Mirror, TxnHistory) {
        let mut m = Mirror::new(Platform::default(), kind, true);
        let hist = drive_txns(&mut m, n);
        (m, hist)
    }

    /// Drive `n` two-write txns on an existing mirror, recording history.
    fn drive_txns(m: &mut Mirror, n: u64) -> TxnHistory {
        let mut t = ThreadCtx::new(0);
        let mut hist = TxnHistory::new(HashMap::new());
        for i in 0..n {
            let mut tx = Txn::begin(m, &mut t, LOG, None);
            tx.write(m, &mut t, D0, 100 + i);
            tx.write(m, &mut t, D1, 200 + i);
            tx.commit(m, &mut t);
            let mut snap = HashMap::new();
            snap.insert(D0, 100 + i);
            snap.insert(D1, 200 + i);
            hist.commit(snap, t.last_dfence);
        }
        hist
    }

    #[test]
    fn every_strategy_survives_all_crash_points() {
        for kind in [StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd] {
            let (m, hist) = run_workload(kind, 5);
            let checked = check_all_crashes(
                &m.backup(0).ledger,
                &hist,
                &[LOG],
                &[D0, D1],
            )
            .unwrap_or_else(|e| panic!("{kind:?}: {e}"));
            assert!(checked > 10, "{kind:?}: only {checked} crash points");
        }
    }

    #[test]
    fn epoch_ordering_holds_for_every_strategy() {
        for kind in [StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd] {
            let (m, _) = run_workload(kind, 5);
            check_epoch_ordering(&m.backup(0).ledger)
                .unwrap_or_else(|e| panic!("{kind:?}: {e}"));
        }
    }

    #[test]
    fn detects_fabricated_ordering_violation() {
        use crate::mem::DurEvent;
        let mut ledger = DurabilityLog::new(true);
        ledger.record(DurEvent {
            addr: D0,
            val: 1,
            at: 100,
            thread: 0,
            txn: 0,
            epoch: 1, // later epoch...
            seq: 1,
        });
        ledger.record(DurEvent {
            addr: D1,
            val: 1,
            at: 200, // ...but the earlier epoch persists later
            thread: 0,
            txn: 0,
            epoch: 0,
            seq: 0,
        });
        assert!(check_epoch_ordering(&ledger).is_err());
    }

    #[test]
    fn detects_durability_violation() {
        // History claims txn 0's dfence completed at t=50, but nothing is
        // durable by then: Guarantee-2 must fail for a crash at t=50.
        let (m, mut hist) = run_workload(StrategyKind::SmOb, 1);
        hist.dfences[0] = 50;
        let err = check_crash(&m.backup(0).ledger, &hist, &[LOG], &[D0, D1], 50);
        assert!(err.is_err(), "expected durability violation");
    }

    #[test]
    fn group_crash_checks_pass_for_all_and_quorum() {
        use crate::config::{AckPolicy, ReplicationConfig};
        for kind in [StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd] {
            for policy in [AckPolicy::All, AckPolicy::Quorum(2)] {
                let repl = ReplicationConfig::new(3, policy);
                let mut m =
                    Mirror::with_replication(Platform::default(), kind, repl, true)
                        .unwrap();
                let hist = drive_txns(&mut m, 4);
                let ledgers = m.fabric().ledgers();
                check_group_epoch_ordering(&ledgers)
                    .unwrap_or_else(|e| panic!("{kind:?}/{policy}: {e}"));
                let checked = check_group_crashes(
                    &ledgers,
                    &hist,
                    &[LOG],
                    &[D0, D1],
                    repl.required(),
                )
                .unwrap_or_else(|e| panic!("{kind:?}/{policy}: {e}"));
                assert!(checked > 10, "{kind:?}/{policy}: only {checked} points");
            }
        }
    }

    #[test]
    fn group_check_detects_fabricated_lag() {
        // A 2-backup group claiming required=2 (All): if one backup's
        // ledger is empty while txns durably acked, the check must fail.
        let (m, hist) = run_workload(StrategyKind::SmOb, 2);
        let full = &m.backup(0).ledger;
        let empty = DurabilityLog::new(true);
        let crash = full.horizon();
        let err = check_group_crash(
            &[full, &empty],
            &hist,
            &[LOG],
            &[D0, D1],
            2,
            crash,
        );
        assert!(err.is_err(), "lagging required backup must fail the check");
        // The same pair under quorum required=1 passes: the full backup
        // alone satisfies the policy.
        check_group_crash(&[full, &empty], &hist, &[LOG], &[D0, D1], 1, crash)
            .expect("quorum:1 tolerates one empty backup");
    }

    #[test]
    fn group_check_rejects_bad_required() {
        let (m, hist) = run_workload(StrategyKind::SmOb, 1);
        let l = &m.backup(0).ledger;
        assert!(check_group_crash(&[l], &hist, &[LOG], &[D0, D1], 0, 0).is_err());
        assert!(check_group_crash(&[l], &hist, &[LOG], &[D0, D1], 2, 0).is_err());
    }

    #[test]
    fn empty_ledgers_with_empty_history_pass() {
        // A group that never wrote anything: every backup trivially holds
        // prefix 0, and nothing was durably acked.
        let hist = TxnHistory::new(HashMap::new());
        let a = DurabilityLog::new(true);
        let b = DurabilityLog::new(true);
        for required in [1usize, 2] {
            let k = check_group_crash(&[&a, &b], &hist, &[LOG], &[D0, D1], required, 0)
                .unwrap();
            assert_eq!(k, 0);
            let k = check_group_crash(
                &[&a, &b],
                &hist,
                &[LOG],
                &[D0, D1],
                required,
                1_000_000,
            )
            .unwrap();
            assert_eq!(k, 0);
        }
        // But an empty ledger cannot cover a durably-acked transaction.
        let (_m, hist) = run_workload(StrategyKind::SmOb, 1);
        let crash = hist.dfences[0]; // txn 0 is durable by here
        assert!(check_group_crash(
            &[&a, &b],
            &hist,
            &[LOG],
            &[D0, D1],
            1,
            crash
        )
        .is_err());
    }

    #[test]
    fn all_backups_dead_is_a_checked_error() {
        use crate::net::FaultTimeline;
        let (m, hist) = run_workload(StrategyKind::SmOb, 2);
        let ledger = &m.backup(0).ledger;
        let crash = ledger.horizon();
        // Both backups killed before the crash: no survivor can serve.
        let tl = FaultTimeline::new(2, vec![(10, 0, false), (20, 1, false)]);
        let err = check_faulted_group_crash(
            &[ledger, ledger],
            &hist,
            &[LOG],
            &[D0, D1],
            1,
            OnLoss::Degrade,
            &tl,
            crash,
        );
        assert!(err.is_err(), "zero survivors must fail even in degrade");
        // Before the kills the same group passes.
        check_faulted_group_crash(
            &[ledger, ledger],
            &hist,
            &[LOG],
            &[D0, D1],
            1,
            OnLoss::Degrade,
            &tl,
            5,
        )
        .unwrap();
    }

    #[test]
    fn faulted_check_excludes_dead_backups_from_the_survivor_set() {
        use crate::net::FaultTimeline;
        // Backup 1 is empty (it missed everything) but is also dead at
        // the crash: the timeline-aware check must not count it, so the
        // full survivor carries the group under degrade.
        let (m, hist) = run_workload(StrategyKind::SmOb, 2);
        let full = &m.backup(0).ledger;
        let empty = DurabilityLog::new(true);
        let crash = full.horizon();
        let tl = FaultTimeline::new(2, vec![(0, 1, false)]);
        // Static required = 2 (All): degrade clamps to the one survivor.
        check_faulted_group_crash(
            &[full, &empty],
            &hist,
            &[LOG],
            &[D0, D1],
            2,
            OnLoss::Degrade,
            &tl,
            crash,
        )
        .expect("degrade must recover from the surviving backup");
        // Halt refuses: 1 survivor < required 2.
        assert!(check_faulted_group_crash(
            &[full, &empty],
            &hist,
            &[LOG],
            &[D0, D1],
            2,
            OnLoss::Halt,
            &tl,
            crash,
        )
        .is_err());
        // A timeline of the wrong width is rejected.
        assert!(check_faulted_group_crash(
            &[full, &empty],
            &hist,
            &[LOG],
            &[D0, D1],
            1,
            OnLoss::Halt,
            &FaultTimeline::new(3, Vec::new()),
            crash,
        )
        .is_err());
    }

    #[test]
    fn crash_check_builder_matches_positional_forms() {
        use crate::config::{AckPolicy, ReplicationConfig};
        let repl = ReplicationConfig::new(3, AckPolicy::Quorum(2));
        let mut m =
            Mirror::with_replication(Platform::default(), StrategyKind::SmOb, repl, true)
                .unwrap();
        let hist = drive_txns(&mut m, 4);
        let ledgers = m.fabric().ledgers();
        let logs = [LOG];
        let data = [D0, D1];
        let crash = hist.dfences[1] + 1;
        // Single instant and full sweep agree with the positional forms.
        let old = check_group_crash(&ledgers, &hist, &logs, &data, 2, crash).unwrap();
        let new = CrashCheck::new(&hist, &logs, &data)
            .ledgers(&ledgers)
            .required(2)
            .at(crash)
            .unwrap();
        assert_eq!(old, new);
        let old_n = check_group_crashes(&ledgers, &hist, &logs, &data, 2).unwrap();
        let new_n = CrashCheck::new(&hist, &logs, &data)
            .ledgers(&ledgers)
            .required(2)
            .sweep()
            .unwrap();
        assert_eq!(old_n, new_n);
        // Default `required` is the whole group (ack policy `all`).
        let all_default = CrashCheck::new(&hist, &logs, &data)
            .ledgers(&ledgers)
            .at(crash)
            .unwrap();
        let all_explicit =
            check_group_crash(&ledgers, &hist, &logs, &data, 3, crash).unwrap();
        assert_eq!(all_default, all_explicit);
    }

    #[test]
    fn crash_check_builder_matches_faulted_and_sharded_forms() {
        use crate::config::{AckPolicy, ReplicationConfig};
        use crate::coordinator::{ShardMapSpec, ShardingConfig};
        use crate::net::{FaultTimeline, FaultsConfig};
        let logs = [LOG];
        let data = [D0, D1];
        // Faulted: dead backup excluded under degrade, same verdicts.
        let (m, hist) = run_workload(StrategyKind::SmOb, 2);
        let full = &m.backup(0).ledger;
        let empty = DurabilityLog::new(true);
        let crash = full.horizon();
        let tl = FaultTimeline::new(2, vec![(0, 1, false)]);
        let pair = [full, &empty];
        let old = check_faulted_group_crash(
            &pair,
            &hist,
            &logs,
            &data,
            2,
            OnLoss::Degrade,
            &tl,
            crash,
        )
        .unwrap();
        let new = CrashCheck::new(&hist, &logs, &data)
            .ledgers(&pair)
            .required(2)
            .on_loss(OnLoss::Degrade)
            .faults(&tl)
            .at(crash)
            .unwrap();
        assert_eq!(old, new);
        // Sharded: per-shard ledger groups over the routing map.
        let sharding = ShardingConfig::new(2, ShardMapSpec::Modulo);
        let mut m = Mirror::try_build_sharded(
            Platform::default(),
            StrategyKind::SmOb,
            None,
            ReplicationConfig::new(2, AckPolicy::All),
            FaultsConfig::default(),
            sharding,
            true,
        )
        .unwrap();
        let hist = drive_txns(&mut m, 3);
        let shard_ledgers = m.shard_ledgers();
        let tls = m.timelines();
        let old_n = check_sharded_group_crashes(
            &shard_ledgers,
            &tls,
            &hist,
            &logs,
            &data,
            2,
            OnLoss::Halt,
            m.shard_map(),
        )
        .unwrap();
        let new_n = CrashCheck::new(&hist, &logs, &data)
            .shards(&shard_ledgers, &tls, m.shard_map())
            .required(2)
            .sweep()
            .unwrap();
        assert_eq!(old_n, new_n);
        // The unsharded timeline knob conflicts with sharded mode.
        assert!(CrashCheck::new(&hist, &logs, &data)
            .shards(&shard_ledgers, &tls, m.shard_map())
            .faults(&tl)
            .sweep()
            .is_err());
    }

    #[test]
    fn crash_check_annotates_failures_with_the_persist_domain() {
        use crate::net::PersistDomain;
        // A fabricated durability violation (dfence claimed before any
        // write persisted) fails under any domain; a non-default domain
        // must show up in the error context.
        let (m, mut hist) = run_workload(StrategyKind::SmOb, 1);
        hist.dfences[0] = 50;
        let ledgers = [&m.backup(0).ledger];
        let logs = [LOG];
        let data = [D0, D1];
        let err = CrashCheck::new(&hist, &logs, &data)
            .ledgers(&ledgers)
            .persist_domain(PersistDomain::Eadr)
            .at(50)
            .unwrap_err();
        assert!(err.to_string().contains("eadr"), "{err}");
        let err = CrashCheck::new(&hist, &logs, &data)
            .ledgers(&ledgers)
            .at(50)
            .unwrap_err();
        assert!(!err.to_string().contains("eadr"), "{err}");
    }

    #[test]
    fn duplicate_epoch_ties_are_tolerated() {
        use crate::mem::DurEvent;
        // Two backups whose ledgers carry duplicate (txn, epoch) entries
        // persisting at identical instants — e.g. the same line written
        // twice in one epoch, landing in the same MC slot — must not
        // confuse the group check: image reconstruction breaks ties by
        // issue sequence.
        let mut hist = TxnHistory::new(HashMap::new());
        let mut snap = HashMap::new();
        snap.insert(D0, 2u64);
        hist.commit(snap, 100);
        let mk = || {
            let mut l = DurabilityLog::new(true);
            l.record(DurEvent {
                addr: D0,
                val: 1,
                at: 100,
                thread: 0,
                txn: 0,
                epoch: 0,
                seq: 0,
            });
            l.record(DurEvent {
                addr: D0,
                val: 2,
                at: 100, // duplicate (txn, epoch) at the same instant
                thread: 0,
                txn: 0,
                epoch: 0,
                seq: 1,
            });
            l
        };
        let a = mk();
        let b = mk();
        check_group_epoch_ordering(&[&a, &b]).unwrap();
        for required in [1usize, 2] {
            let k =
                check_group_crash(&[&a, &b], &hist, &[], &[D0], required, 100).unwrap();
            assert_eq!(k, 1, "required {required}");
        }
        // Before the tie instant nothing is durable yet.
        let k = check_group_crash(&[&a, &b], &hist, &[], &[D0], 2, 99).unwrap();
        assert_eq!(k, 0);
    }

    #[test]
    fn rejoined_backup_with_replayed_suffix_passes_group_checks() {
        use crate::mem::DurEvent;
        // Simulate a dead-then-rejoined ledger: backup B misses txn 1's
        // writes and receives them replayed at the resync completion
        // instant (later than the source's persist times, identical
        // coordinates). The faulted check must accept the divergence.
        use crate::net::FaultTimeline;
        let (m, hist) = run_workload(StrategyKind::SmOb, 2);
        let full = &m.backup(0).ledger;
        let horizon = full.horizon();
        let kill_at = hist.dfences[0]; // dies right after txn 0 acked
        let ready_at = horizon + 50_000; // resync completes post-run
        let mut rejoined = DurabilityLog::new(true);
        for ev in full.events() {
            if ev.at <= kill_at {
                rejoined.record(*ev);
            } else {
                rejoined.record(DurEvent {
                    at: ready_at,
                    ..*ev
                });
            }
        }
        check_epoch_ordering(&rejoined).unwrap();
        let tl = FaultTimeline::new(
            2,
            vec![(kill_at, 1, false), (ready_at, 1, true)],
        );
        // Sweep the whole run including the outage window and the
        // post-resync instant.
        check_faulted_group_crashes(
            &[full, &rejoined],
            &hist,
            &[LOG],
            &[D0, D1],
            2,
            OnLoss::Degrade,
            &tl,
        )
        .expect("dead-then-rejoined ledger must be accepted");
    }

    #[test]
    fn sharded_group_crashes_pass_for_real_runs() {
        use crate::config::{AckPolicy, ReplicationConfig};
        use crate::coordinator::{ShardMapSpec, ShardingConfig};
        use crate::net::{FaultsConfig, OnLoss};
        for kind in [StrategyKind::SmRc, StrategyKind::SmOb, StrategyKind::SmDd] {
            let sharding = ShardingConfig::new(4, ShardMapSpec::Modulo);
            let mut m = Mirror::try_build_sharded(
                Platform::default(),
                kind,
                None,
                ReplicationConfig::new(2, AckPolicy::All),
                FaultsConfig::default(),
                sharding,
                true,
            )
            .unwrap();
            let hist = drive_txns(&mut m, 4);
            let ledgers = m.shard_ledgers();
            let checked = check_sharded_group_crashes(
                &ledgers,
                &m.timelines(),
                &hist,
                &[LOG],
                &[D0, D1],
                2,
                OnLoss::Halt,
                m.shard_map(),
            )
            .unwrap_or_else(|e| panic!("{kind:?}: {e}"));
            assert!(checked > 10, "{kind:?}: only {checked} crash points");
        }
    }

    #[test]
    fn sharded_check_fails_iff_some_shard_is_inconsistent() {
        use crate::config::{AckPolicy, ReplicationConfig};
        use crate::coordinator::{ShardMapSpec, ShardingConfig};
        use crate::net::{FaultsConfig, OnLoss};
        // 2 shards, 1 backup each. LOG/D0 land on shard 0, D1 on
        // shard 1 (line indices 0x4000, 0x8000 even; 0x8001 odd).
        let sharding = ShardingConfig::new(2, ShardMapSpec::Modulo);
        let map = sharding.build_map();
        assert_eq!(map.shard_of(D0), 0);
        assert_eq!(map.shard_of(D1), 1);
        let mut m = Mirror::try_build_sharded(
            Platform::default(),
            StrategyKind::SmOb,
            None,
            ReplicationConfig::new(1, AckPolicy::All),
            FaultsConfig::default(),
            sharding,
            true,
        )
        .unwrap();
        let hist = drive_txns(&mut m, 2);
        let crash = m
            .shard_ledgers()
            .iter()
            .flatten()
            .map(|l| l.horizon())
            .max()
            .unwrap();
        let tls = m.timelines();
        let good = m.shard_ledgers();
        let k = check_sharded_group_crash(
            &good, &tls, &hist, &[LOG], &[D0, D1], 1, OnLoss::Halt, &map, crash,
        )
        .expect("healthy shards must pass");
        assert_eq!(k, 2);
        // Replace shard 1's ledger with an empty one: shard 1's prefix
        // drops to 0 while shard 0 still holds everything, so the
        // merged verdict must fail even though shard 0 alone passes.
        let empty = DurabilityLog::new(true);
        let bad = vec![good[0].clone(), vec![&empty]];
        let err = check_sharded_group_crash(
            &bad, &tls, &hist, &[LOG], &[D0, D1], 1, OnLoss::Halt, &map, crash,
        );
        assert!(err.is_err(), "lagging shard must sink the merged verdict");
        // The intact shard alone (its owned addresses only) is fine.
        check_group_crash(&good[0], &hist, &[LOG], &[D0], 1, crash)
            .expect("shard 0 in isolation is consistent");
        // Shape errors are rejected.
        assert!(check_sharded_group_crash(
            &good,
            &tls[..1],
            &hist,
            &[LOG],
            &[D0, D1],
            1,
            OnLoss::Halt,
            &map,
            crash
        )
        .is_err());
        assert!(check_sharded_group_crash(
            &good,
            &tls,
            &hist,
            &[LOG],
            &[D0, D1],
            1,
            OnLoss::Halt,
            &ShardMap::single(),
            crash
        )
        .is_err());
    }

    #[test]
    fn leader_completeness_checks_the_elected_prefix() {
        use crate::net::FaultTimeline;
        let (m, hist) = run_workload(StrategyKind::SmOb, 3);
        let full = &m.backup(0).ledger;
        let at = hist.dfences[1]; // failover right after txn 1 acked
        // A winner holding the full certified ledger is complete.
        let tl = FaultTimeline::new(2, Vec::new()).with_epochs(vec![(at, 1, 0)]);
        let checked =
            check_leader_completeness(&[full, full], &hist, &[LOG], &[D0, D1], &tl)
                .unwrap();
        assert_eq!(checked, 1);
        // An empty ledger promoted to leader cannot cover the acked txns.
        let empty = DurabilityLog::new(true);
        let tl_bad =
            FaultTimeline::new(2, Vec::new()).with_epochs(vec![(at, 1, 1)]);
        let err = check_leader_completeness(
            &[full, &empty],
            &hist,
            &[LOG],
            &[D0, D1],
            &tl_bad,
        )
        .unwrap_err();
        assert!(
            err.to_string().contains("leader completeness violated"),
            "unexpected error: {err}"
        );
        // A winner slot outside the group is a shape error.
        let tl_oob =
            FaultTimeline::new(2, Vec::new()).with_epochs(vec![(at, 1, 5)]);
        assert!(check_leader_completeness(
            &[full, full],
            &hist,
            &[LOG],
            &[D0, D1],
            &tl_oob
        )
        .is_err());
        // No epoch transitions: trivially complete, zero checks.
        let tl_none = FaultTimeline::new(2, Vec::new());
        let checked = check_leader_completeness(
            &[full, full],
            &hist,
            &[LOG],
            &[D0, D1],
            &tl_none,
        )
        .unwrap();
        assert_eq!(checked, 0);
    }

    #[test]
    fn sharded_leader_completeness_merges_the_winner_images() {
        use crate::mem::DurEvent;
        use crate::net::FaultTimeline;
        // One txn writing D0 (shard 0) and D1 (shard 1); the winner's
        // state only covers the acked txn when both shard images merge.
        let mut hist = TxnHistory::new(HashMap::new());
        let mut snap = HashMap::new();
        snap.insert(D0, 7u64);
        snap.insert(D1, 9u64);
        hist.commit(snap, 100);
        let mk = |addr, val| {
            let mut l = DurabilityLog::new(true);
            l.record(DurEvent {
                addr,
                val,
                at: 90,
                thread: 0,
                txn: 0,
                epoch: 0,
                seq: 0,
            });
            l
        };
        let s0 = mk(D0, 7);
        let s1 = mk(D1, 9);
        let epochs = vec![(200u64, 1u64, 0usize)];
        let tls = vec![
            FaultTimeline::new(1, Vec::new()).with_epochs(epochs.clone()),
            FaultTimeline::new(1, Vec::new()).with_epochs(epochs.clone()),
        ];
        let groups = vec![vec![&s0], vec![&s1]];
        let checked = check_sharded_leader_completeness(
            &groups,
            &tls,
            &hist,
            &[],
            &[D0, D1],
        )
        .unwrap();
        assert_eq!(checked, 1);
        // A shard whose winner image is missing sinks completeness.
        let empty = DurabilityLog::new(true);
        let groups_bad = vec![vec![&s0], vec![&empty]];
        assert!(check_sharded_leader_completeness(
            &groups_bad,
            &tls,
            &hist,
            &[],
            &[D0, D1]
        )
        .is_err());
        // Diverging per-shard epoch logs are a shape error.
        let tls_bad = vec![
            FaultTimeline::new(1, Vec::new()).with_epochs(epochs),
            FaultTimeline::new(1, Vec::new()),
        ];
        let err = check_sharded_leader_completeness(
            &groups,
            &tls_bad,
            &hist,
            &[],
            &[D0, D1],
        )
        .unwrap_err();
        assert!(
            err.to_string().contains("fail over as one unit"),
            "unexpected error: {err}"
        );
    }

    #[test]
    fn faulted_verdicts_carry_the_membership_epoch() {
        use crate::net::FaultTimeline;
        // A lagging required backup under epoch 1: the durability verdict
        // must name the epoch in force at the crash instant.
        let (m, hist) = run_workload(StrategyKind::SmOb, 2);
        let full = &m.backup(0).ledger;
        let empty = DurabilityLog::new(true);
        let crash = full.horizon();
        let tl = FaultTimeline::new(2, Vec::new())
            .with_epochs(vec![(0, 1, 0)]);
        let err = check_faulted_group_crash(
            &[full, &empty],
            &hist,
            &[LOG],
            &[D0, D1],
            2,
            OnLoss::Halt,
            &tl,
            crash,
        )
        .unwrap_err();
        assert!(
            err.to_string().contains("membership epoch 1"),
            "verdict lacks the epoch dimension: {err}"
        );
    }

    #[test]
    fn recovery_rolls_back_active_log() {
        // Crash right before the commit of txn 2 (data written, log still
        // active): recovery must restore txn-1 values.
        let (m, hist) = run_workload(StrategyKind::SmDd, 2);
        let ledger = &m.backup(0).ledger;
        // Find a crash point where txn 1 (0-based) data is durable but its
        // commit (log invalidation) is not: just before the last event.
        let evs = ledger.events();
        let last = evs.iter().map(|e| e.at).max().unwrap();
        let k = check_crash(ledger, &hist, &[LOG], &[D0, D1], last - 1).unwrap();
        assert!(k <= 2);
        // At the very end everything is durable.
        let k = check_crash(ledger, &hist, &[LOG], &[D0, D1], last).unwrap();
        assert_eq!(k, 2);
    }
}
