//! SM-AD: model-driven adaptive strategy (our extension, motivated by the
//! paper's observation that "SM-OB and SM-DD are suitable to different
//! kinds of transactions").
//!
//! At each transaction begin, SM-AD consults a latency predictor — in
//! production wiring, the AOT-compiled JAX/Pallas model executed through
//! PJRT ([`crate::runtime`]) — with the transaction's shape hint
//! (epochs/txn, writes/epoch) and adopts SM-OB or SM-DD behaviour for the
//! whole transaction. Mixing per transaction is safe: both strategies'
//! durability fences cover all prior writes of the thread regardless of
//! the path each write took.

use super::{Strategy, TxnShape};
use crate::config::StrategyKind;
use crate::net::{Fabric, WriteMeta};
use crate::sim::ThreadClock;

/// Latency predictor: `(epochs, writes) -> (lat_ob_ns, lat_dd_ns)`.
pub type Predictor = Box<dyn Fn(f32, f32) -> (f32, f32)>;

/// Behaviour adopted for the current transaction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Mode {
    Ob,
    Dd,
}

/// Model-driven adaptive OB/DD strategy.
pub struct SmAd {
    predictor: Predictor,
    mode: Mode,
    /// Stats: transactions routed to each mode.
    pub chose_ob: u64,
    pub chose_dd: u64,
}

impl SmAd {
    pub fn new(predictor: Predictor) -> Self {
        SmAd {
            predictor,
            mode: Mode::Dd,
            chose_ob: 0,
            chose_dd: 0,
        }
    }
}

impl Strategy for SmAd {
    fn kind(&self) -> StrategyKind {
        StrategyKind::SmAd
    }

    fn on_txn_begin(
        &mut self,
        _fabric: &mut Fabric,
        _t: &mut ThreadClock,
        hint: Option<TxnShape>,
    ) {
        if let Some(shape) = hint {
            let (ob, dd) = (self.predictor)(shape.epochs, shape.writes);
            self.mode = if ob < dd { Mode::Ob } else { Mode::Dd };
        }
        match self.mode {
            Mode::Ob => self.chose_ob += 1,
            Mode::Dd => self.chose_dd += 1,
        }
    }

    fn on_clwb(&mut self, f: &mut Fabric, t: &mut ThreadClock, m: WriteMeta) {
        match self.mode {
            Mode::Ob => f.post_write_wt(t, m),
            Mode::Dd => f.post_write_nt(t, m),
        }
    }

    fn on_ofence(&mut self, f: &mut Fabric, t: &mut ThreadClock) {
        if self.mode == Mode::Ob {
            f.rofence(t);
        }
    }

    fn on_dfence(&mut self, f: &mut Fabric, t: &mut ThreadClock) {
        match self.mode {
            Mode::Ob => f.rdfence(t),
            Mode::Dd => f.read_fence(t),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Platform;

    fn meta(addr: u64, epoch: u32, seq: u64) -> WriteMeta {
        WriteMeta {
            addr,
            val: seq,
            thread: 0,
            txn: 0,
            epoch,
            seq,
        }
    }

    #[test]
    fn picks_mode_from_predictor() {
        // Predictor: OB wins iff epochs > 64.
        let mut s = SmAd::new(Box::new(|e, _w| {
            if e > 64.0 {
                (1.0, 2.0)
            } else {
                (2.0, 1.0)
            }
        }));
        let mut r = Fabric::single(&Platform::default(), true);
        let mut t = ThreadClock::new(0);

        s.on_txn_begin(&mut r, &mut t, Some(TxnShape { epochs: 256.0, writes: 1.0 }));
        assert_eq!(s.mode, Mode::Ob);
        s.on_txn_begin(&mut r, &mut t, Some(TxnShape { epochs: 4.0, writes: 1.0 }));
        assert_eq!(s.mode, Mode::Dd);
        assert_eq!((s.chose_ob, s.chose_dd), (1, 1));
    }

    #[test]
    fn no_hint_keeps_previous_mode() {
        let mut s = SmAd::new(Box::new(|_, _| (1.0, 2.0)));
        let mut r = Fabric::single(&Platform::default(), true);
        let mut t = ThreadClock::new(0);
        s.on_txn_begin(&mut r, &mut t, Some(TxnShape { epochs: 1.0, writes: 1.0 }));
        assert_eq!(s.mode, Mode::Ob);
        s.on_txn_begin(&mut r, &mut t, None);
        assert_eq!(s.mode, Mode::Ob);
    }

    #[test]
    fn mixed_modes_still_replicate_everything() {
        let mut s = SmAd::new(Box::new(|e, _| if e > 2.0 { (1.0, 2.0) } else { (2.0, 1.0) }));
        let mut r = Fabric::single(&Platform::default(), true);
        let mut t = ThreadClock::new(0);
        // Txn 1 -> DD mode; txn 2 -> OB mode.
        for (txn, epochs) in [(0u64, 1.0f32), (1, 8.0)] {
            s.on_txn_begin(
                &mut r,
                &mut t,
                Some(TxnShape { epochs, writes: 1.0 }),
            );
            for epoch in 0..2u32 {
                s.on_clwb(&mut r, &mut t, meta(0x40 * (1 + txn * 2 + epoch as u64), epoch, 0));
                s.on_ofence(&mut r, &mut t);
            }
            s.on_dfence(&mut r, &mut t);
        }
        assert_eq!(r.backup(0).ledger.len(), 4);
    }
}
