//! SM-AD: model-driven adaptive strategy (our extension, motivated by the
//! paper's observation that "SM-OB and SM-DD are suitable to different
//! kinds of transactions").
//!
//! At each transaction begin, SM-AD consults a latency predictor — in
//! production wiring, the AOT-compiled JAX/Pallas model executed through
//! PJRT ([`crate::runtime`]) — with the transaction's shape hint
//! (epochs/txn, writes/epoch) and adopts SM-OB or SM-DD behaviour for the
//! whole transaction. Mixing per transaction is safe: both strategies'
//! durability fences cover all prior writes of the thread regardless of
//! the path each write took.
//!
//! # Online adaptive control plane
//!
//! With an attached [`ControlPlane`] (opt-in via the `[adaptive]` config
//! section), SM-AD grows from a binary OB/DD chooser into a per-class
//! knob-vector controller. At every transaction begin it picks, per
//! transaction class `(epochs, writes)`:
//!
//! * the replication **mode** (OB or DD),
//! * the **ack quorum** `k` — clamped to `[configured policy, backups]`
//!   so the user's durability floor can only be raised, never weakened,
//! * the doorbell **batch cap** for the staged WQE pipeline.
//!
//! Candidates are scored with the knob-aware analytic model
//! `predict(epochs, writes, backups, quorum, batch_cap)`
//! ([`crate::runtime::fallback_knob_predictor`]). Online feedback
//! corrects the model: per `(class, knob-cell)` EWMAs of measured
//! steady-state commit latency replace the model's prediction for cells
//! that have samples, and a per-class scalar correction (EWMA of
//! measured/predicted) transfers the observed scale error to unmeasured
//! cells. A hysteresis guard keeps the current cell unless a challenger
//! is better by more than `hysteresis_pct`, so decisions do not thrash
//! on noise.
//!
//! The chosen knobs are applied through the fabric's per-transaction
//! overrides ([`Fabric::set_txn_quorum`], [`Fabric::set_txn_batch_cap`]);
//! both are clamped at the fabric so no decision can violate the
//! configured durability floor or the coalescing invariants. With the
//! control plane absent (`[adaptive]` disabled — the default), the
//! legacy two-input predictor path runs unchanged, event for event.

use super::{DecisionStats, Strategy, TxnShape};
use crate::config::{AdaptiveConfig, StrategyKind};
use crate::net::{Fabric, WriteMeta};
use crate::sim::ThreadClock;
use crate::Ns;

/// Latency predictor: `(epochs, writes) -> (lat_ob_ns, lat_dd_ns)`.
pub type Predictor = Box<dyn Fn(f32, f32) -> (f32, f32)>;

/// Knob-aware latency predictor for the adaptive control plane:
/// `(epochs, writes, backups, quorum, batch_cap) -> (lat_ob_ns, lat_dd_ns)`.
pub type KnobPredictor = Box<dyn Fn(f32, f32, f32, f32, f32) -> (f32, f32)>;

/// Doorbell batch caps the controller considers. Ascending so score ties
/// break toward the smallest cap (staging defers wire issue; when the
/// model sees no benefit, prefer the eager-most choice).
const CAP_CANDIDATES: [usize; 3] = [1, 8, 32];

/// Behaviour adopted for the current transaction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Mode {
    Ob,
    Dd,
}

/// One point of the per-transaction knob grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct Knobs {
    mode: Mode,
    quorum: usize,
    cap: usize,
}

/// Per-(class, knob-cell) feedback state: the model's latest prediction
/// and the EWMA of measured commit latency when this cell was live.
#[derive(Clone, Debug)]
struct Cell {
    knobs: Knobs,
    pred: f32,
    ewma: f32,
    samples: u64,
}

/// Per-transaction-class controller state. Classes are keyed by the
/// rounded shape hint and stored in a Vec (a workload has a handful of
/// classes; linear scan keeps iteration order deterministic).
#[derive(Clone, Debug)]
struct ClassState {
    key: (u32, u32),
    cells: Vec<Cell>,
    /// Scalar model correction: EWMA of measured/predicted over this
    /// class's feedback. Applied to cells with no samples of their own
    /// so a consistently optimistic model is corrected everywhere, not
    /// only where the controller already dwelled.
    corr: f32,
    current: Option<Knobs>,
}

impl ClassState {
    fn new(key: (u32, u32)) -> Self {
        ClassState {
            key,
            cells: Vec::new(),
            corr: 1.0,
            current: None,
        }
    }

    /// Score a candidate cell: measured EWMA when the cell has samples,
    /// otherwise the model prediction scaled by the class correction.
    fn score(&self, knobs: Knobs, pred: f32, feedback: bool) -> f32 {
        if !feedback {
            return pred;
        }
        if let Some(cell) = self.cells.iter().find(|c| c.knobs == knobs) {
            if cell.samples > 0 {
                return cell.ewma;
            }
        }
        pred * self.corr
    }

    /// Record a decision: remember the chosen cell's latest prediction
    /// (the denominator for feedback error accounting).
    fn note_decision(&mut self, knobs: Knobs, pred: f32) {
        match self.cells.iter_mut().find(|c| c.knobs == knobs) {
            Some(cell) => cell.pred = pred,
            None => self.cells.push(Cell {
                knobs,
                pred,
                ewma: 0.0,
                samples: 0,
            }),
        }
        self.current = Some(knobs);
    }
}

/// Everything the online controller needs beyond the legacy predictor:
/// the adaptive config, the knob-aware model, and the replica-group
/// shape (backup count + configured ack floor).
pub struct ControlPlane {
    pub cfg: AdaptiveConfig,
    pub model: KnobPredictor,
    /// Replica-group size the controller tunes for.
    pub backups: usize,
    /// Configured ack-policy requirement: the durability floor. Quorum
    /// candidates range over `floor..=backups`.
    pub floor: usize,
}

impl ControlPlane {
    pub fn new(cfg: AdaptiveConfig, model: KnobPredictor, backups: usize, floor: usize) -> Self {
        let backups = backups.max(1);
        ControlPlane {
            cfg,
            model,
            backups,
            floor: floor.clamp(1, backups),
        }
    }
}

/// Model-driven adaptive OB/DD strategy.
pub struct SmAd {
    predictor: Predictor,
    mode: Mode,
    /// Stats: transactions routed to each mode.
    pub chose_ob: u64,
    pub chose_dd: u64,
    /// Online control plane (None = legacy binary chooser, the anchor).
    ctl: Option<ControlPlane>,
    classes: Vec<ClassState>,
    /// Knob vector most recently applied to the fabric (across classes);
    /// a decision that changes it counts as one adaptive switch.
    applied: Option<Knobs>,
    adaptive_switches: u64,
    /// Decision histogram over the chosen ack quorum (index = k).
    quorum_hist: Vec<u64>,
    /// Decision histogram over the chosen batch cap, sorted by cap.
    cap_hist: Vec<(usize, u64)>,
    feedback_samples: u64,
    /// Sum over feedback samples of |measured - predicted|/predicted in
    /// percent: the model-vs-measured error the reports surface.
    err_pct_sum: f64,
}

impl SmAd {
    pub fn new(predictor: Predictor) -> Self {
        SmAd {
            predictor,
            mode: Mode::Dd,
            chose_ob: 0,
            chose_dd: 0,
            ctl: None,
            classes: Vec::new(),
            applied: None,
            adaptive_switches: 0,
            quorum_hist: Vec::new(),
            cap_hist: Vec::new(),
            feedback_samples: 0,
            err_pct_sum: 0.0,
        }
    }

    /// Attach the online control plane (callers gate on
    /// `AdaptiveConfig::enabled`; attaching a disabled config is
    /// equivalent to [`SmAd::new`] except decisions re-derive the mode
    /// from the knob model).
    pub fn with_control(predictor: Predictor, ctl: ControlPlane) -> Self {
        let mut s = SmAd::new(predictor);
        s.ctl = Some(ctl);
        s
    }

    fn class_index(&mut self, key: (u32, u32)) -> usize {
        match self.classes.iter().position(|c| c.key == key) {
            Some(i) => i,
            None => {
                self.classes.push(ClassState::new(key));
                self.classes.len() - 1
            }
        }
    }

    fn count_decision(&mut self, knobs: Knobs) {
        match knobs.mode {
            Mode::Ob => self.chose_ob += 1,
            Mode::Dd => self.chose_dd += 1,
        }
        if self.quorum_hist.len() <= knobs.quorum {
            self.quorum_hist.resize(knobs.quorum + 1, 0);
        }
        self.quorum_hist[knobs.quorum] += 1;
        match self.cap_hist.iter_mut().find(|(c, _)| *c == knobs.cap) {
            Some((_, n)) => *n += 1,
            None => {
                self.cap_hist.push((knobs.cap, 1));
                self.cap_hist.sort_unstable_by_key(|(c, _)| *c);
            }
        }
    }

    /// The full adaptive decision for one transaction begin.
    fn decide(&mut self, fabric: &mut Fabric, shape: TxnShape) {
        let (e, w) = (shape.epochs, shape.writes);
        let key = (e.round() as u32, w.round() as u32);
        let ci = self.class_index(key);

        let ctl = self.ctl.as_ref().expect("decide requires a control plane");
        let quorums: Vec<usize> = if ctl.cfg.quorum && ctl.backups > ctl.floor {
            (ctl.floor..=ctl.backups).collect()
        } else {
            vec![ctl.floor]
        };
        let caps: Vec<usize> = if ctl.cfg.batch {
            CAP_CANDIDATES.to_vec()
        } else {
            vec![fabric.model_batch_cap(w).round().max(1.0) as usize]
        };

        let class = &self.classes[ci];
        // Enumerate the grid; strict `<` means the first of a tie wins,
        // so ordering (quorum asc, cap asc, DD before OB) encodes the
        // tie-breaks: lowest quorum, lowest cap, DD (matching the legacy
        // `ob < dd` comparison).
        let mut best: Option<(Knobs, f32, f32)> = None;
        let mut cur: Option<(f32, f32)> = None;
        for &k in &quorums {
            for &c in &caps {
                let (ob, dd) = (ctl.model)(e, w, ctl.backups as f32, k as f32, c as f32);
                for (mode, pred) in [(Mode::Dd, dd), (Mode::Ob, ob)] {
                    let knobs = Knobs { mode, quorum: k, cap: c };
                    let score = class.score(knobs, pred, ctl.cfg.feedback);
                    if class.current == Some(knobs) {
                        cur = Some((score, pred));
                    }
                    if best.as_ref().map_or(true, |b| score < b.1) {
                        best = Some((knobs, score, pred));
                    }
                }
            }
        }
        let (best_knobs, best_score, best_pred) =
            best.expect("knob grid is never empty");

        // Hysteresis: abandon the incumbent cell only when the best
        // challenger beats its score by more than the guard band.
        let (chosen, chosen_pred) = match (class.current, cur) {
            (Some(inc), Some((inc_score, inc_pred)))
                if best_knobs != inc
                    && best_score >= inc_score * (1.0 - ctl.cfg.guard()) =>
            {
                (inc, inc_pred)
            }
            _ => (best_knobs, best_pred),
        };

        let apply_quorum = ctl.cfg.quorum;
        let apply_cap = ctl.cfg.batch;
        self.classes[ci].note_decision(chosen, chosen_pred);
        if self.applied != Some(chosen) {
            if self.applied.is_some() {
                self.adaptive_switches += 1;
            }
            self.applied = Some(chosen);
        }

        self.mode = chosen.mode;
        if apply_quorum {
            fabric.set_txn_quorum(Some(chosen.quorum));
        }
        if apply_cap {
            fabric.set_txn_batch_cap(Some(chosen.cap));
        }
        self.count_decision(chosen);
    }
}

impl Strategy for SmAd {
    fn kind(&self) -> StrategyKind {
        StrategyKind::SmAd
    }

    fn on_txn_begin(
        &mut self,
        fabric: &mut Fabric,
        _t: &mut ThreadClock,
        hint: Option<TxnShape>,
    ) {
        if self.ctl.is_none() {
            // Legacy binary chooser — the `[adaptive]`-disabled anchor.
            if let Some(shape) = hint {
                let (ob, dd) = (self.predictor)(shape.epochs, shape.writes);
                self.mode = if ob < dd { Mode::Ob } else { Mode::Dd };
            }
            match self.mode {
                Mode::Ob => self.chose_ob += 1,
                Mode::Dd => self.chose_dd += 1,
            }
            return;
        }
        match hint {
            Some(shape) => self.decide(fabric, shape),
            None => {
                // No shape: keep the previous knob vector (overrides are
                // sticky on the fabric) and count the mode dwell.
                match self.mode {
                    Mode::Ob => self.chose_ob += 1,
                    Mode::Dd => self.chose_dd += 1,
                }
            }
        }
    }

    fn on_txn_end(&mut self, hint: Option<TxnShape>, commit_ns: Ns) {
        let Some(ctl) = self.ctl.as_ref() else { return };
        if !ctl.cfg.feedback {
            return;
        }
        let Some(shape) = hint else { return };
        let alpha = ctl.cfg.alpha();
        let key = (shape.epochs.round() as u32, shape.writes.round() as u32);
        let Some(class) = self.classes.iter_mut().find(|c| c.key == key) else {
            return;
        };
        let Some(current) = class.current else { return };
        let Some(cell) = class.cells.iter_mut().find(|c| c.knobs == current) else {
            return;
        };
        let measured = commit_ns as f32;
        if cell.samples == 0 {
            cell.ewma = measured;
        } else {
            cell.ewma += alpha * (measured - cell.ewma);
        }
        cell.samples += 1;
        if cell.pred > 0.0 {
            let ratio = measured / cell.pred;
            class.corr += alpha * (ratio - class.corr);
            self.err_pct_sum += ((measured - cell.pred).abs() / cell.pred * 100.0) as f64;
        }
        self.feedback_samples += 1;
    }

    fn decision_stats(&self) -> DecisionStats {
        DecisionStats {
            chose_ob: self.chose_ob,
            chose_dd: self.chose_dd,
            adaptive_switches: self.adaptive_switches,
            quorum_hist: self.quorum_hist.clone(),
            cap_hist: self.cap_hist.clone(),
            feedback_samples: self.feedback_samples,
            err_pct_sum: self.err_pct_sum,
        }
    }

    fn on_clwb(&mut self, f: &mut Fabric, t: &mut ThreadClock, m: WriteMeta) {
        match self.mode {
            Mode::Ob => f.post_write_wt(t, m),
            Mode::Dd => f.post_write_nt(t, m),
        }
    }

    fn on_ofence(&mut self, f: &mut Fabric, t: &mut ThreadClock) {
        if self.mode == Mode::Ob {
            f.rofence(t);
        }
    }

    fn on_dfence(&mut self, f: &mut Fabric, t: &mut ThreadClock) {
        match self.mode {
            Mode::Ob => f.rdfence(t),
            Mode::Dd => f.read_fence(t),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AckPolicy, Platform, ReplicationConfig};
    use crate::runtime::fallback_knob_predictor;

    fn meta(addr: u64, epoch: u32, seq: u64) -> WriteMeta {
        WriteMeta {
            addr,
            val: seq,
            thread: 0,
            txn: 0,
            epoch,
            seq,
        }
    }

    #[test]
    fn picks_mode_from_predictor() {
        // Predictor: OB wins iff epochs > 64.
        let mut s = SmAd::new(Box::new(|e, _w| {
            if e > 64.0 {
                (1.0, 2.0)
            } else {
                (2.0, 1.0)
            }
        }));
        let mut r = Fabric::single(&Platform::default(), true);
        let mut t = ThreadClock::new(0);

        s.on_txn_begin(&mut r, &mut t, Some(TxnShape { epochs: 256.0, writes: 1.0 }));
        assert_eq!(s.mode, Mode::Ob);
        s.on_txn_begin(&mut r, &mut t, Some(TxnShape { epochs: 4.0, writes: 1.0 }));
        assert_eq!(s.mode, Mode::Dd);
        assert_eq!((s.chose_ob, s.chose_dd), (1, 1));
    }

    #[test]
    fn no_hint_keeps_previous_mode() {
        let mut s = SmAd::new(Box::new(|_, _| (1.0, 2.0)));
        let mut r = Fabric::single(&Platform::default(), true);
        let mut t = ThreadClock::new(0);
        s.on_txn_begin(&mut r, &mut t, Some(TxnShape { epochs: 1.0, writes: 1.0 }));
        assert_eq!(s.mode, Mode::Ob);
        s.on_txn_begin(&mut r, &mut t, None);
        assert_eq!(s.mode, Mode::Ob);
    }

    #[test]
    fn mixed_modes_still_replicate_everything() {
        let mut s = SmAd::new(Box::new(|e, _| if e > 2.0 { (1.0, 2.0) } else { (2.0, 1.0) }));
        let mut r = Fabric::single(&Platform::default(), true);
        let mut t = ThreadClock::new(0);
        // Txn 1 -> DD mode; txn 2 -> OB mode.
        for (txn, epochs) in [(0u64, 1.0f32), (1, 8.0)] {
            s.on_txn_begin(
                &mut r,
                &mut t,
                Some(TxnShape { epochs, writes: 1.0 }),
            );
            for epoch in 0..2u32 {
                s.on_clwb(&mut r, &mut t, meta(0x40 * (1 + txn * 2 + epoch as u64), epoch, 0));
                s.on_ofence(&mut r, &mut t);
            }
            s.on_dfence(&mut r, &mut t);
        }
        assert_eq!(r.backup(0).ledger.len(), 4);
    }

    // --- control-plane tests ---

    fn group(backups: usize, ack_policy: AckPolicy) -> Fabric {
        let repl = ReplicationConfig { backups, ack_policy };
        Fabric::new(&Platform::default(), &repl, true)
    }

    fn ctl_for(fabric: &Fabric, cfg: AdaptiveConfig) -> ControlPlane {
        ControlPlane::new(
            cfg,
            fallback_knob_predictor(&Platform::default()),
            fabric.backups(),
            fabric.required(),
        )
    }

    #[test]
    fn control_plane_converges_per_class() {
        // Phase-pure classes at backups=2: latency-sensitive small txns
        // want DD/cap=1, bulk appends and hot-line streams want OB with a
        // large cap (the staged pipeline amortizes doorbells).
        let mut r = group(2, AckPolicy::Quorum(1));
        let mut t = ThreadClock::new(0);
        let mut s = SmAd::with_control(
            Box::new(|_, _| (0.0, 0.0)),
            ctl_for(&r, AdaptiveConfig::enabled()),
        );

        s.on_txn_begin(&mut r, &mut t, Some(TxnShape { epochs: 4.0, writes: 1.0 }));
        assert_eq!(s.mode, Mode::Dd, "small txns: DD (RTT-dominated OB tail)");
        assert_eq!(r.txn_batch_cap(), Some(1), "small txns: eager flush");

        s.on_txn_begin(&mut r, &mut t, Some(TxnShape { epochs: 1.0, writes: 64.0 }));
        assert_eq!(s.mode, Mode::Ob, "bulk append: OB");
        assert_eq!(r.txn_batch_cap(), Some(32), "bulk append: batch doorbells");

        s.on_txn_begin(&mut r, &mut t, Some(TxnShape { epochs: 64.0, writes: 2.0 }));
        assert_eq!(s.mode, Mode::Ob, "hot-line stream: OB");
        assert_eq!(r.txn_batch_cap(), Some(32));

        let stats = s.decision_stats();
        assert_eq!(stats.chose_ob + stats.chose_dd, 3);
        // Bulk append and hot-line stream share the same knob vector
        // (OB / floor quorum / cap 32), so only the DD -> OB boundary
        // counts as an applied switch.
        assert_eq!(stats.adaptive_switches, 1, "one knob-vector change");
    }

    #[test]
    fn quorum_candidates_never_undercut_the_floor() {
        // Policy requires 2 of 3: the controller may only pick k in 2..=3.
        let mut r = group(3, AckPolicy::Quorum(2));
        let mut t = ThreadClock::new(0);
        let mut s = SmAd::with_control(
            Box::new(|_, _| (0.0, 0.0)),
            ctl_for(&r, AdaptiveConfig::enabled()),
        );
        for shape in [
            TxnShape { epochs: 4.0, writes: 1.0 },
            TxnShape { epochs: 1.0, writes: 64.0 },
            TxnShape { epochs: 64.0, writes: 2.0 },
        ] {
            s.on_txn_begin(&mut r, &mut t, Some(shape));
            let k = r.txn_quorum().expect("quorum override applied");
            assert!(k >= 2 && k <= 3, "quorum {k} outside [floor, backups]");
        }
        let stats = s.decision_stats();
        for (k, n) in stats.quorum_hist.iter().enumerate() {
            assert!(k >= 2 || *n == 0, "decision below the floor: k={k} n={n}");
        }
    }

    #[test]
    fn feedback_overrides_a_wrong_model() {
        // Model claims OB is far cheaper for this class; measured latency
        // says the DD cell (which the controller must first be steered
        // into) is 10x better. Steer via measured feedback on the OB cell.
        let mut r = group(2, AckPolicy::All);
        let mut t = ThreadClock::new(0);
        let cfg = AdaptiveConfig {
            quorum: false,
            batch: false,
            ..AdaptiveConfig::enabled()
        };
        let shape = TxnShape { epochs: 8.0, writes: 8.0 };
        let mut s = SmAd::with_control(
            Box::new(|_, _| (0.0, 0.0)),
            ControlPlane::new(
                cfg,
                Box::new(|_, _, _, _, _| (1_000.0, 1_100.0)),
                r.backups(),
                r.required(),
            ),
        );
        s.on_txn_begin(&mut r, &mut t, Some(shape));
        assert_eq!(s.mode, Mode::Ob, "model routes to OB");
        // Measured commit latency is terrible: the OB cell's EWMA grows
        // past the (corrected) DD prediction and the controller flips.
        for _ in 0..8 {
            s.on_txn_end(Some(shape), 50_000);
            s.on_txn_begin(&mut r, &mut t, Some(shape));
        }
        assert_eq!(s.mode, Mode::Dd, "feedback overrode the wrong model");
        assert!(s.decision_stats().adaptive_switches >= 1);
        assert!(s.decision_stats().feedback_samples == 8);
    }

    #[test]
    fn hysteresis_holds_near_ties() {
        // Two cells within the 10% guard band: the incumbent must hold
        // even when the challenger's model score is slightly lower.
        let mut r = group(1, AckPolicy::All);
        let mut t = ThreadClock::new(0);
        let cfg = AdaptiveConfig {
            quorum: false,
            batch: false,
            feedback: false,
            ..AdaptiveConfig::enabled()
        };
        let shape = TxnShape { epochs: 2.0, writes: 2.0 };
        // First decision: DD wins (999 > 1000 is false: dd=999 < ob=1000).
        // Every later decision sees OB at 950 — 4.9% better, inside the
        // 10% band — so DD must hold.
        let calls = std::cell::Cell::new(0u32);
        let mut s = SmAd::with_control(
            Box::new(|_, _| (0.0, 0.0)),
            ControlPlane::new(
                cfg,
                Box::new(move |_, _, _, _, _| {
                    let n = calls.get();
                    calls.set(n + 1);
                    if n == 0 { (1_000.0, 999.0) } else { (950.0, 999.0) }
                }),
                1,
                1,
            ),
        );
        s.on_txn_begin(&mut r, &mut t, Some(shape));
        assert_eq!(s.mode, Mode::Dd);
        for _ in 0..4 {
            s.on_txn_begin(&mut r, &mut t, Some(shape));
            assert_eq!(s.mode, Mode::Dd, "hysteresis must hold inside the band");
        }
        assert_eq!(s.decision_stats().adaptive_switches, 0);
    }

    #[test]
    fn disabled_control_plane_touches_no_overrides() {
        let mut r = group(2, AckPolicy::All);
        let mut t = ThreadClock::new(0);
        let mut s = SmAd::new(Box::new(|_, _| (1.0, 2.0)));
        s.on_txn_begin(&mut r, &mut t, Some(TxnShape { epochs: 4.0, writes: 4.0 }));
        assert_eq!(r.txn_quorum(), None);
        assert_eq!(r.txn_batch_cap(), None);
        let stats = s.decision_stats();
        assert!(stats.quorum_hist.is_empty() && stats.cap_hist.is_empty());
        assert_eq!(stats.adaptive_switches, 0);
    }
}
